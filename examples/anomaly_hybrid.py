"""Anomaly-detection use case (§7.1.1), end to end through the SERVING
stack: packet trace -> data-plane feature extraction -> fused switch
classifier -> capacity-bounded dispatch of low-confidence flows to the
backend. Prints the paper's telemetry.

    PYTHONPATH=src python examples/anomaly_hybrid.py
"""

import jax.numpy as jnp
import numpy as np

from repro.core.mapping import map_tree_ensemble
from repro.data.unsw_like import make_unsw_like, train_test_split
from repro.ml.metrics import accuracy, precision_recall_f1
from repro.ml.trees import fit_random_forest, predict_tree_ensemble
from repro.netsim.features import flow_features, packet_features
from repro.netsim.packets import synth_trace
from repro.serving.hybrid_serving import HybridServer

# --- offline: train switch + backend on historical flow records ------------
x, y = make_unsw_like(16000, n_features=5, seed=0)
xtr, ytr, xte, yte = train_test_split(x, y)
switch_model = fit_random_forest(xtr, ytr, n_classes=2, n_trees=10,
                                 max_depth=5, seed=0)
backend_model = fit_random_forest(xtr, ytr, n_classes=2, n_trees=40,
                                  max_depth=8, seed=1, max_features=5)
artifact = map_tree_ensemble(switch_model, n_features=5)

server = HybridServer(
    artifact,
    backend_fn=lambda rows: predict_tree_ensemble(backend_model, rows),
    threshold=0.7, capacity=512)

# --- online: packets hit the data plane -------------------------------------
trace = synth_trace(n_flows=3000, seed=42)
print(f"trace: {trace.n_packets} packets, {trace.n_flows} flows")

# stateless parser features + stateful flow registers (hash + segment sums)
pkt = packet_features(trace)
bucket, flow_tab = flow_features(trace, n_buckets=1 << 14)

# per-flow feature rows in the §7.2 layout (sport,dsport,proto,~svc,eq)
first = np.unique(np.asarray(trace.flow_id), return_index=True)[1]
rows = np.stack([
    np.asarray(trace.sport, np.float32)[first],
    np.asarray(trace.dport, np.float32)[first],
    np.asarray(trace.proto, np.float32)[first],
    np.minimum(np.asarray(trace.dport, np.float32)[first] % 13, 12),
    (np.asarray(trace.sport)[first] ==
     np.asarray(trace.dport)[first]).astype(np.float32),
], axis=1)

pred, stats = server.classify(jnp.asarray(rows))
labels = trace.flow_label
print(f"handled at switch: {stats.fraction_handled * 100:.1f}%  "
      f"(backend saw {stats.backend_rows}/{len(rows)} flows)")
print(f"accuracy {accuracy(labels, pred):.4f}  "
      f"P/R/F1 {precision_recall_f1(labels, pred)}")
print("anomalous flows dropped at line rate; "
      f"{int((np.asarray(pred) == 1).sum())} flows flagged")
