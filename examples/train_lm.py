"""End-to-end driver: train a ~100M-parameter LM for a few hundred steps
with the full production loop — deterministic sharded data, grad
accumulation, AdamW + cosine, async checkpointing, watchdog, restart.

    PYTHONPATH=src python examples/train_lm.py --steps 300
    (CPU-sized default: --steps 30 finishes in minutes; the loop and every
    subsystem are identical at any scale — the dry-run lowers this exact
    step function on the 512-chip mesh.)
"""

import argparse
import dataclasses

from repro.models.config import ArchConfig
from repro.models import model as M
from repro.training.loop import TrainConfig, train
from repro.training.optim import AdamWConfig

# ~100M decoder (qwen3-flavored: GQA + qk-norm), CPU-trainable
GPT_100M = ArchConfig(
    name="gpt-100m",
    family="dense",
    n_layers=12,
    d_model=640,
    n_heads=10,
    n_kv_heads=2,
    d_ff=2560,
    vocab_size=32000,
    d_head=64,
    qk_norm=True,
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--microbatches", type=int, default=2)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_gpt100m")
    args = ap.parse_args()

    shapes = M.model_param_shapes(GPT_100M)
    print(f"model: {GPT_100M.name}  params "
          f"{M.count_params(shapes) / 1e6:.1f}M")

    tcfg = TrainConfig(
        steps=args.steps,
        seq_len=args.seq_len,
        global_batch=args.global_batch,
        microbatches=args.microbatches,
        opt=AdamWConfig(lr_peak=6e-4, warmup_steps=max(args.steps // 10, 5),
                        total_steps=args.steps),
        ckpt_dir=args.ckpt_dir,
        ckpt_every=max(args.steps // 3, 10),
        log_every=5,
    )
    params, hist = train(GPT_100M, tcfg, seed=0)
    print(f"\nloss {hist[0]['loss_total']:.4f} -> "
          f"{hist[-1]['loss_total']:.4f} over {len(hist)} steps")
    print(f"checkpoints in {args.ckpt_dir} (restart by re-running)")


if __name__ == "__main__":
    main()
