"""Quickstart: train a model, map it to switch tables, classify at the
"switch", and see the hybrid deployment improve the result.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax.numpy as jnp

from repro.core.inference import table_predict
from repro.core.mapping import map_tree_ensemble
from repro.core.resources import artifact_resources
from repro.data.unsw_like import make_unsw_like, train_test_split
from repro.core.hybrid import hybrid_predict
from repro.ml.metrics import accuracy, precision_recall_f1
from repro.ml.trees import fit_random_forest, predict_tree_ensemble

# 1. data: flow records, ~13% anomalies (UNSW-NB15-like)
x, y = make_unsw_like(12000, n_features=5, seed=0)
xtr, ytr, xte, yte = train_test_split(x, y)

# 2. train the small "switch" model and the large "backend" model
switch_model = fit_random_forest(xtr, ytr, n_classes=2, n_trees=10,
                                 max_depth=5, seed=0)
backend_model = fit_random_forest(xtr, ytr, n_classes=2, n_trees=40,
                                  max_depth=8, seed=1, max_features=5)

# 3. IIsy mapping: model -> lookup tables (what the control plane loads)
artifact = map_tree_ensemble(switch_model, n_features=5)
print("switch artifact:", artifact_resources(artifact).row())

# 4. classify entirely "on the switch"
pred, confidence = table_predict(artifact, xte)
print(f"switch-only accuracy: {accuracy(yte, pred):.4f} "
      f"F1 {precision_recall_f1(yte, pred)[2]:.4f}")

# 5. hybrid: low-confidence traffic goes to the backend (tau = 0.7)
res = hybrid_predict(artifact,
                     lambda rows: predict_tree_ensemble(backend_model, rows),
                     xte, threshold=0.7)
print(f"hybrid accuracy:      {accuracy(yte, res.pred):.4f} "
      f"F1 {precision_recall_f1(yte, res.pred)[2]:.4f} "
      f"({float(res.fraction_handled) * 100:.1f}% handled at the switch)")
