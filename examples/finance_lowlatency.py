"""Financial-transactions use case (§7.1.2): tag high-priority trades at
the switch; everything else takes the normal path to the backend XGBoost.

Demonstrates file-level feature extraction (§5.3): each transaction
arrives as a fixed-width CSV payload; the "switch" parses columns
42/43/45/124/126 from the raw bytes (split across two packets for some
rows), classifies, and fast-paths confident strong-buy/sell trades.

    PYTHONPATH=src python examples/finance_lowlatency.py
"""

import time

import jax.numpy as jnp
import numpy as np

from repro.core.inference import table_predict
from repro.core.mapping import map_tree_ensemble
from repro.data.janestreet_like import (SWITCH_FEATURES,
                                        make_janestreet_like,
                                        train_test_split)
from repro.ml.metrics import accuracy, precision_recall_f1
from repro.ml.trees import fit_xgboost, predict_margin_xgboost
from repro.netsim.features import (encode_csv_payload, file_features_csv,
                                   stitch_split_payload)

# --- train: small switch XGB on 5 features; big backend on all 130 ----------
x, y = make_janestreet_like(16000, seed=0)
xtr, ytr, xte, yte = train_test_split(x, y)
sw = fit_xgboost(xtr[:, SWITCH_FEATURES], ytr, n_trees=10, max_depth=5)
backend = fit_xgboost(xtr, ytr, n_trees=60, max_depth=8)
art = map_tree_ensemble(sw, len(SWITCH_FEATURES))

# --- wire format: each trade is a 130-column fixed-width CSV row ------------
n_demo = 512
payload = encode_csv_payload(np.asarray(xte[:n_demo]), width=8)
# rows split across two packets at byte 700 (a feature straddles the cut)
first_pkt, second_pkt = payload[:, :700], payload[:, 700:]

t0 = time.perf_counter()
whole = stitch_split_payload(jnp.asarray(first_pkt), jnp.asarray(second_pkt))
feats = file_features_csv(whole, SWITCH_FEATURES, width=8)   # parse bytes
pred, conf = table_predict(art, feats)
t_parse_classify = time.perf_counter() - t0

tagged = np.asarray((pred == 1) & (conf >= 0.7))
print(f"{n_demo} trades parsed from raw csv bytes + classified in "
      f"{t_parse_classify * 1e3:.1f} ms "
      f"({t_parse_classify / n_demo * 1e6:.1f} us/trade)")
print(f"fast-pathed (tagged strong buy/sell): {tagged.sum()} "
      f"({tagged.mean() * 100:.1f}%)")

# quality of the tags vs the big backend on the same trades
be = (predict_margin_xgboost(backend, xte[:n_demo]) > 0)
gt = yte[:n_demo] == 1
tag_precision = (tagged & gt).sum() / max(tagged.sum(), 1)
print(f"tag precision {tag_precision:.3f} "
      f"(backend would tag {int(np.asarray(be).sum())})")
print(f"switch acc {accuracy(yte[:n_demo], pred):.4f} vs backend "
      f"{accuracy(yte[:n_demo], be.astype(np.int32)):.4f}")
