"""Backend fault tolerance: policy, breaker, injection, and degradation.

The contracts under test (DESIGN.md §9):

* ``GuardedBackend`` applies the FaultPolicy faithfully — bounded retries
  with the exponential backoff schedule, per-attempt timeouts that
  abandon the worker, and the CLOSED -> OPEN -> HALF_OPEN breaker state
  machine with exact telemetry;
* ``FaultyBackend`` replays the identical fault sequence for identical
  seeds (an outage never shifts the downstream error pattern), and
  ``reset()`` rewinds it exactly;
* zero-fault bit-identity — a server built with a FaultPolicy but no
  injected faults reproduces the unguarded server's predictions bit for
  bit on all three serving paths (per-window, deferred flush_every > 1,
  chunked megastep) and on the sharded tier;
* graceful degradation — when a flush ultimately fails, serve_trace still
  completes: degraded rows keep their provisional switch predictions,
  ``StreamStats.degraded`` counts them, and the accounting invariant
  ``handled + backend_rows + deferred + degraded == packets`` holds
  (asserted by ``StreamStats.check()`` on every serve_trace).
"""

import dataclasses

import jax
import numpy as np
import pytest

from repro.core.mapping import map_tree_ensemble
from repro.ml.trees import fit_random_forest, predict_tree_ensemble
from repro.netsim.features import flow_features
from repro.netsim.packets import synth_trace
from repro.serving.faults import (CLOSED, HALF_OPEN, OPEN, BackendFault,
                                  FaultPolicy, FaultyBackend,
                                  GuardedBackend)
from repro.serving.shard_serving import ShardedStreamingServer
from repro.serving.stream_serving import StreamingHybridServer

N_BUCKETS = 1 << 12

# a policy with no real waiting anywhere: tests run instantly
FAST = FaultPolicy(max_retries=1, backoff_base_s=0.0,
                   breaker_threshold=3, breaker_cooldown=2)


@pytest.fixture(scope="module")
def fault_setup():
    trace = synth_trace(n_flows=400, seed=3)
    b, table = flow_features(trace, n_buckets=N_BUCKETS)
    first_idx = np.unique(np.asarray(trace.flow_id), return_index=True)[1]
    rows = np.asarray(table)[np.asarray(b)[first_idx]].astype(np.float32)
    small = fit_random_forest(rows, trace.flow_label, n_classes=2,
                              n_trees=4, max_depth=3, seed=0)
    big = fit_random_forest(rows, trace.flow_label, n_classes=2,
                            n_trees=12, max_depth=5, seed=1)
    art = map_tree_ensemble(small, rows.shape[1])
    return trace, art, (lambda r: predict_tree_ensemble(big, r))


# ---------------------------------------------------------------------------
# FaultPolicy validation
# ---------------------------------------------------------------------------

def test_policy_validation():
    with pytest.raises(ValueError):
        FaultPolicy(timeout_s=0.0)
    with pytest.raises(ValueError):
        FaultPolicy(max_retries=-1)
    with pytest.raises(ValueError):
        FaultPolicy(breaker_threshold=-1)
    with pytest.raises(ValueError):
        FaultPolicy(breaker_threshold=2, breaker_cooldown=0)
    FaultPolicy(breaker_threshold=0, breaker_cooldown=0)   # breaker off: ok


# ---------------------------------------------------------------------------
# GuardedBackend unit behavior (scripted backends, injected sleep)
# ---------------------------------------------------------------------------

def _scripted(outcomes):
    """Backend failing/succeeding per a script of bools (True = ok)."""
    it = iter(outcomes)

    def fn(rows):
        if not next(it):
            raise BackendFault("scripted")
        return np.asarray(rows)[:, 0]
    return fn


def test_guard_success_passthrough():
    g = GuardedBackend(_scripted([True]), FAST, sleep=lambda s: None)
    out = g(np.ones((3, 2)))
    np.testing.assert_array_equal(out, [1.0, 1.0, 1.0])
    assert g.stats.flushes_ok == 1 and g.stats.attempts == 1
    assert g.stats.retries == 0 and g.state == CLOSED


def test_guard_retries_then_succeeds_with_backoff_schedule():
    slept = []
    p = FaultPolicy(max_retries=3, backoff_base_s=0.01, backoff_factor=2.0,
                    breaker_threshold=0)
    g = GuardedBackend(_scripted([False, False, True]), p,
                       sleep=slept.append)
    assert g(np.ones((2, 2))) is not None
    assert g.stats.attempts == 3 and g.stats.retries == 2
    assert slept == [0.01, 0.02]            # base * factor**i, exponential
    assert g.stats.flushes_ok == 1 and g.stats.flushes_failed == 0


def test_guard_exhausted_retries_returns_none():
    g = GuardedBackend(_scripted([False] * 2), FAST, sleep=lambda s: None)
    assert g(np.ones((2, 2))) is None
    assert g.stats.flushes_failed == 1 and g.stats.attempts == 2
    assert g.consecutive_failures == 1 and g.state == CLOSED


def test_guard_timeout_abandons_attempt():
    import threading
    release = threading.Event()

    def slow(rows):
        release.wait(5.0)
        return np.zeros(len(rows))

    p = FaultPolicy(timeout_s=0.05, max_retries=0, breaker_threshold=0)
    g = GuardedBackend(slow, p)
    try:
        assert g(np.ones((2, 2))) is None
        assert g.stats.timeouts == 1 and g.stats.flushes_failed == 1
    finally:
        release.set()                       # unstick the abandoned worker


def test_breaker_opens_rejects_probes_and_closes():
    # 3 consecutive failed flushes open; 2 rejected during cooldown; the
    # HALF_OPEN probe (single attempt) succeeds and closes the breaker
    script = [False] * 6 + [True, True]
    g = GuardedBackend(_scripted(script), FAST, sleep=lambda s: None)
    for _ in range(3):                      # 2 attempts each -> 6 failures
        assert g(np.ones((1, 1))) is None
    assert g.state == OPEN and g.stats.breaker_opens == 1
    for _ in range(2):                      # cooldown: no backend call
        assert g(np.ones((1, 1))) is None
    assert g.stats.rejected == 2 and g.stats.attempts == 6
    assert g(np.ones((1, 1))) is not None   # the probe: 1 attempt, closes
    assert g.state == CLOSED and g.stats.breaker_closes == 1
    assert g.stats.attempts == 7            # probe got exactly one attempt
    assert g(np.ones((1, 1))) is not None   # back to normal service


def test_breaker_failed_probe_reopens():
    script = [False] * 6 + [False] + [True]
    g = GuardedBackend(_scripted(script), FAST, sleep=lambda s: None)
    for _ in range(3 + 2):                  # open + drain cooldown
        g(np.ones((1, 1)))
    assert g(np.ones((1, 1))) is None       # HALF_OPEN probe fails
    assert g.state == OPEN and g.stats.breaker_opens == 2
    assert g.stats.attempts == 7            # the probe was single-attempt


def test_guard_reset_restores_closed_breaker():
    g = GuardedBackend(_scripted([False] * 6), FAST, sleep=lambda s: None)
    for _ in range(3):
        g(np.ones((1, 1)))
    assert g.state == OPEN
    g.reset()
    assert g.state == CLOSED and g.stats.attempts == 0
    assert g.consecutive_failures == 0


# ---------------------------------------------------------------------------
# FaultyBackend injection
# ---------------------------------------------------------------------------

def test_faulty_backend_validation():
    ok = lambda r: r
    with pytest.raises(ValueError):
        FaultyBackend(ok, error_rate=1.5)
    with pytest.raises(ValueError):
        FaultyBackend(ok, spike_rate=-0.1)


def _fault_pattern(fb, n):
    pat = []
    for _ in range(n):
        try:
            fb(np.ones((1, 1)))
            pat.append(False)
        except BackendFault:
            pat.append(True)
    return pat


def test_faulty_backend_seeded_determinism_and_reset():
    mk = lambda: FaultyBackend(lambda r: r, error_rate=0.5, seed=11)
    a, b = mk(), mk()
    pa = _fault_pattern(a, 40)
    assert pa == _fault_pattern(b, 40)      # same seed, same sequence
    assert any(pa) and not all(pa)
    a.reset()
    assert _fault_pattern(a, 40) == pa      # reset rewinds exactly
    c = FaultyBackend(lambda r: r, error_rate=0.5, seed=12)
    assert _fault_pattern(c, 40) != pa      # different seed differs


def test_faulty_backend_outages_dont_shift_error_pattern():
    # both variates are drawn unconditionally per call, so adding an
    # outage window changes only the outage calls' outcomes
    base = _fault_pattern(
        FaultyBackend(lambda r: r, error_rate=0.3, seed=5), 30)
    out = _fault_pattern(
        FaultyBackend(lambda r: r, error_rate=0.3, seed=5,
                      outages=range(10, 14)), 30)
    assert all(out[i] for i in range(10, 14))
    assert out[:10] == base[:10] and out[14:] == base[14:]


# ---------------------------------------------------------------------------
# serving integration: zero-fault bit-identity + graceful degradation
# ---------------------------------------------------------------------------

PATHS = [dict(), dict(flush_every=4), dict(chunk_windows=4)]


@pytest.mark.parametrize("path_kw", PATHS,
                         ids=["per_window", "deferred", "chunked"])
def test_zero_fault_bit_identity(fault_setup, path_kw):
    """A policy-guarded server with a clean backend is invisible: its
    predictions equal the unguarded server's bit for bit on every path."""
    trace, art, backend = fault_setup
    kw = dict(n_buckets=N_BUCKETS, window=256, threshold=0.9, capacity=32,
              **path_kw)
    ref, _ = StreamingHybridServer(art, backend, **kw).serve_trace(trace)
    srv = StreamingHybridServer(art, backend, fault_policy=FAST, **kw)
    got, stats = srv.serve_trace(trace)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))
    assert stats.n_degraded == 0
    assert srv.fault_stats.flushes_failed == 0
    assert srv.fault_stats.flushes_ok == stats.n_flushes


@pytest.mark.parametrize("path_kw", PATHS,
                         ids=["per_window", "deferred", "chunked"])
def test_degraded_rows_keep_switch_predictions(fault_setup, path_kw):
    """With injected flush failures, serve_trace completes; degraded rows
    carry the provisional switch answer and the accounting invariant
    (asserted by serve_trace via StreamStats.check) balances."""
    trace, art, backend = fault_setup
    kw = dict(n_buckets=N_BUCKETS, window=256, threshold=0.9, capacity=32,
              **path_kw)
    # the outage window hard-fails backend calls 0-3 — both attempts of
    # the first two flushes — so degradation fires deterministically on
    # every path regardless of how the 40% error dice land
    faulty = FaultyBackend(backend, error_rate=0.4, seed=9,
                           outages=range(0, 4))
    srv = StreamingHybridServer(art, faulty, fault_policy=FAST, **kw)
    preds, stats = srv.serve_trace(trace)     # check() runs inside
    assert stats.n_degraded > 0
    assert preds.shape == (trace.n_packets,)
    assert (stats.n_handled + stats.total_backend_rows + stats.n_deferred
            + stats.n_degraded == stats.n_packets)
    g = srv.fault_stats
    assert g.flushes_failed > 0
    # flushes telemetry counts only successful backend invocations
    assert stats.n_flushes == g.flushes_ok
    # the degraded predictions are the switch tier's: still in label range
    assert set(np.unique(np.asarray(preds))) <= {0, 1}


def test_degraded_predictions_match_switch_tier(fault_setup):
    """Under a total outage every window degrades — the stream's answers
    must equal a switch-only server (threshold accept + provisional
    low-confidence answers, no backend corrections anywhere)."""
    trace, art, backend = fault_setup
    kw = dict(n_buckets=N_BUCKETS, window=256, threshold=0.9, capacity=32)
    dead = FaultyBackend(backend, error_rate=1.0, seed=0)
    srv = StreamingHybridServer(art, dead, fault_policy=FAST, **kw)
    preds, stats = srv.serve_trace(trace)
    assert stats.total_backend_rows == 0 and stats.n_flushes == 0
    assert stats.n_degraded > 0
    # capacity-overflow rows stay in `deferred` even under a dead backend
    assert (stats.n_handled + stats.n_deferred + stats.n_degraded
            == stats.n_packets)
    # switch-only oracle: the same server with the backend never invoked
    # because nothing clears the confidence bar -> threshold=ignored here;
    # instead compare against the guarded server's own switch half by
    # re-serving with capacity=0 (no rows ever reach a backend)
    srv0 = StreamingHybridServer(art, backend, n_buckets=N_BUCKETS,
                                 window=256, threshold=0.9, capacity=0)
    ref, _ = srv0.serve_trace(trace)
    np.testing.assert_array_equal(np.asarray(preds), np.asarray(ref))


def test_breaker_opens_under_sustained_faults(fault_setup):
    trace, art, backend = fault_setup
    faulty = FaultyBackend(backend, error_rate=0.9, seed=2)
    srv = StreamingHybridServer(art, faulty, fault_policy=FAST,
                                n_buckets=N_BUCKETS, window=256,
                                threshold=0.9, capacity=32)
    _, stats = srv.serve_trace(trace)
    g = srv.fault_stats
    assert g.breaker_opens >= 1
    assert g.rejected >= 1                  # some flushes short-circuited
    assert stats.n_degraded > 0


def test_fault_policy_rejects_fused(fault_setup):
    trace, art, backend = fault_setup
    with pytest.raises(ValueError):
        StreamingHybridServer(art, backend, n_buckets=N_BUCKETS,
                              window=256, fault_policy=FAST, fuse=True)


def test_server_reset_resets_guard(fault_setup):
    """reset() starts a fresh guard epoch: identical reruns see identical
    breaker behavior and per-run telemetry."""
    trace, art, backend = fault_setup
    faulty = FaultyBackend(backend, error_rate=0.4, seed=9)
    srv = StreamingHybridServer(art, faulty, fault_policy=FAST,
                                n_buckets=N_BUCKETS, window=256,
                                threshold=0.9, capacity=32)
    p1, s1 = srv.serve_trace(trace)
    g1 = dataclasses.asdict(srv.fault_stats)
    srv.reset()
    faulty.reset()
    p2, s2 = srv.serve_trace(trace)
    np.testing.assert_array_equal(np.asarray(p1), np.asarray(p2))
    assert s1.n_degraded == s2.n_degraded
    assert dataclasses.asdict(srv.fault_stats) == g1


# ---------------------------------------------------------------------------
# sharded tier: the degradation machinery is layout-agnostic
# ---------------------------------------------------------------------------

SHARDS = [d for d in (1, 2) if jax.device_count() % d == 0
          and d <= jax.device_count()]


@pytest.mark.parametrize("n_shards", SHARDS)
def test_sharded_zero_fault_bit_identity(fault_setup, n_shards):
    trace, art, backend = fault_setup
    kw = dict(n_buckets=N_BUCKETS, window=256, threshold=0.9, capacity=32,
              n_shards=n_shards)
    ref, _ = ShardedStreamingServer(art, backend, **kw).serve_trace(trace)
    srv = ShardedStreamingServer(art, backend, fault_policy=FAST, **kw)
    got, stats = srv.serve_trace(trace)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))
    assert stats.n_degraded == 0


@pytest.mark.parametrize("n_shards", SHARDS)
def test_sharded_degrades_like_single_device(fault_setup, n_shards):
    """The sharded tier under the same fault sequence degrades the same
    rows to the same provisional answers as the single-device tier."""
    trace, art, backend = fault_setup
    kw = dict(window=256, threshold=0.9, capacity=32)
    f1 = FaultyBackend(backend, error_rate=0.4, seed=9,
                       outages=range(0, 4))
    ref, rstats = StreamingHybridServer(
        art, f1, fault_policy=FAST, n_buckets=N_BUCKETS,
        **kw).serve_trace(trace)
    f2 = FaultyBackend(backend, error_rate=0.4, seed=9,
                       outages=range(0, 4))
    srv = ShardedStreamingServer(art, f2, fault_policy=FAST,
                                 n_buckets=N_BUCKETS, n_shards=n_shards,
                                 **kw)
    got, stats = srv.serve_trace(trace)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))
    assert stats.n_degraded == rstats.n_degraded > 0
