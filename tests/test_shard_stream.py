"""Sharded flow-table subsystem: multi-device streaming contracts.

The contracts under test (DESIGN.md §6):

* sharded streaming (shard_map over the 'shard' mesh) is bit-identical
  to the batch flow table AND to the single-device StreamingHybridServer
  on in-order traces with eviction disabled, at every mesh size;
* the aging sweep recycles idle buckets to the init identities — an
  evicted-then-reborn flow is indistinguishable from a fresh one — and
  is a bitwise no-op on surviving buckets;
* the 2^24 overflow guard saturates count registers and counts the hits;
* the stream epoch is a min-merged register, so an out-of-order start
  (reordered first window) is tolerated without a host-side min latch.

Runs on whatever devices exist: mesh sizes are the divisors of
``jax.device_count()`` capped at 4 — a plain single-device session
exercises the D=1 shard_map path; the CI multi-device step
(XLA_FLAGS=--xla_force_host_platform_device_count=4) exercises 1/2/4.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.mapping import map_tree_ensemble
from repro.ml.trees import fit_random_forest, predict_tree_ensemble
from repro.netsim.features import flow_features
from repro.netsim.packets import synth_trace
from repro.netsim.shard_stream import (init_sharded_table,
                                       stream_sharded_flow_features)
from repro.netsim.stream import (OVERFLOW_LIMIT, PacketWindow, age_out,
                                 flow_table_readout, init_flow_table,
                                 iter_windows, saturate_counts,
                                 update_flow_table)
from repro.serving.shard_serving import ShardedStreamingServer
from repro.serving.stream_serving import StreamingHybridServer

N_BUCKETS = 1 << 11

DEVICE_COUNTS = [d for d in (1, 2, 4) if jax.device_count() % d == 0
                 and d <= jax.device_count()]


def _reorder_head(trace, n, seed=0):
    """Permute the first n packets in place-order (a reordered opening)."""
    perm = np.arange(trace.n_packets)
    perm[:n] = np.random.default_rng(seed).permutation(n)
    return dataclasses.replace(trace, **{
        f.name: getattr(trace, f.name)[perm]
        for f in dataclasses.fields(trace) if f.name != "flow_label"})


@pytest.fixture(scope="module")
def shard_setup():
    trace = synth_trace(n_flows=300, seed=3)
    b, table = flow_features(trace, n_buckets=N_BUCKETS)
    first_idx = np.unique(np.asarray(trace.flow_id), return_index=True)[1]
    rows = np.asarray(table)[np.asarray(b)[first_idx]].astype(np.float32)
    small = fit_random_forest(rows, trace.flow_label, n_classes=2,
                              n_trees=4, max_depth=3, seed=0)
    big = fit_random_forest(rows, trace.flow_label, n_classes=2,
                            n_trees=12, max_depth=5, seed=1)
    art = map_tree_ensemble(small, rows.shape[1])
    return trace, art, (lambda r: predict_tree_ensemble(big, r))


# ---------------------------------------------------------------------------
# sharded register carry
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n_shards", DEVICE_COUNTS)
def test_sharded_table_bit_equals_batch(n_shards):
    """shard_map'd window updates over every mesh size reproduce the
    one-shot flow_features table bit for bit (incl. ragged final window)."""
    tr = synth_trace(n_flows=250, seed=5)
    _, batch_table = flow_features(tr, n_buckets=N_BUCKETS)
    _, sh_table = stream_sharded_flow_features(
        tr, n_buckets=N_BUCKETS, window=257, n_shards=n_shards)
    np.testing.assert_array_equal(np.asarray(sh_table),
                                  np.asarray(batch_table))


def test_sharded_table_rejects_indivisible_buckets():
    with pytest.raises(ValueError):
        init_sharded_table(N_BUCKETS + 1, n_shards=2)


# ---------------------------------------------------------------------------
# sharded serving
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n_shards", DEVICE_COUNTS)
def test_sharded_serving_bit_identical_to_single_device(shard_setup,
                                                        n_shards):
    """The acceptance contract: same predictions, same telemetry, same
    flow-table readout as StreamingHybridServer, eviction disabled."""
    trace, art, backend = shard_setup
    kw = dict(n_buckets=N_BUCKETS, window=256, threshold=0.9, capacity=32)
    ref = StreamingHybridServer(art, backend, **kw)
    p_ref, s_ref = ref.serve_trace(trace)
    srv = ShardedStreamingServer(art, backend, n_shards=n_shards, **kw)
    p, s = srv.serve_trace(trace)
    assert srv._fused_ok is True                  # single-dispatch path ran
    np.testing.assert_array_equal(np.asarray(p), np.asarray(p_ref))
    assert s.n_packets == s_ref.n_packets
    assert s.fraction_handled == s_ref.fraction_handled
    assert s.total_backend_rows == s_ref.total_backend_rows
    assert s.n_evicted == 0 and s.n_overflow == 0
    np.testing.assert_array_equal(np.asarray(srv.flow_table()),
                                  np.asarray(ref.flow_table()))
    assert srv.epoch == 0.0                       # in-order stream


def test_sharded_serving_untraceable_backend_falls_back(shard_setup):
    trace, art, _ = shard_setup

    def np_backend(rows):
        return np.zeros(np.asarray(rows).shape[0], np.int32)

    srv = ShardedStreamingServer(art, np_backend, n_buckets=N_BUCKETS,
                                 window=256, threshold=2.0, capacity=16,
                                 n_shards=DEVICE_COUNTS[-1])
    preds, stats = srv.serve_trace(trace)
    assert srv._fused_ok is False
    assert preds.shape == (trace.n_packets,)
    # tau=2.0 forwards everything: every window fills its backend buffer,
    # the overflow past capacity is visible as deferred accounting
    assert stats.total_backend_rows == stats.n_windows * 16
    assert stats.n_deferred == stats.n_packets - stats.total_backend_rows
    np.testing.assert_array_equal(
        np.asarray(srv.flow_table()),
        np.asarray(flow_features(trace, n_buckets=N_BUCKETS)[1]))


# ---------------------------------------------------------------------------
# cross-window deferred dispatch: shard-aware flushes (DESIGN.md §7)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n_shards", DEVICE_COUNTS)
def test_sharded_deferred_bit_matches_flush_every_1(shard_setup, n_shards):
    """The sharded deferral contract at every mesh size: per-shard-slice
    flushes (reduce-scattered complete rows, one backend slice per
    shard) return the same final predictions, flow table and accounting
    as the per-window sharded baseline AND the single-device tier, with
    ceil(windows/k) backend invocations."""
    trace, art, backend = shard_setup
    kw = dict(n_buckets=N_BUCKETS, window=256, threshold=0.9, capacity=32)
    single = StreamingHybridServer(art, backend, **kw)
    p_single, _ = single.serve_trace(trace)
    ref = ShardedStreamingServer(art, backend, n_shards=n_shards, **kw)
    p_ref, s_ref = ref.serve_trace(trace)
    srv = ShardedStreamingServer(art, backend, n_shards=n_shards,
                                 flush_every=4, **kw)
    p, s = srv.serve_trace(trace)
    assert srv._fused_ok is True
    np.testing.assert_array_equal(np.asarray(p), np.asarray(p_ref))
    np.testing.assert_array_equal(np.asarray(p), np.asarray(p_single))
    np.testing.assert_array_equal(np.asarray(srv.flow_table()),
                                  np.asarray(ref.flow_table()))
    assert s.n_packets == s_ref.n_packets
    assert s.fraction_handled == s_ref.fraction_handled
    assert s.total_backend_rows == s_ref.total_backend_rows
    assert s.n_deferred == s_ref.n_deferred
    assert s.n_flushes == -(-s.n_windows // 4)
    assert s_ref.n_flushes == s_ref.n_windows


def test_sharded_deferred_two_phase_bit_identical(shard_setup):
    """Satellite contract: the two-phase fallback of the sharded tier
    under deferral (host backend over the shard-summed buffer) is
    bit-identical to the fused per-shard-slice path and to the
    single-device tier — including a mid-trace backend flush and the
    guaranteed partial flush at trace end."""
    trace, art, backend = shard_setup
    kw = dict(n_buckets=N_BUCKETS, window=256, threshold=0.9, capacity=32,
              flush_every=2)
    fused = ShardedStreamingServer(art, backend,
                                   n_shards=DEVICE_COUNTS[-1], **kw)
    p_f, s_f = fused.serve_trace(trace)
    assert fused._fused_ok is True
    twop = ShardedStreamingServer(art, backend, fuse=False,
                                  n_shards=DEVICE_COUNTS[-1], **kw)
    p_t, s_t = twop.serve_trace(trace)
    assert twop._fused_ok is False
    single = StreamingHybridServer(art, backend, **kw)
    p_s, s_s = single.serve_trace(trace)
    assert s_t.n_windows > 2          # the cycle flushed mid-trace
    assert s_t.n_flushes == -(-s_t.n_windows // 2) >= 2
    np.testing.assert_array_equal(np.asarray(p_t), np.asarray(p_f))
    np.testing.assert_array_equal(np.asarray(p_t), np.asarray(p_s))
    np.testing.assert_array_equal(np.asarray(twop.flow_table()),
                                  np.asarray(single.flow_table()))
    assert s_t.total_backend_rows == s_f.total_backend_rows \
        == s_s.total_backend_rows
    assert s_t.n_flushes == s_f.n_flushes == s_s.n_flushes


def test_sharded_deferred_rejects_indivisible_slots(shard_setup):
    """Under deferral, flush_every*capacity must divide over the mesh
    (each shard's backend serves one slice of the buffer per flush);
    flush_every=1 never builds the buffer, so the same capacity stays
    legal there."""
    if DEVICE_COUNTS[-1] == 1:
        pytest.skip("needs a multi-device mesh")
    trace, art, backend = shard_setup
    with pytest.raises(ValueError):
        ShardedStreamingServer(art, backend, n_buckets=N_BUCKETS,
                               capacity=3, flush_every=3,
                               n_shards=DEVICE_COUNTS[-1])
    srv = ShardedStreamingServer(art, backend, n_buckets=N_BUCKETS,
                                 capacity=3, flush_every=1,
                                 n_shards=DEVICE_COUNTS[-1])
    assert srv.capacity == 3                      # per-window path: legal


# ---------------------------------------------------------------------------
# device-resident chunked streaming (DESIGN.md §8) on the mesh
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n_shards", DEVICE_COUNTS)
def test_sharded_chunked_serving_bit_matches_single_device(shard_setup,
                                                           n_shards):
    """The sharded scan megastep (shard_map'd register scan, one readout
    psum per chunk, per-shard backend slices) serves bit-identically to
    the single-device per-window baseline at every mesh size."""
    trace, art, backend = shard_setup
    kw = dict(n_buckets=N_BUCKETS, window=256, threshold=0.9, capacity=32)
    ref = StreamingHybridServer(art, backend, **kw)
    p_ref, s_ref = ref.serve_trace(trace)
    srv = ShardedStreamingServer(art, backend, chunk_windows=4,
                                 n_shards=n_shards, **kw)
    p, s = srv.serve_trace(trace)
    assert srv._fused_ok is True
    np.testing.assert_array_equal(np.asarray(p), np.asarray(p_ref))
    np.testing.assert_array_equal(np.asarray(srv.flow_table()),
                                  np.asarray(ref.flow_table()))
    assert s.n_packets == s_ref.n_packets
    assert s.n_handled == s_ref.n_handled
    assert s.total_backend_rows == s_ref.total_backend_rows
    assert s.n_deferred == s_ref.n_deferred


def test_sharded_chunked_rejects_indivisible_slots(shard_setup):
    """chunk_windows*capacity must divide over the mesh — each shard's
    backend serves one slice of the chunk's deferred rows."""
    if DEVICE_COUNTS[-1] == 1:
        pytest.skip("needs a multi-device mesh")
    trace, art, backend = shard_setup
    with pytest.raises(ValueError):
        ShardedStreamingServer(art, backend, n_buckets=N_BUCKETS,
                               capacity=3, chunk_windows=3,
                               n_shards=DEVICE_COUNTS[-1])


# ---------------------------------------------------------------------------
# eviction / aging
# ---------------------------------------------------------------------------

def _one_flow_state(ts_list, n_buckets=64, bucket=7, length=100.0):
    """Fold packets of a single flow (given rebased ts) into a fresh table."""
    state = init_flow_table(n_buckets)
    n = len(ts_list)
    win = PacketWindow(
        bucket=jnp.full((n,), bucket, jnp.int32),
        ts=jnp.asarray(ts_list, jnp.float32),
        length=jnp.full((n,), length, jnp.float32),
        is_fwd=jnp.ones((n,), jnp.float32),
        valid=jnp.ones((n,), bool))
    return update_flow_table(state, win)


def test_evicted_then_reborn_flow_matches_fresh():
    """Eviction resets a bucket to the init identities: a flow reborn in
    an evicted bucket reads out bit-for-bit like a fresh flow."""
    old = _one_flow_state([0.5, 1.0, 1.5])
    evicted, n_ev = age_out(old, 10.0)            # cutoff after last-seen
    assert int(n_ev) == 1
    reborn = _one_flow_state([20.0, 21.0])        # same bucket, new life
    win = PacketWindow(bucket=jnp.full((2,), 7, jnp.int32),
                       ts=jnp.asarray([20.0, 21.0], jnp.float32),
                       length=jnp.full((2,), 100.0, jnp.float32),
                       is_fwd=jnp.ones((2,), jnp.float32),
                       valid=jnp.ones((2,), bool))
    reborn_after_evict = update_flow_table(evicted, win)
    np.testing.assert_array_equal(
        np.asarray(flow_table_readout(reborn_after_evict)),
        np.asarray(flow_table_readout(reborn)))


def test_aging_sweep_noop_on_survivors():
    """A sweep on an idle table leaves surviving buckets bit-unchanged
    and resets only the stale ones."""
    tr = synth_trace(n_flows=100, seed=11)
    state = init_flow_table(512)
    for w in iter_windows(tr, 4096, 512):
        state = update_flow_table(state, w)
    cutoff = 0.0                                  # before every packet
    swept, n_ev = age_out(state, cutoff)
    assert int(n_ev) == 0                         # nothing predates t=0
    np.testing.assert_array_equal(np.asarray(flow_table_readout(swept)),
                                  np.asarray(flow_table_readout(state)))
    # now a cutoff that splits: early flows evicted, late flows untouched
    mid = float(np.median(np.asarray(state.t_max)[
        np.asarray(state.pkt_count) > 0]))
    swept, n_ev = age_out(state, mid)
    survivors = np.asarray((state.pkt_count > 0) & (state.t_max >= mid))
    assert 0 < int(n_ev) < int(np.sum(np.asarray(state.pkt_count) > 0))
    for f in ("pkt_count", "byte_count", "t_min", "t_max"):
        np.testing.assert_array_equal(
            np.asarray(getattr(swept, f))[survivors],
            np.asarray(getattr(state, f))[survivors])
    evicted_rows = np.asarray(flow_table_readout(swept))[
        np.asarray((state.pkt_count > 0) & (state.t_max < mid))]
    np.testing.assert_array_equal(evicted_rows,
                                  np.zeros_like(evicted_rows))


def test_lifecycle_sweep_cutoff_clamped_to_window_min():
    """A window whose time span exceeds evict_age must not evict flows
    seen in (or alive at the start of) that window: the cutoff clamps to
    the window's oldest timestamp, so only buckets idle since *before*
    this window can be recycled."""
    from repro.netsim.stream import lifecycle_sweep
    state = _one_flow_state([0.2])                # last seen at t=0.2
    # window spans [0.1, 10.0]: now - evict_age = 9.5 would evict t=0.2,
    # but the clamp to window-min 0.1 keeps it alive
    win = PacketWindow(bucket=jnp.full((2,), 9, jnp.int32),
                       ts=jnp.asarray([0.1, 10.0], jnp.float32),
                       length=jnp.full((2,), 10.0, jnp.float32),
                       is_fwd=jnp.ones((2,), jnp.float32),
                       valid=jnp.ones((2,), bool))
    state = update_flow_table(state, win)
    swept, n_ev, _ = lifecycle_sweep(state, win, 0.5, True)
    assert int(n_ev) == 0
    np.testing.assert_array_equal(np.asarray(flow_table_readout(swept)),
                                  np.asarray(flow_table_readout(state)))
    # a bucket idle since before the window IS evicted by the same sweep
    stale = _one_flow_state([0.05])               # predates window-min
    stale = update_flow_table(stale, win)
    _, n_ev, _ = lifecycle_sweep(stale, win, 0.01, True)
    assert int(n_ev) == 1


def test_sharded_eviction_recycles_buckets(shard_setup):
    """End-to-end: an aggressive evict_age recycles buckets and reports
    them in StreamStats; serving still completes."""
    trace, art, backend = shard_setup
    srv = ShardedStreamingServer(art, backend, n_buckets=N_BUCKETS,
                                 window=256, threshold=0.9, capacity=32,
                                 n_shards=DEVICE_COUNTS[-1], evict_age=0.5)
    preds, stats = srv.serve_trace(trace)
    assert preds.shape == (trace.n_packets,)
    assert stats.n_evicted > 0


# ---------------------------------------------------------------------------
# overflow guard
# ---------------------------------------------------------------------------

def test_overflow_guard_saturates_and_counts():
    state = init_flow_table(32)
    near = OVERFLOW_LIMIT - 2.0
    state = dataclasses.replace(
        state,
        pkt_count=state.pkt_count.at[3].set(near),
        byte_count=state.byte_count.at[3].set(OVERFLOW_LIMIT + 512.0))
    out, n_over = saturate_counts(state)
    assert int(n_over) == 1                       # only byte_count tripped
    assert float(out.byte_count[3]) == OVERFLOW_LIMIT
    assert float(out.pkt_count[3]) == near        # below the limit: exact
    # idempotent on an already-clamped table — and NOT re-counted: the
    # guard reports newly saturated slots, so cumulative telemetry stays
    # constant once a slot sits at the limit (it used to inflate linearly)
    out2, n_over2 = saturate_counts(out)
    assert int(n_over2) == 0
    np.testing.assert_array_equal(np.asarray(out2.byte_count),
                                  np.asarray(out.byte_count))
    # with the pre-window registers available, the count is transition-
    # exact: at-the-limit counts iff the slot was below it before
    out3, n_over3 = saturate_counts(out, prev=state)
    assert int(n_over3) == 0                      # state already >= limit
    fresh = init_flow_table(32)
    _, n_over4 = saturate_counts(out, prev=fresh)
    assert int(n_over4) == 1                      # 0 -> limit: newly


def test_overflow_guard_bitwise_noop_in_envelope():
    """The serving default (saturate=True) must not perturb in-envelope
    streams: clamping below 2^24 is the identity."""
    tr = synth_trace(n_flows=150, seed=7)
    state = init_flow_table(1024)
    for w in iter_windows(tr, 2048, 1024):
        state = update_flow_table(state, w)
    out, n_over = saturate_counts(state)
    assert int(n_over) == 0
    for f in ("pkt_count", "byte_count", "fwd_pkts", "rev_pkts",
              "fwd_bytes", "rev_bytes"):
        np.testing.assert_array_equal(np.asarray(getattr(out, f)),
                                      np.asarray(getattr(state, f)))


# ---------------------------------------------------------------------------
# out-of-order tolerance: epoch as a min-merged register
# ---------------------------------------------------------------------------

def test_out_of_order_start_tolerated_under_provisional_t0():
    """A stream whose true start arrives late, rebased against the first
    packet (the provisional latch) instead of the min: registers are
    associative reductions and features epoch-invariant differences, so
    the sharded readout still bit-matches the batch table. Timestamps are
    2^-10-grained so both rebases are exact in f32 and the contract is
    bitwise, not approximate."""
    tr = synth_trace(n_flows=200, seed=13)
    tr.ts = np.round(tr.ts * 1024.0) / 1024.0     # f32-exact grid
    tr = _reorder_head(tr, min(300, tr.n_packets), seed=1)
    assert float(tr.ts[0]) > float(tr.ts.min())   # true min arrives late
    _, batch_table = flow_features(tr, n_buckets=1024)
    t0_prov = float(tr.ts[0])                     # what a switch latches
    _, sh_table = stream_sharded_flow_features(
        tr, n_buckets=1024, window=128,
        n_shards=DEVICE_COUNTS[-1], t0=t0_prov)
    np.testing.assert_array_equal(np.asarray(sh_table),
                                  np.asarray(batch_table))


def test_sharded_server_epoch_min_merges(shard_setup):
    """Server-level: the epoch register converges to the true observed
    minimum even when the provisional t0 missed it."""
    trace, art, backend = shard_setup
    tr = _reorder_head(trace, 300, seed=2)
    t0_prov = float(tr.ts[0])
    srv = ShardedStreamingServer(art, backend, n_buckets=N_BUCKETS,
                                 window=256, threshold=0.9, capacity=32,
                                 n_shards=DEVICE_COUNTS[-1])
    srv.serve_trace(tr, t0=t0_prov)
    expect = np.float32(np.float64(tr.ts.min()) - t0_prov)
    assert srv.epoch == pytest.approx(float(expect), abs=0.0)


# ---------------------------------------------------------------------------
# partitioned classify on the 2D ('shard', 'data') mesh (DESIGN.md §16)
# ---------------------------------------------------------------------------

# every 2D shape the local device count admits, the (2, 2) square first:
# d_shard*d_data devices on a ('shard', 'data') mesh
MESH_SHAPES = [(ds, dd) for ds, dd in
               ((2, 2), (1, 2), (2, 1), (4, 1), (1, 4), (1, 1))
               if ds * dd <= jax.device_count()]

SERVE_KW = dict(n_buckets=N_BUCKETS, window=256, threshold=0.9, capacity=32)


def _assert_matches_single_device(trace, art, backend, srv, ref=None, **kw):
    """The D×data-parallel grid oracle: preds, flow table and the full
    StreamStats accounting (flushes included) bit-match the single-device
    StreamingHybridServer."""
    if ref is None:
        ref = StreamingHybridServer(art, backend, **kw)
    p_ref, s_ref = ref.serve_trace(trace)
    p, s = srv.serve_trace(trace)
    np.testing.assert_array_equal(np.asarray(p), np.asarray(p_ref))
    np.testing.assert_array_equal(np.asarray(srv.flow_table()),
                                  np.asarray(ref.flow_table()))
    assert s.n_packets == s_ref.n_packets
    assert s.fraction_handled == s_ref.fraction_handled
    assert s.total_backend_rows == s_ref.total_backend_rows
    assert s.n_deferred == s_ref.n_deferred
    assert s.n_flushes == s_ref.n_flushes
    s.check()
    return s


@pytest.mark.parametrize("mesh_shape", MESH_SHAPES)
def test_partitioned_classify_bit_identical_on_2d_mesh(shard_setup,
                                                       mesh_shape):
    """Tentpole oracle, per-window path: the lane-partitioned classify
    (reduce-scattered per-device slabs + all-gathered compact pred/conf)
    is bit-identical to the single-device tier at every mesh shape."""
    from repro.distributed.sharding import flow_shard_mesh
    trace, art, backend = shard_setup
    ds, dd = mesh_shape
    srv = ShardedStreamingServer(art, backend, mesh=flow_shard_mesh(ds, dd),
                                 **SERVE_KW)
    assert srv.partition_classify is True         # the default layout
    _assert_matches_single_device(trace, art, backend, srv, **SERVE_KW)
    assert srv._fused_ok is True


@pytest.mark.parametrize("mesh_shape", MESH_SHAPES)
def test_partitioned_chunked_classify_bit_identical_on_2d_mesh(shard_setup,
                                                               mesh_shape):
    """Tentpole oracle, chunk megastep: the chunk's K*W lanes partition
    into ceil(K*W/D)-row slabs and still bit-match the single-device
    chunked tier."""
    from repro.distributed.sharding import flow_shard_mesh
    trace, art, backend = shard_setup
    ds, dd = mesh_shape
    if (4 * SERVE_KW["capacity"]) % (ds * dd):
        pytest.skip("chunk slots do not divide over this mesh")
    srv = ShardedStreamingServer(art, backend, mesh=flow_shard_mesh(ds, dd),
                                 chunk_windows=4, **SERVE_KW)
    ref = StreamingHybridServer(art, backend, chunk_windows=4, **SERVE_KW)
    _assert_matches_single_device(trace, art, backend, srv, ref=ref)


@pytest.mark.parametrize("mesh_shape", MESH_SHAPES)
def test_classify_rows_per_device_is_padded_ceiling(shard_setup, mesh_shape):
    """Per-device classify work is the padded ceil(K*W/D) slab — NOT the
    full lane width — for both the per-window and the chunked megastep;
    the merge_overhead baseline keeps the full width."""
    from repro.distributed.sharding import flow_shard_mesh
    from repro.kernels.ops import classify_batch_rows
    from repro.kernels.tuning import shard_tiles
    from repro.netsim.shard_stream import lane_slab_rows
    trace, art, backend = shard_setup
    ds, dd = mesh_shape
    mesh = flow_shard_mesh(ds, dd)
    for k in (None, 4):
        if k and (k * SERVE_KW["capacity"]) % (ds * dd):
            continue
        srv = ShardedStreamingServer(art, backend, mesh=mesh,
                                     chunk_windows=k, **SERVE_KW)
        lanes = (k or 1) * SERVE_KW["window"]
        slab = lane_slab_rows(lanes, ds, dd)
        want = classify_batch_rows(art, slab, use_pallas=srv.use_pallas,
                                   tiles=shard_tiles(srv.tiles, slab))
        assert srv.classify_rows_per_device == want
        if ds * dd > 1:
            assert srv.classify_rows_per_device < lanes
        base = ShardedStreamingServer(art, backend, mesh=mesh,
                                      chunk_windows=k,
                                      partition_classify=False, **SERVE_KW)
        assert base.classify_rows_per_device >= lanes


def test_merge_overhead_baseline_bit_identical(shard_setup):
    """partition_classify=False (the pre-partitioning replicated-classify
    layout the bench labels merge_overhead) still bit-matches the
    single-device tier — the flag switches layout, never values."""
    from repro.distributed.sharding import flow_shard_mesh
    trace, art, backend = shard_setup
    ds = DEVICE_COUNTS[-1]
    srv = ShardedStreamingServer(art, backend, mesh=flow_shard_mesh(ds, 1),
                                 partition_classify=False, **SERVE_KW)
    _assert_matches_single_device(trace, art, backend, srv, **SERVE_KW)


def test_legacy_1d_mesh_normalizes(shard_setup):
    """A caller-built 1D ('shard',) mesh keeps working: it normalizes to
    ('shard', 'data') with a size-1 data axis, bit-identically."""
    from jax.sharding import Mesh
    trace, art, backend = shard_setup
    d = DEVICE_COUNTS[-1]
    legacy = Mesh(np.array(jax.devices()[:d]), ("shard",))
    srv = ShardedStreamingServer(art, backend, mesh=legacy, **SERVE_KW)
    assert srv.mesh.axis_names == ("shard", "data")
    assert srv.n_shards == d and srv.n_data == 1
    _assert_matches_single_device(trace, art, backend, srv, **SERVE_KW)


def test_collision_storm_uneven_ownership_never_drops_rows(shard_setup):
    """Uneven-ownership stress: a collision_storm trace concentrates
    nearly all touched buckets on whichever shards own the few target
    buckets. The static per-shard lane tile must never drop rows — lanes
    past dispatch capacity route to deferral and the StreamStats
    accounting invariant (handled + backend_rows + deferred + degraded
    == packets) still closes, bit-identically to single-device."""
    from repro.distributed.sharding import flow_shard_mesh
    from repro.netsim.scenarios import collision_storm
    _, art, backend = shard_setup
    # n_buckets must match the serving table: the storm targets buckets
    # of the SAME hash the servers use
    storm = collision_storm(n_background=150, n_attack=800,
                            n_buckets=N_BUCKETS, n_target_buckets=2,
                            pkts_per_attack=2, seed=0)
    kw = dict(n_buckets=N_BUCKETS, window=256, threshold=0.9, capacity=4)
    ds = DEVICE_COUNTS[-1]
    srv = ShardedStreamingServer(art, backend, mesh=flow_shard_mesh(ds, 1),
                                 **kw)
    s = _assert_matches_single_device(storm, art, backend, srv, **kw)
    # capacity=4 under a storm of colliding low-confidence lanes: the
    # dispatch overflow is real, and every overflowed lane is accounted
    assert s.n_deferred > 0
