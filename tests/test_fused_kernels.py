"""Single-matmul fused path: parity, padding, and artifact round-trips.

Covers the ISSUE-1 acceptance surface:
  * bit-exact parity vs kernels/ref.py for every agg mode
    (vote, wsum_sigmoid, iforest, svm_ovo, nb_log, kmeans);
  * both decision-select strategies (matmul and compare);
  * non-multiple-of-TILE_N batch sizes through the padded entry points;
  * lane-padded artifacts round-tripping through update_tables;
  * _pad_batch replicating the last row (never synthesizing zero rows).
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.artifact import LANE, finalize_artifact, flatten_ftable
from repro.core.inference import table_predict
from repro.core.mapping import map_tree_ensemble
from repro.kernels import ensemble_lookup as ek
from repro.kernels import ref
from repro.kernels.ops import _pad_batch, fused_classify
from repro.kernels.tuning import TileConfig


def _fit_artifact(model, xtr, ytr):
    from benchmarks.common import fit_and_map
    if model == "IForest":
        from repro.ml.trees import fit_isolation_forest
        ens = fit_isolation_forest(np.asarray(xtr), n_trees=6, max_depth=4,
                                   seed=0)
        return map_tree_ensemble(ens, xtr.shape[1])
    _, art, _ = fit_and_map(model, xtr, ytr, n_trees=4, max_depth=4)
    return art


ALL_MODELS = ("DT", "RF", "XGB", "IForest", "SVM", "Bayes", "KMeans")


@pytest.mark.parametrize("model", ALL_MODELS)
def test_fused_classify_all_aggs_bit_exact(model, anomaly_data):
    """Every agg mode: fused kernel (pred, conf) == pure-jnp inference."""
    xtr, ytr, xte, yte = anomaly_data
    art = _fit_artifact(model, xtr, ytr)
    p_ref, c_ref = table_predict(art, xte[:300])
    p_k, c_k = fused_classify(art, xte[:300], use_pallas=True,
                              interpret=True)
    np.testing.assert_array_equal(np.asarray(p_k), np.asarray(p_ref))
    np.testing.assert_allclose(np.asarray(c_k), np.asarray(c_ref),
                               atol=1e-6)


@pytest.mark.parametrize("select", ["matmul", "compare"])
@pytest.mark.parametrize("model", ["RF", "XGB"])
def test_select_strategies_bit_exact(model, select, anomaly_data):
    """Both decision-select strategies return the oracle sums exactly."""
    xtr, ytr, xte, yte = anomaly_data
    art = _fit_artifact(model, xtr, ytr)
    vote = art.agg == "vote"
    dtable = (art.dtable_class if vote
              else art.dtable_value.q).astype(jnp.float32)
    x = jnp.asarray(xte[:256], jnp.float32)
    out = ek.ensemble_lookup_fused(
        x, art.edges, art.ftable_flat, art.dtable_flat, art.dtable_pad,
        interpret=True, select=select)
    expect = ref.ensemble_lookup_ref(x, art.edges, art.ftable, art.strides,
                                     dtable, n_classes=art.n_classes,
                                     vote=vote)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(expect))


@pytest.mark.parametrize("n", [1, 7, 131, 257])
@pytest.mark.parametrize("model", ["RF", "SVM"])
def test_non_tile_multiple_batches(model, n, anomaly_data):
    """Ragged batches pad, classify, and slice back exactly."""
    xtr, ytr, xte, yte = anomaly_data
    art = _fit_artifact(model, xtr, ytr)
    p_ref, c_ref = table_predict(art, xte[:n])
    p_k, c_k = fused_classify(art, xte[:n], use_pallas=True, interpret=True)
    assert p_k.shape == (n,)
    np.testing.assert_array_equal(np.asarray(p_k), np.asarray(p_ref))
    np.testing.assert_allclose(np.asarray(c_k), np.asarray(c_ref),
                               atol=1e-6)


def test_lane_padded_layout_shapes(anomaly_data):
    """finalize_artifact pads B/T/M/S to the lane multiple and keeps the
    logical view recoverable via pad_meta."""
    xtr, ytr, xte, yte = anomaly_data
    art = _fit_artifact("RF", xtr, ytr)
    base = dataclasses.replace(art, ftable_flat=None, vtable_flat=None,
                               dtable_flat=None, dtable_pad=None)
    lane = LANE
    art128 = finalize_artifact(base, lane=lane)
    f, b, t = art128.ftable.shape[0], art128.n_bins, art128.n_trees
    fb, t_pad = art128.ftable_flat.shape
    assert fb % (f * lane) == 0 and fb // f >= b
    assert t_pad % lane == 0 and t_pad >= t
    meta = art128.pad_meta
    assert meta["b_pad"] * f == fb and meta["t_pad"] == t_pad
    assert meta["s_pad"] % lane == 0 and meta["s_pad"] >= meta["s"]
    # padded layout classifies identically
    p_ref, c_ref = table_predict(art128, xte[:256])
    p_k, c_k = fused_classify(art128, xte[:256], use_pallas=True,
                              interpret=True)
    np.testing.assert_array_equal(np.asarray(p_k), np.asarray(p_ref))


def test_flat_tables_match_gather_semantics():
    """flatten_ftable premultiplies strides; one-hot matmul == gather+dot."""
    rng = np.random.default_rng(3)
    f, u, t = 4, 6, 5
    ftable = rng.integers(0, 3, (f, u + 1, t)).astype(np.int32)
    strides = rng.integers(1, 9, (t, f)).astype(np.int32)
    flat = np.asarray(flatten_ftable(jnp.asarray(ftable),
                                     jnp.asarray(strides), lane=8))
    b_pad = flat.shape[0] // f
    bins = rng.integers(0, u + 1, (32, f))
    keys_ref = np.einsum("nft,tf->nt",
                         ftable[np.arange(f)[None, :], bins], strides)
    oh = np.zeros((32, f * b_pad), np.float32)
    for n in range(32):
        for fi in range(f):
            oh[n, fi * b_pad + bins[n, fi]] = 1.0
    keys = oh @ flat
    np.testing.assert_array_equal(keys[:, :t].astype(np.int64), keys_ref)


def test_update_tables_roundtrip_padded(anomaly_data):
    """Same-constraint retrains hot-swap (padded layouts included);
    changed constraints are rejected."""
    from repro.ml.trees import fit_random_forest, predict_tree_ensemble
    from repro.serving.hybrid_serving import HybridServer
    xtr, ytr, xte, yte = anomaly_data
    f = xtr.shape[1]
    a1 = map_tree_ensemble(
        fit_random_forest(xtr, ytr, n_classes=2, n_trees=4, max_depth=3,
                          seed=0), f)
    a2 = map_tree_ensemble(
        fit_random_forest(np.asarray(xtr)[::-1], np.asarray(ytr)[::-1],
                          n_classes=2, n_trees=4, max_depth=3, seed=0), f)
    srv = HybridServer(a1, lambda r: jnp.zeros(r.shape[0], jnp.int32),
                       threshold=0.9, capacity=64)
    same = all(jax.tree.leaves(jax.tree.map(
        lambda x, y: x.shape == y.shape, a1, a2)))
    if same:
        srv.update_tables(a2)                   # padded arrays swap too
        p, _ = srv.classify(xte[:100])
        assert p.shape == (100,)
    a3 = map_tree_ensemble(
        fit_random_forest(xtr, ytr, n_classes=2, n_trees=5, max_depth=3,
                          seed=0), f)
    with pytest.raises(ValueError):
        srv.update_tables(a3)                   # more trees -> new shapes


def test_pad_batch_replicates_last_row():
    x = jnp.arange(10, dtype=jnp.float32).reshape(5, 2)
    xp, n = _pad_batch(x, 4)
    assert n == 5 and xp.shape == (8, 2)
    np.testing.assert_array_equal(np.asarray(xp[5:]),
                                  np.tile(np.asarray(x[4]), (3, 1)))
    xp2, n2 = _pad_batch(x[:4], 4)
    assert n2 == 4 and xp2.shape == (4, 2)      # no pad when aligned


def test_tile_config_override_bit_exact(anomaly_data):
    """Nondefault tile sizes change nothing numerically."""
    xtr, ytr, xte, yte = anomaly_data
    art = _fit_artifact("RF", xtr, ytr)
    p_ref, c_ref = table_predict(art, xte[:200])
    for tiles in (TileConfig(tile_n=64, edge_chunk=8, dtable_chunk=128,
                             select="matmul"),
                  TileConfig(tile_n=256, edge_chunk=64, dtable_chunk=256,
                             select="compare")):
        p, c = fused_classify(art, xte[:200], use_pallas=True,
                              interpret=True, tiles=tiles)
        np.testing.assert_array_equal(np.asarray(p), np.asarray(p_ref))
        np.testing.assert_allclose(np.asarray(c), np.asarray(c_ref),
                                   atol=1e-6)
