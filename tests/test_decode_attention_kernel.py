"""int8-KV decode-attention Pallas kernel vs dense oracle."""

import importlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

da = importlib.import_module("repro.kernels.decode_attention")
from repro.kernels.ref import decode_attention_int8_ref


def _setup(b, s, g, m, hd, seed=0, n_valid=None):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(0, 1, (b, g, m, hd)).astype(np.float32))
    kf = rng.normal(0, 2, (b, s, g, hd)).astype(np.float32)
    vf = rng.normal(0, 2, (b, s, g, hd)).astype(np.float32)
    ks = (np.max(np.abs(kf), axis=-1, keepdims=True) / 127.0 + 1e-8)
    vs = (np.max(np.abs(vf), axis=-1, keepdims=True) / 127.0 + 1e-8)
    kq = np.round(kf / ks).astype(np.int8)
    vq = np.round(vf / vs).astype(np.int8)
    n_valid = n_valid if n_valid is not None else s
    valid = (np.arange(s)[None, :] < n_valid).astype(np.float32)
    valid = np.broadcast_to(valid, (b, s)).copy()
    return (q, jnp.asarray(kq), jnp.asarray(ks.astype(np.float32)),
            jnp.asarray(vq), jnp.asarray(vs.astype(np.float32)),
            jnp.asarray(valid))


@pytest.mark.parametrize("b,s,g,m,hd", [
    (2, 64, 2, 4, 32), (1, 700, 1, 8, 64), (2, 1024, 4, 2, 16),
])
def test_kernel_matches_ref(b, s, g, m, hd):
    args = _setup(b, s, g, m, hd)
    scale = 1.0 / np.sqrt(hd)
    out = da.decode_attention_int8_pallas(*args, scale=scale,
                                          interpret=True)
    ref = decode_attention_int8_ref(*args, scale=scale)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-5)


def test_kernel_masks_invalid_slots():
    args = _setup(2, 128, 2, 4, 32, n_valid=40)
    scale = 1.0 / np.sqrt(32)
    out = da.decode_attention_int8_pallas(*args, scale=scale,
                                          interpret=True)
    ref = decode_attention_int8_ref(*args, scale=scale)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-5)
    # changing an INVALID slot's kv must not change the output
    q, kq, ks, vq, vs, valid = args
    kq2 = kq.at[:, 100].set(127)
    out2 = da.decode_attention_int8_pallas(q, kq2, ks, vq, vs, valid,
                                           scale=scale, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(out2),
                               rtol=0, atol=0)
