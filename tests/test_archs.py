"""Per-arch smoke tests: reduced config, one forward/train step on CPU,
output shapes + no NaNs; prefill->decode consistency."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.models import model as M

KEY = jax.random.PRNGKey(0)


def _batch(cfg, b=2, s=12, with_labels=True):
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (b, s), 0,
                                          cfg.vocab_size)}
    if with_labels:
        batch["labels"] = jax.random.randint(jax.random.PRNGKey(2), (b, s),
                                             0, cfg.vocab_size)
    if cfg.encdec:
        batch["frames"] = 0.1 * jax.random.normal(
            jax.random.PRNGKey(3), (b, cfg.n_frontend_tokens,
                                    cfg.frontend_dim))
    if cfg.frontend == "image_patches":
        batch["patch_embeds"] = 0.1 * jax.random.normal(
            jax.random.PRNGKey(3), (b, cfg.n_frontend_tokens,
                                    cfg.frontend_dim))
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_smoke(arch):
    cfg = get_smoke_config(arch)
    params = M.init_model(cfg, KEY)
    batch = _batch(cfg)
    loss, metrics = M.loss_fn(params, cfg, batch, remat=False)
    assert loss.shape == ()
    assert np.isfinite(float(loss))
    grads = jax.grad(lambda p: M.loss_fn(p, cfg, batch, remat=False)[0])(
        params)
    gnorm = sum(float(jnp.sum(jnp.square(g)))
                for g in jax.tree.leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_decode_consistency(arch):
    cfg = get_smoke_config(arch)
    params = M.init_model(cfg, KEY)
    b, s = 2, 12
    batch = _batch(cfg, b, s, with_labels=False)
    toks = batch["tokens"]
    ref_logits, _ = M.prefill(params, cfg, batch)

    batch2 = dict(batch)
    batch2["tokens"] = toks[:, :s - 1]
    _, caches = M.prefill(params, cfg, batch2)
    n_front = cfg.n_frontend_tokens if cfg.frontend == "image_patches" else 0

    def place(d, src):
        if d.shape == src.shape:
            return src.astype(d.dtype)
        sl = tuple(slice(0, x) for x in src.shape)
        return d.at[sl].set(src.astype(d.dtype))

    dc = jax.tree.map(place,
                      M.init_decode_cache(cfg, b, s + n_front + 4,
                                          dtype=jnp.float32), caches)
    logits2, _ = M.decode_step(params, cfg, toks[:, s - 1],
                               s - 1 + n_front, dc)
    err = float(jnp.max(jnp.abs(ref_logits - logits2)))
    tol = 0.05 if cfg.moe is not None else 1e-3  # MoE: capacity drops
    assert err < tol, (arch, err)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_shapes_only(arch):
    """The FULL config must build its shape tree without allocation."""
    cfg = get_config(arch)
    shapes = M.model_param_shapes(cfg)
    n = M.count_params(shapes)
    assert n > 50e6, f"{arch}: suspiciously small ({n})"
    na = M.active_params(cfg, n)
    assert 0 < na <= n


def test_remat_matches_no_remat():
    cfg = get_smoke_config("qwen3-4b")
    params = M.init_model(cfg, KEY)
    batch = _batch(cfg)
    l1, _ = M.loss_fn(params, cfg, batch, remat=False)
    l2, _ = M.loss_fn(params, cfg, batch, remat=True)
    assert abs(float(l1) - float(l2)) < 1e-5


def test_layer_plan_covers_all_layers():
    from repro.models.transformer import layer_plan
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        if cfg.encdec:
            continue
        plan = layer_plan(cfg)
        total = sum(len(s["specs"]) * s["n_periods"] for s in plan)
        assert total == cfg.n_layers, (arch, total)
        # compile-time proxy: few distinct segments
        assert len(plan) <= 4, (arch, len(plan))


def test_mlstm_chunked_equals_single_chunk():
    """Chunkwise mLSTM == one-chunk (quadratic) evaluation."""
    from repro.models import recurrent as rec
    cfg = get_smoke_config("xlstm-1.3b")
    p = rec.mlstm_params(KEY, cfg)
    x = 0.5 * jax.random.normal(jax.random.PRNGKey(5), (2, 8, cfg.d_model))
    y_chunked, st1 = rec.mlstm_block(p, cfg, x)      # S=8 -> single chunk
    # force multi-chunk by monkeypatching the chunk size
    old = rec.MLSTM_CHUNK
    rec.MLSTM_CHUNK = 4
    try:
        y_multi, st2 = rec.mlstm_block(p, cfg, x)
    finally:
        rec.MLSTM_CHUNK = old
    np.testing.assert_allclose(np.asarray(y_chunked), np.asarray(y_multi),
                               rtol=2e-4, atol=2e-5)
    np.testing.assert_allclose(np.asarray(st1["C"]), np.asarray(st2["C"]),
                               rtol=2e-4, atol=2e-5)
