"""Adversarial scenario generators + approx-LRU eviction under attack.

The contracts under test:

* every generator in ``netsim.scenarios`` returns a well-formed
  ``PacketTrace`` (time-sorted, valid flow ids, per-flow labels with
  attack flows labeled 1) and replays identically for identical seeds;
* ``collision_storm`` actually lands its attack flows in exactly the
  targeted buckets of the same ``fnv1a_hash`` the serving tiers use;
* the pForest-style approx-LRU sweep evicts only under occupancy
  pressure, prefers idle/low-activity buckets, never evicts a bucket
  seen in the current window, and stays a no-op on quiet tables (the
  slow-loris resistance a timeout sweep lacks);
* serving-level: the chunked megastep under ``evict_policy="approx_lru"``
  is bit-identical to the per-window path on the same trace.
"""

import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.mapping import map_tree_ensemble
from repro.ml.trees import fit_random_forest, predict_tree_ensemble
from repro.netsim.features import flow_features, fnv1a_hash
from repro.netsim.scenarios import (SCENARIOS, collision_storm, ddos_flood,
                                    elephant_mice, make_scenario,
                                    merge_traces, slow_loris)
from repro.netsim.packets import PacketTrace, synth_trace
from repro.netsim.stream import (EVICT_POLICIES, approx_lru_sweep,
                                 init_flow_table, lifecycle_sweep,
                                 update_flow_table)
from repro.serving.stream_serving import StreamingHybridServer

N_BUCKETS = 1 << 10


def _bucket_of(tr, n_buckets=N_BUCKETS):
    return np.asarray(fnv1a_hash(tr.src_ip, tr.dst_ip, tr.sport, tr.dport,
                                 tr.proto, n_buckets=n_buckets))


# ---------------------------------------------------------------------------
# generator well-formedness + determinism
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", SCENARIOS)
def test_scenario_well_formed_and_deterministic(name):
    kw = dict(seed=5)
    if name == "collision_storm":
        kw["n_buckets"] = N_BUCKETS
    a = make_scenario(name, **kw)
    assert isinstance(a, PacketTrace)
    ts = np.asarray(a.ts)
    assert (np.diff(ts) >= 0).all()                    # time-sorted
    fid = np.asarray(a.flow_id)
    assert fid.min() >= 0 and fid.max() < a.n_flows
    labels = np.asarray(a.flow_label)
    assert set(np.unique(labels)) <= {0, 1}
    assert labels.sum() > 0                            # attack flows exist
    b = make_scenario(name, **kw)
    for f in dataclasses.fields(PacketTrace):
        np.testing.assert_array_equal(getattr(a, f.name),
                                      getattr(b, f.name))
    c = make_scenario(name, **{**kw, "seed": 6})
    assert not np.array_equal(np.asarray(c.ts), ts)    # seeds matter


def test_make_scenario_unknown_name():
    with pytest.raises(ValueError, match="unknown scenario"):
        make_scenario("teardrop")


def test_merge_traces_preserves_labels_and_order():
    a = synth_trace(n_flows=50, seed=0)
    b = synth_trace(n_flows=30, seed=1)
    la = np.asarray(a.flow_label)[np.asarray(a.flow_id)]
    lb = np.asarray(b.flow_label)[np.asarray(b.flow_id)]
    m = merge_traces(a, b)
    assert m.n_flows == 80 and m.n_packets == a.n_packets + b.n_packets
    assert (np.diff(np.asarray(m.ts)) >= 0).all()
    lm = np.asarray(m.flow_label)[np.asarray(m.flow_id)]
    # per-packet labels survive the merge: match on (ts, length) identity
    order = np.argsort(np.concatenate([a.ts, b.ts]), kind="stable")
    np.testing.assert_array_equal(lm, np.concatenate([la, lb])[order])


def test_ddos_flood_single_use_flows():
    # attack flow ids sit past the background's (merge_traces offsets
    # them); label alone won't do — the synth background has its own
    # ~13% anomalous flows
    t = ddos_flood(n_background=50, n_attack=500, seed=2)
    atk = np.asarray(t.flow_id) >= 50
    # every attack flow is single-packet (maximum admission churn)
    ids, counts = np.unique(np.asarray(t.flow_id)[atk], return_counts=True)
    assert len(ids) == 500 and (counts == 1).all()
    # all converge on one victim
    assert len(np.unique(np.asarray(t.dst_ip)[atk])) == 1


def test_collision_storm_lands_in_target_buckets():
    t = collision_storm(n_background=50, n_attack=400,
                        n_buckets=N_BUCKETS, n_target_buckets=4, seed=3)
    atk = np.asarray(t.flow_id) >= 50
    hit = np.unique(_bucket_of(t)[atk])
    assert len(hit) == 4        # thousands of flows, exactly 4 buckets


def test_slow_loris_idle_gaps():
    t = slow_loris(n_background=50, n_slow=8, n_probes=5, idle_gap=30.0,
                   seed=4)
    atk = np.asarray(t.flow_id) >= 50
    fid = np.asarray(t.flow_id)[atk]
    ts = np.asarray(t.ts)[atk]
    for f in np.unique(fid):
        gaps = np.diff(np.sort(ts[fid == f]))
        assert (gaps > 25.0).all()          # probes idle far past any age


def test_elephant_mice_skew():
    t = elephant_mice(n_mice=100, n_elephants=4, elephant_pkts=500, seed=5)
    atk = np.asarray(t.flow_id) >= 100
    _, counts = np.unique(np.asarray(t.flow_id)[atk], return_counts=True)
    assert (counts == 500).all() and len(counts) == 4


# ---------------------------------------------------------------------------
# approx-LRU sweep unit behavior
# ---------------------------------------------------------------------------

def _table_with(n, occupied_rows, *, t_max, pkt_count=1.0):
    """A table with the given rows occupied (t_min=0, given t_max/count)."""
    s = init_flow_table(n)
    idx = np.asarray(occupied_rows)
    upd = lambda a, v: a.at[idx].set(np.broadcast_to(v, idx.shape).astype(
        np.float32))
    return dataclasses.replace(
        s, pkt_count=upd(s.pkt_count, pkt_count),
        byte_count=upd(s.byte_count, 100.0),
        t_min=upd(s.t_min, 0.0), t_max=upd(s.t_max, t_max))


def _window_at(ts, bucket=0, n=8):
    from repro.netsim.stream import PacketWindow
    return PacketWindow(
        bucket=jnp.full(n, bucket, jnp.int32),
        ts=jnp.full(n, ts, jnp.float32),
        length=jnp.full(n, 100.0, jnp.float32),
        is_fwd=jnp.ones(n, jnp.float32), valid=jnp.ones(n, bool))


def test_approx_lru_no_pressure_is_noop():
    # 4 of 32 occupied, high-water 24: no sweep regardless of age
    s = _table_with(32, [1, 2, 3, 4], t_max=[0.0, 1.0, 2.0, 3.0])
    w = _window_at(100.0, bucket=1)
    s2, n_ev = approx_lru_sweep(s, w, 5.0, occupancy=0.75)
    assert int(n_ev) == 0
    np.testing.assert_array_equal(np.asarray(s2.pkt_count),
                                  np.asarray(s.pkt_count))


def test_approx_lru_pressure_evicts_idle_low_activity_first():
    n = 8
    # 7 of 8 occupied (> 0.5 high water): rows 1-3 idle singles, rows 4-5
    # recent singles, row 6 idle elephant, row 7 recent elephant
    s = _table_with(n, [1, 2, 3], t_max=0.0)
    s = dataclasses.replace(
        s, pkt_count=s.pkt_count.at[np.r_[4:8]].set(
            jnp.asarray([1., 1., 500., 500.])),
        byte_count=s.byte_count.at[np.r_[4:8]].set(100.0),
        t_min=s.t_min.at[np.r_[4:8]].set(0.0),
        t_max=s.t_max.at[np.r_[4:8]].set(
            jnp.asarray([99.9, 99.9, 0., 99.9])))
    w = _window_at(100.0, bucket=0)
    s2, n_ev = approx_lru_sweep(s, w, 10.0, occupancy=0.5)
    evicted = np.asarray(s2.pkt_count) == 0
    # the idle singles go first; the active elephant survives
    assert evicted[[1, 2, 3]].all()
    assert not evicted[7]
    assert int(n_ev) == int(evicted[1:].sum())


def test_approx_lru_never_evicts_current_window():
    n = 8
    s = _table_with(n, list(range(7)), t_max=0.0)   # all ancient, 7/8 full
    w = _window_at(100.0, bucket=3)
    s = update_flow_table(s, w)                      # bucket 3 seen now
    s2, n_ev = approx_lru_sweep(s, w, 5.0, occupancy=0.5)
    assert int(n_ev) > 0
    assert float(s2.pkt_count[3]) > 0                # survivor: seen now


def test_lifecycle_sweep_rejects_unknown_policy():
    s = init_flow_table(8)
    w = _window_at(0.0)
    with pytest.raises(ValueError, match="evict_policy"):
        lifecycle_sweep(s, w, 5.0, True, evict_policy="mru")
    assert "approx_lru" in EVICT_POLICIES


# ---------------------------------------------------------------------------
# approx-LRU vs timeout under the scenarios (the design motivation)
# ---------------------------------------------------------------------------

def _serve(trace, *, evict_policy, evict_age=5.0, **kw):
    b, table = flow_features(trace, n_buckets=N_BUCKETS)
    first = np.unique(np.asarray(trace.flow_id), return_index=True)[1]
    rows = np.asarray(table)[np.asarray(b)[first]].astype(np.float32)
    small = fit_random_forest(rows, trace.flow_label, n_classes=2,
                              n_trees=4, max_depth=3, seed=0)
    art = map_tree_ensemble(small, rows.shape[1])
    backend = lambda r: predict_tree_ensemble(small, r)
    srv = StreamingHybridServer(art, backend, n_buckets=N_BUCKETS,
                                window=256, threshold=0.9, capacity=32,
                                evict_age=evict_age,
                                evict_policy=evict_policy, **kw)
    preds, stats = srv.serve_trace(trace)
    return np.asarray(preds), stats


def test_slow_loris_timeout_churns_lru_spares():
    """The scenario approx-LRU exists for: a timeout sweep evicts the
    idle-but-live slow flows between every probe pair; the pressure
    trigger never fires on this small population, so approx-LRU keeps
    every flow's features accumulating."""
    t = slow_loris(n_background=60, n_slow=16, n_probes=4, idle_gap=20.0,
                   seed=7)
    _, st_timeout = _serve(t, evict_policy="timeout")
    _, st_lru = _serve(t, evict_policy="approx_lru", lru_occupancy=0.75)
    assert st_timeout.n_evicted > 0           # churn on idle time alone
    assert st_lru.n_evicted == 0              # no pressure, no sweep


def test_ddos_flood_lru_evicts_under_pressure():
    """Against a flood of single-use flows the roles flip: the table
    fills past the high-water mark and approx-LRU recycles the dead
    attack buckets."""
    t = ddos_flood(n_background=60, n_attack=2500, seed=8)
    _, st = _serve(t, evict_policy="approx_lru", lru_occupancy=0.5)
    assert st.n_evicted > 0
    st.check()                                # accounting still balances


def test_chunked_approx_lru_bit_matches_per_window():
    t = ddos_flood(n_background=60, n_attack=1500, seed=9)
    p_ref, st_ref = _serve(t, evict_policy="approx_lru", lru_occupancy=0.5)
    p_chunk, st_chunk = _serve(t, evict_policy="approx_lru",
                               lru_occupancy=0.5, chunk_windows=4)
    np.testing.assert_array_equal(p_chunk, p_ref)
    assert st_chunk.n_evicted == st_ref.n_evicted


def test_evict_policy_validation():
    with pytest.raises(ValueError):
        # approx_lru without evict_age is meaningless
        _serve(synth_trace(n_flows=20, seed=0), evict_policy="approx_lru",
               evict_age=None)
    with pytest.raises(ValueError):
        _serve(synth_trace(n_flows=20, seed=0), evict_policy="bogus")
