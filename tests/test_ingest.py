"""Open-ended ingest pipeline: ring buffer, serve_stream, autotune.

The contracts under test (DESIGN.md §13):

* window-granular cuts — the ring never moves a window boundary, so
  predictions, the flow table and every StreamStats field except
  ``flushes`` are invariant under ANY cut grouping; single-batch replay
  reproduces the offline ``iter_chunks`` grouping exactly, which makes
  ``serve_trace`` a bit-identical thin wrapper over ``serve_stream``;
* the prefetch double-buffer changes wall time only, never a bit;
* the chunk-size autotune is an argmin over a set that always contains
  the fixed default — it can never pick a regressing K;
* latency accounting covers every admitted packet exactly once,
  including rows back-patched by a later deferred flush.
"""

import dataclasses

import jax
import numpy as np
import pytest

from repro.core.mapping import map_tree_ensemble
from repro.ml.trees import fit_random_forest, predict_tree_ensemble
from repro.netsim.features import flow_features
from repro.netsim.ingest import (HostCut, PacketRingBuffer, cut_stream,
                                 LatencyRecorder, prefetch_iter,
                                 replay_source, slice_trace)
from repro.netsim.packets import synth_trace
from repro.netsim.stream import iter_chunks, pack_chunk_columns, \
    trace_columns
from repro.serving.faults import FaultPolicy, FaultyBackend
from repro.serving.shard_serving import ShardedStreamingServer
from repro.serving.stream_serving import (CHUNK_WINDOW_CANDIDATES,
                                          DEFAULT_CHUNK_WINDOWS,
                                          StreamingHybridServer,
                                          autotune_chunk_windows,
                                          clear_chunk_tune_cache)

N_BUCKETS = 1 << 11
WINDOW = 64
K = 4

DEVICE_COUNTS = [d for d in (1, 2) if jax.device_count() % d == 0
                 and d <= jax.device_count()]

FAST = FaultPolicy(max_retries=1, backoff_base_s=0.0,
                   breaker_threshold=3, breaker_cooldown=2)


@pytest.fixture(scope="module")
def setup():
    trace = synth_trace(n_flows=300, seed=3)
    b, table = flow_features(trace, n_buckets=N_BUCKETS)
    first_idx = np.unique(np.asarray(trace.flow_id), return_index=True)[1]
    rows = np.asarray(table)[np.asarray(b)[first_idx]].astype(np.float32)
    small = fit_random_forest(rows, trace.flow_label, n_classes=2,
                              n_trees=4, max_depth=3, seed=0)
    big = fit_random_forest(rows, trace.flow_label, n_classes=2,
                            n_trees=12, max_depth=5, seed=1)
    art = map_tree_ensemble(small, rows.shape[1])
    return trace, art, (lambda r: predict_tree_ensemble(big, r))


def _fake_clock(step=0.0, start=100.0):
    """Deterministic wall clock advancing ``step`` seconds per call."""
    state = {"t": start}

    def clock():
        state["t"] += step
        return state["t"]
    return clock


def _served(srv, trace, **kw):
    """(preds, stats, flow_table) — serve_trace, or serve_stream over a
    ``replay={...}``-configured replay_source when given."""
    if "replay" in kw:
        source = replay_source(trace, **kw.pop("replay"))
        pred, stats = srv.serve_stream(source, **kw)
    else:
        pred, stats = srv.serve_trace(trace, **kw)
    return np.asarray(pred), stats, np.asarray(srv.flow_table())


def _assert_same_serving(got, ref, *, ignore_flushes=False):
    (gp, gs, gt), (rp, rs, rt) = got, ref
    np.testing.assert_array_equal(gp, rp)
    np.testing.assert_array_equal(gt, rt)
    for f in dataclasses.fields(rs):
        if ignore_flushes and f.name == "flushes":
            continue
        assert getattr(gs, f.name) == getattr(rs, f.name), f.name


# ---------------------------------------------------------------------------
# ring mechanics (host-only, no serving)
# ---------------------------------------------------------------------------

def test_ring_capacity_floor_validation():
    # floor (K+1)*W - 1: a full ring must always hold a ready chunk,
    # otherwise the pull loop could fill up without ever cutting
    floor = (K + 1) * WINDOW - 1
    with pytest.raises(ValueError):
        PacketRingBuffer(WINDOW, K, N_BUCKETS, capacity=floor - 1)
    ring = PacketRingBuffer(WINDOW, K, N_BUCKETS, capacity=floor)
    assert ring.free == floor


def test_full_ring_always_has_a_ready_chunk():
    tr = synth_trace(n_flows=120, seed=1)
    ring = PacketRingBuffer(WINDOW, K, N_BUCKETS,
                            capacity=(K + 1) * WINDOW - 1)
    n = ring.admit(slice_trace(tr, 0, ring.free))
    assert n == ring.buffered and ring.free == 0
    assert ring.ready()                      # progress guarantee
    cut = ring.cut("count")
    assert cut.kind == "count" and cut.n == K * WINDOW
    assert cut.rows == K and cut.n_windows == K


def test_push_admit_tail_drop_and_overflow():
    tr = synth_trace(n_flows=120, seed=1)
    cap = (K + 1) * WINDOW - 1
    strict = PacketRingBuffer(WINDOW, K, N_BUCKETS, capacity=cap)
    with pytest.raises(ValueError):
        strict.admit(slice_trace(tr, 0, cap + 1))
    lossy = PacketRingBuffer(WINDOW, K, N_BUCKETS, capacity=cap, drop=True)
    n = lossy.admit(slice_trace(tr, 0, cap + 10))
    assert n == cap and lossy.buffered == cap
    assert lossy.stats.admitted == cap and lossy.stats.dropped == 10


def test_drain_pops_ragged_tail():
    tr = synth_trace(n_flows=120, seed=1)
    m = K * WINDOW + WINDOW + 7                    # K full + 1 + ragged
    ring = PacketRingBuffer(WINDOW, K, N_BUCKETS)
    ring.admit(slice_trace(tr, 0, m))
    assert ring.cut("count").n == K * WINDOW
    tail = ring.drain()
    assert tail.kind == "drain" and tail.n == WINDOW + 7
    assert tail.n_windows == 2 and ring.buffered == 0
    assert ring.drain() is None
    s = ring.stats
    assert (s.count_cuts, s.drain_cuts, s.cuts) == (1, 1, 2)


def test_deadline_due_tracks_oldest_admit():
    ring = PacketRingBuffer(WINDOW, K, N_BUCKETS, deadline=5.0,
                            clock=lambda: 100.0)
    tr = synth_trace(n_flows=120, seed=1)
    assert not ring.deadline_due(now=200.0)  # empty: nothing can be due
    ring.admit(slice_trace(tr, 0, WINDOW), now=100.0)
    assert not ring.deadline_due(now=104.0)
    assert ring.deadline_due(now=105.0)      # oldest admit aged past 5s
    assert ring.cut("deadline").kind == "deadline"
    ring.admit(slice_trace(tr, 0, WINDOW // 2), now=100.0)
    assert not ring.deadline_due(now=200.0)  # incomplete window never cuts


def test_count_cut_wins_over_deadline():
    # clock jumps far past the deadline on every call: both triggers are
    # due the moment a chunk completes, and the count cut must win
    tr = synth_trace(n_flows=200, seed=2)
    ring = PacketRingBuffer(WINDOW, K, N_BUCKETS, deadline=0.5,
                            clock=_fake_clock(step=10.0))
    kinds = [c.kind for c in cut_stream(ring, replay_source(tr, batch=None))]
    assert kinds[0] == "count"
    assert ring.stats.count_cuts >= 1


def test_single_batch_replay_bit_identical_to_iter_chunks(setup):
    trace, _, _ = setup
    ring = PacketRingBuffer(WINDOW, K, N_BUCKETS)
    cuts = list(cut_stream(ring, replay_source(trace)))
    ref = list(iter_chunks(trace, WINDOW, K, N_BUCKETS))
    assert len(cuts) == len(ref)
    for cut, rc in zip(cuts, ref):
        got = cut.to_chunk()
        for f in ("bucket", "ts", "length", "is_fwd", "valid"):
            np.testing.assert_array_equal(np.asarray(getattr(got, f)),
                                          np.asarray(getattr(rc, f)), f)
    total = sum(c.n for c in cuts)
    assert total == trace.n_packets
    assert ring.stats.admitted == trace.n_packets


def test_pack_chunk_columns_layout():
    cols, _ = trace_columns(synth_trace(n_flows=40, seed=5), N_BUCKETS)
    n = len(cols["bucket"])
    rows = -(-n // WINDOW) + 1                       # one dead pad window
    full, valid = pack_chunk_columns(cols, n, WINDOW, rows)
    assert valid.shape == (rows * WINDOW,)
    assert valid[:n].all() and not valid[n:].any()   # live lanes lead
    np.testing.assert_array_equal(full["bucket"][:n], cols["bucket"])
    # replicate-last pad inside the ragged window, zeros in dead windows
    live_w = -(-n // WINDOW)
    if n % WINDOW:
        np.testing.assert_array_equal(
            full["bucket"][n:live_w * WINDOW],
            np.repeat(cols["bucket"][-1], live_w * WINDOW - n))
    assert (full["bucket"][live_w * WINDOW:] == 0).all()


def test_prefetch_iter_preserves_order_and_propagates_errors():
    assert list(prefetch_iter(iter(range(100)), depth=2)) == list(range(100))

    def boom():
        yield 1
        raise RuntimeError("source died")
    it = prefetch_iter(boom(), depth=2)
    assert next(it) == 1
    with pytest.raises(RuntimeError, match="source died"):
        next(it)


def test_latency_recorder_summary():
    rec = LatencyRecorder()
    assert rec.summary()["n"] == 0
    rec.record(np.array([0.0, 0.1, 0.2]), 1.0)
    rec.record(np.array([0.5]), 1.0)
    s = rec.summary()
    assert s["n"] == rec.n == 4
    assert s["p50_ms"] <= s["p95_ms"] <= s["p99_ms"] <= s["max_ms"]
    assert s["max_ms"] == pytest.approx(1000.0)


# ---------------------------------------------------------------------------
# serve_stream == serve_trace (the thin-wrapper contract)
# ---------------------------------------------------------------------------

def test_serve_stream_dribbled_equals_serve_trace_chunked(setup):
    trace, art, backend = setup
    kw = dict(n_buckets=N_BUCKETS, window=WINDOW, chunk_windows=K,
              capacity=32)
    ref = _served(StreamingHybridServer(art, backend, **kw), trace)
    srv = StreamingHybridServer(art, backend, **kw)
    for batch in (None, 97, WINDOW * K):     # one-shot, ragged, chunk-sized
        srv.reset()                          # each replay is a new epoch
        got = _served(srv, trace, replay={"batch": batch})
        _assert_same_serving(got, ref)
    assert srv.ingest_stats.admitted == trace.n_packets
    assert srv.ingest_stats.dropped == 0


def test_serve_trace_is_serve_stream_replay(setup):
    trace, art, backend = setup
    kw = dict(n_buckets=N_BUCKETS, window=WINDOW, chunk_windows=K,
              capacity=32)
    srv = StreamingHybridServer(art, backend, **kw)
    pred, stats = srv.serve_trace(trace)
    assert srv.ingest_stats is not None      # it really went through the ring
    ref = StreamingHybridServer(art, backend, **kw)
    rp, rs = ref.serve_stream(replay_source(trace))
    np.testing.assert_array_equal(np.asarray(pred), np.asarray(rp))
    assert stats == rs


def test_prefetch_bit_identical_and_per_window_rejected(setup):
    trace, art, backend = setup
    kw = dict(n_buckets=N_BUCKETS, window=WINDOW, capacity=32)
    srv = StreamingHybridServer(art, backend, chunk_windows=K, **kw)
    on = _served(srv, trace, replay={"batch": 113}, prefetch=True)
    srv.reset()
    off = _served(srv, trace, replay={"batch": 113}, prefetch=False)
    _assert_same_serving(on, off)
    pw = StreamingHybridServer(art, backend, **kw)
    with pytest.raises(ValueError, match="prefetch"):
        pw.serve_stream(replay_source(trace), prefetch=True)
    pred, _ = pw.serve_stream(replay_source(trace))   # None auto-disables
    assert np.asarray(pred).shape == (trace.n_packets,)


def test_serve_stream_per_window_deferred_dribbled(setup):
    trace, art, backend = setup
    kw = dict(n_buckets=N_BUCKETS, window=WINDOW, flush_every=3,
              capacity=32)
    ref = _served(StreamingHybridServer(art, backend, **kw), trace)
    srv = StreamingHybridServer(art, backend, **kw)
    got = _served(srv, trace, replay={"batch": 151}, record_latency=True)
    _assert_same_serving(got, ref)
    # every packet's final (back-patched) prediction was timed once
    assert srv.latency.n == trace.n_packets


def test_deadline_cuts_change_flushes_only(setup):
    trace, art, backend = setup
    kw = dict(n_buckets=N_BUCKETS, window=WINDOW, chunk_windows=K,
              capacity=32)
    ref = _served(StreamingHybridServer(art, backend, **kw), trace)
    srv = StreamingHybridServer(art, backend, **kw)
    # every batch ages the ring 10 fake seconds past the 1s deadline, so
    # sub-chunk groups of complete windows are cut early
    got = _served(srv, trace, replay={"batch": WINDOW + 11}, deadline=1.0,
                  clock=_fake_clock(step=10.0))
    assert srv.ingest_stats.deadline_cuts > 0
    _assert_same_serving(got, ref, ignore_flushes=True)


def test_serve_stream_with_eviction_bit_identical(setup):
    trace, art, backend = setup
    kw = dict(n_buckets=N_BUCKETS, window=WINDOW, chunk_windows=K,
              capacity=32, evict_age=0.5)
    ref = _served(StreamingHybridServer(art, backend, **kw), trace)
    srv = StreamingHybridServer(art, backend, **kw)
    got = _served(srv, trace, replay={"batch": 89})
    _assert_same_serving(got, ref)
    assert ref[1].evicted > 0                # the knob actually fired


def test_serve_stream_fault_injection_replay(setup):
    # an injected-fault schedule is a pure function of (seed, call index);
    # count cuts keep the flush grouping identical, so the dribbled
    # stream must replay the exact degradation sequence of serve_trace
    trace, art, backend = setup
    kw = dict(n_buckets=N_BUCKETS, window=WINDOW, flush_every=2,
              capacity=32, fault_policy=FAST)
    ref_srv = StreamingHybridServer(
        art, FaultyBackend(backend, error_rate=0.4, seed=9), **kw)
    ref = _served(ref_srv, trace)
    srv = StreamingHybridServer(
        art, FaultyBackend(backend, error_rate=0.4, seed=9), **kw)
    got = _served(srv, trace, replay={"batch": 201})
    _assert_same_serving(got, ref)
    assert ref[1].degraded > 0               # faults actually landed


def test_latency_recorder_covers_chunked_path(setup):
    trace, art, backend = setup
    srv = StreamingHybridServer(art, backend, n_buckets=N_BUCKETS,
                                window=WINDOW, chunk_windows=K, capacity=32)
    srv.serve_stream(replay_source(trace, batch=177), record_latency=True)
    s = srv.latency.summary()
    assert s["n"] == trace.n_packets
    assert 0.0 <= s["p50_ms"] <= s["p95_ms"] <= s["p99_ms"]
    srv.serve_stream(replay_source(trace))   # off again: zero-sync loop
    assert srv.latency is None


# ---------------------------------------------------------------------------
# flush-knob composition (wall-clock cuts x data-time flushes)
# ---------------------------------------------------------------------------

def test_flush_knobs_need_deferral_and_exclude_chunked(setup):
    _, art, backend = setup
    kw = dict(n_buckets=N_BUCKETS, window=WINDOW)
    with pytest.raises(ValueError, match="flush_every"):
        StreamingHybridServer(art, backend, flush_occupancy=0.5, **kw)
    with pytest.raises(ValueError, match="flush_every"):
        StreamingHybridServer(art, backend, flush_deadline=1.0, **kw)
    with pytest.raises(ValueError):
        StreamingHybridServer(art, backend, chunk_windows=K,
                              flush_every=4, flush_occupancy=0.5, **kw)


@pytest.mark.parametrize("knob", [{"flush_occupancy": 0.5},
                                  {"flush_deadline": 0.25}])
def test_flush_knobs_compose_with_ingest_deadline(setup, knob):
    # ingest deadline (wall clock) regroups cuts; flush knobs (data time
    # / occupancy) regroup flushes — composed, predictions and the flow
    # table must still match the offline replay bit for bit. On the
    # per-window path (the only one flush knobs can reach) cuts are one
    # window, so count-cut precedence consumes every complete window the
    # moment it exists and the wall-clock deadline is provably inert —
    # the documented "count wins" precedence, asserted here
    trace, art, backend = setup
    kw = dict(n_buckets=N_BUCKETS, window=WINDOW, flush_every=4,
              capacity=32, **knob)
    ref = _served(StreamingHybridServer(art, backend, **kw), trace)
    srv = StreamingHybridServer(art, backend, **kw)
    got = _served(srv, trace, replay={"batch": WINDOW * 2 + 5},
                  deadline=1.0, clock=_fake_clock(step=10.0))
    assert srv.ingest_stats.deadline_cuts == 0
    assert srv.ingest_stats.count_cuts > 0
    _assert_same_serving(got, ref)


# ---------------------------------------------------------------------------
# chunk-size autotune
# ---------------------------------------------------------------------------

def _mk(setup_tuple, **extra):
    _, art, backend = setup_tuple
    return lambda k: StreamingHybridServer(
        art, backend, n_buckets=N_BUCKETS, window=WINDOW, chunk_windows=k,
        capacity=32, **extra)


def test_autotune_picks_per_packet_argmin(setup):
    # time_fn gives wall seconds per chunk step; per-packet scoring must
    # divide by k*window — equal wall times mean the largest K wins
    k = autotune_chunk_windows(
        _mk(setup), window=WINDOW, n_buckets=N_BUCKETS,
        candidates=(4, 8, 16), default=4, time_fn=lambda k: 1.0)
    assert k == 16
    k = autotune_chunk_windows(
        _mk(setup), window=WINDOW, n_buckets=N_BUCKETS,
        candidates=(4, 8, 16), default=4,
        time_fn={4: 1.0, 8: 3.0, 16: 9.0}.__getitem__)
    assert k == 4                            # sublinear growth: smallest


def test_autotune_never_drops_the_default(setup):
    # the default is timed even when absent from candidates, and wins
    # when it measures fastest — the no-regression contract
    times = {4: 5.0, 8: 5.0, 16: 0.1}
    k = autotune_chunk_windows(
        _mk(setup), window=WINDOW, n_buckets=N_BUCKETS,
        candidates=(4, 8), default=16, time_fn=times.__getitem__)
    assert k == 16


def test_autotune_candidate_filter(setup):
    calls = []
    k = autotune_chunk_windows(
        _mk(setup), window=WINDOW, n_buckets=N_BUCKETS,
        candidates=(4, 6, 8), default=4,
        candidate_filter=lambda k: k % 4 == 0,
        time_fn=lambda k: calls.append(k) or 1.0)
    assert k in (4, 8) and 6 not in calls
    # filter rejects the default too: first survivor takes its role
    k = autotune_chunk_windows(
        _mk(setup), window=WINDOW, n_buckets=N_BUCKETS,
        candidates=(6, 12), default=4, candidate_filter=lambda k: k % 3 == 0,
        time_fn=lambda k: 1.0)
    assert k == 12                           # per-packet argmin of 6, 12
    with pytest.raises(ValueError, match="candidate"):
        autotune_chunk_windows(
            _mk(setup), window=WINDOW, n_buckets=N_BUCKETS,
            candidates=(6,), default=4, candidate_filter=lambda k: False,
            time_fn=lambda k: 1.0)


def test_autotune_cache_short_circuits(setup):
    clear_chunk_tune_cache()
    calls = []

    def timer(k):
        calls.append(k)
        return float(k)
    key = ("test", "cache")
    k1 = autotune_chunk_windows(_mk(setup), window=WINDOW,
                                n_buckets=N_BUCKETS, candidates=(4, 8),
                                default=4, time_fn=timer, cache_key=key)
    n_timed = len(calls)
    k2 = autotune_chunk_windows(_mk(setup), window=WINDOW,
                                n_buckets=N_BUCKETS, candidates=(4, 8),
                                default=4, time_fn=timer, cache_key=key)
    assert k1 == k2 and len(calls) == n_timed
    clear_chunk_tune_cache()


def test_chunk_windows_auto_resolves_and_serves(setup):
    trace, art, backend = setup
    clear_chunk_tune_cache()
    srv = StreamingHybridServer(art, backend, n_buckets=N_BUCKETS,
                                window=WINDOW, chunk_windows="auto",
                                capacity=32)
    assert srv.chunk_windows in CHUNK_WINDOW_CANDIDATES + \
        (DEFAULT_CHUNK_WINDOWS,)
    ref = _served(StreamingHybridServer(art, backend, n_buckets=N_BUCKETS,
                                        window=WINDOW,
                                        chunk_windows=srv.chunk_windows,
                                        capacity=32), trace)
    _assert_same_serving(_served(srv, trace), ref)
    clear_chunk_tune_cache()


# ---------------------------------------------------------------------------
# sharded tier
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n_shards", DEVICE_COUNTS)
def test_sharded_serve_stream_equals_serve_trace(setup, n_shards):
    trace, art, backend = setup
    kw = dict(n_buckets=N_BUCKETS, window=WINDOW, chunk_windows=K,
              capacity=32, n_shards=n_shards)
    ref = _served(ShardedStreamingServer(art, backend, **kw), trace)
    srv = ShardedStreamingServer(art, backend, **kw)
    got = _served(srv, trace, replay={"batch": 131})
    _assert_same_serving(got, ref)


@pytest.mark.parametrize("n_shards", DEVICE_COUNTS)
def test_sharded_auto_respects_divisibility(setup, n_shards):
    _, art, backend = setup
    clear_chunk_tune_cache()
    srv = ShardedStreamingServer(art, backend, n_buckets=N_BUCKETS,
                                 window=WINDOW, chunk_windows="auto",
                                 capacity=32, n_shards=n_shards)
    assert (srv.chunk_windows * 32) % n_shards == 0
    clear_chunk_tune_cache()
