"""Streaming flow-table tier: window-carry equivalence with the batch path.

The contract under test (DESIGN.md §5): streaming a trace over W windows
reproduces the one-shot ``flow_features`` table bit for bit, and each
window's hybrid predictions equal the one-shot HybridServer run on the
same prefix-derived features.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.hybrid import (backpatch_pending, combine, defer_window,
                               dispatch, init_deferred)
from repro.core.mapping import map_tree_ensemble
from repro.ml.trees import fit_random_forest, predict_tree_ensemble
from repro.netsim.features import flow_features
from repro.netsim.packets import synth_trace
from repro.netsim.stream import (OVERFLOW_LIMIT, PacketWindow,
                                 flow_table_readout, init_flow_table,
                                 iter_windows, lifecycle_sweep,
                                 stream_flow_features, update_flow_table)
from repro.serving.hybrid_serving import HybridServer
from repro.serving.stream_serving import (StreamingHybridServer,
                                          StreamStats)


N_BUCKETS = 1 << 12


def _trim(trace, n):
    """First n packets of a trace (flow_label is per-flow: kept whole)."""
    return dataclasses.replace(trace, **{
        f.name: getattr(trace, f.name)[:n]
        for f in dataclasses.fields(trace) if f.name != "flow_label"})


@pytest.fixture(scope="module")
def stream_setup():
    trace = synth_trace(n_flows=400, seed=3)
    b, table = flow_features(trace, n_buckets=N_BUCKETS)
    first_idx = np.unique(np.asarray(trace.flow_id), return_index=True)[1]
    rows = np.asarray(table)[np.asarray(b)[first_idx]].astype(np.float32)
    small = fit_random_forest(rows, trace.flow_label, n_classes=2,
                              n_trees=4, max_depth=3, seed=0)
    big = fit_random_forest(rows, trace.flow_label, n_classes=2,
                            n_trees=12, max_depth=5, seed=1)
    art = map_tree_ensemble(small, rows.shape[1])
    return trace, art, (lambda r: predict_tree_ensemble(big, r))


def test_stream_flow_table_bit_equals_batch():
    """Windowed update_flow_table over W windows == one-shot flow_features,
    bitwise, at several window sizes (incl. ragged finals and W > P)."""
    tr = synth_trace(n_flows=300, seed=5)
    b, batch_table = flow_features(tr, n_buckets=2048)
    for w in (64, 257, 1000, tr.n_packets + 5):
        sb, stream_table = stream_flow_features(tr, n_buckets=2048, window=w)
        np.testing.assert_array_equal(np.asarray(stream_table),
                                      np.asarray(batch_table))
        np.testing.assert_array_equal(np.asarray(sb), np.asarray(b))


def test_stream_flow_table_epoch_timestamps():
    """Bit-consistency survives epoch-scale timestamps (the f32-rebase
    regression class): both paths rebase in float64 before the cast."""
    tr = synth_trace(n_flows=200, seed=7)
    tr.ts = tr.ts + 1.7e9
    _, batch_table = flow_features(tr, n_buckets=2048)
    _, stream_table = stream_flow_features(tr, n_buckets=2048, window=300)
    np.testing.assert_array_equal(np.asarray(stream_table),
                                  np.asarray(batch_table))
    dur = np.asarray(batch_table)[:, 2]
    assert (dur > 0).any()            # durations survived the epoch offset


def test_stream_reordered_first_window_bit_equals_batch():
    """Regression (t0 latching): iter_windows defaults the stream epoch to
    the trace *minimum*, not the first packet — so a reordered opening
    window (true start arriving late) rebases identically to the batch
    path and the bit-equality contract holds. Latching ts[0] shifted every
    f32 rounding by a different base and silently broke it."""
    tr = synth_trace(n_flows=200, seed=13)
    perm = np.arange(tr.n_packets)
    perm[:250] = np.random.default_rng(1).permutation(250)
    tr = dataclasses.replace(tr, **{
        f.name: getattr(tr, f.name)[perm]
        for f in dataclasses.fields(tr) if f.name != "flow_label"})
    assert float(tr.ts[0]) > float(tr.ts.min())   # epoch arrives late
    _, batch_table = flow_features(tr, n_buckets=2048)
    _, stream_table = stream_flow_features(tr, n_buckets=2048, window=128)
    np.testing.assert_array_equal(np.asarray(stream_table),
                                  np.asarray(batch_table))
    # explicit t0 override still honored
    _, t0_table = stream_flow_features(tr, n_buckets=2048, window=128,
                                       t0=float(tr.ts.min()))
    np.testing.assert_array_equal(np.asarray(t0_table),
                                  np.asarray(batch_table))


def test_update_flow_table_masks_pad_lanes():
    """Invalid lanes contribute nothing: a window padded to 4x its length
    leaves the registers exactly as the unpadded window does."""
    tr = _trim(synth_trace(n_flows=40, seed=11), 100)
    (w_pad,) = iter_windows(tr, 400, 512)
    (w_raw,) = iter_windows(tr, 400, 512, pad=False)
    assert w_pad.size == 400 and w_raw.size == 100
    s_pad = update_flow_table(init_flow_table(512), w_pad)
    s_raw = update_flow_table(init_flow_table(512), w_raw)
    np.testing.assert_array_equal(np.asarray(flow_table_readout(s_pad)),
                                  np.asarray(flow_table_readout(s_raw)))


def test_streaming_hybrid_matches_oneshot(stream_setup):
    """End-to-end: each streamed window's predictions + telemetry equal the
    one-shot HybridServer on batch features of the prefix trace."""
    trace, art, backend = stream_setup
    w_size, cap, tau = 256, 32, 0.9
    p = (trace.n_packets // w_size) * w_size      # full windows only
    trim = _trim(trace, p)
    srv = StreamingHybridServer(art, backend, n_buckets=N_BUCKETS,
                                window=w_size, threshold=tau, capacity=cap)
    oracle = HybridServer(art, backend, threshold=tau, capacity=cap)
    t0 = float(trace.ts[0])
    for k, w in enumerate(iter_windows(trim, w_size, N_BUCKETS, t0=t0)):
        pred, stats = srv.step(w)
        prefix = _trim(trace, (k + 1) * w_size)
        _, tp = flow_features(prefix, n_buckets=N_BUCKETS)
        x_ref = np.asarray(tp)[np.asarray(w.bucket)]
        pred_ref, stats_ref = oracle.classify(x_ref)
        np.testing.assert_array_equal(np.asarray(pred), np.asarray(pred_ref))
        assert stats.fraction_handled == stats_ref.fraction_handled
        assert stats.backend_rows == stats_ref.backend_rows
    assert srv._fused_ok is True                  # single-dispatch path ran
    np.testing.assert_array_equal(
        np.asarray(srv.flow_table()),
        np.asarray(flow_features(trim, n_buckets=N_BUCKETS)[1]))


def test_streaming_stats_accumulate_on_device(stream_setup):
    """StreamStats is carried as device arrays and only syncs on read."""
    trace, art, backend = stream_setup
    srv = StreamingHybridServer(art, backend, n_buckets=N_BUCKETS,
                                window=512, threshold=0.9, capacity=32)
    preds, stats = srv.serve_trace(trace)
    assert isinstance(stats.windows, jax.Array)
    assert isinstance(stats.packets, jax.Array)
    assert preds.shape == (trace.n_packets,)
    assert stats.n_packets == trace.n_packets
    assert stats.n_windows == -(-trace.n_packets // 512)
    assert 0.0 <= stats.fraction_handled <= 1.0
    assert stats.total_backend_rows <= stats.n_windows * 32


def test_streaming_untraceable_backend_falls_back(stream_setup):
    """numpy-only backends stream through the two-phase path; telemetry
    still accumulates and the register carry still bit-matches batch."""
    trace, art, _ = stream_setup

    def np_backend(rows):
        return np.zeros(np.asarray(rows).shape[0], np.int32)

    srv = StreamingHybridServer(art, np_backend, n_buckets=N_BUCKETS,
                                window=512, threshold=2.0, capacity=16)
    preds, stats = srv.serve_trace(trace)
    assert srv._fused_ok is False
    assert preds.shape == (trace.n_packets,)
    # tau=2.0 forwards everything: every window fills its backend buffer,
    # and every forwarded row past capacity is *counted* as deferred (the
    # capacity-overflow accounting that used to be a silent drop)
    assert stats.total_backend_rows == stats.n_windows * 16
    assert stats.n_deferred == stats.n_packets - stats.total_backend_rows
    assert stats.n_handled + stats.total_backend_rows + stats.n_deferred \
        == stats.n_packets
    np.testing.assert_array_equal(
        np.asarray(srv.flow_table()),
        np.asarray(flow_features(trace, n_buckets=N_BUCKETS)[1]))


def test_dispatch_combine_under_capacity():
    """n_forwarded < capacity: every forwarded row gets the backend
    answer, untouched rows keep the switch answer, and the spare buffer
    lanes are invalid."""
    n, cap = 16, 8
    x = np.arange(n * 2, dtype=np.float32).reshape(n, 2)
    mask = np.zeros(n, bool)
    mask[[1, 5, 11]] = True
    buf, idx, valid = dispatch(jnp.asarray(x), jnp.asarray(mask), cap)
    assert int(valid.sum()) == 3
    be = jnp.full((cap,), 9, jnp.int32)
    out = np.asarray(combine(jnp.zeros(n, jnp.int32), be, idx, valid))
    np.testing.assert_array_equal(np.nonzero(out == 9)[0], [1, 5, 11])
    assert (out[~mask] == 0).all()


def test_dispatch_combine_over_capacity():
    """n_forwarded > capacity: exactly the first ``capacity`` forwarded
    rows (stable order) are served; overflow keeps the switch answer —
    the paper §7.1.2 congestion trade-off."""
    n, cap = 16, 4
    x = np.arange(n * 2, dtype=np.float32).reshape(n, 2)
    fwd_rows = [0, 2, 3, 7, 9, 10, 14]
    mask = np.zeros(n, bool)
    mask[fwd_rows] = True
    buf, idx, valid = dispatch(jnp.asarray(x), jnp.asarray(mask), cap)
    assert int(valid.sum()) == cap
    np.testing.assert_array_equal(np.sort(np.asarray(idx)), fwd_rows[:cap])
    np.testing.assert_array_equal(np.asarray(buf),
                                  x[np.asarray(idx)])
    be = jnp.full((cap,), 9, jnp.int32)
    out = np.asarray(combine(jnp.zeros(n, jnp.int32), be, idx, valid))
    np.testing.assert_array_equal(np.nonzero(out == 9)[0], fwd_rows[:cap])
    assert (out[fwd_rows[cap:]] == 0).all()       # overflow stays switch


def test_fused_deferred_counter_in_capacity_regime(stream_setup):
    """Fused path, tau=2.0 (everything forwarded), tiny capacity: the
    rows past capacity keep the switch answer AND are counted in
    StreamStats.deferred — the accounting identity
    handled + backend_rows + deferred == packets holds."""
    trace, art, backend = stream_setup
    srv = StreamingHybridServer(art, backend, n_buckets=N_BUCKETS,
                                window=512, threshold=2.0, capacity=8)
    _, stats = srv.serve_trace(trace)
    assert srv._fused_ok is True
    assert stats.n_deferred > 0
    assert stats.total_backend_rows == stats.n_windows * 8
    assert stats.n_handled == 0                   # tau=2 forwards all
    assert stats.n_deferred == stats.n_packets - stats.total_backend_rows


# ---------------------------------------------------------------------------
# cross-window deferred dispatch (DESIGN.md §7)
# ---------------------------------------------------------------------------

def test_defer_window_and_backpatch_roundtrip():
    """Unit: rows deferred over two cycle slots come back to their
    (window, lane) return addresses; dead slots never touch the pending
    set (a partial cycle patches exactly what was deferred)."""
    k, cap, w_lanes = 3, 4, 8
    dd = init_deferred(k, cap, 2)
    x0 = np.arange(16, dtype=np.float32).reshape(8, 2)
    m0 = np.zeros(8, bool)
    m0[[1, 5]] = True
    buf0, idx0, val0 = dispatch(jnp.asarray(x0), jnp.asarray(m0), cap)
    dd = defer_window(dd, buf0, idx0, val0, jnp.int32(0))
    m1 = np.zeros(8, bool)
    m1[[0, 2, 7]] = True
    buf1, idx1, val1 = dispatch(jnp.asarray(x0), jnp.asarray(m1), cap)
    dd = defer_window(dd, buf1, idx1, val1, jnp.int32(1))
    assert int(dd.valid.sum()) == 5
    # deferred rows carry their window's features
    np.testing.assert_array_equal(np.asarray(dd.buf[:cap]),
                                  np.asarray(buf0))
    pending = jnp.zeros((k, w_lanes), jnp.int32)
    be = jnp.arange(k * cap, dtype=jnp.int32) + 100
    out = np.asarray(backpatch_pending(pending, be, dd))
    # window 0 lanes 1,5 and window 1 lanes 0,2,7 got their slot's answer
    got = {(w, l) for w, l in zip(*np.nonzero(out >= 100))}
    assert got == {(0, 1), (0, 5), (1, 0), (1, 2), (1, 7)}
    for s in range(k * cap):
        if bool(dd.valid[s]):
            assert out[int(dd.window[s]), int(dd.lane[s])] == 100 + s
    assert (np.asarray(out)[2] == 0).all()        # untouched cycle slot


@pytest.mark.parametrize("k", (2, 4))
def test_deferred_serving_bit_matches_flush_every_1(stream_setup, k):
    """The equivalence oracle: cross-window batching at flush_every=k
    returns the same final predictions, flow table and accounting as the
    per-window baseline (row-wise backend), with ceil(windows/k) backend
    invocations — including the guaranteed partial flush at trace end."""
    trace, art, backend = stream_setup
    kw = dict(n_buckets=N_BUCKETS, window=256, threshold=0.9, capacity=32)
    ref = StreamingHybridServer(art, backend, **kw)
    p_ref, s_ref = ref.serve_trace(trace)
    assert s_ref.n_flushes == s_ref.n_windows     # one invocation/window
    srv = StreamingHybridServer(art, backend, flush_every=k, **kw)
    p, s = srv.serve_trace(trace)
    assert srv._fused_ok is True
    np.testing.assert_array_equal(np.asarray(p), np.asarray(p_ref))
    np.testing.assert_array_equal(np.asarray(srv.flow_table()),
                                  np.asarray(ref.flow_table()))
    assert s.n_windows == s_ref.n_windows
    assert s.n_packets == s_ref.n_packets
    assert s.fraction_handled == s_ref.fraction_handled
    assert s.total_backend_rows == s_ref.total_backend_rows
    assert s.n_deferred == s_ref.n_deferred
    assert s.n_flushes == -(-s.n_windows // k)
    assert s.n_windows % k != 0 or srv.pending_windows == 0


def test_deferred_two_phase_matches_fused(stream_setup):
    """Untraceable backend under deferral: the two-phase flush (host
    backend over the accumulated buffer) is bit-identical to the fused
    flush and to the per-window baseline."""
    trace, art, backend = stream_setup
    # same backend model as the fixture's, forced through numpy so the
    # traceability probe fails and the two-phase flush runs
    b, table = flow_features(trace, n_buckets=N_BUCKETS)
    first_idx = np.unique(np.asarray(trace.flow_id), return_index=True)[1]
    rows = np.asarray(table)[np.asarray(b)[first_idx]].astype(np.float32)
    big = fit_random_forest(rows, trace.flow_label, n_classes=2,
                            n_trees=12, max_depth=5, seed=1)

    def np_backend(r):
        return np.asarray(predict_tree_ensemble(big, np.asarray(r)))

    kw = dict(n_buckets=N_BUCKETS, window=256, threshold=0.9, capacity=32,
              flush_every=4)
    fused = StreamingHybridServer(art, backend, **kw)
    p_f, s_f = fused.serve_trace(trace)
    assert fused._fused_ok is True
    twop = StreamingHybridServer(art, np_backend, **kw)
    p_t, s_t = twop.serve_trace(trace)
    assert twop._fused_ok is False
    np.testing.assert_array_equal(np.asarray(p_t), np.asarray(p_f))
    assert s_t.n_flushes == s_f.n_flushes
    assert s_t.total_backend_rows == s_f.total_backend_rows


def test_deferred_step_returns_provisional_then_flush_patches(stream_setup):
    """Manual stepping: step() under deferral returns switch-tier
    provisional predictions; flush() back-patches the backend answers at
    the recorded return addresses and matches the k=1 predictions."""
    trace, art, backend = stream_setup
    kw = dict(n_buckets=N_BUCKETS, window=256, threshold=0.9, capacity=32)
    ref = StreamingHybridServer(art, backend, **kw)
    srv = StreamingHybridServer(art, backend, flush_every=8, **kw)
    ws = list(iter_windows(trace, 256, N_BUCKETS))[:3]   # partial cycle
    ref_preds = [np.asarray(ref.step(w)[0]) for w in ws]
    prov = [np.asarray(srv.step(w)[0]) for w in ws]
    assert srv.pending_windows == 3
    assert srv.consume_flush() is None            # cycle not full: no auto
    n, patched = srv.flush()
    assert n == 3 and srv.pending_windows == 0
    for i in range(n):
        np.testing.assert_array_equal(np.asarray(patched[i]), ref_preds[i])
    # provisional rows differed exactly where the backend disagreed
    for i in range(n):
        diff = prov[i] != ref_preds[i]
        assert (prov[i][diff] != -1).all()        # only real lanes patched
    assert srv.flush() is None                    # nothing pending now
    assert srv.stats.n_flushes == 1


def test_flush_every_validation():
    with pytest.raises(ValueError):
        StreamingHybridServer(None, lambda r: r, flush_every=0)


def test_flush_queue_keeps_every_unconsumed_cycle(stream_setup):
    """Auto-flush results queue FIFO: stepping through several cycles
    without consuming loses no cycle's back-patched predictions."""
    trace, art, backend = stream_setup
    kw = dict(n_buckets=N_BUCKETS, window=256, threshold=0.9, capacity=32)
    ref = StreamingHybridServer(art, backend, **kw)
    srv = StreamingHybridServer(art, backend, flush_every=2, **kw)
    ws = list(iter_windows(trace, 256, N_BUCKETS))[:6]    # 3 full cycles
    ref_preds = [np.asarray(ref.step(w)[0]) for w in ws]
    for w in ws:                                  # never consume between
        srv.step(w)
    for c in range(3):                            # oldest first
        n, patched = srv.consume_flush()
        assert n == 2
        for i in range(n):
            np.testing.assert_array_equal(np.asarray(patched[i]),
                                          ref_preds[2 * c + i])
    assert srv.consume_flush() is None


def test_serve_trace_flushes_stale_pending_on_entry(stream_setup):
    """Windows pending from manual step() calls belong to a different
    prediction stream: serve_trace flushes them on entry so their
    patches can neither splice into nor shift its own output. Realistic
    shape: a manual prefix of the stream, then serve_trace over the
    rest — the rest's predictions must match a full serve_trace's."""
    trace, art, backend = stream_setup
    w_size = 256
    t0 = float(np.asarray(trace.ts, np.float64).min())
    kw = dict(n_buckets=N_BUCKETS, window=w_size, threshold=0.9,
              capacity=32, flush_every=4)
    ref = StreamingHybridServer(art, backend, **kw)
    p_ref, s_ref = ref.serve_trace(trace, t0=t0)
    srv = StreamingHybridServer(art, backend, **kw)
    for w in list(iter_windows(trace, w_size, N_BUCKETS, t0=t0))[:2]:
        srv.step(w)                               # 2 windows left pending
    assert srv.pending_windows == 2
    rest = dataclasses.replace(trace, **{
        f.name: getattr(trace, f.name)[2 * w_size:]
        for f in dataclasses.fields(trace) if f.name != "flow_label"})
    p, s = srv.serve_trace(rest, t0=t0)
    assert srv.pending_windows == 0
    # the rest of the stream gets exactly the full-serve predictions;
    # the pre-trace windows were flushed into stats, not spliced in
    np.testing.assert_array_equal(np.asarray(p),
                                  np.asarray(p_ref)[2 * w_size:])
    assert s.n_windows == s_ref.n_windows
    assert s.total_backend_rows == s_ref.total_backend_rows


# ---------------------------------------------------------------------------
# overflow telemetry: count only newly saturated slots
# ---------------------------------------------------------------------------

def _one_packet_window(bucket, ts, length):
    return PacketWindow(bucket=jnp.asarray([bucket], jnp.int32),
                        ts=jnp.asarray([ts], jnp.float32),
                        length=jnp.asarray([length], jnp.float32),
                        is_fwd=jnp.ones((1,), jnp.float32),
                        valid=jnp.ones((1,), bool))


def test_overflow_counts_once_across_windows():
    """Regression: the overflow guard used to re-count every already-
    saturated slot each window, inflating StreamStats.overflow linearly
    with stream length. With the pre-update registers threaded through
    (``prev``), a slot counts exactly once — when it first saturates —
    and stays constant afterwards even as traffic keeps arriving."""
    state = init_flow_table(16)
    # window 1: one giant packet saturates byte_count AND fwd_bytes
    prev = state
    state = update_flow_table(state,
                              _one_packet_window(3, 0.0,
                                                 OVERFLOW_LIMIT + 1024.0))
    state, _, n1 = lifecycle_sweep(state, _one_packet_window(3, 0.0, 1.0),
                                   None, True, prev=prev)
    assert int(n1) == 2
    assert float(state.byte_count[3]) == OVERFLOW_LIMIT  # clamped
    # window 2: more traffic to the saturated flow — NOT re-counted
    prev = state
    state = update_flow_table(state, _one_packet_window(3, 1.0, 2048.0))
    state, _, n2 = lifecycle_sweep(state, _one_packet_window(3, 1.0, 1.0),
                                   None, True, prev=prev)
    assert int(n2) == 0
    assert float(state.byte_count[3]) == OVERFLOW_LIMIT
    # a different slot saturating later still counts (fwd+byte again)
    prev = state
    state = update_flow_table(state,
                              _one_packet_window(9, 2.0,
                                                 OVERFLOW_LIMIT + 8.0))
    state, _, n3 = lifecycle_sweep(state, _one_packet_window(9, 2.0, 1.0),
                                   None, True, prev=prev)
    assert int(n3) == 2


# ---------------------------------------------------------------------------
# deadline-triggered early flush (the occupancy knob's time-domain twin)
# ---------------------------------------------------------------------------

def test_flush_deadline_bit_identical_with_earlier_flushes(stream_setup):
    """A deadline splits deferral cycles without changing one final
    prediction — same contract as flush_occupancy — while flushing
    strictly more often on a stream whose windows span real time."""
    trace, art, backend = stream_setup
    kw = dict(n_buckets=N_BUCKETS, window=256, threshold=0.9, capacity=32,
              flush_every=6)
    ref = StreamingHybridServer(art, backend, **kw)
    p_ref, s_ref = ref.serve_trace(trace)
    srv = StreamingHybridServer(art, backend, flush_deadline=0.05, **kw)
    p, s = srv.serve_trace(trace)
    np.testing.assert_array_equal(np.asarray(p), np.asarray(p_ref))
    assert s.n_flushes > s_ref.n_flushes      # deadline actually fired
    assert s.n_packets == s_ref.n_packets
    assert s.total_backend_rows == s_ref.total_backend_rows


def test_flush_deadline_bounds_pending_staleness(stream_setup):
    """Stepping sparse windows manually: once a window's newest timestamp
    ages past the deadline relative to the cycle's birth, the cycle
    flushes on its own instead of waiting for flush_every windows."""
    trace, art, backend = stream_setup
    ws = list(iter_windows(trace, 256, N_BUCKETS))
    # pick a deadline wider than window 0's own span (no flush at step 0)
    # but inside window 1's newest-ts age relative to the cycle's birth
    # (flush at step 1) — the trigger compares max ts against the birth
    t0 = np.asarray(ws[0].ts)[np.asarray(ws[0].valid)]
    t1 = np.asarray(ws[1].ts)[np.asarray(ws[1].valid)]
    span0, span1 = t0.max() - t0.min(), t1.max() - t0.min()
    assert span0 < span1
    srv = StreamingHybridServer(art, backend, n_buckets=N_BUCKETS,
                                window=256, threshold=0.9, capacity=32,
                                flush_every=8,
                                flush_deadline=float((span0 + span1) / 2))
    srv.step(ws[0])
    assert srv.pending_windows == 1
    srv.step(ws[1])             # window 1 ages past the deadline vs birth
    assert srv.pending_windows == 0           # deadline flushed the cycle
    assert srv.consume_flush() is not None


def test_flush_deadline_validation(stream_setup):
    _, art, backend = stream_setup
    with pytest.raises(ValueError):
        StreamingHybridServer(art, backend, n_buckets=N_BUCKETS,
                              flush_deadline=0.5)      # needs flush_every>1
    with pytest.raises(ValueError):
        StreamingHybridServer(art, backend, n_buckets=N_BUCKETS,
                              flush_every=4, flush_deadline=0.0)


# ---------------------------------------------------------------------------
# the StreamStats accounting invariant (checked on every serve_trace)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kw", [dict(), dict(flush_every=4),
                                dict(chunk_windows=4),
                                dict(evict_age=1.0)],
                         ids=["per_window", "deferred", "chunked", "evict"])
def test_stream_stats_invariant_holds(stream_setup, kw):
    """check() — handled + backend_rows + deferred + degraded == packets —
    passes on every serving path and is what serve_trace returns."""
    trace, art, backend = stream_setup
    srv = StreamingHybridServer(art, backend, n_buckets=N_BUCKETS,
                                window=256, threshold=0.9, capacity=32,
                                **kw)
    _, stats = srv.serve_trace(trace)
    assert stats.check() is stats             # idempotent re-check
    assert (stats.n_handled + stats.total_backend_rows + stats.n_deferred
            + stats.n_degraded == stats.n_packets)
    assert stats.n_degraded == 0              # clean backend: none degrade


def test_stream_stats_check_catches_imbalance():
    bad = dataclasses.replace(StreamStats.zero(),
                              packets=jnp.asarray(10, jnp.int32))
    with pytest.raises(AssertionError, match="accounting invariant"):
        bad.check()
