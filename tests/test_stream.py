"""Streaming flow-table tier: window-carry equivalence with the batch path.

The contract under test (DESIGN.md §5): streaming a trace over W windows
reproduces the one-shot ``flow_features`` table bit for bit, and each
window's hybrid predictions equal the one-shot HybridServer run on the
same prefix-derived features.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.hybrid import combine, dispatch
from repro.core.mapping import map_tree_ensemble
from repro.ml.trees import fit_random_forest, predict_tree_ensemble
from repro.netsim.features import flow_features
from repro.netsim.packets import synth_trace
from repro.netsim.stream import (flow_table_readout, init_flow_table,
                                 iter_windows, stream_flow_features,
                                 update_flow_table)
from repro.serving.hybrid_serving import HybridServer
from repro.serving.stream_serving import StreamingHybridServer


N_BUCKETS = 1 << 12


def _trim(trace, n):
    """First n packets of a trace (flow_label is per-flow: kept whole)."""
    return dataclasses.replace(trace, **{
        f.name: getattr(trace, f.name)[:n]
        for f in dataclasses.fields(trace) if f.name != "flow_label"})


@pytest.fixture(scope="module")
def stream_setup():
    trace = synth_trace(n_flows=400, seed=3)
    b, table = flow_features(trace, n_buckets=N_BUCKETS)
    first_idx = np.unique(np.asarray(trace.flow_id), return_index=True)[1]
    rows = np.asarray(table)[np.asarray(b)[first_idx]].astype(np.float32)
    small = fit_random_forest(rows, trace.flow_label, n_classes=2,
                              n_trees=4, max_depth=3, seed=0)
    big = fit_random_forest(rows, trace.flow_label, n_classes=2,
                            n_trees=12, max_depth=5, seed=1)
    art = map_tree_ensemble(small, rows.shape[1])
    return trace, art, (lambda r: predict_tree_ensemble(big, r))


def test_stream_flow_table_bit_equals_batch():
    """Windowed update_flow_table over W windows == one-shot flow_features,
    bitwise, at several window sizes (incl. ragged finals and W > P)."""
    tr = synth_trace(n_flows=300, seed=5)
    b, batch_table = flow_features(tr, n_buckets=2048)
    for w in (64, 257, 1000, tr.n_packets + 5):
        sb, stream_table = stream_flow_features(tr, n_buckets=2048, window=w)
        np.testing.assert_array_equal(np.asarray(stream_table),
                                      np.asarray(batch_table))
        np.testing.assert_array_equal(np.asarray(sb), np.asarray(b))


def test_stream_flow_table_epoch_timestamps():
    """Bit-consistency survives epoch-scale timestamps (the f32-rebase
    regression class): both paths rebase in float64 before the cast."""
    tr = synth_trace(n_flows=200, seed=7)
    tr.ts = tr.ts + 1.7e9
    _, batch_table = flow_features(tr, n_buckets=2048)
    _, stream_table = stream_flow_features(tr, n_buckets=2048, window=300)
    np.testing.assert_array_equal(np.asarray(stream_table),
                                  np.asarray(batch_table))
    dur = np.asarray(batch_table)[:, 2]
    assert (dur > 0).any()            # durations survived the epoch offset


def test_stream_reordered_first_window_bit_equals_batch():
    """Regression (t0 latching): iter_windows defaults the stream epoch to
    the trace *minimum*, not the first packet — so a reordered opening
    window (true start arriving late) rebases identically to the batch
    path and the bit-equality contract holds. Latching ts[0] shifted every
    f32 rounding by a different base and silently broke it."""
    tr = synth_trace(n_flows=200, seed=13)
    perm = np.arange(tr.n_packets)
    perm[:250] = np.random.default_rng(1).permutation(250)
    tr = dataclasses.replace(tr, **{
        f.name: getattr(tr, f.name)[perm]
        for f in dataclasses.fields(tr) if f.name != "flow_label"})
    assert float(tr.ts[0]) > float(tr.ts.min())   # epoch arrives late
    _, batch_table = flow_features(tr, n_buckets=2048)
    _, stream_table = stream_flow_features(tr, n_buckets=2048, window=128)
    np.testing.assert_array_equal(np.asarray(stream_table),
                                  np.asarray(batch_table))
    # explicit t0 override still honored
    _, t0_table = stream_flow_features(tr, n_buckets=2048, window=128,
                                       t0=float(tr.ts.min()))
    np.testing.assert_array_equal(np.asarray(t0_table),
                                  np.asarray(batch_table))


def test_update_flow_table_masks_pad_lanes():
    """Invalid lanes contribute nothing: a window padded to 4x its length
    leaves the registers exactly as the unpadded window does."""
    tr = _trim(synth_trace(n_flows=40, seed=11), 100)
    (w_pad,) = iter_windows(tr, 400, 512)
    (w_raw,) = iter_windows(tr, 400, 512, pad=False)
    assert w_pad.size == 400 and w_raw.size == 100
    s_pad = update_flow_table(init_flow_table(512), w_pad)
    s_raw = update_flow_table(init_flow_table(512), w_raw)
    np.testing.assert_array_equal(np.asarray(flow_table_readout(s_pad)),
                                  np.asarray(flow_table_readout(s_raw)))


def test_streaming_hybrid_matches_oneshot(stream_setup):
    """End-to-end: each streamed window's predictions + telemetry equal the
    one-shot HybridServer on batch features of the prefix trace."""
    trace, art, backend = stream_setup
    w_size, cap, tau = 256, 32, 0.9
    p = (trace.n_packets // w_size) * w_size      # full windows only
    trim = _trim(trace, p)
    srv = StreamingHybridServer(art, backend, n_buckets=N_BUCKETS,
                                window=w_size, threshold=tau, capacity=cap)
    oracle = HybridServer(art, backend, threshold=tau, capacity=cap)
    t0 = float(trace.ts[0])
    for k, w in enumerate(iter_windows(trim, w_size, N_BUCKETS, t0=t0)):
        pred, stats = srv.step(w)
        prefix = _trim(trace, (k + 1) * w_size)
        _, tp = flow_features(prefix, n_buckets=N_BUCKETS)
        x_ref = np.asarray(tp)[np.asarray(w.bucket)]
        pred_ref, stats_ref = oracle.classify(x_ref)
        np.testing.assert_array_equal(np.asarray(pred), np.asarray(pred_ref))
        assert stats.fraction_handled == stats_ref.fraction_handled
        assert stats.backend_rows == stats_ref.backend_rows
    assert srv._fused_ok is True                  # single-dispatch path ran
    np.testing.assert_array_equal(
        np.asarray(srv.flow_table()),
        np.asarray(flow_features(trim, n_buckets=N_BUCKETS)[1]))


def test_streaming_stats_accumulate_on_device(stream_setup):
    """StreamStats is carried as device arrays and only syncs on read."""
    trace, art, backend = stream_setup
    srv = StreamingHybridServer(art, backend, n_buckets=N_BUCKETS,
                                window=512, threshold=0.9, capacity=32)
    preds, stats = srv.serve_trace(trace)
    assert isinstance(stats.windows, jax.Array)
    assert isinstance(stats.packets, jax.Array)
    assert preds.shape == (trace.n_packets,)
    assert stats.n_packets == trace.n_packets
    assert stats.n_windows == -(-trace.n_packets // 512)
    assert 0.0 <= stats.fraction_handled <= 1.0
    assert stats.total_backend_rows <= stats.n_windows * 32


def test_streaming_untraceable_backend_falls_back(stream_setup):
    """numpy-only backends stream through the two-phase path; telemetry
    still accumulates and the register carry still bit-matches batch."""
    trace, art, _ = stream_setup

    def np_backend(rows):
        return np.zeros(np.asarray(rows).shape[0], np.int32)

    srv = StreamingHybridServer(art, np_backend, n_buckets=N_BUCKETS,
                                window=512, threshold=2.0, capacity=16)
    preds, stats = srv.serve_trace(trace)
    assert srv._fused_ok is False
    assert preds.shape == (trace.n_packets,)
    # tau=2.0 forwards everything: every window fills its backend buffer
    assert stats.total_backend_rows == stats.n_windows * 16
    np.testing.assert_array_equal(
        np.asarray(srv.flow_table()),
        np.asarray(flow_features(trace, n_buckets=N_BUCKETS)[1]))


def test_dispatch_combine_under_capacity():
    """n_forwarded < capacity: every forwarded row gets the backend
    answer, untouched rows keep the switch answer, and the spare buffer
    lanes are invalid."""
    n, cap = 16, 8
    x = np.arange(n * 2, dtype=np.float32).reshape(n, 2)
    mask = np.zeros(n, bool)
    mask[[1, 5, 11]] = True
    buf, idx, valid = dispatch(jnp.asarray(x), jnp.asarray(mask), cap)
    assert int(valid.sum()) == 3
    be = jnp.full((cap,), 9, jnp.int32)
    out = np.asarray(combine(jnp.zeros(n, jnp.int32), be, idx, valid))
    np.testing.assert_array_equal(np.nonzero(out == 9)[0], [1, 5, 11])
    assert (out[~mask] == 0).all()


def test_dispatch_combine_over_capacity():
    """n_forwarded > capacity: exactly the first ``capacity`` forwarded
    rows (stable order) are served; overflow keeps the switch answer —
    the paper §7.1.2 congestion trade-off."""
    n, cap = 16, 4
    x = np.arange(n * 2, dtype=np.float32).reshape(n, 2)
    fwd_rows = [0, 2, 3, 7, 9, 10, 14]
    mask = np.zeros(n, bool)
    mask[fwd_rows] = True
    buf, idx, valid = dispatch(jnp.asarray(x), jnp.asarray(mask), cap)
    assert int(valid.sum()) == cap
    np.testing.assert_array_equal(np.sort(np.asarray(idx)), fwd_rows[:cap])
    np.testing.assert_array_equal(np.asarray(buf),
                                  x[np.asarray(idx)])
    be = jnp.full((cap,), 9, jnp.int32)
    out = np.asarray(combine(jnp.zeros(n, jnp.int32), be, idx, valid))
    np.testing.assert_array_equal(np.nonzero(out == 9)[0], fwd_rows[:cap])
    assert (out[fwd_rows[cap:]] == 0).all()       # overflow stays switch
