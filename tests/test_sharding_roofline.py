"""Sharding rules + roofline analysis unit tests (no 512-device init —
uses small meshes compatible with 1 CPU device via spec-only checks)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.roofline.analysis import (collective_bytes_from_hlo,
                                     model_flops, roofline_terms)


class FakeMesh:
    """Just enough of jax.sharding.Mesh for the spec rules."""

    def __init__(self, shape):
        self.shape = shape

    @property
    def axis_names(self):
        return tuple(self.shape)


def test_param_spec_rules():
    from repro.distributed.sharding import spec_for_param
    mesh = FakeMesh({"data": 16, "model": 16})
    # generic 2D
    assert spec_for_param("segments/0/ffn/gate", (7168, 2048), mesh) == \
        P("data", "model")
    # indivisible dims replicate
    assert spec_for_param("segments/0/ffn/gate", (7167, 2049), mesh) == \
        P(None, None)
    # embed: vocab -> model
    assert spec_for_param("embed", (129280, 7168), mesh) == \
        P("model", "data")
    # expert stacks: E -> model (EP)
    s = spec_for_param("segments/1/ffn/w_gate", (58, 256, 7168, 2048), mesh)
    assert s == P(None, "model", "data", None)
    # 1D replicates
    assert spec_for_param("final_norm/w", (7168,), mesh) == P()


def test_cache_spec_batch_by_size():
    from repro.distributed.sharding import cache_specs
    mesh = FakeMesh({"data": 16, "model": 16})
    shapes = {
        "k": jax.ShapeDtypeStruct((64, 128, 32768, 8, 128), jnp.bfloat16),
        "h": jax.ShapeDtypeStruct((128, 4096), jnp.float32),
        "pos": jax.ShapeDtypeStruct((64, 32768), jnp.int32),
    }
    specs = cache_specs(mesh, shapes, batch=128)
    assert specs["k"] == P(None, "data", "model", None, None)
    assert specs["h"] == P("data", "model")
    assert specs["pos"] == P(None, "model")


def test_cache_spec_batch_one_replicates_batch():
    from repro.distributed.sharding import cache_specs
    mesh = FakeMesh({"data": 16, "model": 16})
    shapes = {"C": jax.ShapeDtypeStruct((1, 4, 1024, 1024), jnp.float32)}
    specs = cache_specs(mesh, shapes, batch=1)
    assert specs["C"][0] is None            # batch not sharded


HLO_SAMPLE = """
  %ag = bf16[16,512,7168]{2,1,0} all-gather(%p0), channel_id=1, replica_groups={{0,1,2,3}}, dimensions={0}
  %ar = f32[1024]{0} all-reduce(%x), replica_groups=[32,16]<=[512], to_apply=%add
  %rs = f32[64,128]{1,0} reduce-scatter(%y), replica_groups={{0,1}}, dimensions={0}
  %cp = f32[8,8]{1,0} collective-permute(%z), source_target_pairs={{0,1}}
  %ags = (bf16[4,4]{1,0}, bf16[4,4]{1,0}) all-gather-start(%q), replica_groups={{0,1,2,3}}
  %agd = bf16[4,4]{1,0} all-gather-done(%ags)
"""


def test_collective_parse():
    out = collective_bytes_from_hlo(HLO_SAMPLE)
    by = out["by_op"]
    ag = 16 * 512 * 7168 * 2 * (3 / 4)          # (G-1)/G * result
    ar = 1024 * 4 * 2 * (15 / 16)               # 2(G-1)/G, G=16 (iota)
    rs = 64 * 128 * 4 * 1                       # (G-1), G=2
    cp = 8 * 8 * 4
    ags = 2 * (4 * 4 * 2) * (3 / 4)             # tuple result, started op
    assert np.isclose(by["all-gather"], ag + ags)
    assert np.isclose(by["all-reduce"], ar)
    assert np.isclose(by["reduce-scatter"], rs)
    assert np.isclose(by["collective-permute"], cp)
    assert out["count"] == 5                     # -done not counted


def test_roofline_terms_dominant():
    cost = {"flops": 197e12, "bytes accessed": 819e9 * 2}
    coll = {"total": 50e9 * 0.5}
    r = roofline_terms(cost, coll)
    assert abs(r["compute_s"] - 1.0) < 1e-9
    assert abs(r["memory_s"] - 2.0) < 1e-9
    assert abs(r["collective_s"] - 0.5) < 1e-9
    assert r["dominant"] == "memory_s"


def test_model_flops_kinds():
    class Cfg:
        moe = None
    n = 1_000_000
    assert model_flops(Cfg, n, n, "train", 128, 4) == 6 * n * 128 * 4
    assert model_flops(Cfg, n, n, "prefill", 128, 4) == 2 * n * 128 * 4
    assert model_flops(Cfg, n, n, "decode", 128, 4) == 2 * n * 4


def test_input_specs_cells():
    from repro.configs import get_config
    from repro.launch.shapes import SHAPES, cell_supported, input_specs
    cfg = get_config("qwen3-4b")
    tr = input_specs(cfg, "train_4k")
    assert tr["batch"]["tokens"].shape == (256, 4096)
    pf = input_specs(cfg, "prefill_32k")
    assert pf["batch"]["tokens"].shape == (32, 32768)
    dc = input_specs(cfg, "decode_32k")
    assert dc["token"].shape == (128,)
    assert not cell_supported("qwen3-4b", "long_500k")
    assert cell_supported("xlstm-1.3b", "long_500k")
    assert cell_supported("recurrentgemma-2b", "long_500k")


def test_vlm_input_specs_include_patches():
    from repro.configs import get_config
    from repro.launch.shapes import input_specs
    cfg = get_config("phi-3-vision-4.2b")
    tr = input_specs(cfg, "train_4k")
    assert tr["batch"]["patch_embeds"].shape == (256, 576, 1024)
    cfg2 = get_config("whisper-base")
    tr2 = input_specs(cfg2, "train_4k")
    assert tr2["batch"]["frames"].shape == (256, 1500, 512)
