"""IIsy mapping fidelity: table inference vs direct model evaluation."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.inference import (table_predict, table_predict_per_tree,
                                  tree_vote_predict)
from repro.core.mapping import (map_kmeans, map_naive_bayes, map_svm,
                                map_tree_ensemble)
from repro.ml.kmeans import fit_kmeans, predict_kmeans
from repro.ml.naive_bayes import fit_gaussian_nb, predict_nb
from repro.ml.svm import fit_linear_svm, predict_svm
from repro.ml.trees import (fit_decision_tree, fit_isolation_forest,
                            fit_random_forest, fit_xgboost,
                            predict_iforest_score, predict_margin_xgboost,
                            predict_tree_ensemble, tree_leaf_indices)


def test_decision_tree_table_exact(anomaly_data):
    """A single tree's table pipeline must agree with walking the tree."""
    xtr, ytr, xte, _ = anomaly_data
    dt = fit_decision_tree(xtr, ytr, n_classes=2, max_depth=5)
    art = map_tree_ensemble(dt, xtr.shape[1])
    p_tab, _ = table_predict(art, xte)
    p_dir = predict_tree_ensemble(dt, xte)
    assert float(jnp.mean((p_tab == p_dir).astype(jnp.float32))) == 1.0


def test_rf_per_tree_equivalence(anomaly_data):
    """Every tree's table decision equals that tree's walked decision —
    the strongest mapping-correctness property (per-tree, not just
    ensemble-vote)."""
    xtr, ytr, xte, _ = anomaly_data
    rf = fit_random_forest(xtr, ytr, n_classes=2, n_trees=5, max_depth=4,
                           seed=3)
    art = map_tree_ensemble(rf, xtr.shape[1])
    table_cls = table_predict_per_tree(art, xte)           # (N, T)
    leaf_idx = tree_leaf_indices(rf, xte)                  # (T, N)
    counts = jnp.take_along_axis(rf.leaf, leaf_idx[:, :, None], axis=1)
    walked_cls = jnp.argmax(counts, axis=2).T              # (N, T)
    assert bool(jnp.all(table_cls == walked_cls))


def test_rf_vote_equivalence(anomaly_data):
    xtr, ytr, xte, _ = anomaly_data
    rf = fit_random_forest(xtr, ytr, n_classes=2, n_trees=6, max_depth=4)
    art = map_tree_ensemble(rf, xtr.shape[1])
    p_tab, _ = table_predict(art, xte)
    p_vote, _ = tree_vote_predict(rf, xte)
    assert bool(jnp.all(p_tab == p_vote))


def test_xgb_margin_close(anomaly_data):
    """Quantized table margin ~= float margin; predictions match."""
    xtr, ytr, xte, _ = anomaly_data
    xgb = fit_xgboost(xtr, ytr, n_trees=8, max_depth=4)
    art = map_tree_ensemble(xgb, xtr.shape[1], action_bits=16)
    p_tab, conf = table_predict(art, xte)
    p_dir = predict_tree_ensemble(xgb, xte)
    agree = float(jnp.mean((p_tab == p_dir).astype(jnp.float32)))
    assert agree > 0.999


def test_iforest_score_close(anomaly_data):
    xtr, _, xte, _ = anomaly_data
    iso = fit_isolation_forest(xtr, n_trees=16, max_depth=5, seed=1)
    art = map_tree_ensemble(iso, xtr.shape[1])
    p_tab, conf = table_predict(art, xte)
    score = predict_iforest_score(iso, xte)
    p_dir = (score > 0.5).astype(jnp.int32)
    agree = float(jnp.mean((p_tab == p_dir).astype(jnp.float32)))
    assert agree > 0.995


@pytest.mark.parametrize("n_classes", [2, 3])
def test_svm_multiclass_agreement(n_classes):
    rng = np.random.default_rng(0)
    centers = rng.normal(0, 4, (n_classes, 6))
    x = np.concatenate([rng.normal(c, 1.0, (400, 6)) for c in centers])
    y = np.repeat(np.arange(n_classes), 400)
    svm = fit_linear_svm(x.astype(np.float32), y, n_classes=n_classes)
    art = map_svm(svm, x, n_bins=64)
    p_tab, _ = table_predict(art, x.astype(np.float32))
    p_dir = predict_svm(svm, x.astype(np.float32))
    agree = float(jnp.mean((p_tab == p_dir).astype(jnp.float32)))
    assert agree > 0.97, agree


def test_nb_log_domain_no_underflow(anomaly_data):
    """Log-domain NB removes the paper's Fig-9 underflow failure mode."""
    xtr, ytr, xte, _ = anomaly_data
    nb = fit_gaussian_nb(xtr, ytr, n_classes=2)
    art = map_naive_bayes(nb, xtr, n_bins=64, action_bits=16)
    p_tab, conf = table_predict(art, xte)
    p_dir = predict_nb(nb, xte)
    agree = float(jnp.mean((p_tab == p_dir).astype(jnp.float32)))
    assert agree > 0.995
    assert bool(jnp.all(jnp.isfinite(conf)))


def test_kmeans_agreement(anomaly_data):
    xtr, _, xte, _ = anomaly_data
    km = fit_kmeans(xtr, k=3, seed=0)
    art = map_kmeans(km, xtr, n_bins=128)
    p_tab, _ = table_predict(art, xte)
    p_dir = predict_kmeans(km, xte)
    agree = float(jnp.mean((p_tab == p_dir).astype(jnp.float32)))
    assert agree > 0.99, agree


def test_action_bits_monotone(anomaly_data):
    """More action bits -> calc error does not increase (Fig 9 trend)."""
    from repro.core.quantize import quantize_fixed, relative_error
    rng = np.random.default_rng(1)
    v = rng.normal(0, 3, (64, 64)).astype(np.float32)
    errs = [relative_error(quantize_fixed(v, b), v) for b in (8, 12, 16, 24)]
    assert all(errs[i] >= errs[i + 1] for i in range(len(errs) - 1))


def test_quantize_symmetric_contract():
    """|dequantize(q)| <= max|v| for every code — the most-negative code
    -qmax-1 must never be emitted (deterministic twin of the hypothesis
    property; runs even without hypothesis installed)."""
    from repro.core.quantize import dequantize, quantize_fixed
    rng = np.random.default_rng(3)
    for bits in (4, 8, 12, 16):
        qmax = 2 ** (bits - 1) - 1
        for v in (rng.normal(0, 5, 300).astype(np.float32),
                  np.asarray([-1.0, 1.0], np.float32),
                  np.asarray([-7.25], np.float32),
                  np.linspace(-3, 3, 101, dtype=np.float32)):
            fp = quantize_fixed(v, bits)
            assert int(np.asarray(fp.q).min()) >= -qmax
            max_abs = float(np.abs(v).max())
            deq = np.abs(np.asarray(dequantize(fp)))
            assert float(deq.max()) <= max_abs * (1 + 1e-6)


def test_decision_table_cap():
    """Unmappable (too-deep/too-wide) ensembles raise, like a switch
    rejecting a model that does not fit (paper §4.2 pruning)."""
    rng = np.random.default_rng(0)
    x = rng.normal(0, 1, (2000, 12)).astype(np.float32)
    y = ((x.sum(axis=1) + 0.3 * rng.normal(size=2000)) > 0).astype(np.int32)
    rf = fit_random_forest(x, y, n_classes=2, n_trees=8, max_depth=8,
                           max_features=12, seed=0)
    with pytest.raises(ValueError, match="decision tables"):
        map_tree_ensemble(rf, 12, max_decision_entries=200)
