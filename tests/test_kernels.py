"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs ref.py oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import importlib

bk = importlib.import_module("repro.kernels.bucketize")
ck = importlib.import_module("repro.kernels.classical_lookup")
ek = importlib.import_module("repro.kernels.ensemble_lookup")
from repro.kernels import ref


def _edges(rng, f, u, pad_frac=0.3):
    e = np.sort(rng.normal(0, 10, (f, u)).astype(np.float32), axis=1)
    # ragged: pad a fraction of each row with +inf (never matches)
    for i in range(f):
        k = rng.integers(0, max(1, int(u * pad_frac)) + 1)
        if k:
            e[i, u - k:] = np.inf
    return e


@pytest.mark.parametrize("n,f,u", [
    (256, 1, 1), (256, 5, 7), (512, 3, 33), (256, 8, 64), (512, 16, 128),
])
def test_bucketize_matches_ref(n, f, u):
    rng = np.random.default_rng(n + f + u)
    x = rng.normal(0, 12, (n, f)).astype(np.float32)
    edges = _edges(rng, f, u)
    out = bk.bucketize_pallas(jnp.asarray(x), jnp.asarray(edges),
                              interpret=True)
    expect = ref.bucketize_ref(jnp.asarray(x), jnp.asarray(edges))
    np.testing.assert_array_equal(np.asarray(out), np.asarray(expect))


def test_bucketize_edge_values_exact():
    """Values exactly on an edge must bin consistently (x > e rule)."""
    edges = jnp.asarray([[1.0, 2.0, 3.0, jnp.inf]], jnp.float32)
    x = jnp.asarray([[0.5], [1.0], [1.5], [2.0], [3.0], [99.0]] * 43
                    + [[0.0]] * (256 - 258 + 2 * 1), jnp.float32)
    x = jnp.tile(jnp.asarray([[0.5], [1.0], [1.5], [2.0], [3.0], [99.0],
                              [jnp.float32(-1e30)], [3.0000002]],
                             jnp.float32), (32, 1))
    out = bk.bucketize_pallas(x, edges, interpret=True)
    expect = ref.bucketize_ref(x, edges)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(expect))


def _random_artifact(rng, f, u, t, s_per_tree, n_classes, vote):
    edges = _edges(rng, f, u, pad_frac=0.0)
    radix = rng.integers(1, 4, (t, f))
    ftable = np.zeros((f, u + 1, t), np.int32)
    for ti in range(t):
        for fi in range(f):
            ftable[fi, :, ti] = np.minimum(
                np.sort(rng.integers(0, radix[ti, fi], u + 1)),
                radix[ti, fi] - 1)
    strides = np.zeros((t, f), np.int64)
    for ti in range(t):
        s = 1
        for fi in range(f - 1, -1, -1):
            strides[ti, fi] = s
            s *= radix[ti, fi]
    smax = int(max(np.prod(radix[ti]) for ti in range(t)))
    if vote:
        dtable = rng.integers(0, n_classes, (t, smax)).astype(np.float32)
    else:
        dtable = rng.integers(-500, 500, (t, smax)).astype(np.float32)
    return (jnp.asarray(edges), jnp.asarray(ftable),
            jnp.asarray(strides.astype(np.int32)), jnp.asarray(dtable))


@pytest.mark.parametrize("vote", [True, False])
@pytest.mark.parametrize("n,f,u,t", [
    (128, 2, 4, 1), (128, 5, 16, 6), (256, 3, 8, 10),
])
def test_ensemble_lookup_matches_ref(n, f, u, t, vote):
    rng = np.random.default_rng(n * 7 + f + u + t + vote)
    n_classes = 3
    edges, ftable, strides, dtable = _random_artifact(
        rng, f, u, t, None, n_classes, vote)
    x = jnp.asarray(rng.normal(0, 12, (n, f)).astype(np.float32))
    out = ek.ensemble_lookup_pallas(x, edges, ftable, strides, dtable,
                                    n_classes=n_classes, vote=vote,
                                    interpret=True)
    expect = ref.ensemble_lookup_ref(x, edges, ftable, strides, dtable,
                                     n_classes=n_classes, vote=vote)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=0, atol=0)


@pytest.mark.parametrize("n,f,u,m", [
    (128, 1, 4, 1), (128, 5, 32, 2), (256, 8, 64, 5),
])
def test_classical_lookup_matches_ref(n, f, u, m):
    rng = np.random.default_rng(n + f + u + m)
    x = jnp.asarray(rng.normal(0, 5, (n, f)).astype(np.float32))
    edges = jnp.asarray(_edges(rng, f, u))
    vtable = jnp.asarray(
        rng.integers(-1000, 1000, (f, u + 1, m)).astype(np.float32))
    out = ck.classical_lookup_pallas(x, edges, vtable, interpret=True)
    expect = ref.classical_lookup_ref(x, edges, vtable)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=0, atol=0)


def test_fused_classify_equals_table_predict(anomaly_data):
    """End-to-end: the fused kernel path == pure-jnp inference, all kinds."""
    from repro.core.inference import table_predict
    from repro.kernels.ops import fused_classify
    from benchmarks.common import fit_and_map

    xtr, ytr, xte, yte = anomaly_data
    for model in ("DT", "RF", "XGB", "SVM", "Bayes", "KMeans"):
        _, art, _ = fit_and_map(model, xtr, ytr, n_trees=4, max_depth=4)
        p_ref, c_ref = table_predict(art, xte[:512])
        p_k, c_k = fused_classify(art, xte[:512], use_pallas=True,
                                  interpret=True)
        np.testing.assert_array_equal(np.asarray(p_k), np.asarray(p_ref))
        np.testing.assert_allclose(np.asarray(c_k), np.asarray(c_ref),
                                   atol=1e-5)


def test_batch_padding_path():
    """Non-multiple-of-tile batches round-trip through ops.bucketize."""
    from repro.kernels.ops import bucketize
    rng = np.random.default_rng(0)
    x = rng.normal(0, 5, (131, 3)).astype(np.float32)
    edges = _edges(rng, 3, 9)
    out = bucketize(jnp.asarray(x), jnp.asarray(edges), use_pallas=True)
    expect = ref.bucketize_ref(jnp.asarray(x), jnp.asarray(edges))
    assert out.shape == (131, 3)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(expect))
