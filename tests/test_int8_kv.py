"""int8 KV cache (beyond-paper optimization: "action data bits" applied
to the serving backend's KV memory)."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.models import model as M
from repro.models.attention import _q8


def _place(d, src):
    if d.shape == src.shape:
        return src.astype(d.dtype)
    return d.at[tuple(slice(0, x) for x in src.shape)].set(
        src.astype(d.dtype))


def _fill_quantized(dst, src):
    """Recursive: quantize bf16 prefill KV into int8 cache slots."""
    if isinstance(dst, dict) and "k_scale" in dst:
        out = dict(dst)
        for key in ("k", "v"):
            q, sc = _q8(src[key])
            out[key] = _place(dst[key], q)
            out[key + "_scale"] = _place(dst[key + "_scale"], sc)
        out["pos"] = _place(dst["pos"], src["pos"])
        return out
    if isinstance(dst, dict):
        return {k: _fill_quantized(dst[k], src[k]) for k in dst}
    if isinstance(dst, (list, tuple)):
        return type(dst)(_fill_quantized(d, s) for d, s in zip(dst, src))
    return _place(dst, src)


def test_int8_kv_decode_close_and_halves_bytes():
    cfg = get_smoke_config("qwen2.5-32b")
    params = M.init_model(cfg, jax.random.PRNGKey(0))
    b, s = 2, 12
    toks = jax.random.randint(jax.random.PRNGKey(1), (b, s), 0,
                              cfg.vocab_size)
    ref, _ = M.prefill(params, cfg, {"tokens": toks})
    _, caches = M.prefill(params, cfg, {"tokens": toks[:, :s - 1]})

    dcq = M.init_decode_cache(cfg, b, s + 4, dtype=jnp.float32,
                              quantize_kv=True)
    dcq = _fill_quantized(dcq, caches)
    lq, _ = M.decode_step(params, cfg, toks[:, s - 1], s - 1, dcq)
    rel = float(jnp.max(jnp.abs(ref - lq)) / jnp.max(jnp.abs(ref)))
    assert rel < 0.05, rel
    assert bool(jnp.all(jnp.argmax(ref, -1) == jnp.argmax(lq, -1)))

    # bytes: int8 cache ~half of bf16 (scales add (1/hd) overhead)
    import math
    bf16 = sum(math.prod(l.shape) * l.dtype.itemsize for l in
               jax.tree.leaves(jax.eval_shape(
                   lambda: M.init_decode_cache(cfg, 4, 64))))
    i8 = sum(math.prod(l.shape) * l.dtype.itemsize for l in
             jax.tree.leaves(jax.eval_shape(
                 lambda: M.init_decode_cache(cfg, 4, 64,
                                             quantize_kv=True))))
    assert i8 < 0.65 * bf16, (i8, bf16)


def test_q8_roundtrip_error_bound():
    rng = np.random.default_rng(0)
    v = jnp.asarray(rng.normal(0, 3, (4, 8, 2, 16)).astype(np.float32))
    q, s = _q8(v)
    deq = q.astype(jnp.float32) * s
    err = jnp.max(jnp.abs(deq - v), axis=-1)
    bound = jnp.max(jnp.abs(v), axis=-1) / 127.0
    assert bool(jnp.all(err <= bound * 1.001))
