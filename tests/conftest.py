"""Shared fixtures. NOTE: no XLA_FLAGS here — tests run on 1 CPU device;
only launch/dryrun.py requests 512 placeholder devices."""

import numpy as np
import pytest


@pytest.fixture(scope="session")
def anomaly_data():
    from repro.data.unsw_like import make_unsw_like, train_test_split
    x, y = make_unsw_like(6000, seed=0, n_features=5)
    return train_test_split(x, y)


@pytest.fixture(scope="session")
def finance_data():
    from repro.data.janestreet_like import (SWITCH_FEATURES,
                                            make_janestreet_like,
                                            train_test_split)
    x, y = make_janestreet_like(6000, seed=0)
    return train_test_split(x[:, SWITCH_FEATURES], y)
