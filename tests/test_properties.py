"""Hypothesis property tests on the system's invariants."""

import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="hypothesis not installed; property tests skipped")
from hypothesis import given, settings, strategies as st

from repro.core.quantize import dequantize, quantize_fixed
from repro.kernels import ref

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")


_FLOATS = st.floats(-1e4, 1e4, allow_nan=False, width=32,
                    allow_subnormal=False)   # XLA flushes subnormals (FTZ)


@given(
    st.integers(1, 40).flatmap(lambda n: st.tuples(
        st.just(n),
        st.lists(_FLOATS, min_size=n, max_size=n),
    )),
    st.lists(_FLOATS, min_size=1, max_size=12),
)
def test_bucketize_is_rank(pair, edges_raw):
    """bucketize(x) == #{edges < ... } is the rank of x among edges —
    monotone in x, bounded by the edge count, exact on ties."""
    _, xs = pair
    edges = np.sort(np.asarray(edges_raw, np.float32))
    x = np.asarray(xs, np.float32)[:, None]                 # (N, 1)
    out = np.asarray(ref.bucketize_ref(
        jnp.asarray(x), jnp.asarray(edges[None, :])))[:, 0]
    # bounds
    assert out.min() >= 0 and out.max() <= len(edges)
    # monotone: sort x, bins must be sorted
    order = np.argsort(x[:, 0], kind="stable")
    assert (np.diff(out[order]) >= 0).all()
    # exact semantics
    expect = (x > edges[None, :]).sum(axis=1)
    np.testing.assert_array_equal(out, expect)


@given(st.lists(st.floats(-1e3, 1e3, allow_nan=False, width=32),
                min_size=1, max_size=200),
       st.sampled_from([8, 12, 16, 24]))
def test_quantize_bounded_error(vals, bits):
    """|dequant(quant(v)) - v| <= max|v| / (2^(bits-1) - 1) elementwise."""
    v = np.asarray(vals, np.float32)
    fp = quantize_fixed(v, bits)
    deq = np.asarray(dequantize(fp))
    bound = (np.abs(v).max() + 1e-12) / (2 ** (bits - 1) - 1)
    assert np.all(np.abs(deq - v) <= bound * 1.0001)


@given(st.lists(st.floats(-1e4, 1e4, allow_nan=False, width=32,
                          allow_subnormal=False), min_size=1, max_size=200),
       st.sampled_from([4, 8, 12, 16]))
def test_quantize_symmetric_range(vals, bits):
    """Symmetric fixed point never dequantizes past max|v|: the clip is
    ±qmax, not [-qmax-1, qmax] (regression — the extra negative code
    broke the module's symmetric contract)."""
    v = np.asarray(vals, np.float32)
    fp = quantize_fixed(v, bits)
    qmax = 2 ** (bits - 1) - 1
    assert int(np.asarray(fp.q).min()) >= -qmax
    assert int(np.asarray(fp.q).max()) <= qmax
    max_abs = max(float(np.abs(v).max()), 1e-12)
    assert float(np.abs(np.asarray(dequantize(fp))).max()) \
        <= max_abs * (1 + 1e-6)


@given(st.integers(2, 64), st.integers(1, 8), st.integers(0, 3))
def test_quantize_integer_sum_exact(n, m, seed):
    """Summing in the integer domain then dequantizing == summing
    dequantized values (the switch-ALU exactness property)."""
    rng = np.random.default_rng(seed)
    v = rng.normal(0, 10, (n, m)).astype(np.float32)
    fp = quantize_fixed(v, 16)
    left = fp.q.sum(axis=0).astype(np.float32) / np.asarray(fp.scale)
    right = np.asarray(dequantize(fp)).sum(axis=0)
    # f32 rounding in the two division orders; integer path is the exact one
    np.testing.assert_allclose(left, right, rtol=1e-4, atol=1e-4)


@given(st.integers(0, 1000))
def test_data_pipeline_deterministic(step):
    """batch(step) is a pure function of (seed, step, shard) — the
    failover-recompute property."""
    from repro.data.lm_pipeline import TokenPipeline
    p1 = TokenPipeline(1024, 16, 4, seed=7)
    p2 = TokenPipeline(1024, 16, 4, seed=7)
    b1, b2 = p1.batch(step), p2.batch(step)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    np.testing.assert_array_equal(b1["labels"], b2["labels"])


@given(st.integers(1, 30), st.integers(2, 8), st.integers(0, 5))
def test_hybrid_dispatch_roundtrip(n_fwd, cap, seed):
    """dispatch/combine: forwarded rows (up to capacity) get the backend
    answer; everything else keeps the switch answer."""
    from repro.core.hybrid import combine, dispatch
    rng = np.random.default_rng(seed)
    n = 32
    mask = np.zeros(n, bool)
    mask[rng.choice(n, size=min(n_fwd, n), replace=False)] = True
    x = rng.normal(0, 1, (n, 3)).astype(np.float32)
    sw = np.zeros(n, np.int32)
    buf, idx, valid = dispatch(jnp.asarray(x), jnp.asarray(mask), cap)
    be = jnp.ones((cap,), jnp.int32)
    out = np.asarray(combine(jnp.asarray(sw), be, idx, valid))
    n_served = min(int(mask.sum()), cap)
    assert out.sum() == n_served
    # all served rows were actually forwarded rows
    assert np.all(mask[np.asarray(idx)[np.asarray(valid)]])


@given(st.integers(2, 4), st.integers(0, 3))
def test_moe_capacity_conservation(top_k, seed):
    """MoE combine weights: every kept (token,slot) contributes its router
    weight exactly once; dropped units contribute zero."""
    import jax
    from repro.models.moe import moe_forward
    from repro.models.config import ArchConfig, MoEConfig
    cfg = ArchConfig(name="t", family="moe", n_layers=1, d_model=16,
                     n_heads=2, n_kv_heads=2, d_ff=16, vocab_size=64,
                     moe=MoEConfig(n_experts=4, top_k=top_k, d_expert=16,
                                   capacity_factor=1.0))
    from repro.models.moe import moe_params
    p = moe_params(jax.random.PRNGKey(seed), cfg)
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (2, 8, 16))
    out, aux = moe_forward(p, cfg, x)
    assert out.shape == x.shape
    assert np.isfinite(float(aux))
    assert np.all(np.isfinite(np.asarray(out)))


_INGEST_TRACE = None


def _ingest_trace():
    """Small shared trace for the ring-replay property (built lazily so
    collection stays import-cheap when hypothesis is absent)."""
    global _INGEST_TRACE
    if _INGEST_TRACE is None:
        from repro.netsim.packets import synth_trace
        _INGEST_TRACE = synth_trace(n_flows=30, seed=17)
    return _INGEST_TRACE


@given(st.integers(1, 400), st.sampled_from([3, 5, 8]),
       st.sampled_from([1, 2, 3]), st.booleans())
def test_ring_replay_bit_identical_to_iter_chunks(batch, window, k,
                                                  use_deadline):
    """Window-granular cut invariant (DESIGN.md §13): replaying a trace
    through the ingest ring in ANY batch size, with count cuts, deadline
    cuts (aggressive fake clock) and the ragged-tail drain all firing,
    yields exactly the window sequence of the offline ``iter_chunks``
    iterator — cuts regroup windows, they never move a boundary."""
    from repro.netsim.ingest import PacketRingBuffer, cut_stream, \
        replay_source
    from repro.netsim.stream import iter_chunks
    trace = _ingest_trace()
    n_buckets = 64
    state = {"t": 0.0}

    def clock():
        state["t"] += 1.0          # every look at the clock ages the ring
        return state["t"]

    ring = PacketRingBuffer(window, k, n_buckets,
                            deadline=0.5 if use_deadline else None,
                            clock=clock)
    cuts = list(cut_stream(ring, replay_source(trace, batch=batch)))
    assert sum(c.n for c in cuts) == trace.n_packets
    assert ring.stats.admitted == trace.n_packets
    assert ring.stats.dropped == 0             # pull-based: nothing drops
    assert all(c.kind in ("count", "deadline", "drain") for c in cuts)
    if use_deadline:
        assert ring.stats.deadline_cuts + ring.stats.count_cuts \
            + ring.stats.drain_cuts == len(cuts)
    else:
        assert ring.stats.deadline_cuts == 0

    ref = list(iter_chunks(trace, window, k, n_buckets))
    n_live = -(-trace.n_packets // window) * window   # live windows, padded
    for field in ("bucket", "ts", "length", "is_fwd", "valid"):
        got = np.concatenate([
            (c.valid if field == "valid" else c.cols[field])
            [:c.n_windows * c.window] for c in cuts])
        want = np.concatenate([np.asarray(getattr(rc, field)).reshape(-1)
                               for rc in ref])[:n_live]
        np.testing.assert_array_equal(got, want, err_msg=field)
