"""Device-resident chunked streaming: scan megastep contracts.

The contracts under test (DESIGN.md §8):

* ``iter_chunks`` rows are bit-identical to the ``iter_windows`` windows
  (ragged final chunk padded with dead all-invalid windows);
* ``chunk_update_readout`` — the packed-register scan — folds and reads
  out exactly like K ``window_update_readout`` steps, across saturation,
  eviction and chunk-size regimes;
* chunked serving (``serve_trace`` with ``chunk_windows``) returns
  predictions, flow tables and accounting bit-identical to the
  per-window baseline, on both the fused and the two-phase backend
  paths, with exactly ceil(windows / K) backend invocations;
* the fused Pallas scatter/readout kernel (``kernels/stream_update``)
  matches the XLA reference bit for bit in interpret mode;
* occupancy-triggered early flush splits deferral cycles without
  changing a single final prediction;
* the autotune sweep includes the loop/reference realizations and every
  ``TileConfig.impl`` classifies bit-identically.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.mapping import map_tree_ensemble
from repro.kernels.ops import fused_classify, stream_update
from repro.kernels.ref import stream_update_ref
from repro.kernels.tuning import TileConfig, candidate_tiles
from repro.ml.trees import fit_random_forest, predict_tree_ensemble
from repro.netsim.features import flow_features
from repro.netsim.packets import synth_trace
from repro.netsim.stream import (OVERFLOW_LIMIT, REGISTER_FIELDS,
                                 PacketChunk, PacketWindow,
                                 chunk_update_readout, init_flow_table,
                                 iter_chunks, iter_windows,
                                 window_update_readout)
from repro.serving.stream_serving import StreamingHybridServer

N_BUCKETS = 1 << 11
W_FIELDS = ("bucket", "ts", "length", "is_fwd", "valid")


@pytest.fixture(scope="module")
def chunk_setup():
    trace = synth_trace(n_flows=300, seed=3)
    b, table = flow_features(trace, n_buckets=N_BUCKETS)
    first_idx = np.unique(np.asarray(trace.flow_id), return_index=True)[1]
    rows = np.asarray(table)[np.asarray(b)[first_idx]].astype(np.float32)
    small = fit_random_forest(rows, trace.flow_label, n_classes=2,
                              n_trees=4, max_depth=3, seed=0)
    big = fit_random_forest(rows, trace.flow_label, n_classes=2,
                            n_trees=12, max_depth=5, seed=1)
    art = map_tree_ensemble(small, rows.shape[1])
    return trace, art, (lambda r: predict_tree_ensemble(big, r))


def _assert_states_equal(a, b):
    for f in REGISTER_FIELDS:
        np.testing.assert_array_equal(np.asarray(getattr(a, f)),
                                      np.asarray(getattr(b, f)),
                                      err_msg=f)


# ---------------------------------------------------------------------------
# chunk iterator
# ---------------------------------------------------------------------------

def test_iter_chunks_rows_equal_iter_windows():
    """Row k of the chunk stream == the k-th per-window stream window,
    bitwise; the ragged final chunk is padded with dead windows."""
    tr = synth_trace(n_flows=150, seed=9)
    ws = list(iter_windows(tr, 128, N_BUCKETS))
    for k in (1, 3, 8):
        rows = 0
        for c in iter_chunks(tr, 128, k, N_BUCKETS):
            assert c.n_windows == k and c.window == 128
            for i in range(k):
                if rows < len(ws):
                    w = ws[rows]
                    for f in W_FIELDS:
                        np.testing.assert_array_equal(
                            np.asarray(getattr(c, f)[i]),
                            np.asarray(getattr(w, f)))
                else:   # dead pad window: every lane invalid
                    assert not bool(jnp.any(c.valid[i]))
                rows += 1
        assert rows == -(-len(ws) // k) * k


def test_iter_windows_device_matches_host_path():
    """device=True (one transfer + device slicing) yields bit-identical
    windows to the per-window host-slicing path."""
    tr = synth_trace(n_flows=150, seed=9)
    host = list(iter_windows(tr, 200, N_BUCKETS, device=False))
    dev = list(iter_windows(tr, 200, N_BUCKETS, device=True))
    assert len(host) == len(dev)
    for a, b in zip(host, dev):
        for f in W_FIELDS:
            np.testing.assert_array_equal(np.asarray(getattr(a, f)),
                                          np.asarray(getattr(b, f)))


# ---------------------------------------------------------------------------
# chunked register fold + readout
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("evict_age,saturate",
                         [(None, True), (None, False), (1.5, True)])
def test_chunk_update_readout_bit_equals_stepwise(evict_age, saturate):
    """The packed-register chunk scan == K window_update_readout steps:
    same registers, same readout rows, same eviction/overflow counts —
    at several chunk sizes including a ragged final chunk."""
    tr = synth_trace(n_flows=200, seed=5)
    ws = list(iter_windows(tr, 128, N_BUCKETS))
    s_ref = init_flow_table(N_BUCKETS)
    xs_ref, n_ev_ref, n_ov_ref = [], 0, 0
    for w in ws:
        s_ref, x, ev, ov = window_update_readout(
            s_ref, w, evict_age=evict_age, saturate=saturate,
            use_pallas=False)
        xs_ref.append(np.asarray(x))
        n_ev_ref += int(ev)
        n_ov_ref += int(ov)
    for k in (1, 2, 8):
        s = init_flow_table(N_BUCKETS)
        xs, n_ev, n_ov = [], 0, 0
        for c in iter_chunks(tr, 128, k, N_BUCKETS):
            s, x, ev, ov = chunk_update_readout(
                s, c, evict_age=evict_age, saturate=saturate,
                use_pallas=False)
            xs.append(np.asarray(x))
            n_ev += int(ev)
            n_ov += int(ov)
        xs = np.concatenate(xs)[:len(ws)]
        for i, x_ref in enumerate(xs_ref):
            np.testing.assert_array_equal(xs[i], x_ref,
                                          err_msg=f"window {i}, k={k}")
        _assert_states_equal(s_ref, s)
        assert (n_ev, n_ov) == (n_ev_ref, n_ov_ref)


def _one_lane_chunk(bucket, ts, length, k_pad=2):
    """A chunk whose first window holds one packet, padded with dead
    windows — the smallest fixture that can saturate a register."""
    z = jnp.zeros((k_pad, 1), jnp.float32)
    col = lambda v: z.at[0, 0].set(v)
    return PacketChunk(
        bucket=jnp.zeros((k_pad, 1), jnp.int32).at[0, 0].set(bucket),
        ts=col(ts), length=col(length), is_fwd=col(1.0),
        valid=jnp.zeros((k_pad, 1), bool).at[0, 0].set(True))


def test_chunk_overflow_counted_once():
    """Saturation inside a chunk: the clamp lands and the slot counts
    exactly once across chunks — same contract as the per-window guard."""
    s = init_flow_table(16)
    s, _, _, ov1 = chunk_update_readout(
        s, _one_lane_chunk(3, 0.0, OVERFLOW_LIMIT + 1024.0),
        saturate=True, use_pallas=False)
    assert int(ov1) == 2                      # byte_count AND fwd_bytes
    assert float(s.byte_count[3]) == OVERFLOW_LIMIT
    s, _, _, ov2 = chunk_update_readout(
        s, _one_lane_chunk(3, 1.0, 2048.0), saturate=True, use_pallas=False)
    assert int(ov2) == 0                      # already saturated: no recount
    assert float(s.byte_count[3]) == OVERFLOW_LIMIT


# ---------------------------------------------------------------------------
# chunked serving equivalence oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("k", (1, 2, 8))
def test_chunked_serving_bit_matches_per_window(chunk_setup, k):
    """The tentpole oracle: serve_trace through the scan megastep returns
    the same final predictions, flow table and accounting as the
    per-window path, with ceil(windows/k) backend invocations."""
    trace, art, backend = chunk_setup
    kw = dict(n_buckets=N_BUCKETS, window=256, threshold=0.9, capacity=32)
    ref = StreamingHybridServer(art, backend, **kw)
    p_ref, s_ref = ref.serve_trace(trace)
    srv = StreamingHybridServer(art, backend, chunk_windows=k, **kw)
    p, s = srv.serve_trace(trace)
    assert srv._fused_ok is True              # single-dispatch megastep ran
    np.testing.assert_array_equal(np.asarray(p), np.asarray(p_ref))
    np.testing.assert_array_equal(np.asarray(srv.flow_table()),
                                  np.asarray(ref.flow_table()))
    assert s.n_windows == s_ref.n_windows     # dead pad windows not counted
    assert s.n_packets == s_ref.n_packets
    assert s.n_handled == s_ref.n_handled
    assert s.total_backend_rows == s_ref.total_backend_rows
    assert s.n_deferred == s_ref.n_deferred
    assert s.n_flushes == -(-s.n_windows // k)


def test_chunked_serving_with_eviction_matches(chunk_setup):
    """Eviction + saturation inside a chunk: the scan applies the aging
    sweep per window, so lifecycle serving is bit-identical too."""
    trace, art, backend = chunk_setup
    kw = dict(n_buckets=N_BUCKETS, window=256, threshold=0.9, capacity=32,
              evict_age=1.0, saturate=True)
    ref = StreamingHybridServer(art, backend, **kw)
    p_ref, s_ref = ref.serve_trace(trace)
    assert s_ref.n_evicted > 0                # the sweep actually fired
    srv = StreamingHybridServer(art, backend, chunk_windows=4, **kw)
    p, s = srv.serve_trace(trace)
    np.testing.assert_array_equal(np.asarray(p), np.asarray(p_ref))
    np.testing.assert_array_equal(np.asarray(srv.flow_table()),
                                  np.asarray(ref.flow_table()))
    assert s.n_evicted == s_ref.n_evicted
    assert s.n_overflow == s_ref.n_overflow


def test_chunked_two_phase_matches_fused(chunk_setup):
    """Untraceable backend: the two-phase chunk path (jitted switch half,
    host backend, jitted back-patch) is bit-identical to the fused
    megastep and to the per-window baseline."""
    trace, art, _ = chunk_setup
    b, table = flow_features(trace, n_buckets=N_BUCKETS)
    first_idx = np.unique(np.asarray(trace.flow_id), return_index=True)[1]
    rows = np.asarray(table)[np.asarray(b)[first_idx]].astype(np.float32)
    big = fit_random_forest(rows, trace.flow_label, n_classes=2,
                            n_trees=12, max_depth=5, seed=1)

    def np_backend(r):
        return np.asarray(predict_tree_ensemble(big, np.asarray(r)))

    kw = dict(n_buckets=N_BUCKETS, window=256, threshold=0.9, capacity=32,
              chunk_windows=4)
    fused = StreamingHybridServer(
        art, lambda r: predict_tree_ensemble(big, r), **kw)
    p_f, s_f = fused.serve_trace(trace)
    assert fused._fused_ok is True
    twop = StreamingHybridServer(art, np_backend, **kw)
    p_t, s_t = twop.serve_trace(trace)
    assert twop._fused_ok is False
    np.testing.assert_array_equal(np.asarray(p_t), np.asarray(p_f))
    assert s_t.n_flushes == s_f.n_flushes
    assert s_t.total_backend_rows == s_f.total_backend_rows


def test_step_chunk_interface_validation(chunk_setup):
    trace, art, backend = chunk_setup
    with pytest.raises(ValueError):           # chunking IS the flush cycle
        StreamingHybridServer(art, backend, chunk_windows=4, flush_every=2)
    with pytest.raises(ValueError):
        StreamingHybridServer(art, backend, chunk_windows=0)
    srv = StreamingHybridServer(art, backend, n_buckets=N_BUCKETS,
                                window=256, chunk_windows=4)
    c = next(iter_chunks(trace, 256, 2, N_BUCKETS))
    with pytest.raises(ValueError):           # compiled for K=4, got K=2
        srv.step_chunk(c)
    plain = StreamingHybridServer(art, backend, n_buckets=N_BUCKETS,
                                  window=256)
    with pytest.raises(ValueError):           # server built without chunking
        plain.step_chunk(c)


# ---------------------------------------------------------------------------
# fused Pallas scatter/readout kernel parity (interpret mode)
# ---------------------------------------------------------------------------

def test_stream_update_kernel_matches_ref():
    """Pallas kernel == XLA segment/gather oracle, bitwise, across
    limit on/off, pad lanes, untouched-bucket ±inf identities, and a
    bucket count that is not a tile multiple."""
    rng = np.random.default_rng(0)
    n, w = 600, 96                            # 600 forces column padding
    regs = np.zeros((8, n), np.float32)
    regs[2] = np.inf
    regs[3] = -np.inf
    regs[0, 5] = 3.0
    regs[1, 5] = 300.0
    regs[2, 5] = 0.5
    regs[3, 5] = 1.5
    args = (jnp.asarray(rng.integers(0, n, w).astype(np.int32)),
            jnp.asarray(rng.uniform(0, 10, w).astype(np.float32)),
            jnp.asarray(rng.integers(40, 1500, w).astype(np.float32)),
            jnp.asarray(rng.integers(0, 2, w).astype(np.float32)),
            jnp.asarray(rng.random(w) > 0.2))
    for limit in (None, 1000.0):
        ref_regs, ref_rows = stream_update_ref(jnp.asarray(regs), *args,
                                               limit=limit)
        pl_regs, pl_rows = stream_update(jnp.asarray(regs), *args,
                                         limit=limit, use_pallas=True,
                                         interpret=True)
        np.testing.assert_array_equal(np.asarray(ref_regs),
                                      np.asarray(pl_regs))
        np.testing.assert_array_equal(np.asarray(ref_rows),
                                      np.asarray(pl_rows))


def test_window_update_readout_kernel_path_matches_reference():
    """The serving-step register half is bit-identical whether it runs
    the fused kernel (interpret mode) or the XLA composition — including
    the aging sweep and the overflow guard around it."""
    tr = synth_trace(n_flows=80, seed=11)
    s_ref = init_flow_table(512)
    s_ker = init_flow_table(512)
    for w in iter_windows(tr, 128, 512):
        s_ref, x_ref, ev_r, ov_r = window_update_readout(
            s_ref, w, evict_age=2.0, saturate=True, use_pallas=False)
        s_ker, x_ker, ev_k, ov_k = window_update_readout(
            s_ker, w, evict_age=2.0, saturate=True, use_pallas=True,
            interpret=True)
        np.testing.assert_array_equal(np.asarray(x_ref), np.asarray(x_ker))
        assert int(ev_r) == int(ev_k) and int(ov_r) == int(ov_k)
    _assert_states_equal(s_ref, s_ker)


# ---------------------------------------------------------------------------
# occupancy-triggered early flush
# ---------------------------------------------------------------------------

def test_occupancy_flush_bit_identical_with_more_flushes(chunk_setup):
    """A low occupancy threshold flushes cycles early (more backend
    invocations than the fixed cadence) without changing one final
    prediction — an early flush only splits the cycle."""
    trace, art, backend = chunk_setup
    kw = dict(n_buckets=N_BUCKETS, window=256, threshold=0.9, capacity=32)
    ref = StreamingHybridServer(art, backend, **kw)
    p_ref, _ = ref.serve_trace(trace)
    fixed = StreamingHybridServer(art, backend, flush_every=8, **kw)
    _, s_fixed = fixed.serve_trace(trace)
    early = StreamingHybridServer(art, backend, flush_every=8,
                                  flush_occupancy=0.25, **kw)
    p, s = early.serve_trace(trace)
    np.testing.assert_array_equal(np.asarray(p), np.asarray(p_ref))
    assert s.n_flushes > s_fixed.n_flushes
    assert s.total_backend_rows == s_fixed.total_backend_rows


def test_flush_occupancy_validation(chunk_setup):
    trace, art, backend = chunk_setup
    with pytest.raises(ValueError):           # needs a deferral cycle
        StreamingHybridServer(art, backend, flush_occupancy=0.5)
    with pytest.raises(ValueError):
        StreamingHybridServer(art, backend, flush_every=4,
                              flush_occupancy=1.5)


# ---------------------------------------------------------------------------
# autotune impl candidates (loop / reference)
# ---------------------------------------------------------------------------

def test_candidate_tiles_include_loop_and_ref():
    """The sweep can tune *away* from the fused kernel: the loop kernel
    and the XLA reference are candidates (the rf_narrow regression —
    fused slower than loop — is no longer the forced winner)."""
    impls = {t.impl for t in candidate_tiles(2048)}
    assert {"fused", "loop", "ref"} <= impls


def test_fused_classify_impl_routing_bit_identical(chunk_setup):
    """Every TileConfig.impl realization classifies bit-identically, so
    the tuner is free to pick any of them."""
    trace, art, _ = chunk_setup
    _, table = flow_features(trace, n_buckets=N_BUCKETS)
    x = np.asarray(table)[:256].astype(np.float32)
    p_ref, c_ref = fused_classify(art, x, use_pallas=False)
    for impl in ("loop", "ref", "fused"):
        p, c = fused_classify(art, x, use_pallas=True, interpret=True,
                              tiles=TileConfig(impl=impl))
        np.testing.assert_array_equal(np.asarray(p), np.asarray(p_ref))
        np.testing.assert_array_equal(np.asarray(c), np.asarray(c_ref))


def test_fused_classify_loop_impl_rejected_for_classical():
    from repro.core.mapping import map_svm
    from repro.ml.svm import fit_linear_svm
    rng = np.random.default_rng(0)
    x = rng.normal(size=(200, 5)).astype(np.float32)
    y = (x[:, 0] > 0).astype(np.int32)
    art = map_svm(fit_linear_svm(x, y, n_classes=2, seed=0), x)
    with pytest.raises(ValueError):
        fused_classify(art, x, use_pallas=True, interpret=True,
                       tiles=TileConfig(impl="loop"))


# ---------------------------------------------------------------------------
# lifecycle at chunk boundaries: evict / re-admit / saturate mid-chunk
# ---------------------------------------------------------------------------

def _lane_chunk(entries, k):
    """(k, 1) chunk with one packet per listed window: entries maps
    window index -> (bucket, ts, length); unlisted windows are dead."""
    z = lambda dt, v: jnp.full((k, 1), v, dt)
    bucket, ts, length = z(jnp.int32, 0), z(jnp.float32, 0.0), \
        z(jnp.float32, 0.0)
    valid = jnp.zeros((k, 1), bool)
    for i, (b, t, ln) in entries.items():
        bucket = bucket.at[i, 0].set(b)
        ts = ts.at[i, 0].set(t)
        length = length.at[i, 0].set(ln)
        valid = valid.at[i, 0].set(True)
    return PacketChunk(bucket=bucket, ts=ts, length=length,
                       is_fwd=jnp.ones((k, 1), jnp.float32), valid=valid)


def _windows_of(chunk):
    return [PacketWindow(**{f: getattr(chunk, f)[i] for f in W_FIELDS})
            for i in range(chunk.n_windows)]


def test_evict_readmit_within_one_chunk_bit_matches_stepwise():
    """Regression for the carried below-mask: a flow evicted mid-chunk
    (idle past evict_age) and re-admitted by a *later window of the same
    chunk* must read out as a fresh one-packet flow, bit-identical to the
    per-window path — a scan carry that kept any stale register (or the
    below-threshold mask) across the eviction would diverge here."""
    # w0: flow in bucket 3; w1: unrelated bucket ages it out (idle 10 >
    # evict_age 2); w2: bucket 3 re-admitted; w3: it accumulates again
    entries = {0: (3, 0.0, 100.0), 1: (5, 10.0, 50.0),
               2: (3, 10.5, 70.0), 3: (3, 10.6, 30.0)}
    chunk = _lane_chunk(entries, k=4)
    s_ref = init_flow_table(16)
    xs_ref, ev_ref = [], 0
    for w in _windows_of(chunk):
        s_ref, x, ev, _ = window_update_readout(s_ref, w, evict_age=2.0,
                                                use_pallas=False)
        xs_ref.append(np.asarray(x))
        ev_ref += int(ev)
    assert ev_ref == 1                        # the mid-chunk eviction fired
    s = init_flow_table(16)
    s, xs, ev, _ = chunk_update_readout(s, chunk, evict_age=2.0,
                                        use_pallas=False)
    assert int(ev) == 1
    for i, x_ref in enumerate(xs_ref):
        np.testing.assert_array_equal(np.asarray(xs)[i], x_ref,
                                      err_msg=f"window {i}")
    # the re-admitted readout is a *fresh* flow: 1 packet, 70 bytes
    assert float(np.asarray(xs)[2, 0, 0]) == 1.0
    assert float(np.asarray(xs)[2, 0, 1]) == 70.0
    # and the final registers agree with the stepwise path bitwise
    for f in REGISTER_FIELDS:
        np.testing.assert_array_equal(np.asarray(getattr(s, f)),
                                      np.asarray(getattr(s_ref, f)))


def test_saturate_across_chunk_boundary_counts_once():
    """A register crossing the 2^24 envelope exactly at a chunk boundary:
    chunk 1 leaves it just below, chunk 2's first window crosses — the
    overflow must count once, and identically to the stepwise path."""
    below = OVERFLOW_LIMIT - 512.0
    c1 = _lane_chunk({0: (3, 0.0, below), 1: (3, 0.1, 256.0)}, k=2)
    c2 = _lane_chunk({0: (3, 0.2, 1024.0), 1: (3, 0.3, 64.0)}, k=2)
    s = init_flow_table(16)
    ov = 0
    for c in (c1, c2):
        s, _, _, o = chunk_update_readout(s, c, saturate=True,
                                          use_pallas=False)
        ov += int(o)
    s_ref = init_flow_table(16)
    ov_ref = 0
    for c in (c1, c2):
        for w in _windows_of(c):
            s_ref, _, _, o = window_update_readout(s_ref, w, saturate=True,
                                                   use_pallas=False)
            ov_ref += int(o)
    # byte_count and fwd_bytes clamp together, once, at the crossing
    assert ov == ov_ref == 2
    assert float(s.byte_count[3]) == OVERFLOW_LIMIT
    for f in REGISTER_FIELDS:
        np.testing.assert_array_equal(np.asarray(getattr(s, f)),
                                      np.asarray(getattr(s_ref, f)))


def test_serving_evict_readmit_same_chunk_bit_matches(chunk_setup):
    """Serving-level version of the regression: an aggressive evict_age
    forces evictions inside nearly every chunk (including re-admissions
    later in the same chunk); the chunked server must still bit-match the
    per-window server end to end, and the accounting invariant holds."""
    trace, art, backend = chunk_setup
    kw = dict(n_buckets=N_BUCKETS, window=128, threshold=0.9, capacity=32,
              evict_age=0.25, saturate=True)
    ref = StreamingHybridServer(art, backend, **kw)
    p_ref, s_ref = ref.serve_trace(trace)
    assert s_ref.n_evicted > s_ref.n_windows  # evictions in most windows
    srv = StreamingHybridServer(art, backend, chunk_windows=4, **kw)
    p, s = srv.serve_trace(trace)             # serve_trace runs check()
    np.testing.assert_array_equal(np.asarray(p), np.asarray(p_ref))
    assert s.n_evicted == s_ref.n_evicted
    np.testing.assert_array_equal(np.asarray(srv.flow_table()),
                                  np.asarray(ref.flow_table()))
