"""Hybrid tier + serving engine integration tests."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.hybrid import hybrid_predict, hybrid_serve
from repro.core.inference import table_predict
from repro.core.mapping import map_tree_ensemble
from repro.ml.metrics import accuracy
from repro.ml.trees import fit_random_forest, predict_tree_ensemble
from repro.serving.hybrid_serving import HybridServer


@pytest.fixture(scope="module")
def hybrid_setup(request):
    from repro.data.unsw_like import make_unsw_like, train_test_split
    x, y = make_unsw_like(6000, seed=0, n_features=5)
    xtr, ytr, xte, yte = train_test_split(x, y)
    small = fit_random_forest(xtr, ytr, n_classes=2, n_trees=6, max_depth=4,
                              seed=0)
    big = fit_random_forest(xtr, ytr, n_classes=2, n_trees=30, max_depth=6,
                            seed=1, max_features=5)
    art = map_tree_ensemble(small, 5)
    return art, small, big, xte, yte


def test_hybrid_improves_over_switch_alone(hybrid_setup):
    art, small, big, xte, yte = hybrid_setup
    sw_pred, _ = table_predict(art, xte)
    res = hybrid_predict(art, lambda x: predict_tree_ensemble(big, x),
                         xte, threshold=0.9)
    assert accuracy(yte, res.pred) >= accuracy(yte, sw_pred)


def test_threshold_monotone_fraction(hybrid_setup):
    """Higher tau -> less traffic handled at the switch (Fig 10 trend)."""
    art, _, big, xte, yte = hybrid_setup
    fracs = []
    for tau in (0.5, 0.7, 0.9, 0.99):
        res = hybrid_predict(art, lambda x: predict_tree_ensemble(big, x),
                             xte, threshold=tau)
        fracs.append(float(res.fraction_handled))
    assert all(fracs[i] >= fracs[i + 1] for i in range(len(fracs) - 1))


def test_hybrid_serve_capacity_bound(hybrid_setup):
    art, _, big, xte, yte = hybrid_setup
    seen = []

    def backend(rows):
        seen.append(rows.shape)
        return predict_tree_ensemble(big, rows)

    pred, frac_fwd = hybrid_serve(art, backend, xte[:1024],
                                  threshold=0.95, capacity=128)
    assert seen == [(128, 5)]          # backend saw exactly capacity rows
    assert pred.shape == (1024,)


def test_hybrid_server_update_tables(hybrid_setup):
    art, small, big, xte, yte = hybrid_setup
    srv = HybridServer(art, lambda r: predict_tree_ensemble(big, r),
                       threshold=0.7, capacity=256)
    p1, _ = srv.classify(xte[:512])
    # retrain under same constraints -> same shapes -> hot swap
    from repro.data.unsw_like import make_unsw_like
    x2, y2 = make_unsw_like(3000, seed=9, n_features=5)
    small2 = fit_random_forest(x2, y2, n_classes=2, n_trees=6, max_depth=4,
                               seed=0)
    art2 = map_tree_ensemble(small2, 5)
    if all(jax.tree.leaves(jax.tree.map(lambda a, b: a.shape == b.shape,
                                        art, art2))):
        srv.update_tables(art2)
        p2, _ = srv.classify(xte[:512])
        assert p2.shape == p1.shape


def test_padded_rows_never_perturb_telemetry(hybrid_setup):
    """Ragged batches run through kernel tile padding (replicated last row,
    never zero rows) and must report exactly the telemetry of the logical
    rows — Figs 10-11 quantities can't drift with batch alignment."""
    art, small, big, xte, yte = hybrid_setup
    tau, cap = 0.9, 64
    srv = HybridServer(art, lambda r: predict_tree_ensemble(big, r),
                       threshold=tau, capacity=cap, use_pallas=True)
    for n in (130, 256, 301):                   # ragged and aligned
        pred, stats = srv.classify(xte[:n])
        _, conf = table_predict(art, xte[:n])
        fwd = np.asarray(conf) < tau
        assert pred.shape == (n,)
        assert stats.fraction_handled == pytest.approx(1.0 - fwd.mean())
        assert stats.backend_rows == min(int(fwd.sum()), cap)


def test_classify_stats_are_lazy_device_arrays(hybrid_setup):
    """classify() returns without host syncs: telemetry stays on device
    until a statistic is actually read."""
    art, small, big, xte, yte = hybrid_setup
    srv = HybridServer(art, lambda r: predict_tree_ensemble(big, r),
                       threshold=0.7, capacity=128)
    pred, stats = srv.classify(xte[:256])
    frac, rows = stats.as_arrays()
    assert isinstance(frac, jax.Array) and isinstance(rows, jax.Array)
    assert isinstance(stats.fraction_handled, float)
    assert isinstance(stats.backend_rows, int)
    assert 0.0 <= stats.fraction_handled <= 1.0


def test_hybrid_server_untraceable_backend_falls_back(hybrid_setup):
    """A numpy-only backend can't fuse into the jitted step; the server
    must detect that on first classify and serve via the two-phase path."""
    art, small, big, xte, yte = hybrid_setup

    def np_backend(rows):
        return np.zeros(np.asarray(rows).shape[0], np.int32)

    srv = HybridServer(art, np_backend, threshold=2.0, capacity=32)
    pred, stats = srv.classify(xte[:100])
    assert srv._fused_ok is False
    assert pred.shape == (100,)
    assert stats.backend_rows == 32             # tau=2.0 forwards everything


def test_greedy_generate_deterministic():
    from repro.configs import get_smoke_config
    from repro.models import model as M
    from repro.serving.engine import greedy_generate
    cfg = get_smoke_config("yi-6b")
    params = M.init_model(cfg, jax.random.PRNGKey(0))
    batch = {"tokens": jnp.asarray([[1, 2, 3, 4, 5, 6, 7, 8]], jnp.int32)}
    o1 = greedy_generate(cfg, params, batch, n_new=6)
    o2 = greedy_generate(cfg, params, batch, n_new=6)
    np.testing.assert_array_equal(np.asarray(o1), np.asarray(o2))


def test_generate_matches_rerun_prefill():
    """Token t generated with caches == argmax of prefill(prompt+prefix)."""
    from repro.configs import get_smoke_config
    from repro.models import model as M
    from repro.serving.engine import greedy_generate
    cfg = get_smoke_config("h2o-danube-1.8b")
    params = M.init_model(cfg, jax.random.PRNGKey(0))
    prompt = jnp.asarray([[3, 1, 4, 1, 5, 9, 2, 6]], jnp.int32)
    out = greedy_generate(cfg, params, {"tokens": prompt}, n_new=3,
                          cache_dtype=jnp.float32)
    # recompute token 2 by prefilling prompt + out[:, :2]
    full = jnp.concatenate([prompt, out[:, :2]], axis=1)
    logits, _ = M.prefill(params, cfg, {"tokens": full})
    expect = jnp.argmax(logits, axis=-1)
    np.testing.assert_array_equal(np.asarray(out[:, 2]), np.asarray(expect))
