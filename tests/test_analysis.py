"""repro.analysis: rule registry, AST lint, resource fit, and the
seeded-violation contract (every rule must fire on its fixture).

The heavyweight jaxpr hot-path audits (server construction + tracing)
run once through the CLI entry in ``test_strict_gate_passes_clean_tree``
— the same invocation CI gates on — rather than per-rule, to keep tier-1
wall time bounded.
"""

import json
import subprocess
import sys

import pytest

from repro.analysis import lint
from repro.analysis.registry import (RULES, Finding, Rule, iter_rules,
                                     register, run_rules)
from repro.core.resources import (DEFAULT_PROFILE, NIC_LIKE, PROFILES,
                                  DeviceProfile, FitError, check_fit)


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

def test_registry_rejects_duplicates_and_bad_sections():
    r = Rule(name="t-dup", section="lint", doc="",
             check=lambda: [], selftest=lambda: [Finding("t-dup", "x")])
    register(r)
    try:
        with pytest.raises(ValueError, match="duplicate"):
            register(r)
    finally:
        RULES.pop("t-dup")
    with pytest.raises(ValueError, match="unknown section"):
        Rule(name="t-bad", section="nope", doc="",
             check=lambda: [], selftest=lambda: [])


def test_run_rules_isolates_rule_crashes():
    """A crashing rule is reported, not propagated — one broken auditor
    cannot mask the others' findings."""

    def boom():
        raise RuntimeError("auditor exploded")

    register(Rule(name="t-crash", section="lint", doc="",
                  check=boom, selftest=lambda: []))
    register(Rule(name="t-fine", section="lint", doc="",
                  check=lambda: [], selftest=lambda: [Finding("t-fine", "f")]))
    try:
        report = run_rules(sections=("lint",))
        by_name = {r.rule: r for r in report.results}
        assert "auditor exploded" in by_name["t-crash"].error
        assert not by_name["t-crash"].ok
        assert by_name["t-fine"].ok
        assert not report.ok
    finally:
        RULES.pop("t-crash")
        RULES.pop("t-fine")


def test_silent_selftest_fails_the_report():
    """A rule whose seeded violation does NOT fire is a no-op and must
    fail the report — the anti-rot contract."""
    register(Rule(name="t-noop", section="lint", doc="",
                  check=lambda: [], selftest=lambda: []))
    try:
        report = run_rules(sections=("lint",))
        res = {r.rule: r for r in report.results}["t-noop"]
        assert res.selftest_fired is False
        assert not res.ok and not report.ok
    finally:
        RULES.pop("t-noop")


# ---------------------------------------------------------------------------
# AST lint rules — seeded violations must fire, idiomatic code must not
# ---------------------------------------------------------------------------

def _rules_fired(source):
    return {f.rule for f in lint.lint_source("fixture.py", source)}


def test_lint_host_sync_fires_on_seeded_violations():
    fired = lint.lint_source("fixture.py", lint._FIXTURE_HOST_SYNC)
    msgs = [f.message for f in fired
            if f.rule == "lint-host-sync-in-jit"]
    assert len(msgs) == 3                      # float(), np.asarray, .item()
    assert any("float" in m for m in msgs)
    assert any("asarray" in m for m in msgs)
    assert any(".item()" in m for m in msgs)


def test_lint_host_sync_spares_unjitted_and_decorated_forms():
    # same idioms outside any jitted function: clean
    assert not _rules_fired("""
import numpy as np
def host_side(x):
    return float(np.asarray(x).sum())
""")
    # @jax.jit decorator form is recognized
    assert "lint-host-sync-in-jit" in _rules_fired("""
import jax
@jax.jit
def step(state):
    return state.sum().item()
""")
    # functools.partial wrapping is unwrapped
    assert "lint-host-sync-in-jit" in _rules_fired("""
import functools, jax
def step(state, k):
    return float(state.sum()) + k
step_j = jax.jit(functools.partial(step, k=2))
""")


def test_lint_broad_except_fires_and_respects_waivers():
    assert "lint-broad-except" in _rules_fired(lint._FIXTURE_BROAD_EXCEPT)
    for waiver in ("noqa: BLE001", "lint: allow-broad-except"):
        assert not _rules_fired(f"""
def risky():
    try:
        return 1
    except Exception:  # {waiver} — telemetry never raises
        return 0
""")
    # waiver on the previous line also counts (long messages wrap)
    assert not _rules_fired("""
def risky():
    try:
        return 1
    # noqa: BLE001 — fault boundary, everything must degrade
    except Exception:
        return 0
""")
    # narrow excepts never fire
    assert not _rules_fired("""
def risky():
    try:
        return 1
    except (ValueError, KeyError):
        return 0
""")


def test_lint_env_mutation_fires_outside_launch_only():
    assert "lint-env-mutation" in _rules_fired(lint._FIXTURE_ENV)
    # launch/ entrypoints are exempt (they must set flags pre-jax-init)
    assert not lint.lint_source("src/repro/launch/fixture.py",
                                lint._FIXTURE_ENV)
    # explicit waiver is honored anywhere
    assert not _rules_fired("""
import os
# lint: allow-env-mutation — test shim
os.environ["X"] = "1"
""")
    # function-scoped mutation is runtime behavior, not import time
    assert not _rules_fired("""
import os
def configure():
    os.environ["X"] = "1"
""")


def test_lint_missing_donate_fires_and_spares_compliant_jits():
    assert "lint-missing-donate" in _rules_fired(
        lint._FIXTURE_MISSING_DONATE)
    assert not _rules_fired("""
import jax
def step(art, flow_state, stats, w):
    return flow_state, stats
step_j = jax.jit(step, donate_argnums=(1, 2))
""")
    # shard_map has no donate kwarg: out of scope for this rule
    assert not _rules_fired("""
from jax.experimental.shard_map import shard_map
def step(state, w):
    return state
step_s = shard_map(step, mesh=None, in_specs=None, out_specs=None)
""")


def test_lint_clean_on_the_real_tree():
    """The shipped src/ tree is lint-clean — the same invariant the CI
    gate enforces, asserted here so a violation fails tier-1 too."""
    findings = lint.lint_paths()
    assert not findings, "\n".join(f.format() for f in findings)


# ---------------------------------------------------------------------------
# resource fit
# ---------------------------------------------------------------------------

def test_standard_artifacts_fit_default_profile():
    from repro.analysis.fit import standard_artifacts
    for name, art in standard_artifacts():
        rep = check_fit(art, DEFAULT_PROFILE)
        assert rep.fits, f"{name}: {rep.violations}"
        assert all(0.0 <= u for u in rep.utilization.values())


def test_check_fit_rejects_oversized_artifact():
    from repro.analysis.fit import oversized_report
    for profile in PROFILES.values():
        rep = check_fit(oversized_report(), profile)
        assert not rep.fits
        assert any("entries" in v for v in rep.violations)
    with pytest.raises(FitError, match="does not fit"):
        check_fit(oversized_report(), DEFAULT_PROFILE, strict=True)


def test_finalize_artifact_profile_guard():
    import dataclasses

    from repro.analysis.fit import standard_artifacts
    from repro.core.artifact import finalize_artifact
    from repro.core.resources import artifact_resources
    art = dict(standard_artifacts())["xgb"]
    raw = dataclasses.replace(art, ftable_flat=None, dtable_flat=None,
                              dtable_pad=None)
    entries = artifact_resources(art).entries
    tight = DeviceProfile(name="tight", stages=12, sram_kib=1 << 20,
                          tcam_kib=1 << 20, max_entries=entries // 2,
                          max_tables=1 << 10)
    with pytest.raises(FitError, match="entries"):
        finalize_artifact(raw, profile=tight)
    out = finalize_artifact(raw, profile=DEFAULT_PROFILE)  # fits: finalizes
    assert out.ftable_flat is not None


def test_fit_rows_cover_every_artifact_profile_pair():
    from repro.analysis.fit import fit_rows, standard_artifacts
    rows = fit_rows()
    assert len(rows) == len(standard_artifacts()) * len(PROFILES)
    for row in rows:
        assert set(row) >= {"artifact", "profile", "fits", "util_entries",
                            "util_sram_kib", "util_tcam_kib", "util_tables",
                            "util_stages"}
    assert NIC_LIKE.name in {r["profile"] for r in rows}


def test_resource_report_split_is_consistent():
    """tcam+sram must equal total bits for the tree family (feature
    tables are the TCAM side, decision tables the SRAM side)."""
    from repro.analysis.fit import standard_artifacts
    from repro.core.resources import artifact_resources
    for name, art in standard_artifacts():
        res = artifact_resources(art)
        assert res.tcam_bits + res.sram_bits == res.bits, name


# ---------------------------------------------------------------------------
# the CLI gate itself
# ---------------------------------------------------------------------------

def test_strict_gate_passes_clean_tree():
    """``python -m repro.analysis --strict --json`` exits 0 on the
    shipped tree with every self-test fired — the exact CI invocation.
    Runs the hot-path auditor end to end (server builds + traces)."""
    proc = subprocess.run(
        [sys.executable, "-m", "repro.analysis", "--strict", "--json"],
        capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    report = json.loads(proc.stdout)
    assert report["ok"] and report["n_findings"] == 0
    by_name = {r["rule"]: r for r in report["results"]}
    for rule in ("hotpath-donation", "hotpath-zero-sync", "hotpath-dtype",
                 "hotpath-collectives", "lint-host-sync-in-jit",
                 "lint-broad-except", "lint-env-mutation",
                 "lint-missing-donate", "fit-standard-artifacts"):
        assert rule in by_name, f"rule {rule} not registered"
        assert by_name[rule]["selftest_fired"] is True, rule


def test_cli_section_filter_and_nonstrict_lint():
    """The lint section alone runs fast in-process and exits 0."""
    from repro.analysis.cli import main
    assert main(["--section", "lint", "--json"]) == 0


def test_jaxpr_utils_alias_parse_and_census():
    """Unit-level checks of the auditor's parsing machinery on toy
    programs (the self-tests cover the negative direction)."""
    import jax
    import jax.numpy as jnp

    from repro.analysis import jaxpr_utils as JU

    def good_step(state, w):
        return state * 2.0 + w, jnp.sum(w)
    jitted = jax.jit(good_step, donate_argnums=(0,))
    args = (jnp.zeros((8, 8), jnp.float32), jnp.ones((8, 8), jnp.float32))
    text = JU.compiled_text(jitted, *args)
    assert JU.donation_alias_count(text) == 1
    assert JU.count_donated_leaves(args, (0,)) == 1

    jaxpr = JU.closed_jaxpr(jitted, *args)
    assert JU.forbidden_primitives(jaxpr) == []
    assert JU.collective_census(jaxpr) == {}
    assert JU.jaxpr_dtypes(jaxpr) <= {"float32"}

    # scan sub-jaxprs are walked recursively
    def scanned(xs):
        return jax.lax.scan(lambda c, x: (c + x, c), jnp.float32(0), xs)
    names = JU.primitive_names(JU.closed_jaxpr(scanned,
                                               jnp.ones(4, jnp.float32)))
    assert "scan" in names and "add" in names
