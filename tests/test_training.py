"""Training runtime: optimizer, loop, checkpoint/restart/reshard,
gradient compression with error feedback, watchdog."""

import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import model as M
from repro.training import checkpoint as ckpt
from repro.training import grad_compress as gc
from repro.training.loop import TrainConfig, train
from repro.training.optim import (AdamWConfig, adamw_update,
                                  init_opt_state, lr_at)
from repro.training.watchdog import StepWatchdog


def test_lr_schedule_shape():
    cfg = AdamWConfig(lr_peak=1e-3, lr_min=1e-4, warmup_steps=10,
                      total_steps=100)
    lrs = [float(lr_at(cfg, jnp.int32(s))) for s in (0, 5, 10, 50, 100)]
    assert lrs[0] == 0.0
    assert abs(lrs[2] - 1e-3) < 1e-9          # peak at warmup end
    assert lrs[3] < lrs[2]                     # decaying
    assert abs(lrs[4] - 1e-4) < 1e-6           # floor


def test_adamw_decreases_quadratic():
    w = {"w": jnp.asarray([5.0, -3.0])}
    st = init_opt_state(w)
    cfg = AdamWConfig(lr_peak=0.2, warmup_steps=0, total_steps=100,
                      weight_decay=0.0)
    for _ in range(60):
        g = {"w": 2 * w["w"]}
        w, st, _ = adamw_update(cfg, w, g, st)
    assert float(jnp.abs(w["w"]).max()) < 0.5


def test_train_loss_decreases():
    cfg = get_smoke_config("h2o-danube-1.8b")
    tcfg = TrainConfig(steps=15, seq_len=32, global_batch=4,
                       opt=AdamWConfig(lr_peak=2e-3, warmup_steps=3,
                                       total_steps=15))
    _, hist = train(cfg, tcfg, verbose=False)
    assert hist[-1]["loss_total"] < hist[0]["loss_total"]


def test_checkpoint_restart_resumes():
    cfg = get_smoke_config("qwen3-4b")
    with tempfile.TemporaryDirectory() as d:
        tcfg = TrainConfig(steps=6, seq_len=16, global_batch=2,
                           ckpt_dir=d, ckpt_every=3, log_every=100)
        train(cfg, tcfg, verbose=False)
        assert ckpt.latest_step(d) == 6
        tcfg2 = TrainConfig(steps=8, seq_len=16, global_batch=2,
                            ckpt_dir=d, ckpt_every=3, log_every=100)
        _, hist = train(cfg, tcfg2, verbose=False)
        assert hist[0]["step"] == 6             # resumed, not restarted
        assert hist[-1]["step"] == 7


def test_checkpoint_atomic_and_gc():
    with tempfile.TemporaryDirectory() as d:
        tree = {"a": jnp.arange(10), "b": {"c": jnp.ones((3, 3))}}
        for step in (1, 2, 3, 4):
            ckpt.save_checkpoint(d, step, tree, keep=2)
        steps = sorted(x for x in os.listdir(d) if x.startswith("step_"))
        assert steps == ["step_3", "step_4"]
        restored, step = ckpt.restore_checkpoint(d, tree)
        assert step == 4
        np.testing.assert_array_equal(np.asarray(restored["a"]),
                                      np.arange(10))


def test_checkpoint_restore_with_sharding_tree():
    """Elastic path: restore onto explicit (single-device) shardings."""
    with tempfile.TemporaryDirectory() as d:
        tree = {"w": jnp.arange(16.0).reshape(4, 4)}
        ckpt.save_checkpoint(d, 1, tree)
        sh = jax.tree.map(
            lambda _: jax.sharding.SingleDeviceSharding(jax.devices()[0]),
            tree)
        restored, _ = ckpt.restore_checkpoint(d, tree, shardings=sh)
        np.testing.assert_array_equal(np.asarray(restored["w"]),
                                      np.asarray(tree["w"]))


def test_async_checkpointer():
    with tempfile.TemporaryDirectory() as d:
        w = ckpt.AsyncCheckpointer(d)
        w.save(5, {"x": jnp.ones(4)})
        w.wait()
        assert ckpt.latest_step(d) == 5


@pytest.mark.parametrize("scheme", ["topk", "int8"])
def test_compression_error_feedback_conserves(scheme):
    """sent + residual == grad + old_residual (nothing is lost)."""
    rng = np.random.default_rng(0)
    g = {"w": jnp.asarray(rng.normal(0, 1, (64,)).astype(np.float32))}
    err = gc.init_error_state(g)
    fn = gc.topk_compress if scheme == "topk" else gc.int8_compress
    sent, new_err = fn(g, err)
    np.testing.assert_allclose(
        np.asarray(sent["w"] + new_err["w"]),
        np.asarray(g["w"] + err["w"]), rtol=1e-5, atol=1e-6)


def test_topk_sparsity():
    g = {"w": jnp.arange(100.0)}
    err = gc.init_error_state(g)
    sent, _ = gc.topk_compress(g, err, frac=0.1)
    assert int(jnp.sum(sent["w"] != 0)) == 10
    # kept the largest
    assert float(sent["w"][99]) == 99.0


def test_train_with_compression_converges():
    cfg = get_smoke_config("yi-6b")
    tcfg = TrainConfig(steps=12, seq_len=16, global_batch=2,
                       grad_compress="int8",
                       opt=AdamWConfig(lr_peak=2e-3, warmup_steps=2,
                                       total_steps=12))
    _, hist = train(cfg, tcfg, verbose=False)
    assert hist[-1]["loss_total"] < hist[0]["loss_total"]


def test_compressed_bytes_accounting():
    params = {"w": jnp.zeros((1000,))}
    full = gc.compressed_bytes(params, "none")
    int8 = gc.compressed_bytes(params, "int8")
    topk = gc.compressed_bytes(params, "topk", frac=0.05)
    assert full == 4000
    assert int8 < full / 3
    assert topk < full / 2


def test_watchdog_straggler_detection():
    import time
    wd = StepWatchdog(window=16, slow_factor=2.0, hang_timeout_s=999)
    for s in range(10):
        wd.step_start(s)
        time.sleep(0.002)
        wd.step_end(s)
    wd.step_start(10)
    time.sleep(0.05)
    stat = wd.step_end(10)
    assert stat["straggler"]
    assert wd.events and wd.events[-1]["kind"] == "straggler"
