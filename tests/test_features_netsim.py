"""§5 feature extraction: packet/flow/aggregate/file granularity."""

import jax.numpy as jnp
import numpy as np

from repro.netsim.features import (aggregate_features, encode_csv_payload,
                                   file_features_csv, flow_features,
                                   fnv1a_hash, packet_features,
                                   stitch_split_payload)
from repro.netsim.packets import synth_trace


def test_packet_features_shapes():
    tr = synth_trace(n_flows=200, seed=0)
    f = packet_features(tr)
    assert f.shape == (tr.n_packets, 6)
    assert bool(jnp.all(jnp.isfinite(f)))


def test_fnv_hash_deterministic_and_spread():
    a = jnp.arange(1000, dtype=jnp.uint32)
    h1 = fnv1a_hash(a, a * 3 + 1, n_buckets=256)
    h2 = fnv1a_hash(a, a * 3 + 1, n_buckets=256)
    np.testing.assert_array_equal(np.asarray(h1), np.asarray(h2))
    # reasonable spread: >=60% of buckets hit with 1000 keys
    assert len(np.unique(np.asarray(h1))) > 150


def test_flow_features_counts_match_ground_truth():
    tr = synth_trace(n_flows=50, seed=1)
    b, table = flow_features(tr, n_buckets=1 << 16)   # big => few collisions
    # pick a flow, compare packet count
    fid = 7
    mask = tr.flow_id == fid
    bucket = int(np.asarray(b)[mask][0])
    cnt = float(np.asarray(table)[bucket, 0])
    # collisions can only merge flows -> count >= ground truth
    assert cnt >= mask.sum()
    dur = float(np.asarray(table)[bucket, 2])
    assert dur >= 0


def test_flow_features_epoch_scale_timestamps():
    """Epoch-offset traces (ts ~ 1.7e9 s) must yield the same durations as
    trace-relative ones: f32 resolution at epoch scale is ~256 s, so the
    rebase has to happen in float64 *before* the cast (regression)."""
    tr = synth_trace(n_flows=150, seed=4)
    _, base = flow_features(tr, n_buckets=2048)
    tr.ts = tr.ts + 1.7e9
    _, offset = flow_features(tr, n_buckets=2048)
    base, offset = np.asarray(base), np.asarray(offset)
    # duration / mean-IAT columns survive the epoch offset
    np.testing.assert_allclose(offset[:, 2], base[:, 2], rtol=0, atol=1e-4)
    np.testing.assert_allclose(offset[:, 3], base[:, 3], rtol=0, atol=1e-4)
    assert (base[:, 2] > 0).any() and (offset[:, 2] > 0).any()
    # count/byte columns are exact regardless
    np.testing.assert_array_equal(offset[:, [0, 1, 4, 5, 6, 7]],
                                  base[:, [0, 1, 4, 5, 6, 7]])


def test_aggregate_features_epoch_scale_rate():
    """aggregate_features rates likewise rebase before the f32 cast."""
    tr = synth_trace(n_flows=150, seed=4)
    _, base = aggregate_features(tr, key="dport", n_buckets=1024)
    tr.ts = tr.ts + 1.7e9
    _, offset = aggregate_features(tr, key="dport", n_buckets=1024)
    np.testing.assert_allclose(np.asarray(offset)[:, 2],
                               np.asarray(base)[:, 2], rtol=1e-3, atol=1e-3)


def test_aggregate_features_group_sums():
    tr = synth_trace(n_flows=100, seed=2)
    g, agg = aggregate_features(tr, key="dport", n_buckets=1024)
    total_pkts = float(np.asarray(agg)[:, 0].sum())
    assert total_pkts == tr.n_packets


def test_csv_parse_roundtrip():
    vals = np.asarray([[1.25, -3.5, 42.0, 0.001],
                       [-123.4, 7.0, 0.25, 999.9]], np.float32)
    payload = encode_csv_payload(vals, width=8)
    out = file_features_csv(jnp.asarray(payload), [0, 1, 2, 3], width=8)
    np.testing.assert_allclose(np.asarray(out), vals, rtol=2e-3, atol=2e-3)


def test_csv_encode_wide_values_roundtrip():
    """Values wider than the field drop fractional digits instead of being
    right-truncated to a different number (regression: "12345.678" cut to
    "12345.67"; correct is "12345.68"). Round-trip through the switch
    parser stays within the precision of the retained digits."""
    vals = np.asarray([[12345.678, -9999.995, 1234567.0, 0.125],
                       [-123456.7, 99999.99, -1.0, 8888.888]], np.float32)
    payload = encode_csv_payload(vals, width=8)
    out = np.asarray(file_features_csv(jnp.asarray(payload),
                                       [0, 1, 2, 3], width=8))
    np.testing.assert_allclose(out, vals, rtol=1e-3)
    # the headline case keeps rounded (not truncated) digits
    field0 = payload[0, :8].tobytes().decode("ascii")
    assert field0.strip() == "12345.68"


def test_csv_encode_overflow_raises():
    """A value whose integer part alone exceeds the field is an error,
    never a silently different number."""
    with np.testing.assert_raises(ValueError):
        encode_csv_payload(np.asarray([[123456789.0]], np.float32), width=8)


def test_split_payload_stitch():
    """A field split across two packets parses after stitching (§5.3)."""
    vals = np.asarray([[12.5, -42.25]], np.float32)
    payload = encode_csv_payload(vals, width=8)      # (1, 16) bytes
    first, second = payload[:, :11], payload[:, 11:]
    whole = stitch_split_payload(jnp.asarray(first), jnp.asarray(second))
    out = file_features_csv(whole, [0, 1], width=8)
    np.testing.assert_allclose(np.asarray(out), vals, rtol=2e-3, atol=2e-3)


def test_flow_features_to_classifier_end_to_end():
    """Extracted flow features feed the switch classifier (the full §5->§4
    pipeline): per-flow features -> table model -> predictions."""
    from repro.core.inference import table_predict
    from repro.core.mapping import map_tree_ensemble
    from repro.ml.trees import fit_random_forest

    tr = synth_trace(n_flows=800, seed=3)
    b, table = flow_features(tr, n_buckets=1 << 14)
    # per-flow rows: take each flow's bucket row
    first_idx = np.unique(np.asarray(tr.flow_id), return_index=True)[1]
    rows = np.asarray(table)[np.asarray(b)[first_idx]]
    labels = tr.flow_label
    rf = fit_random_forest(rows.astype(np.float32), labels, n_classes=2,
                           n_trees=4, max_depth=3, seed=0)
    art = map_tree_ensemble(rf, rows.shape[1])
    pred, conf = table_predict(art, rows.astype(np.float32))
    assert pred.shape == (len(labels),)
    assert float(jnp.mean((pred == jnp.asarray(labels)).astype(
        jnp.float32))) > 0.6
