"""§5 feature extraction: packet/flow/aggregate/file granularity."""

import jax.numpy as jnp
import numpy as np

from repro.netsim.features import (aggregate_features, encode_csv_payload,
                                   file_features_csv, flow_features,
                                   fnv1a_hash, packet_features,
                                   stitch_split_payload)
from repro.netsim.packets import synth_trace


def test_packet_features_shapes():
    tr = synth_trace(n_flows=200, seed=0)
    f = packet_features(tr)
    assert f.shape == (tr.n_packets, 6)
    assert bool(jnp.all(jnp.isfinite(f)))


def test_fnv_hash_deterministic_and_spread():
    a = jnp.arange(1000, dtype=jnp.uint32)
    h1 = fnv1a_hash(a, a * 3 + 1, n_buckets=256)
    h2 = fnv1a_hash(a, a * 3 + 1, n_buckets=256)
    np.testing.assert_array_equal(np.asarray(h1), np.asarray(h2))
    # reasonable spread: >=60% of buckets hit with 1000 keys
    assert len(np.unique(np.asarray(h1))) > 150


def test_flow_features_counts_match_ground_truth():
    tr = synth_trace(n_flows=50, seed=1)
    b, table = flow_features(tr, n_buckets=1 << 16)   # big => few collisions
    # pick a flow, compare packet count
    fid = 7
    mask = tr.flow_id == fid
    bucket = int(np.asarray(b)[mask][0])
    cnt = float(np.asarray(table)[bucket, 0])
    # collisions can only merge flows -> count >= ground truth
    assert cnt >= mask.sum()
    dur = float(np.asarray(table)[bucket, 2])
    assert dur >= 0


def test_aggregate_features_group_sums():
    tr = synth_trace(n_flows=100, seed=2)
    g, agg = aggregate_features(tr, key="dport", n_buckets=1024)
    total_pkts = float(np.asarray(agg)[:, 0].sum())
    assert total_pkts == tr.n_packets


def test_csv_parse_roundtrip():
    vals = np.asarray([[1.25, -3.5, 42.0, 0.001],
                       [-123.4, 7.0, 0.25, 999.9]], np.float32)
    payload = encode_csv_payload(vals, width=8)
    out = file_features_csv(jnp.asarray(payload), [0, 1, 2, 3], width=8)
    np.testing.assert_allclose(np.asarray(out), vals, rtol=2e-3, atol=2e-3)


def test_split_payload_stitch():
    """A field split across two packets parses after stitching (§5.3)."""
    vals = np.asarray([[12.5, -42.25]], np.float32)
    payload = encode_csv_payload(vals, width=8)      # (1, 16) bytes
    first, second = payload[:, :11], payload[:, 11:]
    whole = stitch_split_payload(jnp.asarray(first), jnp.asarray(second))
    out = file_features_csv(whole, [0, 1], width=8)
    np.testing.assert_allclose(np.asarray(out), vals, rtol=2e-3, atol=2e-3)


def test_flow_features_to_classifier_end_to_end():
    """Extracted flow features feed the switch classifier (the full §5->§4
    pipeline): per-flow features -> table model -> predictions."""
    from repro.core.inference import table_predict
    from repro.core.mapping import map_tree_ensemble
    from repro.ml.trees import fit_random_forest

    tr = synth_trace(n_flows=800, seed=3)
    b, table = flow_features(tr, n_buckets=1 << 14)
    # per-flow rows: take each flow's bucket row
    first_idx = np.unique(np.asarray(tr.flow_id), return_index=True)[1]
    rows = np.asarray(table)[np.asarray(b)[first_idx]]
    labels = tr.flow_label
    rf = fit_random_forest(rows.astype(np.float32), labels, n_classes=2,
                           n_trees=4, max_depth=3, seed=0)
    art = map_tree_ensemble(rf, rows.shape[1])
    pred, conf = table_predict(art, rows.astype(np.float32))
    assert pred.shape == (len(labels),)
    assert float(jnp.mean((pred == jnp.asarray(labels)).astype(
        jnp.float32))) > 0.6
