"""Benchmark-suite tooling: fail-fast suite runner + bench-v1 validation.

Two CI-trust contracts:

* ``benchmarks/run.py --all-suites`` must exit nonzero the moment a
  sub-suite subprocess fails (propagating the child's code), so an
  oracle failure in any emitter can never leave CI green;
* every ``BENCH_*.json`` must satisfy the bench-v1 schema before it is
  uploaded into the perf trajectory — ``benchmarks.validate_schema``
  is the gate and must reject malformed payloads.
"""

import copy
import json

import pytest

from benchmarks.common import write_bench_json
from benchmarks.run import EXTRA_SUITES, run_suites
from benchmarks.validate_schema import (SchemaError, main as validate_main,
                                        validate_bench_json,
                                        validate_bench_payload)


# ---------------------------------------------------------------------------
# fail-fast suite runner
# ---------------------------------------------------------------------------

def test_run_suites_propagates_child_failure():
    """A failing suite subprocess must abort the run with a nonzero exit
    code — the child's own — not be swallowed into a summary."""
    with pytest.raises(SystemExit) as e:
        run_suites(("definitely_not_a_bench_module",))
    assert e.value.code not in (0, None)


def test_run_suites_failure_is_fail_fast(capfd):
    """The first failure stops the run: the suite after it never
    launches (its banner is never printed)."""
    with pytest.raises(SystemExit):
        run_suites(("definitely_not_a_bench_module", "also_never_reached"))
    out = capfd.readouterr()
    assert "benchmarks.definitely_not_a_bench_module" in out.out
    assert "also_never_reached" not in out.out


def test_run_suites_empty_returns_cleanly():
    assert run_suites(()) is None


def test_all_suites_list_covers_every_emitter():
    """The --all-suites chain names each standalone bench-v1 emitter,
    including the cross-window batching, adversarial-scenario,
    ingest-latency, observability and resource-fit benches."""
    assert set(EXTRA_SUITES) == {"kernel_microbench", "stream_bench",
                                 "shard_stream_bench", "batch_bench",
                                 "scenario_bench", "latency_bench",
                                 "obs_bench", "analysis_bench"}


# ---------------------------------------------------------------------------
# bench-v1 schema validation
# ---------------------------------------------------------------------------

@pytest.fixture()
def valid_bench(tmp_path, monkeypatch):
    """A real emitter-written file (write_bench_json is the single writer
    every suite goes through, so validating its output validates them)."""
    monkeypatch.chdir(tmp_path)
    path = write_bench_json(
        "BENCH_t.json", "batch",
        [{"name": "batch_serving", "paper_ref": "§2.2.1", "ok": True,
          "wall_s": 0.1, "rows": [{"flush_every": 4, "pkts_per_s": 1.0}]}],
        config={"flush_every": [1, 4]})
    return tmp_path / path


def test_validator_accepts_emitter_output(valid_bench):
    payload = validate_bench_json(str(valid_bench))
    assert payload["suite"] == "batch"


def test_validator_accepts_checked_in_trajectory(pytestconfig):
    """Every BENCH_*.json currently in the repo root is schema-valid."""
    root = pytestconfig.rootpath
    files = sorted(root.glob("BENCH_*.json"))
    assert files, "no BENCH_*.json checked in next to the tests"
    for f in files:
        validate_bench_json(str(f))


@pytest.mark.parametrize("mutate", [
    lambda p: p.pop("schema"),
    lambda p: p.update(schema="bench-v2"),
    lambda p: p.pop("benches"),
    lambda p: p.update(benches=[]),
    lambda p: p.update(benches=[{"name": "x"}]),          # missing keys
    lambda p: p["benches"][0].update(ok="yes"),           # wrong type
    lambda p: p["benches"][0].update(wall_s="fast"),      # wrong type
    lambda p: p.update(config=None),
])
def test_validator_rejects_malformed_payloads(valid_bench, mutate):
    payload = json.loads(valid_bench.read_text())
    mutate(payload)
    with pytest.raises(SchemaError):
        validate_bench_payload(copy.deepcopy(payload), "mutated")


def test_validator_cli_exits_nonzero_on_malformed_file(valid_bench,
                                                       tmp_path):
    bad = tmp_path / "BENCH_bad.json"
    payload = json.loads(valid_bench.read_text())
    payload["benches"][0].pop("wall_s")
    bad.write_text(json.dumps(payload))
    validate_main([str(valid_bench)])             # good file: returns
    with pytest.raises(SystemExit) as e:
        validate_main([str(bad)])
    assert e.value.code not in (0, None)
    with pytest.raises(SystemExit):               # not-JSON is also caught
        bad.write_text("{not json")
        validate_main([str(bad)])


def test_validator_cli_requires_files(tmp_path, monkeypatch):
    monkeypatch.chdir(tmp_path)                   # no BENCH_*.json here
    with pytest.raises(SystemExit) as e:
        validate_main([])
    assert e.value.code not in (0, None)


def test_validator_rejects_unknown_suite(valid_bench):
    """A typo'd / unregistered suite tag must fail with a message that
    names the offender and the registry to fix."""
    payload = json.loads(valid_bench.read_text())
    payload["suite"] = "mystery_suite"
    with pytest.raises(SchemaError, match="unknown suite 'mystery_suite'"):
        validate_bench_payload(payload, "mutated")


def test_validator_cli_messages_are_pointed(valid_bench, tmp_path):
    """The CLI exit message must say *what* is malformed and *where* —
    a bare nonzero exit would send the operator spelunking."""
    cases = [
        (lambda p: p.pop("benches"), "missing top-level key 'benches'"),
        (lambda p: p["benches"][0].update(ok="yes"), "'ok' must be"),
        (lambda p: p.update(suite="mystery_suite"),
         "unknown suite 'mystery_suite'"),
    ]
    for i, (mutate, needle) in enumerate(cases):
        payload = json.loads(valid_bench.read_text())
        mutate(payload)
        bad = tmp_path / f"BENCH_bad{i}.json"
        bad.write_text(json.dumps(payload))
        with pytest.raises(SystemExit) as e:
            validate_main([str(bad)])
        assert e.value.code not in (0, None)
        assert needle in str(e.value.code)
        assert str(bad) in str(e.value.code)      # names the offending file


def _analysis_payload():
    return {
        "schema": "bench-v1", "suite": "analysis", "generated_unix": 0.0,
        "backend": "cpu", "config": {},
        "benches": [{"name": "device_fit", "paper_ref": "Tables 1-2",
                     "ok": True, "wall_s": 0.1,
                     "rows": [{"artifact": "rf", "profile": "tofino_like",
                               "fits": True, "util_stages": 0.25,
                               "util_sram_kib": 0.1, "util_tcam_kib": 0.1,
                               "util_entries": 0.1, "util_tables": 0.5},
                              {"artifact": "xgb", "profile": "tight_test",
                               "fits": False, "guard": "FitError"}]}],
    }


def test_validator_analysis_rows_require_utilization():
    validate_bench_payload(_analysis_payload(), "ok")  # guard row exempt
    for strip in ("artifact", "fits", "util_entries", "util_sram_kib"):
        payload = _analysis_payload()
        payload["benches"][0]["rows"][0].pop(strip)
        with pytest.raises(SchemaError, match=strip):
            validate_bench_payload(payload, "stripped")
    payload = _analysis_payload()
    payload["benches"][0]["rows"][0]["fits"] = "yes"      # wrong type
    with pytest.raises(SchemaError, match="fits"):
        validate_bench_payload(payload, "typed")


def _shard_payload():
    return {
        "schema": "bench-v1", "suite": "shard", "generated_unix": 0.0,
        "backend": "cpu", "config": {},
        "benches": [{"name": "shard_stream", "paper_ref": "§5",
                     "ok": True, "wall_s": 0.1,
                     "rows": [{"devices": 4, "d_shard": 2, "d_data": 2,
                               "classify_rows_per_device": 128,
                               "pkts_per_s": 1000.0},
                              {"note": "summary row, no device count"}]}],
    }


def test_validator_shard_rows_require_mesh_shape():
    validate_bench_payload(_shard_payload(), "ok")     # summary row exempt
    for strip in ("d_shard", "d_data", "classify_rows_per_device"):
        payload = _shard_payload()
        payload["benches"][0]["rows"][0].pop(strip)
        with pytest.raises(SchemaError, match=strip):
            validate_bench_payload(payload, "stripped")
    payload = _shard_payload()
    payload["benches"][0]["rows"][0]["classify_rows_per_device"] = 12.5
    with pytest.raises(SchemaError, match="classify_rows_per_device"):
        validate_bench_payload(payload, "typed")


def _latency_payload():
    return {
        "schema": "bench-v1", "suite": "latency", "generated_unix": 0.0,
        "backend": "cpu", "config": {},
        "benches": [{"name": "ingest_latency", "paper_ref": "§5",
                     "ok": True, "wall_s": 0.1,
                     "rows": [{"config": "prefetch_on", "prefetch": True,
                               "p50_ms": 1.0, "p95_ms": 2.0, "p99_ms": 3.0,
                               "bit_identical": True},
                              {"config": "autotune",
                               "chunk_windows": 16}]}],
    }


def test_validator_latency_rows_require_percentiles():
    validate_bench_payload(_latency_payload(), "ok")   # autotune row exempt
    for strip in ("p50_ms", "p95_ms", "p99_ms", "bit_identical"):
        payload = _latency_payload()
        payload["benches"][0]["rows"][0].pop(strip)
        with pytest.raises(SchemaError, match=strip):
            validate_bench_payload(payload, "stripped")
    payload = _latency_payload()
    payload["benches"][0]["rows"][0]["p95_ms"] = "slow"   # wrong type
    with pytest.raises(SchemaError, match="p95_ms"):
        validate_bench_payload(payload, "typed")
