"""Unified observability layer: events, metrics, timing, drift, wiring.

The contracts under test (DESIGN.md §14):

* ``EventBus`` — monotone seq, bounded ring, strict kind vocabulary,
  JSON-lines sink that ``validate_event_log`` accepts;
* ``MetricsRegistry`` — get-or-create metrics, pluggable sources (the
  shared ``as_dict()`` contract of StreamStats / FaultStats /
  IngestStats and LatencyRecorder's ``summary()``) behind one
  ``snapshot()`` that never raises;
* ``RollupWindows`` — per-N-samples keyed windows with element-wise
  list folding (class-count vectors) and partial-window flush;
* ``StageTimer`` / ``SampledSync`` — per-stage accumulation and the
  every-N sync cadence (0 = never);
* ``DriftMonitor`` — frozen per-key baselines, the three detectors
  (conf_collapse, frac_handled_drop, class_mix_shift), min_packets
  guard, reset;
* ``LatencyRecorder`` bounded-reservoir mode — O(k) memory with exact
  n/mean/max and exact percentiles until the reservoir overflows (the
  unbounded-memory regression of open-ended serving);
* ``GuardedBackend`` lifecycle events — the EXACT event sequence of a
  breaker episode (attempt -> timeout -> retry -> OPEN -> rejected ->
  HALF_OPEN probe -> CLOSED), also under seeded FaultyBackend outage
  injection, and ``reset()`` clearing the monitor state;
* serving-tier wiring — a server built with ``obs=None`` is
  bit-identical to one with an ``Observability`` attached (chunked,
  per-window deferred, and sharded paths), rollups carry the boundary
  deltas, and the registry snapshot unifies all four stats objects.
"""

import dataclasses
import json

import numpy as np
import pytest

from repro.core.mapping import map_tree_ensemble
from repro.ml.trees import fit_random_forest, predict_tree_ensemble
from repro.netsim.features import flow_features
from repro.netsim.ingest import IngestStats, LatencyRecorder, replay_source
from repro.netsim.packets import synth_trace
from repro.obs import (DriftConfig, DriftMonitor, EventBus, EventSchemaError,
                       MetricsRegistry, Observability, RollupWindows,
                       SampledSync, StageTimer, validate_event_log)
from repro.serving.faults import (CLOSED, BackendFault, FaultPolicy,
                                  FaultStats, FaultyBackend, GuardedBackend)
from repro.serving.shard_serving import ShardedStreamingServer
from repro.serving.stream_serving import StreamingHybridServer

N_BUCKETS = 1 << 12


@pytest.fixture(scope="module")
def obs_setup():
    trace = synth_trace(n_flows=300, seed=3)
    b, table = flow_features(trace, n_buckets=N_BUCKETS)
    first_idx = np.unique(np.asarray(trace.flow_id), return_index=True)[1]
    rows = np.asarray(table)[np.asarray(b)[first_idx]].astype(np.float32)
    small = fit_random_forest(rows, trace.flow_label, n_classes=2,
                              n_trees=4, max_depth=3, seed=0)
    big = fit_random_forest(rows, trace.flow_label, n_classes=2,
                            n_trees=12, max_depth=5, seed=1)
    art = map_tree_ensemble(small, rows.shape[1])
    return trace, art, (lambda r: predict_tree_ensemble(big, r))


# ---------------------------------------------------------------------------
# EventBus
# ---------------------------------------------------------------------------

def test_event_bus_seq_and_ring():
    bus = EventBus(max_events=4)
    for i in range(6):
        bus.emit("chunk", windows=i)
    assert bus.emitted == 6 and len(bus) == 4        # ring evicted 2
    seqs = [e.seq for e in bus.events]
    assert seqs == sorted(seqs) and seqs[-1] - seqs[0] == 3
    assert bus.counts() == {"chunk": 4}      # only buffered events count


def test_event_bus_rejects_unknown_kind_and_reserved_fields():
    bus = EventBus()
    with pytest.raises(EventSchemaError):
        bus.emit("not_a_kind")
    with pytest.raises(EventSchemaError):
        bus.emit("chunk", seq=7)        # shadows an envelope key
    assert bus.emitted == 0             # failed emits record nothing


def test_event_log_roundtrip_and_validation(tmp_path):
    path = str(tmp_path / "events.jsonl")
    obs = Observability(events_path=path)
    obs.emit("serve_begin", mode="chunked")
    obs.emit("chunk", windows=8)
    obs.emit("serve_end", packets=100)
    obs.close()
    assert validate_event_log(path) == 3
    lines = [json.loads(l) for l in open(path)]
    assert [l["kind"] for l in lines] == ["serve_begin", "chunk",
                                          "serve_end"]
    assert all(l["v"] == 1 for l in lines)
    # corrupt the seq ordering -> validation must fail
    lines[2]["seq"] = lines[0]["seq"]
    with open(path, "w") as f:
        for l in lines:
            f.write(json.dumps(l) + "\n")
    with pytest.raises(EventSchemaError):
        validate_event_log(path)


# ---------------------------------------------------------------------------
# MetricsRegistry + RollupWindows
# ---------------------------------------------------------------------------

def test_registry_metrics_and_type_conflict():
    reg = MetricsRegistry()
    reg.counter("flushes").inc()
    reg.counter("flushes").inc(2)
    reg.gauge("occupancy").set(0.5)
    for v in (1.0, 2.0, 3.0):
        reg.histogram("lat").observe(v)
    snap = reg.snapshot()
    assert snap["counters"]["flushes"] == 3
    assert snap["gauges"]["occupancy"] == 0.5
    assert snap["histograms"]["lat"]["n"] == 3
    assert snap["histograms"]["lat"]["mean"] == 2.0
    with pytest.raises(ValueError):
        reg.gauge("flushes")            # registered as a counter


def test_registry_sources_unify_stats_objects():
    """The satellite contract: StreamStats / FaultStats / IngestStats all
    expose as_dict() and route through one snapshot()."""
    reg = MetricsRegistry()
    fs, ing = FaultStats(flushes_ok=2), IngestStats(admitted=10,
                                                    count_cuts=1)
    reg.register_source("faults", fs.as_dict)
    reg.register_source("ingest", ing.as_dict)
    reg.register_source("broken", lambda: 1 / 0)
    snap = reg.snapshot()
    assert snap["sources"]["faults"]["flushes_ok"] == 2
    assert snap["sources"]["ingest"]["admitted"] == 10
    assert snap["sources"]["ingest"]["cuts"] == 1      # derived key
    assert "error" in snap["sources"]["broken"]        # never raises


def test_rollup_windows_close_flush_and_vector_fold():
    rw = RollupWindows(every=2)
    assert rw.observe({"packets": 10, "class_counts": [8, 2]}) is None
    row = rw.observe({"packets": 5, "class_counts": [5, 0]})
    assert row["samples"] == 2 and row["sums"]["packets"] == 15
    assert row["sums"]["class_counts"] == [13.0, 2.0]
    rw.observe({"packets": 7}, key="tenant_b")         # keyed windows
    assert rw.flush("tenant_b")["sums"]["packets"] == 7
    assert rw.flush("tenant_b") is None                # nothing open
    assert [r["key"] for r in rw.rows] == ["default", "tenant_b"]


# ---------------------------------------------------------------------------
# StageTimer / SampledSync
# ---------------------------------------------------------------------------

def test_stage_timer_accumulates():
    t = iter(np.arange(0.0, 10.0, 0.5))
    timer = StageTimer(clock=lambda: next(t))
    with timer.stage("megastep"):
        pass
    with timer.stage("megastep"):
        pass
    timer.record("h2d", 0.25)
    summ = timer.summary()
    assert summ["megastep"]["n"] == 2
    assert summ["megastep"]["total_s"] == pytest.approx(1.0)
    assert summ["h2d"]["max_ms"] == pytest.approx(250.0)


def test_sampled_sync_cadence():
    assert [SampledSync(0).due() for _ in range(5)] == [False] * 5
    s = SampledSync(3)
    assert [s.due() for _ in range(7)] == [False, False, True,
                                           False, False, True, False]


# ---------------------------------------------------------------------------
# DriftMonitor
# ---------------------------------------------------------------------------

def _row(window, *, packets=1000, conf=0.95, frac=0.9, mix=(0.9, 0.1),
         key="default"):
    return {"key": key, "window": window, "samples": 1,
            "sums": {"packets": packets, "conf_sum": conf * packets,
                     "handled": int(frac * packets),
                     "class_counts": [m * packets for m in mix]}}


def test_drift_baseline_freezes_then_detects():
    mon = DriftMonitor(DriftConfig(baseline_windows=2))
    assert mon.observe(_row(0)) == []
    assert mon.observe(_row(1)) == []                  # baseline windows
    assert mon.baseline_ready()
    assert mon.observe(_row(2)) == []                  # stationary: silent
    fired = mon.observe(_row(3, conf=0.6, frac=0.5, mix=(0.2, 0.8)))
    assert {a.detector for a in fired} == {"conf_collapse",
                                           "frac_handled_drop",
                                           "class_mix_shift"}
    a = next(a for a in fired if a.detector == "conf_collapse")
    assert a.baseline == pytest.approx(0.95) and a.value == pytest.approx(0.6)
    mon.reset()
    assert not mon.fired and not mon.baseline_ready()


def test_drift_min_packets_guard_and_disabled_detectors():
    mon = DriftMonitor(DriftConfig(baseline_windows=1, min_packets=64,
                                   conf_drop=None, frac_drop=None))
    assert mon.observe(_row(0, packets=10)) == []      # ignored entirely
    assert not mon.baseline_ready()
    mon.observe(_row(1))
    fired = mon.observe(_row(2, conf=0.1, frac=0.1, mix=(0.1, 0.9)))
    assert [a.detector for a in fired] == ["class_mix_shift"]


def test_drift_per_key_baselines():
    mon = DriftMonitor(DriftConfig(baseline_windows=1))
    mon.observe(_row(0, key="a"))
    mon.observe(_row(0, key="b", mix=(0.1, 0.9)))
    assert mon.observe(_row(1, key="a", mix=(0.9, 0.1))) == []
    fired = mon.observe(_row(1, key="b", mix=(0.9, 0.1)))
    assert [a.detector for a in fired] == ["class_mix_shift"]
    assert fired[0].key == "b"


# ---------------------------------------------------------------------------
# LatencyRecorder bounded reservoir (the unbounded-memory regression)
# ---------------------------------------------------------------------------

def test_latency_recorder_unbounded_unchanged():
    rec = LatencyRecorder()
    rec.record(np.array([1.0, 2.0]), 3.0)
    rec.record(np.array([2.5]), 3.0)
    np.testing.assert_allclose(rec.latencies(), [2.0, 1.0, 0.5])
    s = rec.summary()
    assert s["n"] == 3
    assert s["mean_ms"] == pytest.approx(3500.0 / 3)
    assert s["max_ms"] == pytest.approx(2000.0)


def test_latency_recorder_reservoir_bounds_memory_exact_until_full():
    rec = LatencyRecorder(max_samples=8)
    rec.record(np.arange(5, dtype=np.float64), 5.0)    # spans 5..1
    assert rec.n == 5 and rec.latencies().size == 5
    exact = LatencyRecorder()
    exact.record(np.arange(5, dtype=np.float64), 5.0)
    assert rec.summary() == exact.summary()            # exact until full
    # overflow: memory stays at k, n/mean/max stay exact over all seen
    rng = np.random.default_rng(0)
    admits = rng.uniform(0.0, 1.0, 10_000)
    rec.record(admits, 2.0)
    assert rec.latencies().size == 8                   # O(k), not O(n)
    s = rec.summary()
    assert s["n"] == 10_005
    true_spans = np.concatenate([5.0 - np.arange(5), 2.0 - admits])
    assert s["mean_ms"] == pytest.approx(true_spans.mean() * 1e3)
    assert s["max_ms"] == pytest.approx(5000.0)
    # the reservoir percentile is a sample estimate of the true one
    assert abs(s["p50_ms"] - np.percentile(true_spans * 1e3, 50)) < 700.0


def test_latency_recorder_seeded_determinism_and_validation():
    a, b = LatencyRecorder(max_samples=4, seed=7), \
        LatencyRecorder(max_samples=4, seed=7)
    for rec in (a, b):
        rec.record(np.linspace(0, 1, 100), 2.0)
    np.testing.assert_array_equal(a.latencies(), b.latencies())
    with pytest.raises(ValueError):
        LatencyRecorder(max_samples=0)


def test_serve_stream_latency_samples_bounds_recorder(obs_setup):
    trace, art, backend = obs_setup
    srv = StreamingHybridServer(art, backend, n_buckets=N_BUCKETS,
                                window=128, chunk_windows=4)
    srv.serve_stream(replay_source(trace), record_latency=True,
                     latency_samples=32)
    assert srv.latency.max_samples == 32
    assert srv.latency.n == trace.n_packets            # n stays exact
    assert srv.latency.latencies().size == 32


# ---------------------------------------------------------------------------
# GuardedBackend lifecycle events (the exact breaker sequence)
# ---------------------------------------------------------------------------

def test_breaker_event_sequence_exact():
    """One full breaker episode, event by event: first flush times out
    then errors (failed), second flush fails twice more -> OPEN, third is
    rejected while cooling down, fourth is the HALF_OPEN probe -> CLOSED."""
    import threading
    release = threading.Event()
    calls = {"i": 0}

    def backend(rows):
        i = calls["i"]
        calls["i"] += 1
        if i == 0:
            release.wait(5.0)           # abandoned by the 30ms timeout
        if i in (1, 2, 3):
            raise BackendFault(f"scripted failure {i}")
        return np.zeros(4, np.int32)

    bus = EventBus()
    guard = GuardedBackend(
        backend, FaultPolicy(timeout_s=0.03, max_retries=1,
                             backoff_base_s=0.0, breaker_threshold=2,
                             breaker_cooldown=1),
        sleep=lambda s: None, events=bus)
    try:
        assert guard(np.zeros((4, 8))) is None         # flush 1: failed
    finally:
        release.set()                   # unstick the abandoned worker
    assert guard(np.zeros((4, 8))) is None             # flush 2: -> OPEN
    assert guard(np.zeros((4, 8))) is None             # flush 3: rejected
    out = guard(np.zeros((4, 8)))                      # flush 4: probe ok
    np.testing.assert_array_equal(out, np.zeros(4, np.int32))
    assert [e.kind for e in bus.events] == [
        "backend_attempt", "backend_timeout",          # flush 1
        "backend_retry", "backend_attempt", "backend_error",
        "flush_failed",
        "backend_attempt", "backend_error",            # flush 2
        "backend_retry", "backend_attempt", "backend_error",
        "flush_failed", "breaker_open",
        "flush_rejected",                              # flush 3
        "breaker_half_open", "backend_attempt",        # flush 4 (probe)
        "flush_ok", "breaker_close",
    ]
    assert guard.stats.breaker_opens == 1
    assert guard.stats.breaker_closes == 1


def test_breaker_events_under_faulty_backend_injection():
    """Same lifecycle driven by seeded FaultyBackend outages instead of a
    scripted backend: deterministic OPEN -> probe -> CLOSED."""
    be = FaultyBackend(lambda rows: np.zeros(len(rows), np.int32),
                       outages=range(0, 4), seed=0)
    bus = EventBus()
    guard = GuardedBackend(
        be, FaultPolicy(max_retries=1, backoff_base_s=0.0,
                        breaker_threshold=2, breaker_cooldown=1),
        sleep=lambda s: None, events=bus)
    assert guard(np.zeros((2, 8))) is None             # outages 0,1
    assert guard(np.zeros((2, 8))) is None             # outages 2,3 -> OPEN
    assert guard(np.zeros((2, 8))) is None             # rejected (cooldown)
    assert guard(np.zeros((2, 8))) is not None         # probe succeeds
    kinds = [e.kind for e in bus.events]
    assert kinds.count("breaker_open") == 1
    assert kinds.count("flush_rejected") == 1
    assert kinds.index("breaker_half_open") < kinds.index("breaker_close")
    assert kinds[-1] == "breaker_close"


def test_guard_reset_clears_monitor_state_and_emits():
    bus = EventBus()
    guard = GuardedBackend(
        lambda rows: (_ for _ in ()).throw(BackendFault("down")),
        FaultPolicy(max_retries=0, backoff_base_s=0.0,
                    breaker_threshold=1, breaker_cooldown=2),
        sleep=lambda s: None, events=bus)
    assert guard(np.zeros((2, 8))) is None
    assert guard.stats.breaker_opens == 1
    guard.reset()
    assert guard.state == CLOSED
    assert guard.stats == FaultStats()                 # telemetry cleared
    assert guard.consecutive_failures == 0
    assert bus.events[-1].kind == "guard_reset"
    # construction-time reset() must NOT have emitted (events bound after)
    assert [e.kind for e in bus.events].count("guard_reset") == 1


# ---------------------------------------------------------------------------
# Serving-tier wiring: bit-identity, rollups, unified snapshot
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("path_kw", [
    {"chunk_windows": 4},                       # chunked megastep
    {"flush_every": 1},                         # per-window immediate
    {"flush_every": 3},                         # per-window deferred
], ids=["chunked", "per_window", "deferred"])
def test_obs_bit_identity_single_device(obs_setup, path_kw):
    trace, art, backend = obs_setup
    kw = dict(n_buckets=N_BUCKETS, window=128, **path_kw)
    ref_preds, ref_stats = StreamingHybridServer(
        art, backend, **kw).serve_trace(trace)
    obs = Observability(rollup_every=2)
    srv = StreamingHybridServer(art, backend, obs=obs, **kw)
    preds, stats = srv.serve_trace(trace)
    np.testing.assert_array_equal(np.asarray(preds), np.asarray(ref_preds))
    assert stats == ref_stats
    assert obs.events.counts()["serve_begin"] == 1
    assert obs.rollups.n_rows > 0
    # rollup deltas reconcile with the final stats
    total = sum(r["sums"]["packets"] for r in obs.rollups.rows)
    assert total == stats.n_packets


def test_obs_bit_identity_sharded(obs_setup):
    trace, art, backend = obs_setup
    kw = dict(n_buckets=N_BUCKETS, window=128, n_shards=1)
    ref_preds, ref_stats = ShardedStreamingServer(
        art, backend, **kw).serve_trace(trace)
    obs = Observability(rollup_every=2)
    srv = ShardedStreamingServer(art, backend, obs=obs, **kw)
    preds, stats = srv.serve_trace(trace)
    np.testing.assert_array_equal(np.asarray(preds), np.asarray(ref_preds))
    assert stats == ref_stats
    assert obs.rollups.n_rows > 0


def test_obs_snapshot_unifies_server_telemetry(obs_setup):
    trace, art, backend = obs_setup
    obs = Observability(rollup_every=2)
    srv = StreamingHybridServer(art, backend, n_buckets=N_BUCKETS,
                                window=128, chunk_windows=4,
                                fault_policy=FaultPolicy(max_retries=0),
                                obs=obs)
    srv.serve_stream(replay_source(trace), record_latency=True)
    snap = obs.snapshot()
    src = snap["sources"]
    assert src["server.stream"]["packets"] == trace.n_packets
    assert src["server.stream"]["conf_sum"] > 0
    assert 0.0 <= src["server.stream"]["mean_conf"] <= 1.0
    assert src["server.faults"]["flushes_ok"] == srv.fault_stats.flushes_ok
    assert src["server.ingest"]["admitted"] == trace.n_packets
    assert src["server.latency"]["n"] == trace.n_packets
    assert "megastep" in snap["stages"]
    assert snap["events"]["emitted"] == obs.events.emitted
    assert snap["drift"]["enabled"] and snap["drift"]["alarms"] == []


def test_obs_stats_as_dict_contract(obs_setup):
    """StreamStats.as_dict carries the additive counters + derived
    ratios, and the accounting invariant survives the conf_sum field."""
    trace, art, backend = obs_setup
    srv = StreamingHybridServer(art, backend, n_buckets=N_BUCKETS,
                                window=128, chunk_windows=4)
    _, stats = srv.serve_trace(trace)
    d = stats.as_dict()
    assert d["handled"] + d["backend_rows"] + d["deferred"] \
        + d["degraded"] == d["packets"]
    assert d["fraction_handled"] == pytest.approx(stats.fraction_handled)
    assert d["mean_conf"] == pytest.approx(d["conf_sum"] / d["packets"])
    for cls in (IngestStats, FaultStats):
        assert isinstance(cls().as_dict(), dict)


def test_obs_sampled_sync_and_stage_timing_bit_identical(obs_setup):
    """sync_every changes when the host waits, never a value; the stage
    timers see the megastep and the synced stage."""
    trace, art, backend = obs_setup
    kw = dict(n_buckets=N_BUCKETS, window=128, chunk_windows=4)
    ref, _ = StreamingHybridServer(art, backend, **kw).serve_trace(trace)
    obs = Observability(rollup_every=2, sync_every=2)
    srv = StreamingHybridServer(art, backend, obs=obs, **kw)
    preds, _ = srv.serve_trace(trace)
    np.testing.assert_array_equal(np.asarray(preds), np.asarray(ref))
    assert obs.timer.count("megastep") > 0
    assert obs.timer.count("megastep_synced") > 0


def test_obs_drift_fires_on_class_mix_shift_trace(obs_setup):
    """End-to-end drift: a benign segment then an anomaly-heavy segment
    appended after it trips class_mix_shift; the stationary replay of
    the same benign trace stays silent (same thresholds)."""
    trace, art, backend = obs_setup
    kw = dict(n_buckets=N_BUCKETS, window=128, chunk_windows=2)
    drift = DriftConfig(baseline_windows=2, mix_l1=0.1)

    obs_flat = Observability(rollup_every=1, drift=drift)
    StreamingHybridServer(art, backend, obs=obs_flat,
                          **kw).serve_trace(trace)
    assert not obs_flat.drift.fired, obs_flat.alarms

    shifted = synth_trace(n_flows=300, anomaly_frac=0.95, seed=4)
    shifted = dataclasses.replace(
        shifted, ts=shifted.ts + float(trace.ts.max()) + 1.0)
    from repro.netsim.scenarios import merge_traces
    both = merge_traces(trace, shifted)
    obs = Observability(rollup_every=1, drift=drift)
    StreamingHybridServer(art, backend, obs=obs, **kw).serve_trace(both)
    assert "class_mix_shift" in obs.drift.fired_detectors, \
        obs.drift.fired_detectors
    assert obs.events.counts().get("drift_alarm", 0) == len(obs.alarms)


def test_obs_flush_and_autotune_events(obs_setup):
    """The per-window deferred path narrates its flush lifecycle, and
    chunk_windows='auto' records the autotune decision."""
    trace, art, backend = obs_setup
    obs = Observability(rollup_every=4)
    srv = StreamingHybridServer(art, backend, n_buckets=N_BUCKETS,
                                window=128, flush_every=3, obs=obs)
    srv.serve_trace(trace)
    counts = obs.events.counts()
    assert counts["flush"] >= 1 and counts["backpatch"] >= 1
    triggers = {e.fields["trigger"] for e in obs.events.of("flush")}
    assert "end_of_stream" in triggers or "cycle_full" in triggers

    obs2 = Observability()
    StreamingHybridServer(art, backend, n_buckets=N_BUCKETS, window=128,
                          chunk_windows="auto", autotune=False, obs=obs2)
    auto = obs2.events.of("autotune")
    assert len(auto) == 1 and auto[0].fields["knob"] == "chunk_windows"
