"""§7.9 analog: model-update time by table swap, no recompile.

Retrain under the same constraints -> remap -> swap arrays into the
already-jitted inference function. The measured quantities:
  * remap time (control-plane table generation),
  * swap-and-first-classify time with the NEW tables through the OLD
    compiled function (must not retrace — asserted via cache stats).
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import load_usecase, print_table
from repro.core.inference import table_predict
from repro.core.mapping import map_tree_ensemble
from repro.ml.trees import fit_random_forest


def run(n=16000, seed=0):
    xtr, ytr, xte, yte = load_usecase("anomaly", n=n, seed=seed)
    rows = []
    for tag, trees, depth in (("small", 6, 4), ("medium", 10, 5),
                              ("large", 14, 6)):
        rf0 = fit_random_forest(xtr, ytr, n_classes=2, n_trees=trees,
                                max_depth=depth, seed=seed)
        art0 = map_tree_ensemble(rf0, xtr.shape[1])
        fn = jax.jit(table_predict)
        fn(art0, xte[:1024])[0].block_until_ready()
        traces0 = fn._cache_size()

        # "data changed": retrain on the second half, same constraints
        t0 = time.perf_counter()
        rf1 = fit_random_forest(xtr[len(xtr) // 2:], ytr[len(ytr) // 2:],
                                n_classes=2, n_trees=trees, max_depth=depth,
                                seed=seed + 1)
        t_retrain = time.perf_counter() - t0
        t0 = time.perf_counter()
        art1 = map_tree_ensemble(rf1, xtr.shape[1])
        t_remap = time.perf_counter() - t0

        shapes_equal = all(jax.tree.leaves(jax.tree.map(
            lambda a, b: a.shape == b.shape, art0, art1)))
        t0 = time.perf_counter()
        fn(art1, xte[:1024])[0].block_until_ready()
        t_swap = time.perf_counter() - t0
        retraced = fn._cache_size() != traces0
        rows.append([tag, trees, depth, f"{t_retrain * 1e3:.0f}ms",
                     f"{t_remap * 1e3:.0f}ms", f"{t_swap * 1e3:.1f}ms",
                     shapes_equal, not retraced])
    print_table("§7.9 — model update by table swap",
                ["size", "trees", "depth", "retrain", "remap",
                 "swap+classify", "shapes_stable", "no_recompile"], rows)
    return rows


if __name__ == "__main__":
    run()
