"""Figs 4-5 analog: memory scaling with feature count; max model size
that fits a fixed budget (the switch-pipeline analog = VMEM budget).

Fig 4: artifact memory vs number of features (DT, both use cases) and
trees-that-fit vs features (RF) under the VMEM budget.
Fig 5: max features per model under the budget.
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import fit_and_map, load_usecase, print_table
from repro.core.mapping import map_tree_ensemble
from repro.core.resources import artifact_resources
from repro.kernels.ops import VMEM_BUDGET_BYTES
from repro.ml.trees import fit_random_forest


def run(n=12000, seed=0):
    # -- Fig 4a/b: DT memory vs features ------------------------------------
    rows = []
    for use_case in ("anomaly", "finance"):
        if use_case == "anomaly":
            from repro.data.unsw_like import make_unsw_like, train_test_split
            x, y = make_unsw_like(n, seed=seed, n_features=10)
        else:
            from repro.data.janestreet_like import (make_janestreet_like,
                                                    train_test_split)
            x, y = make_janestreet_like(n, seed=seed)
        xtr, ytr, xte, yte = train_test_split(x, y)
        for f in (2, 4, 6, 8, 10):
            _, art, _ = fit_and_map("DT", xtr[:, :f], ytr, max_depth=5)
            res = artifact_resources(art)
            rows.append([use_case, f, res.entries, f"{res.kib:.1f}"])
    print_table("Fig 4 — DT memory vs #features",
                ["use_case", "features", "entries", "KiB"], rows)

    # -- Fig 4c: trees that fit vs features (RF, anomaly) --------------------
    from repro.data.unsw_like import make_unsw_like, train_test_split
    x, y = make_unsw_like(n, seed=seed, n_features=10)
    xtr, ytr, _, _ = train_test_split(x, y)
    fit_rows = []
    for f in (2, 4, 6, 8):
        max_fit = 0
        for trees in (2, 5, 10, 20, 40, 80):
            try:
                rf = fit_random_forest(xtr[:, :f], ytr, n_classes=2,
                                       n_trees=trees, max_depth=4, seed=seed)
                art = map_tree_ensemble(rf, f)
            except ValueError:        # decision-table blowup guard
                break
            bits = artifact_resources(art).bits
            if bits / 8 <= VMEM_BUDGET_BYTES:
                max_fit = trees
        fit_rows.append([f, max_fit])
    print_table("Fig 4c — max RF trees fitting the VMEM budget "
                f"({VMEM_BUDGET_BYTES >> 20} MiB)",
                ["features", "max_trees(d=4)"], fit_rows)

    # -- Fig 5: max features per model under the budget ----------------------
    from repro.data.janestreet_like import make_janestreet_like
    from repro.data.janestreet_like import train_test_split as js_split
    x, y = make_janestreet_like(n, seed=seed)
    xtr, ytr, _, _ = js_split(x, y)
    f5 = []
    for model in ("SVM", "Bayes", "KMeans", "DT"):
        best = 0
        for f in (5, 10, 20, 40, 80, 130):
            try:
                _, art, _ = fit_and_map(model, xtr[:, :f], ytr, max_depth=4)
            except ValueError:
                break
            if artifact_resources(art).bits / 8 <= VMEM_BUDGET_BYTES:
                best = f
        f5.append([model, best])
    print_table("Fig 5 — max features under the budget (finance)",
                ["model", "max_features"], f5)
    return rows, fit_rows, f5


if __name__ == "__main__":
    run()
