"""Sharded streaming benchmark: packets/sec vs mesh shape.

``python -m benchmarks.shard_stream_bench`` drives the
``ShardedStreamingServer`` over a synthetic packet trace on a sweep of
('shard', 'data') mesh shapes — the 1D column (1,1)/(2,1)/(4,1) plus the
2D (2,2) square when four devices exist — and reports sustained
packets/sec for the full shard_mapped step (per-shard register update ->
owner-masked readout -> PARTITIONED classify over per-device lane slabs
-> reduce-scatter/all-gather merges -> capacity-bounded backend ->
combine -> telemetry). Run standalone it forces a 4-device CPU host
platform (``--xla_force_host_platform_device_count``) unless XLA_FLAGS
is already set, so the scaling axis exists even on a single-CPU box.

Each mesh shape is timed twice: the partitioned-classify layout
(DESIGN.md §16, the headline number) and the ``partition_classify=False``
**merge_overhead baseline** — the pre-partitioning layout where every
device classifies all W lanes and the owner-masked psum merge throws the
duplicates away. ``speedup_vs_merge_overhead`` is the honest per-shape
comparison (same mesh, same collective overheads, only the classify
partitioned); ``speedup_vs_1dev`` compares partitioned shapes against
the partitioned (1, 1) run.

Before any timing, three gates run per mesh shape:

* the sharded flow table must reproduce the batch ``flow_features``
  table bit for bit,
* both layouts' predictions must equal the single-device
  ``StreamingHybridServer`` on the same trace (a speedup that drifts the
  registers or the answers is not a speedup), and
* ``classify_rows_per_device`` must equal the padded
  ceil(W / (D_shard*D_data)) — NOT the full W — proving the per-device
  classify work actually shrank with the mesh.

Results go to ``BENCH_shard.json`` (schema "bench-v1", DESIGN.md §11).

Caveat on the recorded curve: forced host-platform devices all share one
physical CPU, so the multi-"device" rows pay the partitioning overhead
without any extra silicon — speedup < 1 is expected there. The point of
the bench is the *axis* (and the gates guarding it); on a real
multi-chip mesh the same rows measure real scaling.
"""

from __future__ import annotations

import argparse
import os
import time


def _time_serve(srv, ws, repeats):
    """min-over-repeats wall time for the stepwise loop over ``ws``."""
    import jax

    best = float("inf")
    for _ in range(repeats):
        srv.reset()
        t0 = time.perf_counter()
        for w in ws:
            pred, _ = srv.step(w)
        jax.block_until_ready(pred)            # single end-of-stream sync
        best = min(best, time.perf_counter() - t0)
    return best


def run(n_flows=4000, window=1024, n_buckets=1 << 13, mesh_shapes=None,
        threshold=0.9, capacity=64, repeats=3, seed=0, evict_age=2.0,
        out="BENCH_shard.json"):
    # imports deferred so main() can force the host device count first
    import jax
    import numpy as np

    from benchmarks.common import print_table, write_bench_json
    from benchmarks.common import trace_models
    from repro.distributed.sharding import flow_shard_mesh
    from repro.kernels.ops import classify_batch_rows
    from repro.kernels.tuning import shard_tiles
    from repro.netsim.features import flow_features
    from repro.netsim.packets import synth_trace
    from repro.netsim.shard_stream import (lane_slab_rows,
                                           stream_sharded_flow_features)
    from repro.netsim.stream import iter_windows
    from repro.serving.shard_serving import ShardedStreamingServer
    from repro.serving.stream_serving import StreamingHybridServer

    t_suite = time.time()
    avail = jax.local_device_count()
    if mesh_shapes is None:
        mesh_shapes = [(d, 1) for d in (1, 2, 4, 8) if d <= avail]
        if avail >= 4:
            mesh_shapes.append((2, 2))         # the 2D square
    trace = synth_trace(n_flows=n_flows, seed=seed)
    _, batch_table = flow_features(trace, n_buckets=n_buckets)
    art, backend = trace_models(trace, n_buckets)

    # single-device reference: the bit-consistency oracle's answer key
    ref = StreamingHybridServer(art, backend, n_buckets=n_buckets,
                                window=window, threshold=threshold,
                                capacity=capacity)
    ref_pred, _ = ref.serve_trace(trace)
    ref_pred = np.asarray(ref_pred)

    ws = list(iter_windows(trace, window, n_buckets))
    kw = dict(n_buckets=n_buckets, window=window, threshold=threshold,
              capacity=capacity)
    rows, base_pkts_s = [], None
    for d_shard, d_data in mesh_shapes:
        mesh = flow_shard_mesh(d_shard, d_data)
        # gate 1: sharded register carry == batch flow table, bitwise
        _, sh_table = stream_sharded_flow_features(
            trace, n_buckets=n_buckets, window=window, mesh=mesh)
        np.testing.assert_array_equal(np.asarray(sh_table),
                                      np.asarray(batch_table))
        srv = ShardedStreamingServer(art, backend, mesh=mesh, **kw)
        # gate 2 (+ warm pass: compile + fuse probe): partitioned sharded
        # serving == single-device serving, bitwise
        sh_pred, _ = srv.serve_trace(trace)
        np.testing.assert_array_equal(np.asarray(sh_pred), ref_pred)
        # gate 3 (tentpole): per-device classify rows are the padded
        # per-slab ceiling, NOT the full window width
        slab = lane_slab_rows(window, d_shard, d_data)
        want_rows = classify_batch_rows(art, slab, use_pallas=srv.use_pallas,
                                        tiles=shard_tiles(srv.tiles, slab))
        got_rows = srv.classify_rows_per_device
        if got_rows != want_rows:
            raise AssertionError(
                f"mesh ({d_shard},{d_data}): classify_rows_per_device "
                f"{got_rows} != padded ceil(W/D) {want_rows}")
        if d_shard * d_data > 1 and not got_rows < window:
            raise AssertionError(
                f"mesh ({d_shard},{d_data}): per-device classify rows "
                f"{got_rows} did not shrink below the full window {window}")

        best = _time_serve(srv, ws, repeats)
        stats = srv.stats
        pkts_s = trace.n_packets / best

        # merge_overhead baseline: same mesh, replicated classify +
        # owner-masked psum merge (the pre-partitioning layout)
        base = ShardedStreamingServer(art, backend, mesh=mesh,
                                      partition_classify=False, **kw)
        base_pred, _ = base.serve_trace(trace)     # oracle + warm pass
        np.testing.assert_array_equal(np.asarray(base_pred), ref_pred)
        merge_best = _time_serve(base, ws, repeats)
        merge_pkts_s = trace.n_packets / merge_best

        if base_pkts_s is None:
            base_pkts_s = pkts_s                   # partitioned (1, 1)
        rows.append({
            "devices": d_shard * d_data,
            "d_shard": d_shard,
            "d_data": d_data,
            "window": window,
            "n_packets": trace.n_packets,
            "n_buckets": n_buckets,
            "classify_rows_per_device": got_rows,
            "wall_s": round(best, 4),
            "pkts_per_s": round(pkts_s, 1),
            "speedup_vs_1dev": round(pkts_s / base_pkts_s, 3),
            "merge_overhead_pkts_per_s": round(merge_pkts_s, 1),
            "speedup_vs_merge_overhead": round(pkts_s / merge_pkts_s, 3),
            "fraction_handled": round(stats.fraction_handled, 4),
            "backend_rows": stats.total_backend_rows,
            "bit_consistent": True,
        })

    print_table("Sharded streaming — packets/sec vs mesh shape",
                ["mesh", "pkts", "rows/dev", "wall_s", "pkts/s",
                 "vs_1dev", "vs_merge", "frac_handled"],
                [[f"({r['d_shard']},{r['d_data']})", r["n_packets"],
                  r["classify_rows_per_device"], r["wall_s"],
                  r["pkts_per_s"], r["speedup_vs_1dev"],
                  r["speedup_vs_merge_overhead"], r["fraction_handled"]]
                 for r in rows])

    # lifecycle entry: aging sweep on, telemetry recorded (not oracle-
    # gated against batch — eviction intentionally diverges the table)
    d_shard, d_data = mesh_shapes[-1]
    srv = ShardedStreamingServer(art, backend,
                                 mesh=flow_shard_mesh(d_shard, d_data),
                                 evict_age=evict_age, **kw)
    t0 = time.perf_counter()
    _, stats = srv.serve_trace(trace)
    stats_wall = time.perf_counter() - t0
    evict_rows = [{
        "devices": d_shard * d_data, "d_shard": d_shard, "d_data": d_data,
        "classify_rows_per_device": srv.classify_rows_per_device,
        "evict_age_s": evict_age,
        "n_packets": trace.n_packets, "wall_s": round(stats_wall, 4),
        "evicted": stats.n_evicted, "overflow": stats.n_overflow,
        "fraction_handled": round(stats.fraction_handled, 4),
    }]
    print_table("Sharded streaming — eviction/aging sweep",
                ["mesh", "evict_age_s", "evicted", "overflow",
                 "frac_handled"],
                [[f"({r['d_shard']},{r['d_data']})", r["evict_age_s"],
                  r["evicted"], r["overflow"], r["fraction_handled"]]
                 for r in evict_rows])

    benches = [
        {"name": "shard_stream", "paper_ref": "§5 challenge (ii) / pForest",
         "ok": True, "rows": rows,
         "wall_s": round(time.time() - t_suite, 3)},
        {"name": "shard_eviction", "paper_ref": "pForest window aging",
         "ok": True, "rows": evict_rows, "wall_s": round(stats_wall, 3)},
    ]
    if out:
        write_bench_json(out, "shard", benches,
                         config={"n_flows": n_flows, "window": window,
                                 "n_buckets": n_buckets,
                                 "mesh_shapes": [list(s)
                                                 for s in mesh_shapes],
                                 "threshold": threshold,
                                 "capacity": capacity, "repeats": repeats,
                                 "evict_age": evict_age})
    return rows + evict_rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--devices", type=int, default=4,
                    help="host-platform device count to force when jax is "
                         "not yet configured (ignored if XLA_FLAGS is set)")
    ap.add_argument("--out", default="BENCH_shard.json")
    args = ap.parse_args(argv)
    # must happen before the first jax import in this process
    if "jax" not in __import__("sys").modules and \
            "--xla_force_host_platform_device_count" not in \
            os.environ.get("XLA_FLAGS", ""):
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "")
            + f" --xla_force_host_platform_device_count={args.devices}"
        ).strip()
    if args.quick:
        run(n_flows=1200, window=512, n_buckets=1 << 12, repeats=2,
            out=args.out)
    else:
        run(out=args.out)


if __name__ == "__main__":
    main()
