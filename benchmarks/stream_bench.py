"""Streaming serving microbenchmark: packets/sec vs window size.

``python -m benchmarks.stream_bench`` drives the StreamingHybridServer
over a synthetic packet trace at several window sizes and reports
sustained packets/sec for the full fused step (register update -> flow
feature read-out -> switch classify -> capacity-bounded backend ->
combine -> telemetry). Windows are pre-materialized so the timed loop
measures the serving path, not host-side trace slicing; the loop never
syncs — one block_until_ready at the end, matching the zero-sync serving
contract.

Before any timing, the equivalence oracle runs: streaming the trace over
W windows must reproduce the batch ``flow_features`` table bit for bit
(a speedup from drifted registers is not a speedup).

Results go to ``BENCH_stream.json`` (schema "bench-v1", DESIGN.md §9).
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from benchmarks.common import print_table, write_bench_json
from repro.core.mapping import map_tree_ensemble
from repro.ml.trees import fit_random_forest, predict_tree_ensemble
from repro.netsim.features import flow_features
from repro.netsim.packets import synth_trace
from repro.netsim.stream import iter_windows, stream_flow_features
from repro.serving.stream_serving import StreamingHybridServer


def _models(trace, n_buckets):
    """Train the switch-size RF + backend RF on batch flow features."""
    b, table = flow_features(trace, n_buckets=n_buckets)
    first_idx = np.unique(np.asarray(trace.flow_id), return_index=True)[1]
    rows = np.asarray(table)[np.asarray(b)[first_idx]].astype(np.float32)
    labels = trace.flow_label
    small = fit_random_forest(rows, labels, n_classes=2, n_trees=4,
                              max_depth=3, seed=0)
    big = fit_random_forest(rows, labels, n_classes=2, n_trees=16,
                            max_depth=6, seed=1)
    art = map_tree_ensemble(small, rows.shape[1])
    return art, (lambda r: predict_tree_ensemble(big, r))


def run(n_flows=4000, windows=(256, 1024, 4096), n_buckets=1 << 13,
        threshold=0.9, capacity=64, repeats=3, seed=0,
        out="BENCH_stream.json"):
    t_suite = time.time()
    trace = synth_trace(n_flows=n_flows, seed=seed)
    _, batch_table = flow_features(trace, n_buckets=n_buckets)

    art, backend = _models(trace, n_buckets)
    rows = []
    for w_size in windows:
        # equivalence oracle per window size: streaming at THIS chunking
        # must reproduce the batch flow table before its numbers count
        _, stream_table = stream_flow_features(trace, n_buckets=n_buckets,
                                               window=w_size)
        np.testing.assert_array_equal(np.asarray(stream_table),
                                      np.asarray(batch_table))
        srv = StreamingHybridServer(art, backend, n_buckets=n_buckets,
                                    window=w_size, threshold=threshold,
                                    capacity=capacity)
        ws = list(iter_windows(trace, w_size, n_buckets))
        # warm pass: compile + backend probe
        for w in ws:
            pred, _ = srv.step(w)
        jax.block_until_ready(pred)
        best = float("inf")
        for _ in range(repeats):
            srv.reset()
            t0 = time.perf_counter()
            for w in ws:
                pred, _ = srv.step(w)
            jax.block_until_ready(pred)        # single end-of-stream sync
            best = min(best, time.perf_counter() - t0)
        stats = srv.stats
        rows.append({
            "window": w_size,
            "n_packets": trace.n_packets,
            "n_windows": len(ws),
            "wall_s": round(best, 4),
            "pkts_per_s": round(trace.n_packets / best, 1),
            "fraction_handled": round(stats.fraction_handled, 4),
            "backend_rows": stats.total_backend_rows,
            "bit_consistent": True,
        })

    print_table("Streaming hybrid serving — packets/sec vs window size",
                ["window", "pkts", "windows", "wall_s", "pkts/s",
                 "frac_handled", "backend_rows"],
                [[r["window"], r["n_packets"], r["n_windows"], r["wall_s"],
                  r["pkts_per_s"], r["fraction_handled"], r["backend_rows"]]
                 for r in rows])

    benches = [{"name": "stream_serving",
                "paper_ref": "§5 challenge (ii) / pForest",
                "ok": True, "rows": rows,
                "wall_s": round(time.time() - t_suite, 3)}]
    if out:
        write_bench_json(out, "stream", benches,
                         config={"n_flows": n_flows, "windows": list(windows),
                                 "n_buckets": n_buckets,
                                 "threshold": threshold,
                                 "capacity": capacity, "repeats": repeats})
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--out", default="BENCH_stream.json")
    args = ap.parse_args(argv)
    if args.quick:
        run(n_flows=1200, windows=(256, 1024), repeats=2, out=args.out)
    else:
        run(out=args.out)


if __name__ == "__main__":
    main()
