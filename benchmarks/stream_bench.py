"""Streaming serving microbenchmark: packets/sec vs window size + chunking.

``python -m benchmarks.stream_bench`` drives the StreamingHybridServer
over a synthetic packet trace at several window sizes and reports
sustained packets/sec for the full fused step (register update -> flow
feature read-out -> switch classify -> capacity-bounded backend ->
combine -> telemetry). Windows are pre-materialized so the timed loop
measures the serving path, not host-side trace slicing; the loop never
syncs — one block_until_ready at the end, matching the zero-sync serving
contract.

Before any timing, the equivalence oracle runs: streaming the trace over
W windows must reproduce the batch ``flow_features`` table bit for bit
(a speedup from drifted registers is not a speedup).

The chunked sweep (DESIGN.md §8) times the device-resident megastep —
``step_chunk`` over (K, W) PacketChunks, one scan dispatch and one
backend invocation per K windows — against the per-window baseline on
the *same trace*, gated on two oracles:

* chunked ``serve_trace`` predictions (including the deferred
  back-patching) must equal the per-window baseline bit for bit;
* at the smallest window the best chunked row must clear >= 3x the
  baseline packets/sec — the subsystem's acceptance bar (small windows
  are exactly where per-window dispatch overhead collapses throughput).

Results go to ``BENCH_stream.json`` (schema "bench-v1", DESIGN.md §11).
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from benchmarks.common import print_table, trace_models, write_bench_json
from repro.netsim.features import flow_features
from repro.netsim.packets import synth_trace
from repro.netsim.stream import iter_chunks, iter_windows, \
    stream_flow_features
from repro.serving.stream_serving import StreamingHybridServer


def run(n_flows=4000, windows=(256, 1024, 4096), chunks=(4, 16, 64),
        n_buckets=1 << 13, threshold=0.9, capacity=64, repeats=3, seed=0,
        min_speedup=3.0, out="BENCH_stream.json"):
    t_suite = time.time()
    trace = synth_trace(n_flows=n_flows, seed=seed)
    _, batch_table = flow_features(trace, n_buckets=n_buckets)

    art, backend = trace_models(trace, n_buckets)
    kw = dict(n_buckets=n_buckets, threshold=threshold, capacity=capacity)
    rows = []
    base_preds = None
    for w_size in windows:
        # equivalence oracle per window size: streaming at THIS chunking
        # must reproduce the batch flow table before its numbers count
        _, stream_table = stream_flow_features(trace, n_buckets=n_buckets,
                                               window=w_size)
        np.testing.assert_array_equal(np.asarray(stream_table),
                                      np.asarray(batch_table))
        srv = StreamingHybridServer(art, backend, window=w_size, **kw)
        ws = list(iter_windows(trace, w_size, n_buckets))
        # warm pass: compile + backend probe
        for w in ws:
            pred, _ = srv.step(w)
        jax.block_until_ready(pred)
        best = float("inf")
        for _ in range(repeats):
            srv.reset()
            t0 = time.perf_counter()
            for w in ws:
                pred, _ = srv.step(w)
            jax.block_until_ready(pred)        # single end-of-stream sync
            best = min(best, time.perf_counter() - t0)
        stats = srv.stats
        if w_size == min(windows):
            # baseline predictions the chunk-sweep oracle is gated against
            # (its *timing* baseline is re-measured interleaved below)
            srv.reset()
            base_preds, _ = srv.serve_trace(trace)
            base_preds = np.asarray(base_preds)
        rows.append({
            "window": w_size,
            "n_packets": trace.n_packets,
            "n_windows": len(ws),
            "wall_s": round(best, 4),
            "pkts_per_s": round(trace.n_packets / best, 1),
            "us_per_window": round(best / len(ws) * 1e6, 1),
            "fraction_handled": round(stats.fraction_handled, 4),
            "backend_rows": stats.total_backend_rows,
            "bit_consistent": True,
        })

    print_table("Streaming hybrid serving — packets/sec vs window size",
                ["window", "pkts", "windows", "wall_s", "pkts/s",
                 "us/window", "frac_handled", "backend_rows"],
                [[r["window"], r["n_packets"], r["n_windows"], r["wall_s"],
                  r["pkts_per_s"], r["us_per_window"],
                  r["fraction_handled"], r["backend_rows"]] for r in rows])

    # -- chunked megastep sweep at the smallest window (the regime where
    # -- per-window dispatch overhead dominates — DESIGN.md §8) ------------
    #
    # The baseline is re-timed here, interleaved round-robin with every
    # chunked configuration: a machine-load spike then degrades the same
    # round of *all* configurations instead of silently skewing the
    # speedup ratio one way, and min-over-rounds recovers the true cost
    # of each (same min-robustness rationale as the kernel microbench).
    w_size = min(windows)
    srv_base = StreamingHybridServer(art, backend, window=w_size, **kw)
    ws = list(iter_windows(trace, w_size, n_buckets))
    chunk_srvs, chunk_stats = {}, {}
    for k in chunks:
        srv = StreamingHybridServer(art, backend, window=w_size,
                                    chunk_windows=k, **kw)
        # oracle: chunked predictions (incl. back-patching) must equal the
        # per-window baseline bit for bit before the numbers count
        preds, stats = srv.serve_trace(trace)
        np.testing.assert_array_equal(np.asarray(preds), base_preds)
        chunk_srvs[k] = (srv, list(iter_chunks(trace, w_size, k, n_buckets)))
        chunk_stats[k] = stats
    for w in ws:                                       # warm the baseline
        pred, _ = srv_base.step(w)
    jax.block_until_ready(pred)
    t_base, t_chunk = float("inf"), {k: float("inf") for k in chunks}
    for _ in range(max(repeats, 3)):
        srv_base.reset()
        t0 = time.perf_counter()
        for w in ws:
            pred, _ = srv_base.step(w)
        jax.block_until_ready(pred)            # single end-of-stream sync
        t_base = min(t_base, time.perf_counter() - t0)
        for k in chunks:
            srv, cs = chunk_srvs[k]
            srv.reset()
            t0 = time.perf_counter()
            for c in cs:
                pred, _ = srv.step_chunk(c)
            jax.block_until_ready(pred)
            t_chunk[k] = min(t_chunk[k], time.perf_counter() - t0)
    n_win = len(ws)
    c_rows = []
    for k in chunks:
        # dead-window correction: the ragged final chunk is padded to K
        # with all-invalid windows that serve zero packets but still run
        # a full scan iteration each. The per-window baseline serves only
        # the n_win live windows, so charging the chunked path for its
        # pads distorts the speedup exactly where windows are few — the
        # --quick gate regime (53 windows at K=16 -> 21% dead work).
        # Scaling the wall by the live fraction makes quick and full
        # measure the same quantity: time per *live* window.
        n_total = len(chunk_srvs[k][1]) * k
        live_frac = n_win / n_total
        best = t_chunk[k] * live_frac
        c_rows.append({
            "window": w_size,
            "chunk_windows": k,
            "n_packets": trace.n_packets,
            "n_chunks": len(chunk_srvs[k][1]),
            "dead_window_frac": round(1.0 - live_frac, 4),
            "wall_s": round(best, 4),
            "pkts_per_s": round(trace.n_packets / best, 1),
            "us_per_window": round(best / n_win * 1e6, 1),
            "baseline_pkts_per_s": round(trace.n_packets / t_base, 1),
            "speedup_vs_per_window": round(t_base / best, 2),
            "backend_invocations": chunk_stats[k].n_flushes,
            "bit_consistent": True,
        })
    print_table("Device-resident chunked megastep — packets/sec vs chunk "
                f"size (window={w_size})",
                ["chunk", "pkts", "chunks", "wall_s", "pkts/s", "us/window",
                 "speedup", "backend_invocations"],
                [[r["chunk_windows"], r["n_packets"], r["n_chunks"],
                  r["wall_s"], r["pkts_per_s"], r["us_per_window"],
                  r["speedup_vs_per_window"], r["backend_invocations"]]
                 for r in c_rows])

    # acceptance: the chunked megastep must beat the per-window baseline
    # >= 3x at the smallest window (a chunked path that only matches it
    # is paying the scan for nothing). The dead-window correction above
    # removes the pad-inflation that used to force a lowered --quick
    # gate, so quick and full runs share the same bar.
    best_speedup = max(r["speedup_vs_per_window"] for r in c_rows)
    assert best_speedup >= min_speedup, (
        f"chunked serving at window={w_size}: best speedup {best_speedup}x "
        f"vs per-window baseline — expected >= {min_speedup}x")

    wall = round(time.time() - t_suite, 3)
    benches = [{"name": "stream_serving",
                "paper_ref": "§5 challenge (ii) / pForest",
                "ok": True, "rows": rows, "wall_s": wall},
               {"name": "stream_chunked",
                "paper_ref": "§5 challenge (ii) / pForest",
                "ok": True, "rows": c_rows, "wall_s": wall}]
    if out:
        write_bench_json(out, "stream", benches,
                         config={"n_flows": n_flows, "windows": list(windows),
                                 "chunks": list(chunks),
                                 "n_buckets": n_buckets,
                                 "threshold": threshold,
                                 "capacity": capacity, "repeats": repeats})
    return rows + c_rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--out", default="BENCH_stream.json")
    args = ap.parse_args(argv)
    if args.quick:
        # same 3x gate as the full run: dead-pad windows no longer count
        # against the chunked path (see the live-fraction correction), and
        # k=64 (one chunk, one dispatch) is kept — it is where the scan's
        # amortization actually clears the bar on a short trace
        run(n_flows=1200, windows=(256, 1024), chunks=(4, 16, 64),
            repeats=2, out=args.out)
    else:
        run(out=args.out)


if __name__ == "__main__":
    main()
