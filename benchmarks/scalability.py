"""Table 3 analog: small/medium/large switch ensembles vs the full
backend, switch-only and hybrid (tau = 0.7) ML performance.

Anomaly: Random Forest (paper: RF most suitable — low variance).
Finance: XGBoost (paper: boosting controls bias for the minority class).
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import load_usecase, print_table
from repro.core.hybrid import hybrid_predict
from repro.core.inference import table_predict
from repro.core.mapping import map_tree_ensemble
from repro.ml.metrics import accuracy, precision_recall_f1
from repro.ml.trees import (fit_random_forest, fit_xgboost,
                            predict_margin_xgboost, predict_tree_ensemble)

SIZES = {"Small": dict(features=4, n_trees=6, max_depth=4),
         "Medium": dict(features=5, n_trees=10, max_depth=5),
         "Large": dict(features=6, n_trees=14, max_depth=6)}


def _metrics(y, pred):
    acc = accuracy(y, pred)
    p, r, f1 = precision_recall_f1(y, pred)
    return acc, p, r, f1


def run(n=20000, seed=0, threshold=0.7):
    out = {}
    for use_case, kind in (("anomaly", "rf"), ("finance", "xgb")):
        if use_case == "anomaly":
            from repro.data.unsw_like import make_unsw_like, train_test_split
            x, y = make_unsw_like(n, seed=seed, n_features=10)
            xtr, ytr, xte, yte = train_test_split(x, y)
            if kind == "rf":
                backend_model = fit_random_forest(
                    xtr, ytr, n_classes=2, n_trees=40, max_depth=8,
                    seed=seed + 1, max_features=10)
                backend_fn = lambda xx: predict_tree_ensemble(
                    backend_model, xx)
        else:
            from repro.data.janestreet_like import (make_janestreet_like,
                                                    train_test_split)
            x, y = make_janestreet_like(n, seed=seed)
            xtr, ytr, xte, yte = train_test_split(x, y)
            backend_model = fit_xgboost(xtr, ytr, n_trees=60, max_depth=8)
            backend_fn = lambda xx: (predict_margin_xgboost(
                backend_model, xx) > 0).astype(jnp.int32)

        bacc, bp, br, bf1 = _metrics(yte, backend_fn(xte))
        rows = []
        for size, hp in SIZES.items():
            f = hp["features"]
            if use_case == "finance":
                from repro.data.janestreet_like import SWITCH_FEATURES
                cols = (SWITCH_FEATURES + [7])[:f]
            else:
                cols = list(range(f))
            xs_tr, xs_te = xtr[:, cols], xte[:, cols]
            if kind == "rf":
                sw = fit_random_forest(xs_tr, ytr, n_classes=2,
                                       n_trees=hp["n_trees"],
                                       max_depth=hp["max_depth"], seed=seed)
            else:
                # coarse bins + gamma pruning keep decision tables feasible
                # (paper §4.2 / §7.8: prune & bin to fit the pipeline)
                sw = fit_xgboost(xs_tr, ytr, n_trees=hp["n_trees"],
                                 max_depth=hp["max_depth"], n_bins=16,
                                 gamma=0.2)
            art = map_tree_ensemble(sw, f, max_decision_entries=8_000_000)
            pred, conf = table_predict(art, xs_te)
            acc, p, r, f1 = _metrics(yte, pred)

            hy = hybrid_predict(
                art, lambda _rows, c=cols: backend_fn(xte), xs_te, threshold)
            hacc, _, _, hf1 = _metrics(yte, hy.pred)
            rows.append([size, f, hp["n_trees"], hp["max_depth"],
                         f"{acc:.4f}", f"{p:.4f}", f"{r:.4f}", f"{f1:.4f}",
                         f"{hacc:.4f}", f"{hf1:.4f}",
                         f"{float(hy.fraction_handled):.3f}"])
        rows.append(["Backend", xtr.shape[1],
                     200 if kind == "rf" else 100, "-",
                     f"{bacc:.4f}", f"{bp:.4f}", f"{br:.4f}", f"{bf1:.4f}",
                     "-", "-", "-"])
        print_table(
            f"Table 3 — {use_case} ({kind.upper()}), confidence {threshold}",
            ["size", "feat", "trees", "depth", "acc", "prec", "recall",
             "F1", "hybrid_acc", "hybrid_F1", "frac_switch"], rows)
        out[use_case] = rows
    return out


if __name__ == "__main__":
    run()
