"""Observability overhead + drift-monitor gates for the serving loop.

``python -m benchmarks.obs_bench`` measures what the observability layer
(DESIGN.md §14) costs and proves what it must never change:

* **bit-identity oracle** — a server built with an ``Observability``
  (events to a JSON-lines sink, metric rollups every ``rollup_every``
  chunks, drift monitors on, the default ``sync_every=0``) must return
  predictions bit-identical to an obs-free server on the same replay,
  on BOTH the chunked and the per-window serving paths. Telemetry that
  changes the answer is a bug, not a feature.
* **overhead gate** — obs-on zero-sync throughput must stay >=
  ``obs_floor`` (default 0.9x) of obs-off. The hooks are host-side and
  the device stats are read once per ``rollup_every`` dispatches, so
  the budget is generous; regressing it means an accidental sync crept
  into the hot loop.
* **event-log schema** — the emitted JSON-lines file must pass
  ``validate_event_log`` (schema v1, known kinds, strictly increasing
  seq) — the log is an interchange format, not debug prints.
* **drift gates** — on a stationary trace the monitors stay silent; on
  a synthetic class-mix-shift trace (benign opening segment, then an
  anomaly-heavy segment appended after it) the ``class_mix_shift``
  detector must fire. A drift monitor that cries wolf — or sleeps
  through an attack onset — fails the bench.

Results go to ``BENCH_obs.json`` (schema "bench-v1", DESIGN.md §11);
``validate_schema.py`` additionally pins the row keys below.
"""

from __future__ import annotations

import argparse
import dataclasses
import os
import time

import numpy as np

from benchmarks.common import print_table, trace_models, write_bench_json
from repro.netsim.ingest import replay_source
from repro.netsim.packets import synth_trace
from repro.netsim.scenarios import merge_traces
from repro.obs import DriftConfig, Observability, validate_event_log
from repro.serving.stream_serving import StreamingHybridServer


def shift_trace(n_flows=1200, seed=0, benign_frac=0.02, shifted_frac=0.9):
    """Benign opening segment, then an anomaly-heavy segment strictly
    after it: the class mix flips mid-stream (attack onset)."""
    a = synth_trace(n_flows=n_flows, anomaly_frac=benign_frac, seed=seed)
    b = synth_trace(n_flows=n_flows, anomaly_frac=shifted_frac,
                    seed=seed + 1)
    b = dataclasses.replace(b, ts=b.ts + float(a.ts.max()) + 1.0)
    return merge_traces(a, b)


def _serve_wall(srv, trace, batch, *, repeats):
    """min-over-reps zero-sync serve_stream wall time (warm server)."""
    best, preds = float("inf"), None
    for _ in range(repeats):
        srv.reset()
        t0 = time.perf_counter()
        preds, _ = srv.serve_stream(replay_source(trace, batch=batch))
        best = min(best, time.perf_counter() - t0)
    return best, np.asarray(preds)


def _overhead_rows(art, backend, trace, kw, *, chunk_windows, flush_every,
                   rollup_every, repeats, obs_floor, events_path):
    """One serving path's obs-off vs obs-on pair, oracle-gated."""
    path = "chunked" if chunk_windows else "per_window"
    skw = dict(kw, chunk_windows=chunk_windows, flush_every=flush_every)
    batch = max(1, (chunk_windows or 1) * kw["window"])

    ref = StreamingHybridServer(art, backend, **skw)
    obs = Observability(events_path=events_path,
                        rollup_every=rollup_every)
    srv = StreamingHybridServer(art, backend, obs=obs, **skw)
    # warm both (compile), then interleave reps so host noise hits the
    # off and on timings alike
    _serve_wall(ref, trace, batch, repeats=1)
    _serve_wall(srv, trace, batch, repeats=1)
    t_off = t_on = float("inf")
    for _ in range(max(repeats, 2)):
        w_off, p_off = _serve_wall(ref, trace, batch, repeats=1)
        w_on, p_on = _serve_wall(srv, trace, batch, repeats=1)
        t_off, t_on = min(t_off, w_off), min(t_on, w_on)
    obs.close()

    np.testing.assert_array_equal(p_on, p_off)     # the oracle
    n_events = validate_event_log(events_path)
    assert n_events > 0, "obs-on run emitted no events"
    assert obs.rollups.n_rows > 0, "obs-on run closed no rollup windows"

    ratio = t_off / t_on
    assert ratio >= obs_floor, (
        f"{path}: obs-on throughput {ratio:.3f}x of obs-off "
        f"(floor {obs_floor}x)")
    mk = lambda label, wall, on: {
        "config": f"{path}_{label}", "path": path, "obs_on": on,
        "n_packets": trace.n_packets,
        "pkts_per_s": round(trace.n_packets / wall, 1),
        "wall_s": round(wall, 4),
        "events": n_events if on else 0,
        "rollups": obs.rollups.n_rows if on else 0,
        "throughput_ratio": round(ratio, 3) if on else 1.0,
        "bit_identical": True,
    }
    return [mk("obs_off", t_off, False), mk("obs_on", t_on, True)], ratio


def _drift_row(art, backend, trace, kw, *, scenario, chunk_windows,
               expect_fired):
    """Serve one trace with the drift monitors on; gate what fired.

    rollup_every=1 (one window per chunk) so the baseline freezes well
    inside the benign opening segment and the shifted segment spans
    several detection windows. mix_l1=0.1: the *predicted* mix moves
    less than the true label mix (the switch model recognizes only part
    of the new traffic), so the bench threshold sits ~2x below the
    shifted windows' observed distance and ~3x above stationary
    window-to-window noise."""
    obs = Observability(rollup_every=1,
                        drift=DriftConfig(baseline_windows=2, mix_l1=0.1))
    srv = StreamingHybridServer(art, backend, chunk_windows=chunk_windows,
                                obs=obs, **kw)
    srv.serve_trace(trace)
    fired = obs.drift.fired_detectors
    alarms = [a.as_fields() for a in obs.alarms]
    if expect_fired:
        assert "class_mix_shift" in fired, (
            f"{scenario}: class_mix_shift did not fire "
            f"(fired={fired}, rollups={obs.rollups.n_rows})")
    else:
        assert not fired, f"{scenario}: spurious drift alarms: {alarms}"
    return {
        "scenario": scenario, "n_packets": trace.n_packets,
        "rollups": obs.rollups.n_rows, "fired": bool(fired),
        "detectors": list(fired), "n_alarms": len(alarms),
        "expected_fired": expect_fired,
    }


def run(n_flows=3000, window=256, chunk_windows=8, n_buckets=1 << 13,
        threshold=0.9, capacity=64, flush_every=4, rollup_every=4,
        repeats=3, seed=0, obs_floor=0.9, out="BENCH_obs.json",
        events_path="BENCH_obs_events.jsonl"):
    t_suite = time.time()
    trace = synth_trace(n_flows=n_flows, seed=seed)
    art, backend = trace_models(trace, n_buckets)
    kw = dict(n_buckets=n_buckets, window=window, threshold=threshold,
              capacity=capacity)

    # -- overhead + bit-identity, both serving paths --------------------
    rows, ratios = [], {}
    for label, ck, fe in (("chunked", chunk_windows, 1),
                          ("per_window", None, flush_every)):
        path_rows, ratio = _overhead_rows(
            art, backend, trace, kw, chunk_windows=ck, flush_every=fe,
            rollup_every=rollup_every, repeats=repeats,
            obs_floor=obs_floor, events_path=events_path)
        rows += path_rows
        ratios[label] = ratio
    print_table(f"Observability overhead (rollup_every={rollup_every}, "
                f"sync_every=0)",
                ["config", "pkts/s", "ratio", "events", "rollups"],
                [[r["config"], r["pkts_per_s"], r["throughput_ratio"],
                  r["events"], r["rollups"]] for r in rows])
    for label, ratio in ratios.items():
        print(f"{label}: obs-on {ratio:.3f}x of obs-off "
              f"(floor {obs_floor}x), bit-identical")

    # -- drift monitors: silent when stationary, loud on a mix shift ----
    half = max(400, n_flows // 3)
    drift_rows = [
        _drift_row(art, backend,
                   synth_trace(n_flows=2 * half, anomaly_frac=0.02,
                               seed=seed + 7),
                   kw, scenario="stationary",
                   chunk_windows=chunk_windows, expect_fired=False),
        _drift_row(art, backend, shift_trace(n_flows=half, seed=seed + 7),
                   kw, scenario="class_mix_shift",
                   chunk_windows=chunk_windows, expect_fired=True),
    ]
    print_table("Drift monitors",
                ["scenario", "rollups", "fired", "detectors"],
                [[r["scenario"], r["rollups"], r["fired"],
                  ",".join(r["detectors"]) or "-"] for r in drift_rows])

    wall = round(time.time() - t_suite, 3)
    benches = [
        {"name": "obs_overhead", "paper_ref": "§5 switch-tier economics "
         "(telemetry must not erode them)", "ok": True, "rows": rows,
         "wall_s": wall},
        {"name": "drift_monitors", "paper_ref": "pForest phase-aware "
         "retraining triggers (ROADMAP item 1)", "ok": True,
         "rows": drift_rows, "wall_s": wall},
    ]
    if out:
        write_bench_json(out, "obs", benches,
                         config={"n_flows": n_flows, "window": window,
                                 "chunk_windows": chunk_windows,
                                 "n_buckets": n_buckets,
                                 "threshold": threshold,
                                 "capacity": capacity,
                                 "flush_every": flush_every,
                                 "rollup_every": rollup_every,
                                 "repeats": repeats,
                                 "obs_floor": obs_floor})
    if os.path.exists(events_path):
        print(f"[event log: {events_path}]")
    return rows + drift_rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--out", default="BENCH_obs.json")
    args = ap.parse_args(argv)
    if args.quick:
        # short trace, fewer repeats; same oracles and gates
        run(n_flows=1000, chunk_windows=4, flush_every=2, repeats=2,
            out=args.out)
    else:
        run(out=args.out)


if __name__ == "__main__":
    main()
