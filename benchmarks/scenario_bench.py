"""Adversarial scenario benchmark: serving robustness under attack + faults.

``python -m benchmarks.scenario_bench`` drives the streaming hybrid
server over the ``netsim.scenarios`` adversarial traces — DDoS floods of
single-use flows, crafted bucket-collision storms, slow-loris long-idle
probes, elephant/mice skew — crossed with backend fault profiles injected
through ``serving.faults.FaultyBackend`` under a ``FaultPolicy`` guard.
Each (scenario × fault profile) cell records accuracy against per-packet
ground truth, sustained packets/sec, and the robustness telemetry the
tentpole added: eviction churn, deferral, degraded (switch-only) rows,
and the guard's retry/breaker counters.

Two oracles gate the numbers:

* zero-fault bit-identity — for every scenario, the policy-guarded
  server with no faults injected must reproduce the unguarded server's
  predictions bit for bit (the two-phase degradation machinery must be
  invisible when nothing fails);
* the ``StreamStats`` accounting invariant (``handled + backend_rows +
  deferred + degraded == packets``) is asserted by ``serve_trace`` on
  every run.

The eviction-policy dimension contrasts the timeout sweep against the
pForest-style approx-LRU sweep on the same trace: under a flood,
approx-LRU should evict only under occupancy pressure (and prefer the
dead single-use flows), where the timeout sweep churns on age alone;
under slow-loris its pressure trigger should spare the idle-but-live
probes a timeout sweep forgets.

Results go to ``BENCH_scenarios.json`` (schema "bench-v1").
"""

from __future__ import annotations

import argparse
import time

import numpy as np

from benchmarks.common import print_table, trace_models, \
    write_bench_json
from repro.netsim.scenarios import make_scenario
from repro.serving.faults import FaultPolicy, FaultStats, FaultyBackend
from repro.serving.stream_serving import StreamingHybridServer

# fault profiles: kwargs for FaultyBackend (None = unguarded reference)
FAULT_PROFILES = {
    "none": None,
    "flaky20": dict(error_rate=0.2, seed=42),
    "outage": dict(outages=range(2, 6), seed=7),
}

POLICY = FaultPolicy(max_retries=1, backoff_base_s=0.0,
                     breaker_threshold=3, breaker_cooldown=4)


def _serve(art, backend, trace, *, repeats, **kw):
    """Serve the trace, return (preds, stats, server, best wall_s)."""
    srv = StreamingHybridServer(art, backend, **kw)
    preds, stats = srv.serve_trace(trace)          # warm + oracle pass
    best = float("inf")
    for _ in range(repeats):
        srv.reset()
        _reset_injection(backend)
        t0 = time.perf_counter()
        preds, stats = srv.serve_trace(trace)
        best = min(best, time.perf_counter() - t0)
    return np.asarray(preds), stats, srv, best


def _reset_injection(backend):
    """Replay the identical fault sequence every repeat: the injected
    faults are a pure function of (seed, call index)."""
    if isinstance(backend, FaultyBackend):
        backend.reset()


def run(*, scale=1.0, n_buckets=4096, window=256, capacity=64,
        threshold=0.9, evict_age=5.0, repeats=2,
        profiles=("none", "flaky20", "outage"),
        out="BENCH_scenarios.json"):
    t_suite = time.time()
    s = lambda n: max(1, int(n * scale))
    scenario_kw = {
        "ddos_flood": dict(n_background=s(400), n_attack=s(3000)),
        "collision_storm": dict(n_background=s(400), n_attack=s(2000),
                                n_buckets=n_buckets, n_target_buckets=4),
        "slow_loris": dict(n_background=s(400), n_slow=s(64), n_probes=6,
                           idle_gap=4 * evict_age),
        "elephant_mice": dict(n_mice=s(1000), n_elephants=8,
                              elephant_pkts=s(2000)),
    }
    kw = dict(n_buckets=n_buckets, window=window, capacity=capacity,
              threshold=threshold, evict_age=evict_age)
    rows = []
    for name, skw in scenario_kw.items():
        trace = make_scenario(name, seed=0, **skw)
        truth = np.asarray(trace.flow_label)[np.asarray(trace.flow_id)]
        art, backend = trace_models(trace, n_buckets)

        # unguarded reference + the zero-fault bit-identity oracle: the
        # guarded server with no faults must be invisible
        ref, _, _, _ = _serve(art, backend, trace, repeats=0, **kw)
        for profile in profiles:
            fkw = FAULT_PROFILES[profile]
            be = backend if fkw is None else FaultyBackend(backend, **fkw)
            preds, stats, srv, best = _serve(
                art, be, trace, repeats=repeats, fault_policy=POLICY, **kw)
            if fkw is None:
                np.testing.assert_array_equal(preds, ref)   # the oracle
            g = srv.fault_stats
            rows.append({
                "scenario": name, "fault_profile": profile,
                "evict_policy": "timeout",
                "n_packets": trace.n_packets,
                "wall_s": round(best, 4),
                "pkts_per_s": round(trace.n_packets / best, 1),
                "accuracy": round(float((preds == truth).mean()), 4),
                "fraction_handled": round(stats.fraction_handled, 4),
                "backend_rows": stats.total_backend_rows,
                "deferred": stats.n_deferred,
                "degraded": stats.n_degraded,
                "evicted": stats.n_evicted,
                "overflow": stats.n_overflow,
                "flushes": stats.n_flushes,
                "flushes_failed": g.flushes_failed,
                "retries": g.retries,
                "rejected": g.rejected,
                "breaker_opens": g.breaker_opens,
                "fault_stats": g.as_dict(),
                "zero_fault_bit_identical": fkw is None,
            })

        # eviction-policy contrast on the clean profile: the adaptive
        # defense the adversarial workloads justify
        preds, stats, srv, best = _serve(
            art, backend, trace, repeats=repeats, evict_policy="approx_lru",
            lru_occupancy=0.75, **kw)
        rows.append({
            "scenario": name, "fault_profile": "none",
            "evict_policy": "approx_lru",
            "n_packets": trace.n_packets,
            "wall_s": round(best, 4),
            "pkts_per_s": round(trace.n_packets / best, 1),
            "accuracy": round(float((preds == truth).mean()), 4),
            "fraction_handled": round(stats.fraction_handled, 4),
            "backend_rows": stats.total_backend_rows,
            "deferred": stats.n_deferred,
            "degraded": stats.n_degraded,
            "evicted": stats.n_evicted,
            "overflow": stats.n_overflow,
            "flushes": stats.n_flushes,
            "flushes_failed": 0, "retries": 0, "rejected": 0,
            "breaker_opens": 0,
            # unguarded run: no GuardedBackend, so an all-zero snapshot
            # keeps the row shape uniform with the fault-profile rows
            "fault_stats": FaultStats().as_dict(),
            "zero_fault_bit_identical": False,
        })

    # the injected-outage profile must actually exercise degradation — a
    # run whose faults never fire validates nothing (the flaky profile is
    # probabilistic: 2 attempts at 20% error is a 4% flush failure rate,
    # which a short quick trace can legitimately dodge; the outage
    # profile hard-fails flush calls 2..5 deterministically)
    if "outage" in profiles:
        assert any(r["fault_profile"] == "outage" and r["degraded"] > 0
                   for r in rows), \
            "outage profile produced no degraded rows anywhere"

    print_table(
        "Adversarial scenarios — accuracy / throughput / robustness",
        ["scenario", "faults", "evict", "pkts", "pkts/s", "acc",
         "degraded", "evicted", "deferred", "breaker_opens"],
        [[r["scenario"], r["fault_profile"], r["evict_policy"],
          r["n_packets"], r["pkts_per_s"], r["accuracy"], r["degraded"],
          r["evicted"], r["deferred"], r["breaker_opens"]] for r in rows])

    wall = round(time.time() - t_suite, 3)
    benches = [{"name": "adversarial_scenarios",
                "paper_ref": "pForest / Towards Practical & Usable "
                             "In-network Classification",
                "ok": True, "rows": rows, "wall_s": wall}]
    if out:
        write_bench_json(out, "scenarios", benches,
                         config={"scale": scale, "n_buckets": n_buckets,
                                 "window": window, "capacity": capacity,
                                 "threshold": threshold,
                                 "evict_age": evict_age,
                                 "profiles": list(profiles),
                                 "repeats": repeats})
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--out", default="BENCH_scenarios.json")
    args = ap.parse_args(argv)
    if args.quick:
        # small traces; the outage profile keeps the degradation path
        # exercised deterministically even on the shortest traces
        run(scale=0.2, n_buckets=1024, repeats=1,
            profiles=("none", "flaky20", "outage"), out=args.out)
    else:
        run(out=args.out)


if __name__ == "__main__":
    main()
