"""Kernel microbenchmarks: fused single-matmul pipeline vs baselines.

``python -m benchmarks.kernel_microbench`` times, on the default backend:

  * the fused single-matmul ensemble kernel (one blocked one-hot MXU pass
    for the whole feature-table walk + one match-contraction for the
    decision walk) against ``ensemble_lookup_pallas_loop`` — the previous
    per-feature-loop formulation, kept exactly for this comparison;
  * the autotuned tile configuration against the defaults;
  * the fused classical kernel and bucketize against the XLA references.

Every timed pair is first checked bit-identical against the kernels/ref.py
oracle — a speedup from wrong answers is not a speedup.

Results go to ``BENCH_kernels.json`` (schema "bench-v1", see DESIGN.md §11)
next to the printed table. The headline configuration is the paper's
feature-scaling regime (wide, shallow forests — Figs 4-5): many feature
tables, switch-sized decision tables, where the table walk dominates and
fusing it into one matmul pays the most.
"""

from __future__ import annotations

import argparse
import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import print_table, write_bench_json
from repro.core.mapping import map_tree_ensemble
from repro.kernels import classical_lookup as ck
from repro.kernels import ensemble_lookup as ek
from repro.kernels import ref
from repro.kernels.ops import bucketize, fused_classify
from repro.kernels.tuning import DEFAULT_TILES, autotune_tiles
from repro.ml.trees import fit_random_forest, fit_xgboost


def _bench(fn, iters):
    """Min over individual calls — robust to machine-load spikes."""
    fn().block_until_ready()                    # compile + warm
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        fn().block_until_ready()
        best = min(best, time.perf_counter() - t0)
    return best


def _tile_batch(x, batch):
    x = np.asarray(x, np.float32)
    reps = batch // len(x) + 1
    return jnp.asarray(np.tile(x, (reps, 1))[:batch])


def _ensemble_cases(n, seed):
    """(name, artifact, eval rows) triples spanning the shape regimes."""
    from repro.data.janestreet_like import make_janestreet_like, \
        train_test_split as js_split
    from repro.data.unsw_like import make_unsw_like, \
        train_test_split as un_split

    xj, yj = make_janestreet_like(n, seed=seed)
    xjtr, yjtr, xjte, _ = js_split(xj, yj)
    xu, yu = make_unsw_like(n, seed=seed, n_features=5)
    xutr, yutr, xute, _ = un_split(xu, yu)

    cases = []
    wide = fit_random_forest(xjtr[:, :32], yjtr, n_classes=2, n_trees=32,
                             max_depth=3, seed=seed, max_features=3)
    cases.append(("rf_wide_f32_t32_d3", map_tree_ensemble(wide, 32),
                  xjte[:, :32]))
    narrow = fit_random_forest(xutr, yutr, n_classes=2, n_trees=10,
                               max_depth=5, seed=seed)
    cases.append(("rf_narrow_f5_t10_d5", map_tree_ensemble(narrow, 5), xute))
    xgb = fit_xgboost(xutr, yutr, n_trees=10, max_depth=4)
    cases.append(("xgb_sum_f5_t10_d4", map_tree_ensemble(xgb, 5), xute))
    return cases


def run(n=20000, seed=0, batches=(1024, 8192), iters=20,
        out="BENCH_kernels.json"):
    rows = []
    bench_entries = []
    t_suite = time.time()

    for name, art, xte in _ensemble_cases(min(n, 8000), seed):
        vote = art.agg == "vote"
        dtable = (art.dtable_class if vote
                  else art.dtable_value.q).astype(jnp.float32)
        tiles = autotune_tiles(art, batch=max(batches))
        for batch in batches:
            xb = _tile_batch(xte, batch)

            # bit-exactness first: fused output vs the gather oracle
            expect = ref.ensemble_lookup_ref(
                xb, art.edges, art.ftable, art.strides, dtable,
                n_classes=art.n_classes, vote=vote)
            got = ek.ensemble_lookup_fused(
                xb, art.edges, art.ftable_flat, art.dtable_flat,
                art.dtable_pad)
            np.testing.assert_array_equal(np.asarray(got), np.asarray(expect))

            loop_fn = jax.jit(functools.partial(
                ek.ensemble_lookup_pallas_loop, n_classes=art.n_classes,
                vote=vote))
            fused_fn = jax.jit(ek.ensemble_lookup_fused)

            t_loop = _bench(lambda: loop_fn(
                xb, art.edges, art.ftable, art.strides, dtable), iters)
            t_fused = _bench(lambda: fused_fn(
                xb, art.edges, art.ftable_flat, art.dtable_flat,
                art.dtable_pad), iters)
            # time the realization the tuner actually picked — it may be
            # the loop kernel or the XLA reference (tuning.candidate_tiles
            # includes both, so a shape where fused loses tunes *away*
            # from it instead of to the least-bad fused config)
            if tiles.impl == "loop":
                t_tuned = t_loop      # identical fn+args timed just above
            elif tiles.impl == "ref":
                ref_fn = jax.jit(functools.partial(
                    ref.ensemble_lookup_ref, n_classes=art.n_classes,
                    vote=vote))
                t_tuned = _bench(lambda: ref_fn(
                    xb, art.edges, art.ftable, art.strides, dtable), iters)
            else:
                tuned_fn = jax.jit(functools.partial(
                    ek.ensemble_lookup_fused, tile_n=tiles.tile_n,
                    edge_chunk=tiles.edge_chunk,
                    dtable_chunk=tiles.dtable_chunk, select=tiles.select))
                t_tuned = _bench(lambda: tuned_fn(
                    xb, art.edges, art.ftable_flat, art.dtable_flat,
                    art.dtable_pad), iters)

            best = min(t_fused, t_tuned)
            rows.append({
                "kernel": "ensemble_lookup", "case": name, "batch": batch,
                "loop_ms": round(t_loop * 1e3, 3),
                "fused_ms": round(t_fused * 1e3, 3),
                "fused_tuned_ms": round(t_tuned * 1e3, 3),
                "speedup": round(t_loop / best, 3),
                "tiles": {"tile_n": tiles.tile_n,
                          "edge_chunk": tiles.edge_chunk,
                          "dtable_chunk": tiles.dtable_chunk,
                          "select": tiles.select,
                          "impl": tiles.impl},
                "bit_exact": True,
            })

    # classical + bucketize context rows (vs XLA reference realization)
    from benchmarks.common import fit_and_map, load_usecase
    xtr, ytr, xte, _ = load_usecase("anomaly", n=min(n, 8000), seed=seed)
    _, art_svm, _ = fit_and_map("SVM", xtr, ytr)
    for batch in batches:
        xb = _tile_batch(xte, batch)
        m = art_svm.vtable.q.shape[2]
        expect = ref.classical_lookup_ref(
            xb, art_svm.edges, art_svm.vtable.q.astype(jnp.float32))
        got = ck.classical_lookup_fused(
            xb, art_svm.edges, art_svm.vtable_flat)[:, :m]
        np.testing.assert_array_equal(np.asarray(got), np.asarray(expect))
        fused_fn = jax.jit(ck.classical_lookup_fused)
        ref_fn = jax.jit(ref.classical_lookup_ref)
        t_fused = _bench(lambda: fused_fn(
            xb, art_svm.edges, art_svm.vtable_flat), iters)
        t_ref = _bench(lambda: ref_fn(
            xb, art_svm.edges, art_svm.vtable.q.astype(jnp.float32)), iters)
        rows.append({
            "kernel": "classical_lookup", "case": "svm_f5", "batch": batch,
            "loop_ms": None, "fused_ms": round(t_fused * 1e3, 3),
            "fused_tuned_ms": None,
            "speedup": round(t_ref / t_fused, 3),
            "tiles": None, "bit_exact": True,
            "baseline": "xla_ref",
        })

    headers = ["kernel", "case", "batch", "loop_ms", "fused_ms",
               "tuned_ms", "speedup"]
    print_table("Kernel microbench — fused single-matmul vs per-feature loop",
                headers,
                [[r["kernel"], r["case"], r["batch"], r["loop_ms"],
                  r["fused_ms"], r["fused_tuned_ms"], r["speedup"]]
                 for r in rows])

    bench_entries.append({
        "name": "kernel_microbench", "paper_ref": "Fig 8 / Figs 4-5",
        "ok": True, "rows": rows,
        "wall_s": round(time.time() - t_suite, 3),
    })
    if out:
        write_bench_json(out, "kernels", bench_entries,
                         config={"n": n, "iters": iters,
                                 "batches": list(batches)})
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--out", default="BENCH_kernels.json")
    args = ap.parse_args(argv)
    run(n=6000 if args.quick else 20000,
        iters=10 if args.quick else 20, out=args.out)


if __name__ == "__main__":
    main()
