"""Fig 9 analog: calculation error + misclassification vs action-data bits.

For SVM / Bayes / K-Means, sweep the quantization width of the table
payloads and report (i) the relative calculation error of the summed
quantity (hyperplane value / log joint / squared distance) against the
f32 direct computation, and (ii) the induced misclassification rate vs
the unquantized table pipeline. Paper: errors < 0.001 % at 16 bits, NB
worst (probability products) — our log-domain NB removes the underflow
mode, which the record shows.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import fit_and_map, load_usecase, print_table
from repro.core.inference import feature_bins, table_predict
from repro.ml.kmeans import kmeans_sq_dists
from repro.ml.naive_bayes import nb_log_likelihood
from repro.ml.svm import svm_decision_values


def _table_sum(art, x):
    bins = feature_bins(art.edges, jnp.asarray(x, jnp.float32))
    f_idx = jnp.arange(art.n_features)[None, :]
    vals_q = art.vtable.q[f_idx, bins]
    return vals_q.sum(axis=1).astype(jnp.float32) / art.vtable.scale


def run(n=16000, seed=0):
    xtr, ytr, xte, yte = load_usecase("anomaly", n=n, seed=seed)
    rows = []
    for model, direct_vals in (
            ("SVM", None), ("Bayes", None), ("KMeans", None)):
        for bits in (8, 12, 16, 24):
            direct, art, m = fit_and_map(model, xtr, ytr, action_bits=bits)
            tab = _table_sum(art, xte)
            if model == "SVM":
                ref = svm_decision_values(m, xte) - art.consts[None, :]
            elif model == "Bayes":
                ref = nb_log_likelihood(m, xte) - art.consts[None, :]
            else:
                ref = kmeans_sq_dists(m, xte)
            rel = float(jnp.mean(jnp.abs(tab - ref)
                                 / jnp.maximum(jnp.abs(ref), 1e-9)))
            # misclassification vs the 24-bit table (quantization-only)
            p_q, _ = table_predict(art, xte)
            _, art24, _ = fit_and_map(model, xtr, ytr, action_bits=24)
            p_24, _ = table_predict(art24, xte)
            mis = float(jnp.mean((p_q != p_24).astype(jnp.float32)))
            rows.append([model, bits, f"{rel:.2e}", f"{mis * 100:.4f}%"])
    print_table("Fig 9 — calc error & misclassification vs action bits",
                ["model", "bits", "rel_calc_err", "misclass_vs_24b"], rows)
    return rows


if __name__ == "__main__":
    run()
