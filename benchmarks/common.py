"""Shared benchmark plumbing: use-case data, model zoo, table printing,
and the machine-readable BENCH_*.json emission (schema "bench-v1")."""

from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

# Persistent compilation cache for every bench entry point importing this
# module: the quick CI suites are compile-dominated (tens of seconds of
# XLA work for seconds of compute), and the jitted steps are identical
# run to run — cached executables cut reruns to the actual measurement.
# Opt out (or redirect) with JAX_COMPILATION_CACHE_DIR; the thresholds
# are zeroed so even the small CPU executables of --quick runs cache.
_CACHE_DIR = os.environ.get(
    "JAX_COMPILATION_CACHE_DIR",
    os.path.join(os.path.expanduser("~"), ".cache", "jax_repro_bench"))
if _CACHE_DIR:
    jax.config.update("jax_compilation_cache_dir", _CACHE_DIR)
    jax.config.update("jax_persistent_cache_min_compile_time_secs", 0.0)
    jax.config.update("jax_persistent_cache_min_entry_size_bytes", -1)

from repro.core.mapping import (map_kmeans, map_naive_bayes, map_svm,
                                map_tree_ensemble)
from repro.data.janestreet_like import SWITCH_FEATURES
from repro.ml.kmeans import fit_kmeans, predict_kmeans
from repro.ml.metrics import accuracy, precision_recall_f1
from repro.ml.naive_bayes import fit_gaussian_nb, predict_nb
from repro.ml.svm import fit_linear_svm, predict_svm
from repro.ml.trees import (fit_decision_tree, fit_random_forest,
                            fit_xgboost, predict_margin_xgboost,
                            predict_tree_ensemble)

MODELS = ("SVM", "Bayes", "KMeans", "DT", "RF", "XGB")


def load_usecase(name: str, n=20000, seed=0, switch_features=True):
    """-> (xtr, ytr, xte, yte) with the paper's 5 switch features."""
    if name == "anomaly":
        from repro.data.unsw_like import make_unsw_like, train_test_split
        x, y = make_unsw_like(n, seed=seed, n_features=5)
        return train_test_split(x, y)
    from repro.data.janestreet_like import make_janestreet_like, \
        train_test_split
    x, y = make_janestreet_like(n, seed=seed)
    if switch_features:
        x = x[:, SWITCH_FEATURES]
    return train_test_split(x, y)


def fit_and_map(model: str, xtr, ytr, *, n_bins=64, action_bits=16,
                n_trees=10, max_depth=5, seed=0):
    """Train one switch-size model and map it. -> (direct_fn, artifact)."""
    f = xtr.shape[1]
    if model == "SVM":
        m = fit_linear_svm(xtr, ytr, n_classes=2, seed=seed)
        return (lambda x: predict_svm(m, x),
                map_svm(m, xtr, n_bins=n_bins, action_bits=action_bits), m)
    if model == "Bayes":
        m = fit_gaussian_nb(xtr, ytr, n_classes=2)
        return (lambda x: predict_nb(m, x),
                map_naive_bayes(m, xtr, n_bins=n_bins,
                                action_bits=action_bits), m)
    if model == "KMeans":
        m = fit_kmeans(xtr, k=2, seed=seed)
        # align cluster->class by majority vote on train
        assign = np.asarray(predict_kmeans(m, xtr))
        maj = [int(np.round(np.mean(np.asarray(ytr)[assign == c]))
                   if np.any(assign == c) else c) for c in range(2)]
        flip = maj[0] == 1

        def direct(x):
            p = predict_kmeans(m, x)
            return 1 - p if flip else p

        art = map_kmeans(m, xtr, n_bins=n_bins, action_bits=action_bits)
        art.flip = flip
        return (direct, art, m)
    if model == "DT":
        m = fit_decision_tree(xtr, ytr, n_classes=2, max_depth=max_depth)
        return (lambda x: predict_tree_ensemble(m, x),
                map_tree_ensemble(m, f, action_bits=action_bits), m)
    if model == "RF":
        m = fit_random_forest(xtr, ytr, n_classes=2, n_trees=n_trees,
                              max_depth=max_depth, seed=seed)
        return (lambda x: predict_tree_ensemble(m, x),
                map_tree_ensemble(m, f, action_bits=action_bits), m)
    if model == "XGB":
        m = fit_xgboost(xtr, ytr, n_trees=n_trees, max_depth=max_depth)
        return (lambda x: predict_tree_ensemble(m, x),
                map_tree_ensemble(m, f, action_bits=action_bits), m)
    raise ValueError(model)


def table_pred_maybe_flip(art, x):
    from repro.core.inference import table_predict
    pred, conf = table_predict(art, x)
    if getattr(art, "flip", False):
        pred = 1 - pred
    return pred, conf


def trace_models(trace, n_buckets, *, small=(4, 3, 0), big=(16, 6, 1)):
    """Switch-size RF artifact + backend RF for a synthetic packet trace.
    -> (artifact, backend_fn).

    The streaming-bench model recipe (previously copy-pasted across
    stream_bench, shard_stream_bench and scenario_bench): train both
    forests on the trace's own batch flow features — one row per flow,
    read out at the flow's bucket — map the small (n_trees, max_depth,
    seed) forest to the switch table artifact and close the big one over
    ``predict_tree_ensemble`` as the row-wise backend."""
    from repro.netsim.features import flow_features
    b, table = flow_features(trace, n_buckets=n_buckets)
    first_idx = np.unique(np.asarray(trace.flow_id), return_index=True)[1]
    rows = np.asarray(table)[np.asarray(b)[first_idx]].astype(np.float32)
    s_trees, s_depth, s_seed = small
    b_trees, b_depth, b_seed = big
    sm = fit_random_forest(rows, trace.flow_label, n_classes=2,
                           n_trees=s_trees, max_depth=s_depth, seed=s_seed)
    bg = fit_random_forest(rows, trace.flow_label, n_classes=2,
                           n_trees=b_trees, max_depth=b_depth, seed=b_seed)
    return map_tree_ensemble(sm, rows.shape[1]), \
        (lambda r: predict_tree_ensemble(bg, r))


def jsonable(obj):
    """Best-effort conversion of benchmark rows to JSON-safe values."""
    if isinstance(obj, dict):
        return {str(k): jsonable(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [jsonable(v) for v in obj]
    if isinstance(obj, (bool, np.bool_)):
        return bool(obj)
    if isinstance(obj, (np.integer,)):
        return int(obj)
    if isinstance(obj, (np.floating,)):
        return float(obj)
    if isinstance(obj, (np.ndarray, jax.Array)):
        return jsonable(np.asarray(obj).tolist())
    if isinstance(obj, (str, int, float, bool)) or obj is None:
        return obj
    return str(obj)


def write_bench_json(path, suite, benches, config=None):
    """Write one BENCH_*.json file (schema "bench-v1").

    benches: list of dicts with keys name, paper_ref, wall_s, ok, rows —
    rows being whatever the bench's run() returned (tables keep the
    [headers-implied] row-list form the printed tables use). config
    records the run parameters (sample size, subset, iters) so partial
    --quick/--only runs are distinguishable in the trajectory.
    """
    payload = {
        "schema": "bench-v1",
        "suite": suite,
        "generated_unix": time.time(),
        "backend": jax.default_backend(),
        "config": jsonable(config or {}),
        "benches": [jsonable(b) for b in benches],
    }
    tmp = f"{path}.tmp"
    with open(tmp, "w") as f:
        json.dump(payload, f, indent=1)
        f.write("\n")
    os.replace(tmp, path)
    print(f"[wrote {path}]")
    return path


def print_table(title, headers, rows):
    print(f"\n## {title}")
    widths = [max(len(str(h)), max((len(str(r[i])) for r in rows),
                                   default=0)) for i, h in enumerate(headers)]
    line = " | ".join(str(h).ljust(w) for h, w in zip(headers, widths))
    print(line)
    print("-+-".join("-" * w for w in widths))
    for r in rows:
        print(" | ".join(str(c).ljust(w) for c, w in zip(r, widths)))
