"""Device resource-fit bench: per-artifact utilization vs device budgets.

``python -m benchmarks.analysis_bench`` maps every served artifact
family (DT/RF/XGB over the streaming readout layout, plus the anomaly
use-case trace models) against the declarative ``DeviceProfile`` budgets
of ``core.resources`` (Tofino-like and NIC-ish) and emits one
utilization row per (artifact, profile) — the Tables 1-2 analog as a
*gate* rather than a printout, so resource growth shows up in the
``BENCH_analysis.json`` trajectory long before an artifact stops
fitting.

Oracles gating the rows:

* every standard artifact must fit the default (Tofino-like) profile —
  a mapping change that blows a budget fails here, not at deploy;
* ``check_fit`` must *reject* the deliberately paper-scale oversized
  ensemble (the §4 naive-mapping blowup) on every profile;
* the ``finalize_artifact(..., profile=...)`` deploy guard must raise
  ``FitError`` for an artifact that exceeds a tight budget and pass one
  that fits — exercised end to end on the real XGB artifact.

Results go to ``BENCH_analysis.json`` (schema "bench-v1", DESIGN.md §11).
"""

from __future__ import annotations

import argparse
import dataclasses
import time

from benchmarks.common import print_table, write_bench_json
from repro.analysis.fit import fit_rows, oversized_report, standard_artifacts
from repro.core.artifact import finalize_artifact
from repro.core.resources import (DEFAULT_PROFILE, PROFILES, DeviceProfile,
                                  FitError, artifact_resources, check_fit)


def run(out="BENCH_analysis.json"):
    t_suite = time.time()
    rows = fit_rows()

    # oracle 1: every served artifact family fits the default profile
    for row in rows:
        if row["profile"] == DEFAULT_PROFILE.name:
            assert row["fits"], (
                f"{row['artifact']} no longer fits {row['profile']}: {row}")

    # oracle 2: the paper-scale oversized ensemble is rejected everywhere
    over = oversized_report()
    for profile in PROFILES.values():
        rep = check_fit(over, profile)
        assert not rep.fits, (
            f"oversized ensemble fits {profile.name} — budgets are vacuous")
        rows.append({"artifact": "oversized_rf", **rep.row()})

    # oracle 3: the finalize_artifact deploy guard, end to end on real
    # artifacts — a tight profile must abort the load, the default must
    # admit it (strip the cached layout so finalization actually runs)
    xgb = next(art for name, art in standard_artifacts() if name == "xgb")
    raw = dataclasses.replace(xgb, ftable_flat=None, dtable_flat=None,
                              dtable_pad=None)
    xgb_entries = artifact_resources(xgb).entries
    tight = DeviceProfile(name="tight_test", stages=12, sram_kib=1024,
                          tcam_kib=128, max_entries=max(xgb_entries // 2, 1),
                          max_tables=32)
    try:
        finalize_artifact(raw, profile=tight)
        raise AssertionError("deploy guard admitted an artifact over budget")
    except FitError as e:
        guard_msg = str(e)
    finalize_artifact(raw, profile=DEFAULT_PROFILE)
    rows.append({"artifact": "xgb", "profile": tight.name, "fits": False,
                 "guard": "FitError", "detail": guard_msg[:200]})

    cols = ["artifact", "profile", "fits", "util_entries", "util_sram_kib",
            "util_tcam_kib", "util_tables", "util_stages"]
    print_table("Device fit — utilization per artifact x profile", cols,
                [[r.get(c, "") for c in cols] for r in rows])

    benches = [{"name": "device_fit",
                "paper_ref": "Tables 1-2 / §4 mapping constraints",
                "ok": True, "rows": rows,
                "wall_s": round(time.time() - t_suite, 3)}]
    if out:
        write_bench_json(out, "analysis", benches,
                         config={"profiles": sorted(PROFILES),
                                 "default_profile": DEFAULT_PROFILE.name})
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="accepted for suite-runner symmetry (the bench "
                         "is already CI-sized)")
    ap.add_argument("--out", default="BENCH_analysis.json")
    args = ap.parse_args(argv)
    run(out=args.out)


if __name__ == "__main__":
    main()
