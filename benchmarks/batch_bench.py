"""Cross-window backend batching benchmark: flush_every vs throughput.

``python -m benchmarks.batch_bench`` drives the StreamingHybridServer
over a synthetic packet trace at several ``flush_every`` settings and
reports sustained packets/sec plus the backend-invocation count. The
deferral buffer trades per-row latency (deferred rows wait up to
``flush_every`` windows for their backend answer) for throughput (the
backend runs once per flush at ``flush_every``-times the occupancy) —
the hybrid-deployment knob DESIGN.md §7 documents.

Before any timing, two oracles gate the rows:

* final predictions at every ``flush_every`` must equal the
  ``flush_every=1`` baseline bit for bit (the backend is row-wise, so
  cross-window batching must not change a single answer), and the flow
  table / backend-row / deferred accounting must match;
* at ``flush_every >= 4`` the backend-invocation count must drop by at
  least 2x versus the per-window baseline — the acceptance bar for the
  subsystem (a "batching" path that still invokes per window is a bug).

Results go to ``BENCH_batch.json`` (schema "bench-v1", DESIGN.md §11).
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from benchmarks.common import print_table, write_bench_json
from benchmarks.stream_bench import _models
from repro.netsim.packets import synth_trace
from repro.netsim.stream import iter_windows
from repro.serving.stream_serving import StreamingHybridServer


def run(n_flows=4000, flush_every=(1, 2, 4, 8), window=512,
        n_buckets=1 << 13, threshold=0.9, capacity=64, repeats=3, seed=0,
        out="BENCH_batch.json"):
    t_suite = time.time()
    trace = synth_trace(n_flows=n_flows, seed=seed)
    art, backend = _models(trace, n_buckets)
    ws = list(iter_windows(trace, window, n_buckets))

    def serve(k):
        srv = StreamingHybridServer(art, backend, n_buckets=n_buckets,
                                    window=window, threshold=threshold,
                                    capacity=capacity, flush_every=k)
        pred, stats = srv.serve_trace(trace)
        return srv, np.asarray(pred), stats

    _, p_base, s_base = serve(1)
    rows = []
    for k in flush_every:
        srv, p, s = serve(k)
        # oracle 1: deferred dispatch must not change a single prediction
        np.testing.assert_array_equal(p, p_base)
        assert s.total_backend_rows == s_base.total_backend_rows
        assert s.n_deferred == s_base.n_deferred
        assert s.n_flushes == -(-s.n_windows // k)
        # oracle 2 (acceptance): >= 2x fewer backend invocations at k >= 4
        if k >= 4:
            assert 2 * s.n_flushes <= s_base.n_flushes, (
                f"flush_every={k}: {s.n_flushes} backend invocations vs "
                f"baseline {s_base.n_flushes} — expected >= 2x reduction")

        # timed passes: step every window, end-of-stream flush, one sync
        best = float("inf")
        for _ in range(repeats):
            srv.reset()
            t0 = time.perf_counter()
            for w in ws:
                pred, _ = srv.step(w)
                srv.consume_flush()
            srv.flush()
            jax.block_until_ready(srv.stats.windows)
            best = min(best, time.perf_counter() - t0)
        rows.append({
            "flush_every": k,
            "n_packets": trace.n_packets,
            "n_windows": len(ws),
            "wall_s": round(best, 4),
            "pkts_per_s": round(trace.n_packets / best, 1),
            "backend_invocations": s.n_flushes,
            "backend_rows": s.total_backend_rows,
            "deferred": s.n_deferred,
            "bit_consistent": True,
        })

    print_table("Cross-window backend batching — pkts/sec vs flush_every",
                ["flush_every", "pkts", "windows", "wall_s", "pkts/s",
                 "backend_invocations", "backend_rows", "deferred"],
                [[r["flush_every"], r["n_packets"], r["n_windows"],
                  r["wall_s"], r["pkts_per_s"], r["backend_invocations"],
                  r["backend_rows"], r["deferred"]] for r in rows])

    benches = [{"name": "batch_serving",
                "paper_ref": "§2.2.1 hybrid / backend load reduction",
                "ok": True, "rows": rows,
                "wall_s": round(time.time() - t_suite, 3)}]
    if out:
        write_bench_json(out, "batch", benches,
                         config={"n_flows": n_flows,
                                 "flush_every": list(flush_every),
                                 "window": window, "n_buckets": n_buckets,
                                 "threshold": threshold,
                                 "capacity": capacity, "repeats": repeats})
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--out", default="BENCH_batch.json")
    args = ap.parse_args(argv)
    if args.quick:
        run(n_flows=1200, flush_every=(1, 2, 4), repeats=2, out=args.out)
    else:
        run(out=args.out)


if __name__ == "__main__":
    main()
