"""bench-v1 schema validation for the BENCH_*.json perf trajectory.

``python -m benchmarks.validate_schema [paths...]`` checks every
``BENCH_*.json`` (all of them in the CWD when no paths are given)
against the bench-v1 contract of DESIGN.md §11 and exits nonzero on the
first structural violation — CI runs it after the emitters and before
the artifact upload, so a malformed emitter fails the workflow instead
of silently corrupting the diffable time series.

The check is structural (a *malformed* file, not a *failed* bench, is
the target — each suite already exits nonzero on its own failures):
top-level keys and types, the exact schema tag, and per-bench
name/paper_ref/ok/wall_s/rows shapes.
"""

from __future__ import annotations

import argparse
import glob
import json
import sys

SCHEMA = "bench-v1"

# key -> allowed types, shared by every emitter (run / kernel_microbench /
# stream_bench / shard_stream_bench / batch_bench / latency_bench)
TOP_KEYS = {
    "schema": str,
    "suite": str,
    "generated_unix": (int, float),
    "backend": str,
    "config": dict,
    "benches": list,
}
BENCH_KEYS = {
    "name": str,
    "paper_ref": str,
    "ok": bool,
    "wall_s": (int, float),
    # rows is whatever the bench's run() returned (DESIGN.md §11): a row
    # list, a keyed table dict, or null when the bench failed
    "rows": (list, dict, type(None)),
}
# suite "latency" (latency_bench) additionally promises percentile keys
# on every row that carries a prefetch flag — the downstream trajectory
# diff keys on them, so a renamed field must fail here, not there
LATENCY_ROW_KEYS = {
    "p50_ms": (int, float),
    "p95_ms": (int, float),
    "p99_ms": (int, float),
    "bit_identical": bool,
}
# suite "obs" (obs_bench): overhead rows (carrying an obs_on flag) pin
# the oracle + throughput-ratio keys; drift rows (carrying a scenario)
# pin the detector verdicts — both feed the trajectory diff
OBS_OVERHEAD_ROW_KEYS = {
    "pkts_per_s": (int, float),
    "throughput_ratio": (int, float),
    "bit_identical": bool,
}
OBS_DRIFT_ROW_KEYS = {
    "fired": bool,
    "detectors": list,
    "expected_fired": bool,
}
# suite "analysis" (analysis_bench): every utilization row (carrying a
# profile) pins artifact/fits plus the per-budget utilization columns
# the resource trajectory diffs on
ANALYSIS_ROW_KEYS = {
    "artifact": str,
    "profile": str,
    "fits": bool,
}
ANALYSIS_UTIL_KEYS = {
    "util_stages": (int, float),
    "util_sram_kib": (int, float),
    "util_tcam_kib": (int, float),
    "util_entries": (int, float),
    "util_tables": (int, float),
}
# suite "shard" (shard_stream_bench): every row carrying a device count
# pins the 2D mesh shape and the partitioned-classify telemetry — the
# scaling trajectory diffs on per-device classify rows shrinking with
# the mesh, so a renamed/dropped field must fail here
SHARD_ROW_KEYS = {
    "d_shard": int,
    "d_data": int,
    "classify_rows_per_device": int,
}
# every emitter's suite tag — an unknown suite means a new emitter
# forgot to register here (and in EXTRA_SUITES / DESIGN.md §11), or a
# typo is about to fork the trajectory under a fresh name
KNOWN_SUITES = frozenset({
    "benchmarks", "kernels", "stream", "shard", "batch", "scenarios",
    "latency", "obs", "analysis",
})


class SchemaError(ValueError):
    """A BENCH_*.json payload violates the bench-v1 contract."""


def _require(cond, path, msg):
    if not cond:
        raise SchemaError(f"{path}: {msg}")


def validate_bench_payload(payload, path="<payload>"):
    """Raise SchemaError unless ``payload`` is a valid bench-v1 document."""
    _require(isinstance(payload, dict), path,
             f"top level must be an object, got {type(payload).__name__}")
    for key, types in TOP_KEYS.items():
        _require(key in payload, path, f"missing top-level key {key!r}")
        _require(isinstance(payload[key], types), path,
                 f"top-level {key!r} must be {types}, "
                 f"got {type(payload[key]).__name__}")
    _require(payload["schema"] == SCHEMA, path,
             f"schema must be {SCHEMA!r}, got {payload['schema']!r}")
    _require(payload["suite"] in KNOWN_SUITES, path,
             f"unknown suite {payload['suite']!r} — known suites: "
             f"{sorted(KNOWN_SUITES)} (new emitters must register in "
             "validate_schema.KNOWN_SUITES)")
    _require(payload["benches"], path, "benches must be non-empty")
    for i, bench in enumerate(payload["benches"]):
        where = f"{path}: benches[{i}]"
        _require(isinstance(bench, dict), path,
                 f"benches[{i}] must be an object")
        for key, types in BENCH_KEYS.items():
            _require(key in bench, where, f"missing key {key!r}")
            _require(isinstance(bench[key], types), where,
                     f"{key!r} must be {types}, "
                     f"got {type(bench[key]).__name__}")
        if payload["suite"] == "latency" and isinstance(bench["rows"], list):
            for j, row in enumerate(bench["rows"]):
                if not (isinstance(row, dict) and "prefetch" in row):
                    continue            # autotune/summary rows
                rwhere = f"{where}.rows[{j}]"
                for key, types in LATENCY_ROW_KEYS.items():
                    _require(key in row, rwhere, f"missing key {key!r}")
                    _require(isinstance(row[key], types), rwhere,
                             f"{key!r} must be {types}, "
                             f"got {type(row[key]).__name__}")
        if payload["suite"] == "obs" and isinstance(bench["rows"], list):
            for j, row in enumerate(bench["rows"]):
                if not isinstance(row, dict):
                    continue
                keys = (OBS_OVERHEAD_ROW_KEYS if "obs_on" in row else
                        OBS_DRIFT_ROW_KEYS if "scenario" in row else None)
                if keys is None:
                    continue
                rwhere = f"{where}.rows[{j}]"
                for key, types in keys.items():
                    _require(key in row, rwhere, f"missing key {key!r}")
                    _require(isinstance(row[key], types), rwhere,
                             f"{key!r} must be {types}, "
                             f"got {type(row[key]).__name__}")
        if payload["suite"] == "shard" and isinstance(bench["rows"], list):
            for j, row in enumerate(bench["rows"]):
                if not (isinstance(row, dict) and "devices" in row):
                    continue            # summary rows
                rwhere = f"{where}.rows[{j}]"
                for key, types in SHARD_ROW_KEYS.items():
                    _require(key in row, rwhere, f"missing key {key!r}")
                    _require(isinstance(row[key], types), rwhere,
                             f"{key!r} must be {types}, "
                             f"got {type(row[key]).__name__}")
        if (payload["suite"] == "analysis"
                and isinstance(bench["rows"], list)):
            for j, row in enumerate(bench["rows"]):
                if not (isinstance(row, dict) and "profile" in row):
                    continue
                rwhere = f"{where}.rows[{j}]"
                for key, types in ANALYSIS_ROW_KEYS.items():
                    _require(key in row, rwhere, f"missing key {key!r}")
                    _require(isinstance(row[key], types), rwhere,
                             f"{key!r} must be {types}, "
                             f"got {type(row[key]).__name__}")
                if "guard" in row:
                    continue            # deploy-guard probe row: no utils
                for key, types in ANALYSIS_UTIL_KEYS.items():
                    _require(key in row, rwhere, f"missing key {key!r}")
                    _require(isinstance(row[key], types), rwhere,
                             f"{key!r} must be {types}, "
                             f"got {type(row[key]).__name__}")


def validate_bench_json(path):
    """Load one file and validate it; raise SchemaError on violations."""
    try:
        with open(path) as f:
            payload = json.load(f)
    except json.JSONDecodeError as e:
        raise SchemaError(f"{path}: not valid JSON ({e})") from e
    validate_bench_payload(payload, path)
    return payload


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("paths", nargs="*",
                    help="files to validate (default: ./BENCH_*.json)")
    args = ap.parse_args(argv)
    paths = args.paths or sorted(glob.glob("BENCH_*.json"))
    if not paths:
        sys.exit("validate_schema: no BENCH_*.json files found")
    for path in paths:
        try:
            payload = validate_bench_json(path)
        except SchemaError as e:
            sys.exit(f"validate_schema: FAIL {e}")
        print(f"validate_schema: OK {path} (suite={payload['suite']}, "
              f"{len(payload['benches'])} benches)")


if __name__ == "__main__":
    main()
