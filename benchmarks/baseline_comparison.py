"""Figs 6-7 analog: IIsy's mapping vs prior-work mapping strategies.

SwitchTree / pForest encode each tree (or tree level) separately: stages
scale with depth, tables with trees x features. IIsy's shared feature
tables + code-keyed decision tables keep stages constant. Clustreams
encodes K-means cells as range entries over the feature cross-product.

Same trained models, two mappings each -> entries / memory / stages.
"""

from __future__ import annotations

from benchmarks.common import load_usecase, print_table
from repro.core.mapping import map_kmeans, map_tree_ensemble
from repro.core.naive_mappings import (clustreams_resources,
                                       pforest_resources,
                                       switchtree_resources)
from repro.core.resources import artifact_resources
from repro.ml.kmeans import fit_kmeans
from repro.ml.trees import fit_decision_tree, fit_random_forest


def run(n=12000, seed=0):
    xtr, ytr, xte, yte = load_usecase("finance", n=n, seed=seed)
    f = xtr.shape[1]

    # Fig 6a: K-Means — IIsy vs Clustreams
    km = fit_kmeans(xtr, k=2, seed=seed)
    art = map_kmeans(km, xtr, n_bins=64)
    iisy = artifact_resources(art)
    clus = clustreams_resources(2, f, 64)
    rows = [["IIsy-KM", iisy.entries, f"{iisy.kib:.1f}", iisy.stages],
            ["Clustreams-KM", clus.entries, f"{clus.kib:.1f}", clus.stages]]
    print_table("Fig 6a — K-Means mapping comparison",
                ["mapping", "entries", "KiB", "stages"], rows)

    # Fig 6b: DT — IIsy vs SwitchTree. Coarse training bins bound the
    # per-feature threshold count, keeping the code-keyed decision table
    # feasible at depth 10 (paper §7.8 "binning").
    dt = fit_decision_tree(xtr, ytr, n_classes=2, max_depth=10, n_bins=16)
    art = map_tree_ensemble(dt, f, max_decision_entries=4_000_000)
    iisy = artifact_resources(art)
    st = switchtree_resources(dt, f)
    rows = [["IIsy-DT(d=10)", iisy.entries, f"{iisy.kib:.1f}", iisy.stages],
            ["SwitchTree-DT", st.entries, f"{st.kib:.1f}", st.stages]]
    print_table("Fig 6b — Decision-tree mapping comparison",
                ["mapping", "entries", "KiB", "stages"], rows)

    # Fig 7: RF across three hyperparameter sets
    rows = []
    for tag, (trees, depth) in (("small", (3, 4)), ("max-ST", (5, 10)),
                                ("large", (10, 8))):
        rf = fit_random_forest(xtr, ytr, n_classes=2, n_trees=trees,
                               max_depth=depth, seed=seed,
                               n_bins=16 if depth >= 8 else 64)
        try:
            art = map_tree_ensemble(rf, f, max_decision_entries=5_000_000)
            iisy = artifact_resources(art)
            iisy_row = [iisy.entries, f"{iisy.kib:.1f}", iisy.stages]
        except ValueError:
            iisy_row = ["-", "unmappable", "-"]
        st = switchtree_resources(rf, f)
        pf = pforest_resources(rf, f)
        rows.append([tag, trees, depth, *iisy_row,
                     st.entries, st.stages, pf.entries, pf.stages])
    print_table("Fig 7 — RF: IIsy vs SwitchTree vs pForest",
                ["cfg", "trees", "depth", "iisy_entries", "iisy_KiB",
                 "iisy_stages", "st_entries", "st_stages",
                 "pf_entries", "pf_stages"], rows)
    return rows


if __name__ == "__main__":
    run()
