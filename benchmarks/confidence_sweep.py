"""Figs 10-11 analog: hybrid confidence-threshold sweeps.

(a) fraction of traffic handled at the switch vs tau;
(b) hybrid misclassification vs tau;
(c) switch-handled error vs backend error on the same (low-confidence)
    rows — the paper's "low-confidence rows are hard for the backend too".
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import load_usecase, print_table
from repro.core.inference import table_predict
from repro.core.mapping import map_tree_ensemble
from repro.ml.metrics import accuracy
from repro.ml.trees import (fit_random_forest, fit_xgboost,
                            predict_margin_xgboost, predict_tree_ensemble)


def run(n=20000, seed=0):
    out = {}
    for use_case in ("anomaly", "finance"):
        if use_case == "anomaly":
            from repro.data.unsw_like import make_unsw_like, train_test_split
            x, y = make_unsw_like(n, seed=seed, n_features=10)
            xtr, ytr, xte, yte = train_test_split(x, y)
            cols = list(range(5))
            sw = fit_random_forest(xtr[:, cols], ytr, n_classes=2,
                                   n_trees=10, max_depth=5, seed=seed)
            backend = fit_random_forest(xtr, ytr, n_classes=2, n_trees=40,
                                        max_depth=8, seed=seed + 1,
                                        max_features=10)
            be_pred = predict_tree_ensemble(backend, xte)
        else:
            from repro.data.janestreet_like import (SWITCH_FEATURES,
                                                    make_janestreet_like,
                                                    train_test_split)
            x, y = make_janestreet_like(n, seed=seed)
            xtr, ytr, xte, yte = train_test_split(x, y)
            cols = SWITCH_FEATURES
            sw = fit_xgboost(xtr[:, cols], ytr, n_trees=10, max_depth=5)
            backend = fit_xgboost(xtr, ytr, n_trees=60, max_depth=8)
            be_pred = (predict_margin_xgboost(backend, xte) > 0).astype(
                jnp.int32)

        art = map_tree_ensemble(sw, len(cols))
        sw_pred, conf = table_predict(art, xte[:, cols])
        be_err = 1.0 - accuracy(yte, be_pred)
        rows = []
        for tau in (0.5, 0.55, 0.6, 0.65, 0.7, 0.8, 0.9):
            handled = np.asarray(conf >= tau)
            pred = np.where(handled, np.asarray(sw_pred),
                            np.asarray(be_pred))
            hy_err = 1.0 - accuracy(yte, pred)
            frac = float(handled.mean())
            # error of the switch on its handled rows vs backend on same rows
            if handled.any():
                sw_err_h = float((np.asarray(sw_pred) != np.asarray(yte))
                                 [handled].mean())
                be_err_h = float((np.asarray(be_pred) != np.asarray(yte))
                                 [handled].mean())
            else:
                sw_err_h = be_err_h = float("nan")
            rows.append([tau, f"{frac:.3f}", f"{hy_err:.4f}",
                         f"{sw_err_h:.4f}", f"{be_err_h:.4f}"])
        print_table(
            f"Fig 10/11 — {use_case}: hybrid sweep "
            f"(backend-only err {be_err:.4f})",
            ["tau", "frac_switch", "hybrid_err", "switch_err@handled",
             "backend_err@handled"], rows)
        out[use_case] = rows
    return out


if __name__ == "__main__":
    run()
