"""Run every paper-table benchmark: ``python -m benchmarks.run``.

One module per paper table/figure (see DESIGN.md §7). Pass --quick for
reduced sample sizes (CI), --only <name> for a single benchmark.
"""

from __future__ import annotations

import argparse
import sys
import time

BENCHES = [
    ("resource_anomaly", "Table 1"),
    ("resource_finance", "Table 2"),
    ("scalability", "Table 3"),
    ("feature_scaling", "Figs 4-5"),
    ("baseline_comparison", "Figs 6-7"),
    ("throughput_latency", "Fig 8"),
    ("calc_error", "Fig 9"),
    ("confidence_sweep", "Figs 10-11"),
    ("update_time", "§7.9"),
]


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default=None)
    args = ap.parse_args(argv)

    n = 6000 if args.quick else 20000
    t_all = time.time()
    failures = []
    for mod_name, paper_ref in BENCHES:
        if args.only and args.only != mod_name:
            continue
        print(f"\n{'=' * 70}\n{paper_ref}  ->  benchmarks.{mod_name}"
              f"\n{'=' * 70}")
        t0 = time.time()
        try:
            mod = __import__(f"benchmarks.{mod_name}", fromlist=["run"])
            mod.run(n=n)
            print(f"[{mod_name}: {time.time() - t0:.1f}s]")
        except Exception:   # keep the suite going; report at the end
            import traceback
            traceback.print_exc()
            failures.append(mod_name)
    print(f"\ntotal: {time.time() - t_all:.1f}s; "
          f"{len(failures)} failures {failures or ''}")
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
