"""Run every paper-table benchmark: ``python -m benchmarks.run``.

One module per paper table/figure (see DESIGN.md §12). Pass --quick for
reduced sample sizes (CI), --only <name> for a single benchmark.

Besides the printed tables, the suite writes machine-readable
``BENCH_benchmarks.json`` (schema "bench-v1", see DESIGN.md §11): one row
per benchmark with its wall time and whatever its run() returned, so the
perf trajectory of the repo is tracked run over run. The other bench-v1
emitters — ``kernel_microbench`` (BENCH_kernels.json), ``stream_bench``
(BENCH_stream.json), ``shard_stream_bench`` (BENCH_shard.json),
``batch_bench`` (BENCH_batch.json), ``scenario_bench``
(BENCH_scenarios.json) and ``analysis_bench`` (BENCH_analysis.json,
the device resource-fit trajectory) — are separate entry points with
their own gating oracles; ``--all-suites`` runs them here too, so one
command refreshes the whole trajectory. A failing sub-suite fails the
whole run immediately (its exit code is propagated), so a broken oracle
can never leave CI green.
"""

from __future__ import annotations

import argparse
import sys
import time

from benchmarks.common import write_bench_json

BENCHES = [
    ("resource_anomaly", "Table 1"),
    ("resource_finance", "Table 2"),
    ("scalability", "Table 3"),
    ("feature_scaling", "Figs 4-5"),
    ("baseline_comparison", "Figs 6-7"),
    ("throughput_latency", "Fig 8"),
    ("calc_error", "Fig 9"),
    ("confidence_sweep", "Figs 10-11"),
    ("update_time", "§7.9"),
]

# the standalone bench-v1 emitters --all-suites chains after the in-process
# benches; each must force its own environment (e.g. shard_stream_bench's
# multi-device host platform) before its first jax import, hence subprocesses
EXTRA_SUITES = ("kernel_microbench", "stream_bench", "shard_stream_bench",
                "batch_bench", "scenario_bench", "latency_bench",
                "obs_bench", "analysis_bench")


def run_suites(suite_modules, quick=False):
    """Run each standalone emitter as ``python -m benchmarks.<mod>``.

    Exits the process with the child's return code on the FIRST failure —
    the exit codes of these subprocesses used to be swallowed into an
    end-of-run summary only, so an oracle failure in one suite could
    leave a caller that only checked "did it finish" green. Fail fast
    and propagate instead.
    """
    import subprocess
    for mod_name in suite_modules:
        print(f"\n{'=' * 70}\nbenchmarks.{mod_name}\n{'=' * 70}",
              flush=True)
        cmd = [sys.executable, "-m", f"benchmarks.{mod_name}"]
        if quick:
            cmd.append("--quick")
        rc = subprocess.run(cmd).returncode
        if rc:
            print(f"benchmarks.{mod_name} FAILED (exit {rc})",
                  file=sys.stderr, flush=True)
            sys.exit(rc)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default=None)
    ap.add_argument("--out", default="BENCH_benchmarks.json",
                    help="machine-readable results file (bench-v1 schema)")
    ap.add_argument("--all-suites", action="store_true",
                    help="also run the kernel, streaming, sharded-"
                         "streaming, cross-window-batching and adversarial-"
                         "scenario benches (BENCH_kernels/stream/shard/"
                         "batch/scenarios.json); fails fast on the first "
                         "failing suite")
    args = ap.parse_args(argv)

    n = 6000 if args.quick else 20000
    t_all = time.time()
    failures = []
    results = []
    for mod_name, paper_ref in BENCHES:
        if args.only and args.only != mod_name:
            continue
        print(f"\n{'=' * 70}\n{paper_ref}  ->  benchmarks.{mod_name}"
              f"\n{'=' * 70}")
        t0 = time.time()
        entry = {"name": mod_name, "paper_ref": paper_ref, "ok": True,
                 "rows": None}
        try:
            mod = __import__(f"benchmarks.{mod_name}", fromlist=["run"])
            entry["rows"] = mod.run(n=n)
            print(f"[{mod_name}: {time.time() - t0:.1f}s]")
        except Exception:   # keep the suite going; report at the end
            import traceback
            traceback.print_exc()
            failures.append(mod_name)
            entry["ok"] = False
        entry["wall_s"] = round(time.time() - t0, 3)
        results.append(entry)
    if args.only and not results:
        names = ", ".join(m for m, _ in BENCHES)
        sys.exit(f"unknown benchmark {args.only!r}; choices: {names}")
    if args.out:
        write_bench_json(args.out, "benchmarks", results,
                         config={"n": n, "quick": args.quick,
                                 "only": args.only})
    if failures:
        # fail before launching sub-suites: a broken in-process bench
        # should not be buried under another suite's output
        print(f"\ntotal: {time.time() - t_all:.1f}s; "
              f"{len(failures)} failures {failures}")
        sys.exit(1)
    if args.all_suites:
        # fresh subprocesses, not in-process main() calls: jax is already
        # initialized here, and shard_stream_bench must force its
        # multi-device host platform *before* the first jax import —
        # in-process it would silently degrade to a 1-device scaling axis.
        # run_suites exits nonzero on the first failing child.
        run_suites(EXTRA_SUITES, quick=args.quick)
    print(f"\ntotal: {time.time() - t_all:.1f}s; 0 failures")
    sys.exit(0)


if __name__ == "__main__":
    main()
