"""Packet-to-prediction latency percentiles for the ingest pipeline.

``python -m benchmarks.latency_bench`` drives ``serve_stream`` — the
ring-buffered, open-ended serving loop (DESIGN.md §13) — over a
batch-paced replay of a synthetic trace and reports what ``serve_trace``
throughput numbers cannot: per-packet admit->prediction wall latency
(p50/p95/p99), with and without the prefetch double-buffer, plus the
init-time chunk-size autotune row.

Every number is gated before it counts:

* **bit-identity** — each configuration's predictions (prefetch on/off,
  batch-paced, autotuned K) must equal the offline ``serve_trace``
  replay bit for bit, and ``serve_trace`` itself must equal a manual
  ``iter_chunks`` + ``step_chunk`` loop (the wrapper contract);
* **prefetch must not regress** — zero-sync throughput with the
  prefetch thread on must stay >= ``prefetch_floor`` of the
  prefetch-off pipeline (overlap is allowed to be neutral on a CPU
  host where transfers are memcpy, never clearly harmful);
* **autotune must not regress** — serving at the measured-sweep K must
  stay >= ``auto_floor`` of the fixed-default K (the sweep's argmin
  contains the default by construction; this re-checks it end to end).

Latency rows are measured with ``record_latency=True`` (one host sync
per chunk — the documented cost of the knob), throughput rows with it
off (the zero-sync loop). Results go to ``BENCH_latency.json``
(schema "bench-v1", DESIGN.md §11).
"""

from __future__ import annotations

import argparse
import time

import numpy as np

from benchmarks.common import print_table, trace_models, write_bench_json
from repro.netsim.ingest import replay_source
from repro.netsim.packets import synth_trace
from repro.netsim.stream import iter_chunks
from repro.serving.stream_serving import (DEFAULT_CHUNK_WINDOWS,
                                          StreamingHybridServer,
                                          autotune_chunk_windows)


def _serve_wall(srv, trace, batch, *, prefetch, repeats):
    """min-over-reps zero-sync serve_stream wall time (warm server)."""
    best, preds = float("inf"), None
    for _ in range(repeats):
        srv.reset()
        t0 = time.perf_counter()
        preds, _ = srv.serve_stream(replay_source(trace, batch=batch),
                                    prefetch=prefetch)
        best = min(best, time.perf_counter() - t0)
    return best, np.asarray(preds)


def run(n_flows=4000, window=256, chunk_windows=16, n_buckets=1 << 13,
        threshold=0.9, capacity=64, repeats=3, seed=0,
        batches_per_chunk=1.0, prefetch_floor=0.85, auto_floor=0.9,
        auto_candidates=(4, 8, 16, 32), out="BENCH_latency.json"):
    t_suite = time.time()
    trace = synth_trace(n_flows=n_flows, seed=seed)
    art, backend = trace_models(trace, n_buckets)
    kw = dict(n_buckets=n_buckets, window=window, threshold=threshold,
              capacity=capacity)
    batch = max(1, int(chunk_windows * window * batches_per_chunk))

    # -- oracle chain: manual chunk loop == serve_trace == serve_stream --
    manual = StreamingHybridServer(art, backend, chunk_windows=chunk_windows,
                                   **kw)
    mpreds = []
    for c in iter_chunks(trace, window, chunk_windows, n_buckets):
        pred, _ = manual.step_chunk(c)
        mpreds.append(np.asarray(pred).reshape(-1))
    ref_preds = np.concatenate(mpreds)[:trace.n_packets]
    ref_stats = manual.stats.check()

    srv = StreamingHybridServer(art, backend, chunk_windows=chunk_windows,
                                **kw)
    tr_preds, tr_stats = srv.serve_trace(trace)
    np.testing.assert_array_equal(np.asarray(tr_preds), ref_preds)
    assert tr_stats == ref_stats, (tr_stats, ref_stats)
    print(f"oracle: serve_trace == manual iter_chunks loop "
          f"({trace.n_packets} pkts, K={chunk_windows}, W={window})")

    # -- throughput: prefetch on vs off (zero-sync), interleaved --------
    t_off, t_on = float("inf"), float("inf")
    for _ in range(max(repeats, 2)):
        w_off, p_off = _serve_wall(srv, trace, batch, prefetch=False,
                                   repeats=1)
        w_on, p_on = _serve_wall(srv, trace, batch, prefetch=True,
                                 repeats=1)
        t_off, t_on = min(t_off, w_off), min(t_on, w_on)
    np.testing.assert_array_equal(p_off, ref_preds)
    np.testing.assert_array_equal(p_on, ref_preds)

    # -- latency percentiles (record_latency syncs once per chunk) ------
    rows = []
    for label, pf, wall in (("prefetch_off", False, t_off),
                            ("prefetch_on", True, t_on)):
        srv.reset()
        preds, stats = srv.serve_stream(
            replay_source(trace, batch=batch), prefetch=pf,
            record_latency=True)
        np.testing.assert_array_equal(np.asarray(preds), ref_preds)
        summ = srv.latency.summary()
        assert summ["n"] == trace.n_packets, (summ["n"], trace.n_packets)
        ing = srv.ingest_stats
        rows.append({
            "config": label, "prefetch": pf,
            "window": window, "chunk_windows": chunk_windows,
            "batch": batch, "n_packets": trace.n_packets,
            "p50_ms": round(summ["p50_ms"], 4),
            "p95_ms": round(summ["p95_ms"], 4),
            "p99_ms": round(summ["p99_ms"], 4),
            "mean_ms": round(summ["mean_ms"], 4),
            "wall_s": round(wall, 4),
            "pkts_per_s": round(trace.n_packets / wall, 1),
            "cuts": ing.cuts, "dropped": ing.dropped,
            "bit_identical": True,
        })

    print_table("Ingest pipeline — admit->prediction latency "
                f"(window={window}, K={chunk_windows})",
                ["config", "p50_ms", "p95_ms", "p99_ms", "mean_ms",
                 "pkts/s", "cuts"],
                [[r["config"], r["p50_ms"], r["p95_ms"], r["p99_ms"],
                  r["mean_ms"], r["pkts_per_s"], r["cuts"]] for r in rows])

    speedup = t_off / t_on
    assert speedup >= prefetch_floor, (
        f"prefetch regressed zero-sync throughput: {speedup:.3f}x of the "
        f"prefetch-off pipeline (floor {prefetch_floor}x)")
    print(f"prefetch throughput: {speedup:.3f}x of prefetch-off "
          f"(floor {prefetch_floor}x)")

    # -- init-time chunk-size autotune: measured K sweep ----------------
    k_auto = autotune_chunk_windows(
        lambda k: StreamingHybridServer(art, backend, chunk_windows=k,
                                        **kw),
        window=window, n_buckets=n_buckets, candidates=auto_candidates,
        default=DEFAULT_CHUNK_WINDOWS, verbose=True)
    srv_auto = StreamingHybridServer(art, backend, chunk_windows=k_auto,
                                     **kw)
    srv_dflt = StreamingHybridServer(art, backend,
                                     chunk_windows=DEFAULT_CHUNK_WINDOWS,
                                     **kw)
    # warm both, then interleave
    _serve_wall(srv_auto, trace, batch, prefetch=False, repeats=1)
    _serve_wall(srv_dflt, trace, batch, prefetch=False, repeats=1)
    t_auto, t_dflt = float("inf"), float("inf")
    for _ in range(max(repeats, 2)):
        w_a, p_a = _serve_wall(srv_auto, trace, batch, prefetch=False,
                               repeats=1)
        w_d, _ = _serve_wall(srv_dflt, trace, batch, prefetch=False,
                             repeats=1)
        t_auto, t_dflt = min(t_auto, w_a), min(t_dflt, w_d)
    np.testing.assert_array_equal(p_a, ref_preds)
    ratio = t_dflt / t_auto
    assert ratio >= auto_floor, (
        f"autotuned K={k_auto} regressed vs default "
        f"K={DEFAULT_CHUNK_WINDOWS}: {ratio:.3f}x (floor {auto_floor}x)")
    a_row = {
        "config": "autotune", "chunk_windows": k_auto,
        "default_chunk_windows": DEFAULT_CHUNK_WINDOWS,
        "window": window, "candidates": list(auto_candidates),
        "pkts_per_s": round(trace.n_packets / t_auto, 1),
        "default_pkts_per_s": round(trace.n_packets / t_dflt, 1),
        "speedup_vs_default": round(ratio, 3),
        "bit_identical": True,
    }
    rows.append(a_row)
    print(f"autotune picked K={k_auto}: {ratio:.3f}x of default "
          f"K={DEFAULT_CHUNK_WINDOWS} (floor {auto_floor}x)")

    wall = round(time.time() - t_suite, 3)
    benches = [{"name": "ingest_latency", "paper_ref": "§5 / pForest "
                "real-time classification", "ok": True, "rows": rows,
                "wall_s": wall}]
    if out:
        write_bench_json(out, "latency", benches,
                         config={"n_flows": n_flows, "window": window,
                                 "chunk_windows": chunk_windows,
                                 "n_buckets": n_buckets,
                                 "threshold": threshold,
                                 "capacity": capacity, "repeats": repeats,
                                 "batch": batch,
                                 "prefetch_floor": prefetch_floor,
                                 "auto_floor": auto_floor})
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--out", default="BENCH_latency.json")
    args = ap.parse_args(argv)
    if args.quick:
        # short trace, two autotune candidates (the sweep compiles one
        # megastep per K); same gates as the full run
        run(n_flows=1200, chunk_windows=8, repeats=2,
            auto_candidates=(8, 16), out=args.out)
    else:
        run(out=args.out)


if __name__ == "__main__":
    main()
