"""Table 2 analog: resource consumption + F1, financial-transactions use
case (JaneStreet-like, 5 switch features = columns 42/43/45/124/126)."""

from __future__ import annotations

from benchmarks.common import (MODELS, fit_and_map, load_usecase,
                               print_table, table_pred_maybe_flip)
from repro.core.resources import artifact_resources
from repro.ml.metrics import accuracy, precision_recall_f1


def run(n=20000, seed=0):
    xtr, ytr, xte, yte = load_usecase("finance", n=n, seed=seed)
    rows = []
    for model in MODELS:
        direct, art, _ = fit_and_map(model, xtr, ytr, n_trees=10, max_depth=5)
        res = artifact_resources(art)
        pred, _ = table_pred_maybe_flip(art, xte)
        acc = accuracy(yte, pred)
        _, _, f1 = precision_recall_f1(yte, pred)
        rows.append([model, res.tables, res.entries, f"{res.kib:.1f}",
                     res.stages, f"{acc:.3f}", f"{f1:.3f}"])
    print_table("Table 2 — Financial transactions: resources + ML "
                "performance (5 switch features)",
                ["model", "tables", "entries", "KiB", "stages", "acc", "F1"],
                rows)
    return rows


if __name__ == "__main__":
    run()
