"""Fig 8 analog: switch-pipeline vs direct-model classification throughput.

The paper's Fig 8 compares Tofino line-rate against a CPU baseline. Here
both paths run on the same device, so the meaningful quantities are
  * classifications/s of the fused table pipeline (jit, XLA path),
  * classifications/s of direct model evaluation,
  * the batch-size scaling curve (the "line rate" analog: the table
    pipeline's cost is O(F) lookups/row regardless of model size — the
    paper's scaling property — while direct ensembles walk every tree).
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import fit_and_map, load_usecase, print_table
from repro.core.inference import table_predict
from repro.kernels.ops import fused_classify


def _bench(fn, *args, iters=20):
    fn(*args)[0].block_until_ready()          # compile + warm
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.tree.leaves(out)[0].block_until_ready()
    return (time.perf_counter() - t0) / iters


def run(n=20000, seed=0):
    xtr, ytr, xte, yte = load_usecase("finance", n=n, seed=seed)
    rows = []
    for model in ("RF", "XGB", "SVM"):
        direct, art, m = fit_and_map(model, xtr, ytr, n_trees=10,
                                     max_depth=5, seed=seed)
        jit_table = jax.jit(lambda a, x: table_predict(a, x))
        jit_direct = jax.jit(lambda x: direct(x)) if model != "KMeans" \
            else None
        for batch in (1024, 8192):
            xb = jnp.asarray(xte[:batch]) if batch <= len(xte) else \
                jnp.tile(jnp.asarray(xte), (batch // len(xte) + 1, 1))[:batch]
            dt_t = _bench(jit_table, art, xb)
            dt_d = _bench(lambda x: (jit_direct(x),), xb)
            rows.append([model, batch,
                         f"{batch / dt_t / 1e6:.2f}M/s",
                         f"{batch / dt_d / 1e6:.2f}M/s",
                         f"{dt_t * 1e6 / batch:.3f}us",
                         f"{dt_d * 1e6 / batch:.3f}us"])
    print_table("Fig 8 — throughput/latency: table pipeline vs direct model",
                ["model", "batch", "table_rate", "direct_rate",
                 "table_us/row", "direct_us/row"], rows)
    return rows


if __name__ == "__main__":
    run()
