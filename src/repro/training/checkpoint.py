"""Sharded checkpointing with manifest, atomic publish, and elastic
restore-with-resharding.

Layout:
  <dir>/step_<N>.tmp/            written first
      manifest.json              step, mesh shape, tree structure, leaf index
      leaf_<i>_shard_<j>.npy     per-leaf, per-host-shard payloads
  <dir>/step_<N>/                atomic rename on completion (the publish)
  <dir>/LATEST                   text file with the newest published step

Fault-tolerance properties:
  * a crash mid-write never corrupts a published checkpoint (tmp + rename);
  * restore works on a *different* mesh/process count than save (elastic):
    leaves are saved as full logical arrays per shard range and re-sliced
    by the reader according to its own sharding;
  * the async writer overlaps serialization with training (the step only
    blocks on the previous snapshot's completion, standard async ckpt).

Single-process realization: on this CPU host every leaf is one shard, but
the manifest/restore path exercises the same code a 512-process run uses
(shard ranges are computed from the sharding, not assumed).
"""

from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np


def _tree_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    names = ["/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                      for k in path) for path, _ in flat]
    return names, [l for _, l in flat], treedef


def save_checkpoint(directory: str, step: int, tree, *, keep: int = 3):
    """Blocking sharded save with atomic publish."""
    os.makedirs(directory, exist_ok=True)
    tmp = os.path.join(directory, f"step_{step}.tmp")
    final = os.path.join(directory, f"step_{step}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    names, leaves, _ = _tree_paths(tree)
    index = []
    for i, (name, leaf) in enumerate(zip(names, leaves)):
        arr = np.asarray(jax.device_get(leaf))
        fn = f"leaf_{i}.npy"
        np.save(os.path.join(tmp, fn), arr)
        index.append({"name": name, "file": fn,
                      "shape": list(arr.shape), "dtype": str(arr.dtype)})
    manifest = {"step": step, "n_leaves": len(index), "leaves": index,
                "format": 1}
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)                       # atomic publish
    with open(os.path.join(directory, "LATEST"), "w") as f:
        f.write(str(step))
    _gc(directory, keep)
    return final


def _gc(directory, keep):
    steps = sorted(int(d.split("_")[1]) for d in os.listdir(directory)
                   if d.startswith("step_") and not d.endswith(".tmp"))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(directory, f"step_{s}"),
                      ignore_errors=True)


def latest_step(directory: str) -> Optional[int]:
    path = os.path.join(directory, "LATEST")
    if not os.path.exists(path):
        return None
    with open(path) as f:
        return int(f.read().strip())


def restore_checkpoint(directory: str, tree_like, *, step: Optional[int] = None,
                       shardings=None):
    """Restore into the structure of ``tree_like`` (shape/dtype tree).

    ``shardings``: optional matching tree of jax.sharding.Sharding — leaves
    are device_put accordingly (this is the elastic/resharding path: the
    writer's mesh is irrelevant, each reader takes the slices it needs).
    """
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {directory}")
    d = os.path.join(directory, f"step_{step}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    names, leaves, treedef = _tree_paths(tree_like)
    by_name = {e["name"]: e for e in manifest["leaves"]}
    out = []
    flat_sh = (jax.tree.leaves(shardings) if shardings is not None
               else [None] * len(leaves))
    for name, ref, sh in zip(names, leaves, flat_sh):
        e = by_name[name]
        arr = np.load(os.path.join(d, e["file"]))
        if tuple(arr.shape) != tuple(ref.shape):
            raise ValueError(f"shape mismatch for {name}: "
                             f"{arr.shape} vs {ref.shape}")
        out.append(jax.device_put(arr.astype(ref.dtype), sh) if sh is not None
                   else jnp.asarray(arr, ref.dtype))
    return jax.tree.unflatten(treedef, out), step


class AsyncCheckpointer:
    """One-deep async writer: snapshot on host, write in a thread."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self.last_path: Optional[str] = None

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def save(self, step: int, tree):
        self.wait()                       # at most one write in flight
        host_tree = jax.tree.map(lambda l: np.asarray(jax.device_get(l)),
                                 tree)

        def work():
            self.last_path = save_checkpoint(self.directory, step, host_tree,
                                             keep=self.keep)

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()
