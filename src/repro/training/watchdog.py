"""Step-time watchdog: straggler / hang detection.

At 1000+ nodes the common failure modes are (a) a host silently slowing
down (thermal, ECC retries, network flaps) and (b) a hard hang in a
collective. Both surface as step-time anomalies. The watchdog keeps a
robust running estimate (median + MAD over a window) and:

  * flags a STRAGGLER when a step exceeds ``slow_factor`` x median;
  * arms a hang timer that a monitoring thread can use to abort the
    process (so the job scheduler restarts it from the last checkpoint —
    the restart path is exercised by tests/test_checkpoint.py).
"""

from __future__ import annotations

import collections
import threading
import time
from typing import Callable, Optional


class StepWatchdog:
    def __init__(self, *, window: int = 32, slow_factor: float = 2.5,
                 hang_timeout_s: float = 600.0,
                 on_hang: Optional[Callable[[], None]] = None):
        self.times = collections.deque(maxlen=window)
        self.slow_factor = slow_factor
        self.hang_timeout_s = hang_timeout_s
        self.on_hang = on_hang
        self.events: list[dict] = []
        self._timer: Optional[threading.Timer] = None
        self._t0: Optional[float] = None

    # -- step lifecycle ------------------------------------------------------
    def step_start(self, step: int):
        self._t0 = time.monotonic()
        self._arm(step)

    def step_end(self, step: int) -> dict:
        dt = time.monotonic() - self._t0
        self._disarm()
        med = self.median()
        is_straggler = (med is not None and len(self.times) >= 8
                        and dt > self.slow_factor * med)
        if is_straggler:
            self.events.append({"step": step, "kind": "straggler",
                                "dt": dt, "median": med})
        self.times.append(dt)
        return {"dt": dt, "median": self.median(), "straggler": is_straggler}

    def median(self) -> Optional[float]:
        if not self.times:
            return None
        s = sorted(self.times)
        return s[len(s) // 2]

    # -- hang timer ----------------------------------------------------------
    def _arm(self, step):
        self._disarm()
        if self.on_hang is None:
            return
        self._timer = threading.Timer(self.hang_timeout_s, self._fire, (step,))
        self._timer.daemon = True
        self._timer.start()

    def _fire(self, step):
        self.events.append({"step": step, "kind": "hang"})
        self.on_hang()

    def _disarm(self):
        if self._timer is not None:
            self._timer.cancel()
            self._timer = None
