"""Training runtime: optimizer, loop, checkpointing, compression, watchdog."""

from repro.training.optim import AdamWConfig, adamw_update, init_opt_state, lr_at
from repro.training.loop import TrainConfig, make_train_step, train
from repro.training.checkpoint import (save_checkpoint, restore_checkpoint,
                                       latest_step, AsyncCheckpointer)
from repro.training.watchdog import StepWatchdog
