"""Gradient compression for the DP all-reduce, with error feedback.

Two schemes, composable with any optimizer because they sit *between*
per-shard gradient computation and the cross-replica reduction:

  * top-k sparsification: keep the largest-|g| fraction per tensor; the
    residual is carried to the next step (error feedback, à la Deep
    Gradient Compression) so nothing is lost, only delayed.
  * int8 block quantization: per-block absmax scales; the quantization
    error likewise enters the feedback buffer.

On a real multi-host mesh the compressed payload is what crosses DCN
between pods; here the benefit is *measured* in collective bytes on the
dry-run HLO (see §Perf) by applying compression inside the jitted step
before the psum that GSPMD inserts.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

F32 = jnp.float32


def init_error_state(params):
    return jax.tree.map(lambda p: jnp.zeros_like(p, F32), params)


def _topk_mask(x, frac):
    k = max(1, int(x.size * frac))
    flat = jnp.abs(x.reshape(-1))
    thresh = jax.lax.top_k(flat, k)[0][-1]
    return (jnp.abs(x) >= thresh).astype(F32)


def topk_compress(grads, err, *, frac=0.05):
    """-> (sparse grads to reduce, new error state)."""
    def one(g, e):
        acc = g.astype(F32) + e
        mask = _topk_mask(acc, frac)
        sent = acc * mask
        return sent, acc - sent

    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(err)
    outs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (jax.tree.unflatten(tdef, [o[0] for o in outs]),
            jax.tree.unflatten(tdef, [o[1] for o in outs]))


def int8_compress(grads, err, *, block=256):
    """Quantize (g + err) to int8 blocks; returns (dequantized-to-send,
    new error). The dequantized value is what the all-reduce sees; the
    wire format would be the int8 payload + per-block scales."""
    def one(g, e):
        acc = g.astype(F32) + e
        flat = acc.reshape(-1)
        pad = (-flat.size) % block
        fp = jnp.pad(flat, (0, pad)).reshape(-1, block)
        scale = jnp.max(jnp.abs(fp), axis=1, keepdims=True) / 127.0
        scale = jnp.maximum(scale, 1e-12)
        q = jnp.clip(jnp.round(fp / scale), -127, 127)
        deq = (q * scale).reshape(-1)[:flat.size].reshape(acc.shape)
        return deq, acc - deq

    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(err)
    outs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (jax.tree.unflatten(tdef, [o[0] for o in outs]),
            jax.tree.unflatten(tdef, [o[1] for o in outs]))


def compressed_bytes(params, scheme: str, *, frac=0.05, block=256) -> int:
    """Wire bytes per DP all-reduce under each scheme (for §Perf deltas)."""
    n = sum(l.size for l in jax.tree.leaves(params))
    if scheme == "none":
        return 4 * n
    if scheme == "int8":
        return n + 4 * (n // block)        # payload + scales
    if scheme == "topk":
        k = int(n * frac)
        return k * (4 + 4)                 # value + index
    raise ValueError(scheme)
