"""Training loop: jitted step (grad accumulation, optional gradient
compression), checkpoint/restart, watchdog, deterministic data.

The step function is built once per (cfg, mesh) and carries explicit
in/out shardings, so the same code drives the single-device smoke tests,
the 256-chip single-pod mesh and the 512-chip multi-pod mesh.
"""

from __future__ import annotations

import dataclasses
import functools
import time
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.data.lm_pipeline import TokenPipeline
from repro.distributed.sharding import (batch_specs, named_sharding_tree,
                                        opt_state_specs, param_specs)
from repro.models import model as M
from repro.training import checkpoint as ckpt
from repro.training import grad_compress as gc
from repro.training.optim import AdamWConfig, adamw_update, init_opt_state
from repro.training.watchdog import StepWatchdog

F32 = jnp.float32


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    steps: int = 100
    seq_len: int = 256
    global_batch: int = 8
    microbatches: int = 1            # gradient accumulation
    opt: AdamWConfig = AdamWConfig()
    remat: bool = True
    grad_compress: str = "none"      # none | topk | int8
    topk_frac: float = 0.05
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 50
    log_every: int = 10


def make_train_step(cfg, tcfg: TrainConfig, mesh=None, batch_shapes=None):
    """Build the jitted (params, opt_state, err_state, batch) -> ... step."""

    def loss_of(params, batch):
        return M.loss_fn(params, cfg, batch, remat=tcfg.remat)

    def step(params, opt_state, err_state, batch):
        if tcfg.microbatches > 1:
            # split the batch on dim0 and accumulate grads over a scan —
            # activation memory drops by the microbatch factor
            def micro(acc, mb):
                (l, metrics), g = jax.value_and_grad(
                    loss_of, has_aux=True)(params, mb)
                return jax.tree.map(jnp.add, acc, g), (l, metrics)

            mbs = jax.tree.map(
                lambda a: a.reshape((tcfg.microbatches,
                                     a.shape[0] // tcfg.microbatches)
                                    + a.shape[1:]), batch)
            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, F32), params)
            grads, (losses, metrics) = jax.lax.scan(micro, zeros, mbs)
            grads = jax.tree.map(lambda g: g / tcfg.microbatches, grads)
            loss = losses.mean()
            metrics = jax.tree.map(lambda m: m.mean(), metrics)
        else:
            (loss, metrics), grads = jax.value_and_grad(
                loss_of, has_aux=True)(params, batch)

        if tcfg.grad_compress == "topk":
            grads, err_state = gc.topk_compress(grads, err_state,
                                                frac=tcfg.topk_frac)
        elif tcfg.grad_compress == "int8":
            grads, err_state = gc.int8_compress(grads, err_state)

        params, opt_state, om = adamw_update(tcfg.opt, params, grads,
                                             opt_state)
        metrics = {**metrics, **om, "loss_total": loss}
        return params, opt_state, err_state, metrics

    if mesh is None:
        return jax.jit(step, donate_argnums=(0, 1, 2))

    pspecs = param_specs(M.model_param_shapes(cfg), mesh)
    ospecs = opt_state_specs(M.model_param_shapes(cfg), mesh)
    bspecs = batch_specs(mesh, batch_shapes)
    espec = pspecs if tcfg.grad_compress != "none" else P()
    err_in = pspecs if tcfg.grad_compress != "none" else None
    return jax.jit(
        step,
        in_shardings=(pspecs, ospecs,
                      pspecs if tcfg.grad_compress != "none" else None,
                      bspecs),
        out_shardings=(pspecs, ospecs,
                       pspecs if tcfg.grad_compress != "none" else None,
                       None),
        donate_argnums=(0, 1, 2))


def train(cfg, tcfg: TrainConfig, *, seed=0, mesh=None, extra_batch=None,
          verbose=True):
    """Run the loop on the current devices. Returns (params, history).

    extra_batch: dict of static per-batch arrays (frames / patch_embeds
    stubs) merged into every step's batch.
    """
    key = jax.random.PRNGKey(seed)
    params = M.init_model(cfg, key)
    opt_state = init_opt_state(params)
    err_state = (gc.init_error_state(params)
                 if tcfg.grad_compress != "none" else None)

    start_step = 0
    if tcfg.ckpt_dir:
        latest = ckpt.latest_step(tcfg.ckpt_dir)
        if latest is not None:           # restart path
            (params, opt_state), start_step = ckpt.restore_checkpoint(
                tcfg.ckpt_dir, (params, opt_state), step=latest)

    pipe_batch = None
    step_fn = make_train_step(cfg, tcfg, mesh=mesh,
                              batch_shapes=pipe_batch)
    watchdog = StepWatchdog()
    writer = (ckpt.AsyncCheckpointer(tcfg.ckpt_dir)
              if tcfg.ckpt_dir else None)
    history = []

    # deterministic per-(step, shard) data — any host can regenerate any
    # shard after failover
    pipe = TokenPipeline(cfg.vocab_size, seq_len=tcfg.seq_len,
                         global_batch=tcfg.global_batch, seed=seed)

    for step in range(start_step, tcfg.steps):
        watchdog.step_start(step)
        data = pipe.batch(step)
        batch = {"tokens": jnp.asarray(data["tokens"]),
                 "labels": jnp.asarray(data["labels"])}
        if extra_batch:
            batch.update(extra_batch)
        params, opt_state, err_state, metrics = step_fn(
            params, opt_state, err_state, batch)
        metrics = jax.tree.map(float, jax.device_get(metrics))
        stat = watchdog.step_end(step)
        metrics["step_time"] = stat["dt"]
        history.append({"step": step, **metrics})
        if verbose and (step % tcfg.log_every == 0 or step == tcfg.steps - 1):
            print(f"step {step:5d} loss {metrics['loss_total']:.4f} "
                  f"xent {metrics['xent']:.4f} lr {metrics['lr']:.2e} "
                  f"dt {stat['dt']:.2f}s")
        if writer and (step + 1) % tcfg.ckpt_every == 0:
            writer.save(step + 1, (params, opt_state))
    if writer:
        writer.wait()
    return params, history
