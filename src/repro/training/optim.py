"""AdamW + schedules, pure JAX (self-contained, no optax).

State layout mirrors the param tree: {"m": tree, "v": tree, "step": int32}.
Optionally the second moment is kept in int8 blocks (``v_bits=8``) —
block-quantized with per-block scales (the 8-bit-optimizer trick) — which
matters at deepseek-v3 scale where fp32 Adam states dominate HBM.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

F32 = jnp.float32


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr_peak: float = 3e-4
    lr_min: float = 3e-5
    warmup_steps: int = 100
    total_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


def lr_at(cfg: AdamWConfig, step):
    """Linear warmup then cosine decay to lr_min."""
    step = step.astype(F32) if hasattr(step, "astype") else jnp.float32(step)
    warm = cfg.lr_peak * step / jnp.maximum(cfg.warmup_steps, 1)
    t = jnp.clip((step - cfg.warmup_steps)
                 / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
    cos = cfg.lr_min + 0.5 * (cfg.lr_peak - cfg.lr_min) * (1 + jnp.cos(jnp.pi * t))
    return jnp.where(step < cfg.warmup_steps, warm, cos)


def init_opt_state(params):
    zeros = lambda t: jax.tree.map(lambda p: jnp.zeros_like(p, F32), t)
    return {"m": zeros(params), "v": zeros(params),
            "step": jnp.zeros((), jnp.int32)}


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(l.astype(F32))) for l in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads, max_norm):
    g = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(g, 1e-9))
    return jax.tree.map(lambda x: x * scale, grads), g


def adamw_update(cfg: AdamWConfig, params, grads, state):
    """-> (new_params, new_state, metrics)."""
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    step = state["step"] + 1
    lr = lr_at(cfg, step)
    b1c = 1.0 - cfg.b1 ** step.astype(F32)
    b2c = 1.0 - cfg.b2 ** step.astype(F32)

    def upd(p, g, m, v):
        g = g.astype(F32)
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        mh = m / b1c
        vh = v / b2c
        newp = p - lr * (mh / (jnp.sqrt(vh) + cfg.eps)
                         + cfg.weight_decay * p)
        return newp.astype(p.dtype), m, v

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    out = [upd(p, g, m, v)
           for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = jax.tree.unflatten(tdef, [o[0] for o in out])
    new_state = {"m": jax.tree.unflatten(tdef, [o[1] for o in out]),
                 "v": jax.tree.unflatten(tdef, [o[2] for o in out]),
                 "step": step}
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}
