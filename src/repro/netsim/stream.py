"""Streaming flow-table tier: per-flow registers updated window by window.

The paper's challenge (ii) is extracting features *on the data plane*,
where packets arrive continuously and per-flow registers are updated
incrementally — a switch never sees the whole trace at once. This module
is that deployment shape (pForest's per-flow state across packet windows):

  register file   -> ``FlowTableState``: one array per switch register
                     (pkt/byte counts, first/last ts, fwd/rev splits)
  per-packet ALU  -> ``update_flow_table``: segment-scatter ops folding a
                     ``PacketWindow`` into the registers, jit/donation
                     friendly (all-array dataclasses)
  register readout-> ``flow_table_readout``: derives the same 8 feature
                     columns as the one-shot ``features.flow_features``
  recirculation   -> ``iter_windows``: chunks a PacketTrace into
                     fixed-size packet windows (tile-padded via
                     ``kernels.ops.pad_window`` so shapes stay static)

Bit-consistency contract (asserted by tests and the stream benchmark):
streaming over W windows reproduces the batch ``flow_features`` table on
the concatenated trace *bit for bit*, because

  * count/byte registers are integer-valued f32 sums — exact in any
    association order while magnitudes stay below 2^24 (≈16.7 MB per
    bucket; an eviction/aging policy is the ROADMAP follow-on);
  * first/last-timestamp registers are min/max — associative and exact;
  * duration / mean-IAT are *derived at readout* through the shared
    ``features.table_from_registers``, never accumulated.

Timestamps are rebased to the stream epoch ``t0`` in float64 before the
f32 cast, matching ``features.rebase_ts``. ``t0`` defaults to the trace's
*minimum* timestamp (the batch path's epoch), not the first packet seen —
a reordered first window would otherwise silently shift every rebased
value by the f32 rounding of a different base. Callers serving an
open-ended stream (who cannot pre-scan for the minimum) pass an explicit
provisional ``t0``; the sharded tier additionally carries the true epoch
as a min-merged register (``shard_stream``) so a mis-latched base is
corrected at readout.

Flow lifecycle (pForest-style aging) lives in the same register file:
``age_out`` resets buckets idle since before a cutoff back to the init
identities (via the masked-scatter ``kernels.ops.evict_fill``), and
``saturate_counts`` clamps count/byte registers at the 2^24 f32
integer-exactness envelope, returning a telemetry count so envelope
violations are visible instead of silently inexact.
"""

from __future__ import annotations

import dataclasses
from typing import Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.ops import evict_fill, pad_window
from repro.netsim.features import (fnv1a_hash, rebase_ts_np,
                                   table_from_registers)

FLOW_FEATURES = 8      # columns of the readout table == features.flow_features

# f32 integer-exactness envelope: count/byte registers are integer-valued
# f32 sums, exact only below 2^24. saturate_counts clamps here.
OVERFLOW_LIMIT = float(1 << 24)

# per-register init/evict identities, in FlowTableState field order
REGISTER_FIELDS = ("pkt_count", "byte_count", "t_min", "t_max",
                   "fwd_pkts", "rev_pkts", "fwd_bytes", "rev_bytes")
EVICT_FILLS = (0.0, 0.0, float("inf"), float("-inf"), 0.0, 0.0, 0.0, 0.0)
# registers under the 2^24 envelope (monotone f32 integer accumulators)
COUNT_FIELDS = ("pkt_count", "byte_count", "fwd_pkts", "rev_pkts",
                "fwd_bytes", "rev_bytes")


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class FlowTableState:
    """Register-file carry: one (n_buckets,) f32 array per switch register.

    t_min/t_max start at the segment_min/max identities (±inf) so an
    untouched bucket reads out exactly like one the batch path never saw.
    """
    pkt_count: jax.Array
    byte_count: jax.Array
    t_min: jax.Array
    t_max: jax.Array
    fwd_pkts: jax.Array
    rev_pkts: jax.Array
    fwd_bytes: jax.Array
    rev_bytes: jax.Array

    @property
    def n_buckets(self) -> int:
        return self.pkt_count.shape[0]


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class PacketWindow:
    """One fixed-size chunk of the packet stream, ready for the jitted step.

    ts is rebased f32 (see module docstring); is_fwd is 1.0 for forward
    direction; valid masks tile-pad lanes out of every register update.
    """
    bucket: jax.Array    # (W,) int32 flow-hash bucket ids
    ts: jax.Array        # (W,) f32 rebased seconds
    length: jax.Array    # (W,) f32 packet bytes
    is_fwd: jax.Array    # (W,) f32 1.0 = forward
    valid: jax.Array     # (W,) bool

    @property
    def size(self) -> int:
        return self.bucket.shape[0]


def init_flow_table(n_buckets: int) -> FlowTableState:
    # distinct buffers per register: donated steps may not alias arguments
    z = lambda: jnp.zeros((n_buckets,), jnp.float32)
    return FlowTableState(
        pkt_count=z(), byte_count=z(),
        t_min=jnp.full((n_buckets,), jnp.inf, jnp.float32),
        t_max=jnp.full((n_buckets,), -jnp.inf, jnp.float32),
        fwd_pkts=z(), rev_pkts=z(), fwd_bytes=z(), rev_bytes=z())


def update_flow_table(state: FlowTableState,
                      window: PacketWindow) -> FlowTableState:
    """Fold one window into the register file (pure; jit/donation safe).

    Sums ride masked scatter-adds *into the carry* (``.at[b].add``: an
    invalid lane adds exactly 0.0 — a bitwise no-op on the non-negative
    count registers); first/last ts ride scatter-min/max with invalid
    lanes pinned to the reduction identity. Under donation the scatters
    update the carried buffers in place — no per-window materialization
    of ``n_buckets``-sized temporaries, which dominated the old
    segment_sum formulation (zeroed (n_buckets,) output + full-array add
    per register, 8x per window). Bit-identical to the batch segment
    reductions in any association order while the registers stay in the
    integer-exactness envelope (counts below 2^24; min/max are exact
    always) — the same contract the streaming tier already documents.
    """
    b = window.bucket
    w = window.valid.astype(jnp.float32)
    inf = jnp.float32(jnp.inf)
    ln, fwd = window.length, window.is_fwd
    return FlowTableState(
        pkt_count=state.pkt_count.at[b].add(w),
        byte_count=state.byte_count.at[b].add(ln * w),
        t_min=state.t_min.at[b].min(jnp.where(window.valid, window.ts, inf)),
        t_max=state.t_max.at[b].max(jnp.where(window.valid, window.ts,
                                              -inf)),
        fwd_pkts=state.fwd_pkts.at[b].add(fwd * w),
        rev_pkts=state.rev_pkts.at[b].add((1.0 - fwd) * w),
        fwd_bytes=state.fwd_bytes.at[b].add(ln * fwd * w),
        rev_bytes=state.rev_bytes.at[b].add(ln * (1.0 - fwd) * w))


def age_out(state: FlowTableState, evict_before,
            *, use_pallas=None) -> tuple:
    """LRU/timeout eviction sweep: recycle buckets idle too long.

    A bucket whose last-seen timestamp (t_max) predates ``evict_before``
    is reset to the init identities — bit-identical to a bucket the
    stream never touched, so an evicted-then-reborn flow reads out
    exactly like a fresh one (``table_from_registers`` cannot tell them
    apart; tests assert this). Surviving buckets pass through untouched
    bit for bit. Returns (state, n_evicted i32).

    The reset rides ``kernels.ops.evict_fill`` — a masked scatter over
    the stacked register file (Pallas on TPU, jnp.where elsewhere) — so
    the sweep folds into the same jitted step as the window update.
    """
    evict = (state.pkt_count > 0) & (state.t_max
                                     < jnp.float32(evict_before))
    regs = jnp.stack([getattr(state, f) for f in REGISTER_FIELDS])
    fills = jnp.asarray(EVICT_FILLS, jnp.float32)
    out = evict_fill(regs, evict, fills, use_pallas=use_pallas)
    new = FlowTableState(**{f: out[i]
                            for i, f in enumerate(REGISTER_FIELDS)})
    return new, jnp.sum(evict.astype(jnp.int32))


def saturate_counts(state: FlowTableState, *, limit: float = OVERFLOW_LIMIT,
                    prev: Optional[FlowTableState] = None) -> tuple:
    """Overflow guard for the f32 integer-exactness envelope.

    Count/byte registers are integer-valued f32 accumulators — exact
    below 2^24, silently lossy above. Clamping at the limit is a bitwise
    no-op for every in-envelope register, so the guard can stay on in
    serving paths without perturbing the streaming-vs-batch equality;
    the returned i32 counts register slots *newly* saturated by this
    sweep (cumulative in ``StreamStats.overflow``), so the telemetry
    grows once per saturation event rather than re-counting every
    already-clamped slot each window (which inflated linearly with
    stream length). Returns (state, n_newly_saturated).

    ``prev`` is the register file at the start of the window (before
    ``update_flow_table``): a slot counts iff it reached the limit now
    but was below it then — exactly once per saturation event. The
    serving steps always pass it. Without ``prev`` the guard counts
    slots strictly *above* the limit (the clamp visibly changed them):
    an idle saturated slot (sitting exactly at the limit) is never
    re-counted, but one that keeps receiving traffic rises above the
    limit again each sweep and counts again — a per-sweep clamp-event
    count, not a once-only one. Pass ``prev`` when you need the latter.
    """
    lim = jnp.float32(limit)
    n_over = jnp.zeros((), jnp.int32)
    upd = {}
    for f in COUNT_FIELDS:
        r = getattr(state, f)
        if prev is not None:
            newly = (r >= lim) & (getattr(prev, f) < lim)
        else:
            newly = r > lim
        n_over = n_over + jnp.sum(newly.astype(jnp.int32))
        upd[f] = jnp.minimum(r, lim)
    return dataclasses.replace(state, **upd), n_over


# approx-LRU defaults: 2-bit age counters (pForest's choice) ranked by a
# 2-bit activity class — 16 score levels total
LRU_AGE_BITS = 2
LRU_ACT_BITS = 2

EVICT_POLICIES = ("timeout", "approx_lru")


def approx_lru_sweep(state: FlowTableState, w: "PacketWindow",
                     evict_age: float, *, occupancy: float = 0.75,
                     age_bits: int = LRU_AGE_BITS,
                     act_bits: int = LRU_ACT_BITS,
                     use_pallas=None) -> tuple:
    """pForest-style approx-LRU eviction: multi-bit age counters ranked by
    activity, swept only under occupancy pressure.

    The timeout sweep (``age_out``) evicts on idle time alone — under a
    DDoS flood of single-use flows it either churns the whole table (age
    too short) or lets dead flows squat until live ones cannot be
    admitted (age too long). This sweep instead ranks every occupied
    bucket by a small composite score and evicts only when (and only as
    much as) the table is under pressure:

      age class   = idle time quantized into ``2**age_bits`` levels so a
                    flow idle >= ``evict_age`` sits in the top class —
                    the multi-bit age counter of pForest's approx-LRU;
      activity    = ``log2(pkt_count)`` clipped to ``2**act_bits``
                    classes — bigger flows evict later within an age
                    class (flow-size ranking);
      score       = ``age_class * 2**act_bits + (2**act_bits - 1 -
                    act_class)``: oldest-then-smallest first.

    Nothing is evicted while occupancy (fraction of buckets with any
    packets) is at or below ``occupancy``. Above it, the smallest score
    threshold whose classes cover the excess is chosen from a score
    histogram and *every* bucket at or above it is recycled — class
    granularity is the "approx" in approx-LRU (the sweep may overshoot
    the high-water mark by up to one class). Flows seen in the current
    window are never evicted (same clamp discipline as ``evict_cutoff``),
    and an all-invalid (dead pad) window sweeps nothing. The reset rides
    the same masked-scatter ``kernels.ops.evict_fill`` as the timeout
    sweep. Returns (state, n_evicted i32).
    """
    n = state.n_buckets
    n_scores = 1 << (age_bits + act_bits)
    top_age = jnp.float32((1 << age_bits) - 1)
    top_act = jnp.float32((1 << act_bits) - 1)
    now = jnp.max(jnp.where(w.valid, w.ts, -jnp.inf))
    w_min = jnp.min(jnp.where(w.valid, w.ts, jnp.inf))
    occupied = state.pkt_count > 0
    n_occ = jnp.sum(occupied.astype(jnp.int32))
    high = jnp.int32(int(occupancy * n))
    pressure = jnp.any(w.valid) & (n_occ > high)
    # age/activity classes in float (inf-safe), cast after the clip
    period = jnp.float32(evict_age) / top_age
    idle = jnp.maximum(now - state.t_max, 0.0)
    age_cls = jnp.clip(jnp.floor(idle / period), 0.0, top_age)
    act_cls = jnp.clip(jnp.floor(jnp.log2(state.pkt_count + 1.0)),
                       0.0, top_act)
    score = (age_cls * (top_act + 1.0)
             + (top_act - act_cls)).astype(jnp.int32)
    protected = state.t_max >= w_min          # seen this window: survives
    eligible = occupied & ~protected
    score = jnp.where(eligible, score, -1)
    # smallest threshold whose classes cover the occupancy excess
    n_target = n_occ - high
    s = jnp.arange(n_scores, dtype=jnp.int32)
    counts = jnp.sum((score[None, :] == s[:, None]).astype(jnp.int32),
                     axis=1)
    cum = jnp.cumsum(counts[::-1])[::-1]      # cum[k] = #(score >= k)
    ok = cum >= n_target
    thr = jnp.where(jnp.any(ok), jnp.max(jnp.where(ok, s, -1)),
                    jnp.int32(0))
    evict = eligible & (score >= thr) & pressure
    regs = jnp.stack([getattr(state, f) for f in REGISTER_FIELDS])
    fills = jnp.asarray(EVICT_FILLS, jnp.float32)
    out = evict_fill(regs, evict, fills, use_pallas=use_pallas)
    new = FlowTableState(**{f: out[i]
                            for i, f in enumerate(REGISTER_FIELDS)})
    return new, jnp.sum(evict.astype(jnp.int32))


def evict_cutoff(ts, valid, evict_age: float):
    """Aging cutoff for one window: ``min(now - evict_age, window_min)``.

    Strictly no later than every timestamp in the window, so a flow seen
    in this window always survives it by construction — the single
    definition the reference sweep (``lifecycle_sweep``) and the chunked
    scan (``chunk_update_readout``) share; the bit-identity contract
    between the paths depends on the cutoff never diverging.
    """
    now = jnp.max(jnp.where(valid, ts, -jnp.inf))
    w_min = jnp.min(jnp.where(valid, ts, jnp.inf))
    return jnp.minimum(now - jnp.float32(evict_age), w_min)


def lifecycle_sweep(state: FlowTableState, w: "PacketWindow",
                    evict_age: Optional[float], saturate: bool,
                    prev: Optional[FlowTableState] = None, *,
                    evict_policy: str = "timeout",
                    lru_occupancy: float = 0.75) -> tuple:
    """Aging sweep + overflow guard for one served window.

    The single definition shared by the single-device and sharded serving
    steps — the sharded-vs-single-device bit-identity contract depends on
    the cutoff semantics never diverging between them. With the default
    ``evict_policy="timeout"`` the eviction cutoff is ``min(now -
    evict_age, window_min_ts)``: strictly no later than every timestamp
    in this window, so a flow seen in this window always survives it by
    construction, even when the window's time span exceeds ``evict_age``.
    ``evict_policy="approx_lru"`` substitutes the pressure-triggered
    pForest-style sweep (see ``approx_lru_sweep``; ``lru_occupancy`` is
    its high-water fraction) — same survive-this-window clamp, but
    eviction ranks age *and* activity and fires only above the occupancy
    mark. ``prev`` (the register file before this window's update) lets
    the overflow guard count only *newly* saturated slots — see
    ``saturate_counts``. Returns (state, n_evicted, n_overflow) — both
    counters zero when the corresponding feature is off.
    """
    n_ev = jnp.zeros((), jnp.int32)
    n_ov = jnp.zeros((), jnp.int32)
    if evict_policy not in EVICT_POLICIES:
        raise ValueError(f"evict_policy must be one of {EVICT_POLICIES}, "
                         f"got {evict_policy!r}")
    if evict_age is not None:
        if evict_policy == "approx_lru":
            state, n_ev = approx_lru_sweep(state, w, evict_age,
                                           occupancy=lru_occupancy)
        else:
            state, n_ev = age_out(state,
                                  evict_cutoff(w.ts, w.valid, evict_age))
    if saturate:
        state, n_ov = saturate_counts(state, prev=prev)
    return state, n_ev, n_ov


def flow_table_readout(state: FlowTableState,
                       bucket: Optional[jax.Array] = None) -> jax.Array:
    """Feature table from the registers — same columns as flow_features.

    bucket=None reads out every bucket -> (n_buckets, 8). Passing bucket
    ids gathers the 8 register vectors *first* and derives features on
    the gathered rows -> (len(bucket), 8): bit-identical (the derivation
    is elementwise) but ~n_buckets/len(bucket) less work — the serving
    step uses this to read out only the window's touched flows.
    """
    regs = (state.pkt_count, state.byte_count, state.t_min, state.t_max,
            state.fwd_pkts, state.rev_pkts, state.fwd_bytes,
            state.rev_bytes)
    if bucket is not None:
        regs = tuple(r[bucket] for r in regs)
    return table_from_registers(*regs)


def window_update_readout(state: FlowTableState, w: PacketWindow, *,
                          evict_age: Optional[float] = None,
                          saturate: bool = True,
                          evict_policy: str = "timeout",
                          lru_occupancy: float = 0.75,
                          use_pallas: Optional[bool] = None,
                          interpret: Optional[bool] = None) -> tuple:
    """Fold one window and read out its touched-flow feature rows.

    The serving steps' register half: update → aging sweep → overflow
    guard → touched-row readout, returning ``(state, x (W, 8), n_evicted,
    n_overflow)``. With ``use_pallas`` (default: auto, TPU only) the
    scatter-update, the 2^24 clamp and the touched-row gather run as ONE
    fused VMEM pass (``kernels.stream_update``) instead of scattering to
    HBM and gathering back; the XLA composition is the bit-equality
    oracle. The fusion is exact because

      * eviction cannot touch this window's rows (``evict_cutoff`` is
        clamped to the window minimum, and the approx-LRU sweep protects
        flows seen this window, so a flow seen here never evicts here) —
        sweeping *after* the gather reads the same bits;
      * clamping commutes with eviction (fills are in-envelope) and
        ``saturate_counts`` on an already-clamped file is a bitwise no-op
        that still counts newly saturated slots against ``prev``.
    """
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    prev = state
    if not use_pallas:
        state = update_flow_table(state, w)
        state, n_ev, n_ov = lifecycle_sweep(state, w, evict_age, saturate,
                                            prev=prev,
                                            evict_policy=evict_policy,
                                            lru_occupancy=lru_occupancy)
        return state, flow_table_readout(state, w.bucket), n_ev, n_ov
    from repro.kernels.ops import stream_update
    regs = jnp.stack([getattr(state, f) for f in REGISTER_FIELDS])
    new_regs, rows = stream_update(
        regs, w.bucket, w.ts, w.length, w.is_fwd, w.valid,
        limit=OVERFLOW_LIMIT if saturate else None, interpret=interpret)
    state = FlowTableState(**{f: new_regs[i]
                              for i, f in enumerate(REGISTER_FIELDS)})
    # the shared sweep: the aging cutoff cannot touch this window's rows
    # and the clamp already landed in-kernel (saturate_counts is then a
    # bitwise no-op that still counts newly saturated slots vs ``prev``)
    state, n_ev, n_ov = lifecycle_sweep(state, w, evict_age, saturate,
                                        prev=prev,
                                        evict_policy=evict_policy,
                                        lru_occupancy=lru_occupancy)
    x = table_from_registers(*(rows[i] for i in range(len(REGISTER_FIELDS))))
    return state, x, n_ev, n_ov


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class PacketChunk:
    """K windows stacked into one (K, W) device transfer.

    The chunked serving path (`serving.stream_serving`) runs the whole
    chunk as a single jitted ``lax.scan`` megastep, so the per-window
    Python→device dispatch cost is paid once per K windows. Leading-axis
    slices are exactly the ``PacketWindow``s the per-window path would
    have seen (the bit-equality oracle depends on this); a ragged final
    chunk is padded with *dead* windows — every lane invalid — which fold
    nothing into the registers, dispatch nothing, and report -1
    predictions on every lane.
    """
    bucket: jax.Array    # (K, W) int32 flow-hash bucket ids
    ts: jax.Array        # (K, W) f32 rebased seconds
    length: jax.Array    # (K, W) f32 packet bytes
    is_fwd: jax.Array    # (K, W) f32 1.0 = forward
    valid: jax.Array     # (K, W) bool (all-False row = dead pad window)

    @property
    def n_windows(self) -> int:
        return self.bucket.shape[0]

    @property
    def window(self) -> int:
        return self.bucket.shape[1]


def trace_columns(trace, n_buckets: int, *, t0: Optional[float] = None,
                  bucket=None) -> tuple:
    """Host-side per-packet columns shared by every window/chunk iterator
    AND the open-ended ingest ring (``netsim.ingest``). -> (cols, t0_used).

    Rebasing stays in float64 on host (see module docstring) and the
    bucket hash is elementwise (order-free), so every consumer — batch,
    per-window, chunked, or ring-buffered — presents bit-identical lanes
    to the jitted steps. t0=None latches the batch's minimum timestamp;
    the returned t0_used lets an open-ended caller latch it once on the
    first batch and rebase every later batch against the same epoch.
    """
    ts64 = np.asarray(trace.ts, np.float64)
    if t0 is None:
        t0 = float(ts64.min()) if ts64.size else 0.0
    if bucket is None:
        bucket = fnv1a_hash(
            trace.src_ip, trace.dst_ip, trace.sport, trace.dport,
            trace.proto, n_buckets=n_buckets)
    return dict(bucket=np.asarray(bucket, np.int32),
                ts=rebase_ts_np(ts64, t0),
                length=np.asarray(trace.length, np.float32),
                is_fwd=(np.asarray(trace.direction) == 0)
                .astype(np.float32)), t0


def _trace_columns(trace, n_buckets: int, t0: Optional[float], bucket):
    cols, _ = trace_columns(trace, n_buckets, t0=t0, bucket=bucket)
    return cols


def _pad_columns(cols: dict, n: int, total: int) -> dict:
    """Pad each (n,) column to ``total`` lanes replicating the last packet
    — the same in-distribution discipline as ``kernels.ops.pad_window``,
    applied once to the whole trace instead of per window."""
    if total == n:
        return cols
    return {k: np.concatenate([v, np.repeat(v[n - 1:n], total - n, axis=0)])
            for k, v in cols.items()}


def pack_chunk_columns(cols: dict, n: int, window: int, rows: int) -> tuple:
    """Pack ``n`` packets of host columns into ``rows`` windows of
    ``window`` lanes. -> (full_cols, valid) as flat (rows*window,) arrays.

    The single padding discipline shared by ``iter_chunks`` and the
    ingest ring's deadline/drain cuts (``netsim.ingest``): the ragged
    final *live* window replicate-pads the last packet (valid=False on
    the pad lanes), and any windows beyond the live ones are *dead* —
    all-zero columns, every lane invalid — so they fold nothing into the
    registers, dispatch nothing, and report -1 on every lane. Both
    callers produce bitwise-identical chunks because this is the only
    place the layout is defined.
    """
    n_win = -(-n // window) if n else 0
    if n_win > rows:
        raise ValueError(f"{n} packets need {n_win} windows of {window} "
                         f"lanes, only {rows} rows available")
    live = _pad_columns(cols, n, n_win * window)
    full = {k: np.zeros((rows * window,), v.dtype) for k, v in live.items()}
    for k, v in live.items():
        full[k][:n_win * window] = v
    valid = np.zeros((rows * window,), bool)
    valid[:n_win * window] = np.arange(n_win * window) < n
    return full, valid


def chunk_update_readout(state: FlowTableState, chunk: PacketChunk, *,
                         evict_age: Optional[float] = None,
                         saturate: bool = True,
                         evict_policy: str = "timeout",
                         lru_occupancy: float = 0.75,
                         use_pallas: Optional[bool] = None) -> tuple:
    """Whole-chunk sequential register half: fold K windows, emit rows.

    The chunked megastep's core — fold each of the chunk's K windows into
    the register file in order and stack the (W, 8) touched-row readouts
    as ``xs (K, W, 8)``; everything row-wise (classify, dispatch) runs on
    the stacked rows *after* this returns. Returns
    ``(state, xs, n_evicted, n_overflow)``, bit-identical to K
    ``window_update_readout`` steps.

    The XLA realization keeps the lax.scan body to the irreducibly
    sequential five memory ops — scatter-add the counts, scatter-min the
    2^24 clamp, scatter-min/max the timestamps, gather the touched rows —
    by moving everything window-local out of the loop: per-lane
    contribution vectors and identity-pinned timestamps are precomputed
    for the whole chunk (vectorized scan inputs), the six count
    registers ride ONE packed (N, 6) array and the two timestamp
    registers one (N, 2) array (t_min and *negated* t_max share a single
    scatter-min), and the feature derivation runs once over the stacked
    (K*W, 8) raw rows after the scan. Clamping only touched rows equals
    the per-window full-file clamp because the guard's invariant (every
    count <= 2^24 after every window, from init 0) makes it a no-op
    elsewhere. On TPU (``use_pallas``) the scan body is the fused Pallas
    scatter/readout kernel instead — the packing would only
    re-materialize what the kernel already holds in VMEM.

    Overflow telemetry is counted once per chunk from the entry/exit
    register files — exact, because clamped counts are monotone so a
    slot crosses the envelope at most once per chunk — except when
    eviction is also on (an evicted slot could re-cross), where a
    carried below-envelope mask restores exact per-window counting.
    """
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    # the packed fast path below inlines *timeout* eviction into the scan
    # body; the approx-LRU sweep (histogram + threshold per window) runs
    # through the generic per-window body instead — same shape as the
    # Pallas branch, still one jitted scan megastep
    generic = use_pallas or (evict_age is not None
                             and evict_policy != "timeout")
    if generic:
        def body(state, cw):
            w = PacketWindow(bucket=cw.bucket, ts=cw.ts, length=cw.length,
                             is_fwd=cw.is_fwd, valid=cw.valid)
            state, x, n_ev, n_ov = window_update_readout(
                state, w, evict_age=evict_age, saturate=saturate,
                evict_policy=evict_policy, lru_occupancy=lru_occupancy,
                use_pallas=use_pallas)
            return state, (x, n_ev, n_ov)
        state, (xs, n_evs, n_ovs) = jax.lax.scan(body, state, chunk)
        return state, xs, jnp.sum(n_evs), jnp.sum(n_ovs)

    lim = jnp.float32(OVERFLOW_LIMIT)
    inf = jnp.float32(jnp.inf)
    k, w_lanes = chunk.bucket.shape
    # whole-chunk precompute: masked contribution vectors and pinned
    # timestamps enter the scan as vectorized inputs, not body ops
    wt = chunk.valid.astype(jnp.float32)
    ln, fwd = chunk.length, chunk.is_fwd
    vals = jnp.stack([wt, ln * wt, fwd * wt, (1.0 - fwd) * wt,
                      ln * fwd * wt, ln * (1.0 - fwd) * wt], axis=2)
    # t_min and -t_max share one packed scatter-min / gather
    tpin = jnp.stack([jnp.where(chunk.valid, chunk.ts, inf),
                      -jnp.where(chunk.valid, chunk.ts, -inf)], axis=2)
    lim_rows = jnp.full((w_lanes, 6), lim)
    counts0 = jnp.stack([getattr(state, f) for f in COUNT_FIELDS], axis=1)
    tmm0 = jnp.stack([state.t_min, -state.t_max], axis=1)
    # exact per-window overflow counting is only needed when eviction can
    # reset a saturated slot mid-chunk (see docstring)
    track_below = saturate and evict_age is not None
    carry = (counts0, tmm0,
             counts0 < lim if track_below else jnp.zeros((), jnp.int32),
             jnp.zeros((), jnp.int32), jnp.zeros((), jnp.int32))

    def body(carry, xs_in):
        counts, tmm, below, n_ev, n_ov = carry
        b, v, tp, valid, ts = xs_in
        counts = counts.at[b].add(v)
        if saturate:                       # clamp touched rows in place
            counts = counts.at[b].min(lim_rows)
        tmm = tmm.at[b].min(tp)
        if evict_age is not None:
            cutoff = evict_cutoff(ts, valid, evict_age)
            evict = (counts[:, 0] > 0) & (-tmm[:, 1] < cutoff)
            n_ev = n_ev + jnp.sum(evict.astype(jnp.int32))
            counts = jnp.where(evict[:, None], 0.0, counts)
            tmm = jnp.where(evict[:, None], inf, tmm)
        if track_below:
            n_ov = n_ov + jnp.sum(((counts >= lim) & below)
                                  .astype(jnp.int32))
            below = counts < lim
        x = jnp.concatenate([counts[b], tmm[b]], axis=1)   # raw (W, 8)
        return (counts, tmm, below, n_ev, n_ov), x

    (counts, tmm, _, n_ev, n_ov), raw = jax.lax.scan(
        body, carry, (chunk.bucket, vals, tpin, chunk.valid, chunk.ts))
    if saturate and not track_below:       # once per chunk: exact (monotone)
        n_ov = jnp.sum(((counts >= lim) & (counts0 < lim))
                       .astype(jnp.int32))
    raw = raw.reshape(k * w_lanes, 8)      # derive features post-scan
    xs = table_from_registers(raw[:, 0], raw[:, 1], raw[:, 6], -raw[:, 7],
                              raw[:, 2], raw[:, 3], raw[:, 4], raw[:, 5]
                              ).reshape(k, w_lanes, FLOW_FEATURES)
    state = FlowTableState(
        t_min=tmm[:, 0], t_max=-tmm[:, 1],
        **{f: counts[:, i] for i, f in enumerate(COUNT_FIELDS)})
    return state, xs, n_ev, n_ov


def iter_windows(trace, window: int, n_buckets: int, *,
                 t0: Optional[float] = None, bucket=None,
                 pad: bool = True, device: bool = True
                 ) -> Iterator[PacketWindow]:
    """Chunk a PacketTrace into fixed-size PacketWindows.

    Hashing is elementwise (order-free), so per-window bucket ids equal
    the batch path's; pass ``bucket`` to reuse an already-computed full-
    trace hash. t0 is the stream epoch every window rebases against; it
    defaults to the trace's *minimum* timestamp — the batch path's epoch,
    so reordered packets rebase identically to ``flow_features`` (latching
    the first packet instead shifted every f32 rounding when the stream
    opened out of order). Callers that cannot pre-scan an open-ended
    stream pass an explicit provisional t0; the sharded tier min-merges
    the true epoch as a register and corrects at readout. pad=True
    tile-pads the final ragged window to ``window`` lanes (valid=False)
    so every window presents one static shape to jitted consumers.

    device=True (default) transfers each column ONCE and slices windows
    on device — the per-window cost drops from four host→device copies
    to one row slice of a resident (n_windows, W) array. device=False
    keeps the host-slicing path for open-ended streams that are fed
    window by window and can never be materialized whole; pad=False
    implies it (a ragged window has no static device shape).
    """
    cols = _trace_columns(trace, n_buckets, t0, bucket)
    n = len(cols["ts"])
    if not pad:
        device = False
    if device:
        if not n:
            return
        n_win = -(-n // window)
        cols = _pad_columns(cols, n, n_win * window)
        dev = {k: jnp.asarray(v.reshape(n_win, window))
               for k, v in cols.items()}
        valid = jnp.asarray(
            (np.arange(n_win * window) < n).reshape(n_win, window))
        for k in range(n_win):
            yield PacketWindow(valid=valid[k],
                               **{f: dev[f][k] for f in dev})
        return
    for s in range(0, n, window):
        sl = slice(s, s + window)
        w_cols = {k: jnp.asarray(v[sl]) for k, v in cols.items()}
        if pad:
            w_cols, valid, _ = pad_window(w_cols, window)
        else:
            valid = jnp.ones(w_cols["bucket"].shape[0], bool)
        yield PacketWindow(valid=valid, **w_cols)


def iter_chunks(trace, window: int, chunk_windows: int, n_buckets: int, *,
                t0: Optional[float] = None, bucket=None
                ) -> Iterator[PacketChunk]:
    """Stack the trace's windows K at a time into (K, W) PacketChunks.

    One device transfer per column for the whole trace, one row-range
    slice per chunk — the host never touches per-window data again. Row
    k of a chunk is bit-identical to the k-th ``iter_windows`` window
    (same padding discipline, same rebase); the final chunk is padded to
    K rows with dead windows (valid all-False) so every chunk presents
    one static (K, W) shape to the jitted scan megastep.
    """
    cols = _trace_columns(trace, n_buckets, t0, bucket)
    n = len(cols["ts"])
    if not n:
        return
    n_win = -(-n // window)
    n_chunks = -(-n_win // chunk_windows)
    rows = n_chunks * chunk_windows
    # shared packing discipline (ragged live window replicate-pads, dead
    # pad windows are all-zero/invalid) — see pack_chunk_columns
    full, valid = pack_chunk_columns(cols, n, window, rows)
    dev = {k: jnp.asarray(v.reshape(rows, window)) for k, v in full.items()}
    valid = jnp.asarray(valid.reshape(rows, window))
    for c in range(n_chunks):
        sl = slice(c * chunk_windows, (c + 1) * chunk_windows)
        yield PacketChunk(valid=valid[sl], **{f: dev[f][sl] for f in dev})


# module-level so repeated stream_flow_features calls share the jit cache
_update_flow_table_jit = jax.jit(update_flow_table, donate_argnums=0)


def stream_flow_features(trace, n_buckets=4096, window=1024, *,
                         t0: Optional[float] = None):
    """One-shot convenience: stream the whole trace window by window.

    Returns (bucket_ids (P,), flow_table (n_buckets, 8)) — bit-consistent
    with ``features.flow_features`` on the same trace (the equivalence
    oracle used by tests and benchmarks/stream_bench.py). t0 overrides
    the stream epoch (default: trace minimum, matching the batch path
    even when packets arrive out of order).
    """
    b = fnv1a_hash(trace.src_ip, trace.dst_ip, trace.sport, trace.dport,
                   trace.proto, n_buckets=n_buckets)
    state = init_flow_table(n_buckets)
    for w in iter_windows(trace, window, n_buckets, bucket=b, t0=t0):
        state = _update_flow_table_jit(state, w)
    return b, flow_table_readout(state)
