"""Streaming flow-table tier: per-flow registers updated window by window.

The paper's challenge (ii) is extracting features *on the data plane*,
where packets arrive continuously and per-flow registers are updated
incrementally — a switch never sees the whole trace at once. This module
is that deployment shape (pForest's per-flow state across packet windows):

  register file   -> ``FlowTableState``: one array per switch register
                     (pkt/byte counts, first/last ts, fwd/rev splits)
  per-packet ALU  -> ``update_flow_table``: segment-scatter ops folding a
                     ``PacketWindow`` into the registers, jit/donation
                     friendly (all-array dataclasses)
  register readout-> ``flow_table_readout``: derives the same 8 feature
                     columns as the one-shot ``features.flow_features``
  recirculation   -> ``iter_windows``: chunks a PacketTrace into
                     fixed-size packet windows (tile-padded via
                     ``kernels.ops.pad_window`` so shapes stay static)

Bit-consistency contract (asserted by tests and the stream benchmark):
streaming over W windows reproduces the batch ``flow_features`` table on
the concatenated trace *bit for bit*, because

  * count/byte registers are integer-valued f32 sums — exact in any
    association order while magnitudes stay below 2^24 (≈16.7 MB per
    bucket; an eviction/aging policy is the ROADMAP follow-on);
  * first/last-timestamp registers are min/max — associative and exact;
  * duration / mean-IAT are *derived at readout* through the shared
    ``features.table_from_registers``, never accumulated.

Timestamps are rebased to the stream epoch ``t0`` (first packet seen) in
float64 before the f32 cast, matching ``features.rebase_ts``; packets are
assumed to arrive in time order, so the first packet carries the minimum.
"""

from __future__ import annotations

import dataclasses
from typing import Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.ops import pad_window
from repro.netsim.features import (fnv1a_hash, rebase_ts_np,
                                   table_from_registers)

FLOW_FEATURES = 8      # columns of the readout table == features.flow_features


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class FlowTableState:
    """Register-file carry: one (n_buckets,) f32 array per switch register.

    t_min/t_max start at the segment_min/max identities (±inf) so an
    untouched bucket reads out exactly like one the batch path never saw.
    """
    pkt_count: jax.Array
    byte_count: jax.Array
    t_min: jax.Array
    t_max: jax.Array
    fwd_pkts: jax.Array
    rev_pkts: jax.Array
    fwd_bytes: jax.Array
    rev_bytes: jax.Array

    @property
    def n_buckets(self) -> int:
        return self.pkt_count.shape[0]


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class PacketWindow:
    """One fixed-size chunk of the packet stream, ready for the jitted step.

    ts is rebased f32 (see module docstring); is_fwd is 1.0 for forward
    direction; valid masks tile-pad lanes out of every register update.
    """
    bucket: jax.Array    # (W,) int32 flow-hash bucket ids
    ts: jax.Array        # (W,) f32 rebased seconds
    length: jax.Array    # (W,) f32 packet bytes
    is_fwd: jax.Array    # (W,) f32 1.0 = forward
    valid: jax.Array     # (W,) bool

    @property
    def size(self) -> int:
        return self.bucket.shape[0]


def init_flow_table(n_buckets: int) -> FlowTableState:
    # distinct buffers per register: donated steps may not alias arguments
    z = lambda: jnp.zeros((n_buckets,), jnp.float32)
    return FlowTableState(
        pkt_count=z(), byte_count=z(),
        t_min=jnp.full((n_buckets,), jnp.inf, jnp.float32),
        t_max=jnp.full((n_buckets,), -jnp.inf, jnp.float32),
        fwd_pkts=z(), rev_pkts=z(), fwd_bytes=z(), rev_bytes=z())


def update_flow_table(state: FlowTableState,
                      window: PacketWindow) -> FlowTableState:
    """Fold one window into the register file (pure; jit/donation safe).

    Sums ride masked segment_sum; first/last ts ride segment_min/max with
    invalid lanes pinned to the identity, then merge into the carry with
    elementwise min/max — the exact streaming decomposition of the batch
    segment reductions.
    """
    b, n = window.bucket, state.n_buckets
    w = window.valid.astype(jnp.float32)
    seg = lambda v: jax.ops.segment_sum(v, b, num_segments=n)
    inf = jnp.float32(jnp.inf)
    w_min = jax.ops.segment_min(jnp.where(window.valid, window.ts, inf),
                                b, num_segments=n)
    w_max = jax.ops.segment_max(jnp.where(window.valid, window.ts, -inf),
                                b, num_segments=n)
    ln, fwd = window.length, window.is_fwd
    return FlowTableState(
        pkt_count=state.pkt_count + seg(w),
        byte_count=state.byte_count + seg(ln * w),
        t_min=jnp.minimum(state.t_min, w_min),
        t_max=jnp.maximum(state.t_max, w_max),
        fwd_pkts=state.fwd_pkts + seg(fwd * w),
        rev_pkts=state.rev_pkts + seg((1.0 - fwd) * w),
        fwd_bytes=state.fwd_bytes + seg(ln * fwd * w),
        rev_bytes=state.rev_bytes + seg(ln * (1.0 - fwd) * w))


def flow_table_readout(state: FlowTableState,
                       bucket: Optional[jax.Array] = None) -> jax.Array:
    """Feature table from the registers — same columns as flow_features.

    bucket=None reads out every bucket -> (n_buckets, 8). Passing bucket
    ids gathers the 8 register vectors *first* and derives features on
    the gathered rows -> (len(bucket), 8): bit-identical (the derivation
    is elementwise) but ~n_buckets/len(bucket) less work — the serving
    step uses this to read out only the window's touched flows.
    """
    regs = (state.pkt_count, state.byte_count, state.t_min, state.t_max,
            state.fwd_pkts, state.rev_pkts, state.fwd_bytes,
            state.rev_bytes)
    if bucket is not None:
        regs = tuple(r[bucket] for r in regs)
    return table_from_registers(*regs)


def iter_windows(trace, window: int, n_buckets: int, *,
                 t0: Optional[float] = None, bucket=None,
                 pad: bool = True) -> Iterator[PacketWindow]:
    """Chunk a PacketTrace into fixed-size PacketWindows.

    Hashing is elementwise (order-free), so per-window bucket ids equal
    the batch path's; pass ``bucket`` to reuse an already-computed full-
    trace hash. t0 defaults to the first packet's timestamp — the stream
    epoch a switch would latch; pass the concatenated trace's minimum
    explicitly if packets are out of order. pad=True tile-pads the final
    ragged window to ``window`` lanes (valid=False) so every window
    presents one static shape to jitted consumers.
    """
    ts64 = np.asarray(trace.ts, np.float64)
    if t0 is None:
        t0 = float(ts64[0]) if ts64.size else 0.0
    rel = rebase_ts_np(ts64, t0)
    if bucket is None:
        bucket = fnv1a_hash(
            trace.src_ip, trace.dst_ip, trace.sport, trace.dport,
            trace.proto, n_buckets=n_buckets)
    bucket = np.asarray(bucket)
    length = np.asarray(trace.length, np.float32)
    is_fwd = (np.asarray(trace.direction) == 0).astype(np.float32)
    for s in range(0, len(rel), window):
        sl = slice(s, s + window)
        cols = dict(bucket=jnp.asarray(bucket[sl]), ts=jnp.asarray(rel[sl]),
                    length=jnp.asarray(length[sl]),
                    is_fwd=jnp.asarray(is_fwd[sl]))
        if pad:
            cols, valid, _ = pad_window(cols, window)
        else:
            valid = jnp.ones(cols["bucket"].shape[0], bool)
        yield PacketWindow(valid=valid, **cols)


# module-level so repeated stream_flow_features calls share the jit cache
_update_flow_table_jit = jax.jit(update_flow_table, donate_argnums=0)


def stream_flow_features(trace, n_buckets=4096, window=1024):
    """One-shot convenience: stream the whole trace window by window.

    Returns (bucket_ids (P,), flow_table (n_buckets, 8)) — bit-consistent
    with ``features.flow_features`` on the same trace (the equivalence
    oracle used by tests and benchmarks/stream_bench.py).
    """
    b = fnv1a_hash(trace.src_ip, trace.dst_ip, trace.sport, trace.dport,
                   trace.proto, n_buckets=n_buckets)
    state = init_flow_table(n_buckets)
    for w in iter_windows(trace, window, n_buckets, bucket=b):
        state = _update_flow_table_jit(state, w)
    return b, flow_table_readout(state)
