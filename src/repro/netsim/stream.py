"""Streaming flow-table tier: per-flow registers updated window by window.

The paper's challenge (ii) is extracting features *on the data plane*,
where packets arrive continuously and per-flow registers are updated
incrementally — a switch never sees the whole trace at once. This module
is that deployment shape (pForest's per-flow state across packet windows):

  register file   -> ``FlowTableState``: one array per switch register
                     (pkt/byte counts, first/last ts, fwd/rev splits)
  per-packet ALU  -> ``update_flow_table``: segment-scatter ops folding a
                     ``PacketWindow`` into the registers, jit/donation
                     friendly (all-array dataclasses)
  register readout-> ``flow_table_readout``: derives the same 8 feature
                     columns as the one-shot ``features.flow_features``
  recirculation   -> ``iter_windows``: chunks a PacketTrace into
                     fixed-size packet windows (tile-padded via
                     ``kernels.ops.pad_window`` so shapes stay static)

Bit-consistency contract (asserted by tests and the stream benchmark):
streaming over W windows reproduces the batch ``flow_features`` table on
the concatenated trace *bit for bit*, because

  * count/byte registers are integer-valued f32 sums — exact in any
    association order while magnitudes stay below 2^24 (≈16.7 MB per
    bucket; an eviction/aging policy is the ROADMAP follow-on);
  * first/last-timestamp registers are min/max — associative and exact;
  * duration / mean-IAT are *derived at readout* through the shared
    ``features.table_from_registers``, never accumulated.

Timestamps are rebased to the stream epoch ``t0`` in float64 before the
f32 cast, matching ``features.rebase_ts``. ``t0`` defaults to the trace's
*minimum* timestamp (the batch path's epoch), not the first packet seen —
a reordered first window would otherwise silently shift every rebased
value by the f32 rounding of a different base. Callers serving an
open-ended stream (who cannot pre-scan for the minimum) pass an explicit
provisional ``t0``; the sharded tier additionally carries the true epoch
as a min-merged register (``shard_stream``) so a mis-latched base is
corrected at readout.

Flow lifecycle (pForest-style aging) lives in the same register file:
``age_out`` resets buckets idle since before a cutoff back to the init
identities (via the masked-scatter ``kernels.ops.evict_fill``), and
``saturate_counts`` clamps count/byte registers at the 2^24 f32
integer-exactness envelope, returning a telemetry count so envelope
violations are visible instead of silently inexact.
"""

from __future__ import annotations

import dataclasses
from typing import Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.ops import evict_fill, pad_window
from repro.netsim.features import (fnv1a_hash, rebase_ts_np,
                                   table_from_registers)

FLOW_FEATURES = 8      # columns of the readout table == features.flow_features

# f32 integer-exactness envelope: count/byte registers are integer-valued
# f32 sums, exact only below 2^24. saturate_counts clamps here.
OVERFLOW_LIMIT = float(1 << 24)

# per-register init/evict identities, in FlowTableState field order
REGISTER_FIELDS = ("pkt_count", "byte_count", "t_min", "t_max",
                   "fwd_pkts", "rev_pkts", "fwd_bytes", "rev_bytes")
EVICT_FILLS = (0.0, 0.0, float("inf"), float("-inf"), 0.0, 0.0, 0.0, 0.0)
# registers under the 2^24 envelope (monotone f32 integer accumulators)
COUNT_FIELDS = ("pkt_count", "byte_count", "fwd_pkts", "rev_pkts",
                "fwd_bytes", "rev_bytes")


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class FlowTableState:
    """Register-file carry: one (n_buckets,) f32 array per switch register.

    t_min/t_max start at the segment_min/max identities (±inf) so an
    untouched bucket reads out exactly like one the batch path never saw.
    """
    pkt_count: jax.Array
    byte_count: jax.Array
    t_min: jax.Array
    t_max: jax.Array
    fwd_pkts: jax.Array
    rev_pkts: jax.Array
    fwd_bytes: jax.Array
    rev_bytes: jax.Array

    @property
    def n_buckets(self) -> int:
        return self.pkt_count.shape[0]


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class PacketWindow:
    """One fixed-size chunk of the packet stream, ready for the jitted step.

    ts is rebased f32 (see module docstring); is_fwd is 1.0 for forward
    direction; valid masks tile-pad lanes out of every register update.
    """
    bucket: jax.Array    # (W,) int32 flow-hash bucket ids
    ts: jax.Array        # (W,) f32 rebased seconds
    length: jax.Array    # (W,) f32 packet bytes
    is_fwd: jax.Array    # (W,) f32 1.0 = forward
    valid: jax.Array     # (W,) bool

    @property
    def size(self) -> int:
        return self.bucket.shape[0]


def init_flow_table(n_buckets: int) -> FlowTableState:
    # distinct buffers per register: donated steps may not alias arguments
    z = lambda: jnp.zeros((n_buckets,), jnp.float32)
    return FlowTableState(
        pkt_count=z(), byte_count=z(),
        t_min=jnp.full((n_buckets,), jnp.inf, jnp.float32),
        t_max=jnp.full((n_buckets,), -jnp.inf, jnp.float32),
        fwd_pkts=z(), rev_pkts=z(), fwd_bytes=z(), rev_bytes=z())


def update_flow_table(state: FlowTableState,
                      window: PacketWindow) -> FlowTableState:
    """Fold one window into the register file (pure; jit/donation safe).

    Sums ride masked segment_sum; first/last ts ride segment_min/max with
    invalid lanes pinned to the identity, then merge into the carry with
    elementwise min/max — the exact streaming decomposition of the batch
    segment reductions.
    """
    b, n = window.bucket, state.n_buckets
    w = window.valid.astype(jnp.float32)
    seg = lambda v: jax.ops.segment_sum(v, b, num_segments=n)
    inf = jnp.float32(jnp.inf)
    w_min = jax.ops.segment_min(jnp.where(window.valid, window.ts, inf),
                                b, num_segments=n)
    w_max = jax.ops.segment_max(jnp.where(window.valid, window.ts, -inf),
                                b, num_segments=n)
    ln, fwd = window.length, window.is_fwd
    return FlowTableState(
        pkt_count=state.pkt_count + seg(w),
        byte_count=state.byte_count + seg(ln * w),
        t_min=jnp.minimum(state.t_min, w_min),
        t_max=jnp.maximum(state.t_max, w_max),
        fwd_pkts=state.fwd_pkts + seg(fwd * w),
        rev_pkts=state.rev_pkts + seg((1.0 - fwd) * w),
        fwd_bytes=state.fwd_bytes + seg(ln * fwd * w),
        rev_bytes=state.rev_bytes + seg(ln * (1.0 - fwd) * w))


def age_out(state: FlowTableState, evict_before,
            *, use_pallas=None) -> tuple:
    """LRU/timeout eviction sweep: recycle buckets idle too long.

    A bucket whose last-seen timestamp (t_max) predates ``evict_before``
    is reset to the init identities — bit-identical to a bucket the
    stream never touched, so an evicted-then-reborn flow reads out
    exactly like a fresh one (``table_from_registers`` cannot tell them
    apart; tests assert this). Surviving buckets pass through untouched
    bit for bit. Returns (state, n_evicted i32).

    The reset rides ``kernels.ops.evict_fill`` — a masked scatter over
    the stacked register file (Pallas on TPU, jnp.where elsewhere) — so
    the sweep folds into the same jitted step as the window update.
    """
    evict = (state.pkt_count > 0) & (state.t_max
                                     < jnp.float32(evict_before))
    regs = jnp.stack([getattr(state, f) for f in REGISTER_FIELDS])
    fills = jnp.asarray(EVICT_FILLS, jnp.float32)
    out = evict_fill(regs, evict, fills, use_pallas=use_pallas)
    new = FlowTableState(**{f: out[i]
                            for i, f in enumerate(REGISTER_FIELDS)})
    return new, jnp.sum(evict.astype(jnp.int32))


def saturate_counts(state: FlowTableState, *, limit: float = OVERFLOW_LIMIT,
                    prev: Optional[FlowTableState] = None) -> tuple:
    """Overflow guard for the f32 integer-exactness envelope.

    Count/byte registers are integer-valued f32 accumulators — exact
    below 2^24, silently lossy above. Clamping at the limit is a bitwise
    no-op for every in-envelope register, so the guard can stay on in
    serving paths without perturbing the streaming-vs-batch equality;
    the returned i32 counts register slots *newly* saturated by this
    sweep (cumulative in ``StreamStats.overflow``), so the telemetry
    grows once per saturation event rather than re-counting every
    already-clamped slot each window (which inflated linearly with
    stream length). Returns (state, n_newly_saturated).

    ``prev`` is the register file at the start of the window (before
    ``update_flow_table``): a slot counts iff it reached the limit now
    but was below it then — exactly once per saturation event. The
    serving steps always pass it. Without ``prev`` the guard counts
    slots strictly *above* the limit (the clamp visibly changed them):
    an idle saturated slot (sitting exactly at the limit) is never
    re-counted, but one that keeps receiving traffic rises above the
    limit again each sweep and counts again — a per-sweep clamp-event
    count, not a once-only one. Pass ``prev`` when you need the latter.
    """
    lim = jnp.float32(limit)
    n_over = jnp.zeros((), jnp.int32)
    upd = {}
    for f in COUNT_FIELDS:
        r = getattr(state, f)
        if prev is not None:
            newly = (r >= lim) & (getattr(prev, f) < lim)
        else:
            newly = r > lim
        n_over = n_over + jnp.sum(newly.astype(jnp.int32))
        upd[f] = jnp.minimum(r, lim)
    return dataclasses.replace(state, **upd), n_over


def lifecycle_sweep(state: FlowTableState, w: "PacketWindow",
                    evict_age: Optional[float], saturate: bool,
                    prev: Optional[FlowTableState] = None) -> tuple:
    """Aging sweep + overflow guard for one served window.

    The single definition shared by the single-device and sharded serving
    steps — the sharded-vs-single-device bit-identity contract depends on
    the cutoff semantics never diverging between them. The eviction
    cutoff is ``min(now - evict_age, window_min_ts)``: strictly no later
    than every timestamp in this window, so a flow seen in this window
    always survives it by construction, even when the window's time span
    exceeds ``evict_age``. ``prev`` (the register file before this
    window's update) lets the overflow guard count only *newly* saturated
    slots — see ``saturate_counts``. Returns (state, n_evicted,
    n_overflow) — both counters zero when the corresponding feature is
    off.
    """
    n_ev = jnp.zeros((), jnp.int32)
    n_ov = jnp.zeros((), jnp.int32)
    if evict_age is not None:
        now = jnp.max(jnp.where(w.valid, w.ts, -jnp.inf))
        w_min = jnp.min(jnp.where(w.valid, w.ts, jnp.inf))
        cutoff = jnp.minimum(now - jnp.float32(evict_age), w_min)
        state, n_ev = age_out(state, cutoff)
    if saturate:
        state, n_ov = saturate_counts(state, prev=prev)
    return state, n_ev, n_ov


def flow_table_readout(state: FlowTableState,
                       bucket: Optional[jax.Array] = None) -> jax.Array:
    """Feature table from the registers — same columns as flow_features.

    bucket=None reads out every bucket -> (n_buckets, 8). Passing bucket
    ids gathers the 8 register vectors *first* and derives features on
    the gathered rows -> (len(bucket), 8): bit-identical (the derivation
    is elementwise) but ~n_buckets/len(bucket) less work — the serving
    step uses this to read out only the window's touched flows.
    """
    regs = (state.pkt_count, state.byte_count, state.t_min, state.t_max,
            state.fwd_pkts, state.rev_pkts, state.fwd_bytes,
            state.rev_bytes)
    if bucket is not None:
        regs = tuple(r[bucket] for r in regs)
    return table_from_registers(*regs)


def iter_windows(trace, window: int, n_buckets: int, *,
                 t0: Optional[float] = None, bucket=None,
                 pad: bool = True) -> Iterator[PacketWindow]:
    """Chunk a PacketTrace into fixed-size PacketWindows.

    Hashing is elementwise (order-free), so per-window bucket ids equal
    the batch path's; pass ``bucket`` to reuse an already-computed full-
    trace hash. t0 is the stream epoch every window rebases against; it
    defaults to the trace's *minimum* timestamp — the batch path's epoch,
    so reordered packets rebase identically to ``flow_features`` (latching
    the first packet instead shifted every f32 rounding when the stream
    opened out of order). Callers that cannot pre-scan an open-ended
    stream pass an explicit provisional t0; the sharded tier min-merges
    the true epoch as a register and corrects at readout. pad=True
    tile-pads the final ragged window to ``window`` lanes (valid=False)
    so every window presents one static shape to jitted consumers.
    """
    ts64 = np.asarray(trace.ts, np.float64)
    if t0 is None:
        t0 = float(ts64.min()) if ts64.size else 0.0
    rel = rebase_ts_np(ts64, t0)
    if bucket is None:
        bucket = fnv1a_hash(
            trace.src_ip, trace.dst_ip, trace.sport, trace.dport,
            trace.proto, n_buckets=n_buckets)
    bucket = np.asarray(bucket)
    length = np.asarray(trace.length, np.float32)
    is_fwd = (np.asarray(trace.direction) == 0).astype(np.float32)
    for s in range(0, len(rel), window):
        sl = slice(s, s + window)
        cols = dict(bucket=jnp.asarray(bucket[sl]), ts=jnp.asarray(rel[sl]),
                    length=jnp.asarray(length[sl]),
                    is_fwd=jnp.asarray(is_fwd[sl]))
        if pad:
            cols, valid, _ = pad_window(cols, window)
        else:
            valid = jnp.ones(cols["bucket"].shape[0], bool)
        yield PacketWindow(valid=valid, **cols)


# module-level so repeated stream_flow_features calls share the jit cache
_update_flow_table_jit = jax.jit(update_flow_table, donate_argnums=0)


def stream_flow_features(trace, n_buckets=4096, window=1024, *,
                         t0: Optional[float] = None):
    """One-shot convenience: stream the whole trace window by window.

    Returns (bucket_ids (P,), flow_table (n_buckets, 8)) — bit-consistent
    with ``features.flow_features`` on the same trace (the equivalence
    oracle used by tests and benchmarks/stream_bench.py). t0 overrides
    the stream epoch (default: trace minimum, matching the batch path
    even when packets arrive out of order).
    """
    b = fnv1a_hash(trace.src_ip, trace.dst_ip, trace.sport, trace.dport,
                   trace.proto, n_buckets=n_buckets)
    state = init_flow_table(n_buckets)
    for w in iter_windows(trace, window, n_buckets, bucket=b, t0=t0):
        state = _update_flow_table_jit(state, w)
    return b, flow_table_readout(state)
