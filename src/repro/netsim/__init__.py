"""Network feature extraction (§5 of the paper), in JAX.

The switch parser + stateful registers become JAX ops over packet arrays:
packet-level features are pure maps, flow-level features are hash + segment
reductions (the register-per-flow analog), aggregate features reduce over
flow groups, and file-level features parse payload byte arrays (the paper's
fixed-width csv demonstration, incl. features split across packets).

``stream`` is the always-on deployment shape: the same flow registers
carried as a FlowTableState and updated incrementally per packet window.
"""

from repro.netsim.packets import synth_trace, PacketTrace
from repro.netsim.features import (
    packet_features,
    flow_features,
    aggregate_features,
    file_features_csv,
    stitch_split_payload,
    encode_csv_payload,
    fnv1a_hash,
    rebase_ts,
    table_from_registers,
)
from repro.netsim.stream import (
    FlowTableState,
    PacketWindow,
    init_flow_table,
    update_flow_table,
    flow_table_readout,
    iter_windows,
    stream_flow_features,
    age_out,
    saturate_counts,
    lifecycle_sweep,
    pack_chunk_columns,
    trace_columns,
)
from repro.netsim.ingest import (
    HostCut,
    IngestStats,
    LatencyRecorder,
    PacketRingBuffer,
    cut_stream,
    prefetch_iter,
    replay_source,
    slice_trace,
)
from repro.netsim.shard_stream import (
    ShardedFlowTable,
    init_sharded_table,
    sharded_flow_table,
    stream_sharded_flow_features,
)
