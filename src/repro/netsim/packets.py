"""Synthetic packet-trace generation.

A trace is a struct-of-arrays over packets — the form a data plane sees.
Flows are generated first (with class-conditional statistics mirroring
repro.data.unsw_like) and then exploded into per-packet records with
timestamps, sizes and directions.
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class PacketTrace:
    # per-packet arrays (length P)
    ts: np.ndarray          # float64 seconds
    src_ip: np.ndarray      # uint32
    dst_ip: np.ndarray      # uint32
    sport: np.ndarray       # uint16
    dport: np.ndarray       # uint16
    proto: np.ndarray       # uint8
    length: np.ndarray      # uint16
    direction: np.ndarray   # uint8 0=fwd 1=rev
    flow_id: np.ndarray     # int32 ground-truth flow index (for labels only)
    # per-flow ground truth (length NF)
    flow_label: np.ndarray  # int32 0=normal 1=anomaly

    @property
    def n_packets(self) -> int:
        return len(self.ts)

    @property
    def n_flows(self) -> int:
        return len(self.flow_label)


def synth_trace(n_flows=2000, anomaly_frac=0.13, seed=0,
                mean_pkts=12) -> PacketTrace:
    rng = np.random.default_rng(seed)
    label = (rng.random(n_flows) < anomaly_frac).astype(np.int32)

    # flow 5-tuples
    src_ip = rng.integers(0, 2**32, n_flows, dtype=np.uint32)
    dst_ip = rng.integers(0, 2**32, n_flows, dtype=np.uint32)
    common = np.array([80, 443, 53, 22, 25], np.uint16)
    dport = np.where(label == 0,
                     common[rng.integers(0, 5, n_flows)],
                     rng.integers(1, 10000, n_flows).astype(np.uint16))
    sport = np.where(label == 0,
                     rng.integers(32768, 61000, n_flows),
                     rng.integers(1024, 61000, n_flows)).astype(np.uint16)
    proto = np.where(rng.random(n_flows) < np.where(label == 0, 0.8, 0.45),
                     6, 17).astype(np.uint8)

    # per-flow packet counts / start / duration
    pkts = np.maximum(rng.poisson(np.where(label == 0, mean_pkts,
                                           mean_pkts // 2), n_flows), 2)
    start = np.sort(rng.uniform(0, 60.0, n_flows))
    dur = np.where(label == 0, rng.lognormal(-1.0, 1.0, n_flows),
                   rng.lognormal(-3.0, 0.8, n_flows))

    # explode to packets
    flow_id = np.repeat(np.arange(n_flows, dtype=np.int32), pkts)
    p = len(flow_id)
    offs = rng.random(p)
    ts = start[flow_id] + offs * dur[flow_id]
    order = np.argsort(ts, kind="stable")
    direction = (rng.random(p) < 0.45).astype(np.uint8)
    base_len = np.where(label[flow_id] == 0, 800, 1200)
    length = np.clip(rng.normal(base_len, 300), 64, 1500).astype(np.uint16)

    return PacketTrace(
        ts=ts[order], src_ip=src_ip[flow_id][order],
        dst_ip=dst_ip[flow_id][order], sport=sport[flow_id][order],
        dport=dport[flow_id][order], proto=proto[flow_id][order],
        length=length[order], direction=direction[order],
        flow_id=flow_id[order], flow_label=label)
