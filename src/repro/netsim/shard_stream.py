"""Sharded flow-table tier: the register file partitioned across a mesh.

A single device's register file bounds how many flows the streaming tier
can track; a production deployment shards the table across devices the
way a switch ASIC banks its SRAM. This module partitions the
``FlowTableState`` buckets over a 1D ('shard',) mesh by

    owner(bucket)  = bucket % n_shards
    local(bucket)  = bucket // n_shards

so global bucket ``b`` lives at row ``b // n_shards`` of shard
``b % n_shards`` — the interleaved layout keeps the FNV hash's bucket
distribution uniform per shard. Register leaves carry a leading shard
dim: ``(n_shards, n_local)``, sharded ``P('shard', None)``; the canonical
bucket order is recovered by ``leaf.T.reshape(-1)``.

The per-window step runs under ``shard_map``: every shard receives the
(replicated) window, masks it down to the packets it owns, and folds
them with the *same* ``update_flow_table`` segment-scatter the
single-device tier uses — per-bucket independence means zero cross-device
traffic for the update itself. Readout gathers each packet's row from
its owner shard; non-owner contributions are zeroed so the small psum
merges (predictions, confidences, the capacity-bounded backend buffer,
telemetry counters) are exact: one real value plus zeros. This keeps the
sharded step bit-identical to ``StreamingHybridServer`` on in-order
traces with eviction disabled (the contract tests and the shard bench
oracle assert).

Out-of-order tolerance: every register is an associative, order-free
reduction (sums, min, max), and every derived feature is epoch-invariant
(durations and IATs are timestamp *differences*), so reordered arrivals
— including a reordered first window — fold into the same table
regardless of which provisional ``t0`` the host rebased against. What a
host-side latch cannot provide is the stream's true time origin: that is
the min-merged ``ShardedFlowTable.epoch`` register, which accumulates
the minimum observed relative timestamp (0.0 on an in-order stream,
negative when the true start arrived after the provisional latch) — the
subsystem's source of truth for mapping register timestamps back to
wall clock and for aging decisions that outlive a single host.

Flow lifecycle folds into the same step: ``shard_window_update``
optionally runs the ``age_out`` eviction sweep (idle buckets recycled to
the init identities) and the ``saturate_counts`` overflow guard (clamp at
the 2^24 f32 integer-exactness envelope) per shard, per window.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.distributed.sharding import flow_shard_mesh, flow_table_sharding
from repro.netsim.features import fnv1a_hash, table_from_registers
from repro.netsim.stream import (REGISTER_FIELDS, FlowTableState,
                                 PacketWindow, flow_table_readout,
                                 iter_windows, lifecycle_sweep,
                                 update_flow_table)


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class ShardedFlowTable:
    """Register file partitioned over the 'shard' mesh axis.

    regs leaves are (n_shards, n_local) — shard d's block at [d]; epoch
    is the (n_shards,) min-merged stream-epoch register (every shard sees
    every window, so all rows agree; the min over rows is the stream's
    true observed start in the provisional rebased frame, +inf before any
    packet).
    """
    regs: FlowTableState
    epoch: jax.Array

    @property
    def n_shards(self) -> int:
        return self.regs.pkt_count.shape[0]

    @property
    def n_buckets(self) -> int:
        return self.regs.pkt_count.shape[0] * self.regs.pkt_count.shape[1]


def n_local_buckets(n_buckets: int, n_shards: int) -> int:
    if n_buckets % n_shards:
        raise ValueError(f"n_buckets={n_buckets} must divide evenly over "
                         f"{n_shards} shards")
    return n_buckets // n_shards


def init_sharded_table(n_buckets: int, *, mesh: Optional[Mesh] = None,
                       n_shards: Optional[int] = None) -> ShardedFlowTable:
    """Fresh sharded register file, placed on ``mesh`` when given.

    Same init identities as ``init_flow_table`` (counts 0, t_min/t_max at
    the segment identities) so an untouched sharded bucket reads out
    bit-identically to an untouched single-device one.
    """
    if mesh is not None:
        n_shards = mesh.shape["shard"]
    n_local = n_local_buckets(n_buckets, n_shards)
    z = lambda: jnp.zeros((n_shards, n_local), jnp.float32)
    regs = FlowTableState(
        pkt_count=z(), byte_count=z(),
        t_min=jnp.full((n_shards, n_local), jnp.inf, jnp.float32),
        t_max=jnp.full((n_shards, n_local), -jnp.inf, jnp.float32),
        fwd_pkts=z(), rev_pkts=z(), fwd_bytes=z(), rev_bytes=z())
    state = ShardedFlowTable(
        regs=regs, epoch=jnp.full((n_shards,), jnp.inf, jnp.float32))
    if mesh is not None:
        state = jax.device_put(state, flow_table_sharding(mesh, state))
    return state


def localize_window(w: PacketWindow, n_shards: int, shard_idx):
    """Mask a replicated window down to one shard's packets.

    Returns (local_window, own (W,) bool): bucket ids remapped to local
    rows (b // n_shards — in range for every lane, owned or not) and
    valid restricted to owned lanes, so the unchanged single-device
    ``update_flow_table`` folds exactly the owned packets.
    """
    own = (w.bucket % n_shards) == shard_idx
    local = dataclasses.replace(w, bucket=w.bucket // n_shards,
                                valid=w.valid & own)
    return local, own


def shard_window_update(regs: FlowTableState, w: PacketWindow,
                        n_shards: int, shard_idx, *,
                        evict_age: Optional[float] = None,
                        saturate: bool = True,
                        evict_policy: str = "timeout",
                        lru_occupancy: float = 0.75,
                        readout: bool = True):
    """One shard's whole per-window register pass (shard_map body core).

    update (owned packets only) -> aging sweep -> overflow guard ->
    owner-masked readout of the window's touched rows. Returns
    (regs, epoch_min, own, x, n_evicted, n_overflow); x is (W, 8) with
    non-owned rows zeroed (None when readout=False), so psumming x-derived
    quantities across shards reconstructs the owner's value exactly.

    The aging sweep and overflow guard are the shared
    ``netsim.stream.lifecycle_sweep`` (pForest-style window aging, cutoff
    clamped to the window's oldest timestamp so flows seen this window
    always survive it) — one definition with the single-device tier, on
    which the bit-identity contract depends. ``evict_policy="approx_lru"``
    runs the pressure-triggered sweep *per shard*: occupancy and the
    score histogram are computed over this shard's local bucket block, so
    LRU decisions are shard-local — the sharded table under approx-LRU is
    NOT bit-identical to a single-device table of the global size (each
    shard defends its own slice, which is the deployment semantics of a
    partitioned flow table); the timeout policy keeps the bit-identity
    contract.
    """
    local, own = localize_window(w, n_shards, shard_idx)
    prev = regs                   # pre-update registers: the overflow guard
    regs = update_flow_table(regs, local)   # counts only newly saturated
    regs, n_ev, n_ov = lifecycle_sweep(regs, w, evict_age, saturate,
                                       prev=prev, evict_policy=evict_policy,
                                       lru_occupancy=lru_occupancy)
    x = None
    if readout:
        x = flow_table_readout(regs, local.bucket)          # (W, 8)
        x = jnp.where(own[:, None], x, 0.0)
    epoch = jnp.min(jnp.where(w.valid, w.ts, jnp.inf))
    return regs, epoch, own, x, n_ev, n_ov


def lane_slab_rows(n_lanes: int, n_shards: int, n_data: int = 1) -> int:
    """Static per-device lane tile: ceil(n_lanes / (n_shards * n_data)).

    The partitioned classify (DESIGN.md §16) pads the lane axis to
    ``T * n_shards * n_data`` rows so every device owns a fixed-shape
    slab regardless of which shard the traffic actually hashed to —
    ownership skew moves *values* between slabs, never shapes.
    """
    return -(-n_lanes // (n_shards * n_data))


def scatter_lane_slab(x: jax.Array, n_shards: int, n_data: int) -> jax.Array:
    """Owner-masked lane rows -> this device's complete lane slab.

    Runs under shard_map on the ('shard', 'data') mesh. ``x`` is the
    (N, F) per-shard readout with non-owned rows exactly zero, so the
    reduce-scatter over 'shard' sums one real row plus zeros per lane —
    complete rows, bit-identical to the owner's — and hands this shard
    the contiguous block [s*N/D_s : (s+1)*N/D_s). The 'data' index then
    slices that block into D_d equal slabs. Zero-padded tail lanes stay
    zero and are dropped by ``gather_lane_values``'s [:N].
    """
    n = x.shape[0]
    t = lane_slab_rows(n, n_shards, n_data)
    pad = t * n_shards * n_data - n
    if pad:
        x = jnp.pad(x, ((0, pad),) + ((0, 0),) * (x.ndim - 1))
    sl = jax.lax.psum_scatter(x, "shard", scatter_dimension=0, tiled=True)
    d = jax.lax.axis_index("data")
    return jax.lax.dynamic_slice_in_dim(sl, d * t, t)


def gather_lane_values(v: jax.Array, n_lanes: int) -> jax.Array:
    """Per-device slab results -> the replicated full lane vector.

    The tiled all_gather over ('shard', 'data') concatenates slabs
    shard-major / data-minor — exactly the order ``scatter_lane_slab``
    dealt them — so row i of the result is lane i's value; [:n_lanes]
    drops the even-division padding.
    """
    return jax.lax.all_gather(v, ("shard", "data"), tiled=True)[:n_lanes]


def stream_epoch(state: ShardedFlowTable) -> jax.Array:
    """True observed stream start in the provisional rebased frame.

    0.0 until any packet arrives, exactly 0.0 on an in-order stream whose
    provisional t0 was the first packet, and negative when the true start
    arrived after the host's latch — telemetry for mapping register
    timestamps back to wall clock (features never depend on it; they are
    epoch-invariant differences).
    """
    e = jnp.min(state.epoch)
    return jnp.where(jnp.isfinite(e), e, jnp.float32(0.0))


def sharded_flow_table(state: ShardedFlowTable) -> jax.Array:
    """(n_buckets, 8) canonical-bucket-order feature table.

    Gathers every shard's block back to the interleaved global order
    (row b = regs[b % D, b // D], i.e. ``leaf.T.reshape(-1)``) and derives
    features through the shared ``table_from_registers``. The raw
    t_min/t_max registers feed the derivation untouched — every feature
    is a timestamp difference, invariant to the rebase origin, and
    subtracting the epoch here would round duration bits differently
    than the serving-path readout does. Callers who need wall-clock flow
    times combine the registers with ``stream_epoch`` themselves.
    Test/telemetry path: serving reads out per-packet rows inside the
    shard_map instead.
    """
    flat = {f: getattr(state.regs, f).T.reshape(-1)
            for f in REGISTER_FIELDS}
    return table_from_registers(*[flat[f] for f in REGISTER_FIELDS])


# ---------------------------------------------------------------------------
# one-shot convenience / equivalence oracle
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnums=(2, 3), donate_argnums=0)
def _sharded_update_step(state: ShardedFlowTable, w: PacketWindow,
                         mesh: Mesh, n_shards: int) -> ShardedFlowTable:
    def body(regs, epoch, w):
        sq = jax.tree.map(lambda a: a[0], regs)
        idx = jax.lax.axis_index("shard")
        # saturate=False: this is the equivalence oracle, and the batch /
        # single-device paths it is compared against never clamp — above
        # the 2^24 envelope both sides must be (in)exact identically
        sq, e, _, _, _, _ = shard_window_update(sq, w, n_shards, idx,
                                                saturate=False,
                                                readout=False)
        return (jax.tree.map(lambda a: a[None], sq),
                jnp.minimum(epoch, e))

    regs, epoch = shard_map(
        body, mesh=mesh,
        in_specs=(P("shard", None), P("shard"), P()),
        out_specs=(P("shard", None), P("shard")))(
            state.regs, state.epoch, w)
    return ShardedFlowTable(regs=regs, epoch=epoch)


def stream_sharded_flow_features(trace, n_buckets=4096, window=1024, *,
                                 mesh: Optional[Mesh] = None,
                                 n_shards: Optional[int] = None,
                                 t0: Optional[float] = None):
    """Stream a trace through the sharded register file window by window.

    Returns (bucket_ids (P,), flow_table (n_buckets, 8)) in canonical
    bucket order — the sharded analog of ``stream_flow_features`` and the
    equivalence oracle of tests and ``benchmarks/shard_stream_bench.py``:
    bit-consistent with the batch ``flow_features`` whenever the rebase
    rounds identically under both epochs (always on in-order traces with
    the default t0; also under reordering, since registers are
    associative reductions and features epoch-invariant differences).
    """
    if mesh is None:
        mesh = flow_shard_mesh(n_shards)
    n_shards = mesh.shape["shard"]
    b = fnv1a_hash(trace.src_ip, trace.dst_ip, trace.sport, trace.dport,
                   trace.proto, n_buckets=n_buckets)
    state = init_sharded_table(n_buckets, mesh=mesh)
    for w in iter_windows(trace, window, n_buckets, bucket=b, t0=t0):
        state = _sharded_update_step(state, w, mesh, n_shards)
    return b, sharded_flow_table(state)
