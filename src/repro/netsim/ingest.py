"""Open-ended packet ingest: host ring buffer -> window-granular cuts.

``serve_trace`` takes a complete, finite trace — a benchmark shape. The
deployment shape (DESIGN.md §13) is a stream that never ends: packets are
*admitted* into a host-side ring buffer as they arrive and *cut* into
``PacketChunk``s by whichever fires first —

  count cut     ``chunk_windows`` complete windows are buffered (the
                steady-state path: a full (K, W) chunk, no padding)
  deadline cut  the oldest buffered packet has waited ``deadline`` wall
                seconds and at least one complete window is buffered
  drain cut     the source is exhausted; whatever remains (including a
                ragged partial window) is flushed

Every cut is **window-granular**: it emits only *complete* windows (the
drain cut's ragged tail is the one exception, exactly like the final
``iter_windows`` window). This is what makes the ring bit-identical to
the offline iterators: window boundaries — and therefore per-packet
register readouts, classifications and dispatch groupings — are a pure
function of packet arrival order, never of cut timing. A deadline cut
only changes how many chunks the same windows are grouped into.

The packing discipline is shared with ``iter_chunks`` via
``stream.pack_chunk_columns`` (ragged live window replicate-pads the last
packet with valid=False; missing windows are dead — all-zero, all
invalid), so replaying a finite trace through the ring produces bitwise
the same chunks as ``iter_chunks`` (the property test in
tests/test_ingest.py sweeps cut boundaries).

Backpressure: the ring is *pull-based* when driven by ``cut_stream`` —
admission pauses (the source iterator is simply not advanced) while the
buffer is full, so nothing is ever dropped and ``capacity`` bounds host
memory, not correctness. Push-style callers that cannot pause admission
construct the ring with ``drop=True`` and ``admit`` tail-drops instead
(counted in ``IngestStats.dropped``) rather than raising.

``prefetch_iter`` is the transfer/compute overlap half: it runs the
cut->device pipeline in a background thread with a small bounded queue,
so chunk k+1's (K, W) columns are already in flight while chunk k runs
in the scan megastep (the MaxText latency-hiding discipline).
"""

from __future__ import annotations

import dataclasses
import queue
import threading
import time
from typing import Callable, Iterable, Iterator, Optional

import jax.numpy as jnp
import numpy as np

from repro.netsim.stream import (PacketChunk, PacketWindow,
                                 pack_chunk_columns, trace_columns)

# host column layout of one admitted packet (dtypes match trace_columns)
COLUMN_DTYPES = (("bucket", np.int32), ("ts", np.float32),
                 ("length", np.float32), ("is_fwd", np.float32))

CUT_KINDS = ("count", "deadline", "drain")


@dataclasses.dataclass
class IngestStats:
    """Host-side ring telemetry (wall-clock domain, unlike StreamStats)."""
    admitted: int = 0          # packets accepted into the ring
    dropped: int = 0           # packets tail-dropped (drop=True rings only)
    count_cuts: int = 0        # full (K, W) chunks cut by occupancy
    deadline_cuts: int = 0     # partial chunks cut by admit-age deadline
    drain_cuts: int = 0        # end-of-source flush cuts

    @property
    def cuts(self) -> int:
        return self.count_cuts + self.deadline_cuts + self.drain_cuts

    def as_dict(self) -> dict:
        """Snapshot contract shared with StreamStats/FaultStats — the
        form the obs metrics registry reports (derived ``cuts``
        included)."""
        return dict(dataclasses.asdict(self), cuts=self.cuts)


@dataclasses.dataclass
class HostCut:
    """One window-granular cut: host columns for up to ``rows`` windows.

    ``cols``/``valid`` are flat (rows*window,) arrays in the
    ``pack_chunk_columns`` layout — live packets first, replicate-padded
    ragged window, then dead windows. ``admit_time`` holds the wall
    clock each of the ``n`` live packets entered the ring (latency
    accounting); ``kind`` records which trigger fired.
    """
    cols: dict
    valid: np.ndarray
    admit_time: np.ndarray   # (n,) float64 wall seconds
    n: int                   # live packets
    window: int
    rows: int                # total windows incl. dead padding
    kind: str

    @property
    def n_windows(self) -> int:
        """Live (non-dead) windows in this cut."""
        return -(-self.n // self.window) if self.n else 0

    def to_chunk(self) -> PacketChunk:
        """Device (rows, window) chunk — the step_chunk input. Calling
        this on the prefetch thread starts the transfer early."""
        shape = (self.rows, self.window)
        return PacketChunk(
            valid=jnp.asarray(self.valid.reshape(shape)),
            **{k: jnp.asarray(v.reshape(shape)) for k, v in self.cols.items()})

    def to_windows(self) -> Iterator[PacketWindow]:
        """The cut's *live* windows one by one — the per-window serving
        path's input (dead padding windows are skipped; the per-window
        path has no static chunk shape to satisfy)."""
        for r in range(self.n_windows):
            sl = slice(r * self.window, (r + 1) * self.window)
            yield PacketWindow(
                valid=jnp.asarray(self.valid[sl]),
                **{k: jnp.asarray(v[sl]) for k, v in self.cols.items()})


class PacketRingBuffer:
    """Fixed-capacity circular buffer of admitted packets, cut window-wise.

    window/chunk_windows fix the cut geometry (a cut is at most
    ``chunk_windows`` complete windows, packed to exactly that many rows
    with dead padding); ``n_buckets`` sizes the flow hash the admit path
    computes. ``t0`` is the stream epoch: None latches the first admitted
    batch's minimum timestamp (the offline iterators' default on a
    single-batch replay — the bit-identity contract), open-ended
    multi-batch sources that may open out of order pass an explicit
    provisional t0 (the sharded tier's min-merged epoch register corrects
    at readout, DESIGN.md §5).

    ``capacity`` (default ``4 * chunk_windows * window``) must be at
    least ``(chunk_windows + 1) * window - 1`` lanes: a full ring then
    always holds a complete chunk, so a pull-driven loop (``cut_stream``)
    can always make progress without dropping. ``deadline`` (wall
    seconds, via ``clock``) bounds how long an admitted packet can sit
    uncut; None disables deadline cuts.
    """

    def __init__(self, window: int, chunk_windows: int = 1,
                 n_buckets: int = 4096, *, t0: Optional[float] = None,
                 capacity: Optional[int] = None,
                 deadline: Optional[float] = None, drop: bool = False,
                 clock: Callable[[], float] = time.monotonic):
        if window < 1:
            raise ValueError(f"window must be >= 1, got {window}")
        if chunk_windows < 1:
            raise ValueError(
                f"chunk_windows must be >= 1, got {chunk_windows}")
        if deadline is not None and deadline <= 0:
            raise ValueError(f"deadline must be > 0, got {deadline}")
        if capacity is None:
            capacity = 4 * chunk_windows * window
        floor = (chunk_windows + 1) * window - 1
        if capacity < floor:
            raise ValueError(
                f"capacity={capacity} cannot guarantee cut progress: a "
                f"full ring must always contain {chunk_windows} complete "
                f"windows, which needs >= {floor} lanes "
                f"((chunk_windows+1)*window - 1)")
        self.window = window
        self.chunk_windows = chunk_windows
        self.n_buckets = n_buckets
        self.capacity = capacity
        self.deadline = deadline
        self.drop = drop
        self.t0 = t0
        self._clock = clock
        self._store = {k: np.zeros(capacity, dt) for k, dt in COLUMN_DTYPES}
        self._atime = np.zeros(capacity, np.float64)
        self._head = 0          # read position of the oldest packet
        self._count = 0
        self.stats = IngestStats()

    # -- occupancy ----------------------------------------------------------

    @property
    def buffered(self) -> int:
        return self._count

    @property
    def free(self) -> int:
        return self.capacity - self._count

    @property
    def complete_windows(self) -> int:
        return self._count // self.window

    def ready(self) -> bool:
        """A full count cut is available."""
        return self.complete_windows >= self.chunk_windows

    def deadline_due(self, now: Optional[float] = None) -> bool:
        """The oldest admitted packet has aged past ``deadline`` and at
        least one *complete* window is buffered (cuts are window-
        granular; a lone partial window waits for more packets or the
        drain)."""
        if self.deadline is None or self.complete_windows < 1:
            return False
        if now is None:
            now = self._clock()
        return now - float(self._atime[self._head]) >= self.deadline

    # -- admission ----------------------------------------------------------

    def _latch_t0(self, t0: float) -> None:
        if self.t0 is None:
            self.t0 = t0

    def admit_cols(self, cols: dict, lo: int, hi: int,
                   now: Optional[float] = None) -> int:
        """Admit packets [lo, hi) of precomputed host columns (the
        ``trace_columns`` layout, already rebased against this ring's
        t0). Returns the number admitted; the remainder is tail-dropped
        when ``drop=True`` (counted), otherwise the caller asked for
        more than ``free`` and gets a ValueError."""
        m = hi - lo
        take = min(m, self.free)
        if take < m and not self.drop:
            raise ValueError(
                f"ring full: {m} packets offered, {self.free} lanes free "
                f"(pull-driven ingest should cut first; push-style "
                f"callers construct the ring with drop=True)")
        if now is None:
            now = self._clock()
        w = (self._head + self._count) % self.capacity
        first = min(take, self.capacity - w)
        for k, _ in COLUMN_DTYPES:
            src = cols[k]
            self._store[k][w:w + first] = src[lo:lo + first]
            if take > first:
                self._store[k][:take - first] = src[lo + first:lo + take]
        self._atime[w:w + first] = now
        if take > first:
            self._atime[:take - first] = now
        self._count += take
        self.stats.admitted += take
        self.stats.dropped += m - take
        return take

    def admit(self, trace, now: Optional[float] = None) -> int:
        """Admit a PacketTrace batch: hash + rebase (latching t0 from the
        first batch when unset), then ``admit_cols`` the lot."""
        cols, t0 = trace_columns(trace, self.n_buckets, t0=self.t0)
        self._latch_t0(t0)
        return self.admit_cols(cols, 0, len(cols["ts"]), now=now)

    # -- cutting ------------------------------------------------------------

    def _pop(self, n: int) -> tuple:
        """Remove the oldest ``n`` packets -> (contiguous cols, times)."""
        h, c = self._head, self.capacity
        idx = (h + np.arange(n)) % c if h + n > c else slice(h, h + n)
        cols = {k: np.ascontiguousarray(self._store[k][idx])
                for k, _ in COLUMN_DTYPES}
        times = np.ascontiguousarray(self._atime[idx])
        self._head = (h + n) % c
        self._count -= n
        return cols, times

    def cut(self, kind: str = "count") -> HostCut:
        """Cut up to ``chunk_windows`` complete windows (all buffered
        packets for ``kind='drain'``, including a ragged tail window)
        into one HostCut packed to the full (chunk_windows, window)
        shape."""
        if kind not in CUT_KINDS:
            raise ValueError(f"kind must be one of {CUT_KINDS}, got {kind!r}")
        if kind == "drain":
            n = self._count
        else:
            n = min(self.complete_windows, self.chunk_windows) * self.window
        if n == 0:
            raise ValueError(f"nothing to cut ({kind}): "
                             f"{self._count} packets buffered")
        cols, times = self._pop(n)
        full, valid = pack_chunk_columns(cols, n, self.window,
                                         self.chunk_windows)
        setattr(self.stats, f"{kind}_cuts",
                getattr(self.stats, f"{kind}_cuts") + 1)
        return HostCut(cols=full, valid=valid, admit_time=times, n=n,
                       window=self.window, rows=self.chunk_windows,
                       kind=kind)

    def drain(self) -> Optional[HostCut]:
        """End-of-source flush: everything buffered (ragged tail padded
        like the final ``iter_chunks`` chunk), or None when empty."""
        return self.cut("drain") if self._count else None


def slice_trace(trace, lo: int, hi: int):
    """Per-packet slice [lo, hi) of a PacketTrace (flow arrays shared)."""
    return dataclasses.replace(
        trace, ts=trace.ts[lo:hi], src_ip=trace.src_ip[lo:hi],
        dst_ip=trace.dst_ip[lo:hi], sport=trace.sport[lo:hi],
        dport=trace.dport[lo:hi], proto=trace.proto[lo:hi],
        length=trace.length[lo:hi], direction=trace.direction[lo:hi],
        flow_id=trace.flow_id[lo:hi])


def replay_source(trace, batch: Optional[int] = None) -> Iterator:
    """A finite trace as an ingest source: the whole trace in one batch
    (batch=None — the ``serve_trace`` replay shape, which latches the
    offline iterators' t0 and is bit-identical to them including cut
    grouping), or consecutive ``batch``-packet slices (arrival-paced
    sources for tests/benchmarks; same predictions, cut grouping may
    differ)."""
    if batch is None:
        yield trace
        return
    if batch < 1:
        raise ValueError(f"batch must be >= 1, got {batch}")
    for lo in range(0, trace.n_packets, batch):
        yield slice_trace(trace, lo, min(lo + batch, trace.n_packets))


def cut_stream(ring: PacketRingBuffer, source: Iterable
               ) -> Iterator[HostCut]:
    """Pull-driven ingest loop: admit ``source`` batches into ``ring``,
    yielding cuts as they become ready; drain at exhaustion.

    Oversized batches are admitted in slices as cuts free lanes (the
    backpressure contract: the ring bounds memory, the source just waits),
    so nothing is dropped regardless of batch size. Precedence when both
    triggers are due: count cuts first (a ready ring always cuts full
    chunks), then one deadline cut of whatever complete windows remain.
    Deadlines are evaluated at admission boundaries — a pull loop has no
    other opportunity to act — so a sparse source that blocks for long
    stretches should slice its batches (``replay_source(trace, batch=...)``)
    to give the deadline a chance to fire.
    """
    for tr in source:
        m = tr.n_packets
        if not m:
            continue
        cols, t0 = trace_columns(tr, ring.n_buckets, t0=ring.t0)
        ring._latch_t0(t0)
        now = ring._clock()
        off = 0
        while off < m:
            off += ring.admit_cols(cols, off, min(off + ring.free, m),
                                   now=now)
            while ring.ready():
                yield ring.cut("count")
        if ring.deadline_due():
            yield ring.cut("deadline")
    final = ring.drain()
    if final is not None:
        yield final


def prefetch_iter(it: Iterable, depth: int = 2) -> Iterator:
    """Run ``it`` on a background thread, holding up to ``depth`` items
    ready ahead of the consumer.

    The double-buffer half of the ingest pipeline: the producer maps cuts
    to device chunks (``HostCut.to_chunk`` -> ``jnp.asarray`` starts the
    H2D transfer), so chunk k+1 is in flight while the consumer's scan
    megastep runs chunk k. depth=2 is classic double buffering; deeper
    only helps when transfer time is burstier than compute. The producer
    blocks (bounded queue) rather than running ahead unboundedly, and a
    consumer that abandons the iterator mid-stream stops the thread
    promptly (GeneratorExit -> stop flag) instead of leaking it.
    """
    if depth < 1:
        raise ValueError(f"depth must be >= 1, got {depth}")
    q: queue.Queue = queue.Queue(maxsize=depth)
    stop = threading.Event()
    done = object()
    err: list = []

    def worker():
        try:
            for item in it:
                while not stop.is_set():
                    try:
                        q.put(item, timeout=0.05)
                        break
                    except queue.Full:
                        continue
                if stop.is_set():
                    return
        except BaseException as e:  # noqa: BLE001 — producer-thread trap:
            #                         captured and re-raised on the consumer
            #                         side, so nothing is swallowed
            err.append(e)
        finally:
            while not stop.is_set():
                try:
                    q.put(done, timeout=0.05)
                    break
                except queue.Full:
                    continue

    t = threading.Thread(target=worker, daemon=True,
                         name="ingest-prefetch")
    t.start()
    try:
        while True:
            item = q.get()
            if item is done:
                break
            yield item
    finally:
        stop.set()
        t.join()
    if err:
        raise err[0]


class LatencyRecorder:
    """Per-packet admit->prediction latency accumulator.

    ``record`` takes the admit wall-times of a cut's live packets and the
    wall time their *final* predictions became available (after the host
    sync); ``summary`` reduces to the percentile row the latency bench
    and telemetry report (milliseconds).

    ``max_samples=None`` (the default) keeps every span — exact
    percentiles, memory linear in stream length, right for bounded
    traces. On an *open-ended* stream that is an unbounded leak, so
    ``max_samples=k`` switches to a seeded uniform reservoir (Algorithm
    R): memory is O(k), percentiles come from the reservoir (exact until
    the k+1-th packet, an unbiased sample after), while ``n`` / ``mean``
    / ``max`` stay exact over *all* packets seen via running
    accumulators. ``latencies()`` returns the reservoir in bounded mode
    — a uniform sample, not the full admit-order sequence."""

    def __init__(self, max_samples: Optional[int] = None, seed: int = 0):
        if max_samples is not None and max_samples < 1:
            raise ValueError(f"max_samples must be >= 1 or None, "
                             f"got {max_samples}")
        self.max_samples = max_samples
        self._spans: list = []              # unbounded mode
        self._reservoir: Optional[np.ndarray] = (
            None if max_samples is None
            else np.zeros(max_samples, np.float64))
        self._rng = np.random.default_rng(seed)
        self._n_seen = 0
        self._sum = 0.0
        self._max: Optional[float] = None

    def record(self, admit_time: np.ndarray, finish: float) -> None:
        if not len(admit_time):
            return
        spans = finish - np.asarray(admit_time, np.float64)
        self._sum += float(spans.sum())
        mx = float(spans.max())
        self._max = mx if self._max is None else max(self._max, mx)
        if self.max_samples is None:
            self._n_seen += len(spans)
            self._spans.append(spans)
            return
        k = self.max_samples
        for v in spans:                     # Algorithm R, element-wise
            i = self._n_seen
            self._n_seen += 1
            if i < k:
                self._reservoir[i] = v
            else:
                j = int(self._rng.integers(0, i + 1))
                if j < k:
                    self._reservoir[j] = v

    @property
    def n(self) -> int:
        """Total packets seen (NOT the reservoir size in bounded mode)."""
        return self._n_seen

    def latencies(self) -> np.ndarray:
        """(m,) float64 seconds. Unbounded mode: every span, admit
        order. Bounded mode: the reservoir sample (m = min(n, k))."""
        if self.max_samples is None:
            return (np.concatenate(self._spans) if self._spans
                    else np.zeros(0, np.float64))
        return self._reservoir[:min(self._n_seen, self.max_samples)].copy()

    def summary(self) -> dict:
        """Milliseconds row. ``n``/``mean_ms``/``max_ms`` are exact over
        all packets seen; percentiles are reservoir-approximate once
        bounded mode has evicted (n > max_samples)."""
        if not self._n_seen:
            return {"n": 0, "p50_ms": None, "p95_ms": None, "p99_ms": None,
                    "mean_ms": None, "max_ms": None}
        lat = self.latencies() * 1e3
        p50, p95, p99 = np.percentile(lat, (50, 95, 99))
        return {"n": self._n_seen, "p50_ms": float(p50),
                "p95_ms": float(p95), "p99_ms": float(p99),
                "mean_ms": self._sum / self._n_seen * 1e3,
                "max_ms": self._max * 1e3}
