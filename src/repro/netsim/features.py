"""Feature extraction at four granularities (§5), as JAX dataflow.

Switch mechanism -> JAX realization:
  parser header extraction   -> pure elementwise maps over packet arrays
  hash(flow 5-tuple)         -> vectorized FNV-1a-style integer hash
  per-flow registers         -> jax.ops.segment_* keyed by hash bucket
  aggregate registers        -> segment reductions over coarser keys
  payload parsing (files)    -> fixed-stride byte-array slicing, incl.
                                stitching a field split across packets
                                (§5.3 "examining payload across packets")

Hash-bucket collisions are real (they are on the switch too): features of
colliding flows merge, exactly like two flows sharing a register slot.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def rebase_ts(ts, t0=None) -> jax.Array:
    """Rebase raw timestamps to ``t0``-relative seconds, then cast to f32.

    At epoch scale (~1.7e9 s) float32 resolution is ~256 s, which wipes out
    every duration / inter-arrival feature — the subtraction must happen in
    float64 *before* the cast. Host-side on purpose: traces are numpy
    struct-of-arrays, and the data plane analog is the switch's relative
    packet clock, not wall time. t0 defaults to the minimum timestamp;
    the streaming path passes its latched stream epoch instead. This is
    the single definition both paths share — the streaming-vs-batch
    bit-consistency contract depends on the rebase never diverging.
    """
    return jnp.asarray(rebase_ts_np(ts, t0))


def rebase_ts_np(ts, t0=None) -> "np.ndarray":
    """Host-side core of ``rebase_ts`` -> float32 numpy array.

    The streaming window iterator uses this directly so trace ingest never
    round-trips the full timestamp column through the device.
    """
    ts64 = np.asarray(ts, np.float64)
    if t0 is None:
        t0 = ts64.min() if ts64.size else 0.0
    return (ts64 - t0).astype(np.float32)


def table_from_registers(cnt, byt, t_min, t_max, fwd_pkts, rev_pkts,
                         fwd_bytes, rev_bytes) -> jax.Array:
    """Derive the 8-column flow-feature table from raw registers.

    Shared by the one-shot path (`flow_features`) and the streaming path
    (`netsim.stream.flow_table_readout`) so both derive duration / mean-IAT
    identically — the streaming-vs-batch bit-consistency contract hinges on
    this being the single definition. Untouched buckets carry
    t_min=+inf / t_max=-inf (the segment_min/max identities); the cnt>0
    guard maps them to zero.
    """
    dur = jnp.where(cnt > 0, t_max - t_min, 0.0)
    iat = jnp.where(cnt > 1, dur / jnp.maximum(cnt - 1, 1), 0.0)
    return jnp.stack([cnt, byt, dur, iat, fwd_pkts, rev_pkts,
                      fwd_bytes, rev_bytes], axis=1)


def fnv1a_hash(*cols, n_buckets: int) -> jax.Array:
    """Vectorized 32-bit FNV-1a over integer columns -> bucket id."""
    h = jnp.full(cols[0].shape, 2166136261, jnp.uint32)
    for c in cols:
        c = jnp.asarray(c).astype(jnp.uint32)
        for shift in (0, 8, 16, 24):
            byte = (c >> shift) & jnp.uint32(0xFF)
            h = (h ^ byte) * jnp.uint32(16777619)
    return (h % jnp.uint32(n_buckets)).astype(jnp.int32)


def packet_features(trace) -> jax.Array:
    """Stateless per-packet features (parser stage).

    Columns: sport, dport, proto, length, is_sm_ips_ports (src==dst port),
    direction. -> (P, 6) float32
    """
    sport = jnp.asarray(trace.sport, jnp.float32)
    dport = jnp.asarray(trace.dport, jnp.float32)
    return jnp.stack([
        sport,
        dport,
        jnp.asarray(trace.proto, jnp.float32),
        jnp.asarray(trace.length, jnp.float32),
        (sport == dport).astype(jnp.float32),
        jnp.asarray(trace.direction, jnp.float32),
    ], axis=1)


def flow_features(trace, n_buckets=4096):
    """Stateful flow-level features via hash + segment registers.

    Returns (bucket_ids (P,), flow_table (n_buckets, 8)) where columns are:
      0 pkt_count  1 byte_count  2 duration  3 mean_iat
      4 fwd_pkts   5 rev_pkts    6 fwd_bytes 7 rev_bytes
    """
    b = fnv1a_hash(trace.src_ip, trace.dst_ip, trace.sport, trace.dport,
                   trace.proto, n_buckets=n_buckets)
    ts = rebase_ts(trace.ts)
    ln = jnp.asarray(trace.length, jnp.float32)
    fwd = (jnp.asarray(trace.direction) == 0).astype(jnp.float32)

    seg = lambda v: jax.ops.segment_sum(v, b, num_segments=n_buckets)
    cnt = seg(jnp.ones_like(ln))
    byt = seg(ln)
    t_min = jax.ops.segment_min(ts, b, num_segments=n_buckets)
    t_max = jax.ops.segment_max(ts, b, num_segments=n_buckets)
    table = table_from_registers(
        cnt, byt, t_min, t_max,
        seg(fwd), seg(1.0 - fwd), seg(ln * fwd), seg(ln * (1.0 - fwd)))
    return b, table


def aggregate_features(trace, *, key: str = "dport", n_buckets=1024):
    """Aggregate-level features over a traffic group (§5.2).

    Groups packets by a coarse key (e.g. destination port = "traffic toward
    application X") and reduces volume/rate statistics per group.
    Returns (group_ids (P,), agg_table (n_buckets, 3)): pkts, bytes, rate.
    """
    col = jnp.asarray(getattr(trace, key))
    g = (col.astype(jnp.int32) % n_buckets)
    ln = jnp.asarray(trace.length, jnp.float32)
    ts = rebase_ts(trace.ts)
    cnt = jax.ops.segment_sum(jnp.ones_like(ln), g, num_segments=n_buckets)
    byt = jax.ops.segment_sum(ln, g, num_segments=n_buckets)
    dur = jnp.where(
        cnt > 0,
        jax.ops.segment_max(ts, g, num_segments=n_buckets)
        - jax.ops.segment_min(ts, g, num_segments=n_buckets), 0.0)
    rate = jnp.where(dur > 0, byt / jnp.maximum(dur, 1e-6), 0.0)
    return g, jnp.stack([cnt, byt, rate], axis=1)


# ---------------------------------------------------------------------------
# file-level (§5.3): fixed-width csv payloads, fields split across packets
# ---------------------------------------------------------------------------

def _format_fixed(v: float, width: int) -> str:
    """Format ``v`` into exactly ``width`` ASCII chars, dropping fractional
    digits to fit. Right-truncating an over-wide rendering (the old
    behavior) silently produced a *different number* — "12345.678" cut to
    "12345.67"; here precision shrinks until the string fits, so every
    retained digit is a correctly rounded one.
    """
    for prec in range(3, -1, -1):
        s = f"{v:.{prec}f}"
        if len(s) <= width:
            return s.rjust(width)
    raise ValueError(f"value {v!r} does not fit in {width} ASCII chars")


def encode_csv_payload(values, width=8):
    """Encode float rows as fixed-width ASCII columns (the paper's
    reformatted Jane Street file: "columns of eight characters").

    values (R, C) -> uint8 bytes (R, C*width).
    """
    r, c = values.shape
    out = np.zeros((r, c * width), np.uint8)
    for i in range(r):
        row = "".join(_format_fixed(float(v), width) for v in values[i])
        out[i] = np.frombuffer(row.encode("ascii"), np.uint8)
    return out


def _ascii_to_float(field: jax.Array) -> jax.Array:
    """Parse fixed-width ASCII numeric fields (N, W) -> (N,) float32.

    Switch-feasible parsing: digit accumulation with sign and decimal point,
    no branches — each byte contributes via masked multiply-add.
    """
    is_digit = (field >= 48) & (field <= 57)
    digit = jnp.where(is_digit, field - 48, 0).astype(jnp.float32)
    is_dot = field == 46
    is_minus = field == 45
    # integer part scale: positions before the dot accumulate *10 each digit
    def scan_fn(carry, col):
        val, frac_scale, seen_dot = carry
        d, dot, dig = col
        val = jnp.where(dig & ~seen_dot, val * 10 + d, val)
        frac_scale = jnp.where(dig & seen_dot, frac_scale * 0.1, frac_scale)
        val = jnp.where(dig & seen_dot, val + d * frac_scale, val)
        seen_dot = seen_dot | dot
        return (val, frac_scale, seen_dot), None

    n, w = field.shape
    init = (jnp.zeros(n), jnp.ones(n), jnp.zeros(n, bool))
    cols = (digit.T, is_dot.T, is_digit.T)
    (val, _, _), _ = jax.lax.scan(scan_fn, init, cols)
    sign = jnp.where(jnp.any(is_minus, axis=1), -1.0, 1.0)
    return sign * val


def stitch_split_payload(first_pkt: jax.Array, second_pkt: jax.Array):
    """Re-stitch a record split across two packets (§5.3).

    Models the switch mechanism: the tail bytes of packet k are saved in a
    register and prepended to packet k+1 before parsing. first_pkt (R, A),
    second_pkt (R, B) -> (R, A+B).
    """
    return jnp.concatenate([first_pkt, second_pkt], axis=1)


def file_features_csv(payload: jax.Array, feature_cols, width=8):
    """Extract selected fixed-width columns from csv payload bytes.

    payload (R, C*width) uint8 — use stitch_split_payload first when a row
    spans packets.
    """
    feats = []
    for c in feature_cols:
        field = jax.lax.dynamic_slice_in_dim(payload, c * width, width, axis=1)
        feats.append(_ascii_to_float(field))
    return jnp.stack(feats, axis=1)
