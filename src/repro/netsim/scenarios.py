"""Adversarial packet-trace scenarios: the workloads a flow table fears.

``packets.synth_trace`` generates a benign UNSW-like mix — flows arrive
smoothly, live briefly, and hash uniformly. None of the flow-table
failure modes the streaming tier must survive show up there. This module
generates the ones that do (each an adversarial pattern from the
in-network-classification literature — pForest's churn analysis,
Jaqen/ddos-aware sketches):

  ``ddos_flood``       a burst of single-use flows converging on one
                       victim: every attack packet claims a fresh bucket,
                       churning the table through admission/eviction and
                       starving long-lived benign flows of their slots.
  ``collision_storm``  the flood aimed at the *hash*: attack 5-tuples are
                       rejection-sampled until they land in a handful of
                       target buckets, so a few registers absorb
                       thousands of flows — per-bucket aliasing the
                       uniform-hash assumption hides.
  ``slow_loris``       few flows, long idle gaps between probes: a
                       timeout-based eviction sweep forgets the flow
                       between every pair of packets (aging false
                       positives — each probe reads out as a fresh
                       one-packet flow).
  ``elephant_mice``    heavy per-flow skew: a few elephants carry
                       thousands of packets (per-bucket hot spots
                       pressing the 2^24 count envelope) over a sea of
                       two-packet mice.

Every generator composes its attack with a ``synth_trace`` background
(same class-conditional statistics the models train on) via
``merge_traces``, returns a plain ``PacketTrace`` (attack flows labeled
1), and is fully seeded — identical seeds replay identical traces, the
reproducibility contract of ``benchmarks/scenario_bench.py``. Per-packet
ground truth is ``trace.flow_label[trace.flow_id]`` as everywhere else.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.netsim.features import fnv1a_hash
from repro.netsim.packets import PacketTrace, synth_trace

SCENARIOS = ("ddos_flood", "collision_storm", "slow_loris",
             "elephant_mice")


def merge_traces(a: PacketTrace, b: PacketTrace) -> PacketTrace:
    """Interleave two traces by timestamp (stable) into one.

    ``b``'s flow ids are offset past ``a``'s so the concatenated
    ``flow_label`` stays a valid per-flow table; per-packet labels
    (``flow_label[flow_id]``) are preserved exactly.
    """
    order = np.argsort(np.concatenate([a.ts, b.ts]), kind="stable")
    cat = lambda f: np.concatenate([getattr(a, f),
                                    getattr(b, f)])[order]
    flow_id = np.concatenate([a.flow_id,
                              b.flow_id + a.n_flows]).astype(np.int32)
    return PacketTrace(
        ts=cat("ts"), src_ip=cat("src_ip"), dst_ip=cat("dst_ip"),
        sport=cat("sport"), dport=cat("dport"), proto=cat("proto"),
        length=cat("length"), direction=cat("direction"),
        flow_id=flow_id[order],
        flow_label=np.concatenate([a.flow_label,
                                   b.flow_label]).astype(np.int32))


def _attack_packets(rng, flow_id: np.ndarray, ts: np.ndarray, src_ip,
                    dst_ip, sport, dport, proto, label,
                    mean_len: float = 120.0) -> PacketTrace:
    """Assemble per-packet arrays for an attack flow set (time-sorted)."""
    order = np.argsort(ts, kind="stable")
    length = np.clip(rng.normal(mean_len, 40, len(flow_id)),
                     64, 1500).astype(np.uint16)
    direction = (rng.random(len(flow_id)) < 0.1).astype(np.uint8)
    return PacketTrace(
        ts=ts[order].astype(np.float64),
        src_ip=src_ip[flow_id][order], dst_ip=dst_ip[flow_id][order],
        sport=sport[flow_id][order], dport=dport[flow_id][order],
        proto=proto[flow_id][order], length=length[order],
        direction=direction[order],
        flow_id=flow_id[order].astype(np.int32),
        flow_label=np.asarray(label, np.int32))


def ddos_flood(*, n_background: int = 300, n_attack: int = 3000,
               pkts_per_attack: int = 1, attack_start: float = 20.0,
               attack_dur: float = 10.0, seed: int = 0) -> PacketTrace:
    """Burst of single-use flows converging on one victim.

    Each attack flow sends ``pkts_per_attack`` packets (default 1 — the
    spoofed-source SYN-flood shape) inside the ``attack_dur`` burst, from
    a unique random source, so every packet claims a fresh flow bucket:
    maximum admission churn, the workload timeout eviction handles worst
    (too-short ages churn live flows out with the flood; too-long ages
    let dead attack buckets squat).
    """
    bg = synth_trace(n_flows=n_background, seed=seed)
    rng = np.random.default_rng(seed + 0x9E37)
    src = rng.integers(0, 2 ** 32, n_attack, dtype=np.uint32)
    dst = np.full(n_attack, rng.integers(0, 2 ** 32, dtype=np.uint32),
                  dtype=np.uint32)                    # one victim
    sport = rng.integers(1024, 65535, n_attack).astype(np.uint16)
    dport = np.full(n_attack, 80, np.uint16)
    proto = np.full(n_attack, 6, np.uint8)
    flow_id = np.repeat(np.arange(n_attack, dtype=np.int32),
                        pkts_per_attack)
    ts = attack_start + rng.uniform(0, attack_dur, len(flow_id))
    atk = _attack_packets(rng, flow_id, ts, src, dst, sport, dport, proto,
                          np.ones(n_attack, np.int32))
    return merge_traces(bg, atk)


def collision_storm(*, n_background: int = 300, n_attack: int = 2000,
                    n_buckets: int = 4096, n_target_buckets: int = 4,
                    pkts_per_attack: int = 2, attack_start: float = 20.0,
                    attack_dur: float = 10.0, seed: int = 0) -> PacketTrace:
    """The flood aimed at the hash: thousands of flows, a handful of
    buckets.

    Attack 5-tuples are rejection-sampled against the same ``fnv1a_hash``
    the serving tiers use until they land in ``n_target_buckets`` chosen
    buckets — the crafted-collision attack a public hash invites. The
    targeted registers aggregate thousands of unrelated flows (feature
    garbage in, prediction garbage out for anything sharing the bucket)
    while the rest of the table stays idle, so occupancy-triggered
    defenses never fire. ``n_buckets`` must match the serving table for
    the collisions to land.
    """
    bg = synth_trace(n_flows=n_background, seed=seed)
    rng = np.random.default_rng(seed + 0x517C)
    targets = rng.choice(n_buckets, n_target_buckets, replace=False)
    keep_src = []
    keep_sport = []
    dst = rng.integers(0, 2 ** 32, dtype=np.uint32)
    need = n_attack
    while need > 0:
        # vectorized rejection sampling: acceptance is
        # n_target_buckets/n_buckets, so draw generously per round
        m = max(64 * 1024, need * (n_buckets // n_target_buckets) * 2)
        s = rng.integers(0, 2 ** 32, m, dtype=np.uint32)
        sp = rng.integers(1024, 65535, m).astype(np.uint16)
        b = np.asarray(fnv1a_hash(
            s, np.full(m, dst, np.uint32), sp, np.full(m, 80, np.uint16),
            np.full(m, 6, np.uint8), n_buckets=n_buckets))
        hit = np.isin(b, targets)
        keep_src.append(s[hit][:need])
        keep_sport.append(sp[hit][:need])
        need -= len(keep_src[-1])
    src = np.concatenate(keep_src)
    sport = np.concatenate(keep_sport)
    dsts = np.full(n_attack, dst, np.uint32)
    dport = np.full(n_attack, 80, np.uint16)
    proto = np.full(n_attack, 6, np.uint8)
    flow_id = np.repeat(np.arange(n_attack, dtype=np.int32),
                        pkts_per_attack)
    ts = attack_start + rng.uniform(0, attack_dur, len(flow_id))
    atk = _attack_packets(rng, flow_id, ts, src, dsts, sport, dport, proto,
                          np.ones(n_attack, np.int32))
    return merge_traces(bg, atk)


def slow_loris(*, n_background: int = 300, n_slow: int = 64,
               n_probes: int = 8, idle_gap: float = 30.0,
               seed: int = 0) -> PacketTrace:
    """Few flows, long-idle probes: the aging sweep's false-positive bait.

    Each slow flow sends ``n_probes`` small packets ``idle_gap`` seconds
    apart — idle far longer than any reasonable eviction age, so a
    timeout sweep evicts the flow between every pair of probes and each
    probe reads out as a fresh one-packet flow (the per-flow features the
    classifier needs never accumulate). The background keeps its normal
    pace; total span is ``n_probes * idle_gap`` seconds.
    """
    bg = synth_trace(n_flows=n_background, seed=seed)
    rng = np.random.default_rng(seed + 0x10F1)
    src = rng.integers(0, 2 ** 32, n_slow, dtype=np.uint32)
    dst = rng.integers(0, 2 ** 32, n_slow, dtype=np.uint32)
    sport = rng.integers(1024, 65535, n_slow).astype(np.uint16)
    dport = np.full(n_slow, 80, np.uint16)
    proto = np.full(n_slow, 6, np.uint8)
    flow_id = np.repeat(np.arange(n_slow, dtype=np.int32), n_probes)
    probe = np.tile(np.arange(n_probes, dtype=np.float64), n_slow)
    jitter = rng.uniform(0, 0.2, len(flow_id))
    ts = rng.uniform(0, idle_gap, n_slow)[flow_id] \
        + probe * idle_gap + jitter
    atk = _attack_packets(rng, flow_id, ts, src, dst, sport, dport, proto,
                          np.ones(n_slow, np.int32), mean_len=80.0)
    return merge_traces(bg, atk)


def elephant_mice(*, n_mice: int = 1000, n_elephants: int = 8,
                  elephant_pkts: int = 2000, duration: float = 60.0,
                  seed: int = 0) -> PacketTrace:
    """Heavy-tail skew: a few elephants over a sea of mice.

    The elephants (labeled anomalous — exfiltration-shaped bulk flows)
    each carry ``elephant_pkts`` packets across the whole trace span:
    per-bucket hot spots whose count registers grow ~1000x faster than
    any mouse's, pressing toward the 2^24 saturation envelope and making
    their buckets permanent residents no idle-based sweep can recycle.
    The mice are the plain ``synth_trace`` background.
    """
    bg = synth_trace(n_flows=n_mice, seed=seed)
    rng = np.random.default_rng(seed + 0xE1E0)
    src = rng.integers(0, 2 ** 32, n_elephants, dtype=np.uint32)
    dst = rng.integers(0, 2 ** 32, n_elephants, dtype=np.uint32)
    sport = rng.integers(1024, 65535, n_elephants).astype(np.uint16)
    dport = np.full(n_elephants, 443, np.uint16)
    proto = np.full(n_elephants, 6, np.uint8)
    flow_id = np.repeat(np.arange(n_elephants, dtype=np.int32),
                        elephant_pkts)
    ts = rng.uniform(0, duration, len(flow_id))
    atk = _attack_packets(rng, flow_id, ts, src, dst, sport, dport, proto,
                          np.ones(n_elephants, np.int32), mean_len=1400.0)
    return merge_traces(bg, atk)


SCENARIO_FNS = {
    "ddos_flood": ddos_flood,
    "collision_storm": collision_storm,
    "slow_loris": slow_loris,
    "elephant_mice": elephant_mice,
}


def make_scenario(name: str, **kw) -> PacketTrace:
    """Generate a named adversarial scenario (see ``SCENARIOS``)."""
    if name not in SCENARIO_FNS:
        raise ValueError(f"unknown scenario {name!r}; "
                         f"expected one of {SCENARIOS}")
    return SCENARIO_FNS[name](**kw)
