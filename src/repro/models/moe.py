"""Mixture-of-experts FFN with capacity-bounded scatter/gather dispatch.

Design notes (TPU/GSPMD):
  * Dispatch is *index-based* (scatter token ids into an (E, C) buffer and
    gather), not one-hot einsum: the one-hot dispatch tensor for
    deepseek-v3 train_4k would be (65k tokens, 256 experts, 2.5k capacity)
    — ~10^10 elements — while the index buffer is (E*C,) int32 and the
    gathered activations are exactly tokens*top_k*capacity_factor rows.
  * Expert weights are stacked (E, D, F) so expert-parallelism is a plain
    dim-0 sharding (P('model', ...)); the per-expert FFN is one einsum.
  * Over-capacity (token, slot) units are dropped — their combine weight
    never lands — matching capacity-factor semantics (GShard/Switch).
    The shared-expert / dense-residual path is never dropped.
  * The same dispatch/compact machinery realizes the paper's hybrid
    forwarding (core/hybrid.py): route-by-confidence is route-by-router.

Router: softmax over expert logits, top-k, weights renormalized over the
selected k (DeepSeek-style), optional always-on shared experts and an
Arctic-style dense residual branch. The load-balance aux loss (Switch
style: E * sum_e f_e * p_e) is returned for the training loss.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init, swiglu, swiglu_params

F32 = jnp.float32


def moe_params(key, cfg):
    m = cfg.moe
    d = cfg.d_model
    ks = jax.random.split(key, 4)
    p = {
        "router": dense_init(ks[0], (d, m.n_experts), scale=0.02),
        # stacked expert SwiGLU weights
        "w_gate": dense_init(ks[1], (m.n_experts, d, m.d_expert)),
        "w_up": dense_init(ks[2], (m.n_experts, d, m.d_expert)),
        "w_down": dense_init(ks[3], (m.n_experts, m.d_expert, d)),
    }
    if m.n_shared:
        p["shared"] = swiglu_params(jax.random.fold_in(key, 7),
                                    d, m.d_expert * m.n_shared)
    if m.dense_residual:
        p["dense"] = swiglu_params(jax.random.fold_in(key, 11),
                                   d, m.dense_d_ff)
    return p


def _capacity(n_tokens: int, top_k: int, n_experts: int, factor: float) -> int:
    cap = int(n_tokens * top_k * factor / n_experts)
    return max(8, ((cap + 7) // 8) * 8)   # pad to 8 for lane alignment


def moe_forward(p, cfg, x):
    """x (B, S, D) -> (out (B, S, D), aux_loss scalar)."""
    m = cfg.moe
    b, s, d = x.shape
    t = b * s
    xf = x.reshape(t, d)
    cap = _capacity(t, m.top_k, m.n_experts, m.capacity_factor)

    # --- router ------------------------------------------------------------
    logits = (xf.astype(F32) @ p["router"].astype(F32))          # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_w, gate_i = jax.lax.top_k(probs, m.top_k)               # (T, K)
    gate_w = gate_w / jnp.maximum(gate_w.sum(-1, keepdims=True), 1e-9)

    # load-balance aux loss (Switch): E * sum_e mean(frac_e) * mean(prob_e)
    onehot_top1 = jax.nn.one_hot(gate_i[:, 0], m.n_experts, dtype=F32)
    frac = onehot_top1.mean(0)
    aux = m.n_experts * jnp.sum(frac * probs.mean(0))

    # --- dispatch: position-in-expert via cumsum over (T, K) units ----------
    # unit u = (token, slot); eid (T*K,), weight (T*K,)
    eid = gate_i.reshape(-1)
    uw = gate_w.reshape(-1)
    unit_tok = jnp.repeat(jnp.arange(t), m.top_k)
    # rank of unit within its expert: cumsum of one-hot along units
    oh = jax.nn.one_hot(eid, m.n_experts, dtype=jnp.int32)       # (T*K, E)
    pos = (jnp.cumsum(oh, axis=0) - oh)                          # exclusive
    pos_in_e = jnp.take_along_axis(pos, eid[:, None], axis=1)[:, 0]
    keep = pos_in_e < cap
    slot = jnp.where(keep, eid * cap + pos_in_e, m.n_experts * cap)

    # scatter token index / weight into the (E*C,) buffer (+1 overflow row)
    buf_tok = jnp.full((m.n_experts * cap + 1,), t, jnp.int32)
    buf_tok = buf_tok.at[slot].set(unit_tok.astype(jnp.int32))
    buf_w = jnp.zeros((m.n_experts * cap + 1,), F32).at[slot].set(uw)
    buf_tok, buf_w = buf_tok[:-1], buf_w[:-1]

    # gather tokens -> (E, C, D); sentinel t hits the zero pad row.
    # The dispatch buffer rides bf16: it is the dominant EP collective
    # (tokens cross the mesh to reach their experts) — §Perf iteration A2
    # measured f32 dispatch at 2x the wire bytes with no quality change
    # (expert matmuls are bf16-in anyway; the combine stays f32).
    from repro.distributed.sharding import shard_hint
    xd = xf.astype(jnp.bfloat16)
    xpad = jnp.concatenate([xd, jnp.zeros((1, d), xd.dtype)], axis=0)
    xe = xpad[buf_tok].reshape(m.n_experts, cap, d)
    xe = shard_hint(xe, "model", None, None)       # EP: experts over 'model'

    # --- expert FFN (stacked SwiGLU) ----------------------------------------
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, p["w_gate"])) * \
        jnp.einsum("ecd,edf->ecf", xe, p["w_up"])
    ye = jnp.einsum("ecf,efd->ecd", h, p["w_down"])              # (E, C, D)
    ye = ye.astype(jnp.bfloat16)                   # combine-path bytes too
    ye = shard_hint(ye, "model", None, None)

    # --- combine: weighted scatter-add back to tokens -----------------------
    # bf16 payload + an explicit token-sharded layout on the output: the
    # combine was the measured collective whale (§Perf A5) — without the
    # hint GSPMD replicated the (T, D) f32 accumulator across 'model'
    # (~330 GB/dev/step at deepseek train_4k under remat).
    from repro.distributed.sharding import _ambient_mesh
    yflat = (ye.reshape(m.n_experts * cap, d).astype(F32)
             * buf_w[:, None]).astype(jnp.bfloat16)
    acc = jnp.zeros((t + 1, d), jnp.bfloat16)
    mesh_ = _ambient_mesh()
    if mesh_ is not None:
        baxes = ("pod", "data") if "pod" in mesh_.axis_names else ("data",)
        acc = shard_hint(acc, baxes, None)
    out = acc.at[buf_tok].add(yflat)[:-1].astype(F32)
    if mesh_ is not None:
        out = shard_hint(out, baxes, None)

    if m.n_shared:
        out = out + swiglu(p["shared"], xf).astype(F32)
    if m.dense_residual:
        out = out + swiglu(p["dense"], xf).astype(F32)
    return out.reshape(b, s, d).astype(x.dtype), aux
