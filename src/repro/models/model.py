"""Model registry: one uniform (init / train_step-able loss / prefill /
decode) surface over the three backbone families (decoder-only, enc-dec,
VLM decoder). The launcher, trainer, server and dry-run all go through
this module and never branch on architecture internals.
"""

from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.models import transformer as tfm
from repro.models import whisper as whi
from repro.models.config import ArchConfig

F32 = jnp.float32

MOE_AUX_WEIGHT = 0.01
MTP_WEIGHT = 0.3


def init_model(cfg: ArchConfig, key):
    if cfg.encdec:
        return whi.init_params(cfg, key)
    return tfm.init_params(cfg, key)


def model_param_shapes(cfg: ArchConfig):
    return jax.eval_shape(lambda k: init_model(cfg, k), jax.random.PRNGKey(0))


def _xent(logits, labels):
    """Mean token cross-entropy, f32 logsumexp (vocab-sharding friendly)."""
    logits = logits.astype(F32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None],
                               axis=-1)[..., 0]
    return jnp.mean(lse - gold)


def loss_fn(params, cfg: ArchConfig, batch, *, remat=True):
    """batch: tokens/labels (+frames or patch_embeds). -> (loss, metrics)."""
    tokens, labels = batch["tokens"], batch["labels"]
    if cfg.encdec:
        logits, aux = whi.forward_train(params, cfg, tokens, batch["frames"],
                                        remat=remat)
    else:
        logits, aux = tfm.forward_train(
            params, cfg, tokens,
            patch_embeds=batch.get("patch_embeds"), remat=remat)
    loss = _xent(logits, labels)
    total = loss + MOE_AUX_WEIGHT * aux["moe_aux"]
    metrics = {"xent": loss, "moe_aux": aux["moe_aux"]}
    if "mtp_logits" in aux:
        # MTP head predicts token t+2: logits t covers label t+1
        mtp = _xent(aux["mtp_logits"], labels[:, 1:])
        total = total + MTP_WEIGHT * mtp
        metrics["mtp_xent"] = mtp
    metrics["loss"] = total
    return total, metrics


def prefill(params, cfg: ArchConfig, batch):
    """-> (last-token logits (B, V), caches)."""
    if cfg.encdec:
        return whi.forward_prefill(params, cfg, batch["tokens"],
                                   batch["frames"])
    return tfm.forward_prefill(params, cfg, batch["tokens"],
                               patch_embeds=batch.get("patch_embeds"))


def decode_step(params, cfg: ArchConfig, token, pos, caches):
    """-> (logits (B, V), new caches)."""
    if cfg.encdec:
        return whi.forward_decode(params, cfg, token, pos, caches)
    return tfm.forward_decode(params, cfg, token, pos, caches)


def init_decode_cache(cfg: ArchConfig, batch, max_len, dtype=jnp.bfloat16,
                      quantize_kv=False):
    if cfg.encdec:
        return whi.init_decode_cache(cfg, batch, max_len,
                                     cfg.n_frontend_tokens, dtype)
    return tfm.init_decode_cache(cfg, batch, max_len, dtype,
                                 quantize_kv=quantize_kv)


def count_params(shapes) -> int:
    import math
    return sum(math.prod(l.shape) for l in jax.tree.leaves(shapes))


def active_params(cfg: ArchConfig, total: int) -> int:
    """Per-token active parameters (MoE: routed experts count top_k/E)."""
    if cfg.moe is None:
        return total
    m = cfg.moe
    per_expert = 3 * cfg.d_model * m.d_expert
    n_moe_layers = sum(
        1 for i in range(cfg.n_layers)
        if cfg.moe is not None and i >= m.n_dense_layers and cfg.d_ff > 0)
    inactive = n_moe_layers * per_expert * (m.n_experts - m.top_k)
    return total - inactive
