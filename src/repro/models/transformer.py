"""Composable decoder stack driven by ArchConfig.

Layer plan / compile-time scaling
---------------------------------
Layers are grouped into *segments*: maximal runs where the per-layer spec
sequence is periodic with the arch's block pattern. Each segment's params
are stacked over periods and executed with ``jax.lax.scan`` — so HLO size
and compile time scale with the number of *distinct* layer specs (2-3 for
every assigned arch), not with n_layers (61 for deepseek-v3). Remainders
that don't fill a period run unrolled.

Per-layer wiring (pre-norm residual):
  x = x + Block(norm1(x))          Block in {gqa, local gqa, MLA, RG-LRU,
                                             mLSTM, sLSTM}
  x = x + FFN(norm2(x))            FFN in {swiglu, moe, none}

Three entry modes share the same layer code:
  train    full sequence, no caches, returns (logits, aux)
  prefill  full sequence, returns (logits, caches)
  decode   one token + caches, returns (logits, new caches)

Caches mirror the segment structure (stacked leading period dim), so the
decode step scans (params, cache) jointly.
"""

from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.models import attention as att
from repro.models import moe as moe_mod
from repro.models import recurrent as rec
from repro.models.config import ArchConfig
from repro.models.layers import (apply_norm, dense_init, norm_params,
                                 swiglu, swiglu_params)

F32 = jnp.float32


# ---------------------------------------------------------------------------
# layer plan
# ---------------------------------------------------------------------------

def _layer_spec(cfg: ArchConfig, i: int):
    block = cfg.block_kind(i)
    if block == "attn" and cfg.attn_kind == "mla":
        block = "mla"
    if cfg.d_ff == 0:
        ffn = "none"
    elif cfg.moe is not None and i >= cfg.moe.n_dense_layers:
        ffn = "moe"
    else:
        ffn = "dense"
    return (block, ffn)


def layer_plan(cfg: ArchConfig):
    """-> list of segments: {"specs": tuple[LayerSpec], "n_periods": int}.

    A segment with n_periods > 1 is scanned; n_periods == 1 runs inline.
    """
    specs = [_layer_spec(cfg, i) for i in range(cfg.n_layers)]
    period = len(cfg.block_pattern)
    segments = []
    i = 0
    while i < cfg.n_layers:
        # longest periodic run starting at i
        pat = tuple(specs[i:i + period])
        n = 0
        while (i + (n + 1) * period <= cfg.n_layers
               and tuple(specs[i + n * period:i + (n + 1) * period]) == pat):
            n += 1
        if n >= 1 and len(pat) == period:
            segments.append({"specs": pat, "n_periods": n})
            i += n * period
        else:   # ragged tail: single layers
            segments.append({"specs": (specs[i],), "n_periods": 1})
            i += 1
    return segments


# ---------------------------------------------------------------------------
# per-layer params
# ---------------------------------------------------------------------------

def _init_block(cfg, key, kind):
    if kind in ("attn", "local_attn"):
        return att.gqa_params(key, cfg)
    if kind == "mla":
        return att.mla_params(key, cfg)
    if kind == "rglru":
        return rec.rglru_params(key, cfg)
    if kind == "mlstm":
        return rec.mlstm_params(key, cfg)
    if kind == "slstm":
        return rec.slstm_params(key, cfg)
    raise ValueError(kind)


def _init_layer(cfg, key, spec):
    block, ffn = spec
    k1, k2 = jax.random.split(key)
    p = {"norm1": norm_params(cfg, cfg.d_model),
         "block": _init_block(cfg, k1, block)}
    if ffn != "none":
        p["norm2"] = norm_params(cfg, cfg.d_model)
        p["ffn"] = (moe_mod.moe_params(k2, cfg) if ffn == "moe"
                    else swiglu_params(k2, cfg.d_model, cfg.d_ff))
    return p


def _init_period(cfg, key, specs):
    ks = jax.random.split(key, len(specs))
    return [_init_layer(cfg, k, s) for k, s in zip(ks, specs)]


def init_params(cfg: ArchConfig, key) -> dict:
    ks = jax.random.split(key, 8)
    segs = []
    for si, seg in enumerate(layer_plan(cfg)):
        kseg = jax.random.fold_in(ks[1], si)
        if seg["n_periods"] == 1:
            segs.append(_init_period(cfg, kseg, seg["specs"]))
        else:
            pks = jax.random.split(kseg, seg["n_periods"])
            segs.append(jax.vmap(
                lambda k: _init_period(cfg, k, seg["specs"]))(pks))
    p = {
        "embed": dense_init(ks[0], (cfg.vocab_size, cfg.d_model), scale=0.02),
        "segments": segs,
        "final_norm": norm_params(cfg, cfg.d_model),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = dense_init(ks[2], (cfg.d_model, cfg.vocab_size))
    if cfg.frontend == "image_patches":
        p["patch_proj"] = dense_init(ks[3], (cfg.frontend_dim, cfg.d_model))
    if cfg.mtp:
        p["mtp"] = {"proj": dense_init(ks[4], (2 * cfg.d_model, cfg.d_model)),
                    "layer": _init_layer(cfg, ks[5],
                                         _layer_spec(cfg, cfg.n_layers - 1)),
                    "norm": norm_params(cfg, cfg.d_model)}
    return p


def param_shapes(cfg: ArchConfig):
    """Shape/dtype tree without allocating (for dry-run / checkpoints)."""
    return jax.eval_shape(
        lambda k: init_params(cfg, k), jax.random.PRNGKey(0))


# ---------------------------------------------------------------------------
# per-layer forward (mode in {"train", "prefill", "decode"})
# ---------------------------------------------------------------------------

def _window(cfg, kind):
    if kind == "local_attn":
        return cfg.local_window
    return cfg.sliding_window   # None for full attention


def _block_apply(p, cfg, kind, x, positions, mode, cache, pos):
    """-> (y, new_cache)."""
    if kind in ("attn", "local_attn"):
        w = _window(cfg, kind)
        if mode == "decode":
            return att.gqa_decode(p, cfg, x, pos, cache, window=w)
        y, kv = att.gqa_prefill(p, cfg, x, positions, window=w,
                                flash=x.shape[1] >= 2048)
        if mode == "train":
            return y, None
        return y, _kv_to_cache(cfg, kv, positions, w)
    if kind == "mla":
        if mode == "decode":
            return att.mla_decode(p, cfg, x, pos, cache)
        y, (c_kv, k_rope) = att.mla_forward(p, cfg, x, positions)
        if mode == "train":
            return y, None
        return y, {"c_kv": c_kv, "k_rope": k_rope}
    fwd = {"rglru": (rec.rglru_block, rec.rglru_block_decode),
           "mlstm": (rec.mlstm_block, rec.mlstm_block_decode),
           "slstm": (rec.slstm_block, rec.slstm_block_decode)}[kind]
    if mode == "decode":
        return fwd[1](p, cfg, x, cache)
    y, state = fwd[0](p, cfg, x)
    return y, (state if mode == "prefill" else None)


def _kv_to_cache(cfg, kv, positions, window):
    """Turn prefill (k, v) into the decode ring cache layout."""
    k, v = kv
    s = k.shape[1]
    size = min(s, window) if window else s
    pos_ids = positions[0]                           # (S,) assume aligned
    if window and s > size:
        k, v, pos_ids = k[:, -size:], v[:, -size:], pos_ids[-size:]
    # ring layout: slot = pos % size
    slots = pos_ids % size
    order = jnp.argsort(slots)
    return {"k": k[:, order], "v": v[:, order], "pos": pos_ids[order]}


def _layer_apply(p, cfg, spec, x, positions, mode, cache, pos):
    """-> (x, new_cache, aux)."""
    block, ffn = spec
    h = apply_norm(cfg, p["norm1"], x)
    y, new_cache = _block_apply(p["block"], cfg, block, h, positions,
                                mode, cache, pos)
    x = x + y
    aux = jnp.zeros((), F32)
    if ffn == "dense":
        x = x + swiglu(p["ffn"], apply_norm(cfg, p["norm2"], x))
    elif ffn == "moe":
        y, aux = moe_mod.moe_forward(p["ffn"], cfg,
                                     apply_norm(cfg, p["norm2"], x))
        x = x + y
    return x, new_cache, aux


def _period_apply(period_params, cfg, specs, x, positions, mode,
                  period_cache, pos):
    new_caches = []
    aux = jnp.zeros((), F32)
    for li, (p, spec) in enumerate(zip(period_params, specs)):
        c = None if period_cache is None else period_cache[li]
        x, nc, a = _layer_apply(p, cfg, spec, x, positions, mode, c, pos)
        new_caches.append(nc)
        aux = aux + a
    return x, new_caches, aux


# ---------------------------------------------------------------------------
# stack forward
# ---------------------------------------------------------------------------

def _run_segments(params, cfg, x, positions, mode, caches, pos, remat):
    """caches: list aligned with segments (None in train mode)."""
    new_caches = []
    aux_total = jnp.zeros((), F32)
    plan = layer_plan(cfg)
    for si, seg in enumerate(plan):
        seg_p = params["segments"][si]
        specs = seg["specs"]
        seg_cache = None if caches is None else caches[si]
        if seg["n_periods"] == 1:
            x, nc, aux = _period_apply(seg_p, cfg, specs, x, positions,
                                       mode, seg_cache, pos)
            new_caches.append(nc)
            aux_total = aux_total + aux
        else:
            def body(carry, xs):
                xc, aux_c = carry
                if mode == "decode":
                    pp, pc = xs
                else:
                    pp, pc = xs, None
                xc, nc, aux = _period_apply(pp, cfg, specs, xc, positions,
                                            mode, pc, pos)
                return (xc, aux_c + aux), nc

            if remat:
                body = jax.checkpoint(body)
            xs = (seg_p, seg_cache) if mode == "decode" else seg_p
            (x, aux_total), nc = jax.lax.scan(body, (x, aux_total), xs)
            new_caches.append(nc if mode != "train" else None)
    return x, new_caches, aux_total


def _embed(params, cfg, tokens, patch_embeds=None, frames=None):
    x = params["embed"][tokens]                      # (B, S, D)
    if cfg.frontend == "image_patches" and patch_embeds is not None:
        pe = patch_embeds.astype(x.dtype) @ params["patch_proj"]
        x = jnp.concatenate([pe, x], axis=1)
    return x


def _logits(params, cfg, x):
    x = apply_norm(cfg, params["final_norm"], x)
    head = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
    return x @ head


def forward_train(params, cfg: ArchConfig, tokens, *, patch_embeds=None,
                  remat=True):
    """tokens (B, S) -> (logits (B, S_text_out, V), aux losses dict).

    With an image frontend, logits cover only the text positions.
    """
    b, s = tokens.shape
    x = _embed(params, cfg, tokens, patch_embeds)
    positions = jnp.broadcast_to(jnp.arange(x.shape[1]), (b, x.shape[1]))
    x, _, aux = _run_segments(params, cfg, x, positions, "train",
                              None, None, remat)
    n_front = x.shape[1] - s
    xt = x[:, n_front:]
    logits = _logits(params, cfg, xt)
    out_aux = {"moe_aux": aux}
    if cfg.mtp:
        # DeepSeek-V3 MTP: one extra layer predicts token t+2 from
        # concat(h_t, embed(token_{t+1})), sharing the embedding/head.
        emb_next = params["embed"][tokens]
        h_in = jnp.concatenate([xt[:, :-1], emb_next[:, 1:]], axis=-1)
        h = h_in @ params["mtp"]["proj"]
        h, _, _ = _period_apply([params["mtp"]["layer"]], cfg,
                                (_layer_spec(cfg, cfg.n_layers - 1),),
                                h, positions[:, 1:], "train", None, None)
        out_aux["mtp_logits"] = _logits(
            {**params, "final_norm": params["mtp"]["norm"]}, cfg, h)
    return logits, out_aux


def forward_prefill(params, cfg: ArchConfig, tokens, *, patch_embeds=None):
    """-> (last-position logits (B, V), caches)."""
    b, s = tokens.shape
    x = _embed(params, cfg, tokens, patch_embeds)
    positions = jnp.broadcast_to(jnp.arange(x.shape[1]), (b, x.shape[1]))
    x, caches, _ = _run_segments(params, cfg, x, positions, "prefill",
                                 None, None, False)
    return _logits(params, cfg, x[:, -1]), caches


def forward_decode(params, cfg: ArchConfig, token, pos, caches):
    """token (B,) int32, pos scalar -> (logits (B, V), new caches)."""
    x = params["embed"][token][:, None, :]           # (B, 1, D)
    positions = jnp.full((x.shape[0], 1), pos, jnp.int32)
    x, new_caches, _ = _run_segments(params, cfg, x, positions, "decode",
                                     caches, pos, False)
    return _logits(params, cfg, x[:, 0]), new_caches


# ---------------------------------------------------------------------------
# decode cache init (shape-faithful for every block kind)
# ---------------------------------------------------------------------------

def _layer_cache(cfg, spec, batch, max_len, dtype, quantize_kv=False):
    block, _ = spec
    if block in ("attn", "local_attn"):
        return att.init_gqa_cache(cfg, batch, max_len, dtype,
                                  window=_window(cfg, block),
                                  quantized=quantize_kv)
    if block == "mla":
        return att.init_mla_cache(cfg, batch, max_len, dtype)
    if block == "rglru":
        return rec.rglru_init_state(cfg, batch, dtype)
    if block == "mlstm":
        return rec.mlstm_init_state(cfg, batch, dtype)
    if block == "slstm":
        return rec.slstm_init_state(cfg, batch, dtype)
    raise ValueError(block)


def init_decode_cache(cfg: ArchConfig, batch, max_len, dtype=jnp.bfloat16,
                      quantize_kv=False):
    caches = []
    for seg in layer_plan(cfg):
        per = [_layer_cache(cfg, s, batch, max_len, dtype, quantize_kv)
               for s in seg["specs"]]
        if seg["n_periods"] == 1:
            caches.append(per)
        else:
            caches.append(jax.tree.map(
                lambda a: jnp.broadcast_to(
                    a, (seg["n_periods"],) + a.shape).copy(), per))
    return caches


def decode_cache_shapes(cfg: ArchConfig, batch, max_len, dtype=jnp.bfloat16):
    return jax.eval_shape(
        functools.partial(init_decode_cache, cfg, batch, max_len, dtype))
