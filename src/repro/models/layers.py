"""Shared neural building blocks (norms, rope, MLPs, init)."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def dense_init(key, shape, scale=None):
    """Truncated-normal fan-in init, fp32 master weights."""
    fan_in = shape[0] if len(shape) >= 2 else max(shape[0], 1)
    std = scale if scale is not None else 1.0 / np.sqrt(fan_in)
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * std)


def rmsnorm(x, w, eps=1e-6):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps)).astype(x.dtype) * w


def layernorm(x, w, b, eps=1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    return (((xf - mu) * jax.lax.rsqrt(var + eps)).astype(x.dtype) * w + b)


def apply_norm(cfg, p, x):
    if cfg.norm == "rmsnorm":
        return rmsnorm(x, p["w"])
    return layernorm(x, p["w"], p["b"])


def norm_params(cfg, d):
    if cfg.norm == "rmsnorm":
        return {"w": jnp.ones((d,), jnp.float32)}
    return {"w": jnp.ones((d,), jnp.float32), "b": jnp.zeros((d,), jnp.float32)}


# ---------------------------------------------------------------------------
# rotary embeddings
# ---------------------------------------------------------------------------

def rope_freqs(dim, theta):
    return 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))


def apply_rope(x, positions, theta=10000.0):
    """x (..., S, H, hd), positions (..., S) -> same shape, rotated."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # (hd/2,)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    cos = jnp.cos(angles)[..., None, :]                 # (..., S, 1, hd/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x, 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# feed-forward
# ---------------------------------------------------------------------------

def swiglu_params(key, d_model, d_ff):
    k1, k2, k3 = jax.random.split(key, 3)
    return {"gate": dense_init(k1, (d_model, d_ff)),
            "up": dense_init(k2, (d_model, d_ff)),
            "down": dense_init(k3, (d_ff, d_model))}


def swiglu(p, x):
    h = jax.nn.silu(x @ p["gate"]) * (x @ p["up"])
    return h @ p["down"]


def gelu_mlp_params(key, d_model, d_ff):
    k1, k2 = jax.random.split(key)
    return {"up": dense_init(k1, (d_model, d_ff)),
            "up_b": jnp.zeros((d_ff,), jnp.float32),
            "down": dense_init(k2, (d_ff, d_model)),
            "down_b": jnp.zeros((d_model,), jnp.float32)}


def gelu_mlp(p, x):
    return jax.nn.gelu(x @ p["up"] + p["up_b"]) @ p["down"] + p["down_b"]


def sinusoidal_positions(n_pos, dim):
    pos = np.arange(n_pos)[:, None]
    i = np.arange(dim // 2)[None, :]
    angle = pos / np.power(10000.0, 2 * i / dim)
    out = np.concatenate([np.sin(angle), np.cos(angle)], axis=1)
    return jnp.asarray(out, jnp.float32)
