"""Whisper-style encoder-decoder backbone (audio frontend is a stub).

Per the assignment the conv frontend is NOT modeled: ``input_specs`` provides
precomputed frame embeddings (B, n_frames, d_model) — the post-conv
representation. The encoder adds sinusoidal positions and runs bidirectional
attention; the decoder is causal self-attention + cross-attention + GELU MLP
(LayerNorm pre-norm, as in Whisper).

Deviation noted in DESIGN.md: decoder positions use RoPE rather than learned
absolute embeddings (shared attention substrate); encoder disables rotation
by passing position 0 and relies on the additive sinusoidal table.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import attention as att
from repro.models.config import ArchConfig
from repro.models.layers import (dense_init, gelu_mlp, gelu_mlp_params,
                                 layernorm, sinusoidal_positions)

F32 = jnp.float32


def _ln_params(d):
    return {"w": jnp.ones((d,), F32), "b": jnp.zeros((d,), F32)}


def _enc_layer_params(key, cfg):
    k1, k2 = jax.random.split(key)
    return {"norm1": _ln_params(cfg.d_model),
            "attn": att.gqa_params(k1, cfg),
            "norm2": _ln_params(cfg.d_model),
            "mlp": gelu_mlp_params(k2, cfg.d_model, cfg.d_ff)}


def _dec_layer_params(key, cfg):
    k1, k2, k3 = jax.random.split(key, 3)
    return {"norm1": _ln_params(cfg.d_model),
            "self": att.gqa_params(k1, cfg),
            "norm2": _ln_params(cfg.d_model),
            "cross": att.cross_attn_params(k2, cfg),
            "norm3": _ln_params(cfg.d_model),
            "mlp": gelu_mlp_params(k3, cfg.d_model, cfg.d_ff)}


def init_params(cfg: ArchConfig, key) -> dict:
    ks = jax.random.split(key, 4)
    enc_keys = jax.random.split(ks[0], cfg.n_encoder_layers)
    dec_keys = jax.random.split(ks[1], cfg.n_layers)
    return {
        "embed": dense_init(ks[2], (cfg.vocab_size, cfg.d_model), scale=0.02),
        "enc_layers": jax.vmap(lambda k: _enc_layer_params(k, cfg))(enc_keys),
        "enc_norm": _ln_params(cfg.d_model),
        "dec_layers": jax.vmap(lambda k: _dec_layer_params(k, cfg))(dec_keys),
        "final_norm": _ln_params(cfg.d_model),
    }   # head tied to embed (Whisper ties)


def _ln(p, x):
    return layernorm(x, p["w"], p["b"])


def encode(params, cfg: ArchConfig, frames, *, remat=True):
    """frames (B, T, D) -> encoder states (B, T, D)."""
    b, t, d = frames.shape
    x = frames + sinusoidal_positions(t, d)[None]
    zero_pos = jnp.zeros((b, t), jnp.int32)     # disables rotary

    def body(x, lp):
        h, _ = att.gqa_forward(lp["attn"], cfg, _ln(lp["norm1"], x),
                               zero_pos, bidirectional=True)
        x = x + h
        x = x + gelu_mlp(lp["mlp"], _ln(lp["norm2"], x))
        return x, None

    if remat:
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, params["enc_layers"])
    return _ln(params["enc_norm"], x)


def _dec_layer(lp, cfg, x, positions, enc_kv, mode, cache, pos):
    if mode == "decode":
        h, new_self = att.gqa_decode(lp["self"], cfg,
                                     _ln(lp["norm1"], x), pos, cache["self"])
    else:
        h, kv = att.gqa_prefill(lp["self"], cfg, _ln(lp["norm1"], x),
                                positions, flash=x.shape[1] >= 2048)
        new_self = _prefill_cache(kv, positions) if mode == "prefill" else None
    x = x + h
    x = x + att.cross_attention(lp["cross"], cfg, _ln(lp["norm2"], x), enc_kv)
    x = x + gelu_mlp(lp["mlp"], _ln(lp["norm3"], x))
    new_cache = (None if mode == "train"
                 else {"self": new_self, "cross": enc_kv})
    return x, new_cache


def _prefill_cache(kv, positions):
    k, v = kv
    return {"k": k, "v": v, "pos": positions[0]}


def forward_train(params, cfg: ArchConfig, tokens, frames, *, remat=True):
    """Teacher-forced decoder over stub-encoded audio. -> (logits, aux)."""
    enc = encode(params, cfg, frames)
    b, s = tokens.shape
    x = params["embed"][tokens]
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))

    def body(x, lp):
        enc_kv = att.encode_cross_kv(lp["cross"], cfg, enc)
        x, _ = _dec_layer(lp, cfg, x, positions, enc_kv, "train", None, None)
        return x, None

    if remat:
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, params["dec_layers"])
    logits = _ln(params["final_norm"], x) @ params["embed"].T
    return logits, {"moe_aux": jnp.zeros((), F32)}


def forward_prefill(params, cfg: ArchConfig, tokens, frames):
    enc = encode(params, cfg, frames)
    b, s = tokens.shape
    x = params["embed"][tokens]
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))

    def body(x, lp):
        enc_kv = att.encode_cross_kv(lp["cross"], cfg, enc)
        x, cache = _dec_layer(lp, cfg, x, positions, enc_kv, "prefill",
                              None, None)
        return x, cache

    x, caches = jax.lax.scan(body, x, params["dec_layers"])
    logits = _ln(params["final_norm"], x[:, -1]) @ params["embed"].T
    return logits, caches


def forward_decode(params, cfg: ArchConfig, token, pos, caches):
    x = params["embed"][token][:, None, :]

    def body(x, xs):
        lp, cache = xs
        x, new_cache = _dec_layer(lp, cfg, x, None, cache["cross"],
                                  "decode", cache, pos)
        return x, new_cache

    x, new_caches = jax.lax.scan(body, x, (params["dec_layers"], caches))
    logits = _ln(params["final_norm"], x[:, 0]) @ params["embed"].T
    return logits, new_caches


def init_decode_cache(cfg: ArchConfig, batch, max_len, n_frames,
                      dtype=jnp.bfloat16):
    """Self-attn ring caches + cross-KV slots, stacked over decoder layers."""
    g, hd = cfg.n_kv_heads, cfg.head_dim
    L = cfg.n_layers
    self_c = att.init_gqa_cache(cfg, batch, max_len, dtype)
    return {
        "self": jax.tree.map(
            lambda a: jnp.broadcast_to(a, (L,) + a.shape).copy(), self_c),
        "cross": (jnp.zeros((L, batch, n_frames, g, hd), dtype),
                  jnp.zeros((L, batch, n_frames, g, hd), dtype)),
    }
