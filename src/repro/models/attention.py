"""Attention variants: GQA (+qk-norm, +bias, +sliding window), blockwise
"flash" attention for long prefill, MLA (DeepSeek latent attention) with a
naive and an *absorbed* decode path, and cross-attention for enc-dec.

Shapes: hidden (B, S, D); per-head tensors (B, S, H, hd).
Caches are functional: every decode returns the updated cache pytree.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init, apply_rope, rmsnorm

F32 = jnp.float32


# ---------------------------------------------------------------------------
# parameter builders
# ---------------------------------------------------------------------------

def gqa_params(key, cfg):
    d, h, g, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    p = {"wq": dense_init(ks[0], (d, h * hd)),
         "wk": dense_init(ks[1], (d, g * hd)),
         "wv": dense_init(ks[2], (d, g * hd)),
         "wo": dense_init(ks[3], (h * hd, d))}
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h * hd,), F32)
        p["bk"] = jnp.zeros((g * hd,), F32)
        p["bv"] = jnp.zeros((g * hd,), F32)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), F32)
        p["k_norm"] = jnp.ones((hd,), F32)
    return p


def mla_params(key, cfg):
    m = cfg.mla
    d, h = cfg.d_model, cfg.n_heads
    ks = jax.random.split(key, 5)
    return {
        "wq_a": dense_init(ks[0], (d, m.q_lora_rank)),
        "q_norm": jnp.ones((m.q_lora_rank,), F32),
        "wq_b": dense_init(ks[1], (m.q_lora_rank,
                                   h * (m.qk_nope_dim + m.qk_rope_dim))),
        "wkv_a": dense_init(ks[2], (d, m.kv_lora_rank + m.qk_rope_dim)),
        "kv_norm": jnp.ones((m.kv_lora_rank,), F32),
        "wkv_b": dense_init(ks[3], (m.kv_lora_rank,
                                    h * (m.qk_nope_dim + m.v_head_dim))),
        "wo": dense_init(ks[4], (h * m.v_head_dim, d)),
    }


def cross_attn_params(key, cfg):
    return gqa_params(key, cfg)


# ---------------------------------------------------------------------------
# QKV projection (GQA)
# ---------------------------------------------------------------------------

def _project_qkv(p, cfg, x, positions):
    from repro.distributed.sharding import hint_batch_heads
    b, s, _ = x.shape
    h, g, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = hint_batch_heads(q.reshape(b, s, h, hd))
    k = hint_batch_heads(k.reshape(b, s, g, hd))
    v = hint_batch_heads(v.reshape(b, s, g, hd))
    if cfg.qk_norm:
        q = rmsnorm(q, p["q_norm"])
        k = rmsnorm(k, p["k_norm"])
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _sdpa(q, k, v, mask, scale):
    """q (B,Sq,H,hd), k/v (B,Sk,G,hd) grouped attention with bool mask."""
    b, sq, h, hd = q.shape
    g = k.shape[2]
    q = q.reshape(b, sq, g, h // g, hd)
    scores = jnp.einsum("bqgmd,bkgd->bgmqk", q, k,
                        preferred_element_type=F32) * scale
    if mask is not None:
        scores = jnp.where(mask[:, None, None, :, :], scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bgmqk,bkgd->bqgmd", w, v)
    return out.reshape(b, sq, h, hd)


def causal_mask(sq, sk, window=None, offset=0):
    """(1, Sq, Sk) bool. offset = number of kv positions before q[0]."""
    qi = jnp.arange(sq)[:, None] + offset
    ki = jnp.arange(sk)[None, :]
    m = ki <= qi
    if window is not None:
        m = m & (qi - ki < window)
    return m[None]


def gqa_forward(p, cfg, x, positions, *, window=None, bidirectional=False):
    """Full-sequence attention (training / short prefill)."""
    q, k, v = _project_qkv(p, cfg, x, positions)
    s = x.shape[1]
    mask = None if bidirectional else causal_mask(s, s, window)
    out = _sdpa(q, k, v, mask, 1.0 / jnp.sqrt(cfg.head_dim).astype(F32))
    return out.reshape(x.shape[0], s, -1) @ p["wo"], (k, v)


# ---------------------------------------------------------------------------
# blockwise online-softmax attention (long prefill; O(S * block) memory)
# ---------------------------------------------------------------------------

def flash_attention(q, k, v, *, window=None, q_block=1024, k_block=1024,
                    scale=None):
    """Causal grouped attention via online softmax. q (B,S,H,hd).

    Sequences are padded internally to block multiples: padded KV columns
    sit at positions > any real query (causally masked out); padded query
    rows are sliced off. v's head dim may differ from q/k's (MLA).
    """
    b, s_orig, h, hd = q.shape
    g = k.shape[2]
    hd_v = v.shape[-1]
    scale = scale if scale is not None else 1.0 / jnp.sqrt(jnp.float32(hd))
    q_block = min(q_block, s_orig)
    k_block = min(k_block, s_orig)
    pad = (-s_orig) % q_block
    if q_block != k_block:
        lcm = (q_block * k_block) // __import__("math").gcd(q_block, k_block)
        pad = (-s_orig) % lcm
    if pad:
        padder = lambda a: jnp.pad(a, ((0, 0), (0, pad), (0, 0), (0, 0)))
        q, k, v = padder(q), padder(k), padder(v)
    s = s_orig + pad
    nq = s // q_block
    nk = s // k_block
    qb = q.reshape(b, nq, q_block, h, hd)

    def per_qblock(qi, q_i):
        # q_i (B, Qb, H, hd); scan kv blocks 0..nk-1, masked beyond causal
        q_i = q_i.reshape(b, q_block, g, h // g, hd)

        def step(carry, ki):
            m_run, l_run, acc = carry
            k_i = jax.lax.dynamic_slice_in_dim(k, ki * k_block, k_block, 1)
            v_i = jax.lax.dynamic_slice_in_dim(v, ki * k_block, k_block, 1)
            sc = jnp.einsum("bqgmd,bkgd->bgmqk", q_i, k_i,
                            preferred_element_type=F32) * scale
            qpos = qi * q_block + jnp.arange(q_block)[:, None]
            kpos = ki * k_block + jnp.arange(k_block)[None, :]
            msk = kpos <= qpos
            if window is not None:
                msk = msk & (qpos - kpos < window)
            sc = jnp.where(msk[None, None, None], sc, -1e30)
            m_new = jnp.maximum(m_run, sc.max(-1))
            alpha = jnp.exp(m_run - m_new)
            pexp = jnp.exp(sc - m_new[..., None])
            l_new = l_run * alpha + pexp.sum(-1)
            acc = acc * alpha[..., None] + jnp.einsum(
                "bgmqk,bkgd->bgmqd", pexp, v_i.astype(F32))
            return (m_new, l_new, acc), None

        m0 = jnp.full((b, g, h // g, q_block), -1e30, F32)
        l0 = jnp.zeros((b, g, h // g, q_block), F32)
        a0 = jnp.zeros((b, g, h // g, q_block, hd_v), F32)
        (m_f, l_f, acc), _ = jax.lax.scan(step, (m0, l0, a0), jnp.arange(nk))
        out = acc / jnp.maximum(l_f[..., None], 1e-30)
        return out.transpose(0, 3, 1, 2, 4).reshape(b, q_block, h, hd_v)

    outs = jax.lax.map(lambda i: per_qblock(i, qb[:, i]), jnp.arange(nq))
    out = outs.transpose(1, 0, 2, 3, 4).reshape(b, s, h, hd_v).astype(q.dtype)
    return out[:, :s_orig]


def gqa_prefill(p, cfg, x, positions, *, window=None, flash=True):
    """Long prefill: blockwise attention, returns output and (k, v) cache."""
    q, k, v = _project_qkv(p, cfg, x, positions)
    if flash:
        out = flash_attention(q, k, v, window=window)
    else:
        s = x.shape[1]
        out = _sdpa(q, k, v, causal_mask(s, s, window),
                    1.0 / jnp.sqrt(cfg.head_dim).astype(F32))
    return out.reshape(x.shape[0], x.shape[1], -1) @ p["wo"], (k, v)


# ---------------------------------------------------------------------------
# decode (single token) with KV caches
# ---------------------------------------------------------------------------

def init_gqa_cache(cfg, batch, max_len, dtype=jnp.bfloat16, window=None,
                   quantized=False):
    """quantized=True stores K/V as int8 with a per-(slot, head) fp32
    absmax scale — the paper's "action data bits" knob applied to the
    serving backend's KV memory (halves cache HBM reads vs bf16)."""
    size = min(max_len, window) if window else max_len
    g, hd = cfg.n_kv_heads, cfg.head_dim
    kv_dtype = jnp.int8 if quantized else dtype
    c = {"k": jnp.zeros((batch, size, g, hd), kv_dtype),
         "v": jnp.zeros((batch, size, g, hd), kv_dtype),
         "pos": jnp.full((size,), -1, jnp.int32)}
    if quantized:
        c["k_scale"] = jnp.zeros((batch, size, g, 1), jnp.float32)
        c["v_scale"] = jnp.zeros((batch, size, g, 1), jnp.float32)
    return c


def _q8(v):
    """Symmetric int8 quantize along the last dim. -> (q, scale)."""
    s = jnp.max(jnp.abs(v.astype(F32)), axis=-1, keepdims=True) / 127.0
    s = jnp.maximum(s, 1e-8)
    return jnp.round(v.astype(F32) / s).astype(jnp.int8), s


def gqa_decode(p, cfg, x, pos, cache, *, window=None):
    """x (B, 1, D), pos scalar int32. Returns (out (B,1,D), new cache)."""
    b = x.shape[0]
    positions = jnp.full((b, 1), pos, jnp.int32)
    q, k, v = _project_qkv(p, cfg, x, positions)
    size = cache["k"].shape[1]
    slot = pos % size if window else pos
    quantized = "k_scale" in cache
    if quantized:
        k_q, k_s = _q8(k)
        v_q, v_s = _q8(v)
        ck = jax.lax.dynamic_update_slice_in_dim(cache["k"], k_q, slot, 1)
        cv = jax.lax.dynamic_update_slice_in_dim(cache["v"], v_q, slot, 1)
        cks = jax.lax.dynamic_update_slice_in_dim(cache["k_scale"], k_s,
                                                  slot, 1)
        cvs = jax.lax.dynamic_update_slice_in_dim(cache["v_scale"], v_s,
                                                  slot, 1)
        k_eff = ck.astype(F32) * cks          # fused dequant (VMEM on TPU)
        v_eff = cv.astype(F32) * cvs
    else:
        ck = jax.lax.dynamic_update_slice_in_dim(
            cache["k"], k.astype(cache["k"].dtype), slot, 1)
        cv = jax.lax.dynamic_update_slice_in_dim(
            cache["v"], v.astype(cache["v"].dtype), slot, 1)
        k_eff, v_eff = ck.astype(F32), cv.astype(F32)
    cpos = jax.lax.dynamic_update_slice_in_dim(
        cache["pos"], jnp.full((1,), pos, jnp.int32), slot, 0)
    valid = (cpos >= 0) & (cpos <= pos)
    if window is not None:
        valid = valid & (pos - cpos < window)
    g, hd = cfg.n_kv_heads, cfg.head_dim
    h = cfg.n_heads
    qh = q.reshape(b, g, h // g, hd)
    scores = jnp.einsum("bgmd,bkgd->bgmk", qh, k_eff,
                        preferred_element_type=F32) / jnp.sqrt(hd)
    scores = jnp.where(valid[None, None, None, :], scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bgmk,bkgd->bgmd", w, v_eff)
    out = out.reshape(b, 1, h * hd).astype(x.dtype) @ p["wo"]
    new = {"k": ck, "v": cv, "pos": cpos}
    if quantized:
        new["k_scale"] = cks
        new["v_scale"] = cvs
    return out, new


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V2/V3 latent attention)
# ---------------------------------------------------------------------------

def _mla_q(p, cfg, x, positions):
    m = cfg.mla
    b, s, _ = x.shape
    h = cfg.n_heads
    q = rmsnorm(x @ p["wq_a"], p["q_norm"]) @ p["wq_b"]
    q = q.reshape(b, s, h, m.qk_nope_dim + m.qk_rope_dim)
    q_nope, q_rope = jnp.split(q, [m.qk_nope_dim], axis=-1)
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def _mla_ckv(p, cfg, x, positions):
    m = cfg.mla
    kv = x @ p["wkv_a"]
    c_kv, k_rope = jnp.split(kv, [m.kv_lora_rank], axis=-1)
    c_kv = rmsnorm(c_kv, p["kv_norm"])
    k_rope = apply_rope(k_rope[:, :, None, :], positions,
                        cfg.rope_theta)[:, :, 0, :]         # shared head
    return c_kv, k_rope


def mla_forward(p, cfg, x, positions):
    """Full-sequence MLA (training / prefill). Cache = (c_kv, k_rope).

    Long sequences run blockwise: q/k are assembled as
    concat(nope, rope) per head (the shared rope key broadcast across
    heads) and fed through flash_attention — never materializing the
    (B, H, S, S) score tensor (137 GB at train_4k for deepseek-v3).
    """
    m = cfg.mla
    b, s, _ = x.shape
    h = cfg.n_heads
    q_nope, q_rope = _mla_q(p, cfg, x, positions)
    c_kv, k_rope = _mla_ckv(p, cfg, x, positions)
    kv = (c_kv @ p["wkv_b"]).reshape(b, s, h, m.qk_nope_dim + m.v_head_dim)
    k_nope, v = jnp.split(kv, [m.qk_nope_dim], axis=-1)
    scale = 1.0 / jnp.sqrt(m.qk_nope_dim + m.qk_rope_dim).astype(F32)
    if s >= 2048:
        qf = jnp.concatenate([q_nope, q_rope], axis=-1)
        kf = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_rope[:, :, None, :],
                                      (b, s, h, m.qk_rope_dim))], axis=-1)
        out = flash_attention(qf, kf, v, scale=scale)
    else:
        sc = (jnp.einsum("bqhd,bkhd->bhqk", q_nope, k_nope,
                         preferred_element_type=F32)
              + jnp.einsum("bqhd,bkd->bhqk", q_rope, k_rope,
                           preferred_element_type=F32)) * scale
        sc = jnp.where(causal_mask(s, s)[:, None], sc, -1e30)
        w = jax.nn.softmax(sc, axis=-1).astype(v.dtype)
        out = jnp.einsum("bhqk,bkhd->bqhd", w, v)
    out = out.reshape(b, s, h * m.v_head_dim) @ p["wo"]
    return out, (c_kv, k_rope)


def init_mla_cache(cfg, batch, max_len, dtype=jnp.bfloat16):
    m = cfg.mla
    return {"c_kv": jnp.zeros((batch, max_len, m.kv_lora_rank), dtype),
            "k_rope": jnp.zeros((batch, max_len, m.qk_rope_dim), dtype)}


def mla_decode(p, cfg, x, pos, cache, *, absorb=True):
    """Single-token MLA decode over the compressed cache.

    absorb=False (naive): expand every cached latent back to per-head K/V
    each step — O(S * H * (nope+v) * kv_lora) FLOPs and a huge transient.
    absorb=True: fold W_uk into the query and W_uv into the output — the
    attention runs directly in the 512-dim latent space; the cache is read
    once. This is the §Perf memory-term optimization for decode_32k.
    """
    m = cfg.mla
    b = x.shape[0]
    h = cfg.n_heads
    positions = jnp.full((b, 1), pos, jnp.int32)
    q_nope, q_rope = _mla_q(p, cfg, x, positions)       # (B,1,H,*)
    c_new, r_new = _mla_ckv(p, cfg, x, positions)       # (B,1,lora),(B,1,rope)
    ckv = jax.lax.dynamic_update_slice_in_dim(
        cache["c_kv"], c_new.astype(cache["c_kv"].dtype), pos, 1)
    krp = jax.lax.dynamic_update_slice_in_dim(
        cache["k_rope"], r_new.astype(cache["k_rope"].dtype), pos, 1)
    s_max = ckv.shape[1]
    valid = jnp.arange(s_max) <= pos
    scale = 1.0 / jnp.sqrt(m.qk_nope_dim + m.qk_rope_dim).astype(F32)
    wkv_b = p["wkv_b"].reshape(m.kv_lora_rank, h, m.qk_nope_dim + m.v_head_dim)
    w_uk = wkv_b[..., :m.qk_nope_dim]                   # (lora, H, nope)
    w_uv = wkv_b[..., m.qk_nope_dim:]                   # (lora, H, v)

    if absorb:
        q_abs = jnp.einsum("bqhn,lhn->bqhl", q_nope, w_uk)   # (B,1,H,lora)
        sc = (jnp.einsum("bqhl,bkl->bhqk", q_abs, ckv.astype(F32))
              + jnp.einsum("bqhr,bkr->bhqk", q_rope, krp.astype(F32))) * scale
        sc = jnp.where(valid[None, None, None, :], sc, -1e30)
        w = jax.nn.softmax(sc, axis=-1)
        ctx = jnp.einsum("bhqk,bkl->bqhl", w, ckv.astype(F32))  # latent ctx
        out = jnp.einsum("bqhl,lhv->bqhv", ctx, w_uv)
    else:
        kv = jnp.einsum("bkl,lhe->bkhe", ckv.astype(F32), wkv_b)
        k_nope, v = jnp.split(kv, [m.qk_nope_dim], axis=-1)
        sc = (jnp.einsum("bqhn,bkhn->bhqk", q_nope, k_nope)
              + jnp.einsum("bqhr,bkr->bhqk", q_rope, krp.astype(F32))) * scale
        sc = jnp.where(valid[None, None, None, :], sc, -1e30)
        w = jax.nn.softmax(sc, axis=-1)
        out = jnp.einsum("bhqk,bkhv->bqhv", w, v)
    out = out.reshape(b, 1, h * m.v_head_dim).astype(x.dtype) @ p["wo"]
    return out, {"c_kv": ckv, "k_rope": krp}


# ---------------------------------------------------------------------------
# cross-attention (whisper decoder -> encoder states)
# ---------------------------------------------------------------------------

def cross_attention(p, cfg, x, enc_kv):
    """x (B,S,D) queries; enc_kv = (k, v) precomputed from encoder output."""
    b, s, _ = x.shape
    h, g, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = (x @ p["wq"]).reshape(b, s, h, hd)
    k, v = enc_kv
    out = _sdpa(q, k, v, None, 1.0 / jnp.sqrt(hd).astype(F32))
    return out.reshape(b, s, -1) @ p["wo"]


def encode_cross_kv(p, cfg, enc_out):
    b, t, _ = enc_out.shape
    g, hd = cfg.n_kv_heads, cfg.head_dim
    k = (enc_out @ p["wk"]).reshape(b, t, g, hd)
    v = (enc_out @ p["wv"]).reshape(b, t, g, hd)
    return k, v
