"""Recurrent sequence blocks: RG-LRU (Griffin/RecurrentGemma) and
xLSTM's mLSTM / sLSTM.

All blocks expose the same triple of entry points:
  <kind>_params(key, cfg)                -> param pytree
  <kind>_block(p, cfg, x)                -> (y, final_state)   full sequence
  <kind>_block_decode(p, cfg, x, state)  -> (y, new_state)     single token

Full-sequence forms are parallel where the math allows it:
  * RG-LRU is a linear recurrence  h_t = a_t h_{t-1} + u_t  — evaluated with
    jax.lax.associative_scan (Blelloch), O(log S) depth.
  * mLSTM's matrix memory is evaluated in its parallel quadratic form
    (the xLSTM paper's eq. (2x): attention-like with a cumulative-gate
    decay matrix) — O(S^2) compute, O(1) recurrent state for decode. The
    long_500k shape only exercises the *decode* path, whose state is
    (H, hd, hd) per layer, independent of context length.
  * sLSTM has genuine hidden-to-hidden recurrence (its defining feature),
    so the full-sequence form is a lax.scan over time.

Decode states are plain pytrees of arrays — they live in the serving cache
alongside KV caches.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init

F32 = jnp.float32


# ---------------------------------------------------------------------------
# causal depthwise conv1d (width w), used by RG-LRU and mLSTM branches
# ---------------------------------------------------------------------------

def conv1d_params(key, width, channels):
    return {"w": dense_init(key, (width, channels), scale=0.3),
            "b": jnp.zeros((channels,), F32)}


def causal_conv1d(p, x):
    """x (B, S, C) -> (B, S, C); y_t = b + sum_w W[w] * x_{t-w}."""
    width = p["w"].shape[0]
    y = jnp.zeros_like(x) + p["b"]
    for w in range(width):
        shifted = jnp.pad(x, ((0, 0), (w, 0), (0, 0)))[:, :x.shape[1]]
        y = y + shifted * p["w"][w]
    return y


def causal_conv1d_decode(p, x1, conv_state):
    """x1 (B, 1, C), conv_state (B, width-1, C) = previous inputs (oldest
    first). Returns (y1, new_state)."""
    width = p["w"].shape[0]
    window = jnp.concatenate([conv_state, x1], axis=1)      # (B, width, C)
    # window[:, -1] is x_t and must pair with W[0] (shift 0): flip taps
    y = p["b"] + jnp.einsum("bwc,wc->bc", window,
                            p["w"][::-1])[:, None, :]
    return y, window[:, 1:]


def conv_tail(x, width):
    """Last width-1 positions of x (left-padded if S < width-1)."""
    b, s, c = x.shape
    pad = max(0, (width - 1) - s)
    xp = jnp.pad(x, ((0, 0), (pad, 0), (0, 0)))
    return xp[:, -(width - 1):]


# ---------------------------------------------------------------------------
# RG-LRU block (Griffin recurrent block)
# ---------------------------------------------------------------------------

_RGLRU_C = 8.0


def rglru_params(key, cfg):
    d = cfg.d_model
    w = cfg.rglru_width or d
    ks = jax.random.split(key, 7)
    # Lambda init so a = exp(-c*softplus(L)) lands in [0.9, 0.999]
    u = jax.random.uniform(ks[0], (w,), F32, 0.9, 0.999)
    lam = jnp.log(jnp.expm1(-jnp.log(u) / _RGLRU_C))
    return {
        "in_x": dense_init(ks[1], (d, w)),       # recurrent branch
        "in_g": dense_init(ks[2], (d, w)),       # gate branch (GeLU)
        "conv": conv1d_params(ks[3], cfg.conv1d_width, w),
        "w_rg": dense_init(ks[4], (w, w), scale=0.02),  # recurrence gate
        "b_rg": jnp.zeros((w,), F32),
        "w_ig": dense_init(ks[5], (w, w), scale=0.02),  # input gate
        "b_ig": jnp.zeros((w,), F32),
        "lam": lam,
        "out": dense_init(ks[6], (w, d)),
    }


def _rglru_scan_coeffs(p, u):
    """u (B,S,W) conv output -> (a, gated_input) for the linear scan."""
    r = jax.nn.sigmoid(u @ p["w_rg"] + p["b_rg"])
    i = jax.nn.sigmoid(u @ p["w_ig"] + p["b_ig"])
    log_a = -_RGLRU_C * jax.nn.softplus(p["lam"]) * r       # (B,S,W) <= 0
    a = jnp.exp(log_a)
    # sqrt(1 - a^2) computed stably from log a
    beta = jnp.sqrt(jnp.maximum(-jnp.expm1(2.0 * log_a), 1e-12))
    return a, beta * (i * u)


def rglru_block(p, cfg, x):
    """x (B,S,D) -> (y (B,S,D), state)."""
    u = causal_conv1d(p["conv"], x @ p["in_x"])
    g = jax.nn.gelu(x @ p["in_g"])
    a, v = _rglru_scan_coeffs(p, u.astype(F32))

    def combine(c1, c2):
        a1, u1 = c1
        a2, u2 = c2
        return a1 * a2, a2 * u1 + u2

    a_c, h = jax.lax.associative_scan(combine, (a, v), axis=1)
    y = (h * g.astype(F32)).astype(x.dtype) @ p["out"]
    state = {"h": h[:, -1], "conv": conv_tail(x @ p["in_x"], cfg.conv1d_width)}
    return y, state


def rglru_init_state(cfg, batch, dtype=F32):
    w = cfg.rglru_width or cfg.d_model
    return {"h": jnp.zeros((batch, w), F32),
            "conv": jnp.zeros((batch, cfg.conv1d_width - 1, w), dtype)}


def rglru_block_decode(p, cfg, x, state):
    """x (B,1,D) -> (y (B,1,D), state)."""
    u_in = x @ p["in_x"]
    u, conv_state = causal_conv1d_decode(p["conv"], u_in, state["conv"])
    g = jax.nn.gelu(x @ p["in_g"])
    a, v = _rglru_scan_coeffs(p, u.astype(F32))
    h = a[:, 0] * state["h"] + v[:, 0]
    y = (h[:, None] * g.astype(F32)).astype(x.dtype) @ p["out"]
    return y, {"h": h, "conv": conv_state}


# ---------------------------------------------------------------------------
# mLSTM block (xLSTM): matrix memory, exponential gating
# ---------------------------------------------------------------------------

def mlstm_params(key, cfg):
    d = cfg.d_model
    w = 2 * d                                   # up-projection factor 2
    nh = cfg.n_heads
    hd = w // nh
    ks = jax.random.split(key, 9)
    return {
        "up_u": dense_init(ks[0], (d, w)),
        "up_z": dense_init(ks[1], (d, w)),
        "conv": conv1d_params(ks[2], cfg.conv1d_width, w),
        # per-head block-diagonal q/k/v (the xLSTM BlockDiagonal linear)
        "wq": dense_init(ks[3], (nh, hd, hd)),
        "wk": dense_init(ks[4], (nh, hd, hd)),
        "wv": dense_init(ks[5], (nh, hd, hd)),
        "w_if": dense_init(ks[6], (w, 2 * cfg.n_heads), scale=0.02),
        "b_if": jnp.concatenate([jnp.zeros((cfg.n_heads,), F32),
                                 jnp.full((cfg.n_heads,), 3.0, F32)]),
        "gn": jnp.ones((w,), F32),              # per-channel group norm gain
        "down": dense_init(ks[7], (w, d)),
        "skip": jnp.ones((w,), F32),            # learnable per-channel skip
    }


def _blockdiag(x_heads, w):
    """x (B,S,H,hd) @ per-head (H, hd, hd) -> (B,S,H,hd)."""
    return jnp.einsum("bshd,hde->bshe", x_heads, w)


def _mlstm_qkv_gates(p, cfg, x):
    u = x @ p["up_u"]
    z = x @ p["up_z"]
    c = jax.nn.silu(causal_conv1d(p["conv"], u))
    b, s, w = u.shape
    nh = cfg.n_heads
    hd = w // nh
    ch = c.reshape(b, s, nh, hd)
    q = _blockdiag(ch, p["wq"])
    k = _blockdiag(ch, p["wk"]) / jnp.sqrt(jnp.float32(hd))
    v = _blockdiag(u.reshape(b, s, nh, hd), p["wv"])
    g = c @ p["w_if"] + p["b_if"]                                # (B,S,2H)
    log_i = g[..., :nh].astype(F32)                              # pre-act ~ log i
    log_f = jax.nn.log_sigmoid(g[..., nh:].astype(F32))          # f = sigmoid
    return q, k, v, z, c, log_i, log_f


def _headnorm(h, gain):
    """Per-head RMS norm then flatten; h (B,S,H,hd), gain (H*hd,)."""
    var = jnp.mean(jnp.square(h), axis=-1, keepdims=True)
    hn = h * jax.lax.rsqrt(var + 1e-6)
    b, s = h.shape[:2]
    return hn.reshape(b, s, -1) * gain


MLSTM_CHUNK = 256


def mlstm_block(p, cfg, x):
    """Chunkwise-parallel mLSTM: intra-chunk quadratic + carried matrix
    state across chunks (lax.scan). O(S*L) memory instead of O(S^2)."""
    b, s, d = x.shape
    q, k, v, z, c, log_i, log_f = _mlstm_qkv_gates(p, cfg, x)
    nh = cfg.n_heads
    hd = q.shape[-1]
    L = MLSTM_CHUNK if s % MLSTM_CHUNK == 0 else s
    nc = s // L

    # chunked views: (NC, B, L, H, ...)
    def chunked(a):
        return jnp.swapaxes(a.reshape(b, nc, L, *a.shape[2:]), 0, 1)

    qc, kc, vc = chunked(q.astype(F32)), chunked(k.astype(F32)), \
        chunked(v.astype(F32))
    lic, lfc = chunked(log_i), chunked(log_f)
    causal = jnp.tril(jnp.ones((L, L), bool))

    def chunk_step(carry, inp):
        C, n, m_st = carry
        qi, ki, vi, li, lf = inp                       # (B,L,H,*) / (B,L,H)
        lf_cum = jnp.cumsum(lf, axis=1)                # (B,L,H)
        lf_tot = lf_cum[:, -1]                         # (B,H)
        # inter-chunk: query i sees state with decay lf_cum[i] (+ m_st)
        b_i = lf_cum + m_st[:, None, :]                # (B,L,H)
        # intra-chunk decay matrix
        dmat = (lf_cum[:, :, None, :] - lf_cum[:, None, :, :]
                + li[:, None, :, :])                   # (B,Lq,Lk,H)
        dmat = jnp.where(causal[None, :, :, None], dmat, -jnp.inf)
        m_i = jnp.maximum(jnp.maximum(jnp.max(dmat, axis=2), b_i), 0.0)
        dexp = jnp.exp(dmat - m_i[:, :, None, :])      # (B,Lq,Lk,H)
        inter_sc = jnp.exp(b_i - m_i)                  # (B,L,H)

        scores = jnp.einsum("blhd,bmhd->blmh", qi, ki) * dexp
        num = (jnp.einsum("blmh,bmhe->blhe", scores, vi)
               + inter_sc[..., None]
               * jnp.einsum("blhd,bhde->blhe", qi, C))
        den = (scores.sum(axis=2)
               + inter_sc * jnp.einsum("blhd,bhd->blh", qi, n))
        hval = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_i))[..., None]

        # state update to end of chunk
        dlast = lf_tot[:, None, :] - lf_cum + li       # (B,L,H)
        m_new = jnp.maximum(lf_tot + m_st, jnp.max(dlast, axis=1))
        carry_sc = jnp.exp(lf_tot + m_st - m_new)      # (B,H)
        wgt = jnp.exp(dlast - m_new[:, None, :])       # (B,L,H)
        C_new = (carry_sc[..., None, None] * C
                 + jnp.einsum("blh,blhd,blhe->bhde", wgt, ki, vi))
        n_new = (carry_sc[..., None] * n
                 + jnp.einsum("blh,blhd->bhd", wgt, ki))
        return (C_new, n_new, m_new), hval

    C0 = jnp.zeros((b, nh, hd, hd), F32)
    n0 = jnp.zeros((b, nh, hd), F32)
    m0 = jnp.full((b, nh), -1e30, F32)
    (C, n, m_f), hs = jax.lax.scan(chunk_step, (C0, n0, m0),
                                   (qc, kc, vc, lic, lfc))
    h = jnp.swapaxes(hs, 0, 1).reshape(b, s, nh, hd)   # (B,S,H,hd)

    hn = _headnorm(h, p["gn"]) + c.astype(F32) * p["skip"]
    y = (hn * jax.nn.silu(z.astype(F32))).astype(x.dtype) @ p["down"]
    state = {"C": C, "n": n, "m": m_f,
             "conv": conv_tail(x @ p["up_u"], cfg.conv1d_width)}
    return y, state


def mlstm_init_state(cfg, batch, dtype=F32):
    w = 2 * cfg.d_model
    nh = cfg.n_heads
    hd = w // nh
    return {"C": jnp.zeros((batch, nh, hd, hd), F32),
            "n": jnp.zeros((batch, nh, hd), F32),
            "m": jnp.full((batch, nh), -1e30, F32),
            "conv": jnp.zeros((batch, cfg.conv1d_width - 1, w), dtype)}


def mlstm_block_decode(p, cfg, x, state):
    """Single-token recurrent mLSTM step. x (B,1,D)."""
    b = x.shape[0]
    nh = cfg.n_heads
    u = x @ p["up_u"]
    z = x @ p["up_z"]
    cval, conv_state = causal_conv1d_decode(p["conv"], u, state["conv"])
    cact = jax.nn.silu(cval)
    w = u.shape[-1]
    hd = w // nh
    ch = cact.reshape(b, 1, nh, hd)
    q = _blockdiag(ch, p["wq"])[:, 0]
    k = _blockdiag(ch, p["wk"])[:, 0] / jnp.sqrt(jnp.float32(hd))
    v = _blockdiag(u.reshape(b, 1, nh, hd), p["wv"])[:, 0]
    g = (cact @ p["w_if"] + p["b_if"])[:, 0]                     # (B,2H)
    log_i = g[:, :nh].astype(F32)
    log_f = jax.nn.log_sigmoid(g[:, nh:].astype(F32))

    m_new = jnp.maximum(log_f + state["m"], log_i)               # (B,H)
    f_sc = jnp.exp(log_f + state["m"] - m_new)
    i_sc = jnp.exp(log_i - m_new)
    C = f_sc[..., None, None] * state["C"] + \
        i_sc[..., None, None] * jnp.einsum("bhd,bhe->bhde",
                                           k.astype(F32), v.astype(F32))
    n = f_sc[..., None] * state["n"] + i_sc[..., None] * k.astype(F32)
    num = jnp.einsum("bhde,bhd->bhe", C, q.astype(F32))
    den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", n, q.astype(F32))),
                      jnp.exp(-m_new))
    h = (num / den[..., None])[:, None]                          # (B,1,H,hd)
    hn = _headnorm(h, p["gn"]) + cact.astype(F32) * p["skip"]
    y = (hn * jax.nn.silu(z.astype(F32))).astype(x.dtype) @ p["down"]
    return y, {"C": C, "n": n, "m": m_new, "conv": conv_state}


# ---------------------------------------------------------------------------
# sLSTM block (xLSTM): scalar memory, true hidden-to-hidden recurrence
# ---------------------------------------------------------------------------

def slstm_params(key, cfg):
    d = cfg.d_model
    nh = cfg.n_heads
    hd = d // nh
    ks = jax.random.split(key, 6)
    d_up = int(d * 4 / 3)
    return {
        # input projections for z, i, f, o (4 gates)
        "w_in": dense_init(ks[0], (d, 4 * d)),
        "b_in": jnp.concatenate([
            jnp.zeros((d,), F32),                 # z
            jnp.zeros((d,), F32),                 # i
            jnp.full((d,), 3.0, F32),             # f (open at init)
            jnp.zeros((d,), F32)]),               # o
        # block-diagonal (per-head) hidden-to-hidden recurrence
        "w_rec": dense_init(ks[1], (nh, hd, 4 * hd), scale=0.02),
        "gn": jnp.ones((d,), F32),
        # post-block GeGLU FFN, factor 4/3
        "ffn_gate": dense_init(ks[2], (d, d_up)),
        "ffn_up": dense_init(ks[3], (d, d_up)),
        "ffn_down": dense_init(ks[4], (d_up, d)),
    }


def _slstm_step(p, cfg, xg, carry):
    """One time step. xg (B, 4D) pre-computed input gates; carry pytree."""
    c, n, h, m = carry["c"], carry["n"], carry["h"], carry["m"]
    b = xg.shape[0]
    nh = cfg.n_heads
    d = c.shape[1]
    hd = d // nh
    hh = h.reshape(b, nh, hd)
    rec = jnp.einsum("bhd,hde->bhe", hh, p["w_rec"]).reshape(b, 4 * d)
    g = xg + rec
    zt = jnp.tanh(g[:, :d])
    log_i = g[:, d:2 * d].astype(F32)
    log_f = jax.nn.log_sigmoid(g[:, 2 * d:3 * d].astype(F32))
    o = jax.nn.sigmoid(g[:, 3 * d:])
    m_new = jnp.maximum(log_f + m, log_i)
    i_sc = jnp.exp(log_i - m_new)
    f_sc = jnp.exp(log_f + m - m_new)
    c_new = f_sc * c + i_sc * zt
    n_new = f_sc * n + i_sc
    h_new = o * (c_new / jnp.maximum(n_new, 1e-6))
    return {"c": c_new, "n": n_new, "h": h_new, "m": m_new}


def slstm_init_state(cfg, batch, dtype=F32):
    d = cfg.d_model
    return {"c": jnp.zeros((batch, d), F32), "n": jnp.zeros((batch, d), F32),
            "h": jnp.zeros((batch, d), F32),
            "m": jnp.full((batch, d), -1e30, F32)}


def _slstm_ffn(p, h):
    return (jax.nn.gelu(h @ p["ffn_gate"]) * (h @ p["ffn_up"])) @ p["ffn_down"]


def slstm_block(p, cfg, x):
    """Sequential scan over time. x (B,S,D) -> (y, state)."""
    b, s, d = x.shape
    xg = x @ p["w_in"] + p["b_in"]                               # (B,S,4D)

    def step(carry, xt):
        new = _slstm_step(p, cfg, xt, carry)
        return new, new["h"]

    init = slstm_init_state(cfg, b)
    state, hs = jax.lax.scan(step, init, jnp.swapaxes(xg, 0, 1))
    h = jnp.swapaxes(hs, 0, 1)                                   # (B,S,D)
    var = jnp.mean(jnp.square(h.reshape(b, s, cfg.n_heads, -1)),
                   axis=-1, keepdims=True)
    hn = (h.reshape(b, s, cfg.n_heads, -1)
          * jax.lax.rsqrt(var + 1e-6)).reshape(b, s, d) * p["gn"]
    y = _slstm_ffn(p, hn.astype(x.dtype))
    return y, state


def slstm_block_decode(p, cfg, x, state):
    b = x.shape[0]
    xg = (x @ p["w_in"] + p["b_in"])[:, 0]
    new = _slstm_step(p, cfg, xg, state)
    h = new["h"][:, None]
    d = x.shape[-1]
    var = jnp.mean(jnp.square(h.reshape(b, 1, cfg.n_heads, -1)),
                   axis=-1, keepdims=True)
    hn = (h.reshape(b, 1, cfg.n_heads, -1)
          * jax.lax.rsqrt(var + 1e-6)).reshape(b, 1, d) * p["gn"]
    y = _slstm_ffn(p, hn.astype(x.dtype))
    return y, new
