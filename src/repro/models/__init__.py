"""Backend model substrate: composable JAX transformer/SSM stack.

Pure-function style: params are pytrees of arrays, every forward is a
function of (params, batch). Sharding is annotated externally via
repro.distributed.sharding rules so the same model code runs on 1 CPU
device (smoke tests) and a 512-chip multi-pod mesh (dry-run).
"""

from repro.models.config import ArchConfig, MoEConfig, MLAConfig
from repro.models.transformer import (
    init_params,
    param_shapes,
    forward_train,
    forward_prefill,
    forward_decode,
    init_decode_cache,
    decode_cache_shapes,
)
