"""Architecture configuration schema.

One frozen dataclass describes every supported family; repro/configs/<id>.py
files instantiate it with the exact published numbers.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_expert: int               # expert FFN hidden size
    n_shared: int = 0           # always-on shared experts (DeepSeek)
    dense_residual: bool = False  # dense FFN in parallel with MoE (Arctic)
    dense_d_ff: int = 0         # size of the parallel dense FFN
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.001
    n_dense_layers: int = 0     # leading layers that use a dense FFN instead


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                 # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    d_head: Optional[int] = None          # default d_model // n_heads

    # attention flavor
    attn_kind: str = "gqa"                # gqa | mla
    qk_norm: bool = False
    qkv_bias: bool = False
    sliding_window: Optional[int] = None  # SWA width (h2o-danube)
    local_window: Optional[int] = None    # local-attn width (recurrentgemma)
    rope_theta: float = 10000.0

    # mixture / latent configs
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    mtp: bool = False                     # multi-token-prediction head (DSv3)

    # layer pattern, cycled across n_layers:
    #   'attn' | 'local_attn' | 'rglru' | 'mlstm' | 'slstm'
    block_pattern: Tuple[str, ...] = ("attn",)

    # encoder-decoder (whisper)
    encdec: bool = False
    n_encoder_layers: int = 0

    # modality frontend stub: None | 'audio_frames' | 'image_patches'
    frontend: Optional[str] = None
    n_frontend_tokens: int = 0            # frames / patches per example
    frontend_dim: int = 0                 # raw embedding dim from the stub

    norm: str = "rmsnorm"                 # rmsnorm | layernorm
    tie_embeddings: bool = False
    rglru_width: Optional[int] = None     # recurrent branch width
    conv1d_width: int = 4

    # ---- derived -----------------------------------------------------------
    @property
    def head_dim(self) -> int:
        return self.d_head if self.d_head is not None else self.d_model // self.n_heads

    def block_kind(self, layer: int) -> str:
        return self.block_pattern[layer % len(self.block_pattern)]

    @property
    def sub_quadratic(self) -> bool:
        """True if long-context decode (500k) is feasible: no unbounded
        full-attention KV growth."""
        kinds = set(self.block_pattern)
        if "attn" in kinds and self.sliding_window is None:
            return False
        return not self.encdec

    def scaled(self, *, n_layers=None, d_model=None, n_heads=None,
               n_kv_heads=None, d_ff=None, vocab_size=None, moe=None,
               **kw) -> "ArchConfig":
        """Reduced copy for smoke tests (same family/wiring, tiny sizes)."""
        return dataclasses.replace(
            self,
            n_layers=n_layers or self.n_layers,
            d_model=d_model or self.d_model,
            n_heads=n_heads or self.n_heads,
            n_kv_heads=n_kv_heads or self.n_kv_heads,
            d_ff=d_ff if d_ff is not None else self.d_ff,
            vocab_size=vocab_size or self.vocab_size,
            moe=moe if moe is not None else self.moe,
            d_head=kw.pop("d_head", None) or (
                None if self.d_head is None else max(8, self.d_head // 16)),
            **kw,
        )
