"""Jit'd public wrappers over the Pallas kernels.

Handles batch padding to kernel tiles, dtype marshalling (quantized payloads
ride as exact f32), backend routing (Pallas on TPU, interpret-mode on CPU for
validation, or the XLA gather reference for speed), and the scalar epilogues
that turn kernel outputs into (pred, confidence).

VMEM fit check: the switch-SRAM analog. A model whose tables exceed the
budget is rejected for the fused kernel — same failure mode as a model that
doesn't fit the switch pipeline in the paper — and falls back to the XLA
path (the "run it on the host" situation).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.artifact import TableArtifact
from repro.kernels import bucketize as _bk
from repro.kernels import ensemble_lookup as _ek
from repro.kernels import classical_lookup as _ck
from repro.kernels import ref as _ref

VMEM_BUDGET_BYTES = 8 * 1024 * 1024   # half of a v5e core's ~16MB VMEM


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pad_batch(x, tile):
    n = x.shape[0]
    pad = (-n) % tile
    if pad:
        x = jnp.concatenate([x, jnp.zeros((pad,) + x.shape[1:], x.dtype)])
    return x, n


def bucketize(x, edges, *, use_pallas=None):
    """Public bucketize. x (N, F), edges (F, U) -> (N, F) int32."""
    if use_pallas is None:
        use_pallas = _on_tpu()
    if not use_pallas:
        return _ref.bucketize_ref(x, edges)
    xp, n = _pad_batch(jnp.asarray(x, jnp.float32), _bk.TILE_N)
    return _bk.bucketize_pallas(xp, edges, interpret=not _on_tpu())[:n]


def tree_tables_vmem_bytes(art: TableArtifact) -> int:
    e = art.edges.size * 4
    f = art.ftable.size * 4
    s = art.strides.size * 4
    d = art.dtable_class.size * 4
    return e + f + s + d


def fits_vmem(art: TableArtifact) -> bool:
    if art.ftable is None:
        return (art.edges.size + art.vtable.q.size) * 4 <= VMEM_BUDGET_BYTES
    return tree_tables_vmem_bytes(art) <= VMEM_BUDGET_BYTES


# ---------------------------------------------------------------------------
# fused classify
# ---------------------------------------------------------------------------

def _tree_epilogue(art: TableArtifact, out):
    if art.agg == "vote":
        votes = out                                         # (N, C)
        pred = jnp.argmax(votes, axis=1)
        conf = jnp.max(votes, axis=1) / art.n_trees
        return pred, conf
    total = out[:, 0] / art.dtable_value.scale
    if art.agg == "wsum_sigmoid":
        p1 = jax.nn.sigmoid(art.base_score + art.learning_rate * total)
        return (p1 > 0.5).astype(jnp.int32), jnp.maximum(p1, 1 - p1)
    if art.agg == "iforest":
        n = jnp.float32(art.iforest_subsample)
        cfac = 2.0 * (jnp.log(n - 1.0) + 0.5772156649) - 2.0 * (n - 1.0) / n
        score = 2.0 ** (-(total / art.n_trees) / cfac)
        return (score > 0.5).astype(jnp.int32), jnp.maximum(score, 1 - score)
    raise ValueError(art.agg)


def _classical_epilogue(art: TableArtifact, out):
    total = out / art.vtable.scale                          # (N, M)
    if art.agg == "svm_ovo":
        planes = total + art.consts[None, :]
        win_i = planes > 0
        votes = jnp.zeros((planes.shape[0], art.n_classes), jnp.float32)
        votes = votes.at[:, art.pairs[:, 0]].add(win_i.astype(jnp.float32))
        votes = votes.at[:, art.pairs[:, 1]].add((~win_i).astype(jnp.float32))
        pred = jnp.argmax(votes, axis=1)
        if planes.shape[1] == 1:
            conf = jax.nn.sigmoid(2.0 * jnp.abs(planes[:, 0]))
        else:
            conf = jnp.max(votes, axis=1) / planes.shape[1]
        return pred, conf
    if art.agg == "nb_log":
        joint = total + art.consts[None, :]
        return (jnp.argmax(joint, axis=1),
                jnp.max(jax.nn.softmax(joint, axis=1), axis=1))
    if art.agg == "kmeans":
        pred = jnp.argmin(total, axis=1)
        top2 = jax.lax.top_k(-total, 2)[0]
        return pred, 1.0 - jnp.exp(top2[:, 1] - top2[:, 0])
    raise ValueError(art.agg)


def fused_classify(art: TableArtifact, x, *, use_pallas=None,
                   interpret=None):
    """(pred, confidence) through the fused kernel path.

    use_pallas=None auto-routes: Pallas on TPU, XLA reference otherwise.
    Pass use_pallas=True on CPU to exercise interpret mode (tests do).
    """
    if use_pallas is None:
        use_pallas = _on_tpu()
    if interpret is None:
        interpret = not _on_tpu()
    x = jnp.asarray(x, jnp.float32)

    if art.ftable is not None:
        vote = art.agg == "vote"
        dtable = (art.dtable_class if vote else art.dtable_value.q)
        dtable = dtable.astype(jnp.float32)
        if use_pallas and fits_vmem(art):
            xp, n = _pad_batch(x, _ek.TILE_N)
            out = _ek.ensemble_lookup_pallas(
                xp, art.edges, art.ftable, art.strides, dtable,
                n_classes=art.n_classes, vote=vote, interpret=interpret)[:n]
        else:
            out = _ref.ensemble_lookup_ref(
                x, art.edges, art.ftable, art.strides, dtable,
                n_classes=art.n_classes, vote=vote)
        return _tree_epilogue(art, out)

    if use_pallas and fits_vmem(art):
        xp, n = _pad_batch(x, _ck.TILE_N)
        out = _ck.classical_lookup_pallas(
            xp, art.edges, art.vtable.q.astype(jnp.float32),
            interpret=interpret)[:n]
    else:
        out = _ref.classical_lookup_ref(x, art.edges,
                                        art.vtable.q.astype(jnp.float32))
    return _classical_epilogue(art, out)
