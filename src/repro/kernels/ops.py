"""Jit'd public wrappers over the Pallas kernels.

Handles batch padding to kernel tiles, dtype marshalling (quantized payloads
ride as exact f32), backend routing (Pallas on TPU, interpret-mode on CPU for
validation, or the XLA gather reference for speed), and the scalar epilogues
that turn kernel outputs into (pred, confidence).

The fused path consumes the artifact's pre-flattened single-matmul layout
(core.artifact.finalize_artifact); artifacts built by hand without it are
flattened on the fly, so every TableArtifact works.

VMEM fit check: the switch-SRAM analog. A model whose tables exceed the
budget is rejected for the fused kernel — same failure mode as a model that
doesn't fit the switch pipeline in the paper — and falls back to the XLA
path (the "run it on the host" situation).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.artifact import (TableArtifact, build_dtable_flat,
                                 default_lane, flatten_ftable,
                                 flatten_vtable, pad_dtable,
                                 round_up_to_lane)
from repro.kernels import bucketize as _bk
from repro.kernels import ensemble_lookup as _ek
from repro.kernels import evict as _ev
from repro.kernels import classical_lookup as _ck
from repro.kernels import ref as _ref
from repro.kernels import stream_update as _su
from repro.kernels.tuning import DEFAULT_TILES, TileConfig, padded_rows

VMEM_BUDGET_BYTES = 8 * 1024 * 1024   # half of a v5e core's ~16MB VMEM


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pad_batch(x, tile):
    """Pad N up to a tile multiple by replicating the last valid row.

    Replication (not zeros) keeps every padded lane on a real sample: a
    zero row is out-of-distribution for the tables and, in fused serving
    paths that compute telemetry before slicing, could perturb confidence
    statistics. A replicated row classifies identically to its source and
    is sliced off by [:n] like any pad.
    """
    n = x.shape[0]
    pad = (-n) % tile
    if pad:
        fill = jnp.broadcast_to(x[n - 1:n], (pad,) + x.shape[1:])
        x = jnp.concatenate([x, fill])
    return x, n


def pad_window(cols, tile: int):
    """Tile-pad per-packet columns to a multiple of ``tile``.

    ``cols`` is a pytree of arrays sharing leading length W0; returns
    (padded_cols, valid (Wp,) bool, n). Pad lanes replicate the last packet
    — in-distribution, the same discipline as ``_pad_batch`` — and carry
    valid=False, so streaming register updates and telemetry mask them out
    exactly. This is the streaming entry point: every window enters the
    jitted step at one static shape (``tile`` = the window size), so a
    ragged final window never recompiles and never perturbs flow state.
    """
    leaves = jax.tree.leaves(cols)
    n = leaves[0].shape[0]
    pad = (-n) % tile
    if pad:
        cols = jax.tree.map(
            lambda a: _pad_batch(jnp.asarray(a), tile)[0], cols)
    valid = jnp.arange(n + pad) < n
    return cols, valid, n


def evict_fill(regs, mask, fills, *, use_pallas=None, interpret=None):
    """Masked register reset: the eviction sweep's scatter.

    regs (R, N) f32 stacked register file, mask (N,) bool (True = evict),
    fills (R,) per-register reset identities -> (R, N). Evicted columns
    take their fill value, surviving columns pass through bit for bit.
    Pallas on TPU (``kernels.evict``), jnp.where elsewhere — the XLA form
    is what runs inside the shard_mapped streaming step on CPU meshes.
    """
    regs = jnp.asarray(regs, jnp.float32)
    fills = jnp.asarray(fills, jnp.float32)
    if use_pallas is None:
        use_pallas = _on_tpu()
    if not use_pallas:
        return jnp.where(mask[None, :], fills[:, None], regs)
    r, n = regs.shape
    tile = min(_ev.TILE_B, n) if n % _ev.TILE_B else _ev.TILE_B
    pad = (-n) % tile
    if pad:
        regs = jnp.pad(regs, ((0, 0), (0, pad)))
        mask = jnp.pad(mask, (0, pad))       # pad columns: never evicted
    out = _ev.evict_fill_pallas(regs, mask, fills, interpret=interpret,
                                tile_b=tile)
    return out[:, :n]


def stream_update(regs, bucket, ts, length, is_fwd, valid, *, limit=None,
                  use_pallas=None, interpret=None):
    """Fused streaming register scatter + touched-row gather.

    regs (8, N) f32 stacked register file (``netsim.stream.
    REGISTER_FIELDS`` order); bucket/ts/length/is_fwd/valid the (W,)
    window columns -> (new_regs (8, N), rows (8, W)): the window folded
    into the registers (count registers clamped at ``limit`` when given
    — the 2^24 overflow guard) and each lane's updated register row.
    Pallas on TPU (``kernels.stream_update``: one VMEM pass per bucket
    tile, no HBM round-trip between scatter and gather), the XLA
    segment/gather reference elsewhere — bit-identical by the
    integer-exactness/associativity argument in the kernel docstring.
    """
    regs = jnp.asarray(regs, jnp.float32)
    if use_pallas is None:
        use_pallas = _on_tpu()
    if not use_pallas:
        return _ref.stream_update_ref(regs, bucket, ts, length, is_fwd,
                                      valid, limit=limit)
    r, n = regs.shape
    tile = min(_su.TILE_B, n) if n % _su.TILE_B else _su.TILE_B
    pad = (-n) % tile
    if pad:
        regs = jnp.pad(regs, ((0, 0), (0, pad)))   # bucket < n: never matched
    new_regs, rows = _su.stream_update_pallas(
        regs, bucket, ts, length, is_fwd, valid, limit=limit,
        interpret=interpret, tile_b=tile)
    return new_regs[:, :n], rows


def bucketize(x, edges, *, use_pallas=None):
    """Public bucketize. x (N, F), edges (F, U) -> (N, F) int32."""
    if use_pallas is None:
        use_pallas = _on_tpu()
    if not use_pallas:
        return _ref.bucketize_ref(x, edges)
    xp, n = _pad_batch(jnp.asarray(x, jnp.float32), _bk.TILE_N)
    return _bk.bucketize_pallas(xp, edges)[:n]


def _flat_tree_tables(art: TableArtifact, vote: bool):
    """Pre-flattened tables from the artifact, or on-the-fly fallback."""
    if art.ftable_flat is not None:
        return art.ftable_flat, art.dtable_flat, art.dtable_pad
    dtable = art.dtable_class if vote else art.dtable_value.q
    return (flatten_ftable(art.ftable, art.strides),
            build_dtable_flat(dtable, art.n_classes, vote),
            pad_dtable(dtable))


def _flat_vtable(art: TableArtifact):
    if art.vtable_flat is not None:
        return art.vtable_flat
    return flatten_vtable(art.vtable.q)


def tree_tables_vmem_bytes(art: TableArtifact) -> int:
    """Bytes the fused kernel will actually hold in VMEM — i.e. the
    lane-padded flat layout, whether it is pre-built on the artifact or
    about to be flattened on the fly. Only one decision table (flat or
    pad) is a kernel operand, chosen by the same crossover as
    select='auto' — mirror it so large-table models that would run the
    compare strategy are not rejected for the matmul table they'd never
    load."""
    e = art.edges.size * 4
    if art.ftable_flat is not None:
        f = art.ftable_flat.size * 4
        cout, t, s_pad = art.dtable_flat.shape
    else:
        lane = default_lane()
        fdim, b, t = art.ftable.shape
        s_pad = round_up_to_lane(art.dtable_class.shape[1], lane)
        cout = art.n_classes if art.agg == "vote" else 1
        f = (fdim * round_up_to_lane(b, lane)
             * round_up_to_lane(t, lane) * 4)
    matmul_select = t * s_pad * cout <= _ek.SELECT_MATMUL_MAX
    d = (cout if matmul_select else 1) * t * s_pad * 4
    return e + f + d


def _vtable_vmem_bytes(art: TableArtifact) -> int:
    if art.vtable_flat is not None:
        return art.vtable_flat.size * 4
    lane = default_lane()
    fdim, b, m = art.vtable.q.shape
    return fdim * round_up_to_lane(b, lane) * round_up_to_lane(m, lane) * 4


def fits_vmem(art: TableArtifact) -> bool:
    if art.ftable is None:
        return (art.edges.size * 4 + _vtable_vmem_bytes(art)
                <= VMEM_BUDGET_BYTES)
    return tree_tables_vmem_bytes(art) <= VMEM_BUDGET_BYTES


# ---------------------------------------------------------------------------
# fused classify
# ---------------------------------------------------------------------------

def _tree_epilogue(art: TableArtifact, out):
    if art.agg == "vote":
        votes = out                                         # (N, C)
        pred = jnp.argmax(votes, axis=1)
        conf = jnp.max(votes, axis=1) / art.n_trees
        return pred, conf
    total = out[:, 0] / art.dtable_value.scale
    if art.agg == "wsum_sigmoid":
        p1 = jax.nn.sigmoid(art.base_score + art.learning_rate * total)
        return (p1 > 0.5).astype(jnp.int32), jnp.maximum(p1, 1 - p1)
    if art.agg == "iforest":
        n = jnp.float32(art.iforest_subsample)
        cfac = 2.0 * (jnp.log(n - 1.0) + 0.5772156649) - 2.0 * (n - 1.0) / n
        score = 2.0 ** (-(total / art.n_trees) / cfac)
        return (score > 0.5).astype(jnp.int32), jnp.maximum(score, 1 - score)
    raise ValueError(art.agg)


def _classical_epilogue(art: TableArtifact, out):
    total = out / art.vtable.scale                          # (N, M)
    if art.agg == "svm_ovo":
        planes = total + art.consts[None, :]
        win_i = planes > 0
        votes = jnp.zeros((planes.shape[0], art.n_classes), jnp.float32)
        votes = votes.at[:, art.pairs[:, 0]].add(win_i.astype(jnp.float32))
        votes = votes.at[:, art.pairs[:, 1]].add((~win_i).astype(jnp.float32))
        pred = jnp.argmax(votes, axis=1)
        if planes.shape[1] == 1:
            conf = jax.nn.sigmoid(2.0 * jnp.abs(planes[:, 0]))
        else:
            conf = jnp.max(votes, axis=1) / planes.shape[1]
        return pred, conf
    if art.agg == "nb_log":
        joint = total + art.consts[None, :]
        return (jnp.argmax(joint, axis=1),
                jnp.max(jax.nn.softmax(joint, axis=1), axis=1))
    if art.agg == "kmeans":
        pred = jnp.argmin(total, axis=1)
        top2 = jax.lax.top_k(-total, 2)[0]
        return pred, 1.0 - jnp.exp(top2[:, 1] - top2[:, 0])
    raise ValueError(art.agg)


def classify_batch_rows(art: TableArtifact, n: int, *, use_pallas=None,
                        tiles: TileConfig = None) -> int:
    """Rows ``fused_classify`` actually processes for an n-row batch.

    The fused/loop Pallas realizations pad the batch to their tile
    granularity (``_pad_batch``); the XLA reference processes exactly n.
    Mirrors the routing in ``fused_classify`` so callers reporting
    per-device classify work (the shard bench's classify_rows_per_device
    gate) count the kernel's real row count, not the logical one.
    """
    if use_pallas is None:
        use_pallas = _on_tpu()
    tiles = tiles or DEFAULT_TILES
    impl = tiles.impl if (use_pallas and fits_vmem(art)) else "ref"
    if impl == "fused":
        return padded_rows(n, tiles.tile_n)
    if impl == "loop":
        return padded_rows(n, _ek.TILE_N)
    return n


def fused_classify(art: TableArtifact, x, *, use_pallas=None,
                   interpret=None, tiles: TileConfig = None):
    """(pred, confidence) through the fused kernel path.

    use_pallas=None auto-routes: Pallas on TPU, XLA reference otherwise.
    Pass use_pallas=True on CPU to exercise interpret mode (tests do).
    tiles overrides the kernel tile sizes (see kernels.tuning.autotune_tiles)
    and the realization: ``tiles.impl`` picks the fused single-matmul
    kernel (default), the per-feature-loop kernel ('loop', tree artifacts
    only) or the XLA gather reference ('ref') — all bit-identical, so the
    autotuner is free to pick whichever is fastest for the artifact shape.
    """
    if use_pallas is None:
        use_pallas = _on_tpu()
    tiles = tiles or DEFAULT_TILES
    x = jnp.asarray(x, jnp.float32)
    impl = tiles.impl if (use_pallas and fits_vmem(art)) else "ref"

    if art.ftable is not None:
        vote = art.agg == "vote"
        if impl == "fused":
            ftable_flat, dtable_flat, dtable_pad = _flat_tree_tables(art, vote)
            xp, n = _pad_batch(x, tiles.tile_n)
            out = _ek.ensemble_lookup_fused(
                xp, art.edges, ftable_flat, dtable_flat, dtable_pad,
                interpret=interpret, tile_n=tiles.tile_n,
                edge_chunk=tiles.edge_chunk,
                dtable_chunk=tiles.dtable_chunk,
                select=tiles.select)[:n]
        else:
            dtable = (art.dtable_class if vote else art.dtable_value.q)
            if impl == "loop":
                xp, n = _pad_batch(x, _ek.TILE_N)
                out = _ek.ensemble_lookup_pallas_loop(
                    xp, art.edges, art.ftable, art.strides,
                    dtable.astype(jnp.float32), n_classes=art.n_classes,
                    vote=vote, interpret=interpret)[:n]
            else:
                out = _ref.ensemble_lookup_ref(
                    x, art.edges, art.ftable, art.strides,
                    dtable.astype(jnp.float32),
                    n_classes=art.n_classes, vote=vote)
        return _tree_epilogue(art, out)

    if impl == "loop":
        raise ValueError("impl='loop' is the per-feature-loop tree kernel; "
                         "classical artifacts have no loop realization")
    m = art.vtable.q.shape[2]
    if impl == "fused":
        xp, n = _pad_batch(x, tiles.tile_n)
        out = _ck.classical_lookup_fused(
            xp, art.edges, _flat_vtable(art), interpret=interpret,
            tile_n=tiles.tile_n, edge_chunk=tiles.edge_chunk)[:n, :m]
    else:
        out = _ref.classical_lookup_ref(x, art.edges,
                                        art.vtable.q.astype(jnp.float32))
    return _classical_epilogue(art, out)
