"""Pallas TPU kernels for the IIsy hot path (validated interpret=True on CPU).

bucketize        -- per-feature range match (the TCAM analog)
ensemble_lookup  -- fused tree-family match-action pipeline
classical_lookup -- fused SVM/NB/K-Means per-feature value tables

ops.py holds the jitd public wrappers (+ XLA fallback); ref.py the oracles.
"""

from repro.kernels.ops import bucketize, fused_classify, fits_vmem
