"""Tile-size selection for the fused lookup kernels.

The fused kernels take three tile knobs:

  tile_n        batch rows per grid step (VMEM tile height)
  edge_chunk    edges compared per sweep step in the range match
  dtable_chunk  decision entries compared per TCAM step

The best settings depend on the artifact shape (F, U, T/M, S) and the
backend (MXU tiles on TPU vs the interpret-mode grid overhead on CPU), so
``autotune_tiles`` times a small candidate sweep on synthetic data and
caches the winner per (artifact shape, backend). Serving calls it once at
server init (opt-in); everything else uses ``DEFAULT_TILES``.

``resolve_interpret`` is the backend auto-detect shared by every raw kernel
entry point: Pallas compiled on TPU, interpreter elsewhere — so direct
callers never run the interpreter on a real accelerator by accident.
"""

from __future__ import annotations

import dataclasses
import functools
import time
from typing import Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class TileConfig:
    tile_n: int = 128
    edge_chunk: int = 32
    dtable_chunk: int = 512
    select: str = "auto"     # decision-select strategy: matmul|compare|auto
    impl: str = "fused"      # kernel realization: fused|loop|ref — the
                             # autotune sweep includes the per-feature-loop
                             # kernel and the XLA gather reference as
                             # candidates, so shapes where the fused
                             # single-matmul loses (narrow/deep artifacts;
                             # BENCH_kernels.json rf_narrow) tune to the
                             # faster strategy instead of a regression


DEFAULT_TILES = TileConfig()

# Pallas-on-TPU sublane granularity for f32 (pallas_guide: min tile is
# 8 x 128) — the floor any clamped batch tile must respect.
MIN_TILE_N = 8

_TILE_CACHE: dict = {}


def padded_rows(n: int, tile: int) -> int:
    """Rows a tile-granular kernel actually processes for an n-row batch
    (``_pad_batch`` pads up to the next tile multiple)."""
    return -(-n // tile) * tile


def shard_tiles(tiles: TileConfig, batch: int) -> TileConfig:
    """Clamp ``tile_n`` to a partitioned per-device batch.

    The sharded classify hands each device a slab of ~K*W/D rows
    (DESIGN.md §16); with the full-width ``tile_n`` the kernel grid
    would pad that slab back up toward the unpartitioned batch and
    erase the per-device work reduction. Clamping to the slab (rounded
    up to the 8-row sublane floor) keeps padded work at
    ceil(slab/8)*8 — within one sublane of the ideal ceil(K*W/D). Only
    the fused realization tiles the batch; 'loop'/'ref' pass through.
    """
    if tiles.impl != "fused" or batch >= tiles.tile_n:
        return tiles
    return dataclasses.replace(
        tiles, tile_n=max(MIN_TILE_N, padded_rows(batch, MIN_TILE_N)))


def resolve_interpret(interpret: Optional[bool]) -> bool:
    """None -> interpreter off on TPU, on everywhere else."""
    if interpret is None:
        return jax.default_backend() != "tpu"
    return bool(interpret)


def clear_tile_cache() -> None:
    _TILE_CACHE.clear()


def _artifact_key(art) -> tuple:
    if art.ftable is not None:
        return ("tree", art.agg, tuple(art.ftable.shape),
                tuple(art.dtable_class.shape))
    return ("classical", art.agg, tuple(art.vtable.q.shape))


def measure_min(fn, reps: int, warmup: int = 1) -> float:
    """min-over-reps wall time of ``fn()`` (which must block until the
    work is done). Warmup runs absorb compilation / first-trace cost;
    the minimum is robust to host load spikes — the measurement
    discipline every autotuner here shares."""
    for _ in range(warmup):
        fn()
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def sweep_best(candidates, time_one, *, default, verbose: bool = False,
               label: str = "autotune") -> tuple:
    """Time each candidate, return (best, timings dict).

    ``default`` is ALWAYS timed (appended when missing from
    ``candidates``) and the winner is the measured argmin over a set
    containing it — so by construction the sweep can never select a
    config that regresses versus the default on the tuned shape. A
    candidate whose ``time_one`` raises is skipped (unsupported
    config), mirroring the tile sweep; if every candidate fails the
    default wins untimed.
    """
    cands = list(candidates)
    if default not in cands:
        cands.append(default)
    timings, best, best_dt = {}, default, float("inf")
    for cand in cands:
        try:
            dt = time_one(cand)
        except Exception:  # noqa: BLE001 — candidate probing: any raise
            #                (compile error, OOM, shape mismatch) just means
            #                "config unsupported", and the default wins
            continue
        timings[cand] = dt
        if verbose:
            print(f"{label} {cand} -> {dt * 1e3:.3f} ms")
        if dt < best_dt:
            best, best_dt = cand, dt
    return best, timings


def _time_config(art, x, tiles: TileConfig, reps: int) -> float:
    from repro.kernels import ops as _ops

    @functools.partial(jax.jit, static_argnames=("tiles",))
    def run(art, x, tiles):
        return _ops.fused_classify(art, x, use_pallas=True, tiles=tiles)[0]

    return measure_min(lambda: run(art, x, tiles).block_until_ready(), reps)


def candidate_tiles(batch: int) -> list:
    """Small sweep: grid granularity × chunking × select strategy, plus
    the non-fused realizations (the per-feature-loop kernel and the XLA
    gather reference). Without them the tuner could only pick the least-
    bad *fused* config — on shapes where the fused single-matmul loses
    outright (BENCH_kernels.json: rf_narrow at 0.866x) that is a tuned
    regression; with them the loser falls back to the faster strategy."""
    cands = []
    for tile_n in (128, 512):
        if tile_n > batch:
            continue
        for dtable_chunk in (256, 1024):
            for select in ("matmul", "compare"):
                cands.append(TileConfig(tile_n=tile_n, edge_chunk=32,
                                        dtable_chunk=dtable_chunk,
                                        select=select))
    if not cands:       # batch below every tile: still time default fused
        cands.append(DEFAULT_TILES)
    cands.append(TileConfig(impl="loop"))   # skipped where unsupported
    cands.append(TileConfig(impl="ref"))
    return cands


def autotune_tiles(art, *, batch: int = 2048, reps: int = 2,
                   candidates=None, seed: int = 0,
                   verbose: bool = False) -> TileConfig:
    """Pick the fastest TileConfig for this artifact shape on this backend.

    Cached per (artifact shape, backend); the sweep runs on synthetic rows
    drawn around the edge range so the compare sweeps see realistic bins.
    """
    key = (_artifact_key(art), jax.default_backend(), batch)
    hit = _TILE_CACHE.get(key)
    if hit is not None:
        return hit
    edges = jnp.where(jnp.isfinite(art.edges), art.edges, 0.0)
    lo, hi = float(edges.min()), float(edges.max())
    span = max(hi - lo, 1.0)
    x = jax.random.uniform(jax.random.PRNGKey(seed),
                           (batch, art.n_features), jnp.float32,
                           lo - 0.1 * span, hi + 0.1 * span)
    best, _ = sweep_best(candidates or candidate_tiles(batch),
                         lambda tiles: _time_config(art, x, tiles, reps),
                         default=DEFAULT_TILES, verbose=verbose,
                         label="autotune")
    _TILE_CACHE[key] = best
    return best
