"""Pure-jnp oracles for every kernel — the ground truth for allclose tests.

These share semantics with repro.core.inference (the reference data plane)
but expose the exact kernel contracts (same inputs, same outputs) so tests
sweep shapes/dtypes against them directly.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def bucketize_ref(x: jax.Array, edges: jax.Array) -> jax.Array:
    """x (N, F), edges (F, U) (+inf padded) -> (N, F) int32 bin ids."""
    return jnp.sum(x[:, :, None] > edges[None, :, :], axis=2).astype(jnp.int32)


def ensemble_lookup_ref(x, edges, ftable, strides, dtable, *,
                        n_classes: int, vote: bool) -> jax.Array:
    """Gather-based oracle for the fused tree pipeline."""
    bins = bucketize_ref(x, edges)                          # (N, F)
    f_idx = jnp.arange(x.shape[1])[None, :]
    codes = ftable[f_idx, bins]                             # (N, F, T)
    keys = jnp.einsum("nft,tf->nt", codes.astype(jnp.int32),
                      strides).astype(jnp.int32)
    t_idx = jnp.arange(dtable.shape[0])[None, :]
    leaf = dtable[t_idx, keys]                              # (N, T)
    if vote:
        return jax.nn.one_hot(leaf.astype(jnp.int32), n_classes,
                              dtype=jnp.float32).sum(axis=1)
    return leaf.astype(jnp.float32).sum(axis=1, keepdims=True)


def classical_lookup_ref(x, edges, vtable) -> jax.Array:
    """Gather-based oracle for the classical pipeline. -> (N, M) f32."""
    bins = bucketize_ref(x, edges)
    f_idx = jnp.arange(x.shape[1])[None, :]
    vals = vtable[f_idx, bins]                              # (N, F, M)
    return vals.astype(jnp.float32).sum(axis=1)


def stream_update_ref(regs, bucket, ts, length, is_fwd, valid, *,
                      limit=None):
    """Oracle for the fused streaming scatter/readout kernel.

    regs (8, N) f32 — the stacked register file in
    ``netsim.stream.REGISTER_FIELDS`` order (pkt_count, byte_count,
    t_min, t_max, fwd_pkts, rev_pkts, fwd_bytes, rev_bytes); window
    columns (W,). Returns (new_regs (8, N), rows (8, W)): the register
    file with this window folded in (count registers clamped at
    ``limit`` when given — the 2^24 overflow guard) and the updated
    register rows gathered at each lane's bucket.

    Mirrors ``netsim.stream.update_flow_table`` + ``saturate_counts``
    op for op (same masked segment primitives, same identity pinning,
    same clamp) so the composition is bit-identical — the layering keeps
    this module free of netsim imports, so the mirroring is asserted by
    tests rather than shared code.
    """
    n = regs.shape[1]
    v = valid.astype(jnp.float32)
    ln, fwd = length, is_fwd
    seg = lambda x: jax.ops.segment_sum(x, bucket, num_segments=n)
    inf = jnp.float32(jnp.inf)
    w_min = jax.ops.segment_min(jnp.where(valid, ts, inf), bucket,
                                num_segments=n)
    w_max = jax.ops.segment_max(jnp.where(valid, ts, -inf), bucket,
                                num_segments=n)
    new = [regs[0] + seg(v),
           regs[1] + seg(ln * v),
           jnp.minimum(regs[2], w_min),
           jnp.maximum(regs[3], w_max),
           regs[4] + seg(fwd * v),
           regs[5] + seg((1.0 - fwd) * v),
           regs[6] + seg(ln * fwd * v),
           regs[7] + seg(ln * (1.0 - fwd) * v)]
    if limit is not None:
        lim = jnp.float32(limit)
        for i in (0, 1, 4, 5, 6, 7):              # count registers only
            new[i] = jnp.minimum(new[i], lim)
    new_regs = jnp.stack(new)
    return new_regs, new_regs[:, bucket]


def decode_attention_int8_ref(q, k_q, k_s, v_q, v_s, valid, *, scale):
    """Dense oracle for the int8-KV decode-attention kernel.

    q (B,G,M,hd) f32; k_q/v_q (B,S,G,hd) int8; k_s/v_s (B,S,G,1) f32;
    valid (B,S) -> (B,G,M,hd) f32."""
    k = k_q.astype(jnp.float32) * k_s                      # (B,S,G,hd)
    v = v_q.astype(jnp.float32) * v_s
    sc = jnp.einsum("bgmd,bsgd->bgms", q, k) * scale
    sc = jnp.where(valid[:, None, None, :] > 0.5, sc, -1e30)
    w = jax.nn.softmax(sc, axis=-1)
    return jnp.einsum("bgms,bsgd->bgmd", w, v)
