"""Pallas TPU kernel: per-feature range match ("bucketize").

The switch's TCAM range match becomes a *parallel compare against every
edge* on the VPU — literally what a TCAM does in silicon, expressed in
vector registers. For each sample n and feature f:

    bin[n, f] = #{ u : x[n, f] > edges[f, u] }

Edges are padded with +inf (never match), so one dense (F, U) array serves
ragged per-feature edge counts.

Tiling: the batch is blocked into (TILE_N, F) VMEM tiles; the edge table is
small (the switch-SRAM analog) and stays fully VMEM-resident across the
grid. The compare sweep is chunked over U to bound the (TILE_N, F, CHUNK)
broadcast intermediate.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.tuning import resolve_interpret

TILE_N = 256
EDGE_CHUNK = 32


def _bucketize_kernel(x_ref, edges_ref, out_ref, *, u_total: int):
    x = x_ref[...]                                     # (TILE_N, F)
    acc = jnp.zeros(x.shape, jnp.int32)
    n_chunks = pl.cdiv(u_total, EDGE_CHUNK)
    for c in range(n_chunks):                          # static unroll
        lo = c * EDGE_CHUNK
        hi = min(lo + EDGE_CHUNK, u_total)
        e = edges_ref[:, lo:hi]                        # (F, cu)
        cmp = x[:, :, None] > e[None, :, :]            # (TILE_N, F, cu)
        acc = acc + jnp.sum(cmp.astype(jnp.int32), axis=2)
    out_ref[...] = acc


def bucketize_pallas(x: jax.Array, edges: jax.Array, *,
                     interpret=None, tile_n=None) -> jax.Array:
    """x (N, F) float32, edges (F, U) float32 (+inf padded) -> (N, F) int32.

    N must be a multiple of tile_n (ops.py pads). interpret=None
    auto-detects the backend (compiled on TPU, interpreter elsewhere).
    """
    interpret = resolve_interpret(interpret)
    tile_n = tile_n or TILE_N
    n, f = x.shape
    u = edges.shape[1]
    assert n % tile_n == 0, (n, tile_n)
    kernel = functools.partial(_bucketize_kernel, u_total=u)
    return pl.pallas_call(
        kernel,
        grid=(n // tile_n,),
        in_specs=[
            pl.BlockSpec((tile_n, f), lambda i: (i, 0)),
            pl.BlockSpec((f, u), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((tile_n, f), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, f), jnp.int32),
        interpret=interpret,
    )(x, edges)
