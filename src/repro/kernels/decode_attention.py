"""Pallas TPU kernel: fused int8-KV decode attention.

The §Perf B2 optimization (int8 KV cache with per-slot scales) realized
as a TPU kernel: the XLA path materializes the dequantized (S, hd) f32
cache in HBM before the dot; this kernel streams int8 KV blocks into
VMEM, dequantizes in-register, and runs the online-softmax accumulation
— HBM traffic is the int8 bytes, which is the whole point of B2.

One grid step = one (batch, kv-head) pair:
  q      (M, hd)  f32   M = query heads per kv head (GQA group)
  k_q    (S, hd)  int8  + k_s (S, 1) f32 per-slot scales
  v_q    (S, hd)  int8  + v_s (S, 1) f32
  valid  (S, 1)   f32   1.0 = live cache slot (ring-buffer mask)
  out    (M, hd)  f32

The S dimension is processed in VMEM-sized blocks with the standard
running-max online softmax, so the kernel supports 32k-deep caches with
a constant VMEM footprint.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

S_BLOCK = 512


def _decode_attn_kernel(q_ref, kq_ref, ks_ref, vq_ref, vs_ref, valid_ref,
                        out_ref, *, s_total: int, scale: float):
    q = q_ref[0, 0]                                  # (M, hd)
    m, hd = q.shape
    m_run = jnp.full((m, 1), -1e30, jnp.float32)
    l_run = jnp.zeros((m, 1), jnp.float32)
    acc = jnp.zeros((m, hd), jnp.float32)

    for blk in range(pl.cdiv(s_total, S_BLOCK)):
        lo = blk * S_BLOCK
        hi = min(lo + S_BLOCK, s_total)
        k = (kq_ref[0, 0, lo:hi, :].astype(jnp.float32)
             * ks_ref[0, 0, lo:hi, :])               # dequant in VMEM
        v = (vq_ref[0, 0, lo:hi, :].astype(jnp.float32)
             * vs_ref[0, 0, lo:hi, :])
        ok = valid_ref[0, 0, lo:hi, :]               # (s, 1)
        sc = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale   # (M, s)
        sc = jnp.where((ok > 0.5).T, sc, -1e30)
        m_new = jnp.maximum(m_run, sc.max(axis=1, keepdims=True))
        alpha = jnp.exp(m_run - m_new)
        p = jnp.exp(sc - m_new)
        l_run = l_run * alpha + p.sum(axis=1, keepdims=True)
        acc = acc * alpha + jax.lax.dot(
            p, v, preferred_element_type=jnp.float32)
        m_run = m_new

    out_ref[0, 0] = acc / jnp.maximum(l_run, 1e-30)


def decode_attention_int8_pallas(q, k_q, k_s, v_q, v_s, valid, *,
                                 scale: float, interpret: bool = True):
    """q (B, G, M, hd) f32; k_q/v_q (B, S, G, hd) int8;
    k_s/v_s (B, S, G, 1) f32; valid (B, S) f32 -> (B, G, M, hd) f32."""
    b, g, m, hd = q.shape
    s = k_q.shape[1]
    kernel = functools.partial(_decode_attn_kernel, s_total=s, scale=scale)
    # layout per grid step: (S, hd) slices for one (batch, head)
    kq = jnp.swapaxes(k_q, 1, 2)                     # (B, G, S, hd)
    vq = jnp.swapaxes(v_q, 1, 2)
    ks = jnp.swapaxes(k_s, 1, 2)                     # (B, G, S, 1)
    vs = jnp.swapaxes(v_s, 1, 2)
    val = valid[:, None, :, None].astype(jnp.float32)  # (B, 1, S, 1)
    val = jnp.broadcast_to(val, (b, g, s, 1))
    return pl.pallas_call(
        kernel,
        grid=(b, g),
        in_specs=[
            pl.BlockSpec((1, 1, m, hd), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, 1, s, hd), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, 1, s, 1), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, 1, s, hd), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, 1, s, 1), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((1, 1, s, 1), lambda i, j: (i, j, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, m, hd), lambda i, j: (i, j, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, g, m, hd), jnp.float32),
        interpret=interpret,
    )(q, kq, ks, vq, vs, val)
