"""Pallas TPU kernel: the fused IIsy match-action pipeline (tree family).

One kernel = the whole switch pipeline:

  1. range match         bins[n,f] = #{u : x[n,f] > edges[f,u]}        (VPU)
  2. feature tables +    keys[n,t] = sum_f ftable[f, bins[n,f], t] * strides[t,f]
     decision key        -> ONE blocked one-hot matmul: the (TN, F) bins
                            become a (TN, F*Bp) blocked one-hot (offset iota,
                            no per-feature loop) and the whole feature-table
                            walk is a single MXU matmul against the
                            stride-premultiplied flat table (F*Bp, Tp) built
                            by core.artifact.finalize_artifact. On TPU a
                            lookup table IS a matmul with a one-hot key —
                            here ALL F lookups and the mixed-radix combine
                            are one systolic pass.
  3. decision tables     leaf[n,t] = dtable[t, keys[n,t]]
     + aggregation       votes[n,c] = #{t : leaf class == c}  (vote)
                         total[n]   = sum_t leaf value         (sum aggs)
                         -> ONE more matmul. The TCAM-style parallel
                            compare (every entry matched against the key
                            simultaneously, what TCAM silicon does) builds a
                            match one-hot over (T, Sp); contracting it with
                            the precomputed aggregation table
                            dtable_flat[c, t, s] (one-hot of leaf classes,
                            or leaf payloads) yields votes/totals directly:
                            out[n,c] = sum_{t,s} match[n,t,s]*dflat[c,t,s].
                            Select and aggregate never materialize per-tree
                            leaves — they are one systolic pass, chunked
                            over Sp to bound the match intermediate.

All tables stay fully VMEM-resident across the grid — the VMEM budget plays
the switch-SRAM role (artifact_resources() decides fit, like Tables 1-2).
The scalar epilogue (argmax / sigmoid / iforest score) runs in kernels/ops.py.

Integer payloads ride as f32 (exact below 2^24), so the MXU path needs no
integer matmul support and quantized sums stay bit-exact vs the oracle.

``ensemble_lookup_pallas_loop`` keeps the previous per-feature-loop kernel
(F small matmuls in a Python loop) as the microbenchmark baseline —
benchmarks/kernel_microbench.py records the fused-vs-loop speedup.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.artifact import build_dtable_flat, flatten_ftable, pad_dtable
from repro.kernels.tuning import DEFAULT_TILES, resolve_interpret

TILE_N = DEFAULT_TILES.tile_n
EDGE_CHUNK = DEFAULT_TILES.edge_chunk
DTABLE_CHUNK = DEFAULT_TILES.dtable_chunk

# select='auto' crossover: the matmul select touches T*Sp*Co MACs per row,
# the compare select T*Sp wheres plus a per-tree leaf pass — so the
# crossover is on T*Sp*Co. Measured on CPU and sized for VMEM, the matmul
# wins while the whole flat decision table stays within a couple of
# MXU-sized chunks per row.
SELECT_MATMUL_MAX = 8192


def _range_match(x, edges_ref, u_total, edge_chunk=EDGE_CHUNK):
    """bins[n,f] = #{u : x[n,f] > edges[f,u]} — chunked compare sweep."""
    tn, f = x.shape
    bins = jnp.zeros((tn, f), jnp.int32)
    for c in range(pl.cdiv(u_total, edge_chunk)):
        lo = c * edge_chunk
        hi = min(lo + edge_chunk, u_total)
        e = edges_ref[:, lo:hi]                             # (F, cu)
        bins = bins + jnp.sum(
            (x[:, :, None] > e[None, :, :]).astype(jnp.int32), axis=2)
    return bins


def _blocked_one_hot(bins, b_pad):
    """(TN, F) bins -> (TN, F*Bp) blocked one-hot (feature f owns lanes
    [f*Bp, (f+1)*Bp)). bins <= U < Bp, so padded lanes are never hot."""
    tn, f = bins.shape
    iota = jax.lax.broadcasted_iota(jnp.int32, (1, 1, b_pad), 2)
    oh = (bins[:, :, None] == iota).astype(jnp.float32)     # (TN, F, Bp)
    return oh.reshape(tn, f * b_pad)


def _match_agg(keys_i, dflat_ref, dtable_chunk):
    """Decision select + aggregation as one chunked matmul.

    out[n, c] = sum_{t,s} (keys[n,t] == s) * dflat[c, t, s]. The match
    one-hot is the TCAM compare; the contraction against the precomputed
    aggregation table does lookup AND vote-count/payload-sum in one MXU
    pass. Padded entries (index >= logical S) can never match: keys < S.
    """
    tn, t = keys_i.shape
    cout, _, s_pad = dflat_ref.shape
    out = jnp.zeros((tn, cout), jnp.float32)
    for c in range(pl.cdiv(s_pad, dtable_chunk)):
        lo = c * dtable_chunk
        hi = min(lo + dtable_chunk, s_pad)
        s_iota = jax.lax.broadcasted_iota(jnp.int32, (1, 1, hi - lo), 2) + lo
        match = (keys_i[:, :, None] == s_iota).astype(jnp.float32)
        match = match.reshape(tn, t * (hi - lo))            # (TN, T*cs)
        dflat = dflat_ref[:, :, lo:hi].reshape(cout, t * (hi - lo))
        out = out + jax.lax.dot_general(
            match, dflat, dimension_numbers=(((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)             # (TN, Co)
    return out


def _fused_kernel(x_ref, edges_ref, ftab_ref, dflat_ref, out_ref, *,
                  u_total: int, t_logical: int, edge_chunk: int,
                  dtable_chunk: int):
    x = x_ref[...]                                          # (TN, F)
    tn, f = x.shape
    b_pad = ftab_ref.shape[0] // f

    bins = _range_match(x, edges_ref, u_total, edge_chunk)

    # stages 2+3 as ONE matmul: the flat table is stride-premultiplied, so
    # the matmul performs all F lookups AND the mixed-radix key combine.
    oh = _blocked_one_hot(bins, b_pad)                      # (TN, F*Bp)
    keys = jax.lax.dot(oh, ftab_ref[...],
                       preferred_element_type=jnp.float32)  # (TN, Tp)
    keys_i = keys[:, :t_logical].astype(jnp.int32)          # exact below 2^24

    # stages 4+5 as one more matmul: select + aggregate
    out_ref[...] = _match_agg(keys_i, dflat_ref, dtable_chunk)


def _fused_compare_kernel(x_ref, edges_ref, ftab_ref, dtable_ref, out_ref, *,
                          u_total: int, t_logical: int, n_classes: int,
                          vote: bool, edge_chunk: int, dtable_chunk: int):
    """Fused stage-2 matmul + compare-select decision stage.

    For large T*Sp the match one-hot of the matmul select costs more than
    TCAM-style where/sum over the raw (T, Sp) table; this variant keeps the
    single-matmul feature-table walk and selects leaves the seed way.
    """
    x = x_ref[...]                                          # (TN, F)
    tn, f = x.shape
    b_pad = ftab_ref.shape[0] // f
    s_pad = dtable_ref.shape[1]

    bins = _range_match(x, edges_ref, u_total, edge_chunk)
    oh = _blocked_one_hot(bins, b_pad)                      # (TN, F*Bp)
    keys = jax.lax.dot(oh, ftab_ref[...],
                       preferred_element_type=jnp.float32)  # (TN, Tp)
    keys_i = keys[:, :t_logical].astype(jnp.int32)

    leaf = jnp.zeros((tn, t_logical), jnp.float32)
    for c in range(pl.cdiv(s_pad, dtable_chunk)):
        lo = c * dtable_chunk
        hi = min(lo + dtable_chunk, s_pad)
        s_iota = jax.lax.broadcasted_iota(jnp.int32, (1, 1, hi - lo), 2) + lo
        match = (keys_i[:, :, None] == s_iota)              # (TN, T, cs)
        dt = dtable_ref[:, lo:hi]                           # (T, cs)
        leaf = leaf + jnp.sum(jnp.where(match, dt[None, :, :], 0.0), axis=2)

    if vote:
        c_iota = jax.lax.broadcasted_iota(jnp.float32, (1, 1, n_classes), 2)
        out_ref[...] = jnp.sum(
            (leaf[:, :, None] == c_iota).astype(jnp.float32), axis=1)
    else:
        out_ref[...] = jnp.sum(leaf, axis=1, keepdims=True)


def ensemble_lookup_fused(x, edges, ftable_flat, dtable_flat, dtable_pad, *,
                          interpret=None, tile_n=None, edge_chunk=None,
                          dtable_chunk=None, select: str = "auto"
                          ) -> jax.Array:
    """Single-matmul fused pipeline on pre-flattened tables.

    x (N, F) f32 with N % tile_n == 0; edges (F, U) f32;
    ftable_flat (F*Bp, Tp) f32 stride-premultiplied (finalize_artifact);
    dtable_flat (Co, T, Sp) f32 decision+aggregation table;
    dtable_pad (T, Sp) f32 raw decision table (compare-select strategy).
    select: 'matmul' | 'compare' | 'auto' (matmul while T*Sp is small
    enough that the match one-hot contraction beats TCAM where/sum).
    Returns (N, Co): per-class votes (vote) or payload sums (Co == 1).
    """
    interpret = resolve_interpret(interpret)
    tile_n = tile_n or TILE_N
    edge_chunk = edge_chunk or EDGE_CHUNK
    dtable_chunk = dtable_chunk or DTABLE_CHUNK
    n, f = x.shape
    u = edges.shape[1]
    fb, t_pad = ftable_flat.shape
    cout, t, s_pad = dtable_flat.shape
    assert n % tile_n == 0, (n, tile_n)
    if select == "auto":
        select = ("matmul" if t * s_pad * cout <= SELECT_MATMUL_MAX
                  else "compare")
    if select == "matmul":
        kernel = functools.partial(_fused_kernel, u_total=u, t_logical=t,
                                   edge_chunk=edge_chunk,
                                   dtable_chunk=dtable_chunk)
        dtable_in = dtable_flat
        dtable_spec = pl.BlockSpec((cout, t, s_pad), lambda i: (0, 0, 0))
    else:
        kernel = functools.partial(_fused_compare_kernel, u_total=u,
                                   t_logical=t, n_classes=cout,
                                   vote=cout > 1, edge_chunk=edge_chunk,
                                   dtable_chunk=dtable_chunk)
        dtable_in = dtable_pad
        dtable_spec = pl.BlockSpec((t, s_pad), lambda i: (0, 0))
    return pl.pallas_call(
        kernel,
        grid=(n // tile_n,),
        in_specs=[
            pl.BlockSpec((tile_n, f), lambda i: (i, 0)),
            pl.BlockSpec((f, u), lambda i: (0, 0)),
            pl.BlockSpec((fb, t_pad), lambda i: (0, 0)),
            dtable_spec,
        ],
        out_specs=pl.BlockSpec((tile_n, cout), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, cout), jnp.float32),
        interpret=interpret,
    )(x, edges, ftable_flat, dtable_in)


def ensemble_lookup_pallas(x, edges, ftable, strides, dtable, *,
                           n_classes: int, vote: bool, interpret=None,
                           tile_n=None, edge_chunk=None, dtable_chunk=None,
                           select: str = "auto") -> jax.Array:
    """Run the fused pipeline from unflattened tables (compat entry).

    Flattens ftable/strides/dtable into the single-matmul layout on the fly
    (serving uses the artifact's pre-flattened copies instead). Shapes:
    x (N, F) f32 with N % tile_n == 0; edges (F, U) f32; ftable (F, U+1, T)
    int32; strides (T, F) int32; dtable (T, S) f32 (class ids or quantized
    payload as exact floats). interpret=None auto-detects the backend.
    Returns (N, n_classes) votes or (N, 1) sums, as before.
    """
    ftable_flat = flatten_ftable(ftable, strides)
    dtable_flat = build_dtable_flat(dtable, n_classes, vote)
    dtable_padded = pad_dtable(dtable)
    return ensemble_lookup_fused(
        x, edges, ftable_flat, dtable_flat, dtable_padded,
        interpret=interpret, tile_n=tile_n, edge_chunk=edge_chunk,
        dtable_chunk=dtable_chunk, select=select)


# ---------------------------------------------------------------------------
# legacy per-feature-loop kernel — kept as the microbenchmark baseline
# ---------------------------------------------------------------------------

def _loop_kernel(x_ref, edges_ref, ftable_ref, strides_ref, dtable_ref,
                 out_ref, *, u_total: int, s_total: int, n_classes: int,
                 vote: bool):
    x = x_ref[...]                                          # (TN, F)
    tn, f = x.shape
    t = strides_ref.shape[0]
    n_bins = u_total + 1

    bins = _range_match(x, edges_ref, u_total)

    # stages 2+3 as F separate small matmuls (the pre-fusion formulation)
    keys = jnp.zeros((tn, t), jnp.float32)
    b_iota = jax.lax.broadcasted_iota(jnp.int32, (1, n_bins), 1)
    for fi in range(f):                                     # static unroll
        oh = (bins[:, fi][:, None] == b_iota).astype(jnp.float32)  # (TN, B)
        ft = ftable_ref[fi].astype(jnp.float32)             # (B, T)
        code = jax.lax.dot(oh, ft,
                           preferred_element_type=jnp.float32)     # (TN, T)
        keys = keys + code * strides_ref[:, fi].astype(jnp.float32)[None, :]
    keys_i = keys.astype(jnp.int32)

    # stage 4: TCAM compare-select, then a separate aggregation pass
    leaf = jnp.zeros((tn, t), jnp.float32)
    for c in range(pl.cdiv(s_total, DTABLE_CHUNK)):
        lo = c * DTABLE_CHUNK
        hi = min(lo + DTABLE_CHUNK, s_total)
        s_iota = jax.lax.broadcasted_iota(jnp.int32, (1, 1, hi - lo), 2) + lo
        match = (keys_i[:, :, None] == s_iota)              # (TN, T, cs)
        dt = dtable_ref[:, lo:hi].astype(jnp.float32)       # (T, cs)
        leaf = leaf + jnp.sum(jnp.where(match, dt[None, :, :], 0.0), axis=2)

    if vote:
        c_iota = jax.lax.broadcasted_iota(jnp.float32, (1, 1, n_classes), 2)
        votes = jnp.sum((leaf[:, :, None] == c_iota).astype(jnp.float32),
                        axis=1)                             # (TN, C)
        out_ref[...] = votes
    else:
        out_ref[...] = jnp.sum(leaf, axis=1, keepdims=True)


def ensemble_lookup_pallas_loop(x, edges, ftable, strides, dtable, *,
                                n_classes: int, vote: bool,
                                interpret=None) -> jax.Array:
    """Per-feature-loop variant (F small matmuls). Baseline only — use
    ensemble_lookup_pallas / ensemble_lookup_fused in real code."""
    interpret = resolve_interpret(interpret)
    n, f = x.shape
    u = edges.shape[1]
    t, s = dtable.shape
    assert n % TILE_N == 0, n
    out_cols = n_classes if vote else 1
    kernel = functools.partial(_loop_kernel, u_total=u, s_total=s,
                               n_classes=n_classes, vote=vote)
    return pl.pallas_call(
        kernel,
        grid=(n // TILE_N,),
        in_specs=[
            pl.BlockSpec((TILE_N, f), lambda i: (i, 0)),
            pl.BlockSpec((f, u), lambda i: (0, 0)),
            pl.BlockSpec((f, u + 1, t), lambda i: (0, 0, 0)),
            pl.BlockSpec((t, f), lambda i: (0, 0)),
            pl.BlockSpec((t, s), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((TILE_N, out_cols), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, out_cols), jnp.float32),
        interpret=interpret,
    )(x, edges, ftable, strides, dtable)
