"""Pallas TPU kernel: the fused IIsy match-action pipeline (tree family).

One kernel = the whole switch pipeline:

  1. range match         bins[n,f] = #{u : x[n,f] > edges[f,u]}        (VPU)
  2. feature tables +    keys[n,t] = sum_f ftable[f, bins[n,f], t] * strides[t,f]
     decision key        -> realized per feature as one-hot(bins_f) @ ftable[f],
                            an MXU matmul: on TPU a lookup table IS a matmul
                            with a one-hot key. The per-tree code and the
                            mixed-radix combine fuse into one accumulation.
  3. decision tables     leaf[n,t] = dtable[t, keys[n,t]]
                         -> TCAM-style *parallel compare-select* chunked over
                            table entries: every entry is matched against the
                            key simultaneously, exactly what TCAM silicon
                            does, expressed on the VPU.
  4. aggregation         votes[n,c] = #{t : leaf class == c}  (vote)
                         total[n]   = sum_t leaf value         (sum aggs)

All tables stay fully VMEM-resident across the grid — the VMEM budget plays
the switch-SRAM role (artifact_resources() decides fit, like Tables 1-2).
The scalar epilogue (argmax / sigmoid / iforest score) runs in kernels/ops.py.

Integer payloads ride as f32 (exact below 2^24), so the MXU path needs no
integer matmul support and quantized sums stay bit-exact vs the oracle.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE_N = 128
EDGE_CHUNK = 32
DTABLE_CHUNK = 512


def _range_match(x, edges_ref, u_total):
    """bins[n,f] = #{u : x[n,f] > edges[f,u]} — chunked compare sweep."""
    tn, f = x.shape
    bins = jnp.zeros((tn, f), jnp.int32)
    for c in range(pl.cdiv(u_total, EDGE_CHUNK)):
        lo = c * EDGE_CHUNK
        hi = min(lo + EDGE_CHUNK, u_total)
        e = edges_ref[:, lo:hi]                             # (F, cu)
        bins = bins + jnp.sum(
            (x[:, :, None] > e[None, :, :]).astype(jnp.int32), axis=2)
    return bins


def _ensemble_kernel(x_ref, edges_ref, ftable_ref, strides_ref, dtable_ref,
                     out_ref, *, u_total: int, s_total: int, n_classes: int,
                     vote: bool):
    x = x_ref[...]                                          # (TN, F)
    tn, f = x.shape
    t = strides_ref.shape[0]
    n_bins = u_total + 1

    bins = _range_match(x, edges_ref, u_total)

    # stages 2+3 fused: keys[n,t] = sum_f (onehot(bins_f) @ ftable[f]) * strides[:,f]
    keys = jnp.zeros((tn, t), jnp.float32)
    b_iota = jax.lax.broadcasted_iota(jnp.int32, (1, n_bins), 1)
    for fi in range(f):                                     # static unroll, F small
        oh = (bins[:, fi][:, None] == b_iota).astype(jnp.float32)  # (TN, B)
        ft = ftable_ref[fi].astype(jnp.float32)             # (B, T)
        code = jax.lax.dot(oh, ft,
                           preferred_element_type=jnp.float32)     # (TN, T)
        keys = keys + code * strides_ref[:, fi].astype(jnp.float32)[None, :]
    keys_i = keys.astype(jnp.int32)                         # exact below 2^24

    # stage 4: TCAM-style parallel compare-select over decision entries
    leaf = jnp.zeros((tn, t), jnp.float32)
    for c in range(pl.cdiv(s_total, DTABLE_CHUNK)):
        lo = c * DTABLE_CHUNK
        hi = min(lo + DTABLE_CHUNK, s_total)
        s_iota = jax.lax.broadcasted_iota(jnp.int32, (1, 1, hi - lo), 2) + lo
        match = (keys_i[:, :, None] == s_iota)              # (TN, T, cs)
        dt = dtable_ref[:, lo:hi].astype(jnp.float32)       # (T, cs)
        leaf = leaf + jnp.sum(jnp.where(match, dt[None, :, :], 0.0), axis=2)

    # stage 5: aggregation
    if vote:
        c_iota = jax.lax.broadcasted_iota(jnp.float32, (1, 1, n_classes), 2)
        votes = jnp.sum((leaf[:, :, None] == c_iota).astype(jnp.float32),
                        axis=1)                             # (TN, C)
        out_ref[...] = votes
    else:
        out_ref[...] = jnp.sum(leaf, axis=1, keepdims=True)


def ensemble_lookup_pallas(x, edges, ftable, strides, dtable, *,
                           n_classes: int, vote: bool,
                           interpret: bool = True) -> jax.Array:
    """Run the fused pipeline. Returns (N, n_classes) votes or (N, 1) sums.

    x (N, F) f32 with N % TILE_N == 0; edges (F, U) f32; ftable (F, U+1, T)
    int32; strides (T, F) int32; dtable (T, S) f32 (class ids or quantized
    payload as exact floats).
    """
    n, f = x.shape
    u = edges.shape[1]
    t, s = dtable.shape
    assert n % TILE_N == 0, n
    out_cols = n_classes if vote else 1
    kernel = functools.partial(_ensemble_kernel, u_total=u, s_total=s,
                               n_classes=n_classes, vote=vote)
    return pl.pallas_call(
        kernel,
        grid=(n // TILE_N,),
        in_specs=[
            pl.BlockSpec((TILE_N, f), lambda i: (i, 0)),
            pl.BlockSpec((f, u), lambda i: (0, 0)),
            pl.BlockSpec((f, u + 1, t), lambda i: (0, 0, 0)),
            pl.BlockSpec((t, f), lambda i: (0, 0)),
            pl.BlockSpec((t, s), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((TILE_N, out_cols), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, out_cols), jnp.float32),
        interpret=interpret,
    )(x, edges, ftable, strides, dtable)
