"""Pallas TPU kernel: fused streaming register scatter + touched-row gather.

The streaming serving step's register half is scatter-then-gather over
the same flow table: fold the window's packets into the per-bucket
registers (segment sums / min / max), clamp the count registers at the
2^24 f32 integer-exactness envelope, then gather each lane's updated
register row for the classify stage. Composed from XLA ops that is two
HBM round-trips over the (8, N) register file with a (W, 8) gather
materialized in between; here the whole pass is fused in VMEM per
bucket tile — the per-packet ALU + register read of the switch pipeline
as one kernel.

TPU realization (no native scatter on the VPU):

  scatter  -> a one-hot contraction. The (W, TILE_B) bucket-match
              one-hot against the six masked per-lane value vectors is
              ONE (6, W) x (W, TILE_B) MXU pass producing every count
              register's tile contribution; first/last-seen timestamps
              ride masked min/max reductions of the same match (VPU).
  gather   -> a masked-max over the same one-hot: exactly one tile
              matches each lane, so accumulating
              max(where(match, reg, -inf)) across grid steps
              reconstructs reg[bucket[w]] exactly — including the ±inf
              min/max identities of untouched buckets, which a
              multiply-gather would NaN-poison (inf * 0).

Exactness: count/byte registers are integer-valued f32 (exact below
2^24 in any association order), timestamps ride min/max (associative) —
so the matmul-scatter and masked-max gather are bit-identical to the
``kernels.ref.stream_update_ref`` segment-op oracle, asserted by
interpret-mode parity tests.

The register file is small ((8, N) f32: 256 KB at N=8192) but the match
one-hot is not — the bucket axis is tiled (grid over ``TILE_B`` column
blocks) so the (W, TILE_B) one-hot and its temporaries stay a few MB.
The rows output block is revisited by every grid step (TPU grids are
sequential) and initialized at step 0.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.tuning import resolve_interpret

TILE_B = 512

N_REGISTERS = 8
# indices into the stacked register file (netsim.stream.REGISTER_FIELDS
# order); the module is deliberately free of netsim imports — layering —
# so the order is pinned here and asserted by tests
IDX_COUNTS = (0, 1, 4, 5, 6, 7)        # pkt, byte, fwd/rev pkts, fwd/rev bytes
IDX_T_MIN = 2
IDX_T_MAX = 3


def _stream_update_kernel(bucket_ref, ts_ref, len_ref, fwd_ref, valid_ref,
                          regs_ref, out_regs_ref, rows_ref, *,
                          tile_b: int, limit):
    j = pl.program_id(0)
    b = bucket_ref[0, :]                               # (W,) i32
    ts = ts_ref[0, :]                                  # (W,) f32
    ln = len_ref[0, :]
    fw = fwd_ref[0, :]
    vf = (valid_ref[0, :] != 0).astype(jnp.float32)
    w = b.shape[0]

    iota = (jax.lax.broadcasted_iota(jnp.int32, (w, tile_b), 1)
            + j * tile_b)
    match = b[:, None] == iota                         # (W, TILE_B) one-hot
    matchv = match & (vf[:, None] > 0.0)               # pad lanes masked out

    # scatter: all six count-register contributions in ONE MXU pass
    vals = jnp.stack([vf, ln * vf, fw * vf, (1.0 - fw) * vf,
                      ln * fw * vf, ln * (1.0 - fw) * vf])       # (6, W)
    contrib = jax.lax.dot_general(
        vals, matchv.astype(jnp.float32),
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)            # (6, TILE_B)

    old = regs_ref[...]                                # (8, TILE_B)
    inf = jnp.float32(jnp.inf)
    t_min = jnp.minimum(old[IDX_T_MIN],
                        jnp.min(jnp.where(matchv, ts[:, None], inf), axis=0))
    t_max = jnp.maximum(old[IDX_T_MAX],
                        jnp.max(jnp.where(matchv, ts[:, None], -inf), axis=0))
    counts = [old[i] + contrib[k] for k, i in enumerate(IDX_COUNTS)]
    if limit is not None:
        counts = [jnp.minimum(c, jnp.float32(limit)) for c in counts]
    new = jnp.stack([counts[0], counts[1], t_min, t_max,
                     counts[2], counts[3], counts[4], counts[5]])

    out_regs_ref[...] = new

    # gather: masked-max select — exact even for ±inf identities
    @pl.when(j == 0)
    def _init():
        rows_ref[...] = jnp.full((N_REGISTERS, w), -inf, jnp.float32)

    gathered = jnp.stack([
        jnp.max(jnp.where(match, new[r][None, :], -inf), axis=1)
        for r in range(N_REGISTERS)])                  # (8, W)
    rows_ref[...] = jnp.maximum(rows_ref[...], gathered)


def stream_update_pallas(regs, bucket, ts, length, is_fwd, valid, *,
                         limit=None, interpret=None, tile_b=None):
    """regs (8, N) f32 stacked register file, window columns (W,)
    -> (new_regs (8, N), rows (8, W)).

    N must be a multiple of ``tile_b`` (ops.py pads; bucket ids are < N,
    so pad columns are never matched and pass through with only the
    clamp applied — sliced off by the wrapper). ``limit`` clamps the
    count registers (the 2^24 overflow guard) inside the same pass;
    None skips it bit-exactly. interpret=None auto-detects the backend.
    """
    interpret = resolve_interpret(interpret)
    tile_b = tile_b or TILE_B
    r, n = regs.shape
    assert r == N_REGISTERS, r
    assert n % tile_b == 0, (n, tile_b)
    w = bucket.shape[0]
    kernel = functools.partial(_stream_update_kernel, tile_b=tile_b,
                               limit=limit)
    row = lambda a, dt: a[None, :].astype(dt)
    return pl.pallas_call(
        kernel,
        grid=(n // tile_b,),
        in_specs=[
            pl.BlockSpec((1, w), lambda j: (0, 0)),
            pl.BlockSpec((1, w), lambda j: (0, 0)),
            pl.BlockSpec((1, w), lambda j: (0, 0)),
            pl.BlockSpec((1, w), lambda j: (0, 0)),
            pl.BlockSpec((1, w), lambda j: (0, 0)),
            pl.BlockSpec((r, tile_b), lambda j: (0, j)),
        ],
        out_specs=[
            pl.BlockSpec((r, tile_b), lambda j: (0, j)),
            pl.BlockSpec((r, w), lambda j: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((r, n), jnp.float32),
            jax.ShapeDtypeStruct((r, w), jnp.float32),
        ],
        interpret=interpret,
    )(row(bucket, jnp.int32), row(ts, jnp.float32),
      row(length, jnp.float32), row(is_fwd, jnp.float32),
      row(valid, jnp.int32), regs)
