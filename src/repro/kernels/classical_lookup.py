"""Pallas TPU kernel: fused classical-model pipeline (SVM / NB / K-Means).

The §4.3 "table per feature" mapping: each feature's bin holds a quantized
partial term vector (a_j*x for SVM planes, log P(x|c) for NB, (x-c)^2 for
K-Means); the pipeline sums them. Fused as:

  out[n, m] = sum_f vtable[f, bins[n, f], m]
            = sum_f onehot(bins_f) @ vtable[f]     (MXU matmuls)

The epilogue (plane votes / argmax / argmin + confidence) is elementwise and
lives in kernels/ops.py. Integer payloads ride as exact f32, so the result
is bit-identical to the integer-domain oracle sum.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.ensemble_lookup import _range_match

TILE_N = 128


def _classical_kernel(x_ref, edges_ref, vtable_ref, out_ref, *, u_total: int):
    x = x_ref[...]                                          # (TN, F)
    tn, f = x.shape
    m = vtable_ref.shape[2]
    n_bins = u_total + 1

    bins = _range_match(x, edges_ref, u_total)

    total = jnp.zeros((tn, m), jnp.float32)
    b_iota = jax.lax.broadcasted_iota(jnp.int32, (1, n_bins), 1)
    for fi in range(f):
        oh = (bins[:, fi][:, None] == b_iota).astype(jnp.float32)  # (TN, B)
        vt = vtable_ref[fi].astype(jnp.float32)             # (B, M)
        total = total + jax.lax.dot(oh, vt,
                                    preferred_element_type=jnp.float32)
    out_ref[...] = total


def classical_lookup_pallas(x, edges, vtable, *, interpret: bool = True):
    """x (N, F) f32, edges (F, U), vtable (F, U+1, M) -> (N, M) f32 sums."""
    n, f = x.shape
    u = edges.shape[1]
    m = vtable.shape[2]
    assert n % TILE_N == 0, n
    kernel = functools.partial(_classical_kernel, u_total=u)
    return pl.pallas_call(
        kernel,
        grid=(n // TILE_N,),
        in_specs=[
            pl.BlockSpec((TILE_N, f), lambda i: (i, 0)),
            pl.BlockSpec((f, u), lambda i: (0, 0)),
            pl.BlockSpec((f, u + 1, m), lambda i: (0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((TILE_N, m), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, m), jnp.float32),
        interpret=interpret,
    )(x, edges, vtable)
