"""Pallas TPU kernel: fused classical-model pipeline (SVM / NB / K-Means).

The §4.3 "table per feature" mapping: each feature's bin holds a quantized
partial term vector (a_j*x for SVM planes, log P(x|c) for NB, (x-c)^2 for
K-Means); the pipeline sums them. Fused as ONE matmul:

  out[n, m] = sum_f vtable[f, bins[n, f], m]
            = blocked_onehot(bins) @ vtable_flat       (one MXU pass)

where vtable_flat (F*Bp, Mp) is the lane-padded flattened table built by
core.artifact.finalize_artifact — feature f owns rows [f*Bp, (f+1)*Bp), so
the blocked one-hot selects all F partial terms in a single systolic pass
instead of F small matmuls in a Python loop.

The epilogue (plane votes / argmax / argmin + confidence) is elementwise and
lives in kernels/ops.py. Integer payloads ride as exact f32, so the result
is bit-identical to the integer-domain oracle sum.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.artifact import flatten_vtable
from repro.kernels.ensemble_lookup import _blocked_one_hot, _range_match
from repro.kernels.tuning import DEFAULT_TILES, resolve_interpret

TILE_N = DEFAULT_TILES.tile_n
EDGE_CHUNK = DEFAULT_TILES.edge_chunk


def _fused_classical_kernel(x_ref, edges_ref, vtab_ref, out_ref, *,
                            u_total: int, edge_chunk: int):
    x = x_ref[...]                                          # (TN, F)
    tn, f = x.shape
    b_pad = vtab_ref.shape[0] // f

    bins = _range_match(x, edges_ref, u_total, edge_chunk)
    oh = _blocked_one_hot(bins, b_pad)                      # (TN, F*Bp)
    out_ref[...] = jax.lax.dot(oh, vtab_ref[...],
                               preferred_element_type=jnp.float32)


def classical_lookup_fused(x, edges, vtable_flat, *, interpret=None,
                           tile_n=None, edge_chunk=None) -> jax.Array:
    """Single-matmul pipeline on the pre-flattened table.

    x (N, F) f32 with N % tile_n == 0; edges (F, U); vtable_flat (F*Bp, Mp)
    f32 -> (N, Mp) f32 sums (padded cols are zero; callers slice to M).
    """
    interpret = resolve_interpret(interpret)
    tile_n = tile_n or TILE_N
    edge_chunk = edge_chunk or EDGE_CHUNK
    n, f = x.shape
    u = edges.shape[1]
    fb, m_pad = vtable_flat.shape
    assert n % tile_n == 0, (n, tile_n)
    kernel = functools.partial(_fused_classical_kernel, u_total=u,
                               edge_chunk=edge_chunk)
    return pl.pallas_call(
        kernel,
        grid=(n // tile_n,),
        in_specs=[
            pl.BlockSpec((tile_n, f), lambda i: (i, 0)),
            pl.BlockSpec((f, u), lambda i: (0, 0)),
            pl.BlockSpec((fb, m_pad), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((tile_n, m_pad), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, m_pad), jnp.float32),
        interpret=interpret,
    )(x, edges, vtable_flat)


def classical_lookup_pallas(x, edges, vtable, *, interpret=None,
                            tile_n=None, edge_chunk=None) -> jax.Array:
    """x (N, F) f32, edges (F, U), vtable (F, U+1, M) -> (N, M) f32 sums.

    Compat entry: flattens vtable on the fly (serving uses the artifact's
    pre-flattened copy). interpret=None auto-detects the backend.
    """
    m = vtable.shape[2]
    out = classical_lookup_fused(x, edges, flatten_vtable(vtable),
                                 interpret=interpret, tile_n=tile_n,
                                 edge_chunk=edge_chunk)
    return out[:, :m]
