"""Pallas TPU kernel: masked register reset (the eviction sweep's scatter).

The aging sweep recycles idle flow buckets by writing each register's
init identity back over the evicted slots — on the switch this is the
control plane's register clear, here it is a masked scatter over the
whole register file. The registers are stacked to one (R, N) tile so the
sweep is a single VPU pass: one mask row broadcast against R register
rows, one fill scalar per register.

Tiling: the bucket axis is blocked into (R, TILE_B) VMEM tiles; the mask
rides as a (1, TILE_B) row and the fills as an (R, 1) column, both
broadcast inside the tile. R (the register count) is small and static —
the whole register file height fits one tile.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.tuning import resolve_interpret

TILE_B = 1024


def _evict_fill_kernel(mask_ref, regs_ref, fills_ref, out_ref):
    m = mask_ref[...]                                  # (1, TILE_B) int32
    r = regs_ref[...]                                  # (R, TILE_B)
    f = fills_ref[...]                                 # (R, 1)
    out_ref[...] = jnp.where(m != 0, f, r)


def evict_fill_pallas(regs: jax.Array, mask: jax.Array, fills: jax.Array,
                      *, interpret=None, tile_b=None) -> jax.Array:
    """regs (R, N) f32, mask (N,) int32 (1 = evict), fills (R,) f32
    -> (R, N) with evicted columns reset to their fill identities.

    N must be a multiple of tile_b (ops.py pads with mask=0, so pad
    columns pass through untouched). interpret=None auto-detects the
    backend (compiled on TPU, interpreter elsewhere).
    """
    interpret = resolve_interpret(interpret)
    tile_b = tile_b or TILE_B
    r, n = regs.shape
    assert n % tile_b == 0, (n, tile_b)
    return pl.pallas_call(
        _evict_fill_kernel,
        grid=(n // tile_b,),
        in_specs=[
            pl.BlockSpec((1, tile_b), lambda i: (0, i)),
            pl.BlockSpec((r, tile_b), lambda i: (0, i)),
            pl.BlockSpec((r, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((r, tile_b), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((r, n), jnp.float32),
        interpret=interpret,
    )(mask[None, :].astype(jnp.int32), regs, fills[:, None])
