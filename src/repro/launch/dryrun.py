import os
# lint: allow-env-mutation — dryrun is a launch/ entrypoint, never
# library-imported: the flag must land before jax first initializes
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the
production mesh, print memory_analysis / cost_analysis, parse collective
bytes, and emit a JSON record per cell for §Dry-run / §Roofline.

The two lines above MUST stay first: jax locks the device count on first
init, and only the dry-run wants 512 placeholder host devices.

Usage:
  python -m repro.launch.dryrun --arch qwen3-4b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--out artifacts/dryrun]
  python -m repro.launch.dryrun --all --both-meshes   # 16x16 and 2x16x16

--all spawns one subprocess per cell (compile failures isolate; memory is
returned to the OS between cells).

§Perf knobs (per-cell variants for the hillclimb log):
  --remat {none,full}       activation checkpointing policy for train cells
  --compress {none,int8,topk}  DP-gradient compression inside the step
  --seq-shard               shard prefill activations' sequence dim (SP)
  --cache-seq-shard=0       disable sequence-sharding of decode caches
"""

import argparse
import dataclasses
import functools
import json
import subprocess
import sys
import time

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCH_IDS, get_config
from repro.distributed.sharding import (batch_specs, cache_specs,
                                        named_sharding_tree,
                                        opt_state_specs, param_specs)
from repro.launch.mesh import make_production_mesh
from repro.launch.shapes import SHAPES, cell_supported, input_specs
from repro.models import model as M
from repro.roofline.analysis import (collective_bytes_from_hlo, model_flops,
                                     roofline_terms)
from repro.training import grad_compress as gc
from repro.training.optim import AdamWConfig, adamw_update

F32 = jnp.float32


def _opt_shapes(param_shapes):
    zeros = jax.tree.map(
        lambda l: jax.ShapeDtypeStruct(l.shape, F32), param_shapes)
    return {"m": zeros, "v": zeros,
            "step": jax.ShapeDtypeStruct((), jnp.int32)}


def build_step(cfg, kind, *, remat=True, compress="none",
               bf16_params=False):
    """The function each cell lowers.

    bf16_params: cast fp32 master weights to bf16 before the forward —
    XLA sinks the convert below the FSDP all-gather, halving the
    dominant gather bytes (§Perf iteration; grads stay fp32)."""
    def maybe_cast(params):
        if not bf16_params:
            return params
        return jax.tree.map(
            lambda p: p.astype(jnp.bfloat16)
            if p.dtype == jnp.float32 else p, params)

    if kind == "train":
        ocfg = AdamWConfig()

        def train_step(params, opt_state, batch, err):
            (loss, metrics), grads = jax.value_and_grad(
                lambda p: M.loss_fn(maybe_cast(p), cfg, batch, remat=remat),
                has_aux=True)(params)
            if compress == "topk":
                grads, err = gc.topk_compress(grads, err)
            elif compress == "int8":
                grads, err = gc.int8_compress(grads, err)
            params, opt_state, om = adamw_update(ocfg, params, grads,
                                                 opt_state)
            return params, opt_state, err, loss

        return train_step
    if kind == "prefill":
        def prefill_step(params, batch):
            return M.prefill(maybe_cast(params), cfg, batch)

        return prefill_step

    def decode_step(params, token, pos, caches):
        return M.decode_step(maybe_cast(params), cfg, token, pos, caches)

    return decode_step


def _reduced_cfgs(cfg):
    """Depth-reduced configs for the scan-undercount correction.

    Returns (base_cfg, [(seg_idx, n_periods_full, variant_cfg), ...]) where
    base has ONE period per segment and each variant adds one period to a
    single scanned segment. cost(variant) - cost(base) = one period's
    exact flops/bytes/collectives; the full-depth value is
    base + sum_seg (n_periods-1) * marginal_seg.
    """
    import dataclasses as dc
    from repro.models.transformer import layer_plan
    if cfg.encdec:
        base = dc.replace(cfg, n_encoder_layers=1, n_layers=1)
        return base, [
            (0, cfg.n_encoder_layers,
             dc.replace(cfg, n_encoder_layers=2, n_layers=1)),
            (1, cfg.n_layers,
             dc.replace(cfg, n_encoder_layers=1, n_layers=2)),
        ]
    plan = layer_plan(cfg)
    period_lens = [len(s["specs"]) for s in plan]
    n_dense = cfg.moe.n_dense_layers if cfg.moe else 0

    def build(periods):
        n_layers = sum(p * l for p, l in zip(periods, period_lens))
        new = dc.replace(cfg, n_layers=n_layers)
        if cfg.moe and n_dense:
            # dense prefix segment is segment 0
            nd = periods[0] * period_lens[0]
            new = dc.replace(new, moe=dc.replace(cfg.moe,
                                                 n_dense_layers=nd))
        return new

    base_periods = [1] * len(plan)
    base = build(base_periods)
    variants = []
    for i, seg in enumerate(plan):
        if seg["n_periods"] <= 1:
            continue                      # unrolled: counted exactly in base
        pp = list(base_periods)
        pp[i] += 1
        variants.append((i, seg["n_periods"], build(pp)))
    return base, variants


def _measure(cfg, kind, shape, mesh, *, remat, compress, seq_shard,
             cache_seq_shard, serve_params=False, bf16_params=False,
             int8_kv=False, want_hlo=True):
    """Lower+compile one config; return (compiled stats dict)."""
    spec = SHAPES[shape]
    ins = input_specs(cfg, shape, int8_kv=int8_kv)
    pshapes = M.model_param_shapes(cfg)
    if bf16_params:
        # STORED bf16 weights (f32 Adam moments stay). The cast-at-use
        # variant was measured and refuted (§Perf A1): XLA gathers f32
        # then converts, so gather/grad-reduction bytes only halve when
        # the stored dtype itself is bf16.
        pshapes = jax.tree.map(
            lambda l: jax.ShapeDtypeStruct(
                l.shape,
                jnp.bfloat16 if l.dtype == jnp.float32 else l.dtype),
            pshapes)
    pspecs = param_specs(pshapes, mesh, serve=serve_params)
    psh = named_sharding_tree(mesh, pspecs)
    step = build_step(cfg, kind, remat=remat, compress=compress,
                      bf16_params=bf16_params)
    t0 = time.time()
    with mesh:
        if kind == "train":
            oshapes = _opt_shapes(pshapes)
            ospecs = opt_state_specs(pshapes, mesh)
            bspecs = batch_specs(mesh, ins["batch"], seq_shard=False)
            esh = (named_sharding_tree(mesh, pspecs)
                   if compress != "none" else None)
            err_shapes = (jax.tree.map(
                lambda l: jax.ShapeDtypeStruct(l.shape, F32), pshapes)
                if compress != "none" else None)
            jitted = jax.jit(
                step,
                in_shardings=(psh, named_sharding_tree(mesh, ospecs),
                              named_sharding_tree(mesh, bspecs), esh),
                donate_argnums=(0, 1, 3))
            lowered = jitted.lower(pshapes, oshapes, ins["batch"],
                                   err_shapes)
        elif kind == "prefill":
            bspecs = batch_specs(mesh, ins["batch"], seq_shard=seq_shard)
            jitted = jax.jit(
                step, in_shardings=(psh, named_sharding_tree(mesh, bspecs)))
            lowered = jitted.lower(pshapes, ins["batch"])
        else:
            b = spec["global_batch"]
            cspecs = cache_specs(mesh, ins["caches"], b)
            if not cache_seq_shard:
                cspecs = jax.tree.map(
                    lambda s: P(*[a if a != "model" else None for a in s]),
                    cspecs, is_leaf=lambda x: isinstance(x, P))
            tok_spec = NamedSharding(mesh, batch_specs(
                mesh, {"t": ins["token"]})["t"])
            jitted = jax.jit(
                step,
                in_shardings=(psh, tok_spec, NamedSharding(mesh, P()),
                              named_sharding_tree(mesh, cspecs)),
                donate_argnums=(3,))
            lowered = jitted.lower(pshapes, ins["token"], ins["pos"],
                                   ins["caches"])
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    cost = cost if isinstance(cost, dict) else cost[0]
    coll = collective_bytes_from_hlo(compiled.as_text()) if want_hlo \
        else {"total": 0.0, "by_op": {}, "count": 0}
    return {"mem": mem, "cost": cost, "coll": coll,
            "t_lower": t_lower, "t_compile": t_compile}


def run_cell(arch: str, shape: str, *, multi_pod: bool, remat=True,
             compress="none", seq_shard=False, cache_seq_shard=True,
             serve_params=False, bf16_params=False, int8_kv=False,
             correct_scans=None, verbose=True):
    cfg = get_config(arch)
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.size
    spec = SHAPES[shape]
    kind = spec["kind"]
    ins = input_specs(cfg, shape)
    if correct_scans is None:
        correct_scans = not multi_pod     # roofline table is single-pod

    kw = dict(remat=remat, compress=compress, seq_shard=seq_shard,
              cache_seq_shard=cache_seq_shard, serve_params=serve_params,
              bf16_params=bf16_params, int8_kv=int8_kv)
    full = _measure(cfg, kind, shape, mesh, **kw)

    # --- scan-undercount correction (collectives; HLO counts scan bodies
    # once — verified empirically, see EXPERIMENTS.md §Methodology) --------
    coll_corrected = None
    if correct_scans:
        try:
            base_cfg, variants = _reduced_cfgs(cfg)
            base = _measure(base_cfg, kind, shape, mesh, **kw)
            total = base["coll"]["total"]
            for _, n_periods, vcfg in variants:
                var = _measure(vcfg, kind, shape, mesh, **kw)
                marginal = max(var["coll"]["total"]
                               - base["coll"]["total"], 0.0)
                total += (n_periods - 1) * marginal
            coll_corrected = total
        except Exception as e:  # noqa: BLE001 — correction is best-effort:
            #                     a failed re-measure must not lose the
            #                     uncorrected dry-run numbers
            coll_corrected = None
            if verbose:
                print("scan correction failed:", e)

    # --- analytic exact flops / streaming bytes ---------------------------
    from repro.roofline.analytic import (cell_flops_per_device,
                                         cell_hbm_bytes_per_device,
                                         decode_cache_bytes)
    pshapes = M.model_param_shapes(cfg)
    n_total = M.count_params(pshapes)
    n_active = M.active_params(cfg, n_total)
    an_flops = cell_flops_per_device(cfg, shape, n_chips, remat=remat)
    cache_b = (decode_cache_bytes(cfg, shape, int8_kv=int8_kv)
               if kind == "decode" else 0)
    an_bytes = cell_hbm_bytes_per_device(cfg, shape, n_chips, n_total,
                                         cache_b, remat=remat)
    coll_best = (coll_corrected if coll_corrected is not None
                 else full["coll"]["total"])
    roof = roofline_terms({"flops": an_flops, "bytes accessed": an_bytes},
                          {"total": coll_best})
    hlo_roof = roofline_terms(full["cost"], full["coll"])

    mf = model_flops(cfg, n_total, n_active, kind,
                     spec["seq_len"], spec["global_batch"])
    mem = full["mem"]
    record = {
        "arch": arch, "shape": shape, "kind": kind,
        "mesh": list(mesh.devices.shape), "chips": n_chips,
        "multi_pod": multi_pod,
        "remat": remat, "compress": compress, "seq_shard": seq_shard,
        "cache_seq_shard": cache_seq_shard,
        "serve_params": serve_params, "bf16_params": bf16_params,
        "int8_kv": int8_kv,
        "params_total": n_total, "params_active": n_active,
        "lower_s": round(full["t_lower"], 1),
        "compile_s": round(full["t_compile"], 1),
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "peak_per_device": (mem.argument_size_in_bytes
                                + mem.output_size_in_bytes
                                + mem.temp_size_in_bytes
                                - mem.alias_size_in_bytes),
        },
        "cost_hlo_raw": {"flops_per_dev": hlo_roof["flops_per_dev"],
                         "hbm_bytes_per_dev": hlo_roof["hbm_bytes_per_dev"],
                         "note": "scan bodies counted once by XLA"},
        "analytic": {"flops_per_dev": an_flops,
                     "hbm_bytes_per_dev": an_bytes,
                     "decode_cache_bytes_total": cache_b},
        "collectives": full["coll"],
        "collective_bytes_corrected": coll_corrected,
        "roofline": {k: roof[k] for k in
                     ("compute_s", "memory_s", "collective_s", "dominant",
                      "overlap_roofline_frac")},
        "roofline_hlo_raw": {k: hlo_roof[k] for k in
                             ("compute_s", "memory_s", "collective_s",
                              "dominant")},
        "model_flops_global": mf,
        "useful_flops_ratio": (mf / (an_flops * n_chips)
                               if an_flops else 0.0),
    }
    if verbose:
        print(json.dumps(record, indent=1))
    return record


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--remat", default="full", choices=["full", "none"])
    ap.add_argument("--compress", default="none",
                    choices=["none", "int8", "topk"])
    ap.add_argument("--seq-shard", action="store_true")
    ap.add_argument("--no-cache-seq-shard", action="store_true")
    ap.add_argument("--serve-params", action="store_true",
                    help="TP-only weights (no FSDP) for serve steps")
    ap.add_argument("--bf16-params", action="store_true",
                    help="cast weights to bf16 before use (halves gathers)")
    ap.add_argument("--int8-kv", action="store_true",
                    help="int8 KV cache with per-slot scales (decode)")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--tag", default="")
    args = ap.parse_args(argv)

    os.makedirs(args.out, exist_ok=True)

    if args.all:
        meshes = [False, True] if args.both_meshes else [args.multi_pod]
        failures = []
        for mp in meshes:
            for arch in ARCH_IDS:
                for shape in SHAPES:
                    if not cell_supported(arch, shape):
                        _write(args.out, arch, shape, mp, args.tag,
                               {"arch": arch, "shape": shape,
                                "multi_pod": mp, "skipped":
                                "full-attention arch at 500k decode"})
                        continue
                    name = _cell_name(arch, shape, mp, args.tag)
                    path = os.path.join(args.out, name + ".json")
                    if args.skip_existing and os.path.exists(path):
                        print("skip", name)
                        continue
                    cmd = [sys.executable, "-m", "repro.launch.dryrun",
                           "--arch", arch, "--shape", shape,
                           "--out", args.out, "--tag", args.tag,
                           "--remat", args.remat,
                           "--compress", args.compress]
                    if mp:
                        cmd.append("--multi-pod")
                    if args.seq_shard:
                        cmd.append("--seq-shard")
                    if args.no_cache_seq_shard:
                        cmd.append("--no-cache-seq-shard")
                    print(">>", name, flush=True)
                    r = subprocess.run(cmd, capture_output=True, text=True)
                    if r.returncode != 0:
                        failures.append(name)
                        print("FAIL", name, "\n", r.stdout[-2000:],
                              r.stderr[-4000:], flush=True)
                    else:
                        print(r.stdout.strip().splitlines()[-1], flush=True)
        print(f"\ndry-run sweep done; {len(failures)} failures")
        for f in failures:
            print("  FAILED:", f)
        sys.exit(1 if failures else 0)

    record = run_cell(args.arch, args.shape, multi_pod=args.multi_pod,
                      remat=args.remat == "full", compress=args.compress,
                      seq_shard=args.seq_shard,
                      cache_seq_shard=not args.no_cache_seq_shard,
                      serve_params=args.serve_params,
                      bf16_params=args.bf16_params,
                      int8_kv=args.int8_kv,
                      verbose=False)
    _write(args.out, args.arch, args.shape, args.multi_pod, args.tag, record)
    roof = record.get("roofline", {})
    print(json.dumps({
        "cell": _cell_name(args.arch, args.shape, args.multi_pod, args.tag),
        "peak_bytes_per_dev": record["memory"]["peak_per_device"],
        "dominant": roof.get("dominant"),
        "compute_s": round(roof.get("compute_s", 0), 6),
        "memory_s": round(roof.get("memory_s", 0), 6),
        "collective_s": round(roof.get("collective_s", 0), 6),
        "compile_s": record["compile_s"]}))


def _cell_name(arch, shape, multi_pod, tag):
    mesh = "2x16x16" if multi_pod else "16x16"
    return f"{arch}__{shape}__{mesh}" + (f"__{tag}" if tag else "")


def _write(out, arch, shape, multi_pod, tag, record):
    path = os.path.join(out, _cell_name(arch, shape, multi_pod, tag) + ".json")
    with open(path, "w") as f:
        json.dump(record, f, indent=1)


if __name__ == "__main__":
    main()
