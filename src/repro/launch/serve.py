"""Serving launcher: hybrid IIsy switch tier + LM/ensemble backend.

``python -m repro.launch.serve --use-case anomaly --threshold 0.7``
trains the small switch model + large backend on the synthetic use-case
data, stands up the HybridServer, runs batched requests through it, and
prints the paper's telemetry (fraction handled, misclassification).

``--backend lm`` scores forwarded requests with a (smoke-sized) LM
backend instead of the full ensemble — the integration path where the
low-confidence subset is re-encoded as tokens for an LM scorer.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.mapping import map_tree_ensemble
from repro.kernels.ops import fused_classify
from repro.ml.metrics import accuracy, precision_recall_f1
from repro.ml.trees import (fit_random_forest, fit_xgboost,
                            predict_margin_xgboost, predict_tree_ensemble)
from repro.serving.hybrid_serving import HybridServer


def build_usecase(name: str, n=20000, seed=0):
    if name == "anomaly":
        from repro.data.unsw_like import make_unsw_like, train_test_split
        x, y = make_unsw_like(n, seed=seed, n_features=5)
        return train_test_split(x, y)
    from repro.data.janestreet_like import (SWITCH_FEATURES,
                                            make_janestreet_like,
                                            train_test_split)
    x, y = make_janestreet_like(n, seed=seed)
    return train_test_split(x, y)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--use-case", default="anomaly",
                    choices=["anomaly", "finance"])
    ap.add_argument("--threshold", type=float, default=0.7)
    ap.add_argument("--capacity", type=int, default=1024)
    ap.add_argument("--switch-trees", type=int, default=10)
    ap.add_argument("--switch-depth", type=int, default=5)
    ap.add_argument("--backend", default="ensemble",
                    choices=["ensemble", "lm"])
    ap.add_argument("--batch", type=int, default=2048)
    args = ap.parse_args(argv)

    xtr, ytr, xte, yte = build_usecase(args.use_case)
    if args.use_case == "finance":
        from repro.data.janestreet_like import SWITCH_FEATURES
        xsw_tr, xsw_te = xtr[:, SWITCH_FEATURES], xte[:, SWITCH_FEATURES]
    else:
        xsw_tr, xsw_te = xtr, xte

    # small switch model (paper Table 3 "Medium") + big backend
    small = fit_random_forest(xsw_tr, ytr, n_classes=2,
                              n_trees=args.switch_trees,
                              max_depth=args.switch_depth, seed=0)
    art = map_tree_ensemble(small, xsw_tr.shape[1])

    if args.backend == "ensemble":
        big = fit_xgboost(xtr, ytr, n_trees=60, max_depth=6)
        full_dim = xtr.shape[1]

        def backend_fn(rows_sw):
            # the backend sees the full feature vector; look rows up by
            # matching switch features is not possible -> in serving the
            # forwarded request carries its full payload. Here we emulate
            # by an index side-channel set per batch (see loop below).
            idx = backend_fn.idx
            margins = predict_margin_xgboost(big, backend_fn.full_rows[idx])
            return (margins > 0).astype(jnp.int32)
    else:
        from repro.configs import get_smoke_config
        from repro.models import model as M
        cfg = get_smoke_config("qwen3-4b")
        params = M.init_model(cfg, jax.random.PRNGKey(0))

        def backend_fn(rows_sw):
            # encode each forwarded row as a token sequence (feature
            # binning as tokens) and read class from the last logit sign
            toks = (jnp.abs(rows_sw[:, :8]) * 7).astype(jnp.int32) % cfg.vocab_size
            toks = jnp.pad(toks, ((0, 0), (0, max(0, 8 - toks.shape[1]))))
            logits, _ = M.prefill(params, cfg, {"tokens": toks})
            return (logits[:, 0] > logits[:, 1]).astype(jnp.int32)

    # the ensemble backend reads per-batch side-channels (idx/full_rows on
    # the function object): it must not be traced into the fused step
    server = HybridServer(art, backend_fn, threshold=args.threshold,
                          capacity=args.capacity,
                          fuse=False if args.backend == "ensemble" else None)

    n = xsw_te.shape[0]
    preds = []
    t0 = time.time()
    for lo in range(0, n - args.batch + 1, args.batch):
        rows = xsw_te[lo:lo + args.batch]
        if args.backend == "ensemble":
            backend_fn.full_rows = jnp.asarray(xte[lo:lo + args.batch])
            # dispatch indices are produced inside classify; recompute here
            # with the SAME switch realization the server uses
            # (use_pallas=False default) so idx matches bit for bit —
            # a different kernel path could order the dispatch differently
            # and silently score the wrong full-feature rows
            sw_pred, conf = fused_classify(art, rows, use_pallas=False)
            from repro.core.hybrid import dispatch
            fwd = conf < args.threshold
            buf, idx, valid = dispatch(jnp.asarray(rows, jnp.float32), fwd,
                                       args.capacity)
            backend_fn.idx = idx
        pred, stats = server.classify(rows)
        preds.append(np.asarray(pred))
    pred = np.concatenate(preds)
    m = len(pred)
    acc = accuracy(yte[:m], pred)
    p, r, f1 = precision_recall_f1(yte[:m], pred)
    print(f"use_case={args.use_case} backend={args.backend} "
          f"tau={args.threshold}")
    print(f"acc={acc:.4f} precision={p:.4f} recall={r:.4f} f1={f1:.4f}")
    print(f"handled_at_switch={stats.fraction_handled:.3f} "
          f"backend_rows/batch={stats.backend_rows}/{args.batch} "
          f"wall={time.time()-t0:.1f}s")


if __name__ == "__main__":
    main()
