"""Input ShapeDtypeStruct stand-ins for every (arch x shape) dry-run cell.

Shapes (LM family, per the assignment):
  train_4k     seq 4,096   global_batch 256   -> train_step
  prefill_32k  seq 32,768  global_batch 32    -> prefill
  decode_32k   seq 32,768 (KV), batch 128     -> serve (decode) step
  long_500k    seq 524,288 (KV), batch 1      -> decode; sub-quadratic only

long_500k applicability: requires O(1)-or-windowed per-token state —
xlstm-1.3b (recurrent), h2o-danube-1.8b (SWA ring), recurrentgemma-2b
(RG-LRU + local window). Pure full-attention archs skip it (recorded).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import model as M

I32 = jnp.int32
F32 = jnp.float32
BF16 = jnp.bfloat16

SHAPES = {
    "train_4k": dict(kind="train", seq_len=4096, global_batch=256),
    "prefill_32k": dict(kind="prefill", seq_len=32768, global_batch=32),
    "decode_32k": dict(kind="decode", seq_len=32768, global_batch=128),
    "long_500k": dict(kind="decode", seq_len=524288, global_batch=1),
}

LONG_OK = {"xlstm-1.3b", "h2o-danube-1.8b", "recurrentgemma-2b"}


def cell_supported(arch_id: str, shape_name: str, cfg=None) -> bool:
    if shape_name == "long_500k":
        return arch_id in LONG_OK
    return True


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def _frontend_extras(cfg, batch):
    extras = {}
    if cfg.encdec:
        extras["frames"] = _sds(
            (batch, cfg.n_frontend_tokens, cfg.frontend_dim), F32)
    if cfg.frontend == "image_patches":
        extras["patch_embeds"] = _sds(
            (batch, cfg.n_frontend_tokens, cfg.frontend_dim), F32)
    return extras


def input_specs(cfg, shape_name: str, *, int8_kv: bool = False):
    """-> dict of ShapeDtypeStruct args for the cell's step function.

    train:   {"batch": {tokens, labels, extras...}}
    prefill: {"batch": {tokens, extras...}}
    decode:  {"token": (B,), "pos": scalar, "caches": cache shapes}
    """
    spec = SHAPES[shape_name]
    b, s = spec["global_batch"], spec["seq_len"]
    if spec["kind"] == "train":
        batch = {"tokens": _sds((b, s), I32), "labels": _sds((b, s), I32)}
        batch.update(_frontend_extras(cfg, b))
        return {"batch": batch}
    if spec["kind"] == "prefill":
        batch = {"tokens": _sds((b, s), I32)}
        batch.update(_frontend_extras(cfg, b))
        return {"batch": batch}
    # decode: one new token against a seq_len-deep cache
    cache_shapes = jax.eval_shape(
        lambda: M.init_decode_cache(cfg, b, s, dtype=BF16,
                                    quantize_kv=int8_kv))
    return {"token": _sds((b,), I32),
            "pos": _sds((), I32),
            "caches": cache_shapes}
