"""Training launcher: ``python -m repro.launch.train --arch <id> [...]``.

Runs the real training loop (repro.training.loop) on whatever devices
exist. On this CPU container use --smoke for the reduced config; the full
config + production mesh path is exercised by the dry-run (launch.dryrun),
which lowers the *same* step function.
"""

from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.models import model as M
from repro.training.loop import TrainConfig, train
from repro.training.optim import AdamWConfig


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-sized)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--compress", default="none",
                    choices=["none", "topk", "int8"])
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    tcfg = TrainConfig(
        steps=args.steps, seq_len=args.seq_len,
        global_batch=args.global_batch, microbatches=args.microbatches,
        opt=AdamWConfig(lr_peak=args.lr, warmup_steps=max(args.steps // 20, 5),
                        total_steps=args.steps),
        grad_compress=args.compress, ckpt_dir=args.ckpt_dir)

    extra = {}
    if cfg.encdec:
        extra["frames"] = jnp.zeros(
            (args.global_batch // max(args.microbatches, 1),
             cfg.n_frontend_tokens, cfg.frontend_dim), jnp.float32)
    if cfg.frontend == "image_patches":
        extra["patch_embeds"] = jnp.zeros(
            (args.global_batch // max(args.microbatches, 1),
             cfg.n_frontend_tokens, cfg.frontend_dim), jnp.float32)

    params, history = train(cfg, tcfg, extra_batch=extra or None)
    print(f"final loss: {history[-1]['loss_total']:.4f} "
          f"({len(history)} steps)")


if __name__ == "__main__":
    main()
