"""Distribution: mesh-aware sharding rules, specs for params/batches/caches."""

from repro.distributed.sharding import (
    param_specs,
    batch_specs,
    cache_specs,
    opt_state_specs,
    named_sharding_tree,
    flow_shard_mesh,
    flow_table_sharding,
)
