"""Sharding rules: param / batch / cache PartitionSpecs for the production
mesh.

Axes:
  pod    (multi-pod only)  pure data parallelism across pods; params are
                           replicated across pods, gradients all-reduce
                           over ('pod','data').
  data   FSDP: batch parallelism + ZeRO-3 parameter/optimizer sharding
         (weights shard their *input* dim over 'data'; XLA all-gathers
         them per layer and the backward reduce-scatters — classic FSDP
         realized through GSPMD annotations).
  model  tensor parallelism (attention heads / FFN columns / vocab) and
         expert parallelism (MoE expert dim).

Rules are name+shape driven: special-cases for embed / lm_head / expert
stacks / routers, then a generic "last dim -> model, second-to-last ->
data" for 2D+ weights, with divisibility checks (a dim that doesn't
divide stays replicated). 1D leaves (norms, biases) replicate.

Batch specs: tokens/labels shard over ('pod','data') on batch; decode
caches shard batch over data and heads (or sequence, when heads don't
divide) over model.
"""

from __future__ import annotations

from typing import Any, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def _axis(mesh: Mesh, name: str) -> int:
    return mesh.shape[name] if name in mesh.shape else 1


def _div(n: int, k: int) -> bool:
    return k > 0 and n % k == 0


def _path_str(path) -> str:
    return "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                    for k in path)


def _leading_nones(shape, n_tail):
    return (None,) * (len(shape) - n_tail)


def spec_for_param(path: str, shape, mesh: Mesh, *, serve: bool = False) -> P:
    """serve=False (train): FSDP x TP — weights shard input dim over 'data'
    (ZeRO-3 gather per layer) and output dim over 'model' (TP).
    serve=True: TP only — weights replicate over 'data' so a decode step
    never pays the per-layer FSDP all-gather (weights are read-only and
    batch-per-device is tiny; the gather would dominate the step)."""
    d_sz = _axis(mesh, "data")
    m_sz = _axis(mesh, "model")
    nd = len(shape)
    data_ax = None if serve else "data"

    # --- special cases ------------------------------------------------------
    if path.endswith("embed"):                       # (V, D): vocab -> model
        v, d = shape
        return P("model" if _div(v, m_sz) else None,
                 data_ax if (data_ax and _div(d, d_sz)) else None)
    if path.endswith("lm_head"):                     # (D, V)
        d, v = shape
        return P(data_ax if (data_ax and _div(d, d_sz)) else None,
                 "model" if _div(v, m_sz) else None)
    leaf = path.rsplit("/", 1)[-1]
    if leaf in ("w_gate", "w_up", "w_down") and nd >= 3:
        # expert stacks (..., E, D, F) / (..., E, F, D): experts -> model (EP)
        e, a, b = shape[-3:]
        return P(*_leading_nones(shape, 3),
                 "model" if _div(e, m_sz) else None,
                 data_ax if (data_ax and _div(a, d_sz)) else None,
                 None)
    if leaf in ("wq", "wk", "wv") and nd >= 3 and shape[-1] == shape[-2]:
        # per-head block-diagonal stacks (..., H, hd, hd): heads -> model
        h = shape[-3]
        return P(*_leading_nones(shape, 3),
                 "model" if _div(h, m_sz) else None, None, None)

    # --- generic ------------------------------------------------------------
    if nd >= 2:
        a, b = shape[-2], shape[-1]
        return P(*_leading_nones(shape, 2),
                 data_ax if (data_ax and _div(a, d_sz)) else None,
                 "model" if _div(b, m_sz) else None)
    return P()                                        # 1D: replicate


def param_specs(params_or_shapes, mesh: Mesh, *, serve: bool = False):
    """PartitionSpec tree matching the param tree."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params_or_shapes)
    specs = [spec_for_param(_path_str(p), l.shape, mesh, serve=serve)
             for p, l in flat]
    return jax.tree.unflatten(treedef, specs)


def opt_state_specs(params_or_shapes, mesh: Mesh):
    """Adam m/v mirror the param sharding; step is replicated."""
    ps = param_specs(params_or_shapes, mesh)
    return {"m": ps, "v": ps, "step": P()}


# ---------------------------------------------------------------------------
# batch / activation specs
# ---------------------------------------------------------------------------

def _batch_axes(mesh: Mesh):
    return ("pod", "data") if "pod" in mesh.shape else ("data",)


def batch_specs(mesh: Mesh, batch_shapes: dict, *, seq_shard: bool = False):
    """Specs for a train/prefill batch dict. Batch dim -> (pod, data) when
    divisible; optionally shard sequence over 'model' (SP for long prefill)."""
    baxes = _batch_axes(mesh)
    bsz = int(np.prod([_axis(mesh, a) for a in baxes]))
    m_sz = _axis(mesh, "model")

    def one(leaf):
        shape = leaf.shape
        b = shape[0]
        first = baxes if _div(b, bsz) else (
            "data" if _div(b, _axis(mesh, "data")) else None)
        rest = [None] * (len(shape) - 1)
        if seq_shard and len(shape) >= 2 and _div(shape[1], m_sz):
            rest[0] = "model"
        return P(first, *rest)

    return jax.tree.map(one, batch_shapes)


def cache_specs(mesh: Mesh, cache_shapes, batch: int):
    """Decode-cache specs.

    The batch dim is identified *by size* (the serving batch is known),
    never by position — scan-stacked segment caches carry a leading
    period dim. Rules: batch -> 'data' when divisible; then the largest
    remaining divisible dim (sequence for KV rings, state width for
    recurrent states) -> 'model' (context parallelism for decode)."""
    d_sz = _axis(mesh, "data")
    m_sz = _axis(mesh, "model")

    def one(leaf):
        shape = leaf.shape
        nd = len(shape)
        spec = [None] * nd
        bdim = None
        if batch > 1:
            for i, s in enumerate(shape):
                if s == batch:
                    bdim = i
                    break
        if bdim is not None and _div(shape[bdim], d_sz):
            spec[bdim] = "data"
        cand = [i for i in range(nd) if i != bdim and spec[i] is None
                and _div(shape[i], m_sz) and shape[i] >= m_sz]
        if cand:
            best = max(cand, key=lambda i: shape[i])
            spec[best] = "model"
        return P(*spec)

    return jax.tree.map(one, cache_shapes)


def named_sharding_tree(mesh: Mesh, spec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------------------
# flow-table shard mesh (streaming tier)
# ---------------------------------------------------------------------------

def flow_shard_mesh(n_shards: Optional[int] = None,
                    n_data: int = 1) -> Mesh:
    """2D ('shard', 'data') mesh for the sharded flow-table tier.

    'shard' partitions flow-table *buckets* (storage: each shard owns
    bucket % n_shards == s); 'data' is pure batch parallelism over the
    partitioned classify lanes and the backend slices — registers are
    replicated along it (DESIGN.md §16). ``n_data=1`` (the default)
    degenerates to the historical 1D behavior; ``n_shards`` defaults to
    every local device not consumed by 'data' — on a CPU host-platform
    run that is whatever ``--xla_force_host_platform_device_count``
    provided. The flow-table axes are deliberately separate from the
    ('data','model') training axes above: bucket shards are storage
    partitions, not tensor parallelism.
    """
    n = n_shards or max(1, jax.local_device_count() // n_data)
    return jax.make_mesh((n, n_data), ("shard", "data"))


def as_flow_mesh(mesh: Mesh) -> Mesh:
    """Normalize a flow-table mesh to the 2D ('shard', 'data') form.

    A legacy 1D ('shard',) mesh gains a size-1 'data' axis (same
    devices, same shard blocks), so every shard_map body can reference
    both axes unconditionally; a 2D ('shard', 'data') mesh passes
    through. Anything else is not a flow-table mesh.
    """
    if mesh.axis_names == ("shard", "data"):
        return mesh
    if mesh.axis_names == ("shard",):
        return Mesh(mesh.devices.reshape(-1, 1), ("shard", "data"))
    raise ValueError(
        f"flow-table mesh must have axes ('shard',) or ('shard', 'data'), "
        f"got {mesh.axis_names}")


def flow_table_sharding(mesh: Mesh, state_tree):
    """NamedSharding tree placing a sharded flow-table pytree on ``mesh``.

    Every leaf shards its leading (n_shards) dim over 'shard' and
    replicates the rest — registers are (n_shards, n_local), the epoch
    register is (n_shards,); both derive from ndim, so the rule survives
    new registers being added to the state. On a 2D ('shard', 'data')
    mesh the registers replicate along 'data' (the data axis parallelizes
    classify lanes and backend slices, never storage).
    """
    spec = jax.tree.map(
        lambda a: P("shard", *([None] * (a.ndim - 1))), state_tree)
    return named_sharding_tree(mesh, spec)


def shard_hint(x, *spec):
    """Best-effort with_sharding_constraint: a no-op when traced outside a
    mesh context (single-device tests), a GSPMD hint inside one (dry-run /
    launcher). Keeps model code mesh-agnostic."""
    try:
        return jax.lax.with_sharding_constraint(x, P(*spec))
    except (RuntimeError, ValueError):
        # RuntimeError: no ambient mesh (single-device tests);
        # ValueError: spec rank does not divide this shape — both mean
        # "no hint applies here", never a real serving failure
        return x


def _ambient_mesh():
    try:
        from jax.interpreters.pxla import thread_resources
        m = thread_resources.env.physical_mesh
        return m if m.devices.size > 1 else None
    except (ImportError, AttributeError):
        # private jax internals moved — treat as "no ambient mesh"
        return None


def hint_batch_heads(x, heads_dim: int = 2):
    """Pin an activation (B, S, H, hd)-like tensor: batch over the batch
    axes, heads over 'model' (when divisible). No-op without a mesh.

    This is the anti-"involuntary full remat" hint: it keeps q/k/v in the
    head-sharded layout through the blockwise attention scan, so GSPMD
    never invents a batch<->head resharding mid-loop."""
    m = _ambient_mesh()
    if m is None:
        return x
    baxes = ("pod", "data") if "pod" in m.axis_names else ("data",)
    bsz = int(np.prod([m.shape[a] for a in baxes]))
    spec = [None] * x.ndim
    if x.shape[0] % bsz == 0:
        spec[0] = baxes
    elif x.shape[0] % m.shape["data"] == 0:
        spec[0] = "data"
    if heads_dim < x.ndim and x.shape[heads_dim] % m.shape["model"] == 0:
        spec[heads_dim] = "model"
    return shard_hint(x, *spec)
