"""Streaming hybrid serving: the always-on switch, one window at a time.

``StreamingHybridServer`` extends the zero-sync ``HybridServer`` with the
register-file carry of ``netsim.stream``: each ``step(window)`` is ONE
jitted, buffer-donating device dispatch that fuses

  register update        (segment-scatter into the donated FlowTableState)
  feature read-out       (gather the updated table rows for the window's
                          touched flows — per-packet, as a switch
                          classifies each arriving packet with its flow's
                          registers)
  fused switch classify  (the single-matmul kernel pipeline)
  capacity-bounded dispatch -> backend -> combine
  telemetry accumulation (StreamStats carried as donated device arrays)

Nothing in ``step`` touches the host: state and running statistics are
device arrays donated back in, per-window telemetry returns as a lazy
``HybridStats``, and predictions stay on device until the caller reads
them. Donation discipline (also DESIGN.md §5): the register file and the
stats carry are consumed every step and replaced by the returned pytrees —
callers must never hold a reference to a previous state.

Backends that cannot trace fall back to the same two-phase shape as
``HybridServer``: jitted update+switch+dispatch (still donating state),
host backend call, jitted combine+stats (donating the stats carry).

Cross-window backend batching (DESIGN.md §7): ``flush_every=k`` defers
the dispatched low-confidence rows of up to k windows into a donated
``core.hybrid.DeferredDispatch`` buffer and runs the backend once per
flush at k-times the occupancy; the answers back-patch the per-window
pending prediction set at their recorded (window, lane) return
addresses. ``flush_every=1`` (default) is the unchanged per-window path
— the equivalence oracle; final predictions are bit-identical either
way for row-wise backends.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.artifact import TableArtifact
from repro.core.hybrid import (DeferredDispatch, backpatch_pending, combine,
                               defer_window, dispatch, init_deferred)
from repro.kernels.ops import fused_classify
from repro.kernels.tuning import TileConfig
from repro.netsim.stream import (FLOW_FEATURES, FlowTableState, PacketWindow,
                                 flow_table_readout, init_flow_table,
                                 iter_windows, lifecycle_sweep,
                                 update_flow_table)
from repro.serving.hybrid_serving import HybridServer, HybridStats


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class StreamStats:
    """Running telemetry over all windows served — scalar device arrays.

    Constructed and updated entirely on device (the carry is donated into
    every step); reading any python-typed property below is the only point
    that syncs, mirroring HybridStats' laziness.
    """
    windows: jax.Array        # i32: windows served
    packets: jax.Array        # i32: valid packets seen
    handled: jax.Array        # i32: answered at the switch tier
    backend_rows: jax.Array   # i32: rows the backend actually served
    deferred: jax.Array       # i32: low-confidence rows past capacity that
                              #      never reached the backend (switch
                              #      answer kept — was silent before)
    flushes: jax.Array        # i32: backend invocations (one per flush;
                              #      == windows when flush_every == 1)
    evicted: jax.Array        # i32: buckets recycled by the aging sweep
    overflow: jax.Array       # i32: register slots newly saturated at 2^24

    @classmethod
    def zero(cls) -> "StreamStats":
        z = lambda: jnp.zeros((), jnp.int32)
        return cls(windows=z(), packets=z(), handled=z(), backend_rows=z(),
                   deferred=z(), flushes=z(), evicted=z(), overflow=z())

    @property
    def n_windows(self) -> int:
        return int(self.windows)

    @property
    def n_packets(self) -> int:
        return int(self.packets)

    @property
    def n_handled(self) -> int:
        """Packets answered confidently at the switch tier."""
        return int(self.handled)

    @property
    def fraction_handled(self) -> float:
        n = int(self.packets)
        return float(self.handled) / n if n else 0.0

    @property
    def total_backend_rows(self) -> int:
        return int(self.backend_rows)

    @property
    def n_deferred(self) -> int:
        """Low-confidence rows that overflowed the dispatch capacity and
        kept the (low-confidence) switch answer. Nonzero means the stream
        wants a larger ``capacity`` or a larger ``flush_every`` — visible
        accounting for what used to be a silent drop. After the final
        flush, ``handled + backend_rows + deferred == packets``."""
        return int(self.deferred)

    @property
    def n_flushes(self) -> int:
        """Backend invocations so far: one per window at flush_every=1,
        one per ``flush_every`` windows (plus the end-of-trace flush)
        under cross-window batching."""
        return int(self.flushes)

    @property
    def n_evicted(self) -> int:
        """Buckets recycled by the aging sweep (0 when eviction is off)."""
        return int(self.evicted)

    @property
    def n_overflow(self) -> int:
        """Register slots that hit the 2^24 exactness envelope; nonzero
        means count features saturated and the stream needs eviction (or
        more buckets) — the guard makes that visible, not silent."""
        return int(self.overflow)

    def __repr__(self):
        return (f"StreamStats(windows={self.n_windows}, "
                f"packets={self.n_packets}, "
                f"fraction_handled={self.fraction_handled:.3f}, "
                f"backend_rows={self.total_backend_rows}, "
                f"deferred={self.n_deferred}, flushes={self.n_flushes}, "
                f"evicted={self.n_evicted}, overflow={self.n_overflow})")


def accumulate_stream_stats(stats: StreamStats, w: PacketWindow, sw_pred,
                            be_pred, idx, valid, fwd, n_evicted, n_overflow):
    """Shared jit-traceable epilogue: combine backend answers, mask pad
    lanes, fold this window into the running StreamStats. Used by both the
    single-device and the sharded step (the sharded one passes psummed
    inputs — already replicated, so the fold is identical per device).
    The backend ran for this window, so ``flushes`` advances by one;
    forwarded rows past capacity land in ``deferred`` instead of silently
    keeping the switch answer uncounted.
    Returns (stats, pred, frac_handled, backend_rows)."""
    pred = combine(sw_pred, be_pred, idx, valid)
    pred = jnp.where(w.valid, pred, -1)                  # pad lanes
    n_valid = jnp.sum(w.valid.astype(jnp.int32))
    n_handled = jnp.sum((w.valid & ~fwd).astype(jnp.int32))
    n_fwd = jnp.sum(fwd.astype(jnp.int32))
    rows = jnp.sum(valid.astype(jnp.int32))
    frac = (n_handled.astype(jnp.float32)
            / jnp.maximum(n_valid, 1).astype(jnp.float32))
    stats = StreamStats(windows=stats.windows + 1,
                        packets=stats.packets + n_valid,
                        handled=stats.handled + n_handled,
                        backend_rows=stats.backend_rows + rows,
                        deferred=stats.deferred + (n_fwd - rows),
                        flushes=stats.flushes + 1,
                        evicted=stats.evicted + n_evicted,
                        overflow=stats.overflow + n_overflow)
    return stats, pred, frac, rows


def accumulate_deferred_stats(stats: StreamStats, w: PacketWindow, fwd,
                              valid, n_evicted, n_overflow):
    """Per-window stats fold for the deferred-dispatch path: everything
    *except* the backend accounting, which folds at flush time
    (``fold_flush_stats``) when the backend actually runs.
    Returns (stats, frac_handled, rows_deferred_this_window)."""
    n_valid = jnp.sum(w.valid.astype(jnp.int32))
    n_handled = jnp.sum((w.valid & ~fwd).astype(jnp.int32))
    n_fwd = jnp.sum(fwd.astype(jnp.int32))
    rows = jnp.sum(valid.astype(jnp.int32))
    frac = (n_handled.astype(jnp.float32)
            / jnp.maximum(n_valid, 1).astype(jnp.float32))
    stats = dataclasses.replace(
        stats, windows=stats.windows + 1, packets=stats.packets + n_valid,
        handled=stats.handled + n_handled,
        deferred=stats.deferred + (n_fwd - rows),
        evicted=stats.evicted + n_evicted,
        overflow=stats.overflow + n_overflow)
    return stats, frac, rows


def fold_flush_stats(stats: StreamStats, dd: DeferredDispatch) -> StreamStats:
    """One backend flush served every live slot of the deferral buffer."""
    rows = jnp.sum(dd.valid.astype(jnp.int32))
    return dataclasses.replace(stats, backend_rows=stats.backend_rows + rows,
                               flushes=stats.flushes + 1)


def defer_tail(stats, dd, pending, w: PacketWindow, sw_pred, fwd, buf, idx,
               valid, counts, pos):
    """Shared tail of the deferred-path window step (single-device and
    sharded): mask pad lanes, append the dispatched rows to the deferral
    buffer at cycle slot ``pos``, record the provisional predictions in
    the pending set, fold the non-backend stats.
    Returns (stats, dd, pending, pred, frac, rows)."""
    pred = jnp.where(w.valid, sw_pred, -1)                   # pad lanes
    dd = defer_window(dd, buf, idx, valid, pos)
    pending = pending.at[pos].set(pred)
    stats, frac, rows = accumulate_deferred_stats(stats, w, fwd, valid,
                                                  *counts)
    return stats, dd, pending, pred, frac, rows


class StreamingHybridServer(HybridServer):
    """HybridServer over a packet stream with per-flow register state.

    window is the static packet chunk size (the compiled step shape);
    n_buckets sizes the flow register file. The batch ``classify`` of the
    parent stays available (tests use it as the one-shot oracle).
    """

    def __init__(self, artifact: TableArtifact, backend_fn: Callable, *,
                 n_buckets: int = 4096, window: int = 512,
                 threshold: float = 0.7, capacity: int = 64,
                 flush_every: int = 1,
                 evict_age: Optional[float] = None, saturate: bool = True,
                 use_pallas: bool = False, autotune: bool = False,
                 tiles: Optional[TileConfig] = None,
                 fuse: Optional[bool] = None):
        """evict_age: recycle a flow bucket once it has been idle for this
        many (rebased) seconds — the aging sweep runs inside every step
        (``netsim.stream.lifecycle_sweep``) with its cutoff clamped to the
        window's oldest timestamp, so a flow seen in this window survives
        it by construction even when the window spans more than
        evict_age. None disables eviction (bit-exact contract with the
        batch path). saturate keeps the 2^24 overflow
        guard on; clamping is a bitwise no-op below the envelope, so it
        only changes behavior for streams that were already silently
        inexact — now counted in StreamStats.overflow instead.

        flush_every: defer the backend across this many windows
        (DESIGN.md §7). 1 (default) keeps today's one-backend-call-per-
        window behavior bit for bit — the equivalence oracle. k > 1
        accumulates the dispatched low-confidence rows of up to k windows
        in a donated ``DeferredDispatch`` buffer and runs the backend
        once per flush at k-times the occupancy; ``step`` then returns
        *provisional* (switch-tier) predictions and the backend answers
        are back-patched into the pending windows at flush
        (``serve_trace`` consumes the patches and always ends with a
        guaranteed flush, so its predictions are final). Deferred rows'
        features are the register readout of their own window, so final
        predictions match flush_every=1 for any row-wise backend.
        """
        if flush_every < 1:
            raise ValueError(f"flush_every must be >= 1, got {flush_every}")
        super().__init__(artifact, backend_fn, threshold=threshold,
                         capacity=capacity, use_pallas=use_pallas,
                         autotune=autotune, tiles=tiles, fuse=fuse)
        self.n_buckets = n_buckets
        self.window = window
        self.flush_every = flush_every
        self.evict_age = evict_age
        self.saturate = saturate
        self._state = self._make_state()
        self._stats = StreamStats.zero()
        self._reset_deferred()

        def _switch_half(art, state, w: PacketWindow, threshold):
            """update registers -> aging sweep -> overflow guard -> read
            out touched flows -> classify -> dispatch; shared by the fused
            and two-phase paths."""
            prev = state              # pre-update registers: the overflow
            state = update_flow_table(state, w)   # guard counts only newly
            state, n_ev, n_ov = lifecycle_sweep(state, w, evict_age,
                                                saturate, prev=prev)
            x = flow_table_readout(state, w.bucket)          # (W, 8)
            sw_pred, conf = fused_classify(art, x, use_pallas=use_pallas,
                                           tiles=self.tiles)
            fwd = (conf < threshold) & w.valid
            buf, idx, valid = dispatch(x, fwd, capacity)
            return state, x, sw_pred, fwd, buf, idx, valid, (n_ev, n_ov)

        def stream_step(art, state, stats, w: PacketWindow, threshold):
            state, x, sw_pred, fwd, buf, idx, valid, counts = _switch_half(
                art, state, w, threshold)
            be_pred = jnp.asarray(backend_fn(buf))
            stats, pred, frac, rows = accumulate_stream_stats(
                stats, w, sw_pred, be_pred, idx, valid, fwd, *counts)
            return state, stats, pred, frac, rows

        self._stream_step = jax.jit(stream_step, donate_argnums=(1, 2))

        def stream_switch(art, state, w: PacketWindow, threshold):
            state, x, sw_pred, fwd, buf, idx, valid, counts = _switch_half(
                art, state, w, threshold)
            return state, sw_pred, fwd, buf, idx, valid, counts

        self._stream_switch = jax.jit(stream_switch, donate_argnums=(1,))

        self._stream_epilogue = jax.jit(accumulate_stream_stats,
                                        donate_argnums=(0,))

        # -- cross-window deferred dispatch (flush_every > 1) ---------------

        def defer_step(art, state, stats, dd, pending, w, threshold, pos):
            """One window on the deferred path: switch half as above, but
            the dispatched rows go to the deferral buffer instead of the
            backend, and the provisional (switch) predictions land in the
            pending set at cycle slot ``pos`` (traced: no recompiles)."""
            state, x, sw_pred, fwd, buf, idx, valid, counts = _switch_half(
                art, state, w, threshold)
            stats, dd, pending, pred, frac, rows = defer_tail(
                stats, dd, pending, w, sw_pred, fwd, buf, idx, valid,
                counts, pos)
            return state, stats, dd, pending, pred, frac, rows

        self._defer_step = jax.jit(defer_step, donate_argnums=(1, 2, 3, 4))

        def flush_fused(stats, dd, pending):
            """Backend over the whole deferral buffer, answers back-patched
            into the pending set; fresh (zeroed) carries come back with
            the patched predictions."""
            be_pred = jnp.asarray(backend_fn(dd.buf))
            patched = backpatch_pending(pending, be_pred, dd)
            stats = fold_flush_stats(stats, dd)
            return (stats, jax.tree.map(jnp.zeros_like, dd), patched,
                    jnp.full_like(pending, -1))

        self._flush_fused = jax.jit(flush_fused, donate_argnums=(0, 1, 2))

        def flush_patch(stats, dd, pending, be_pred):
            """Two-phase flush epilogue: the backend ran on host; patch."""
            patched = backpatch_pending(pending, be_pred, dd)
            stats = fold_flush_stats(stats, dd)
            return (stats, jax.tree.map(jnp.zeros_like, dd), patched,
                    jnp.full_like(pending, -1))

        self._flush_patch = jax.jit(flush_patch, donate_argnums=(0, 1, 2))

    # -- streaming state ----------------------------------------------------

    def _make_state(self):
        """Fresh register file — the state-layout hook subclasses override
        (the sharded tier allocates its mesh-placed table here instead of
        a dead single-device one)."""
        return init_flow_table(self.n_buckets)

    def _make_deferred(self) -> DeferredDispatch:
        """Fresh deferral buffer — the sharded tier overrides with its
        per-shard partial-row layout."""
        return init_deferred(self.flush_every, self.capacity, FLOW_FEATURES)

    def _reset_deferred(self):
        """Empty pending cycle: deferral buffer, per-window pending
        prediction set, and the host-side cycle position."""
        self._pending_n = 0
        self._flush_queue = []
        if self.flush_every > 1:
            self._dd = self._make_deferred()
            self._pending = jnp.full((self.flush_every, self.window), -1,
                                     jnp.int32)
        else:
            self._dd = self._pending = None

    @property
    def state(self) -> FlowTableState:
        """Current register file. Donated into every step: read, don't keep."""
        return self._state

    @property
    def stats(self) -> StreamStats:
        return self._stats

    @property
    def pending_windows(self) -> int:
        """Windows deferred in the current (unflushed) cycle."""
        return self._pending_n

    def flow_table(self) -> jax.Array:
        """(n_buckets, 8) feature table from the current registers."""
        return flow_table_readout(self._state)

    def reset(self):
        """Fresh register file + telemetry (a new stream epoch). Any
        pending deferred windows are dropped unflushed — flush() first if
        their backend answers matter."""
        self._state = self._make_state()
        self._stats = StreamStats.zero()
        self._reset_deferred()

    # -- serving ------------------------------------------------------------

    def step(self, w: PacketWindow):
        """Serve one window. -> (pred (W,), HybridStats for this window).

        Single device dispatch on the fused path; pad lanes report -1.
        Fully async — nothing here blocks on the device.

        With flush_every > 1 the returned predictions are *provisional*:
        deferred rows carry the low-confidence switch answer until the
        cycle flushes (automatically every flush_every windows, or on an
        explicit ``flush()``), at which point the back-patched final
        predictions for the whole cycle are available from
        ``consume_flush()``. ``HybridStats.backend_rows`` reports the
        rows *deferred* this window (they reach the backend at flush).

        NOT retry-safe: the register file advances (and the old state is
        donated) before the backend runs, so on the two-phase path a
        backend exception leaves the window already folded in — calling
        step(w) again double-counts it. Recover by reset() or by skipping
        the failed window, never by replaying it.
        """
        tau = jnp.float32(self.threshold)
        if self.flush_every == 1:
            if self._fused_ok is None:
                try:
                    self._state, self._stats, pred, frac, rows = \
                        self._stream_step(self.artifact, self._state,
                                          self._stats, w, tau)
                    self._fused_ok = True
                    return pred, HybridStats(frac, rows, self.capacity)
                except (jax.errors.JAXTypeError, TypeError):
                    # tracing failed before execution: neither the state
                    # nor the stats carry was consumed by the donation
                    self._fused_ok = False
            if self._fused_ok:
                self._state, self._stats, pred, frac, rows = \
                    self._stream_step(self.artifact, self._state,
                                      self._stats, w, tau)
                return pred, HybridStats(frac, rows, self.capacity)
            (self._state, sw_pred, fwd, buf, idx, valid,
             counts) = self._stream_switch(self.artifact, self._state, w,
                                           tau)
            be_pred = jnp.asarray(self.backend_fn(buf))
            self._stats, pred, frac, rows = self._stream_epilogue(
                self._stats, w, sw_pred, be_pred, idx, valid, fwd, *counts)
            return pred, HybridStats(frac, rows, self.capacity)
        # deferred path: no backend here — defer, auto-flush when full
        (self._state, self._stats, self._dd, self._pending, pred, frac,
         rows) = self._defer_step(self.artifact, self._state, self._stats,
                                  self._dd, self._pending, w, tau,
                                  jnp.int32(self._pending_n))
        self._pending_n += 1
        if self._pending_n >= self.flush_every:
            # queued, not overwritten: a manual caller who steps through
            # several cycles without consuming loses nothing
            self._flush_queue.append(self.flush())
        return pred, HybridStats(frac, rows, self.capacity)

    # -- deferred-dispatch flushing -----------------------------------------

    def _flush_rows_host(self):
        """Complete deferred rows for a host (two-phase) backend call.
        The sharded buffer holds per-shard partial rows (non-owner lanes
        exactly zero), so summing the shard dim reconstructs them."""
        buf = np.asarray(self._dd.buf)
        return buf.sum(axis=0, dtype=np.float32) if buf.ndim == 3 else buf

    def flush(self):
        """Run the backend on the pending deferral cycle and back-patch.

        -> (n_windows_flushed, patched (flush_every, W) predictions) with
        the flushed windows at rows [0, n); None when nothing is pending
        (or flush_every == 1, where every step already ran the backend).
        ``serve_trace`` calls this at trace end — the guaranteed flush —
        and after every auto-flush; drive it yourself when stepping
        manually. The deferral buffer and pending set are consumed
        (donated) and replaced by fresh zeroed carries.
        """
        if self.flush_every == 1 or self._pending_n == 0:
            return None
        n = self._pending_n
        if self._fused_ok is None:
            try:
                self._stats, self._dd, patched, self._pending = \
                    self._flush_fused(self._stats, self._dd, self._pending)
                self._fused_ok = True
                self._pending_n = 0
                return n, patched
            except (jax.errors.JAXTypeError, TypeError):
                # tracing failed before execution: nothing was donated
                self._fused_ok = False
        if self._fused_ok:
            self._stats, self._dd, patched, self._pending = \
                self._flush_fused(self._stats, self._dd, self._pending)
        else:
            be_pred = jnp.asarray(self.backend_fn(self._flush_rows_host()))
            self._stats, self._dd, patched, self._pending = \
                self._flush_patch(self._stats, self._dd, self._pending,
                                  be_pred)
        self._pending_n = 0
        return n, patched

    def consume_flush(self):
        """Pop the oldest unconsumed auto-flush result (or None): the
        (n_windows, patched predictions) pair ``step`` queued when a
        cycle filled. FIFO, so stepping through several cycles before
        consuming loses nothing."""
        return self._flush_queue.pop(0) if self._flush_queue else None

    def serve_trace(self, trace, *, t0: Optional[float] = None):
        """Stream a whole PacketTrace through step(). -> (pred (P,), stats).

        Per-packet predictions concatenated in arrival order (pad lanes
        stripped); the only host sync is the final concatenation. Under
        deferred dispatch (flush_every > 1) every auto-flush back-patches
        the backend answers over the provisional windows, and the trailing
        partial cycle is flushed before returning — the predictions are
        always final, bit-identical to flush_every=1 for row-wise
        backends. Windows still pending from manual step() calls are
        flushed (and their patches dropped, along with any unconsumed
        queue) on entry: they belong to a different prediction stream
        and must not patch into this trace's output.
        """
        self.flush()
        self._flush_queue = []
        preds = []
        for w in iter_windows(trace, self.window, self.n_buckets, t0=t0):
            pred, _ = self.step(w)
            preds.append(pred)
            fl = self.consume_flush()
            if fl is not None:
                k, patched = fl
                preds[-k:] = [patched[i] for i in range(k)]
        fl = self.flush()                    # guaranteed end-of-trace flush
        if fl is not None:
            k, patched = fl
            preds[-k:] = [patched[i] for i in range(k)]
        flat = (np.concatenate([np.asarray(p) for p in preds])
                [:trace.n_packets] if preds else np.zeros((0,), np.int32))
        return jnp.asarray(flat), self._stats
