"""Streaming hybrid serving: the always-on switch, one window at a time.

``StreamingHybridServer`` extends the zero-sync ``HybridServer`` with the
register-file carry of ``netsim.stream``: each ``step(window)`` is ONE
jitted, buffer-donating device dispatch that fuses

  register update        (segment-scatter into the donated FlowTableState)
  feature read-out       (gather the updated table rows for the window's
                          touched flows — per-packet, as a switch
                          classifies each arriving packet with its flow's
                          registers)
  fused switch classify  (the single-matmul kernel pipeline)
  capacity-bounded dispatch -> backend -> combine
  telemetry accumulation (StreamStats carried as donated device arrays)

Nothing in ``step`` touches the host: state and running statistics are
device arrays donated back in, per-window telemetry returns as a lazy
``HybridStats``, and predictions stay on device until the caller reads
them. Donation discipline (also DESIGN.md §5): the register file and the
stats carry are consumed every step and replaced by the returned pytrees —
callers must never hold a reference to a previous state.

Backends that cannot trace fall back to the same two-phase shape as
``HybridServer``: jitted update+switch+dispatch (still donating state),
host backend call, jitted combine+stats (donating the stats carry).

Cross-window backend batching (DESIGN.md §7): ``flush_every=k`` defers
the dispatched low-confidence rows of up to k windows into a donated
``core.hybrid.DeferredDispatch`` buffer and runs the backend once per
flush at k-times the occupancy; the answers back-patch the per-window
pending prediction set at their recorded (window, lane) return
addresses. ``flush_every=1`` (default) is the unchanged per-window path
— the equivalence oracle; final predictions are bit-identical either
way for row-wise backends.

Open-ended ingest (DESIGN.md §13): ``serve_stream(source)`` is the
primary serving loop — a pull-based pipeline over ``netsim.ingest``'s
ring buffer (count/deadline window-granular cuts, optional prefetch
double-buffering of chunk transfers, per-packet admit->prediction
latency percentiles). ``serve_trace`` is its thin finite-replay wrapper,
bit-identical to the pre-refactor trace loop. ``chunk_windows="auto"``
runs a measured K sweep at init (``autotune_chunk_windows``) that can
never select a chunk size regressing versus ``DEFAULT_CHUNK_WINDOWS``.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.artifact import TableArtifact
from repro.core.hybrid import (DeferredDispatch, backpatch_pending,
                               chunk_dispatch, combine, defer_window,
                               dispatch, init_deferred)
from repro.kernels.ops import fused_classify
from repro.kernels.tuning import (TileConfig, measure_min, sweep_best,
                                  _artifact_key)
from repro.netsim.ingest import (LatencyRecorder, PacketRingBuffer,
                                 cut_stream, prefetch_iter, replay_source)
from repro.netsim.stream import (EVICT_POLICIES, FLOW_FEATURES,
                                 FlowTableState, PacketChunk, PacketWindow,
                                 chunk_update_readout, flow_table_readout,
                                 init_flow_table, window_update_readout)
from repro.obs import Observability
from repro.serving.faults import FaultPolicy, FaultStats, GuardedBackend
from repro.serving.hybrid_serving import HybridServer, HybridStats


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class StreamStats:
    """Running telemetry over all windows served — scalar device arrays.

    Constructed and updated entirely on device (the carry is donated into
    every step); reading any python-typed property below is the only point
    that syncs, mirroring HybridStats' laziness.
    """
    windows: jax.Array        # i32: windows served
    packets: jax.Array        # i32: valid packets seen
    handled: jax.Array        # i32: answered at the switch tier
    backend_rows: jax.Array   # i32: rows the backend actually served
    deferred: jax.Array       # i32: low-confidence rows past capacity that
                              #      never reached the backend (switch
                              #      answer kept — was silent before)
    degraded: jax.Array       # i32: dispatched rows whose backend flush
                              #      ultimately failed (fault policy) —
                              #      provisional switch answer kept
    flushes: jax.Array        # i32: successful backend invocations (one
                              #      per served flush; == windows when
                              #      flush_every == 1 and nothing degrades)
    evicted: jax.Array        # i32: buckets recycled by the aging sweep
    overflow: jax.Array       # i32: register slots newly saturated at 2^24
    conf_sum: jax.Array       # f32: switch confidence summed over valid
                              #      lanes — mean_conf = conf_sum/packets
                              #      is the drift monitors' confidence-
                              #      collapse signal (ROADMAP item 1)

    @classmethod
    def zero(cls) -> "StreamStats":
        z = lambda: jnp.zeros((), jnp.int32)
        return cls(windows=z(), packets=z(), handled=z(), backend_rows=z(),
                   deferred=z(), degraded=z(), flushes=z(), evicted=z(),
                   overflow=z(), conf_sum=jnp.zeros((), jnp.float32))

    @property
    def n_windows(self) -> int:
        return int(self.windows)

    @property
    def n_packets(self) -> int:
        return int(self.packets)

    @property
    def n_handled(self) -> int:
        """Packets answered confidently at the switch tier."""
        return int(self.handled)

    @property
    def fraction_handled(self) -> float:
        n = int(self.packets)
        return float(self.handled) / n if n else 0.0

    @property
    def total_backend_rows(self) -> int:
        return int(self.backend_rows)

    @property
    def n_deferred(self) -> int:
        """Low-confidence rows that overflowed the dispatch capacity and
        kept the (low-confidence) switch answer. Nonzero means the stream
        wants a larger ``capacity`` or a larger ``flush_every`` — visible
        accounting for what used to be a silent drop. After the final
        flush, ``handled + backend_rows + deferred + degraded ==
        packets`` (see ``check``)."""
        return int(self.deferred)

    @property
    def n_degraded(self) -> int:
        """Dispatched rows whose backend flush ultimately failed under a
        ``FaultPolicy`` — the tier degraded to switch-only for them: the
        provisional switch prediction was kept, the back-patch skipped.
        Always 0 without a fault policy (no failure path exists)."""
        return int(self.degraded)

    @property
    def n_flushes(self) -> int:
        """Successful backend invocations so far: one per window at
        flush_every=1, one per ``flush_every`` windows (plus the
        end-of-trace flush) under cross-window batching. A flush that
        ultimately fails under a ``FaultPolicy`` does not count — its
        rows land in ``degraded``."""
        return int(self.flushes)

    @property
    def n_evicted(self) -> int:
        """Buckets recycled by the aging sweep (0 when eviction is off)."""
        return int(self.evicted)

    @property
    def n_overflow(self) -> int:
        """Register slots that hit the 2^24 exactness envelope; nonzero
        means count features saturated and the stream needs eviction (or
        more buckets) — the guard makes that visible, not silent."""
        return int(self.overflow)

    @property
    def total_conf(self) -> float:
        """Switch confidence summed over all valid packets."""
        return float(self.conf_sum)

    @property
    def mean_conf(self) -> float:
        """Mean switch confidence per valid packet — the signal whose
        windowed drop is the confidence-collapse drift detector."""
        n = int(self.packets)
        return float(self.conf_sum) / n if n else 0.0

    def as_dict(self) -> dict:
        """Host-side snapshot (syncs every counter) — the same plain-dict
        contract as ``FaultStats.as_dict``/``IngestStats.as_dict``, so
        the obs metrics registry reports all tiers uniformly. Counter
        keys are additive (deltas between two snapshots are meaningful);
        the two trailing ratios are derived, not additive."""
        return {"windows": self.n_windows, "packets": self.n_packets,
                "handled": self.n_handled,
                "backend_rows": self.total_backend_rows,
                "deferred": self.n_deferred, "degraded": self.n_degraded,
                "flushes": self.n_flushes, "evicted": self.n_evicted,
                "overflow": self.n_overflow, "conf_sum": self.total_conf,
                "fraction_handled": self.fraction_handled,
                "mean_conf": self.mean_conf}

    def check(self) -> "StreamStats":
        """Assert the accounting invariant: every valid packet is answered
        exactly once — confidently at the switch (``handled``), by the
        backend (``backend_rows``), by a kept switch answer past dispatch
        capacity (``deferred``), or by a kept switch answer on a failed
        flush (``degraded``):

            handled + backend_rows + deferred + degraded == packets

        Holds whenever no flush is pending; ``serve_trace`` calls it after
        the guaranteed end-of-trace flush. Reading the counters syncs (the
        caller is already at a sync point there). Returns self."""
        n = (self.n_handled + self.total_backend_rows + self.n_deferred
             + self.n_degraded)
        if n != self.n_packets:
            raise AssertionError(
                f"StreamStats accounting invariant violated: "
                f"handled={self.n_handled}"
                f" + backend_rows={self.total_backend_rows}"
                f" + deferred={self.n_deferred}"
                f" + degraded={self.n_degraded} = {n}"
                f" != packets={self.n_packets}")
        return self

    def __repr__(self):
        return (f"StreamStats(windows={self.n_windows}, "
                f"packets={self.n_packets}, "
                f"fraction_handled={self.fraction_handled:.3f}, "
                f"backend_rows={self.total_backend_rows}, "
                f"deferred={self.n_deferred}, degraded={self.n_degraded}, "
                f"flushes={self.n_flushes}, "
                f"evicted={self.n_evicted}, overflow={self.n_overflow})")


def accumulate_stream_stats(stats: StreamStats, w: PacketWindow, sw_pred,
                            be_pred, idx, valid, fwd, conf, n_evicted,
                            n_overflow):
    """Shared jit-traceable epilogue: combine backend answers, mask pad
    lanes, fold this window into the running StreamStats. Used by both the
    single-device and the sharded step (the sharded one passes psummed
    inputs — already replicated, so the fold is identical per device).
    The backend ran for this window, so ``flushes`` advances by one;
    forwarded rows past capacity land in ``deferred`` instead of silently
    keeping the switch answer uncounted. ``conf`` is the switch-tier
    confidence vector — valid lanes fold into ``conf_sum``.
    Returns (stats, pred, frac_handled, backend_rows)."""
    pred = combine(sw_pred, be_pred, idx, valid)
    pred = jnp.where(w.valid, pred, -1)                  # pad lanes
    n_valid = jnp.sum(w.valid.astype(jnp.int32))
    n_handled = jnp.sum((w.valid & ~fwd).astype(jnp.int32))
    n_fwd = jnp.sum(fwd.astype(jnp.int32))
    rows = jnp.sum(valid.astype(jnp.int32))
    frac = (n_handled.astype(jnp.float32)
            / jnp.maximum(n_valid, 1).astype(jnp.float32))
    stats = dataclasses.replace(
        stats, windows=stats.windows + 1,
        packets=stats.packets + n_valid,
        handled=stats.handled + n_handled,
        backend_rows=stats.backend_rows + rows,
        deferred=stats.deferred + (n_fwd - rows),
        flushes=stats.flushes + 1,
        evicted=stats.evicted + n_evicted,
        overflow=stats.overflow + n_overflow,
        conf_sum=stats.conf_sum + _fold_conf(conf, w.valid))
    return stats, pred, frac, rows


def _fold_conf(conf, valid):
    """Valid-lane confidence sum (f32 scalar) for the conf_sum fold."""
    return jnp.sum(jnp.where(valid, conf, 0.0).astype(jnp.float32))


def degrade_window_stats(stats: StreamStats, w: PacketWindow, sw_pred, fwd,
                         valid, conf, n_evicted, n_overflow):
    """Degraded epilogue for the per-window (flush_every=1) two-phase
    path: this window's backend flush ultimately failed under the fault
    policy, so every dispatched row keeps its provisional switch-tier
    prediction — counted in ``degraded``, not ``backend_rows``, and
    ``flushes`` does not advance (it counts successful invocations).
    Returns (stats, pred, frac_handled, rows_degraded)."""
    pred = jnp.where(w.valid, sw_pred, -1)               # pad lanes
    n_valid = jnp.sum(w.valid.astype(jnp.int32))
    n_handled = jnp.sum((w.valid & ~fwd).astype(jnp.int32))
    n_fwd = jnp.sum(fwd.astype(jnp.int32))
    rows = jnp.sum(valid.astype(jnp.int32))
    frac = (n_handled.astype(jnp.float32)
            / jnp.maximum(n_valid, 1).astype(jnp.float32))
    stats = dataclasses.replace(
        stats, windows=stats.windows + 1,
        packets=stats.packets + n_valid,
        handled=stats.handled + n_handled,
        deferred=stats.deferred + (n_fwd - rows),
        degraded=stats.degraded + rows,
        evicted=stats.evicted + n_evicted,
        overflow=stats.overflow + n_overflow,
        conf_sum=stats.conf_sum + _fold_conf(conf, w.valid))
    return stats, pred, frac, rows


def accumulate_deferred_stats(stats: StreamStats, w: PacketWindow, fwd,
                              valid, conf, n_evicted, n_overflow):
    """Per-window stats fold for the deferred-dispatch path: everything
    *except* the backend accounting, which folds at flush time
    (``fold_flush_stats``) when the backend actually runs.
    Returns (stats, frac_handled, rows_deferred_this_window)."""
    n_valid = jnp.sum(w.valid.astype(jnp.int32))
    n_handled = jnp.sum((w.valid & ~fwd).astype(jnp.int32))
    n_fwd = jnp.sum(fwd.astype(jnp.int32))
    rows = jnp.sum(valid.astype(jnp.int32))
    frac = (n_handled.astype(jnp.float32)
            / jnp.maximum(n_valid, 1).astype(jnp.float32))
    stats = dataclasses.replace(
        stats, windows=stats.windows + 1,
        packets=stats.packets + n_valid,
        handled=stats.handled + n_handled,
        deferred=stats.deferred + (n_fwd - rows),
        evicted=stats.evicted + n_evicted,
        overflow=stats.overflow + n_overflow,
        conf_sum=stats.conf_sum + _fold_conf(conf, w.valid))
    return stats, frac, rows


def fold_flush_stats(stats: StreamStats, dd: DeferredDispatch) -> StreamStats:
    """One backend flush served every live slot of the deferral buffer."""
    rows = jnp.sum(dd.valid.astype(jnp.int32))
    return dataclasses.replace(stats, backend_rows=stats.backend_rows + rows,
                               flushes=stats.flushes + 1)


def fold_degraded_flush(stats: StreamStats,
                        dd: DeferredDispatch) -> StreamStats:
    """Flush-time fold when the backend ultimately failed: the cycle's
    deferred rows keep their provisional switch predictions (the
    back-patch is skipped) and land in ``degraded``; ``flushes`` does not
    advance — it counts successful backend invocations only."""
    rows = jnp.sum(dd.valid.astype(jnp.int32))
    return dataclasses.replace(stats, degraded=stats.degraded + rows)


def degrade_chunk_stats(stats: StreamStats,
                        dd: DeferredDispatch) -> StreamStats:
    """Corrective fold for a degraded chunk flush:
    ``accumulate_chunk_stats`` folds the backend accounting inside the
    jitted switch half, *before* the host backend runs — when the flush
    then ultimately fails, move its rows to ``degraded`` and retract the
    optimistic flush count."""
    rows = jnp.sum(dd.valid.astype(jnp.int32))
    return dataclasses.replace(
        stats, backend_rows=stats.backend_rows - rows,
        degraded=stats.degraded + rows, flushes=stats.flushes - 1)


def defer_tail(stats, dd, pending, w: PacketWindow, sw_pred, fwd, buf, idx,
               valid, conf, counts, pos):
    """Shared tail of the deferred-path window step (single-device and
    sharded): mask pad lanes, append the dispatched rows to the deferral
    buffer at cycle slot ``pos``, record the provisional predictions in
    the pending set, fold the non-backend stats.
    Returns (stats, dd, pending, pred, frac, rows)."""
    pred = jnp.where(w.valid, sw_pred, -1)                   # pad lanes
    dd = defer_window(dd, buf, idx, valid, pos)
    pending = pending.at[pos].set(pred)
    stats, frac, rows = accumulate_deferred_stats(stats, w, fwd, valid,
                                                  conf, *counts)
    return stats, dd, pending, pred, frac, rows


def chunk_classify_tail(art, stats, chunk, xs, n_ev, n_ov, threshold,
                        capacity: int, *, use_pallas, tiles):
    """Shared batched half of the chunk megastep (single-device and
    sharded), after the sequential register scan produced the (K, W, 8)
    readout rows: ONE fused classify over all K*W rows, vmapped
    capacity-bounded dispatch, the whole-chunk stats fold, and the
    provisional prediction set (pad/dead lanes at -1). Bit-identical to
    K per-window passes because every op is row-independent.
    Returns (stats, dd, pending, frac, rows)."""
    k, w_lanes, nf = xs.shape
    with jax.named_scope("fused_classify"):
        sw_pred, conf = fused_classify(art, xs.reshape(k * w_lanes, nf),
                                       use_pallas=use_pallas, tiles=tiles)
    sw_pred = sw_pred.reshape(k, w_lanes).astype(jnp.int32)
    conf = conf.reshape(k, w_lanes)
    fwd = (conf < threshold) & chunk.valid
    dd = chunk_dispatch(xs, fwd, capacity)
    stats, frac, rows = accumulate_chunk_stats(stats, chunk, fwd, dd,
                                               conf, n_ev, n_ov)
    pending = jnp.where(chunk.valid, sw_pred, -1)        # pad/dead lanes
    return stats, dd, pending, frac, rows


def accumulate_chunk_stats(stats: StreamStats, chunk, fwd,
                           dd: DeferredDispatch, conf, n_evicted,
                           n_overflow):
    """Whole-chunk stats fold: the per-window telemetry identities summed
    over the (K, W) chunk in one pass (dead pad windows contribute no
    valid lanes, and are masked out of the window count), plus the
    backend accounting for the chunk's single flush.
    Returns (stats, frac_handled, backend_rows)."""
    n_valid = jnp.sum(chunk.valid.astype(jnp.int32))
    n_handled = jnp.sum((chunk.valid & ~fwd).astype(jnp.int32))
    n_fwd = jnp.sum(fwd.astype(jnp.int32))
    rows = jnp.sum(dd.valid.astype(jnp.int32))
    live = jnp.sum(jnp.any(chunk.valid, axis=1).astype(jnp.int32))
    frac = (n_handled.astype(jnp.float32)
            / jnp.maximum(n_valid, 1).astype(jnp.float32))
    stats = dataclasses.replace(
        stats, windows=stats.windows + live,
        packets=stats.packets + n_valid,
        handled=stats.handled + n_handled,
        backend_rows=stats.backend_rows + rows,
        deferred=stats.deferred + (n_fwd - rows),
        flushes=stats.flushes + 1,
        evicted=stats.evicted + n_evicted,
        overflow=stats.overflow + n_overflow,
        conf_sum=stats.conf_sum + _fold_conf(conf, chunk.valid))
    return stats, frac, rows


# -- chunk-size autotuning ---------------------------------------------------

DEFAULT_CHUNK_WINDOWS = 16
CHUNK_WINDOW_CANDIDATES = (4, 8, 16, 32)

_CHUNK_TUNE_CACHE: dict = {}


def clear_chunk_tune_cache() -> None:
    _CHUNK_TUNE_CACHE.clear()


def probe_chunk(window: int, k: int, n_buckets: int,
                seed: int = 0) -> PacketChunk:
    """Synthetic all-valid (k, window) chunk for timing sweeps: uniform
    bucket ids (realistic scatter conflicts), monotone timestamps,
    in-distribution lengths."""
    rng = np.random.RandomState(seed)
    n = k * window
    shp = (k, window)
    return PacketChunk(
        bucket=jnp.asarray(rng.randint(0, n_buckets, n)
                           .astype(np.int32).reshape(shp)),
        ts=jnp.asarray(np.linspace(0.0, 1.0, n, dtype=np.float32)
                       .reshape(shp)),
        length=jnp.asarray(rng.uniform(60.0, 1500.0, n)
                           .astype(np.float32).reshape(shp)),
        is_fwd=jnp.asarray((rng.rand(n) < 0.5)
                           .astype(np.float32).reshape(shp)),
        valid=jnp.asarray(np.ones(shp, bool)))


def probe_window(window: int, n_buckets: int, seed: int = 0) -> PacketWindow:
    """Synthetic all-valid window (the 1D sibling of ``probe_chunk``),
    shared by the chunk-size autotuner's warmup and the
    ``repro.analysis`` hot-path auditor's tracing probes."""
    c = probe_chunk(window, 1, n_buckets, seed)
    return PacketWindow(bucket=c.bucket[0], ts=c.ts[0], length=c.length[0],
                        is_fwd=c.is_fwd[0], valid=c.valid[0])


def autotune_chunk_windows(make_server, *, window: int, n_buckets: int,
                           candidates=CHUNK_WINDOW_CANDIDATES,
                           default: int = DEFAULT_CHUNK_WINDOWS,
                           candidate_filter=None, reps: int = 3,
                           seed: int = 0, cache_key=None, time_fn=None,
                           verbose: bool = False, events=None) -> int:
    """Measured K sweep at server init: pick ``chunk_windows``.

    ``make_server(k)`` builds a throwaway server compiled for chunk size
    k; each candidate is timed (``kernels.tuning.measure_min`` — warmup
    absorbs compilation) on one synthetic ``probe_chunk`` and scored
    per *packet* so different K compete fairly. The fixed ``default`` is
    always timed too and the winner is the measured argmin over a set
    containing it (``kernels.tuning.sweep_best``), so the sweep can
    never pick a chunk size that regresses versus the default on the
    tuned shape — the same no-tuned-regression contract as the kernel
    tile autotuner. ``candidate_filter`` drops Ks a config cannot use
    (the sharded tier's per-shard backend-slice divisibility); when it
    rejects the default itself, the first surviving candidate takes over
    the default's role. ``time_fn(k) -> seconds`` replaces the
    measurement (deterministic tests); ``cache_key`` memoizes the
    winner per (artifact shape, backend, geometry).

    Timing probes call the real ``backend_fn`` — a *stateful* backend
    (e.g. an injected-fault schedule keyed on call count) will observe
    those extra calls, so combine "auto" with stateless backends or
    pass an explicit chunk_windows.
    """
    if cache_key is not None:
        hit = _CHUNK_TUNE_CACHE.get(cache_key)
        if hit is not None:
            if events is not None:
                events.emit("autotune", knob="chunk_windows", chosen=hit,
                            cached=True)
            return hit
    cands = [k for k in candidates
             if candidate_filter is None or candidate_filter(k)]
    if candidate_filter is not None and not candidate_filter(default):
        if not cands:
            raise ValueError(
                "no chunk_windows candidate satisfies this configuration "
                f"(candidates={tuple(candidates)})")
        default = cands[0]

    def time_k(k: int) -> float:
        if time_fn is not None:
            return float(time_fn(k)) / (k * window)
        srv = make_server(k)
        chunk = probe_chunk(window, k, n_buckets, seed)

        def one():
            pred, _ = srv.step_chunk(chunk)
            jax.block_until_ready(pred)
        return measure_min(one, reps) / (k * window)   # per-packet seconds

    best, _ = sweep_best(cands, time_k, default=default, verbose=verbose,
                         label="chunk-autotune")
    if cache_key is not None:
        _CHUNK_TUNE_CACHE[cache_key] = best
    if events is not None:
        events.emit("autotune", knob="chunk_windows", chosen=best,
                    default=default, candidates=list(cands), cached=False)
    return best


class StreamingHybridServer(HybridServer):
    """HybridServer over a packet stream with per-flow register state.

    window is the static packet chunk size (the compiled step shape);
    n_buckets sizes the flow register file. The batch ``classify`` of the
    parent stays available (tests use it as the one-shot oracle).
    """

    # Declarative contracts the ``repro.analysis`` hot-path auditor keys
    # on: each row names a jitted step attribute, the donate_argnums it
    # is built with (the auditor proves every donated leaf really
    # aliases in the compiled HLO — jax prunes unusable donations
    # silently), and which probe shape traces it. ``collectives`` (set
    # by the sharded tier) pins the exact cross-device census.
    AUDIT_CONTRACTS = (
        {"attr": "_stream_step", "donate": (1, 2), "probe": "window",
         "collectives": {}},
        {"attr": "_stream_switch", "donate": (1,), "probe": "window",
         "collectives": {}},
        {"attr": "_chunk_step", "donate": (1, 2), "probe": "chunk",
         "collectives": {}},
    )

    def __init__(self, artifact: TableArtifact, backend_fn: Callable, *,
                 n_buckets: int = 4096, window: int = 512,
                 threshold: float = 0.7, capacity: int = 64,
                 flush_every: int = 1, chunk_windows: Optional[int] = None,
                 flush_occupancy: Optional[float] = None,
                 flush_deadline: Optional[float] = None,
                 evict_age: Optional[float] = None, saturate: bool = True,
                 evict_policy: str = "timeout", lru_occupancy: float = 0.75,
                 fault_policy: Optional[FaultPolicy] = None,
                 use_pallas: bool = False, autotune: bool = False,
                 tiles: Optional[TileConfig] = None,
                 fuse: Optional[bool] = None,
                 obs: Optional[Observability] = None):
        """evict_age: recycle a flow bucket once it has been idle for this
        many (rebased) seconds — the aging sweep runs inside every step
        (``netsim.stream.lifecycle_sweep``) with its cutoff clamped to the
        window's oldest timestamp, so a flow seen in this window survives
        it by construction even when the window spans more than
        evict_age. None disables eviction (bit-exact contract with the
        batch path). saturate keeps the 2^24 overflow
        guard on; clamping is a bitwise no-op below the envelope, so it
        only changes behavior for streams that were already silently
        inexact — now counted in StreamStats.overflow instead.

        flush_every: defer the backend across this many windows
        (DESIGN.md §7). 1 (default) keeps today's one-backend-call-per-
        window behavior bit for bit — the equivalence oracle. k > 1
        accumulates the dispatched low-confidence rows of up to k windows
        in a donated ``DeferredDispatch`` buffer and runs the backend
        once per flush at k-times the occupancy; ``step`` then returns
        *provisional* (switch-tier) predictions and the backend answers
        are back-patched into the pending windows at flush
        (``serve_trace`` consumes the patches and always ends with a
        guaranteed flush, so its predictions are final). Deferred rows'
        features are the register readout of their own window, so final
        predictions match flush_every=1 for any row-wise backend.

        chunk_windows: device-resident chunked streaming (DESIGN.md §8).
        ``serve_trace`` stacks this many windows into one (K, W)
        ``PacketChunk`` transferred once and runs the whole chunk as a
        single jitted ``lax.scan`` megastep — register update, touched-
        flow readout, fused classify and deferral all inside the scan
        with donated carries, the backend exactly once per chunk at the
        boundary (the deferral buffer is the scan carry, so flushes are
        chunk-aligned by construction). Final predictions are
        back-patched before the megastep returns — bit-identical to the
        per-window path for row-wise backends (the oracle tests and
        ``benchmarks/stream_bench.py`` assert). Mutually exclusive with
        flush_every > 1: the chunk IS the flush cycle. Pass the string
        ``"auto"`` to pick K by a measured init-time sweep
        (``autotune_chunk_windows`` — cached per artifact/geometry,
        never a regression versus ``DEFAULT_CHUNK_WINDOWS``).

        flush_occupancy: occupancy-triggered early flush for the
        flush_every > 1 path. A host-side policy (the host already
        tracks the cycle position) flushes the pending cycle as soon as
        the deferral buffer holds at least this fraction of its
        ``flush_every * capacity`` slots, instead of always waiting the
        full cycle — bounding how stale a deferred row can get on
        streams that dispatch at high occupancy, at unchanged final
        predictions (an early flush only splits the cycle). Reading the
        per-window deferred-row count costs one host sync per step, so
        the knob is opt-in; None keeps the fixed cadence (and the
        zero-sync step).

        flush_deadline: deadline-triggered early flush for the
        flush_every > 1 path (the occupancy knob's time-domain twin).
        The host-side cycle tracker latches the earliest timestamp of
        the cycle's first deferred window and flushes as soon as any
        window's newest timestamp is at least this many (rebased)
        seconds past it — bounding how *stale* a deferred row can get
        on sparse streams that never fill the buffer. Same contract as
        flush_occupancy: no recompile (an early flush only splits the
        cycle), bit-identical final predictions, opt-in because reading
        the window timestamps costs one host sync per step.

        evict_policy: "timeout" (default) recycles any bucket idle for
        evict_age seconds; "approx_lru" substitutes the pForest-style
        pressure-triggered sweep (``netsim.stream.approx_lru_sweep``) —
        multi-bit idle-age classes ranked by flow activity, evicting
        only while occupancy exceeds ``lru_occupancy`` and preferring
        oldest-then-smallest flows. Both need evict_age (for approx-LRU
        it is the age-class quantization horizon).

        fault_policy: wrap the backend in a ``serving.faults``
        ``GuardedBackend`` — per-flush timeout, bounded retries with
        exponential backoff, circuit breaker. Forces the two-phase
        serving path (the guard runs on host; bit-identical to fused by
        the equivalence oracle). When a flush ultimately fails the tier
        degrades: dispatched rows keep their provisional switch-tier
        predictions, counted in ``StreamStats.degraded``; with zero
        faults predictions are bit-identical to an unguarded server.

        obs: attach a ``repro.obs.Observability`` — lifecycle events
        (cuts, chunks, flushes, breaker transitions, autotune, drift
        alarms), per-stage timings, metric rollups and drift monitors
        over the serving loop (DESIGN.md §14). None (the default) takes
        no observability branch anywhere and is bit-identical to pre-obs
        serving; with an instance attached, all hooks stay host-side and
        predictions remain bit-identical (the BENCH_obs.json oracle) —
        only ``sync_every > 0`` adds sampled blocking syncs, and only
        the per-``rollup_every`` boundary reads device stats.
        """
        self._obs = obs
        if obs is not None:
            obs.bind(self)
        if flush_every < 1:
            raise ValueError(f"flush_every must be >= 1, got {flush_every}")
        if chunk_windows == "auto":
            # measured K sweep (never a regression vs the fixed default —
            # see autotune_chunk_windows); resolved before the validation
            # arithmetic below so every downstream check sees an int
            chunk_windows = self._resolve_auto_chunk_windows(
                artifact, backend_fn, n_buckets=n_buckets, window=window,
                threshold=threshold, capacity=capacity,
                evict_age=evict_age, saturate=saturate,
                evict_policy=evict_policy, lru_occupancy=lru_occupancy,
                use_pallas=use_pallas, tiles=tiles, fuse=fuse)
        if chunk_windows is not None:
            if chunk_windows < 1:
                raise ValueError(
                    f"chunk_windows must be >= 1, got {chunk_windows}")
            if flush_every != 1:
                raise ValueError(
                    "chunked streaming aligns backend flushes to chunk "
                    "boundaries (one flush per chunk_windows windows); "
                    "combine it with flush_every=1, not "
                    f"flush_every={flush_every}")
        if flush_occupancy is not None:
            if not 0.0 < flush_occupancy <= 1.0:
                raise ValueError(f"flush_occupancy must be in (0, 1], "
                                 f"got {flush_occupancy}")
            if flush_every == 1:
                raise ValueError("flush_occupancy needs flush_every > 1 "
                                 "(there is no deferral cycle to flush "
                                 "early at flush_every=1)")
        if flush_deadline is not None:
            if flush_deadline <= 0:
                raise ValueError(f"flush_deadline must be > 0, "
                                 f"got {flush_deadline}")
            if flush_every == 1:
                raise ValueError("flush_deadline needs flush_every > 1 "
                                 "(there is no deferral cycle to flush "
                                 "early at flush_every=1)")
        if evict_policy not in EVICT_POLICIES:
            raise ValueError(f"evict_policy must be one of "
                             f"{EVICT_POLICIES}, got {evict_policy!r}")
        if evict_policy == "approx_lru":
            if evict_age is None:
                raise ValueError("evict_policy='approx_lru' needs "
                                 "evict_age (the idle-age quantization "
                                 "horizon of the age classes)")
            if not 0.0 < lru_occupancy < 1.0:
                raise ValueError(f"lru_occupancy must be in (0, 1), "
                                 f"got {lru_occupancy}")
        if fault_policy is not None:
            if fuse:
                raise ValueError("fault_policy guards the host backend "
                                 "call and therefore needs the two-phase "
                                 "serving path; it cannot be combined "
                                 "with fuse=True")
            fuse = False
        super().__init__(artifact, backend_fn, threshold=threshold,
                         capacity=capacity, use_pallas=use_pallas,
                         autotune=autotune, tiles=tiles, fuse=fuse)
        self.n_buckets = n_buckets
        self.window = window
        self.flush_every = flush_every
        self.chunk_windows = chunk_windows
        self.flush_occupancy = flush_occupancy
        self.flush_deadline = flush_deadline
        self.evict_age = evict_age
        self.saturate = saturate
        self.evict_policy = evict_policy
        self.lru_occupancy = lru_occupancy
        self.fault_policy = fault_policy
        self._guard = (GuardedBackend(backend_fn, fault_policy,
                                      events=(obs.events if obs is not None
                                              else None))
                       if fault_policy is not None else None)
        self._state = self._make_state()
        self._stats = StreamStats.zero()
        self._reset_deferred()
        self._ingest = None      # ring telemetry of the last serve_stream
        self._latency = None     # LatencyRecorder of the last serve_stream

        def _switch_half(art, state, w: PacketWindow, threshold):
            """update registers -> aging sweep -> overflow guard -> read
            out touched flows -> classify -> dispatch; shared by the fused
            and two-phase paths. The register half routes through
            ``window_update_readout``: with use_pallas the scatter-update,
            2^24 clamp and touched-row gather fuse into one VMEM pass
            (``kernels.stream_update``), skipping the HBM round-trip
            between them."""
            with jax.named_scope("register_update"):
                state, x, n_ev, n_ov = window_update_readout(
                    state, w, evict_age=evict_age, saturate=saturate,
                    evict_policy=evict_policy, lru_occupancy=lru_occupancy,
                    use_pallas=use_pallas)
            with jax.named_scope("fused_classify"):
                sw_pred, conf = fused_classify(art, x, use_pallas=use_pallas,
                                               tiles=self.tiles)
            fwd = (conf < threshold) & w.valid
            buf, idx, valid = dispatch(x, fwd, capacity)
            return (state, x, sw_pred, fwd, buf, idx, valid, conf,
                    (n_ev, n_ov))

        def stream_step(art, state, stats, w: PacketWindow, threshold):
            (state, x, sw_pred, fwd, buf, idx, valid, conf,
             counts) = _switch_half(art, state, w, threshold)
            be_pred = jnp.asarray(backend_fn(buf))
            stats, pred, frac, rows = accumulate_stream_stats(
                stats, w, sw_pred, be_pred, idx, valid, fwd, conf, *counts)
            return state, stats, pred, frac, rows

        self._stream_step = jax.jit(stream_step, donate_argnums=(1, 2))

        def stream_switch(art, state, w: PacketWindow, threshold):
            (state, x, sw_pred, fwd, buf, idx, valid, conf,
             counts) = _switch_half(art, state, w, threshold)
            return state, sw_pred, fwd, buf, idx, valid, conf, counts

        self._stream_switch = jax.jit(stream_switch, donate_argnums=(1,))

        self._stream_epilogue = jax.jit(accumulate_stream_stats,
                                        donate_argnums=(0,))

        # degraded epilogue: the flush_every=1 two-phase window whose
        # backend flush ultimately failed keeps its switch predictions
        self._degrade_window = jax.jit(degrade_window_stats,
                                       donate_argnums=(0,))

        # -- cross-window deferred dispatch (flush_every > 1) ---------------

        def defer_step(art, state, stats, dd, pending, w, threshold, pos):
            """One window on the deferred path: switch half as above, but
            the dispatched rows go to the deferral buffer instead of the
            backend, and the provisional (switch) predictions land in the
            pending set at cycle slot ``pos`` (traced: no recompiles)."""
            (state, x, sw_pred, fwd, buf, idx, valid, conf,
             counts) = _switch_half(art, state, w, threshold)
            stats, dd, pending, pred, frac, rows = defer_tail(
                stats, dd, pending, w, sw_pred, fwd, buf, idx, valid,
                conf, counts, pos)
            return state, stats, dd, pending, pred, frac, rows

        self._defer_step = jax.jit(defer_step, donate_argnums=(1, 2, 3, 4))

        def flush_fused(stats, dd, pending):
            """Backend over the whole deferral buffer, answers back-patched
            into the pending set; fresh (zeroed) carries come back with
            the patched predictions."""
            be_pred = jnp.asarray(backend_fn(dd.buf))
            patched = backpatch_pending(pending, be_pred, dd)
            stats = fold_flush_stats(stats, dd)
            return (stats, jax.tree.map(jnp.zeros_like, dd), patched,
                    jnp.full_like(pending, -1))

        self._flush_fused = jax.jit(flush_fused, donate_argnums=(0, 1, 2))

        def flush_patch(stats, dd, pending, be_pred):
            """Two-phase flush epilogue: the backend ran on host; patch."""
            patched = backpatch_pending(pending, be_pred, dd)
            stats = fold_flush_stats(stats, dd)
            return (stats, jax.tree.map(jnp.zeros_like, dd), patched,
                    jnp.full_like(pending, -1))

        self._flush_patch = jax.jit(flush_patch, donate_argnums=(0, 1, 2))

        def flush_degraded(stats, dd, pending):
            """Degraded flush: the backend ultimately failed, so the
            pending set — which already holds the provisional switch
            predictions — comes back *unpatched* as the flush result;
            the cycle's rows fold into ``degraded``. ``pending`` is not
            donated: it is returned as-is."""
            stats = fold_degraded_flush(stats, dd)
            return (stats, jax.tree.map(jnp.zeros_like, dd), pending,
                    jnp.full_like(pending, -1))

        self._flush_degraded = jax.jit(flush_degraded,
                                       donate_argnums=(0, 1))

        # -- device-resident chunked streaming (chunk_windows) --------------

        def chunk_switch(art, state, stats, chunk: PacketChunk, threshold):
            """K windows as ONE device program, sequential only where the
            data is: ``chunk_update_readout`` carries the register file
            through the K scatter-update + touched-row-gather steps (a
            lax.scan over the packed register file; the Pallas kernel
            per step on TPU), stacking the (K, W, 8) readout rows.
            Everything row-wise then runs ONCE over the whole chunk —
            fused classify on K*W rows, vmapped capacity-bounded
            dispatch, the stats fold — instead of K small sequential
            passes; the batched composition is bit-identical because
            every per-row op is row-independent."""
            with jax.named_scope("register_scan"):
                state, xs, n_ev, n_ov = chunk_update_readout(
                    state, chunk, evict_age=evict_age, saturate=saturate,
                    evict_policy=evict_policy, lru_occupancy=lru_occupancy,
                    use_pallas=use_pallas)
            stats, dd, pending, frac, rows = chunk_classify_tail(
                art, stats, chunk, xs, n_ev, n_ov, threshold, capacity,
                use_pallas=use_pallas, tiles=self.tiles)
            return state, stats, dd, pending, frac, rows

        self._chunk_switch = jax.jit(chunk_switch, donate_argnums=(1, 2))

        def chunk_step(art, state, stats, chunk: PacketChunk, threshold):
            """The whole megastep as one device dispatch: scan + batched
            switch half, backend ONCE over the chunk's deferred rows,
            back-patch — returning *final* predictions."""
            state, stats, dd, pending, frac, rows = chunk_switch(
                art, state, stats, chunk, threshold)
            be_pred = jnp.asarray(backend_fn(dd.buf))
            patched = backpatch_pending(pending, be_pred, dd)
            return state, stats, patched, frac, rows

        self._chunk_step = jax.jit(chunk_step, donate_argnums=(1, 2))

        def chunk_patch(pending, be_pred, dd):
            """Two-phase chunk epilogue: the backend ran on host; patch."""
            return backpatch_pending(pending, be_pred, dd)

        self._chunk_patch = jax.jit(chunk_patch, donate_argnums=(0,))

        self._degrade_chunk = jax.jit(degrade_chunk_stats,
                                      donate_argnums=(0,))

    # -- streaming state ----------------------------------------------------

    def _make_state(self):
        """Fresh register file — the state-layout hook subclasses override
        (the sharded tier allocates its mesh-placed table here instead of
        a dead single-device one)."""
        return init_flow_table(self.n_buckets)

    def _make_deferred(self) -> DeferredDispatch:
        """Fresh deferral buffer — the sharded tier overrides with its
        per-shard partial-row layout."""
        return init_deferred(self.flush_every, self.capacity, FLOW_FEATURES)

    def _reset_deferred(self):
        """Empty pending cycle: deferral buffer, per-window pending
        prediction set, and the host-side cycle position / occupancy
        count. (The chunked path carries no deferral state between
        megasteps — its DeferredDispatch lives and dies inside one
        chunk.)"""
        self._pending_n = 0
        self._occ_rows = 0
        self._cycle_born = None
        self._flush_queue = []
        if self.flush_every > 1:
            self._dd = self._make_deferred()
            self._pending = jnp.full((self.flush_every, self.window), -1,
                                     jnp.int32)
        else:
            self._dd = self._pending = None

    @property
    def state(self) -> FlowTableState:
        """Current register file. Donated into every step: read, don't keep."""
        return self._state

    @property
    def stats(self) -> StreamStats:
        return self._stats

    @property
    def pending_windows(self) -> int:
        """Windows deferred in the current (unflushed) cycle."""
        return self._pending_n

    @property
    def fault_stats(self) -> Optional[FaultStats]:
        """Host-side telemetry of the fault-policy guard (None without a
        ``fault_policy``): attempts, retries, timeouts, breaker
        transitions — see ``serving.faults.FaultStats``."""
        return self._guard.stats if self._guard is not None else None

    @property
    def ingest_stats(self):
        """``netsim.ingest.IngestStats`` of the most recent (or running)
        ``serve_stream`` — admitted/dropped packets, count vs deadline vs
        drain cuts. None before the first serve_stream."""
        return self._ingest

    @property
    def latency(self) -> Optional[LatencyRecorder]:
        """Admit->prediction LatencyRecorder of the most recent
        ``serve_stream(record_latency=True)``; ``.summary()`` gives the
        p50/p95/p99 row. None otherwise."""
        return self._latency

    # -- chunk-size autotune hooks ------------------------------------------

    def _auto_chunk_server(self, k: int, artifact, backend_fn, **kw):
        """Throwaway same-tier server compiled for chunk size k — the
        sweep's timing target. The sharded tier overrides to pin its
        mesh. fault_policy is deliberately not forwarded: probe timings
        should measure the serving path, not retry/backoff schedules
        (and "auto" is documented as a stateless-backend knob)."""
        return StreamingHybridServer(artifact, backend_fn,
                                     chunk_windows=k, **kw)

    def _auto_chunk_filter(self, capacity: int):
        """Candidate predicate (None = all Ks valid); the sharded tier
        restricts to Ks whose deferral buffer divides over the mesh."""
        return None

    def _resolve_auto_chunk_windows(self, artifact, backend_fn, *,
                                    n_buckets, window, capacity,
                                    **kw) -> int:
        key = (type(self).__name__, getattr(self, "n_shards", 1),
               _artifact_key(artifact), id(backend_fn),
               jax.default_backend(), window, n_buckets, capacity)
        return autotune_chunk_windows(
            lambda k: self._auto_chunk_server(
                k, artifact, backend_fn, n_buckets=n_buckets,
                window=window, capacity=capacity, **kw),
            window=window, n_buckets=n_buckets,
            candidate_filter=self._auto_chunk_filter(capacity),
            cache_key=key,
            events=(self._obs.events if self._obs is not None else None))

    def _host_backend(self, rows):
        """The two-phase host backend invocation, fault-guarded when a
        policy is set. Returns the backend's predictions, or None when
        the flush ultimately failed and the caller must degrade (keep
        provisional switch predictions, fold into ``degraded``). With an
        Observability attached the call is timed as the
        ``backend_flush`` stage."""
        obs = self._obs
        if obs is None:
            if self._guard is None:
                return self.backend_fn(rows)
            return self._guard(rows)
        with obs.stage("backend_flush"):
            if self._guard is None:
                return self.backend_fn(rows)
            return self._guard(rows)

    def flow_table(self) -> jax.Array:
        """(n_buckets, 8) feature table from the current registers."""
        return flow_table_readout(self._state)

    def reset(self):
        """Fresh register file + telemetry (a new stream epoch). Any
        pending deferred windows are dropped unflushed — flush() first if
        their backend answers matter."""
        self._state = self._make_state()
        self._stats = StreamStats.zero()
        self._reset_deferred()
        if self._guard is not None:
            self._guard.reset()

    # -- serving ------------------------------------------------------------

    def step(self, w: PacketWindow):
        """Serve one window. -> (pred (W,), HybridStats for this window).

        Single device dispatch on the fused path; pad lanes report -1.
        Fully async — nothing here blocks on the device.

        With flush_every > 1 the returned predictions are *provisional*:
        deferred rows carry the low-confidence switch answer until the
        cycle flushes (automatically every flush_every windows, or on an
        explicit ``flush()``), at which point the back-patched final
        predictions for the whole cycle are available from
        ``consume_flush()``. ``HybridStats.backend_rows`` reports the
        rows *deferred* this window (they reach the backend at flush).

        NOT retry-safe: the register file advances (and the old state is
        donated) before the backend runs, so on the two-phase path a
        backend exception leaves the window already folded in — calling
        step(w) again double-counts it. Recover by reset() or by skipping
        the failed window, never by replaying it.
        """
        tau = jnp.float32(self.threshold)
        if self.flush_every == 1:
            if self._fused_ok is None:
                try:
                    self._state, self._stats, pred, frac, rows = \
                        self._stream_step(self.artifact, self._state,
                                          self._stats, w, tau)
                    self._fused_ok = True
                    return pred, HybridStats(frac, rows, self.capacity)
                except (jax.errors.JAXTypeError, TypeError):
                    # tracing failed before execution: neither the state
                    # nor the stats carry was consumed by the donation
                    self._fused_ok = False
            if self._fused_ok:
                self._state, self._stats, pred, frac, rows = \
                    self._stream_step(self.artifact, self._state,
                                      self._stats, w, tau)
                return pred, HybridStats(frac, rows, self.capacity)
            (self._state, sw_pred, fwd, buf, idx, valid, conf,
             counts) = self._stream_switch(self.artifact, self._state, w,
                                           tau)
            be = self._host_backend(buf)
            if be is None:          # flush failed: degrade to switch-only
                self._stats, pred, frac, rows = self._degrade_window(
                    self._stats, w, sw_pred, fwd, valid, conf, *counts)
                return pred, HybridStats(frac, rows, self.capacity)
            self._stats, pred, frac, rows = self._stream_epilogue(
                self._stats, w, sw_pred, jnp.asarray(be), idx, valid, fwd,
                conf, *counts)
            return pred, HybridStats(frac, rows, self.capacity)
        # deferred path: no backend here — defer, auto-flush when full
        (self._state, self._stats, self._dd, self._pending, pred, frac,
         rows) = self._defer_step(self.artifact, self._state, self._stats,
                                  self._dd, self._pending, w, tau,
                                  jnp.int32(self._pending_n))
        self._pending_n += 1
        full = self._pending_n >= self.flush_every
        trigger = "cycle_full"
        if self.flush_occupancy is not None and not full:
            # occupancy-triggered early flush: reading the deferred-row
            # count costs one host sync — the knob is opt-in (see __init__)
            self._occ_rows += int(rows)
            if self._occ_rows >= self.flush_occupancy * self._dd.slots:
                full = True
                trigger = "occupancy"
        if self.flush_deadline is not None:
            # deadline-triggered early flush: age the oldest pending
            # window (earliest ts latched at cycle start) against this
            # window's newest timestamp — one host sync, opt-in
            ts = np.asarray(w.ts)[np.asarray(w.valid)]
            if ts.size:
                if self._cycle_born is None:
                    self._cycle_born = float(ts.min())
                if (not full and float(ts.max()) - self._cycle_born
                        >= self.flush_deadline):
                    full = True
                    trigger = "deadline"
        if full:
            # queued, not overwritten: a manual caller who steps through
            # several cycles without consuming loses nothing
            self._flush_queue.append(self.flush(trigger=trigger))
        return pred, HybridStats(frac, rows, self.capacity)

    # -- deferred-dispatch flushing -----------------------------------------

    def _flush_rows_host(self, dd: Optional[DeferredDispatch] = None):
        """Complete deferred rows for a host (two-phase) backend call.
        The sharded buffer holds per-shard partial rows (non-owner lanes
        exactly zero), so summing the shard dim reconstructs them."""
        buf = np.asarray((dd or self._dd).buf)
        return buf.sum(axis=0, dtype=np.float32) if buf.ndim == 3 else buf

    def flush(self, *, trigger: str = "manual"):
        """Run the backend on the pending deferral cycle and back-patch.

        -> (n_windows_flushed, patched (flush_every, W) predictions) with
        the flushed windows at rows [0, n); None when nothing is pending
        (or flush_every == 1, where every step already ran the backend).
        ``serve_trace`` calls this at trace end — the guaranteed flush —
        and after every auto-flush; drive it yourself when stepping
        manually. The deferral buffer and pending set are consumed
        (donated) and replaced by fresh zeroed carries. ``trigger``
        labels the lifecycle event when an Observability is attached
        ("cycle_full" / "occupancy" / "deadline" / "end_of_stream" /
        "manual") — it never changes behavior.
        """
        if self.flush_every == 1 or self._pending_n == 0:
            return None
        n = self._pending_n
        obs = self._obs
        if obs is not None:
            obs.emit("flush", windows=n, trigger=trigger)
        if self._fused_ok is None:
            try:
                self._stats, self._dd, patched, self._pending = \
                    self._flush_fused(self._stats, self._dd, self._pending)
                self._fused_ok = True
                self._pending_n = 0
                self._occ_rows = 0
                self._cycle_born = None
                if obs is not None:
                    obs.emit("backpatch", windows=n)
                return n, patched
            except (jax.errors.JAXTypeError, TypeError):
                # tracing failed before execution: nothing was donated
                self._fused_ok = False
        if self._fused_ok:
            self._stats, self._dd, patched, self._pending = \
                self._flush_fused(self._stats, self._dd, self._pending)
            if obs is not None:
                obs.emit("backpatch", windows=n)
        else:
            be = self._host_backend(self._flush_rows_host())
            if be is None:      # flush failed: keep provisional answers
                self._stats, self._dd, patched, self._pending = \
                    self._flush_degraded(self._stats, self._dd,
                                         self._pending)
                if obs is not None:
                    obs.emit("degraded", windows=n)
            else:
                if obs is not None:
                    with obs.stage("backpatch"):
                        (self._stats, self._dd, patched,
                         self._pending) = self._flush_patch(
                            self._stats, self._dd, self._pending,
                            jnp.asarray(be))
                    obs.emit("backpatch", windows=n)
                else:
                    self._stats, self._dd, patched, self._pending = \
                        self._flush_patch(self._stats, self._dd,
                                          self._pending, jnp.asarray(be))
        self._pending_n = 0
        self._occ_rows = 0
        self._cycle_born = None
        return n, patched

    def consume_flush(self):
        """Pop the oldest unconsumed auto-flush result (or None): the
        (n_windows, patched predictions) pair ``step`` queued when a
        cycle filled. FIFO, so stepping through several cycles before
        consuming loses nothing."""
        return self._flush_queue.pop(0) if self._flush_queue else None

    # -- chunked serving -----------------------------------------------------

    def step_chunk(self, chunk: PacketChunk):
        """Serve K stacked windows as ONE device dispatch.
        -> (pred (K, W), HybridStats for the chunk).

        The megastep scans the chunk's windows through the switch half
        with donated carries (register file, stats, deferral buffer),
        runs the backend exactly once over the chunk's deferred rows,
        and back-patches — the returned predictions are *final* (not
        provisional), with pad/dead lanes at -1. Requires
        ``chunk_windows`` (the compiled scan length); chunks must have
        exactly that many window rows (``iter_chunks`` pads the ragged
        final chunk with dead windows). Same retry discipline as
        ``step``: the state advances before a two-phase backend runs,
        so never replay a failed chunk.
        """
        if self.chunk_windows is None:
            raise ValueError("server built without chunk_windows")
        if chunk.n_windows != self.chunk_windows:
            raise ValueError(f"chunk has {chunk.n_windows} windows, server "
                             f"compiled for {self.chunk_windows}")
        if chunk.window != self.window:
            raise ValueError(f"chunk windows are {chunk.window} lanes wide, "
                             f"server compiled for {self.window}")
        tau = jnp.float32(self.threshold)
        if self._fused_ok is None:
            try:
                self._state, self._stats, patched, frac, rows = \
                    self._chunk_step(self.artifact, self._state,
                                     self._stats, chunk, tau)
                self._fused_ok = True
                return patched, HybridStats(frac, rows, self.capacity)
            except (jax.errors.JAXTypeError, TypeError):
                # tracing failed before execution: nothing was donated
                self._fused_ok = False
        if self._fused_ok:
            self._state, self._stats, patched, frac, rows = \
                self._chunk_step(self.artifact, self._state, self._stats,
                                 chunk, tau)
            return patched, HybridStats(frac, rows, self.capacity)
        # two-phase: jitted switch half, host backend, jitted back-patch
        self._state, self._stats, dd, pending, frac, rows = \
            self._chunk_switch(self.artifact, self._state, self._stats,
                               chunk, tau)
        be = self._host_backend(self._flush_rows_host(dd))
        obs = self._obs
        if be is None:          # flush failed: provisional set unpatched,
            #                     retract the optimistic in-graph fold
            self._stats = self._degrade_chunk(self._stats, dd)
            if obs is not None:
                obs.emit("degraded", windows=chunk.n_windows)
            return pending, HybridStats(frac, rows, self.capacity)
        if obs is not None:
            with obs.stage("backpatch"):
                patched = self._chunk_patch(pending, jnp.asarray(be), dd)
            obs.emit("backpatch", windows=chunk.n_windows)
        else:
            patched = self._chunk_patch(pending, jnp.asarray(be), dd)
        return patched, HybridStats(frac, rows, self.capacity)

    # -- open-ended serving --------------------------------------------------

    def serve_stream(self, source, *, t0: Optional[float] = None,
                     deadline: Optional[float] = None,
                     ring_capacity: Optional[int] = None,
                     prefetch: Optional[bool] = None,
                     prefetch_depth: int = 2,
                     record_latency: bool = False,
                     latency_samples: Optional[int] = None,
                     clock: Callable[[], float] = time.monotonic):
        """The primary serving loop: pull packets from an open-ended
        ``source`` through the ingest ring. -> (pred (P,), stats).

        ``source`` is any iterable of PacketTrace batches (a live
        capture adapter, ``netsim.ingest.replay_source`` for finite
        traces, a generator pacing a scenario). Batches are admitted
        into a ``PacketRingBuffer`` and cut into window-granular chunks
        by count or ``deadline`` (wall seconds an admitted packet may
        wait), whichever fires first — see ``netsim.ingest``. Because
        cuts never move window boundaries, predictions, the flow table
        and every StreamStats field except ``flushes`` are bit-identical
        under ANY cut grouping; replaying a finite trace in one batch
        reproduces the offline grouping exactly (``serve_trace``'s
        contract, oracle-gated by tests/test_ingest.py).

        Ingest is pull-based, so backpressure is "the source waits":
        nothing is dropped, ``ring_capacity`` (default 4 chunks) bounds
        host memory. Push-style admission with tail-drop is the ring's
        own ``drop=True`` mode, not this loop.

        On the chunked path (``chunk_windows`` set) ``prefetch`` (default
        on) maps cuts to device chunks on a background thread with a
        bounded ``prefetch_depth`` queue — chunk k+1's (K, W) transfer
        is in flight while chunk k runs in the scan megastep. The
        per-window path has no chunk transfer to overlap: prefetch=True
        there is a configuration error (ValueError); the default (None)
        auto-disables.

        record_latency=True records every packet's admit->prediction
        wall latency into ``self.latency`` (p50/p95/p99 via
        ``.summary()``) — *final*-prediction semantics: a chunk's
        packets complete when the megastep's back-patched predictions
        are host-visible; under deferred dispatch (flush_every > 1) a
        window's packets complete at the flush that back-patches its
        cycle (deferred rows' extra wait is therefore included). The
        required per-cut host sync costs throughput, so the knob is
        opt-in; off keeps the zero-sync loop. ``latency_samples`` bounds
        the recorder's memory with a seeded reservoir (exact mean/max,
        sampled percentiles) — None keeps exact percentiles at unbounded
        memory, the right default for finite traces; open-ended streams
        should set it (see ``netsim.ingest.LatencyRecorder``).

        With an ``obs=Observability`` attached at construction, this
        loop emits lifecycle events (serve_begin/cut/chunk/window/
        flush/rollup/serve_end), times pipeline stages, closes a metric
        rollup window every ``rollup_every`` dispatches (the loop's only
        device-stats read), feeds the drift monitors, and — only when
        ``sync_every > 0`` — samples a blocking device sync as the
        ``megastep_synced`` stage. Predictions, flow table, and
        StreamStats stay bit-identical with obs attached (oracle-gated
        in tests and benchmarks/obs_bench.py).

        Composition with the flush knobs (documented precedence): the
        ingest ``deadline`` acts in the *wall-clock* domain on admitted
        packets and only changes cut grouping; ``flush_deadline`` /
        ``flush_occupancy`` act in the *data-time / occupancy* domain on
        the deferral cycle inside ``step`` and only change flush
        grouping. They compose freely (flush knobs require
        flush_every > 1, which excludes the chunked path, so at most one
        of {chunk prefetch, flush knobs} is ever active); when a count
        cut and a deadline cut are both due, the count cut wins.
        ``self.ingest_stats`` reports admitted/dropped/cut telemetry.
        """
        chunked = bool(self.chunk_windows)
        if prefetch is None:
            prefetch = chunked
        if prefetch and not chunked:
            raise ValueError(
                "prefetch double-buffers (K, W) chunk transfers and "
                "needs the chunked path — build the server with "
                "chunk_windows (prefetch=None auto-disables on the "
                "per-window path)")
        ring = PacketRingBuffer(self.window,
                                self.chunk_windows if chunked else 1,
                                self.n_buckets, t0=t0,
                                capacity=ring_capacity, deadline=deadline,
                                clock=clock)
        self._ingest = ring.stats
        rec = (LatencyRecorder(max_samples=latency_samples)
               if record_latency else None)
        self._latency = rec
        # windows pending from manual step() calls belong to a different
        # prediction stream: flush them, drop their patches
        self.flush()
        self._flush_queue = []
        preds = []
        cuts = cut_stream(ring, source)
        obs = self._obs
        if obs is not None:
            obs.emit("serve_begin", tier=type(self).__name__,
                     window=self.window,
                     chunk_windows=self.chunk_windows or 0,
                     flush_every=self.flush_every, prefetch=bool(prefetch))
            obs.reset_ticks()
            # the rollup baseline: ONE stats read before the loop, so
            # boundary deltas are exact even on a warm server
            obs_prev = self._stats.as_dict()
            obs_b0 = 0                # preds index of the last boundary

        def _done(x) -> float:
            jax.block_until_ready(x)
            return clock()

        if chunked:
            def make_pairs():
                # generator (not genexpr) so the obs stage timers can
                # bracket the cut pull and the H2D map separately; with
                # prefetch on, both run on the prefetch thread and the
                # timings measure producer-side durations
                it = iter(cuts)
                while True:
                    try:
                        if obs is not None:
                            with obs.stage("ring_cut"):
                                c = next(it)
                        else:
                            c = next(it)
                    except StopIteration:
                        return
                    if obs is not None:
                        with obs.stage("h2d"):
                            ch = c.to_chunk()
                    else:
                        ch = c.to_chunk()
                    yield c, ch

            pairs = make_pairs()
            if prefetch:
                pairs = prefetch_iter(pairs, depth=prefetch_depth)
            for cut, chunk in pairs:
                if obs is not None:
                    obs.emit("cut", cut_kind=cut.kind, packets=cut.n,
                             windows=cut.n_windows)
                    with obs.annotate("megastep"), obs.stage("megastep"):
                        pred, _ = self.step_chunk(chunk)
                else:
                    pred, _ = self.step_chunk(chunk)
                flat = pred.reshape(-1)[:cut.n]   # live rows lead; pad/-1
                #                                   lanes only trail them
                if rec is not None:
                    rec.record(cut.admit_time, _done(flat))
                preds.append(flat)
                if obs is not None:
                    obs.emit("chunk", windows=cut.n_windows, packets=cut.n)
                    if obs.sync_due():
                        with obs.stage("megastep_synced"):
                            jax.block_until_ready(flat)
                    if obs.tick():
                        obs_prev, obs_b0 = self._obs_rollup(
                            obs, preds, obs_b0, obs_prev,
                            n_dispatches=obs.config.rollup_every,
                            collapse=True)
            if obs is not None and obs.pending_ticks:
                obs_prev, obs_b0 = self._obs_rollup(
                    obs, preds, obs_b0, obs_prev,
                    n_dispatches=obs.pending_ticks, collapse=True)
            flat = (np.concatenate([np.asarray(p) for p in preds])
                    if preds else np.zeros((0,), np.int32))
            if obs is not None:
                obs.emit("serve_end", packets=int(flat.size),
                         cuts=ring.stats.cuts,
                         windows=self._stats.n_windows)
            return jnp.asarray(flat), self._stats.check()

        # per-window path (incl. deferred dispatch); one window per cut
        times = []                    # admit times aligned with preds
        n_live = 0

        def _patch(fl):
            k, patched = fl
            preds[-k:] = [patched[i] for i in range(k)]
            if rec is not None:
                done = _done(patched)
                for at in times[len(times) - k:]:
                    rec.record(at, done)

        for cut in cuts:
            if obs is not None:
                obs.emit("cut", cut_kind=cut.kind, packets=cut.n,
                         windows=cut.n_windows)
            for w in cut.to_windows():
                if obs is not None:
                    with obs.annotate("window_step"), obs.stage("megastep"):
                        pred, _ = self.step(w)
                else:
                    pred, _ = self.step(w)
                preds.append(pred)
                times.append(cut.admit_time)
                n_live += cut.n
                if rec is not None and self.flush_every == 1:
                    rec.record(cut.admit_time, _done(pred))
                fl = self.consume_flush()
                if fl is not None:
                    _patch(fl)
                if obs is not None:
                    obs.emit("window", packets=cut.n)
                    if obs.sync_due():
                        with obs.stage("megastep_synced"):
                            jax.block_until_ready(pred)
                    if obs.tick():
                        # never collapse: _patch slices preds per window
                        obs_prev, obs_b0 = self._obs_rollup(
                            obs, preds, obs_b0, obs_prev,
                            n_dispatches=obs.config.rollup_every,
                            collapse=False)
        fl = self.flush(trigger="end_of_stream")   # guaranteed final flush
        if fl is not None:
            _patch(fl)
        if obs is not None and obs.pending_ticks:
            obs_prev, obs_b0 = self._obs_rollup(
                obs, preds, obs_b0, obs_prev,
                n_dispatches=obs.pending_ticks, collapse=False)
        flat = (np.concatenate([np.asarray(p) for p in preds])[:n_live]
                if preds else np.zeros((0,), np.int32))
        if obs is not None:
            obs.emit("serve_end", packets=n_live, cuts=ring.stats.cuts,
                     windows=self._stats.n_windows)
        return jnp.asarray(flat), self._stats.check()

    def _obs_rollup(self, obs, preds, b0, prev, *, n_dispatches, collapse):
        """Close one observability rollup window at a dispatch boundary.

        The loop's ONE device read per ``rollup_every`` dispatches: a
        StreamStats snapshot whose delta against the previous boundary
        is the rollup sample (all additive counters), plus the predicted
        class counts of the predictions emitted since the last boundary
        (pad/-1 lanes excluded; on the deferred per-window path these
        may still be provisional — the class-mix signal tolerates that).
        ``collapse=True`` (chunked path only) replaces the consumed
        preds entries with their host concatenation so the end-of-stream
        concat does no second device->host conversion; the per-window
        path must keep one entry per window for the flush back-patch.
        An eviction-sweep delta surfaces as an ``eviction`` event.
        Returns (snapshot, new_b0) for the next boundary."""
        cur = self._stats.as_dict()
        delta = {k: cur[k] - prev[k]
                 for k in ("windows", "packets", "handled", "backend_rows",
                           "deferred", "degraded", "flushes", "evicted",
                           "overflow", "conf_sum")}
        if len(preds) > b0:
            seg = np.concatenate([np.asarray(p).reshape(-1)
                                  for p in preds[b0:]])
            if collapse:
                preds[b0:] = [seg]
        else:
            seg = np.zeros(0, np.int32)
        live = seg[seg >= 0]
        counts = np.bincount(live, minlength=self.artifact.n_classes)
        if delta["evicted"] > 0:
            obs.emit("eviction", buckets=int(delta["evicted"]))
        sample = dict(delta, dispatches=int(n_dispatches),
                      class_counts=counts.tolist())
        obs.observe_rollup(sample)
        return cur, len(preds)

    def serve_trace(self, trace, *, t0: Optional[float] = None):
        """Stream a whole PacketTrace. -> (pred (P,), stats).

        A thin finite-replay wrapper over ``serve_stream``: the trace
        enters the ingest ring as one batch, so t0 latches to the trace
        minimum (the offline iterators' epoch), every cut is a count cut
        and the grouping — hence predictions, flow table and StreamStats
        including ``flushes`` — is bit-identical to driving
        ``iter_chunks``/``iter_windows`` through ``step_chunk``/``step``
        directly (the pre-refactor loop; tests/test_ingest.py keeps the
        oracle). Per-packet predictions return concatenated in arrival
        order with pad lanes stripped; under deferred dispatch they are
        final (every cycle back-patched, trailing cycle flushed).
        Prefetch is left at its default (on for the chunked path).
        """
        return self.serve_stream(replay_source(trace), t0=t0)
