"""Streaming hybrid serving: the always-on switch, one window at a time.

``StreamingHybridServer`` extends the zero-sync ``HybridServer`` with the
register-file carry of ``netsim.stream``: each ``step(window)`` is ONE
jitted, buffer-donating device dispatch that fuses

  register update        (segment-scatter into the donated FlowTableState)
  feature read-out       (gather the updated table rows for the window's
                          touched flows — per-packet, as a switch
                          classifies each arriving packet with its flow's
                          registers)
  fused switch classify  (the single-matmul kernel pipeline)
  capacity-bounded dispatch -> backend -> combine
  telemetry accumulation (StreamStats carried as donated device arrays)

Nothing in ``step`` touches the host: state and running statistics are
device arrays donated back in, per-window telemetry returns as a lazy
``HybridStats``, and predictions stay on device until the caller reads
them. Donation discipline (also DESIGN.md §5): the register file and the
stats carry are consumed every step and replaced by the returned pytrees —
callers must never hold a reference to a previous state.

Backends that cannot trace fall back to the same two-phase shape as
``HybridServer``: jitted update+switch+dispatch (still donating state),
host backend call, jitted combine+stats (donating the stats carry).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.artifact import TableArtifact
from repro.core.hybrid import combine, dispatch
from repro.kernels.ops import fused_classify
from repro.kernels.tuning import TileConfig
from repro.netsim.stream import (FlowTableState, PacketWindow,
                                 flow_table_readout, init_flow_table,
                                 iter_windows, lifecycle_sweep,
                                 update_flow_table)
from repro.serving.hybrid_serving import HybridServer, HybridStats


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class StreamStats:
    """Running telemetry over all windows served — scalar device arrays.

    Constructed and updated entirely on device (the carry is donated into
    every step); reading any python-typed property below is the only point
    that syncs, mirroring HybridStats' laziness.
    """
    windows: jax.Array        # i32: windows served
    packets: jax.Array        # i32: valid packets seen
    handled: jax.Array        # i32: answered at the switch tier
    backend_rows: jax.Array   # i32: rows the backend actually served
    evicted: jax.Array        # i32: buckets recycled by the aging sweep
    overflow: jax.Array       # i32: register slots clamped at 2^24

    @classmethod
    def zero(cls) -> "StreamStats":
        z = lambda: jnp.zeros((), jnp.int32)
        return cls(windows=z(), packets=z(), handled=z(), backend_rows=z(),
                   evicted=z(), overflow=z())

    @property
    def n_windows(self) -> int:
        return int(self.windows)

    @property
    def n_packets(self) -> int:
        return int(self.packets)

    @property
    def fraction_handled(self) -> float:
        n = int(self.packets)
        return float(self.handled) / n if n else 0.0

    @property
    def total_backend_rows(self) -> int:
        return int(self.backend_rows)

    @property
    def n_evicted(self) -> int:
        """Buckets recycled by the aging sweep (0 when eviction is off)."""
        return int(self.evicted)

    @property
    def n_overflow(self) -> int:
        """Register slots that hit the 2^24 exactness envelope; nonzero
        means count features saturated and the stream needs eviction (or
        more buckets) — the guard makes that visible, not silent."""
        return int(self.overflow)

    def __repr__(self):
        return (f"StreamStats(windows={self.n_windows}, "
                f"packets={self.n_packets}, "
                f"fraction_handled={self.fraction_handled:.3f}, "
                f"backend_rows={self.total_backend_rows}, "
                f"evicted={self.n_evicted}, overflow={self.n_overflow})")


def accumulate_stream_stats(stats: StreamStats, w: PacketWindow, sw_pred,
                            be_pred, idx, valid, fwd, n_evicted, n_overflow):
    """Shared jit-traceable epilogue: combine backend answers, mask pad
    lanes, fold this window into the running StreamStats. Used by both the
    single-device and the sharded step (the sharded one passes psummed
    inputs — already replicated, so the fold is identical per device).
    Returns (stats, pred, frac_handled, backend_rows)."""
    pred = combine(sw_pred, be_pred, idx, valid)
    pred = jnp.where(w.valid, pred, -1)                  # pad lanes
    n_valid = jnp.sum(w.valid.astype(jnp.int32))
    n_handled = jnp.sum((w.valid & ~fwd).astype(jnp.int32))
    rows = jnp.sum(valid.astype(jnp.int32))
    frac = (n_handled.astype(jnp.float32)
            / jnp.maximum(n_valid, 1).astype(jnp.float32))
    stats = StreamStats(windows=stats.windows + 1,
                        packets=stats.packets + n_valid,
                        handled=stats.handled + n_handled,
                        backend_rows=stats.backend_rows + rows,
                        evicted=stats.evicted + n_evicted,
                        overflow=stats.overflow + n_overflow)
    return stats, pred, frac, rows


class StreamingHybridServer(HybridServer):
    """HybridServer over a packet stream with per-flow register state.

    window is the static packet chunk size (the compiled step shape);
    n_buckets sizes the flow register file. The batch ``classify`` of the
    parent stays available (tests use it as the one-shot oracle).
    """

    def __init__(self, artifact: TableArtifact, backend_fn: Callable, *,
                 n_buckets: int = 4096, window: int = 512,
                 threshold: float = 0.7, capacity: int = 64,
                 evict_age: Optional[float] = None, saturate: bool = True,
                 use_pallas: bool = False, autotune: bool = False,
                 tiles: Optional[TileConfig] = None,
                 fuse: Optional[bool] = None):
        """evict_age: recycle a flow bucket once it has been idle for this
        many (rebased) seconds — the aging sweep runs inside every step
        (``netsim.stream.lifecycle_sweep``) with its cutoff clamped to the
        window's oldest timestamp, so a flow seen in this window survives
        it by construction even when the window spans more than
        evict_age. None disables eviction (bit-exact contract with the
        batch path). saturate keeps the 2^24 overflow
        guard on; clamping is a bitwise no-op below the envelope, so it
        only changes behavior for streams that were already silently
        inexact — now counted in StreamStats.overflow instead.
        """
        super().__init__(artifact, backend_fn, threshold=threshold,
                         capacity=capacity, use_pallas=use_pallas,
                         autotune=autotune, tiles=tiles, fuse=fuse)
        self.n_buckets = n_buckets
        self.window = window
        self.evict_age = evict_age
        self.saturate = saturate
        self._state = self._make_state()
        self._stats = StreamStats.zero()

        def _switch_half(art, state, w: PacketWindow, threshold):
            """update registers -> aging sweep -> overflow guard -> read
            out touched flows -> classify -> dispatch; shared by the fused
            and two-phase paths."""
            state = update_flow_table(state, w)
            state, n_ev, n_ov = lifecycle_sweep(state, w, evict_age,
                                                saturate)
            x = flow_table_readout(state, w.bucket)          # (W, 8)
            sw_pred, conf = fused_classify(art, x, use_pallas=use_pallas,
                                           tiles=self.tiles)
            fwd = (conf < threshold) & w.valid
            buf, idx, valid = dispatch(x, fwd, capacity)
            return state, x, sw_pred, fwd, buf, idx, valid, (n_ev, n_ov)

        def stream_step(art, state, stats, w: PacketWindow, threshold):
            state, x, sw_pred, fwd, buf, idx, valid, counts = _switch_half(
                art, state, w, threshold)
            be_pred = jnp.asarray(backend_fn(buf))
            stats, pred, frac, rows = accumulate_stream_stats(
                stats, w, sw_pred, be_pred, idx, valid, fwd, *counts)
            return state, stats, pred, frac, rows

        self._stream_step = jax.jit(stream_step, donate_argnums=(1, 2))

        def stream_switch(art, state, w: PacketWindow, threshold):
            state, x, sw_pred, fwd, buf, idx, valid, counts = _switch_half(
                art, state, w, threshold)
            return state, sw_pred, fwd, buf, idx, valid, counts

        self._stream_switch = jax.jit(stream_switch, donate_argnums=(1,))

        self._stream_epilogue = jax.jit(accumulate_stream_stats,
                                        donate_argnums=(0,))

    # -- streaming state ----------------------------------------------------

    def _make_state(self):
        """Fresh register file — the state-layout hook subclasses override
        (the sharded tier allocates its mesh-placed table here instead of
        a dead single-device one)."""
        return init_flow_table(self.n_buckets)

    @property
    def state(self) -> FlowTableState:
        """Current register file. Donated into every step: read, don't keep."""
        return self._state

    @property
    def stats(self) -> StreamStats:
        return self._stats

    def flow_table(self) -> jax.Array:
        """(n_buckets, 8) feature table from the current registers."""
        return flow_table_readout(self._state)

    def reset(self):
        """Fresh register file + telemetry (a new stream epoch)."""
        self._state = self._make_state()
        self._stats = StreamStats.zero()

    # -- serving ------------------------------------------------------------

    def step(self, w: PacketWindow):
        """Serve one window. -> (pred (W,), HybridStats for this window).

        Single device dispatch on the fused path; pad lanes report -1.
        Fully async — nothing here blocks on the device.

        NOT retry-safe: the register file advances (and the old state is
        donated) before the backend runs, so on the two-phase path a
        backend exception leaves the window already folded in — calling
        step(w) again double-counts it. Recover by reset() or by skipping
        the failed window, never by replaying it.
        """
        tau = jnp.float32(self.threshold)
        if self._fused_ok is None:
            try:
                self._state, self._stats, pred, frac, rows = \
                    self._stream_step(self.artifact, self._state,
                                      self._stats, w, tau)
                self._fused_ok = True
                return pred, HybridStats(frac, rows, self.capacity)
            except (jax.errors.JAXTypeError, TypeError):
                # tracing failed before execution: neither the state nor
                # the stats carry was consumed by the donation
                self._fused_ok = False
        if self._fused_ok:
            self._state, self._stats, pred, frac, rows = self._stream_step(
                self.artifact, self._state, self._stats, w, tau)
            return pred, HybridStats(frac, rows, self.capacity)
        (self._state, sw_pred, fwd, buf, idx, valid,
         counts) = self._stream_switch(self.artifact, self._state, w, tau)
        be_pred = jnp.asarray(self.backend_fn(buf))
        self._stats, pred, frac, rows = self._stream_epilogue(
            self._stats, w, sw_pred, be_pred, idx, valid, fwd, *counts)
        return pred, HybridStats(frac, rows, self.capacity)

    def serve_trace(self, trace, *, t0: Optional[float] = None):
        """Stream a whole PacketTrace through step(). -> (pred (P,), stats).

        Per-packet predictions concatenated in arrival order (pad lanes
        stripped); the only host sync is the final concatenation.
        """
        preds = []
        for w in iter_windows(trace, self.window, self.n_buckets, t0=t0):
            pred, _ = self.step(w)
            preds.append(pred)
        flat = (np.concatenate([np.asarray(p) for p in preds])
                [:trace.n_packets] if preds else np.zeros((0,), np.int32))
        return jnp.asarray(flat), self._stats
