"""Sharded streaming hybrid serving: the flow table scaled out over a mesh.

``ShardedStreamingServer`` is the ``StreamingHybridServer`` with its
register file partitioned across a 2D ('shard', 'data') device mesh
(``netsim.shard_stream`` / DESIGN.md §16): each ``step(window)`` is still
ONE jitted, state-donating dispatch, but the register update runs under
``shard_map`` — every shard folds only the buckets it owns
(bucket % n_shards), so the table capacity and the scatter bandwidth
scale with the mesh while the step keeps the parent's exact shape:

  shard_map:  per-shard register update (+ aging sweep + overflow guard)
              -> owner-masked touched-flow readout
              -> PARTITIONED classify: reduce-scatter the owner-masked
                 rows into complete ceil(K*W/D)-row lane slabs, fused
                 classify the slab only, all-gather the compact
                 (pred, conf) vectors back to full width
              -> capacity-bounded dispatch -> psum-merge backend buffer
  jit level:  backend -> combine -> StreamStats accumulation (the same
              ``accumulate_stream_stats`` the single-device tier uses)

The 'shard' axis partitions storage (flow-table buckets); the 'data'
axis adds pure batch parallelism over the classify lanes and the backend
slices (registers replicate along it). Per-device classify work is
~K*W/(D_shard*D_data) rows instead of K*W — the replicated-classify
layout this replaced survives as ``partition_classify=False``, the
``merge_overhead`` baseline the shard bench reports speedups against.

Cross-device traffic is only the small merges: the lane-slab
reduce-scatter/all-gathers, the (capacity, F) backend buffer psum, and
the i32 telemetry counters — never the register file itself (per-bucket
independence is what makes the flow table shardable at all).

Contract (tests + benchmarks/shard_stream_bench.py): with eviction
disabled, the sharded server is bit-identical to the single-device
``StreamingHybridServer`` on in-order traces — same predictions, same
telemetry, same ``flow_table()`` readout — at every mesh shape. The
reduce-scatter of owner-masked rows sums exactly one real row plus
zeros per lane, so each device's slab holds the owner's rows bitwise,
and classify is row-independent — partitioning moves work, not values.

Out-of-order arrivals (including a reordered first window) are tolerated
because every register is an associative reduction and every feature an
epoch-invariant difference; the min-merged ``epoch`` register replaces
the host-side latch as the record of the stream's true time origin
(``.epoch`` telemetry). The same donation discipline as the parent
applies — state and stats carries are consumed every step.

Cross-window batching is shard-aware (DESIGN.md §7): with
``flush_every=k`` the per-window psum of the dispatch buffer disappears
entirely — each shard accumulates the partial rows it owns in its slice
of the (n_shards, k*capacity, F) deferral buffer, and a flush
reduce-scatters complete rows so every device's backend serves only
k*capacity/(D_shard*D_data) of them. Backend capacity scales with the
whole mesh; the flush_every=1 default keeps the per-window
replicated-buffer path bit for bit.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Optional

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.artifact import TableArtifact
from repro.core.hybrid import (DeferredDispatch, backpatch_pending,
                               chunk_dispatch, dispatch, init_deferred)
from repro.distributed.sharding import as_flow_mesh, flow_shard_mesh
from repro.kernels.ops import classify_batch_rows, fused_classify
from repro.kernels.tuning import TileConfig, shard_tiles
from repro.netsim.shard_stream import (ShardedFlowTable, gather_lane_values,
                                       init_sharded_table, lane_slab_rows,
                                       n_local_buckets, scatter_lane_slab,
                                       shard_window_update,
                                       sharded_flow_table, stream_epoch)
from repro.netsim.stream import FLOW_FEATURES, PacketChunk, PacketWindow
from repro.serving.faults import FaultPolicy
from repro.serving.stream_serving import (StreamingHybridServer,
                                          accumulate_chunk_stats,
                                          accumulate_stream_stats,
                                          chunk_classify_tail,
                                          defer_tail, fold_flush_stats)


class ShardedStreamingServer(StreamingHybridServer):
    """StreamingHybridServer over a bucket-sharded register file.

    mesh (or n_shards / n_data) picks the 2D ('shard', 'data') mesh —
    default every local device on 'shard'; a legacy 1D ('shard',) mesh is
    normalized to a size-1 'data' axis. n_buckets is the *global* table
    size and must divide evenly over the shards. All parent knobs
    (threshold, capacity, evict_age, saturate, tiles, fuse) keep their
    meaning; ``step``/``serve_trace``/``reset`` are inherited — only the
    jitted closures and the state layout differ.
    ``partition_classify=False`` restores the pre-partitioning layout
    (every device classifies all lanes, owner-masked psum merge) — the
    ``merge_overhead`` baseline of the shard bench.
    """

    # Hot-path auditor contracts (repro.analysis.hotpath). The census
    # pins DESIGN.md §6/§8/§16 exactly: each audited step pays ONE
    # rank-2 lane-slab reduce-scatter (jax lowers psum_scatter to the
    # reduce_scatter primitive), TWO all-gathers (the compact pred and
    # conf slabs coming back), and three psums — the dispatch/deferral
    # buffer (the single rank>=2 "readout" psum) plus the two scalar
    # evict/overflow counts. The chunk megastep amortizes all of it to
    # once per K windows. Any extra collective that sneaks into these
    # jaxprs is a regression the auditor rejects; the census is
    # mesh-shape-invariant, so it holds on the 1-device audit mesh and
    # the (2, 2) CI mesh alike.
    AUDIT_CONTRACTS = (
        {"attr": "_stream_step", "donate": (1, 2), "probe": "window",
         "collectives": {"psum": 3, "reduce_scatter": 1, "all_gather": 2},
         "readout_psums": 1, "readout_scatters": 1},
        {"attr": "_stream_switch", "donate": (1,), "probe": "window",
         "collectives": {"psum": 3, "reduce_scatter": 1, "all_gather": 2},
         "readout_psums": 1, "readout_scatters": 1},
        {"attr": "_chunk_step", "donate": (1, 2), "probe": "chunk",
         "collectives": {"psum": 3, "reduce_scatter": 1, "all_gather": 2},
         "readout_psums": 1, "readout_scatters": 1},
    )

    def __init__(self, artifact: TableArtifact, backend_fn: Callable, *,
                 n_buckets: int = 4096, window: int = 512,
                 threshold: float = 0.7, capacity: int = 64,
                 flush_every: int = 1, chunk_windows: Optional[int] = None,
                 flush_occupancy: Optional[float] = None,
                 flush_deadline: Optional[float] = None,
                 evict_age: Optional[float] = None, saturate: bool = True,
                 evict_policy: str = "timeout", lru_occupancy: float = 0.75,
                 fault_policy: Optional[FaultPolicy] = None,
                 mesh: Optional[Mesh] = None, n_shards: Optional[int] = None,
                 n_data: Optional[int] = None,
                 partition_classify: bool = True,
                 use_pallas: bool = False, autotune: bool = False,
                 tiles: Optional[TileConfig] = None,
                 fuse: Optional[bool] = None, obs=None):
        # mesh before super().__init__: the parent allocates the register
        # file through the _make_state hook, which needs it
        if mesh is not None:
            self.mesh = as_flow_mesh(mesh)
        else:
            self.mesh = flow_shard_mesh(n_shards, n_data or 1)
        n_sh = self.n_shards = self.mesh.shape["shard"]
        n_dt = self.n_data = self.mesh.shape["data"]
        n_dev = self.n_devices = n_sh * n_dt
        self.partition_classify = bool(partition_classify)
        n_local_buckets(n_buckets, n_sh)          # validate divisibility
        if flush_every > 1 and (flush_every * capacity) % n_dev:
            # flush_every == 1 never builds the deferral buffer, so the
            # per-device slice constraint does not apply there
            raise ValueError(
                f"flush_every*capacity={flush_every * capacity} must divide "
                f"evenly over {n_dev} devices (each device's backend serves "
                f"one slice of the deferral buffer per flush)")
        # "auto" resolves inside the parent init (through the
        # _auto_chunk_filter override below, which enforces this same
        # divisibility on every candidate), so only explicit ints are
        # checked here
        if (isinstance(chunk_windows, int)
                and (chunk_windows * capacity) % n_dev):
            raise ValueError(
                f"chunk_windows*capacity={chunk_windows * capacity} must "
                f"divide evenly over {n_dev} devices (each device's backend "
                f"serves one slice of the chunk's deferral buffer)")
        super().__init__(artifact, backend_fn, n_buckets=n_buckets,
                         window=window, threshold=threshold,
                         capacity=capacity, flush_every=flush_every,
                         chunk_windows=chunk_windows,
                         flush_occupancy=flush_occupancy,
                         flush_deadline=flush_deadline,
                         evict_age=evict_age,
                         saturate=saturate, evict_policy=evict_policy,
                         lru_occupancy=lru_occupancy,
                         fault_policy=fault_policy, use_pallas=use_pallas,
                         autotune=autotune, tiles=tiles, fuse=fuse, obs=obs)

        def _slab_classify(art, x):
            """Partitioned fused classify (DESIGN.md §16): reduce-scatter
            the owner-masked (N, F) rows into complete per-device lane
            slabs, classify ONLY the ceil(N/D)-row slab, all-gather the
            compact (pred, conf) vectors back to the replicated full
            width. Bit-identical to classifying the full width because
            each complete row equals the owner's row exactly (one real
            value plus zeros) and classify is row-independent. tile_n is
            clamped to the slab so the kernel grid never pads the
            partitioned batch back up toward N."""
            n_lanes = x.shape[0]
            t = lane_slab_rows(n_lanes, n_sh, n_dt)
            sl = scatter_lane_slab(x, n_sh, n_dt)
            pred, conf = fused_classify(art, sl, use_pallas=use_pallas,
                                        tiles=shard_tiles(self.tiles, t))
            return (gather_lane_values(pred.astype(jnp.int32), n_lanes),
                    gather_lane_values(conf, n_lanes))

        def _shard_body(regs, epoch, art, w: PacketWindow, threshold, *,
                        merge_buf):
            """Per-shard half of the step (runs under shard_map; regs
            leaves arrive as this shard's (1, n_local) block). merge_buf
            psums the dispatch buffer to a replicated (capacity, F) for
            the immediate backend; the deferred path skips that merge and
            keeps each shard's partial rows — they accumulate in the
            deferral buffer and are reduce-scattered once per flush."""
            sq = jax.tree.map(lambda a: a[0], regs)
            d = jax.lax.axis_index("shard")
            sq, e, own, x, n_ev, n_ov = shard_window_update(
                sq, w, n_sh, d, evict_age=evict_age, saturate=saturate,
                evict_policy=evict_policy, lru_occupancy=lru_occupancy)
            if self.partition_classify:
                sw_pred, conf = _slab_classify(art, x)
            else:
                # merge_overhead baseline: every device classifies all W
                # lanes; exact merges — exactly one shard contributes a
                # nonzero lane
                sw_pred, conf = fused_classify(art, x, use_pallas=use_pallas,
                                               tiles=self.tiles)
                sw_pred = jax.lax.psum(jnp.where(own, sw_pred, 0), "shard")
                conf = jax.lax.psum(jnp.where(own, conf, 0.0), "shard")
            fwd = (conf < threshold) & w.valid
            buf, idx, valid = dispatch(x, fwd, capacity)
            buf = jax.lax.psum(buf, "shard") if merge_buf else buf[None]
            counts = (jax.lax.psum(n_ev, "shard"),
                      jax.lax.psum(n_ov, "shard"))
            return (jax.tree.map(lambda a: a[None], sq),
                    jnp.minimum(epoch, e),
                    sw_pred, fwd, buf, idx, valid, conf, counts)

        # check_rep=False: jax's static replication checker cannot infer
        # replication through all_gather (the partitioned classify's
        # merge); the out_specs still pin the layout, and the bit-identity
        # oracles pin the values.
        state_specs = (P("shard", None), P("shard"), P(), P(), P())
        shard_half = shard_map(
            functools.partial(_shard_body, merge_buf=True), mesh=self.mesh,
            in_specs=state_specs,
            out_specs=(P("shard", None), P("shard"),
                       P(), P(), P(), P(), P(), P(), P()),
            check_rep=False)
        defer_half = shard_map(
            functools.partial(_shard_body, merge_buf=False), mesh=self.mesh,
            in_specs=state_specs,
            out_specs=(P("shard", None), P("shard"),
                       P(), P(), P("shard", None, None), P(), P(), P(), P()),
            check_rep=False)

        def _switch_half(art, state: ShardedFlowTable, w, threshold, *,
                         half=shard_half):
            (regs, epoch, sw_pred, fwd, buf, idx, valid, conf,
             counts) = half(state.regs, state.epoch, art, w, threshold)
            return (ShardedFlowTable(regs=regs, epoch=epoch),
                    sw_pred, fwd, buf, idx, valid, conf, counts)

        def stream_step(art, state, stats, w: PacketWindow, threshold):
            (state, sw_pred, fwd, buf, idx, valid, conf,
             counts) = _switch_half(art, state, w, threshold)
            be_pred = jnp.asarray(backend_fn(buf))
            stats, pred, frac, rows = accumulate_stream_stats(
                stats, w, sw_pred, be_pred, idx, valid, fwd, conf, *counts)
            return state, stats, pred, frac, rows

        self._stream_step = jax.jit(stream_step, donate_argnums=(1, 2))

        def stream_switch(art, state, w: PacketWindow, threshold):
            return _switch_half(art, state, w, threshold)

        self._stream_switch = jax.jit(stream_switch, donate_argnums=(1,))
        # the epilogue (accumulate_stream_stats) is inherited as-is

        # -- cross-window deferred dispatch (shard-aware) --------------------

        def defer_step(art, state, stats, dd, pending, w, threshold, pos):
            """Deferred-path window: the parent's shared tail, but the
            dispatch buffer stays per-shard partial ((n_shards, capacity,
            F), the rows each shard owns, zeros elsewhere) — no
            per-window psum."""
            (state, sw_pred, fwd, buf, idx, valid, conf,
             counts) = _switch_half(art, state, w, threshold,
                                    half=defer_half)
            stats, dd, pending, pred, frac, rows = defer_tail(
                stats, dd, pending, w, sw_pred, fwd, buf, idx, valid,
                conf, counts, pos)
            return state, stats, dd, pending, pred, frac, rows

        self._defer_step = jax.jit(defer_step, donate_argnums=(1, 2, 3, 4))

        def _flush_body(buf):
            """Per-device flush half: reduce-scatter the partial deferral
            buffers over 'shard' so each shard holds complete rows for
            its slice, then slice that block again by the 'data' index —
            every one of the D_shard*D_data devices' backends serves
            slots/D rows, and the ('shard', 'data')-concatenated out_spec
            reassembles the full (slots,) answer vector in slot order."""
            sl = jax.lax.psum_scatter(buf[0], "shard", scatter_dimension=0,
                                      tiled=True)
            per = sl.shape[0] // n_dt
            i = jax.lax.axis_index("data")
            sl = jax.lax.dynamic_slice_in_dim(sl, i * per, per)
            return jnp.asarray(backend_fn(sl)).astype(jnp.int32)

        flush_half = shard_map(_flush_body, mesh=self.mesh,
                               in_specs=(P("shard", None, None),),
                               out_specs=P(("shard", "data")),
                               check_rep=False)

        def flush_fused(stats, dd, pending):
            be_pred = flush_half(dd.buf)
            patched = backpatch_pending(pending, be_pred, dd)
            stats = fold_flush_stats(stats, dd)
            return (stats, jax.tree.map(jnp.zeros_like, dd), patched,
                    jnp.full_like(pending, -1))

        self._flush_fused = jax.jit(flush_fused, donate_argnums=(0, 1, 2))
        # _flush_patch (two-phase: host backend on summed partial rows,
        # jitted back-patch) is inherited — backpatch/fold are layout-
        # agnostic and _flush_rows_host sums the shard dim.

        # -- device-resident chunked streaming (shard_map over the scan
        # -- body: the sequential register half runs per shard) -------------

        def _chunk_register_scan(regs, epoch, chunk: PacketChunk):
            """Shared sequential core of both chunk bodies: carry this
            shard's register block through the K owner-masked
            scatter-update + readout steps, stacking owner-masked (W, 8)
            readout partials."""
            sq = jax.tree.map(lambda a: a[0], regs)
            d = jax.lax.axis_index("shard")

            def body(carry, cw: PacketChunk):
                sq, ep = carry
                w = PacketWindow(bucket=cw.bucket, ts=cw.ts,
                                 length=cw.length, is_fwd=cw.is_fwd,
                                 valid=cw.valid)
                sq, e, own, x, n_ev, n_ov = shard_window_update(
                    sq, w, n_sh, d, evict_age=evict_age, saturate=saturate,
                    evict_policy=evict_policy, lru_occupancy=lru_occupancy)
                return (sq, jnp.minimum(ep, e)), (x, n_ev, n_ov)

            return jax.lax.scan(body, (sq, epoch[0]), chunk)

        if self.partition_classify:

            def _chunk_part_body(regs, epoch, art, chunk: PacketChunk,
                                 threshold):
                """Per-shard chunk megastep core: the register scan, then
                the partitioned classify over the chunk's K*W lane rows
                (one ceil(K*W/D)-row slab per device) and the per-shard
                capacity-bounded dispatch — the deferred rows merge
                through ONE rank-2 psum, the chunk's single readout
                merge."""
                (sq, ep), (xs, n_evs, n_ovs) = _chunk_register_scan(
                    regs, epoch, chunk)
                k, w_lanes, nf = xs.shape
                sw_pred, conf = _slab_classify(art, xs.reshape(k * w_lanes,
                                                               nf))
                sw_pred = sw_pred.reshape(k, w_lanes)
                conf = conf.reshape(k, w_lanes)
                fwd = (conf < threshold) & chunk.valid
                dd = chunk_dispatch(xs, fwd, capacity)
                dd = dataclasses.replace(
                    dd, buf=jax.lax.psum(dd.buf, "shard"))
                n_ev = jax.lax.psum(jnp.sum(n_evs), "shard")
                n_ov = jax.lax.psum(jnp.sum(n_ovs), "shard")
                return (jax.tree.map(lambda a: a[None], sq), ep[None],
                        sw_pred, conf, fwd, dd, n_ev, n_ov)

            dd_specs = DeferredDispatch(buf=P(), lane=P(), window=P(),
                                        valid=P())
            chunk_part_half = shard_map(
                _chunk_part_body, mesh=self.mesh,
                in_specs=(P("shard", None), P("shard"), P(), P(), P()),
                out_specs=(P("shard", None), P("shard"),
                           P(), P(), P(), dd_specs, P(), P()),
                check_rep=False)

            def chunk_switch(art, state, stats, chunk: PacketChunk,
                             threshold):
                """Sharded chunk megastep switch half: everything down to
                the dispatch runs inside ONE shard_map (classify included
                — that is the point), leaving only the layout-agnostic
                whole-chunk stats fold and the provisional prediction set
                at the jit level. Identical math to the single-device
                ``chunk_classify_tail``, which is the bit-identity
                contract."""
                (regs, epoch, sw_pred, conf, fwd, dd, n_ev,
                 n_ov) = chunk_part_half(state.regs, state.epoch, art,
                                         chunk, threshold)
                state = ShardedFlowTable(regs=regs, epoch=epoch)
                stats, frac, rows = accumulate_chunk_stats(
                    stats, chunk, fwd, dd, conf, n_ev, n_ov)
                pending = jnp.where(chunk.valid, sw_pred, -1)  # pad lanes
                return state, stats, dd, pending, frac, rows

        else:

            def _chunk_scan_body(regs, epoch, chunk: PacketChunk):
                """merge_overhead baseline chunk body: ONE psum over the
                stacked (K, W, 8) readout rows completes them; the
                parent's replicated ``chunk_classify_tail`` then
                classifies all K*W rows on every device."""
                (sq, ep), (xs, n_evs, n_ovs) = _chunk_register_scan(
                    regs, epoch, chunk)
                xs = jax.lax.psum(xs, "shard")  # owner partials -> complete
                n_ev = jax.lax.psum(jnp.sum(n_evs), "shard")
                n_ov = jax.lax.psum(jnp.sum(n_ovs), "shard")
                return (jax.tree.map(lambda a: a[None], sq), ep[None],
                        xs, n_ev, n_ov)

            chunk_scan_half = shard_map(
                _chunk_scan_body, mesh=self.mesh,
                in_specs=(P("shard", None), P("shard"), P()),
                out_specs=(P("shard", None), P("shard"), P(), P(), P()),
                check_rep=False)

            def chunk_switch(art, state, stats, chunk: PacketChunk,
                             threshold):
                regs, epoch, xs, n_ev, n_ov = chunk_scan_half(
                    state.regs, state.epoch, chunk)
                state = ShardedFlowTable(regs=regs, epoch=epoch)
                stats, dd, pending, frac, rows = chunk_classify_tail(
                    art, stats, chunk, xs, n_ev, n_ov, threshold, capacity,
                    use_pallas=use_pallas, tiles=self.tiles)
                return state, stats, dd, pending, frac, rows

        self._chunk_switch = jax.jit(chunk_switch, donate_argnums=(1, 2))

        chunk_be_half = shard_map(
            lambda bs: jnp.asarray(backend_fn(bs[0])).astype(jnp.int32),
            mesh=self.mesh, in_specs=(P(("shard", "data"), None, None),),
            out_specs=P(("shard", "data")), check_rep=False)

        def chunk_step(art, state, stats, chunk: PacketChunk, threshold):
            """Megastep with the mesh-wide backend: the chunk's deferred
            rows are complete (the readout psum already merged them), so
            each of the D_shard*D_data devices' backends serves one
            (K*capacity/D)-row slice and the concatenated answers
            back-patch the stacked predictions — still one device
            dispatch per chunk."""
            state, stats, dd, pending, frac, rows = chunk_switch(
                art, state, stats, chunk, threshold)
            slots = dd.buf.shape[0]
            be_pred = chunk_be_half(
                dd.buf.reshape(n_dev, slots // n_dev, FLOW_FEATURES))
            patched = backpatch_pending(pending, be_pred, dd)
            return state, stats, patched, frac, rows

        self._chunk_step = jax.jit(chunk_step, donate_argnums=(1, 2))
        # _chunk_patch (two-phase epilogue) is inherited — the chunk's
        # deferred rows are already complete, so the host path needs no
        # shard-dim sum either.

    # -- partitioned-classify telemetry -------------------------------------

    @property
    def classify_rows_per_device(self) -> int:
        """Rows each device's fused classify actually processes per
        megastep, kernel tile padding included (``classify_batch_rows``).

        Partitioned (the default): one ceil(K*W / (D_shard*D_data))-row
        lane slab per device. merge_overhead baseline
        (``partition_classify=False``): the full K*W lanes, replicated.
        The shard bench gates on the partitioned value being the padded
        ceiling — per-device classify work must shrink with the mesh.
        """
        lanes = (self.chunk_windows or 1) * self.window
        if not self.partition_classify:
            return classify_batch_rows(self.artifact, lanes,
                                       use_pallas=self.use_pallas,
                                       tiles=self.tiles)
        t = lane_slab_rows(lanes, self.n_shards, self.n_data)
        return classify_batch_rows(self.artifact, t,
                                   use_pallas=self.use_pallas,
                                   tiles=shard_tiles(self.tiles, t))

    # -- chunk-size autotune hooks ------------------------------------------

    def _auto_chunk_server(self, k: int, artifact, backend_fn, **kw):
        """Sweep throwaways share this server's mesh and classify layout
        so candidate timings include the real collectives."""
        return ShardedStreamingServer(
            artifact, backend_fn, chunk_windows=k, mesh=self.mesh,
            partition_classify=self.partition_classify, **kw)

    def _auto_chunk_filter(self, capacity: int):
        """Only Ks whose chunk deferral buffer divides over the mesh
        (the per-device backend-slice constraint validated in __init__)."""
        n_dev = self.n_devices
        return lambda k: (k * capacity) % n_dev == 0

    # -- streaming state ----------------------------------------------------

    def _make_state(self) -> ShardedFlowTable:
        """Mesh-placed sharded register file (parent init/reset hook)."""
        return init_sharded_table(self.n_buckets, mesh=self.mesh)

    def _make_deferred(self) -> DeferredDispatch:
        """Per-shard partial-row deferral buffer, placed on the mesh:
        the (n_shards, slots, F) accumulation buffer shards its leading
        dim over 'shard' (replicated along 'data'); the return addresses
        are replicated."""
        dd = init_deferred(self.flush_every, self.capacity, FLOW_FEATURES,
                           n_shards=self.n_shards)
        sh = lambda *spec: NamedSharding(self.mesh, P(*spec))
        return DeferredDispatch(
            buf=jax.device_put(dd.buf, sh("shard", None, None)),
            lane=jax.device_put(dd.lane, sh()),
            window=jax.device_put(dd.window, sh()),
            valid=jax.device_put(dd.valid, sh()))

    def flow_table(self) -> jax.Array:
        """(n_buckets, 8) canonical-bucket-order table, gathered across
        shards (a telemetry/test readout, not a hot path). Timestamps in
        the underlying registers stay in the provisional rebased frame —
        combine with ``.epoch`` for wall-clock flow times."""
        return sharded_flow_table(self._state)

    @property
    def epoch(self) -> float:
        """True observed stream start (min-merged register), in the
        provisional rebased frame; 0.0 for an in-order stream."""
        return float(stream_epoch(self._state))
