"""Backend fault tolerance: policy, circuit breaker, and fault injection.

The hybrid split's whole value proposition is that the switch keeps
answering when the backend is the bottleneck — which includes the backend
being *down*. This module wraps the host-side backend invocation (the
two-phase flush path of both streaming tiers) in an operational policy:

  ``FaultPolicy``    — per-flush timeout, bounded retries with
                       exponential backoff, and a circuit breaker that
                       opens after consecutive flush failures;
  ``GuardedBackend`` — the dispatcher applying a policy to a backend
                       function: returns the backend's answers, or
                       ``None`` when the flush ultimately failed (the
                       serving tiers then *degrade*: deferred rows keep
                       their provisional switch-tier predictions and are
                       counted in ``StreamStats.degraded``);
  ``FaultyBackend``  — a seeded injection wrapper for tests and the
                       scenario bench: configurable error rate, latency
                       spikes, and hard outages by flush index.

Everything here runs on host, outside the jitted graphs — a server built
with a ``FaultPolicy`` forces the two-phase serving path (jitted switch
half, host backend, jitted epilogue), which the equivalence tests already
pin bit-identical to the fused path. That is the zero-fault oracle: with
no faults injected, a policy-guarded server returns exactly the
predictions of an unguarded one.

Circuit breaker state machine (per GuardedBackend instance):

  CLOSED     every flush calls the backend (with timeout/retries);
             ``breaker_threshold`` *consecutive* ultimate failures open it.
  OPEN       flushes short-circuit to degraded (no backend call, no
             timeout wait) for ``breaker_cooldown`` flushes.
  HALF_OPEN  after the cooldown, exactly one probe flush reaches the
             backend (single attempt, no retries); success closes the
             breaker, failure re-opens it for another cooldown.

Timeouts run the backend on a worker thread and abandon it on expiry
(python cannot interrupt an arbitrary call); an abandoned call may still
complete in the background — its answer is dropped. Timeout enforcement
therefore costs one thread per in-flight abandoned call, which is the
standard trade-off for guarding foreign-runtime backends.
"""

from __future__ import annotations

import concurrent.futures
import dataclasses
import time
from typing import Callable, Iterable, Optional

import numpy as np


class BackendFault(RuntimeError):
    """A backend invocation failed (injected or real)."""


class BackendTimeout(BackendFault):
    """A backend invocation exceeded the policy's per-attempt timeout."""


# breaker states
CLOSED, OPEN, HALF_OPEN = "closed", "open", "half_open"


@dataclasses.dataclass(frozen=True)
class FaultPolicy:
    """Operational policy for one backend flush (host-side, see module doc).

    timeout_s          per-*attempt* timeout; None disables (no worker
                       thread is spawned).
    max_retries        retries after the first attempt (total attempts =
                       1 + max_retries; a HALF_OPEN probe gets exactly 1).
    backoff_base_s     sleep before retry i is backoff_base_s *
                       backoff_factor**i — exponential backoff.
    backoff_factor     growth factor of the backoff schedule.
    breaker_threshold  consecutive ultimately-failed flushes that open
                       the breaker; 0 disables the breaker entirely.
    breaker_cooldown   flushes short-circuited while OPEN before the
                       HALF_OPEN probe.
    """
    timeout_s: Optional[float] = None
    max_retries: int = 2
    backoff_base_s: float = 0.01
    backoff_factor: float = 2.0
    breaker_threshold: int = 3
    breaker_cooldown: int = 4

    def __post_init__(self):
        if self.timeout_s is not None and self.timeout_s <= 0:
            raise ValueError(f"timeout_s must be > 0, got {self.timeout_s}")
        if self.max_retries < 0:
            raise ValueError(f"max_retries must be >= 0, "
                             f"got {self.max_retries}")
        if self.breaker_threshold < 0:
            raise ValueError(f"breaker_threshold must be >= 0, "
                             f"got {self.breaker_threshold}")
        if self.breaker_threshold and self.breaker_cooldown < 1:
            raise ValueError(f"breaker_cooldown must be >= 1, "
                             f"got {self.breaker_cooldown}")


@dataclasses.dataclass
class FaultStats:
    """Host-side telemetry of one GuardedBackend (plain ints, no sync)."""
    flushes_ok: int = 0        # flushes the backend ultimately served
    flushes_failed: int = 0    # flushes that degraded (incl. rejected)
    attempts: int = 0          # backend invocations attempted
    retries: int = 0           # attempts beyond the first, per flush
    timeouts: int = 0          # attempts abandoned on timeout
    rejected: int = 0          # flushes short-circuited by an OPEN breaker
    breaker_opens: int = 0     # CLOSED/HALF_OPEN -> OPEN transitions
    breaker_closes: int = 0    # HALF_OPEN -> CLOSED transitions

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


class GuardedBackend:
    """Apply a FaultPolicy to a host backend function.

    Calling the guard with a row buffer returns the backend's answer
    array, or ``None`` when the flush ultimately failed — the caller
    degrades (keeps provisional switch predictions). Never raises for
    backend failures; genuine bugs (e.g. shape errors in the caller)
    surface as usual because only ``Exception``s raised *by the backend
    attempt* are treated as faults.

    ``sleep`` is injectable so tests can assert the backoff schedule
    without real waiting. ``events`` takes an ``EventBus``
    (repro.obs.events): when set, the guard narrates its lifecycle —
    ``backend_attempt`` / ``backend_timeout`` / ``backend_error`` /
    ``backend_retry`` per attempt, ``flush_ok`` / ``flush_failed`` /
    ``flush_rejected`` per flush, and ``breaker_open`` /
    ``breaker_half_open`` / ``breaker_close`` on state transitions —
    the exact sequence tests/test_obs.py pins.
    """

    def __init__(self, backend_fn: Callable, policy: FaultPolicy, *,
                 sleep: Callable[[float], None] = time.sleep,
                 events=None):
        self.backend_fn = backend_fn
        self.policy = policy
        self._sleep = sleep
        self._executor = None
        self._events = None        # init-time reset() emits nothing
        self.reset()
        self._events = events

    def _emit(self, kind: str, **fields) -> None:
        if self._events is not None:
            self._events.emit(kind, **fields)

    def reset(self):
        """Fresh telemetry and a CLOSED breaker (a new stream epoch —
        ``StreamingHybridServer.reset`` calls this so repeated runs see
        identical guard behavior)."""
        self.stats = FaultStats()
        self.state = CLOSED
        self.consecutive_failures = 0
        self._cooldown_left = 0
        self._emit("guard_reset")

    # -- timeout plumbing ---------------------------------------------------

    def _attempt(self, rows):
        """One backend attempt under the per-attempt timeout."""
        self.stats.attempts += 1
        self._emit("backend_attempt", attempt=self.stats.attempts,
                   state=self.state)
        if self.policy.timeout_s is None:
            return self.backend_fn(rows)
        if self._executor is None:
            self._executor = concurrent.futures.ThreadPoolExecutor(
                max_workers=1, thread_name_prefix="guarded-backend")
        fut = self._executor.submit(self.backend_fn, rows)
        try:
            return fut.result(timeout=self.policy.timeout_s)
        except concurrent.futures.TimeoutError:
            self.stats.timeouts += 1
            # abandon the in-flight call: its thread keeps running, so a
            # fresh executor serves the next attempt (the stuck worker is
            # never awaited again)
            self._executor.shutdown(wait=False)
            self._executor = None
            raise BackendTimeout(
                f"backend exceeded {self.policy.timeout_s}s") from None

    # -- breaker transitions ------------------------------------------------

    def _record_failure(self):
        self.stats.flushes_failed += 1
        self.consecutive_failures += 1
        self._emit("flush_failed",
                   consecutive_failures=self.consecutive_failures)
        p = self.policy
        if not p.breaker_threshold:
            return
        if (self.state == HALF_OPEN
                or self.consecutive_failures >= p.breaker_threshold):
            # open (or re-open after a failed HALF_OPEN probe)
            self.state = OPEN
            self._cooldown_left = p.breaker_cooldown
            self.stats.breaker_opens += 1
            self._emit("breaker_open", cooldown=p.breaker_cooldown)

    def _record_success(self):
        self.stats.flushes_ok += 1
        self.consecutive_failures = 0
        self._emit("flush_ok")
        if self.state != CLOSED:
            self.state = CLOSED
            self.stats.breaker_closes += 1
            self._emit("breaker_close")

    # -- the guarded flush --------------------------------------------------

    def __call__(self, rows) -> Optional[np.ndarray]:
        p = self.policy
        if self.state == OPEN:
            if self._cooldown_left > 0:
                self._cooldown_left -= 1
                self.stats.rejected += 1
                self.stats.flushes_failed += 1
                self._emit("flush_rejected",
                           cooldown_left=self._cooldown_left)
                return None
            self.state = HALF_OPEN          # cooldown over: one probe
            self._emit("breaker_half_open")
        attempts = 1 if self.state == HALF_OPEN else 1 + p.max_retries
        for i in range(attempts):
            if i:
                self.stats.retries += 1
                self._emit("backend_retry", retry=i)
                self._sleep(p.backoff_base_s * p.backoff_factor ** (i - 1))
            try:
                out = self._attempt(rows)
            except Exception as e:  # noqa: BLE001 — fault boundary: ANY
                #                     backend failure must degrade, not crash
                #                     the serving loop
                kind = ("backend_timeout" if isinstance(e, BackendTimeout)
                        else "backend_error")
                self._emit(kind, error=f"{type(e).__name__}: {e}")
                continue
            self._record_success()
            return np.asarray(out)
        self._record_failure()
        return None


class FaultyBackend:
    """Seeded fault-injection wrapper around a backend function.

    error_rate          probability an invocation raises BackendFault;
    spike_rate/spike_s  probability (and duration) of a latency spike
                        before the call — with ``sleep=time.sleep`` a
                        spike longer than the policy timeout turns into
                        a timeout fault;
    outages             iterable of invocation indices (0-based, counted
                        over *calls to this wrapper*) that hard-fail
                        regardless of error_rate — deterministic outage
                        windows like ``range(10, 14)``;
    seed                the rng seed: identical seeds replay identical
                        fault sequences (the reproducibility contract of
                        the scenario bench).

    The wrapper is host-only by construction (rng + counters are python
    state); serving tiers built with a FaultPolicy never trace the
    backend, so the injected faults fire on the two-phase path where the
    guard can catch them.
    """

    def __init__(self, backend_fn: Callable, *, error_rate: float = 0.0,
                 spike_rate: float = 0.0, spike_s: float = 0.0,
                 outages: Iterable[int] = (), seed: int = 0,
                 sleep: Callable[[float], None] = time.sleep):
        if not 0.0 <= error_rate <= 1.0:
            raise ValueError(f"error_rate must be in [0, 1], "
                             f"got {error_rate}")
        if not 0.0 <= spike_rate <= 1.0:
            raise ValueError(f"spike_rate must be in [0, 1], "
                             f"got {spike_rate}")
        self.backend_fn = backend_fn
        self.error_rate = error_rate
        self.spike_rate = spike_rate
        self.spike_s = spike_s
        self.outages = frozenset(int(i) for i in outages)
        self.seed = seed
        self._sleep = sleep
        self.reset()

    def reset(self):
        """Rewind the rng and counters: the next call sequence replays
        the identical fault sequence (pure function of seed + index)."""
        self._rng = np.random.default_rng(self.seed)
        self.calls = 0
        self.errors = 0
        self.spikes = 0

    def __call__(self, rows):
        i = self.calls
        self.calls += 1
        # draw both variates unconditionally so the fault sequence is a
        # pure function of (seed, call index) — an outage never shifts
        # the downstream error pattern
        err = self._rng.random() < self.error_rate
        spike = self._rng.random() < self.spike_rate
        if spike:
            self.spikes += 1
            self._sleep(self.spike_s)
        if i in self.outages or err:
            self.errors += 1
            raise BackendFault(f"injected fault at invocation {i}")
        return self.backend_fn(rows)
