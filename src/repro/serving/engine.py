"""Batched prefill/decode serving engine.

One jitted prefill (full prompt -> last logits + caches) and one jitted
decode step (token + caches -> logits + caches), reused across requests.
Caches are functional pytrees — the engine threads them; on a mesh they
carry the cache_specs shardings so decode runs fully distributed.

Sampling: greedy or temperature; the engine is deliberately simple —
batching discipline (fixed batch, fixed max_len) mirrors what the
dry-run's decode shapes lower.
"""

from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.models import model as M


class ServeEngine:
    def __init__(self, cfg, params, *, batch: int, max_len: int,
                 cache_dtype=jnp.bfloat16):
        self.cfg = cfg
        self.params = params
        self.batch = batch
        self.max_len = max_len
        self.cache_dtype = cache_dtype
        self._prefill = jax.jit(
            functools.partial(M.prefill, cfg=cfg))
        # decode() passes caches by keyword, so donation must be by name —
        # donate_argnums silently never fired, leaving a cache copy per step
        self._decode = jax.jit(
            functools.partial(M.decode_step, cfg=cfg),
            donate_argnames=("caches",))

    def prefill(self, batch_dict):
        logits, caches = self._prefill(self.params, batch=batch_dict)
        return logits, caches

    def decode(self, token, pos, caches):
        return self._decode(self.params, token=token, pos=pos,
                            caches=caches)


def _place_prefill_into_decode(decode_cache, prefill_cache):
    def place(d, s):
        if d.shape == s.shape:
            return s.astype(d.dtype)
        sl = tuple(slice(0, x) for x in s.shape)
        return d.at[sl].set(s.astype(d.dtype))

    return jax.tree.map(place, decode_cache, prefill_cache)


def greedy_generate(cfg, params, batch_dict, *, n_new: int,
                    max_len: Optional[int] = None,
                    cache_dtype=jnp.float32, temperature: float = 0.0,
                    key=None):
    """Prefill the prompt, then decode n_new tokens. Returns (B, n_new)."""
    tokens = batch_dict["tokens"]
    b, s = tokens.shape
    n_front = (cfg.n_frontend_tokens
               if cfg.frontend == "image_patches" else 0)
    max_len = max_len or (s + n_front + n_new + 1)

    logits, pcache = M.prefill(params, cfg, batch_dict)
    dcache = M.init_decode_cache(cfg, b, max_len, dtype=cache_dtype)
    caches = _place_prefill_into_decode(dcache, pcache)

    outs = []
    pos = s + n_front
    for i in range(n_new):
        if temperature > 0.0 and key is not None:
            key, sub = jax.random.split(key)
            nxt = jax.random.categorical(sub, logits / temperature, axis=-1)
        else:
            nxt = jnp.argmax(logits, axis=-1)
        nxt = nxt.astype(jnp.int32)
        outs.append(nxt)
        logits, caches = M.decode_step(params, cfg, nxt, pos + i, caches)
    return jnp.stack(outs, axis=1)
