"""Hybrid serving tier: the paper's §2.2.1 deployment, end to end.

Request path:
  1. feature extraction (netsim) produced a feature vector per request;
  2. the SWITCH TIER — the fused IIsy table pipeline — classifies the
     whole batch at line rate and yields (class, confidence);
  3. confidence >= tau  -> answered at the switch (dropped / tagged /
     fast-pathed per use case);
  4. confidence <  tau  -> the low-confidence subset is *compacted* into a
     fixed-capacity buffer (same machinery as MoE token dispatch) and only
     that buffer hits the BACKEND — either the full-grown ensemble
     (paper-faithful) or an LM scorer. This is the paper's back-end load
     reduction, in batch-size form: the expensive model runs on
     capacity-many rows, not on the full batch.

The per-batch telemetry (fraction handled, backend batch occupancy)
matches Figs 10-11's sweep quantities.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.core.artifact import TableArtifact
from repro.core.hybrid import combine, dispatch
from repro.kernels.ops import fused_classify


@dataclasses.dataclass
class HybridStats:
    fraction_handled: float
    backend_rows: int
    capacity: int


class HybridServer:
    def __init__(self, artifact: TableArtifact, backend_fn: Callable,
                 *, threshold: float = 0.7, capacity: int = 256,
                 use_pallas: bool = False):
        """backend_fn: (rows (capacity, F)) -> class predictions (capacity,)."""
        self.artifact = artifact
        self.backend_fn = backend_fn
        self.threshold = threshold
        self.capacity = capacity
        self.use_pallas = use_pallas
        self._switch = jax.jit(
            lambda art, x: fused_classify(art, x, use_pallas=use_pallas))

    def classify(self, x):
        """x (N, F) -> (pred (N,), stats)."""
        sw_pred, conf = self._switch(self.artifact, x)
        fwd = conf < self.threshold
        buf, idx, valid = dispatch(jnp.asarray(x, jnp.float32), fwd,
                                   self.capacity)
        be_pred = self.backend_fn(buf)
        pred = combine(sw_pred, jnp.asarray(be_pred), idx, valid)
        stats = HybridStats(
            fraction_handled=float(1.0 - jnp.mean(fwd.astype(jnp.float32))),
            backend_rows=int(jnp.sum(valid)),
            capacity=self.capacity)
        return pred, stats

    def update_tables(self, artifact: TableArtifact):
        """§4.4: retraining swaps table *contents*; nothing recompiles as
        long as shapes (the model constraints) are unchanged."""
        same = jax.tree.map(lambda a, b: a.shape == b.shape,
                            self.artifact, artifact)
        if not all(jax.tree.leaves(same)):
            raise ValueError("table shapes changed: constraints violated "
                             "(paper §4.4 requires fixed model constraints)")
        self.artifact = artifact
