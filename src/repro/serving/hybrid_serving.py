"""Hybrid serving tier: the paper's §2.2.1 deployment, end to end.

Request path:
  1. feature extraction (netsim) produced a feature vector per request;
  2. the SWITCH TIER — the fused IIsy table pipeline — classifies the
     whole batch at line rate and yields (class, confidence);
  3. confidence >= tau  -> answered at the switch (dropped / tagged /
     fast-pathed per use case);
  4. confidence <  tau  -> the low-confidence subset is *compacted* into a
     fixed-capacity buffer (same machinery as MoE token dispatch) and only
     that buffer hits the BACKEND — either the full-grown ensemble
     (paper-faithful) or an LM scorer. This is the paper's back-end load
     reduction, in batch-size form: the expensive model runs on
     capacity-many rows, not on the full batch.

Zero-sync single-dispatch path: switch classify + dispatch + backend +
combine are ONE jitted, buffer-donating function, so a classify() is a
single device dispatch with no host round-trips in between. Telemetry
(fraction handled, backend occupancy — Figs 10-11's sweep quantities)
returns as device arrays wrapped in a lazy HybridStats: nothing blocks on
a float()/int() host sync unless the caller actually reads a statistic.

Backends that cannot be traced (e.g. they call into a foreign runtime)
are detected on the first classify and served by a two-phase fallback:
jitted switch+dispatch, host backend call, jitted combine — still one
host hop fewer than the pre-refactor path.
"""

from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.core.artifact import TableArtifact, finalize_artifact
from repro.core.hybrid import combine, dispatch
from repro.kernels.ops import fused_classify
from repro.kernels.tuning import DEFAULT_TILES, TileConfig, autotune_tiles


class HybridStats:
    """Per-batch telemetry holding device arrays; converts lazily.

    Reading .fraction_handled / .backend_rows is the only point that
    blocks on the device — constructing or returning HybridStats never
    does, which keeps classify() fully asynchronous.
    """

    __slots__ = ("_fraction_handled", "_backend_rows", "capacity")

    def __init__(self, fraction_handled, backend_rows, capacity: int):
        self._fraction_handled = fraction_handled
        self._backend_rows = backend_rows
        self.capacity = capacity

    @property
    def fraction_handled(self) -> float:
        return float(self._fraction_handled)

    @property
    def backend_rows(self) -> int:
        return int(self._backend_rows)

    def as_arrays(self):
        """(fraction_handled, backend_rows) as device arrays — no sync."""
        return self._fraction_handled, self._backend_rows

    def __repr__(self):
        return (f"HybridStats(fraction_handled={self.fraction_handled:.3f}, "
                f"backend_rows={self.backend_rows}, "
                f"capacity={self.capacity})")


class HybridServer:
    # Hot-path auditor contract (repro.analysis.hotpath): the batch step
    # is audited for zero-sync and dtype layout with an empty donation
    # set — donate=True is documented below as unaliasable for the
    # current output shapes (jax would silently prune it, which is
    # exactly what the auditor exists to reject on the streaming tiers).
    AUDIT_CONTRACTS = (
        {"attr": "_step", "donate": (), "probe": "batch",
         "collectives": {}},
    )

    def __init__(self, artifact: TableArtifact, backend_fn: Callable,
                 *, threshold: float = 0.7, capacity: int = 256,
                 use_pallas: bool = False, autotune: bool = False,
                 donate: bool = False, tiles: Optional[TileConfig] = None,
                 fuse: Optional[bool] = None):
        """backend_fn: (rows (capacity, F)) -> class predictions (capacity,).

        autotune=True sweeps kernel tile sizes once for this artifact shape
        (cached per shape+backend; only meaningful — and only run — when
        use_pallas=True, since the XLA reference path ignores tile
        configs). donate=True marks the input batch
        donatable to the fused step; with the current step outputs (pred
        (N,) i32 + scalar telemetry) nothing can alias an (N, F) f32 input,
        so this is off by default — enable it if you extend the step to
        return row-shaped outputs. A caller that passes an already-float32
        jax.Array then cedes that buffer (standard donation semantics).

        fuse: None probes on the first classify whether backend_fn traces
        into the single-dispatch step; False forces the two-phase path.
        Backends that *appear* traceable but read mutable side-channels
        (per-batch state on the function object) MUST pass fuse=False —
        tracing would bake the first batch's state in as a constant.
        """
        self.artifact = finalize_artifact(artifact)
        # capacity and backend_fn are baked into the jitted step: frozen.
        # threshold is a *traced* argument, so it stays tunable per call
        # (sweeping tau never recompiles).
        self._backend_fn = backend_fn
        self._capacity = capacity
        self.threshold = threshold
        self.use_pallas = use_pallas
        # tiles only steer the Pallas kernels; sweeping them for the XLA
        # reference path would be pure init latency
        self.tiles = tiles or (autotune_tiles(self.artifact)
                               if autotune and use_pallas else DEFAULT_TILES)
        self._fused_ok = fuse                   # None = not yet probed

        def step(art, x, threshold):
            sw_pred, conf = fused_classify(art, x, use_pallas=use_pallas,
                                           tiles=self.tiles)
            fwd = conf < threshold
            buf, idx, valid = dispatch(x, fwd, capacity)
            be_pred = jnp.asarray(backend_fn(buf))
            pred = combine(sw_pred, be_pred, idx, valid)
            frac = 1.0 - jnp.mean(fwd.astype(jnp.float32))
            rows = jnp.sum(valid.astype(jnp.int32))
            return pred, frac, rows

        self._step = jax.jit(step, donate_argnums=(1,) if donate else ())

        def switch_only(art, x, threshold):
            sw_pred, conf = fused_classify(art, x, use_pallas=use_pallas,
                                           tiles=self.tiles)
            fwd = conf < threshold
            buf, idx, valid = dispatch(x, fwd, capacity)
            frac = 1.0 - jnp.mean(fwd.astype(jnp.float32))
            rows = jnp.sum(valid.astype(jnp.int32))
            return sw_pred, buf, idx, valid, frac, rows

        self._switch_only = jax.jit(switch_only)
        self._combine = jax.jit(combine)

    @property
    def capacity(self) -> int:
        """Backend buffer size. Frozen: it fixes the compiled shapes —
        build a new server to change it."""
        return self._capacity

    @property
    def backend_fn(self):
        """Frozen: traced into the fused step at construction."""
        return self._backend_fn

    def classify(self, x):
        """x (N, F) -> (pred (N,), HybridStats). Fully async: nothing here
        blocks on the device; read the stats (or the preds) to sync."""
        x = jnp.asarray(x, jnp.float32)
        tau = jnp.float32(self.threshold)
        if self._fused_ok is None:
            try:
                pred, frac, rows = self._step(self.artifact, x, tau)
                self._fused_ok = True
                return pred, HybridStats(frac, rows, self.capacity)
            except (jax.errors.JAXTypeError, TypeError):
                # backend_fn is not traceable; tracing failed before any
                # execution, so x was not consumed by the donation
                self._fused_ok = False
        if self._fused_ok:
            pred, frac, rows = self._step(self.artifact, x, tau)
            return pred, HybridStats(frac, rows, self.capacity)
        # two-phase fallback: untraceable backend runs on host between
        # the jitted switch half and the jitted combine
        sw_pred, buf, idx, valid, frac, rows = self._switch_only(
            self.artifact, x, tau)
        be_pred = jnp.asarray(self.backend_fn(buf))
        pred = self._combine(sw_pred, be_pred, idx, valid)
        return pred, HybridStats(frac, rows, self.capacity)

    def update_tables(self, artifact: TableArtifact):
        """§4.4: retraining swaps table *contents*; nothing recompiles as
        long as shapes (the model constraints) are unchanged."""
        artifact = finalize_artifact(artifact)
        try:
            same = jax.tree.map(lambda a, b: a.shape == b.shape,
                                self.artifact, artifact)
            ok = all(jax.tree.leaves(same))
        except ValueError:                      # tree structure mismatch
            ok = False
        if not ok:
            raise ValueError("table shapes changed: constraints violated "
                             "(paper §4.4 requires fixed model constraints)")
        self.artifact = artifact
