"""Serving runtime: cache plumbing, prefill/decode engine, hybrid tier
(batch and streaming)."""

from repro.serving.engine import ServeEngine, greedy_generate
from repro.serving.hybrid_serving import HybridServer
from repro.serving.stream_serving import StreamingHybridServer, StreamStats
from repro.serving.shard_serving import ShardedStreamingServer
