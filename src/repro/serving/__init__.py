"""Serving runtime: cache plumbing, prefill/decode engine, hybrid tier."""

from repro.serving.engine import ServeEngine, greedy_generate
from repro.serving.hybrid_serving import HybridServer
