"""recurrentgemma-2b [hybrid] — Griffin: RG-LRU + local attention, 2:1.

26L d_model=2560 10H (MQA kv=1, head_dim 256) d_ff=7680 vocab=256000,
local window 2048  [arXiv:2402.19427]
"""

from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    n_layers=26,
    d_model=2560,
    n_heads=10,
    n_kv_heads=1,
    d_ff=7680,
    vocab_size=256_000,
    d_head=256,
    block_pattern=("rglru", "rglru", "local_attn"),
    local_window=2048,
    rglru_width=2560,
    conv1d_width=4,
    tie_embeddings=True,
)


def smoke():
    return CONFIG.scaled(n_layers=6, d_model=64, n_heads=2, n_kv_heads=1,
                         d_ff=96, vocab_size=256, d_head=32,
                         local_window=16, rglru_width=64)
