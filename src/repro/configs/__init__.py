"""Architecture registry: ``--arch <id>`` resolves here.

Each module holds CONFIG (the exact published numbers) and smoke()
(a reduced same-family config for CPU tests).
"""

from __future__ import annotations

import importlib

ARCH_IDS = [
    "deepseek-v3-671b",
    "arctic-480b",
    "xlstm-1.3b",
    "qwen3-4b",
    "qwen2.5-32b",
    "h2o-danube-1.8b",
    "yi-6b",
    "whisper-base",
    "phi-3-vision-4.2b",
    "recurrentgemma-2b",
]

_MOD = {a: "repro.configs." + a.replace("-", "_").replace(".", "_")
        for a in ARCH_IDS}


def get_config(arch_id: str):
    if arch_id not in _MOD:
        raise KeyError(f"unknown arch {arch_id!r}; choices: {ARCH_IDS}")
    return importlib.import_module(_MOD[arch_id]).CONFIG


def get_smoke_config(arch_id: str):
    return importlib.import_module(_MOD[arch_id]).smoke()
