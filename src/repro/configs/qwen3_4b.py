"""qwen3-4b [dense] — GQA with qk-norm, decoupled head_dim=128.

36L d_model=2560 32H (GQA kv=8) d_ff=9728 vocab=151936  [hf:Qwen/Qwen3-*]
"""

from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-4b",
    family="dense",
    n_layers=36,
    d_model=2560,
    n_heads=32,
    n_kv_heads=8,
    d_ff=9728,
    vocab_size=151936,
    d_head=128,
    qk_norm=True,
    rope_theta=1_000_000.0,
)


def smoke():
    return CONFIG.scaled(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                         d_ff=128, vocab_size=256, d_head=16)
