"""phi-3-vision-4.2b [vlm] — phi3-mini decoder + CLIP patch frontend STUB
(input_specs provides precomputed patch embeddings, CLIP-L/14 width 1024).

32L d_model=3072 32H (kv=32) d_ff=8192 vocab=32064
[hf:microsoft/Phi-3-vision-128k-instruct]
"""

from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="phi-3-vision-4.2b",
    family="vlm",
    n_layers=32,
    d_model=3072,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab_size=32064,
    frontend="image_patches",
    n_frontend_tokens=576,       # 336px / 14 patch = 24x24
    frontend_dim=1024,           # CLIP-L/14 hidden
)


def smoke():
    return CONFIG.scaled(n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
                         d_ff=128, vocab_size=256, n_frontend_tokens=4,
                         frontend_dim=32)
