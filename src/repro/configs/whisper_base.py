"""whisper-base [audio] — encoder-decoder; conv frontend is a STUB
(input_specs provides precomputed frame embeddings at d_model).

6L enc + 6L dec, d_model=512 8H d_ff=2048 vocab=51865  [arXiv:2212.04356]
"""

from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="whisper-base",
    family="audio",
    n_layers=6,                  # decoder layers
    d_model=512,
    n_heads=8,
    n_kv_heads=8,
    d_ff=2048,
    vocab_size=51865,
    encdec=True,
    n_encoder_layers=6,
    norm="layernorm",
    tie_embeddings=True,
    frontend="audio_frames",
    n_frontend_tokens=1500,      # 30s of audio at 50 Hz post-conv
    frontend_dim=512,
)


def smoke():
    return CONFIG.scaled(n_layers=2, d_model=32, n_heads=2, n_kv_heads=2,
                         d_ff=64, vocab_size=128, n_encoder_layers=2,
                         n_frontend_tokens=8, frontend_dim=32)
