"""arctic-480b [moe] — 128 experts top-2 + always-on dense residual branch.

35L d_model=7168 56H (GQA kv=8) d_ff=4864 vocab=32000
[hf:Snowflake/snowflake-arctic-base]
"""

from repro.models.config import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="arctic-480b",
    family="moe",
    n_layers=35,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=4864,
    vocab_size=32000,
    moe=MoEConfig(n_experts=128, top_k=2, d_expert=4864,
                  dense_residual=True, dense_d_ff=4864,
                  capacity_factor=1.25),
)


def smoke():
    return CONFIG.scaled(
        n_layers=2, d_model=64, n_heads=8, n_kv_heads=2, d_ff=96,
        vocab_size=256,
        moe=MoEConfig(n_experts=4, top_k=2, d_expert=48,
                      dense_residual=True, dense_d_ff=48,
                      capacity_factor=1.5),
    )
