"""deepseek-v3-671b [moe] — MLA, 1 shared + 256 routed top-8 experts, MTP.

61L d_model=7168 128H d_ff(expert)=2048 vocab=129280  [arXiv:2412.19437; hf]
First 3 layers use a dense FFN (18432, the published dense intermediate
size); remaining 58 are MoE with 2048-wide experts.
"""

from repro.models.config import ArchConfig, MLAConfig, MoEConfig

CONFIG = ArchConfig(
    name="deepseek-v3-671b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=128,
    n_kv_heads=128,
    d_ff=18432,                      # dense-prefix FFN width
    vocab_size=129280,
    attn_kind="mla",
    mla=MLAConfig(q_lora_rank=1536, kv_lora_rank=512,
                  qk_nope_dim=128, qk_rope_dim=64, v_head_dim=128),
    moe=MoEConfig(n_experts=256, top_k=8, d_expert=2048, n_shared=1,
                  n_dense_layers=3, capacity_factor=1.25),
    mtp=True,
    rope_theta=10000.0,
)


def smoke():
    return CONFIG.scaled(
        n_layers=4, d_model=64, n_heads=4, n_kv_heads=4, d_ff=96,
        vocab_size=256,
        mla=MLAConfig(q_lora_rank=32, kv_lora_rank=16,
                      qk_nope_dim=16, qk_rope_dim=8, v_head_dim=16),
        moe=MoEConfig(n_experts=4, top_k=2, d_expert=32, n_shared=1,
                      n_dense_layers=1, capacity_factor=1.5),
    )
