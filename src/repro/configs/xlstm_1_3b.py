"""xlstm-1.3b [ssm] — mLSTM + sLSTM blocks, 7:1 ratio (xLSTM[7:1]).

48L d_model=2048 4H d_ff=0 (blocks carry their own projections)
vocab=50304  [arXiv:2405.04517]
"""

from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="xlstm-1.3b",
    family="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    block_pattern=("mlstm",) * 7 + ("slstm",),
    tie_embeddings=True,
)


def smoke():
    return CONFIG.scaled(
        n_layers=4, d_model=32, n_heads=2, n_kv_heads=2, d_ff=0,
        vocab_size=128, block_pattern=("mlstm", "mlstm", "mlstm", "slstm"),
    )
