"""Jaxpr hot-path auditor (rule section ``hotpath``).

Builds tiny instances of every serving tier — `HybridServer`,
`StreamingHybridServer` (per-window and chunked), and
`ShardedStreamingServer` — and statically proves, on their *actual*
jitted closures, the contracts the code and DESIGN.md §5/§6/§8 claim:

* **donation** — every leaf of every ``donate_argnums`` buffer in each
  server's ``AUDIT_CONTRACTS`` really aliases an output in the compiled
  HLO (``input_output_alias``). jax prunes unusable donations *silently*,
  so a refactor that breaks aliasing (e.g. changing a carry's dtype or
  dropping it from the outputs) shows up as a silent extra copy per
  window — this rule turns that into a CI failure.
* **zero-sync** — no host-callback / infeed / outfeed / device_put
  primitive anywhere in the step jaxprs: the serving loop never blocks
  on the host.
* **dtype layout** — the traced steps use only the DESIGN.md register
  layout (f32 registers/conf, i32/bool control); any f64 promotion or
  stray wide integer fails.
* **collectives** — the sharded steps contain *exactly* the promised
  collective census: the psum counts (one rank>=2 "readout" psum per
  step/chunk, DESIGN.md §6/§8) plus the partitioned-classify lane-slab
  merges (one rank>=2 reduce_scatter and two all_gathers per step,
  DESIGN.md §16) — no accidental extra merges, and no silent fallback
  to the replicated-classify layout (losing the scatter would change
  the census too).

Servers declare what to audit via ``AUDIT_CONTRACTS`` rows
(attr/donate/probe/collectives); the auditor owns *how* to check.
"""

from __future__ import annotations

import functools
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis import jaxpr_utils as JU
from repro.analysis.registry import Finding, Rule, register

# Every dtype the serving jaxprs are allowed to touch (DESIGN.md §5/§8:
# f32 registers + conf_sum, i32 stats counters, bool masks).
ALLOWED_DTYPES = frozenset({"float32", "int32", "bool"})

# Small probe geometry: big enough to exercise every code path (scatter
# conflicts, dispatch, chunk scan), small enough to trace in ~seconds.
PROBE = dict(window=32, n_buckets=64, capacity=8, chunk_windows=4,
             threshold=0.7, seed=0)


def _traceable_backend(rows):
    """A backend the fused step can trace through (all-zeros answers)."""
    return jnp.zeros(rows.shape[0], jnp.int32)


@functools.lru_cache(maxsize=1)
def _probe_artifact():
    """Tiny finalized RF artifact over the FLOW_FEATURES readout layout
    (the streaming tiers' readout emits FLOW_FEATURES-wide rows, so the
    probe model must be trained on that many features)."""
    from repro.core.artifact import finalize_artifact
    from repro.core.mapping import map_tree_ensemble
    from repro.ml.trees import fit_random_forest
    from repro.netsim.stream import FLOW_FEATURES
    rng = np.random.RandomState(0)
    x = rng.rand(256, FLOW_FEATURES).astype(np.float32) * 1500.0
    y = (x[:, 0] > x[:, 1]).astype(np.int32)
    m = fit_random_forest(x, y, n_classes=2, n_trees=3, max_depth=3)
    return finalize_artifact(map_tree_ensemble(m, FLOW_FEATURES))


@functools.lru_cache(maxsize=1)
def _audit_targets():
    """(label, server, contract, args) rows for every audited step."""
    from repro.serving.hybrid_serving import HybridServer
    from repro.serving.shard_serving import ShardedStreamingServer
    from repro.serving.stream_serving import (StreamingHybridServer,
                                              probe_chunk, probe_window)
    art = _probe_artifact()
    p = PROBE
    servers = [
        ("HybridServer",
         HybridServer(art, _traceable_backend, capacity=p["capacity"])),
        ("StreamingHybridServer",
         StreamingHybridServer(art, _traceable_backend,
                               n_buckets=p["n_buckets"],
                               window=p["window"], capacity=p["capacity"])),
        ("StreamingHybridServer[chunked]",
         StreamingHybridServer(art, _traceable_backend,
                               n_buckets=p["n_buckets"], window=p["window"],
                               capacity=p["capacity"],
                               chunk_windows=p["chunk_windows"])),
        ("ShardedStreamingServer",
         ShardedStreamingServer(art, _traceable_backend, n_shards=1,
                                n_buckets=p["n_buckets"], window=p["window"],
                                capacity=p["capacity"],
                                chunk_windows=p["chunk_windows"])),
    ]
    if jax.device_count() >= 4:
        # the census contracts are mesh-shape-invariant; audit the real
        # 2D ('shard', 'data') layout whenever the host platform provides
        # the devices (CI's 4-host-device step)
        from repro.distributed.sharding import flow_shard_mesh
        servers.append(
            ("ShardedStreamingServer[2x2]",
             ShardedStreamingServer(art, _traceable_backend,
                                    mesh=flow_shard_mesh(2, 2),
                                    n_buckets=p["n_buckets"],
                                    window=p["window"],
                                    capacity=p["capacity"],
                                    chunk_windows=p["chunk_windows"])))
    w = probe_window(p["window"], p["n_buckets"], p["seed"])
    chunk = probe_chunk(p["window"], p["chunk_windows"], p["n_buckets"],
                        p["seed"])
    xbatch = jnp.asarray(
        np.random.RandomState(p["seed"])
        .rand(p["window"], art.edges.shape[0]).astype(np.float32))
    tau = jnp.float32(p["threshold"])

    targets = []
    for label, srv in servers:
        for contract in srv.AUDIT_CONTRACTS:
            attr = contract["attr"]
            if label.endswith("[chunked]") and contract["probe"] != "chunk":
                continue    # window steps already audited on the
                #             per-window instance; don't trace them twice
            if not hasattr(srv, attr):
                targets.append((f"{label}.{attr}", srv, contract, None))
                continue
            if contract["probe"] == "window":
                args = (srv.artifact, srv._state, srv._stats, w, tau)
            elif contract["probe"] == "chunk":
                if srv.chunk_windows is None:
                    continue            # per-window server: no chunk step
                args = (srv.artifact, srv._state, srv._stats, chunk, tau)
            elif contract["probe"] == "batch":
                args = (srv.artifact, xbatch, tau)
            else:
                raise ValueError(f"unknown probe {contract['probe']!r}")
            # _stream_switch takes (art, state, w, tau) — no stats carry
            if attr == "_stream_switch":
                args = (srv.artifact, srv._state, w, tau)
            targets.append((f"{label}.{attr}", srv, contract, args))
    return targets


@functools.lru_cache(maxsize=None)
def _traced(label: str):
    """(closed_jaxpr, compiled_text, contract, args) for one target —
    traced once, shared by all four rules."""
    for tlabel, srv, contract, args in _audit_targets():
        if tlabel == label:
            if args is None:
                return None
            fn = getattr(srv, contract["attr"])
            return (JU.closed_jaxpr(fn, *args), JU.compiled_text(fn, *args),
                    contract, args)
    raise KeyError(label)


def _target_labels() -> List[str]:
    return [label for label, _, _, _ in _audit_targets()]


# -- rules ------------------------------------------------------------------


def check_donation() -> List[Finding]:
    out: List[Finding] = []
    for label in _target_labels():
        traced = _traced(label)
        if traced is None:
            out.append(Finding(rule="hotpath-donation",
                               message=f"{label}: contracted step attribute "
                                       "is missing on the server"))
            continue
        _, text, contract, args = traced
        want = JU.count_donated_leaves(args, contract["donate"])
        got = JU.donation_alias_count(text)
        if got < want:
            out.append(Finding(
                rule="hotpath-donation",
                message=(f"{label}: only {got}/{want} donated buffer leaves "
                         "alias an output in the compiled HLO — jax "
                         "silently pruned the rest (an extra device copy "
                         "per step)")))
    return out


def check_zero_sync() -> List[Finding]:
    out: List[Finding] = []
    for label in _target_labels():
        traced = _traced(label)
        if traced is None:
            continue                     # donation rule already reports it
        jaxpr, _, _, _ = traced
        hits = JU.forbidden_primitives(jaxpr)
        if hits:
            out.append(Finding(
                rule="hotpath-zero-sync",
                message=(f"{label}: host-sync/transfer primitives in the "
                         f"serving step jaxpr: {sorted(set(hits))}")))
    return out


def check_dtypes() -> List[Finding]:
    out: List[Finding] = []
    for label in _target_labels():
        traced = _traced(label)
        if traced is None:
            continue
        jaxpr, _, _, _ = traced
        bad = sorted(JU.jaxpr_dtypes(jaxpr) - ALLOWED_DTYPES)
        if bad:
            out.append(Finding(
                rule="hotpath-dtype",
                message=(f"{label}: dtypes outside the DESIGN.md §5/§8 "
                         f"register layout {sorted(ALLOWED_DTYPES)}: {bad}")))
    return out


def _readout_psum_count(jaxpr) -> int:
    """psum equations whose outputs are rank >= 2 (the readout merges)."""
    n = 0
    for eqn in JU.iter_eqns(jaxpr):
        if JU._normalize(eqn.primitive.name) == "psum":
            if any(getattr(v.aval, "ndim", 0) >= 2 for v in eqn.outvars):
                n += 1
    return n


def _readout_scatter_count(jaxpr) -> int:
    """reduce_scatter equations whose outputs are rank >= 2 (the
    partitioned classify's lane-slab merge — jax lowers psum_scatter to
    the reduce_scatter primitive). Rank >= 2 distinguishes the (T, F)
    feature-row scatter from any scalar/vector reduction that might
    legitimately appear."""
    n = 0
    for eqn in JU.iter_eqns(jaxpr):
        if JU._normalize(eqn.primitive.name) == "reduce_scatter":
            if any(getattr(v.aval, "ndim", 0) >= 2 for v in eqn.outvars):
                n += 1
    return n


def check_collectives() -> List[Finding]:
    out: List[Finding] = []
    for label in _target_labels():
        traced = _traced(label)
        if traced is None:
            continue
        jaxpr, _, contract, _ = traced
        census = JU.collective_census(jaxpr)
        want = dict(contract.get("collectives", {}))
        if census != want:
            out.append(Finding(
                rule="hotpath-collectives",
                message=(f"{label}: collective census {census} != "
                         f"contracted {want}")))
        want_readout = contract.get("readout_psums")
        if want_readout is not None:
            got = _readout_psum_count(jaxpr)
            if got != want_readout:
                out.append(Finding(
                    rule="hotpath-collectives",
                    message=(f"{label}: {got} rank>=2 readout psums, "
                             f"contract promises exactly {want_readout} "
                             "(DESIGN.md §6/§8)")))
        want_scatter = contract.get("readout_scatters")
        if want_scatter is not None:
            got = _readout_scatter_count(jaxpr)
            if got != want_scatter:
                out.append(Finding(
                    rule="hotpath-collectives",
                    message=(f"{label}: {got} rank>=2 lane-slab "
                             f"reduce_scatters, contract promises exactly "
                             f"{want_scatter} (DESIGN.md §16)")))
    return out


# -- seeded-violation self-tests --------------------------------------------


def _selftest_donation() -> List[Finding]:
    """A step that drops its donated carry from the outputs must be
    caught: jax prunes the alias with no warning."""
    import warnings

    def bad_step(state, w):
        return jnp.sum(state * w)        # state (donated) cannot alias a scalar
    jitted = jax.jit(bad_step, donate_argnums=(0,))
    args = (jnp.zeros((8, 8), jnp.float32), jnp.ones((8, 8), jnp.float32))
    with warnings.catch_warnings():
        # the seeded violation legitimately trips jax's donation warning
        warnings.simplefilter("ignore")
        text = JU.compiled_text(jitted, *args)
    want = JU.count_donated_leaves(args, (0,))
    got = JU.donation_alias_count(text)
    if got < want:
        return [Finding(rule="hotpath-donation",
                        message=f"selftest: {got}/{want} leaves aliased")]
    return []


def _selftest_zero_sync() -> List[Finding]:
    def bad_step(x):
        return jax.pure_callback(lambda a: a,
                                 jax.ShapeDtypeStruct(x.shape, x.dtype), x)
    jaxpr = JU.closed_jaxpr(jax.jit(bad_step), jnp.zeros(4, jnp.float32))
    hits = JU.forbidden_primitives(jaxpr)
    if hits:
        return [Finding(rule="hotpath-zero-sync",
                        message=f"selftest: {sorted(set(hits))}")]
    return []


def _selftest_dtypes() -> List[Finding]:
    prev = jax.config.jax_enable_x64
    try:
        jax.config.update("jax_enable_x64", True)

        def bad_step(x):
            return jnp.cumsum(x.astype(jnp.float64))
        jaxpr = JU.closed_jaxpr(bad_step, jnp.zeros(4, jnp.float32))
    finally:
        jax.config.update("jax_enable_x64", prev)
    bad = sorted(JU.jaxpr_dtypes(jaxpr) - ALLOWED_DTYPES)
    if bad:
        return [Finding(rule="hotpath-dtype",
                        message=f"selftest: {bad}")]
    return []


def _selftest_collectives() -> List[Finding]:
    """Seeded census violations must be caught: extra psums, extra
    reduce_scatters (wrong count), and a rank-1 scatter masquerading as
    the rank>=2 lane-slab merge (wrong rank)."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import Mesh, PartitionSpec as P
    mesh = Mesh(np.array(jax.devices()[:1]), ("shard",))
    out: List[Finding] = []

    def chatty(x):
        return jax.lax.psum(jax.lax.psum(x, "shard"), "shard")
    fn = jax.jit(shard_map(chatty, mesh=mesh, in_specs=(P(),),
                           out_specs=P()))
    jaxpr = JU.closed_jaxpr(fn, jnp.zeros((4, 4), jnp.float32))
    census = JU.collective_census(jaxpr)
    if census != {"psum": 1}:
        out.append(Finding(rule="hotpath-collectives",
                           message=f"selftest: census {census} != promised "
                                   "{'psum': 1}"))

    # wrong count: two lane-slab scatters where the contract promises one
    def double_scatter(x):
        s = jax.lax.psum_scatter(x, "shard", scatter_dimension=0, tiled=True)
        return jax.lax.psum_scatter(s, "shard", scatter_dimension=0,
                                    tiled=True)
    fn = jax.jit(shard_map(double_scatter, mesh=mesh, in_specs=(P(),),
                           out_specs=P(), check_rep=False))
    jaxpr = JU.closed_jaxpr(fn, jnp.zeros((4, 4), jnp.float32))
    census = JU.collective_census(jaxpr)
    if census.get("reduce_scatter") != 1:
        out.append(Finding(
            rule="hotpath-collectives",
            message=(f"selftest: census {census} != promised "
                     "{'reduce_scatter': 1}")))

    # wrong rank: a rank-1 scatter is NOT the (T, F) lane-slab merge —
    # _readout_scatter_count must refuse to count it toward the contract
    def vector_scatter(x):
        return jax.lax.psum_scatter(x, "shard", scatter_dimension=0,
                                    tiled=True)
    fn = jax.jit(shard_map(vector_scatter, mesh=mesh, in_specs=(P(),),
                           out_specs=P(), check_rep=False))
    jaxpr = JU.closed_jaxpr(fn, jnp.zeros((8,), jnp.float32))
    got = _readout_scatter_count(jaxpr)
    if got != 1:
        out.append(Finding(
            rule="hotpath-collectives",
            message=(f"selftest: {got} rank>=2 lane-slab reduce_scatters, "
                     "contract promises exactly 1")))
    return out


def register_rules() -> None:
    register(Rule(name="hotpath-donation", section="hotpath",
                  doc="every contracted donate_argnums leaf aliases an "
                      "output in the compiled HLO (no silent copy)",
                  check=check_donation, selftest=_selftest_donation))
    register(Rule(name="hotpath-zero-sync", section="hotpath",
                  doc="no host callback / infeed / outfeed / device_put "
                      "primitives inside the jitted serving steps",
                  check=check_zero_sync, selftest=_selftest_zero_sync))
    register(Rule(name="hotpath-dtype", section="hotpath",
                  doc="serving-step jaxprs use only the f32/i32/bool "
                      "register layout (no f64 promotion)",
                  check=check_dtypes, selftest=_selftest_dtypes))
    register(Rule(name="hotpath-collectives", section="hotpath",
                  doc="sharded steps carry exactly the contracted "
                      "collective census (one readout psum and one "
                      "lane-slab reduce_scatter per step/chunk)",
                  check=check_collectives, selftest=_selftest_collectives))
