"""``python -m repro.analysis`` — run the static-analysis gate.

Exit status: 0 iff every rule is clean (no findings, no rule crashes,
and — with self-tests on — every rule's seeded violation fired).

    python -m repro.analysis                 # human output, all sections
    python -m repro.analysis --json          # machine output
    python -m repro.analysis --strict        # CI gate (self-tests forced on)
    python -m repro.analysis --section lint  # one section only
"""

from __future__ import annotations

import argparse
import json
import sys
from typing import List, Optional

from repro.analysis.registry import SECTIONS, AnalysisReport, run_rules


def _register_all() -> None:
    from repro.analysis import fit, hotpath, lint
    for mod in (lint, fit, hotpath):
        mod.register_rules()


def _human(report: AnalysisReport) -> str:
    lines: List[str] = []
    for res in report.results:
        status = "OK"
        if res.error:
            status = f"CRASH ({res.error})"
        elif res.findings:
            status = f"{len(res.findings)} finding(s)"
        elif res.selftest_fired is False:
            status = "SELFTEST SILENT (rule is a no-op)"
        lines.append(f"[{res.section:7s}] {res.rule:28s} {status:30s} "
                     f"{res.elapsed_s:6.2f}s")
        for f in res.findings:
            lines.append(f"    {f.format()}")
    lines.append(f"{'PASS' if report.ok else 'FAIL'}: "
                 f"{len(report.results)} rules, "
                 f"{len(report.findings)} findings")
    return "\n".join(lines)


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="static-analysis gate: AST lint, jaxpr hot-path "
                    "auditor, device resource-fit checker")
    ap.add_argument("--json", action="store_true",
                    help="machine-readable report on stdout")
    ap.add_argument("--strict", action="store_true",
                    help="CI mode: self-tests forced on; nonzero exit on "
                         "any finding, rule crash, or silent self-test")
    ap.add_argument("--section", choices=SECTIONS, action="append",
                    help="run only this section (repeatable)")
    ap.add_argument("--no-selftests", action="store_true",
                    help="skip the seeded-violation self-tests "
                         "(ignored under --strict)")
    args = ap.parse_args(argv)

    _register_all()
    selftests = args.strict or not args.no_selftests
    report = run_rules(sections=args.section, selftests=selftests)
    if args.json:
        print(json.dumps(report.to_json(), indent=2, sort_keys=True))
    else:
        print(_human(report))
    return 0 if report.ok else 1


if __name__ == "__main__":
    sys.exit(main())
