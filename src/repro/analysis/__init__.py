"""repro.analysis — static analysis gate for the serving stack.

Three subsystems behind one rule registry and one CLI
(``python -m repro.analysis``, DESIGN.md §15):

* **hotpath** — jaxpr/HLO auditor proving the serving tiers' load-bearing
  contracts on every commit: declared donations really alias (no silent
  copy), no host callbacks or transfers inside the jitted steps
  (zero-sync), register/stats dtype layout (f32 registers, i32 counters,
  f32 conf_sum, no f64), and the exact collective census the sharded
  steps promise (one readout psum per chunk).
* **lint** — custom AST pass over ``src/``: host-sync idioms inside
  jitted functions, broad ``except`` without justification, module-level
  ``os.environ`` mutation, jitted ``*_state`` carries without donation.
* **fit** — switch resource-fit checker (``core.resources.check_fit``)
  mapping artifacts against declarative :class:`DeviceProfile` budgets
  before deploy, Planter-style.

Every rule carries a seeded-violation self-test (``--strict`` runs them)
so the analyzer can never rot into a silent no-op.
"""

from repro.analysis.registry import (AnalysisReport, Finding, Rule,  # noqa: F401
                                     RULES, iter_rules, register, run_rules)
from repro.analysis.lint import lint_paths, lint_source  # noqa: F401
