"""Custom AST lint pass over ``src/`` (rule section ``lint``).

Four repo-specific rules no off-the-shelf linter ships:

* ``lint-host-sync-in-jit`` — host-sync idioms (``float(x)``,
  ``int(x)``, ``np.asarray``/``np.array``, ``.item()``,
  ``jax.device_get``) inside a function that is jitted or shard_mapped
  anywhere in the same module (``jax.jit(fn)``, ``@jax.jit``,
  ``functools.partial`` wrapping included). Each of these forces a
  blocking device->host transfer per call — the exact failure mode the
  serving loop's zero-sync design exists to avoid.
* ``lint-broad-except`` — ``except Exception`` / bare ``except`` without
  a justification comment on the same or previous line. Accepted
  waivers: ``noqa: BLE001`` (the ``obs/metrics.py`` idiom) or
  ``lint: allow-broad-except``; both must carry a reason after the tag.
* ``lint-env-mutation`` — module-level ``os.environ`` mutation outside
  ``launch/`` entrypoints (imports must be side-effect free; an env
  tweak at import time reorders against jax backend init in whatever
  module happens to import first). Waiver: ``lint: allow-env-mutation``.
* ``lint-missing-donate`` — ``jax.jit(fn)`` where ``fn``'s parameters
  include a ``state``/``*_state``/``stats`` carry but no
  ``donate_argnums``/``donate_argnames`` was passed: the carry is
  copied every step instead of reused in place.
"""

from __future__ import annotations

import ast
import os
from typing import Dict, Iterable, List, Optional, Set, Tuple

from repro.analysis.registry import Finding, Rule, register

SRC_ROOT = os.path.join(os.path.dirname(os.path.dirname(
    os.path.dirname(os.path.abspath(__file__)))))        # .../repo/src

WAIVER_TAGS = ("noqa: BLE001", "lint: allow-broad-except")
ENV_WAIVER_TAG = "lint: allow-env-mutation"

# Carry-parameter names whose jit should donate them.
CARRY_NAMES = ("state", "stats")

HOST_SYNC_CALLS = {"float", "int", "bool"}
NUMPY_SYNC_ATTRS = {"asarray", "array"}


def iter_source_files(root: str = SRC_ROOT) -> Iterable[str]:
    for dirpath, dirnames, filenames in os.walk(root):
        dirnames[:] = [d for d in dirnames if d != "__pycache__"]
        for fn in sorted(filenames):
            if fn.endswith(".py"):
                yield os.path.join(dirpath, fn)


def _is_launch_module(path: str) -> bool:
    parts = os.path.normpath(path).split(os.sep)
    return "launch" in parts


def _numpy_aliases(tree: ast.Module) -> Set[str]:
    """Module-level aliases of the numpy module (``import numpy as np``)."""
    aliases = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for alias in node.names:
                if alias.name == "numpy":
                    aliases.add(alias.asname or "numpy")
    return aliases


# -- rule: host-sync idioms inside jitted functions -------------------------


def _unwrap_partial(call: ast.Call) -> Optional[ast.expr]:
    """functools.partial(fn, ...) -> fn (one level)."""
    f = call.func
    name = None
    if isinstance(f, ast.Attribute):
        name = f.attr
    elif isinstance(f, ast.Name):
        name = f.id
    if name == "partial" and call.args:
        return call.args[0]
    return None


def _is_jit_callable(func: ast.expr) -> bool:
    """``jax.jit`` / ``jit`` / ``shard_map`` / ``pjit`` reference?"""
    if isinstance(func, ast.Attribute):
        return func.attr in ("jit", "pjit", "shard_map")
    if isinstance(func, ast.Name):
        return func.id in ("jit", "pjit", "shard_map")
    return False


def _jitted_names(tree: ast.Module) -> Set[str]:
    """Names of functions that get jitted/shard_mapped in this module."""
    jitted: Set[str] = set()

    def first_name(arg: ast.expr) -> Optional[str]:
        if isinstance(arg, ast.Name):
            return arg.id
        if isinstance(arg, ast.Call):
            inner = _unwrap_partial(arg)
            if inner is not None:
                return first_name(inner)
        return None

    for node in ast.walk(tree):
        if isinstance(node, ast.Call) and _is_jit_callable(node.func):
            if node.args:
                name = first_name(node.args[0])
                if name:
                    jitted.add(name)
        elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            for dec in node.decorator_list:
                target = dec.func if isinstance(dec, ast.Call) else dec
                if _is_jit_callable(target):
                    jitted.add(node.name)
    return jitted


def _check_host_sync(path: str, tree: ast.Module) -> List[Finding]:
    jitted = _jitted_names(tree)
    if not jitted:
        return []
    np_aliases = _numpy_aliases(tree)
    out: List[Finding] = []

    class Visitor(ast.NodeVisitor):
        def __init__(self):
            self.fn_stack: List[str] = []

        def _in_jitted(self) -> bool:
            return any(name in jitted for name in self.fn_stack)

        def visit_FunctionDef(self, node):
            self.fn_stack.append(node.name)
            self.generic_visit(node)
            self.fn_stack.pop()

        visit_AsyncFunctionDef = visit_FunctionDef

        def visit_Call(self, node: ast.Call):
            if self._in_jitted():
                bad = None
                f = node.func
                if isinstance(f, ast.Name) and f.id in HOST_SYNC_CALLS \
                        and node.args \
                        and not isinstance(node.args[0], ast.Constant):
                    bad = f"{f.id}(...) on a traced value"
                elif isinstance(f, ast.Attribute):
                    if f.attr == "item":
                        bad = ".item()"
                    elif (f.attr in NUMPY_SYNC_ATTRS
                          and isinstance(f.value, ast.Name)
                          and f.value.id in np_aliases):
                        bad = f"{f.value.id}.{f.attr}(...)"
                    elif f.attr == "device_get":
                        bad = "jax.device_get(...)"
                if bad:
                    out.append(Finding(
                        rule="lint-host-sync-in-jit",
                        message=(f"host-sync idiom {bad} inside jitted "
                                 f"function {'/'.join(self.fn_stack)!r}"),
                        path=path, line=node.lineno))
            self.generic_visit(node)

    Visitor().visit(tree)
    return out


# -- rule: broad except without justification -------------------------------


def _has_waiver(lines: List[str], lineno: int, tags: Tuple[str, ...]) -> bool:
    """Waiver tag on the flagged line or the line above it."""
    for ln in (lineno, lineno - 1):
        if 1 <= ln <= len(lines) and any(t in lines[ln - 1] for t in tags):
            return True
    return False


def _check_broad_except(path: str, tree: ast.Module,
                        lines: List[str]) -> List[Finding]:
    out: List[Finding] = []
    for node in ast.walk(tree):
        if not isinstance(node, ast.ExceptHandler):
            continue
        broad = node.type is None or (
            isinstance(node.type, ast.Name)
            and node.type.id in ("Exception", "BaseException"))
        if broad and not _has_waiver(lines, node.lineno, WAIVER_TAGS):
            what = ("bare except" if node.type is None
                    else f"except {node.type.id}")
            out.append(Finding(
                rule="lint-broad-except",
                message=(f"{what} without justification — narrow it or "
                         "add '# noqa: BLE001 — <reason>'"),
                path=path, line=node.lineno))
    return out


# -- rule: module-level os.environ mutation ---------------------------------


def _env_mutations(tree: ast.Module) -> List[ast.stmt]:
    """Top-level statements that write os.environ."""

    def is_environ(expr: ast.expr) -> bool:
        return (isinstance(expr, ast.Attribute) and expr.attr == "environ"
                and isinstance(expr.value, ast.Name)
                and expr.value.id == "os")

    hits = []
    for node in tree.body:                       # module top level only
        for sub in ast.walk(node):
            if isinstance(sub, (ast.FunctionDef, ast.AsyncFunctionDef)):
                break                            # defs run later, not at import
            if isinstance(sub, ast.Assign) and any(
                    isinstance(t, ast.Subscript) and is_environ(t.value)
                    for t in sub.targets):
                hits.append(sub)
            elif isinstance(sub, ast.Call):
                f = sub.func
                if (isinstance(f, ast.Attribute)
                        and f.attr in ("setdefault", "update", "pop")
                        and is_environ(f.value)):
                    hits.append(sub)
    return hits


def _check_env_mutation(path: str, tree: ast.Module,
                        lines: List[str]) -> List[Finding]:
    if _is_launch_module(path):
        return []
    out = []
    for node in _env_mutations(tree):
        if _has_waiver(lines, node.lineno, (ENV_WAIVER_TAG,)):
            continue
        out.append(Finding(
            rule="lint-env-mutation",
            message=("module-level os.environ mutation outside launch/ — "
                     "imports must be side-effect free (waive with "
                     f"'# {ENV_WAIVER_TAG} — <reason>')"),
            path=path, line=node.lineno))
    return out


# -- rule: jitted carry without donation ------------------------------------


def _is_carry_param(name: str) -> bool:
    return name in CARRY_NAMES or name.endswith("_state")


def _check_missing_donate(path: str, tree: ast.Module) -> List[Finding]:
    # map function name -> its positional parameter names
    fn_params: Dict[str, List[str]] = {}
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            fn_params[node.name] = [a.arg for a in node.args.args]

    def is_jit_only(func: ast.expr) -> bool:
        # shard_map has no donate kwarg — only jit/pjit are in scope here
        if isinstance(func, ast.Attribute):
            return func.attr in ("jit", "pjit")
        return isinstance(func, ast.Name) and func.id in ("jit", "pjit")

    out: List[Finding] = []
    for node in ast.walk(tree):
        if not (isinstance(node, ast.Call) and is_jit_only(node.func)
                and node.args and isinstance(node.args[0], ast.Name)):
            continue
        params = fn_params.get(node.args[0].id)
        if params is None or not any(_is_carry_param(p) for p in params):
            continue
        kw = {k.arg for k in node.keywords}
        if not kw & {"donate_argnums", "donate_argnames"}:
            carry = [p for p in params if _is_carry_param(p)]
            out.append(Finding(
                rule="lint-missing-donate",
                message=(f"jit of {node.args[0].id!r} takes carry "
                         f"parameter(s) {carry} but passes no "
                         "donate_argnums/donate_argnames — the carry is "
                         "copied every step"),
                path=path, line=node.lineno))
    return out


# -- driver -----------------------------------------------------------------


def lint_source(path: str, source: str) -> List[Finding]:
    """All lint findings for one module's source text."""
    try:
        tree = ast.parse(source, filename=path)
    except SyntaxError as exc:
        return [Finding(rule="lint-parse", path=path, line=exc.lineno or 0,
                        message=f"syntax error: {exc.msg}")]
    lines = source.splitlines()
    findings: List[Finding] = []
    findings += _check_host_sync(path, tree)
    findings += _check_broad_except(path, tree, lines)
    findings += _check_env_mutation(path, tree, lines)
    findings += _check_missing_donate(path, tree)
    return findings


def lint_paths(paths: Optional[Iterable[str]] = None) -> List[Finding]:
    findings: List[Finding] = []
    for path in (paths if paths is not None else iter_source_files()):
        with open(path, "r", encoding="utf-8") as fh:
            source = fh.read()
        rel = os.path.relpath(path, os.path.dirname(SRC_ROOT))
        findings += lint_source(rel, source)
    return findings


def _only(rule: str, findings: List[Finding]) -> List[Finding]:
    return [f for f in findings if f.rule == rule]


def _tree_findings(rule: str) -> List[Finding]:
    return _only(rule, lint_paths())


# Seeded-violation fixtures: each must make its rule fire.
_FIXTURE_HOST_SYNC = """
import jax
import numpy as np

def step(state, w):
    n = float(state.sum())
    rows = np.asarray(w)
    k = state[0].item()
    return n + rows.sum() + k

step_j = jax.jit(step, donate_argnums=(0,))
"""

_FIXTURE_BROAD_EXCEPT = """
def risky():
    try:
        return 1
    except Exception:
        return 0
    except:
        return -1
"""

_FIXTURE_ENV = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
"""

_FIXTURE_MISSING_DONATE = """
import jax

def step(art, flow_state, stats, w):
    return flow_state, stats

step_j = jax.jit(step)
"""


def register_rules() -> None:
    register(Rule(
        name="lint-host-sync-in-jit", section="lint",
        doc="no float()/np.asarray/.item()/device_get on traced values "
            "inside jitted or shard_mapped functions",
        check=lambda: _tree_findings("lint-host-sync-in-jit"),
        selftest=lambda: _only("lint-host-sync-in-jit",
                               lint_source("fixture.py",
                                           _FIXTURE_HOST_SYNC))))
    register(Rule(
        name="lint-broad-except", section="lint",
        doc="except Exception / bare except requires a justification "
            "comment (noqa: BLE001 or lint: allow-broad-except)",
        check=lambda: _tree_findings("lint-broad-except"),
        selftest=lambda: _only("lint-broad-except",
                               lint_source("fixture.py",
                                           _FIXTURE_BROAD_EXCEPT))))
    register(Rule(
        name="lint-env-mutation", section="lint",
        doc="no module-level os.environ mutation outside launch/",
        check=lambda: _tree_findings("lint-env-mutation"),
        selftest=lambda: _only("lint-env-mutation",
                               lint_source("fixture.py", _FIXTURE_ENV))))
    register(Rule(
        name="lint-missing-donate", section="lint",
        doc="jit of a function taking a state/stats carry must pass "
            "donate_argnums/donate_argnames",
        check=lambda: _tree_findings("lint-missing-donate"),
        selftest=lambda: _only("lint-missing-donate",
                               lint_source("fixture.py",
                                           _FIXTURE_MISSING_DONATE))))
