"""Jaxpr/HLO introspection helpers for the hot-path auditor.

These are the mechanical layers the hotpath rules build on:

* :func:`iter_eqns` — walk a (Closed)Jaxpr recursively through call/scan/
  shard_map sub-jaxprs hidden in ``eqn.params``.
* :func:`collective_census` — count collective primitives by normalized
  name (jax suffixes channel indices, e.g. ``psum`` lowers as ``psum2``
  inside ``shard_map``; we strip trailing digits so contracts stay
  stable across jax versions).
* :func:`forbidden_primitives` — host-callback / transfer primitives
  that break the zero-sync claim if they appear in a serving step.
* :func:`donation_alias_count` — parse the compiled HLO module header's
  ``input_output_alias={...}`` and count actual aliases. jax silently
  *prunes* unusable donations (no warning), so the only reliable check
  is alias-count == donated-leaf-count.
* :func:`jaxpr_dtypes` — the set of dtypes appearing anywhere in the
  jaxpr (vars and literals), for the no-f64 / layout rules.
"""

from __future__ import annotations

import re
from typing import Dict, Iterator, List, Sequence, Set

import jax
import jax.numpy as jnp
from jax import core as jax_core

# Normalized primitive names that perform cross-device communication.
# pbroadcast is deliberately absent: shard_map inserts it as replication
# *bookkeeping* (it lowers to identity — no data ever moves), so counting
# it would make the census a function of the rep-rule checker, not of
# the program's real collectives.
COLLECTIVE_NAMES = frozenset({
    "psum", "pmax", "pmin", "pmean", "all_gather", "all_to_all",
    "psum_scatter", "reduce_scatter", "ppermute",
})

# Normalized primitive-name fragments that imply a host round-trip or an
# explicit transfer — none of these may appear in a zero-sync step.
FORBIDDEN_FRAGMENTS = ("callback", "infeed", "outfeed", "device_put")


def _normalize(name: str) -> str:
    """Strip jax's trailing channel-index digits: ``psum2`` -> ``psum``."""
    return re.sub(r"\d+$", "", name)


def _sub_jaxprs(params: Dict) -> Iterator[jax_core.Jaxpr]:
    for val in params.values():
        vals = val if isinstance(val, (list, tuple)) else (val,)
        for v in vals:
            if isinstance(v, jax_core.ClosedJaxpr):
                yield v.jaxpr
            elif isinstance(v, jax_core.Jaxpr):
                yield v


def iter_eqns(jaxpr) -> Iterator[jax_core.JaxprEqn]:
    """Depth-first walk over every equation, including nested jaxprs."""
    if isinstance(jaxpr, jax_core.ClosedJaxpr):
        jaxpr = jaxpr.jaxpr
    for eqn in jaxpr.eqns:
        yield eqn
        for sub in _sub_jaxprs(eqn.params):
            yield from iter_eqns(sub)


def primitive_names(jaxpr) -> List[str]:
    return [_normalize(eqn.primitive.name) for eqn in iter_eqns(jaxpr)]


def collective_census(jaxpr) -> Dict[str, int]:
    """Normalized-name -> count for every collective in the jaxpr."""
    census: Dict[str, int] = {}
    for name in primitive_names(jaxpr):
        if name in COLLECTIVE_NAMES:
            census[name] = census.get(name, 0) + 1
    return census


def forbidden_primitives(jaxpr) -> List[str]:
    """Host-sync / transfer primitive names present in the jaxpr."""
    hits = []
    for name in primitive_names(jaxpr):
        if any(frag in name for frag in FORBIDDEN_FRAGMENTS):
            hits.append(name)
    return hits


def jaxpr_dtypes(jaxpr) -> Set[str]:
    """Every dtype appearing on a var or literal anywhere in the jaxpr."""
    dtypes: Set[str] = set()
    if isinstance(jaxpr, jax_core.ClosedJaxpr):
        closed = jaxpr
        jaxpr = jaxpr.jaxpr
        for const in closed.consts:
            aval = jax_core.get_aval(const)
            if hasattr(aval, "dtype"):
                dtypes.add(str(aval.dtype))

    def visit(jx: jax_core.Jaxpr) -> None:
        for v in list(jx.invars) + list(jx.outvars) + list(jx.constvars):
            aval = getattr(v, "aval", None)
            if aval is not None and hasattr(aval, "dtype"):
                dtypes.add(str(aval.dtype))
        for eqn in jx.eqns:
            for v in list(eqn.invars) + list(eqn.outvars):
                aval = getattr(v, "aval", None)
                if aval is not None and hasattr(aval, "dtype"):
                    dtypes.add(str(aval.dtype))
            for sub in _sub_jaxprs(eqn.params):
                visit(sub)

    visit(jaxpr)
    return dtypes


_ALIAS_RE = re.compile(r"input_output_alias=\{(.*?)\},\s*entry_computation_layout",
                       re.DOTALL)


def donation_alias_count(compiled_text: str) -> int:
    """Number of input->output aliases in a compiled HLO module header.

    jax expresses honoured donations as
    ``input_output_alias={ {0}: (1, {}, may-alias), ... }``; a donated
    buffer that could not be aliased is simply absent (pruned without
    warning), which is why the auditor counts instead of trusting
    ``donate_argnums``.
    """
    m = _ALIAS_RE.search(compiled_text)
    if m is None:
        return 0
    return m.group(1).count(": (")


def count_donated_leaves(args: Sequence, donate_argnums: Sequence[int]) -> int:
    """Flat array-leaf count across the donated positional arguments."""
    total = 0
    for i in donate_argnums:
        total += len(jax.tree_util.tree_leaves(args[i]))
    return total


def compiled_text(jitted, *args) -> str:
    """Lowered+compiled HLO text for a jitted callable at these args."""
    return jitted.lower(*args).compile().as_text()


def closed_jaxpr(jitted, *args):
    return jax.make_jaxpr(jitted)(*args)


def abstractify(tree):
    """Shape/dtype skeleton of a pytree (for eval_shape-style tracing)."""
    return jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(jnp.shape(x), jnp.result_type(x)), tree)
