"""Resource-fit rules (rule section ``fit``).

The check half proves every artifact class the repo actually serves
(RF, XGB over the streaming readout layout, plus the classical SVM /
Bayes mappings) deploys under the default Tofino-like profile; the
self-test half proves :func:`check_fit` genuinely *rejects* — a
paper-scale oversized ensemble (wide per-feature radices, the regime
IIsy §4/Table 1 calls out as the naive-mapping blowup) must fail.
"""

from __future__ import annotations

import functools
from typing import Dict, List

import numpy as np

from repro.analysis.registry import Finding, Rule, register
from repro.core.resources import DEFAULT_PROFILE, PROFILES, check_fit


@functools.lru_cache(maxsize=1)
def standard_artifacts():
    """(name, finalized artifact) for the model families the serving
    stack deploys — small trained instances of each mapping."""
    from repro.core.artifact import finalize_artifact
    from repro.core.mapping import map_tree_ensemble
    from repro.ml.trees import (fit_decision_tree, fit_random_forest,
                                fit_xgboost)
    from repro.netsim.stream import FLOW_FEATURES
    rng = np.random.RandomState(0)
    x = rng.rand(512, FLOW_FEATURES).astype(np.float32) * 1500.0
    y = ((x[:, 0] > x[:, 1]) ^ (x[:, 2] > 700.0)).astype(np.int32)
    dt = fit_decision_tree(x, y, n_classes=2, max_depth=5)
    rf = fit_random_forest(x, y, n_classes=2, n_trees=10, max_depth=5)
    xgb = fit_xgboost(x, y, n_trees=10, max_depth=5)
    return (
        ("dt", finalize_artifact(map_tree_ensemble(dt, FLOW_FEATURES))),
        ("rf", finalize_artifact(map_tree_ensemble(rf, FLOW_FEATURES))),
        ("xgb", finalize_artifact(map_tree_ensemble(xgb, FLOW_FEATURES))),
    )


def oversized_report():
    """A deliberately paper-scale ResourceReport no single device holds:
    8 features x 256-entry range tables feeding trees whose per-feature
    code radix is 16 — prod(radix) decision entries per tree, the §4
    blowup the mapping's table split exists to avoid."""
    from repro.core.resources import ResourceReport
    f_dim, radix, n_trees, feat_entries = 8, 16, 4, 256 * 8
    dec_entries = n_trees * radix ** f_dim          # 4 * 16^8 ~ 1.7e10
    feat_bits = feat_entries * 4 * f_dim
    dec_bits = dec_entries * 2
    return ResourceReport(tables=f_dim + n_trees + 1,
                          entries=feat_entries + dec_entries,
                          bits=feat_bits + dec_bits, stages=3,
                          tcam_bits=feat_bits, sram_bits=dec_bits)


def fit_rows() -> List[Dict[str, object]]:
    """Per-(artifact, profile) utilization rows (bench + CLI --json)."""
    rows = []
    for name, art in standard_artifacts():
        for profile in PROFILES.values():
            rep = check_fit(art, profile)
            row = {"artifact": name, **rep.row()}
            rows.append(row)
    return rows


def check_standard_artifacts_fit() -> List[Finding]:
    out = []
    for name, art in standard_artifacts():
        rep = check_fit(art, DEFAULT_PROFILE)
        if not rep.fits:
            out.append(Finding(
                rule="fit-standard-artifacts",
                message=(f"{name} artifact no longer fits "
                         f"{DEFAULT_PROFILE.name}: "
                         + "; ".join(rep.violations))))
    return out


def _selftest_rejects_oversized() -> List[Finding]:
    rep = check_fit(oversized_report(), DEFAULT_PROFILE)
    if not rep.fits:
        return [Finding(rule="fit-standard-artifacts",
                        message="selftest: oversized ensemble rejected: "
                                + "; ".join(rep.violations))]
    return []


def register_rules() -> None:
    register(Rule(
        name="fit-standard-artifacts", section="fit",
        doc="every served artifact family (dt/rf/xgb) deploys under the "
            "default device profile; check_fit rejects paper-scale "
            "oversized ensembles",
        check=check_standard_artifacts_fit,
        selftest=_selftest_rejects_oversized))
