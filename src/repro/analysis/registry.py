"""Rule registry for the static-analysis gate.

A :class:`Rule` is a named check in one of three sections (``lint``,
``hotpath``, ``fit``). Rules self-register at import time via
:func:`register`; the CLI runs them through :func:`run_rules` and folds
the findings into an :class:`AnalysisReport`. Every rule must carry a
``selftest`` callable that seeds a violation and proves the rule fires —
``--strict`` refuses to pass if any rule's self-test is silent.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Sequence

SECTIONS = ("lint", "hotpath", "fit")


@dataclasses.dataclass(frozen=True)
class Finding:
    """One concrete violation reported by a rule."""

    rule: str
    message: str
    path: str = ""
    line: int = 0
    severity: str = "error"  # "error" | "warning"

    def format(self) -> str:
        loc = f"{self.path}:{self.line}: " if self.path else ""
        return f"{loc}[{self.rule}] {self.message}"

    def to_json(self) -> Dict[str, object]:
        return dataclasses.asdict(self)


@dataclasses.dataclass(frozen=True)
class Rule:
    """A named static check.

    ``check`` returns the findings on the real tree (empty = clean).
    ``selftest`` seeds a violation out-of-tree and returns the findings
    the rule produced on it; an empty self-test result means the rule
    has rotted into a no-op and fails ``--strict``.
    """

    name: str
    section: str
    doc: str
    check: Callable[[], List[Finding]]
    selftest: Callable[[], List[Finding]]

    def __post_init__(self) -> None:
        if self.section not in SECTIONS:
            raise ValueError(f"unknown section {self.section!r} for rule {self.name!r}")


RULES: Dict[str, Rule] = {}


def register(rule: Rule) -> Rule:
    if rule.name in RULES:
        raise ValueError(f"duplicate rule name {rule.name!r}")
    RULES[rule.name] = rule
    return rule


def iter_rules(sections: Optional[Sequence[str]] = None) -> List[Rule]:
    """Rules in registration order, optionally filtered by section."""
    rules = list(RULES.values())
    if sections:
        wanted = set(sections)
        unknown = wanted - set(SECTIONS)
        if unknown:
            raise ValueError(f"unknown sections: {sorted(unknown)}")
        rules = [r for r in rules if r.section in wanted]
    return rules


@dataclasses.dataclass
class RuleResult:
    rule: str
    section: str
    findings: List[Finding]
    selftest_fired: Optional[bool]  # None = self-test not run
    elapsed_s: float
    error: str = ""  # non-empty if the rule itself crashed

    @property
    def ok(self) -> bool:
        return not self.findings and not self.error and self.selftest_fired is not False

    def to_json(self) -> Dict[str, object]:
        return {
            "rule": self.rule,
            "section": self.section,
            "findings": [f.to_json() for f in self.findings],
            "selftest_fired": self.selftest_fired,
            "elapsed_s": round(self.elapsed_s, 3),
            "error": self.error,
        }


@dataclasses.dataclass
class AnalysisReport:
    results: List[RuleResult]

    @property
    def findings(self) -> List[Finding]:
        return [f for r in self.results for f in r.findings]

    @property
    def ok(self) -> bool:
        return all(r.ok for r in self.results)

    def to_json(self) -> Dict[str, object]:
        return {
            "ok": self.ok,
            "n_rules": len(self.results),
            "n_findings": len(self.findings),
            "results": [r.to_json() for r in self.results],
        }


def run_rules(sections: Optional[Sequence[str]] = None,
              selftests: bool = True) -> AnalysisReport:
    """Run every registered rule (and optionally its self-test).

    A rule that raises is reported as a failed result rather than
    aborting the whole run, so one broken auditor cannot mask the rest.
    """
    results: List[RuleResult] = []
    for rule in iter_rules(sections):
        t0 = time.perf_counter()
        findings: List[Finding] = []
        fired: Optional[bool] = None
        error = ""
        try:
            findings = list(rule.check())
            if selftests:
                fired = bool(rule.selftest())
        except Exception as exc:  # noqa: BLE001 — isolate rule crashes into the report
            error = f"{type(exc).__name__}: {exc}"
        results.append(RuleResult(rule=rule.name, section=rule.section,
                                  findings=findings, selftest_fired=fired,
                                  elapsed_s=time.perf_counter() - t0, error=error))
    return AnalysisReport(results=results)
