"""IIsy core: model -> table mapping, table inference, hybrid deployment."""

from repro.core.artifact import TableArtifact
from repro.core.mapping import (
    map_tree_ensemble,
    map_svm,
    map_naive_bayes,
    map_kmeans,
)
from repro.core.inference import (
    table_predict,
    table_predict_per_tree,
    tree_vote_predict,
    feature_bins,
)
from repro.core.hybrid import (hybrid_predict, hybrid_serve, dispatch,
                               combine, DeferredDispatch, init_deferred,
                               defer_window, backpatch_pending)
from repro.core.quantize import FixedPoint, quantize_fixed, dequantize, relative_error
from repro.core.resources import artifact_resources, ResourceReport
