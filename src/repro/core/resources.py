"""Switch-resource accounting for a TableArtifact (Tables 1-2 analog).

We report the quantities the paper reports, computed from the artifact:
  tables   — number of lookup tables (feature tables + decision tables + agg)
  entries  — total table entries
  bits     — total payload storage
  stages   — pipeline-stage analog: dependent lookup rounds. IIsy's mapping
             is constant-stage: features (parallel) -> decisions (parallel)
             -> aggregation, i.e. 3, independent of tree count/depth (§4.1).

Beyond *reporting*, :func:`check_fit` maps a report against a declarative
:class:`DeviceProfile` budget (Tofino-like / NIC-ish) and rejects
artifacts that would not deploy — the Planter-style fit gate IIsy's §4
mapping discussion assumes but the repo previously never enforced.
Feature (range-match) tables bill against TCAM, decision/value
(exact-match) tables against SRAM, mirroring the paper's table-type
split.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional

import numpy as np

from repro.core.artifact import TableArtifact


@dataclasses.dataclass
class ResourceReport:
    tables: int
    entries: int
    bits: int
    stages: int
    # match-kind split used by check_fit: range/ternary feature tables
    # (TCAM) vs exact-match decision/value tables (SRAM). 0/0 (the
    # naive-mapping constructors) means "unsplit" — check_fit then bills
    # everything against SRAM, the conservative default for exact-match
    # flat layouts.
    tcam_bits: int = 0
    sram_bits: int = 0

    @property
    def kib(self) -> float:
        return self.bits / 8 / 1024

    def row(self) -> str:
        return (f"tables={self.tables} entries={self.entries} "
                f"mem={self.kib:.1f}KiB stages={self.stages}")


def _code_bits(radix: np.ndarray) -> np.ndarray:
    return np.ceil(np.log2(np.maximum(radix, 2))).astype(np.int64)


def artifact_resources(art: TableArtifact) -> ResourceReport:
    edges = np.asarray(art.edges)
    f_dim = edges.shape[0]
    valid_edges = np.isfinite(edges).sum(axis=1)            # (F,)

    if art.ftable is not None:
        n_trees = np.asarray(art.strides).shape[0]
        # per-tree radices recoverable from the feature-table code maxima
        ftab = np.asarray(art.ftable)                       # (F, U+1, T)
        radix = ftab.max(axis=1) + 1                        # (F, T)
        sizes = radix.astype(np.int64).prod(axis=0)         # (T,)
        feat_entries = int((valid_edges + 1).sum())
        feat_bits = int(((valid_edges + 1)[:, None]
                         * _code_bits(radix)[...]).sum())
        dec_entries = int(sizes.sum())
        payload_bits = (art.dtable_value.bits
                        if art.agg != "vote"
                        else max(1, math.ceil(math.log2(max(art.n_classes, 2)))))
        dec_bits = int(sizes.sum()) * payload_bits
        return ResourceReport(
            tables=f_dim + n_trees + 1,
            entries=feat_entries + dec_entries,
            bits=feat_bits + dec_bits,
            stages=3,
            tcam_bits=feat_bits, sram_bits=dec_bits)

    # classical: feature value tables + one aggregation/compare stage
    m = art.vtable.q.shape[2]
    feat_entries = int((valid_edges + 1).sum())
    bits = feat_entries * m * art.vtable.bits
    extra_tables = 1 if art.agg != "nb_log" else 2   # paper: NB uses 2 tables
    # classical value tables are range-keyed on the feature axis but
    # store per-class payload vectors: key side TCAM, payload side SRAM.
    # The key codes are log2(radix)-ish and dwarfed by the payloads, so
    # bill the whole bits figure as SRAM and the entry *keys* as TCAM at
    # the code width of the edge count.
    key_bits = (int(((valid_edges + 1) * _code_bits(valid_edges + 1)).sum())
                if f_dim else 0)
    return ResourceReport(tables=f_dim + extra_tables,
                          entries=feat_entries, bits=bits,
                          stages=3 if art.agg != "nb_log" else 4,
                          tcam_bits=int(key_bits), sram_bits=bits)


# -- device fit (Planter-style deploy gate) ---------------------------------


@dataclasses.dataclass(frozen=True)
class DeviceProfile:
    """Declarative per-device resource budget.

    Budgets are deliberately coarse — the public numbers for a
    Tofino-class switch ASIC (order: 12 stages, O(10) MiB SRAM, O(1) MiB
    TCAM) and a SmartNIC match-action pipeline. The point is not cycle
    accuracy but a *monotone gate*: any artifact the profile rejects
    has genuinely outgrown that class of device, and growth in any
    utilization column is visible in the bench trajectory.
    """

    name: str
    stages: int
    sram_kib: int
    tcam_kib: int
    max_entries: int
    max_tables: int

    def budgets(self) -> Dict[str, float]:
        return {"stages": self.stages,
                "sram_kib": float(self.sram_kib),
                "tcam_kib": float(self.tcam_kib),
                "entries": float(self.max_entries),
                "tables": float(self.max_tables)}


# Default profiles. tofino_like mirrors the device class IIsy's Table 2
# targets; nic_like is a deliberately leaner SmartNIC-ish budget so the
# utilization rows show meaningful headroom differences.
TOFINO_LIKE = DeviceProfile(name="tofino_like", stages=12,
                            sram_kib=10 * 1024, tcam_kib=1024,
                            max_entries=400_000, max_tables=32)
NIC_LIKE = DeviceProfile(name="nic_like", stages=6,
                         sram_kib=2 * 1024, tcam_kib=128,
                         max_entries=100_000, max_tables=16)
PROFILES: Dict[str, DeviceProfile] = {p.name: p
                                      for p in (TOFINO_LIKE, NIC_LIKE)}
DEFAULT_PROFILE = TOFINO_LIKE


class FitError(ValueError):
    """Raised by check_fit(..., strict=True) when an artifact cannot
    deploy on the profile. Carries the full report for diagnostics."""

    def __init__(self, report: "FitReport"):
        self.report = report
        super().__init__(
            f"artifact does not fit {report.profile}: "
            + "; ".join(report.violations))


@dataclasses.dataclass
class FitReport:
    profile: str
    fits: bool
    utilization: Dict[str, float]   # budget key -> used/budget fraction
    used: Dict[str, float]
    violations: List[str]

    def row(self) -> Dict[str, object]:
        """bench-v1 style flat row (benchmarks/analysis_bench.py)."""
        out: Dict[str, object] = {"profile": self.profile,
                                  "fits": bool(self.fits)}
        for k, v in self.utilization.items():
            out[f"util_{k}"] = round(float(v), 6)
        return out


def check_fit(art_or_report, profile: DeviceProfile = DEFAULT_PROFILE, *,
              strict: bool = False) -> FitReport:
    """Map an artifact (or a precomputed ResourceReport) against a
    device budget *before* deploy.

    Every budget dimension yields a utilization fraction; any fraction
    above 1.0 is a violation. ``strict=True`` raises :class:`FitError`
    instead of returning an unfit report — that is the mode
    ``finalize_artifact(..., profile=...)`` uses as a deploy guard.
    """
    if isinstance(art_or_report, ResourceReport):
        res = art_or_report
    else:
        res = artifact_resources(art_or_report)
    sram_bits = res.sram_bits if (res.sram_bits or res.tcam_bits) else res.bits
    used = {"stages": float(res.stages),
            "sram_kib": sram_bits / 8 / 1024,
            "tcam_kib": res.tcam_bits / 8 / 1024,
            "entries": float(res.entries),
            "tables": float(res.tables)}
    budgets = profile.budgets()
    util = {k: (used[k] / budgets[k] if budgets[k] else float("inf"))
            for k in budgets}
    violations = [f"{k}: {used[k]:g} > budget {budgets[k]:g} "
                  f"({util[k]:.2f}x)"
                  for k in budgets if util[k] > 1.0]
    report = FitReport(profile=profile.name, fits=not violations,
                       utilization=util, used=used, violations=violations)
    if strict and violations:
        raise FitError(report)
    return report
