"""Switch-resource accounting for a TableArtifact (Tables 1-2 analog).

We report the quantities the paper reports, computed from the artifact:
  tables   — number of lookup tables (feature tables + decision tables + agg)
  entries  — total table entries
  bits     — total payload storage
  stages   — pipeline-stage analog: dependent lookup rounds. IIsy's mapping
             is constant-stage: features (parallel) -> decisions (parallel)
             -> aggregation, i.e. 3, independent of tree count/depth (§4.1).
"""

from __future__ import annotations

import dataclasses
import math

import numpy as np

from repro.core.artifact import TableArtifact


@dataclasses.dataclass
class ResourceReport:
    tables: int
    entries: int
    bits: int
    stages: int

    @property
    def kib(self) -> float:
        return self.bits / 8 / 1024

    def row(self) -> str:
        return (f"tables={self.tables} entries={self.entries} "
                f"mem={self.kib:.1f}KiB stages={self.stages}")


def _code_bits(radix: np.ndarray) -> np.ndarray:
    return np.ceil(np.log2(np.maximum(radix, 2))).astype(np.int64)


def artifact_resources(art: TableArtifact) -> ResourceReport:
    edges = np.asarray(art.edges)
    f_dim = edges.shape[0]
    valid_edges = np.isfinite(edges).sum(axis=1)            # (F,)

    if art.ftable is not None:
        n_trees = np.asarray(art.strides).shape[0]
        # per-tree radices recoverable from the feature-table code maxima
        ftab = np.asarray(art.ftable)                       # (F, U+1, T)
        radix = ftab.max(axis=1) + 1                        # (F, T)
        sizes = radix.astype(np.int64).prod(axis=0)         # (T,)
        feat_entries = int((valid_edges + 1).sum())
        feat_bits = int(((valid_edges + 1)[:, None]
                         * _code_bits(radix)[...]).sum())
        dec_entries = int(sizes.sum())
        payload_bits = (art.dtable_value.bits
                        if art.agg != "vote"
                        else max(1, math.ceil(math.log2(max(art.n_classes, 2)))))
        dec_bits = int(sizes.sum()) * payload_bits
        return ResourceReport(
            tables=f_dim + n_trees + 1,
            entries=feat_entries + dec_entries,
            bits=feat_bits + dec_bits,
            stages=3)

    # classical: feature value tables + one aggregation/compare stage
    m = art.vtable.q.shape[2]
    feat_entries = int((valid_edges + 1).sum())
    bits = feat_entries * m * art.vtable.bits
    extra_tables = 1 if art.agg != "nb_log" else 2   # paper: NB uses 2 tables
    return ResourceReport(tables=f_dim + extra_tables,
                          entries=feat_entries, bits=bits,
                          stages=3 if art.agg != "nb_log" else 4)
