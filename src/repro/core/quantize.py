"""Fixed-point quantization — the paper's "action data bits" knob (§7.7, Fig 9).

Table payloads on a switch are carried in metadata of a configured bit width.
We model this as symmetric fixed point: ``q = round(v * scale)`` stored in
``bits``-wide signed integers, with one shared scale per table so summation
across tables stays exact in the integer domain (what a switch ALU does).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class FixedPoint:
    q: jax.Array           # int32 payload (values fit in `bits` signed bits)
    scale: jax.Array       # scalar float32
    bits: int = dataclasses.field(metadata=dict(static=True), default=16)


def quantize_fixed(v, bits: int) -> FixedPoint:
    """Quantize array ``v`` to signed fixed point with ``bits`` total bits."""
    v = np.asarray(v, np.float32)
    max_abs = float(np.max(np.abs(v))) if v.size else 1.0
    max_abs = max(max_abs, 1e-12)
    qmax = float(2 ** (bits - 1) - 1)
    scale = qmax / max_abs
    # symmetric clip: the code -qmax-1 exists in two's complement but
    # dequantizes past max_abs, breaking the symmetric contract above
    q = np.clip(np.round(v * scale), -qmax, qmax).astype(np.int32)
    return FixedPoint(q=jnp.asarray(q), scale=jnp.float32(scale), bits=bits)


def dequantize(fp: FixedPoint) -> jax.Array:
    return fp.q.astype(jnp.float32) / fp.scale


def relative_error(fp: FixedPoint, v) -> float:
    """Mean relative calc error of the quantized representation (Fig 9)."""
    v = jnp.asarray(v, jnp.float32)
    d = dequantize(fp)
    denom = jnp.maximum(jnp.abs(v), 1e-9)
    return float(jnp.mean(jnp.abs(d - v) / denom))
