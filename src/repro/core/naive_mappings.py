"""Analytic resource models of prior-work mappings (Figs 6-7 baselines).

These reproduce the *mapping strategies* of SwitchTree / pForest /
Clustreams so the paper's comparisons can be regenerated:

- SwitchTree [29]: each tree encoded independently; evaluation walks the
  tree, so stages scale with depth and tables scale with trees x features.
- pForest [12]: one table per tree *level*; stages again scale with depth.
- Clustreams [17]: K-means cells encoded as per-cluster range entries over
  the full feature cross-product.

They are resource estimators (entries/tables/stages), not execution engines —
IIsy's own artifact is the only execution path, which mirrors the paper
(baselines are compared on resources, Fig 6-7).
"""

from __future__ import annotations

import numpy as np

from repro.core.resources import ResourceReport
from repro.ml.trees import TreeEnsemble
from repro.core.mapping import _tree_thresholds


def switchtree_resources(ens: TreeEnsemble, n_features: int,
                         class_bits: int = 8) -> ResourceReport:
    """Per-tree, per-feature tables; depth-many dependent stages per tree."""
    feat = np.asarray(ens.feat); thresh = np.asarray(ens.thresh)
    entries = 0
    tables = 0
    for t in range(ens.n_trees):
        ths = _tree_thresholds(feat[t], thresh[t], n_features)
        for f in range(n_features):
            if len(ths[f]) == 0:
                continue
            tables += 1
            entries += len(ths[f]) + 1
        # per-tree decision logic: one table per tree with one entry per leaf
        tables += 1
        entries += 2 ** ens.depth
    # conditions evaluated level by level -> depth stages (+1 vote)
    stages = ens.depth + 1
    bits = entries * class_bits
    return ResourceReport(tables=tables, entries=entries, bits=bits,
                          stages=stages)


def pforest_resources(ens: TreeEnsemble, n_features: int,
                      class_bits: int = 8) -> ResourceReport:
    """Table per level per tree: level d holds 2**d node entries."""
    entries = sum(ens.n_trees * (2 ** d) for d in range(ens.depth))
    entries += ens.n_trees * 2 ** ens.depth              # leaves
    tables = ens.n_trees * (ens.depth + 1)
    stages = ens.depth + 1
    return ResourceReport(tables=tables, entries=entries,
                          bits=entries * class_bits, stages=stages)


def clustreams_resources(n_clusters: int, n_features: int, n_bins: int,
                         value_bits: int = 16) -> ResourceReport:
    """Axis-aligned cell encoding: each cluster covered by range entries on
    every feature, matched in one wide table — entries scale with
    K * bins^(F/2) style box decomposition; we use the paper-favourable
    lower bound K * n_bins * F."""
    entries = n_clusters * n_bins * n_features
    return ResourceReport(tables=n_features, entries=entries,
                          bits=entries * value_bits, stages=2)
