"""Hybrid deployment (§2.2.1, §7.7): small switch model + large backend.

``hybrid_predict`` is the analysis-friendly dense form used by the paper's
sweeps (Figs 10-11). ``dispatch``/``combine`` are the serving form: the
low-confidence subset is *compacted* (MoE-dispatch style) so the expensive
backend only sees the forwarded queries — the load-reduction benefit in
collective/compute terms.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

from repro.core.artifact import TableArtifact
from repro.core.inference import table_predict


@dataclasses.dataclass
class HybridResult:
    pred: jax.Array          # (N,) final classes
    switch_pred: jax.Array   # (N,) switch-tier classes
    confidence: jax.Array    # (N,)
    handled: jax.Array       # (N,) bool: True = answered at the switch
    fraction_handled: jax.Array


def hybrid_predict(art: TableArtifact, backend_fn: Callable, x,
                   threshold: float) -> HybridResult:
    """Dense hybrid: backend evaluated everywhere, selected where needed."""
    sw_pred, conf = table_predict(art, x)
    handled = conf >= threshold
    be_pred = backend_fn(x)
    pred = jnp.where(handled, sw_pred, be_pred)
    return HybridResult(pred=pred, switch_pred=sw_pred, confidence=conf,
                        handled=handled,
                        fraction_handled=jnp.mean(handled.astype(jnp.float32)))


def dispatch(x: jax.Array, forward_mask: jax.Array, capacity: int):
    """Compact the forwarded rows into a fixed-capacity buffer.

    Returns (buf (capacity, F), idx (capacity,), valid (capacity,)).
    Rows beyond capacity are dropped from forwarding (the switch would answer
    them itself under congestion — paper §7.1.2's trade-off); callers keep the
    switch prediction for them.
    """
    n = x.shape[0]
    order = jnp.argsort(~forward_mask, stable=True)        # forwarded first
    idx = order[:capacity]
    valid = forward_mask[idx]
    buf = x[idx]
    return buf, idx, valid


def combine(switch_pred: jax.Array, backend_pred_subset: jax.Array,
            idx: jax.Array, valid: jax.Array) -> jax.Array:
    """Scatter backend answers for forwarded rows back over switch answers."""
    upd = jnp.where(valid, backend_pred_subset, switch_pred[idx])
    return switch_pred.at[idx].set(upd)


def hybrid_serve(art: TableArtifact, backend_fn: Callable, x,
                 threshold: float, capacity: int):
    """Serving-form hybrid with bounded backend batch.

    backend_fn receives exactly ``capacity`` rows (padded with whatever rows
    were not forwarded) — a static shape, so the backend step stays jittable.
    """
    sw_pred, conf = table_predict(art, x)
    fwd = conf < threshold
    buf, idx, valid = dispatch(x, fwd, capacity)
    be_pred = backend_fn(buf)
    pred = combine(sw_pred, be_pred, idx, valid)
    return pred, jnp.mean(fwd.astype(jnp.float32))
