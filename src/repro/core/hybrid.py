"""Hybrid deployment (§2.2.1, §7.7): small switch model + large backend.

``hybrid_predict`` is the analysis-friendly dense form used by the paper's
sweeps (Figs 10-11). ``dispatch``/``combine`` are the serving form: the
low-confidence subset is *compacted* (MoE-dispatch style) so the expensive
backend only sees the forwarded queries — the load-reduction benefit in
collective/compute terms.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

from repro.core.artifact import TableArtifact
from repro.core.inference import table_predict


@dataclasses.dataclass
class HybridResult:
    pred: jax.Array          # (N,) final classes
    switch_pred: jax.Array   # (N,) switch-tier classes
    confidence: jax.Array    # (N,)
    handled: jax.Array       # (N,) bool: True = answered at the switch
    fraction_handled: jax.Array


def hybrid_predict(art: TableArtifact, backend_fn: Callable, x,
                   threshold: float) -> HybridResult:
    """Dense hybrid: backend evaluated everywhere, selected where needed."""
    sw_pred, conf = table_predict(art, x)
    handled = conf >= threshold
    be_pred = backend_fn(x)
    pred = jnp.where(handled, sw_pred, be_pred)
    return HybridResult(pred=pred, switch_pred=sw_pred, confidence=conf,
                        handled=handled,
                        fraction_handled=jnp.mean(handled.astype(jnp.float32)))


def dispatch(x: jax.Array, forward_mask: jax.Array, capacity: int):
    """Compact the forwarded rows into a fixed-capacity buffer.

    Returns (buf (capacity, F), idx (capacity,), valid (capacity,)).
    Rows beyond capacity are dropped from forwarding (the switch would answer
    them itself under congestion — paper §7.1.2's trade-off); callers keep the
    switch prediction for them.
    """
    n = x.shape[0]
    order = jnp.argsort(~forward_mask, stable=True)        # forwarded first
    idx = order[:capacity]
    valid = forward_mask[idx]
    buf = x[idx]
    return buf, idx, valid


def combine(switch_pred: jax.Array, backend_pred_subset: jax.Array,
            idx: jax.Array, valid: jax.Array) -> jax.Array:
    """Scatter backend answers for forwarded rows back over switch answers."""
    upd = jnp.where(valid, backend_pred_subset, switch_pred[idx])
    return switch_pred.at[idx].set(upd)


# ---------------------------------------------------------------------------
# cross-window deferred dispatch (DESIGN.md §7)
# ---------------------------------------------------------------------------

@jax.tree_util.register_dataclass
@dataclasses.dataclass
class DeferredDispatch:
    """Device-resident deferral buffer for cross-window backend batching.

    Instead of paying one backend invocation per window for at most
    ``capacity`` rows, serving defers the compacted low-confidence rows of
    up to ``flush_every`` windows into this buffer and runs the backend
    once per flush at ``flush_every``-times the occupancy. Each slot keeps
    its *return address* — ``(window, lane)``: the pending-cycle slot the
    row came from and its lane within that window — so a flush can
    back-patch the backend answers into the per-window pending prediction
    set (``backpatch_pending``).

    ``buf`` is ``(flush_every * capacity, F)`` on a single device, or
    ``(n_shards, flush_every * capacity, F)`` on the sharded tier, where
    every shard accumulates the partial rows it owns (non-owner lanes
    zero) and a flush reduce-scatters complete rows so each shard's
    backend serves only its slice. Donation discipline matches the other
    serving carries: the buffer is donated into every defer/flush step —
    callers never hold a reference to a previous one.
    """
    buf: jax.Array       # (k*cap, F) or (n_shards, k*cap, F) deferred rows
    lane: jax.Array      # (k*cap,) i32 lane within the source window
    window: jax.Array    # (k*cap,) i32 pending-cycle slot in [0, flush_every)
    valid: jax.Array     # (k*cap,) bool: slot holds a live deferred row

    @property
    def slots(self) -> int:
        return self.lane.shape[0]


def init_deferred(flush_every: int, capacity: int, n_features: int, *,
                  n_shards: int = None) -> DeferredDispatch:
    """Empty deferral buffer for ``flush_every`` windows of ``capacity``
    rows each. ``n_shards`` adds the leading shard dim of the sharded
    tier's partial-row accumulation buffer."""
    n = flush_every * capacity
    shape = (n, n_features) if n_shards is None else (n_shards, n, n_features)
    return DeferredDispatch(
        buf=jnp.zeros(shape, jnp.float32),
        lane=jnp.zeros((n,), jnp.int32),
        window=jnp.zeros((n,), jnp.int32),
        valid=jnp.zeros((n,), bool))


def defer_window(dd: DeferredDispatch, buf: jax.Array, idx: jax.Array,
                 valid: jax.Array, pos) -> DeferredDispatch:
    """Append one window's dispatched rows at pending-cycle slot ``pos``.

    ``buf``/``idx``/``valid`` are ``dispatch``'s outputs for the window
    (the sharded tier passes its per-shard partial ``(n_shards, capacity,
    F)`` buffer); ``pos`` is a traced i32 scalar, so stepping through the
    cycle never recompiles. Slot ``pos`` occupies rows
    ``[pos*capacity, (pos+1)*capacity)``.
    """
    cap = idx.shape[0]
    row0 = pos * cap
    if dd.buf.ndim == 3:
        new_buf = jax.lax.dynamic_update_slice(dd.buf, buf, (0, row0, 0))
    else:
        new_buf = jax.lax.dynamic_update_slice(dd.buf, buf, (row0, 0))
    return DeferredDispatch(
        buf=new_buf,
        lane=jax.lax.dynamic_update_slice(
            dd.lane, idx.astype(jnp.int32), (row0,)),
        window=jax.lax.dynamic_update_slice(
            dd.window, jnp.full((cap,), pos, jnp.int32), (row0,)),
        valid=jax.lax.dynamic_update_slice(dd.valid, valid, (row0,)))


def chunk_dispatch(xs: jax.Array, fwd: jax.Array,
                   capacity: int) -> DeferredDispatch:
    """Vectorized per-window dispatch over a whole chunk of windows.

    xs (K, W, F) feature rows, fwd (K, W) forward masks -> one
    ``DeferredDispatch`` covering the chunk: ``dispatch`` vmapped over
    the window axis (every window still capacity-bounded exactly as the
    per-window path bounds it — the bit-equality contract of the chunked
    megastep), the (window, lane) return addresses laid out row-major so
    slot ``k*capacity + i`` is window k's i-th dispatched row. Built in
    one shot from stacked scan outputs — nothing is carried through the
    scan and no per-window buffer writes happen; ``backpatch_pending``
    consumes it unchanged.
    """
    k, w, f = xs.shape
    buf, idx, valid = jax.vmap(lambda x1, f1: dispatch(x1, f1, capacity))(
        xs, fwd)
    return DeferredDispatch(
        buf=buf.reshape(k * capacity, f),
        lane=idx.reshape(-1).astype(jnp.int32),
        window=jnp.repeat(jnp.arange(k, dtype=jnp.int32), capacity),
        valid=valid.reshape(-1))


def backpatch_pending(pending: jax.Array, backend_pred: jax.Array,
                      dd: DeferredDispatch) -> jax.Array:
    """Scatter flushed backend answers into the per-window pending set.

    ``pending`` is the ``(flush_every, W)`` prediction buffer holding each
    pending window's switch answers; every live deferral slot overwrites
    its ``(window, lane)`` return address with the backend's answer.
    Dead slots are routed out of bounds and dropped, so a partially
    filled cycle (the guaranteed end-of-trace flush) patches exactly the
    rows that were deferred. Live addresses are unique by construction
    (lanes are distinct within a window, cycle slots distinct across
    windows), so the scatter is deterministic.
    """
    row = jnp.where(dd.valid, dd.window, pending.shape[0])
    return pending.at[row, dd.lane].set(
        backend_pred.astype(pending.dtype), mode="drop")


def hybrid_serve(art: TableArtifact, backend_fn: Callable, x,
                 threshold: float, capacity: int):
    """Serving-form hybrid with bounded backend batch.

    backend_fn receives exactly ``capacity`` rows (padded with whatever rows
    were not forwarded) — a static shape, so the backend step stays jittable.
    """
    sw_pred, conf = table_predict(art, x)
    fwd = conf < threshold
    buf, idx, valid = dispatch(x, fwd, capacity)
    be_pred = backend_fn(buf)
    pred = combine(sw_pred, be_pred, idx, valid)
    return pred, jnp.mean(fwd.astype(jnp.float32))
