"""Pure-jnp table inference — the reference "switch data plane".

This module is the oracle semantics: kernels/ensemble_lookup reimplements the
same pipeline as a fused Pallas kernel. Both return ``(pred, confidence)``.

Stages (mirrors the match-action pipeline):
  1. per-feature range match           -> union bin        (parser + feature tables)
  2. per-tree code gather + mixed radix -> decision key
  3. per-tree decision-table gather     -> leaf payload
  4. aggregation                        -> class + confidence
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.artifact import TableArtifact
from repro.core.quantize import dequantize


def feature_bins(edges: jax.Array, x: jax.Array) -> jax.Array:
    """(N, F) union-bin ids; edges padded with +inf never match."""
    return jnp.sum(x[:, :, None] > edges[None, :, :], axis=2).astype(jnp.int32)


def _c_factor(n):
    n = jnp.maximum(n, 2.0)
    return 2.0 * (jnp.log(n - 1.0) + 0.5772156649) - 2.0 * (n - 1.0) / n


def table_predict(art: TableArtifact, x: jax.Array):
    """Classify a batch. Returns (pred (N,), confidence (N,))."""
    x = jnp.asarray(x, jnp.float32)
    bins = feature_bins(art.edges, x)                       # (N, F)
    f_idx = jnp.arange(art.n_features)[None, :]

    if art.ftable is not None:                              # tree family
        codes = art.ftable[f_idx, bins]                     # (N, F, T)
        keys = jnp.einsum("nft,tf->nt", codes.astype(jnp.int32),
                          art.strides).astype(jnp.int32)    # (N, T)
        t_idx = jnp.arange(art.n_trees)[None, :]
        if art.agg == "vote":
            cls = art.dtable_class[t_idx, keys]             # (N, T)
            votes = jax.nn.one_hot(cls, art.n_classes,
                                   dtype=jnp.float32).sum(axis=1)
            pred = jnp.argmax(votes, axis=1)
            conf = jnp.max(votes, axis=1) / art.n_trees
            return pred, conf
        vals_q = art.dtable_value.q[t_idx, keys]            # (N, T) int32
        # integer-domain sum (what the switch ALU does), one dequant at the end
        total = vals_q.sum(axis=1).astype(jnp.float32) / art.dtable_value.scale
        if art.agg == "wsum_sigmoid":
            margin = art.base_score + art.learning_rate * total
            p1 = jax.nn.sigmoid(margin)
            pred = (p1 > 0.5).astype(jnp.int32)
            conf = jnp.maximum(p1, 1.0 - p1)
            return pred, conf
        if art.agg == "iforest":
            e_path = total / art.n_trees
            score = 2.0 ** (-e_path / _c_factor(jnp.float32(art.iforest_subsample)))
            pred = (score > 0.5).astype(jnp.int32)
            conf = jnp.maximum(score, 1.0 - score)
            return pred, conf
        raise ValueError(art.agg)

    # classical family
    vals_q = art.vtable.q[f_idx, bins]                      # (N, F, M)
    total = vals_q.sum(axis=1).astype(jnp.float32) / art.vtable.scale
    if art.agg == "svm_ovo":
        planes = total + art.consts[None, :]                # (N, m)
        win_i = planes > 0
        n = planes.shape[0]
        votes = jnp.zeros((n, art.n_classes), jnp.float32)
        votes = votes.at[:, art.pairs[:, 0]].add(win_i.astype(jnp.float32))
        votes = votes.at[:, art.pairs[:, 1]].add((~win_i).astype(jnp.float32))
        pred = jnp.argmax(votes, axis=1)
        if planes.shape[1] == 1:                            # binary: margin conf
            conf = jax.nn.sigmoid(2.0 * jnp.abs(planes[:, 0]))
        else:
            conf = jnp.max(votes, axis=1) / planes.shape[1]
        return pred, conf
    if art.agg == "nb_log":
        joint = total + art.consts[None, :]                 # (N, C) log joint
        pred = jnp.argmax(joint, axis=1)
        conf = jnp.max(jax.nn.softmax(joint, axis=1), axis=1)
        return pred, conf
    if art.agg == "kmeans":
        d2 = total                                          # (N, K)
        pred = jnp.argmin(d2, axis=1)
        # margin confidence: how decisively the nearest beats the runner-up
        top2 = jax.lax.top_k(-d2, 2)[0]
        conf = 1.0 - jnp.exp(top2[:, 1] - top2[:, 0])       # in [0, 1)
        return pred, conf
    raise ValueError(art.agg)


def table_predict_per_tree(art: TableArtifact, x: jax.Array) -> jax.Array:
    """Per-tree classes (N, T) — used by equivalence tests."""
    x = jnp.asarray(x, jnp.float32)
    bins = feature_bins(art.edges, x)
    f_idx = jnp.arange(art.n_features)[None, :]
    codes = art.ftable[f_idx, bins]
    keys = jnp.einsum("nft,tf->nt", codes.astype(jnp.int32),
                      art.strides).astype(jnp.int32)
    t_idx = jnp.arange(art.n_trees)[None, :]
    return art.dtable_class[t_idx, keys]


def tree_vote_predict(ens, x):
    """Direct (non-table) per-tree majority vote — the apples-to-apples
    baseline for the table pipeline (paper's per-tree 'classification
    results of all trees')."""
    from repro.ml.trees import tree_leaf_indices
    leaf_idx = tree_leaf_indices(ens, x)                    # (T, N)
    counts = jnp.take_along_axis(ens.leaf, leaf_idx[:, :, None], axis=1)
    cls = jnp.argmax(counts, axis=2)                        # (T, N)
    votes = jax.nn.one_hot(cls.T, ens.n_classes, dtype=jnp.float32).sum(axis=1)
    return jnp.argmax(votes, axis=1), jnp.max(votes, axis=1) / ens.n_trees
