"""IIsy's mapping tool: trained model -> TableArtifact (§4 of the paper).

Key ideas implemented exactly as in the paper:
  * one feature table per feature, **shared across all trees** of an ensemble
    (§4.2 "Ilsy significantly reduces resources by sharing feature tables");
  * per-tree decision tables keyed on the concatenated per-feature codes, so
    the number of lookup stages is independent of tree depth (§4.1);
  * classical models (SVM / NB / K-Means) as per-feature value tables whose
    quantized partial terms are summed at the end of the pipeline (§4.3);
  * payload quantization controlled by ``action_bits`` (§7.7 / Fig 9).

Mapping runs host-side in numpy (it is the paper's control-plane "python
script"); the resulting artifact is consumed by jit/pallas inference.
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.core.artifact import TableArtifact, finalize_artifact
from repro.core.quantize import quantize_fixed
from repro.ml.trees import TreeEnsemble
from repro.ml.svm import LinearSVM
from repro.ml.naive_bayes import GaussianNB
from repro.ml.kmeans import KMeansModel


# ---------------------------------------------------------------------------
# tree family
# ---------------------------------------------------------------------------

def _tree_thresholds(feat, thresh, n_features):
    """Per-feature sorted unique finite thresholds of one tree."""
    out = []
    for f in range(n_features):
        t = thresh[(feat == f) & np.isfinite(thresh)]
        out.append(np.unique(t))
    return out


def _leaf_walk(feat, thresh, x, depth):
    """Evaluate one tree on rows of x (numpy). Returns leaf indices."""
    node = np.zeros(x.shape[0], np.int64)
    for _ in range(depth):
        f = feat[node]
        t = thresh[node]
        node = 2 * node + 1 + (x[np.arange(x.shape[0]), f] > t)
    return node - (2 ** depth - 1)


def map_tree_ensemble(ens: TreeEnsemble, n_features: int, *,
                      action_bits: int = 16,
                      max_decision_entries: int = 2_000_000) -> TableArtifact:
    feat = np.asarray(ens.feat)        # (T, H)
    thresh = np.asarray(ens.thresh)    # (T, H)
    leaf = np.asarray(ens.leaf)        # (T, L, C)
    n_trees, depth = ens.n_trees, ens.depth

    per_tree = [_tree_thresholds(feat[t], thresh[t], n_features)
                for t in range(n_trees)]

    # union edges per feature
    unions = [np.unique(np.concatenate([per_tree[t][f] for t in range(n_trees)]
                                       + [np.zeros(0, np.float32)]))
              for f in range(n_features)]
    u_max = max(1, max(len(u) for u in unions))
    edges = np.full((n_features, u_max), np.inf, np.float32)
    for f, u in enumerate(unions):
        edges[f, :len(u)] = u

    # feature tables: code of union-bin b under tree t on feature f
    # code = #(tree thresholds with position-in-union < b)
    ftable = np.zeros((n_features, u_max + 1, n_trees), np.int32)
    for f, u in enumerate(unions):
        for t in range(n_trees):
            pos = np.searchsorted(u, per_tree[t][f])   # positions within union
            bins = np.arange(u_max + 1)
            ftable[f, :, t] = np.searchsorted(pos, bins, side="left")

    # mixed-radix strides and decision tables
    radix = np.array([[len(per_tree[t][f]) + 1 for f in range(n_features)]
                      for t in range(n_trees)], np.int64)      # (T, F)
    sizes = radix.prod(axis=1)
    s_max = int(sizes.max())
    if int(sizes.sum()) > max_decision_entries:
        raise ValueError(
            f"decision tables need {int(sizes.sum())} entries > "
            f"{max_decision_entries}; prune the trees (paper §4.2) or raise "
            f"the cap")
    strides = np.zeros((n_trees, n_features), np.int64)
    for t in range(n_trees):
        s = 1
        for f in range(n_features - 1, -1, -1):
            strides[t, f] = s
            s *= radix[t, f]

    dtable_class = np.zeros((n_trees, s_max), np.int32)
    dtable_value = np.zeros((n_trees, s_max), np.float32)
    c_euler = 0.5772156649

    def c_factor(n):
        n = np.maximum(n, 2.0)
        return 2.0 * (np.log(n - 1.0) + c_euler) - 2.0 * (n - 1.0) / n

    for t in range(n_trees):
        # representative value per (feature, code)
        reps = []
        for f in range(n_features):
            th = per_tree[t][f]
            if len(th) == 0:
                reps.append(np.zeros(1, np.float32))
                continue
            mid = (th[:-1] + th[1:]) / 2.0
            reps.append(np.concatenate([[th[0] - 1.0], mid, [th[-1] + 1.0]]))
        # enumerate every code combination (mixed-radix grid)
        size = int(sizes[t])
        keys = np.arange(size)
        grid = np.zeros((size, n_features), np.float32)
        rem = keys.copy()
        for f in range(n_features):
            idx = rem // strides[t, f]
            rem = rem % strides[t, f]
            grid[:, f] = reps[f][idx]
        leaves = _leaf_walk(feat[t], thresh[t], grid, depth)
        payload = leaf[t][leaves]                       # (size, C)
        if ens.kind in ("dt", "rf"):
            dtable_class[t, :size] = payload.argmax(axis=1)
        elif ens.kind == "xgb":
            dtable_value[t, :size] = payload[:, 0]
        elif ens.kind == "iforest":
            n_leaf = payload[:, 0]
            dtable_value[t, :size] = depth + np.where(
                n_leaf > 1, c_factor(n_leaf), 0.0)
        else:
            raise ValueError(ens.kind)

    agg = {"dt": "vote", "rf": "vote", "xgb": "wsum_sigmoid",
           "iforest": "iforest"}[ens.kind]
    return finalize_artifact(TableArtifact(
        edges=jnp.asarray(edges), agg=agg, n_classes=ens.n_classes,
        ftable=jnp.asarray(ftable),
        strides=jnp.asarray(strides.astype(np.int32)),
        dtable_class=jnp.asarray(dtable_class),
        dtable_value=quantize_fixed(dtable_value, action_bits),
        base_score=ens.base_score, learning_rate=ens.learning_rate))


# ---------------------------------------------------------------------------
# classical family — quantile-binned value tables
# ---------------------------------------------------------------------------

def _quantile_edges(x_train, n_bins):
    qs = np.linspace(0, 1, n_bins + 1)[1:-1]
    return np.quantile(np.asarray(x_train, np.float32), qs, axis=0).T  # (F,B-1)


def _bin_centers(edges_f):
    """Representative value per bin given one feature's edges (len B-1)."""
    e = edges_f
    if len(e) == 0:
        return np.zeros(1, np.float32)
    mid = (e[:-1] + e[1:]) / 2.0
    span = max(e[-1] - e[0], 1e-6)
    return np.concatenate([[e[0] - 0.05 * span], mid, [e[-1] + 0.05 * span]])


def _data_reps(x_f, edges_f, n_bins):
    """Per-bin representative = mean of training values landing in the bin.

    Midpoint reps are badly wrong for discrete features (duplicate quantile
    edges make the midpoint of a {0,1} feature 0.5); the control plane has the
    training data anyway, so it loads the empirical bin mean and falls back to
    the geometric midpoint only for bins no training point hits.
    """
    mids = _bin_centers(edges_f)
    reps = np.zeros(n_bins, np.float32)
    reps[:len(mids)] = mids
    bins = np.sum(x_f[:, None] > edges_f[None, :], axis=1)  # match feature_bins
    sums = np.bincount(bins, weights=x_f, minlength=n_bins)[:n_bins]
    cnts = np.bincount(bins, minlength=n_bins)[:n_bins]
    hit = cnts > 0
    reps[hit] = (sums[hit] / cnts[hit]).astype(np.float32)
    return reps


def map_svm(model: LinearSVM, x_train, *, n_bins=64,
            action_bits: int = 16) -> TableArtifact:
    """Table-per-feature SVM mapping (paper §4.3 / Appendix A.1, option 1).

    vtable[f, b, j] = a_{j,f} * rep(bin b of feature f)  (quantized); the
    hyperplane value is the sum over features plus the intercept.
    """
    edges = _quantile_edges(x_train, n_bins)            # (F, B-1)
    f_dim, m = edges.shape[0], model.weights.shape[0]
    w = np.asarray(model.weights)                       # (m, F) on standardized x
    mean, scale = np.asarray(model.mean), np.asarray(model.scale)
    x_np = np.asarray(x_train, np.float32)
    vtable = np.zeros((f_dim, n_bins, m), np.float32)
    for f in range(f_dim):
        reps = _data_reps(x_np[:, f], edges[f], n_bins)  # raw domain
        reps_std = (reps - mean[f]) / scale[f]
        vtable[f, :, :] = reps_std[:, None] * w[:, f][None, :]
    pad = np.full((f_dim, n_bins - 1), np.inf, np.float32)
    pad[:, :edges.shape[1]] = edges
    return finalize_artifact(TableArtifact(
        edges=jnp.asarray(pad), agg="svm_ovo", n_classes=model.n_classes,
        vtable=quantize_fixed(vtable, action_bits),
        consts=jnp.asarray(np.asarray(model.bias)),
        pairs=model.pairs))


def map_naive_bayes(model: GaussianNB, x_train, *, n_bins=64,
                    action_bits: int = 16) -> TableArtifact:
    """Log-domain NB mapping: vtable[f, b, c] = log P(bin_rep | c).

    The paper multiplies probabilities through paired tables; storing logs and
    summing is the resource-optimal variant it alludes to ("coding the
    results ... rather than normalizing values") and removes the underflow
    error mode of Fig 9.
    """
    edges = _quantile_edges(x_train, n_bins)
    f_dim, c_dim = model.mu.shape[1], model.mu.shape[0]
    mu, var = np.asarray(model.mu), np.asarray(model.var)
    x_np = np.asarray(x_train, np.float32)
    vtable = np.zeros((f_dim, n_bins, c_dim), np.float32)
    for f in range(f_dim):
        reps = _data_reps(x_np[:, f], edges[f], n_bins)
        d = reps[:, None] - mu[None, :, f]
        vtable[f, :, :] = -0.5 * (
            np.log(2 * np.pi * var[None, :, f]) + d * d / var[None, :, f])
    pad = np.full((f_dim, n_bins - 1), np.inf, np.float32)
    pad[:, :edges.shape[1]] = edges
    return finalize_artifact(TableArtifact(
        edges=jnp.asarray(pad), agg="nb_log", n_classes=c_dim,
        vtable=quantize_fixed(vtable, action_bits),
        consts=jnp.asarray(np.asarray(model.log_prior))))


def map_kmeans(model: KMeansModel, x_train, *, n_bins=64,
               action_bits: int = 16, n_classes=None) -> TableArtifact:
    """vtable[f, b, k] = (rep_std(bin) - center[k, f])^2 (quantized)."""
    edges = _quantile_edges(x_train, n_bins)
    centers = np.asarray(model.centers)                 # (K, F) standardized
    mean, scale = np.asarray(model.mean), np.asarray(model.scale)
    f_dim, k_dim = edges.shape[0], centers.shape[0]
    x_np = np.asarray(x_train, np.float32)
    vtable = np.zeros((f_dim, n_bins, k_dim), np.float32)
    for f in range(f_dim):
        reps = (_data_reps(x_np[:, f], edges[f], n_bins) - mean[f]) / scale[f]
        d = reps[:, None] - centers[None, :, f]
        vtable[f, :, :] = d * d
    pad = np.full((f_dim, n_bins - 1), np.inf, np.float32)
    pad[:, :edges.shape[1]] = edges
    return finalize_artifact(TableArtifact(
        edges=jnp.asarray(pad), agg="kmeans",
        n_classes=(n_classes or k_dim),
        vtable=quantize_fixed(vtable, action_bits),
        consts=jnp.asarray(np.zeros(k_dim, np.float32))))
