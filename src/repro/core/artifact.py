"""TableArtifact — the deployable output of IIsy's mapping tool.

The artifact is what the control plane would load into switch tables. Every
array is a *runtime input* to the jitted inference step (never a baked
constant), so retraining swaps tables without recompiling — the paper's
"model updates by table updates only" property (§4.4).

Two families share the container:

Tree ensembles (dt / rf / xgb / iforest):
  edges   (F, U)      union of the ensemble's thresholds per feature (+inf pad)
  ftable  (F, U+1, T) per-union-bin, per-tree code (tree-local bin rank)
  strides (T, F)      mixed-radix strides turning codes into a decision key
  dtable_class (T, S) leaf class id per key              (vote aggregation)
  dtable_value (T, S) quantized leaf payload per key     (weight / path len)

Classical (svm / nb / kmeans):
  edges   (F, U)      quantile bin edges (+inf pad)
  vtable  (F, U+1, M) quantized per-bin partial terms
                      M = hyperplanes | classes | clusters
  consts  (M,)        intercept sums / log priors / zeros
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.quantize import FixedPoint


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class TableArtifact:
    # shared
    edges: jax.Array
    agg: str = dataclasses.field(metadata=dict(static=True))
    # 'vote' | 'wsum_sigmoid' | 'iforest' | 'svm_ovo' | 'nb_log' | 'kmeans'
    n_classes: int = dataclasses.field(metadata=dict(static=True))

    # tree family
    ftable: Optional[jax.Array] = None
    strides: Optional[jax.Array] = None
    dtable_class: Optional[jax.Array] = None
    dtable_value: Optional[FixedPoint] = None

    # classical family
    vtable: Optional[FixedPoint] = None
    consts: Optional[jax.Array] = None

    # svm extras
    pairs: Optional[jax.Array] = None          # (m, 2) class pairs

    # scalars used by aggregation
    base_score: float = dataclasses.field(metadata=dict(static=True), default=0.0)
    learning_rate: float = dataclasses.field(metadata=dict(static=True), default=1.0)
    iforest_subsample: float = dataclasses.field(metadata=dict(static=True), default=256.0)

    @property
    def n_features(self) -> int:
        return self.edges.shape[0]

    @property
    def n_trees(self) -> int:
        return 0 if self.ftable is None else self.ftable.shape[2]
